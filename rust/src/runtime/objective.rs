//! The XLA-backed fitting objective: plugs the tiled PJRT runner into
//! the generic `fit::Objective` interface, so the same L-BFGS/Adam
//! drivers work over either backend.

use super::engine::{Engine, TiledNll};
use crate::fit::Objective;
use crate::linalg::Mat;
use crate::util::error::Result;

/// Weighted MCTM NLL evaluated through the AOT-compiled artifact.
pub struct XlaNll<'a> {
    runner: TiledNll<'a>,
    /// scaled data rows, flat n×J
    y: Vec<f64>,
    weights: Vec<f64>,
}

impl<'a> XlaNll<'a> {
    /// `data` is RAW data; scaling happens here with the same min–max
    /// rule the native backend uses (so both backends see identical
    /// inputs). Pass the scaler from a shared `Design` when comparing.
    pub fn from_scaled(
        engine: &'a Engine,
        j: usize,
        d: usize,
        scaled: &Mat,
        weights: Vec<f64>,
    ) -> Result<Self> {
        assert_eq!(scaled.cols, j);
        let runner = TiledNll::new(engine, j, d)?;
        Ok(XlaNll { runner, y: scaled.data.clone(), weights })
    }

    pub fn n_rows(&self) -> usize {
        self.y.len() / self.runner.j
    }

    /// Forward-only NLL via the fused Pallas artifact.
    pub fn eval(&self, x: &[f64]) -> Result<f64> {
        self.runner.nll_eval(x, &self.y, &self.weights)
    }
}

impl Objective for XlaNll<'_> {
    fn dim(&self) -> usize {
        self.runner.n_params
    }

    fn value_grad_into(&self, x: &[f64], grad: &mut [f64]) -> f64 {
        match self.runner.nll_grad(x, &self.y, &self.weights) {
            Ok((v, g)) => {
                grad.copy_from_slice(&g);
                v
            }
            Err(e) => {
                // surface runtime errors as +inf so the line search backs
                // off rather than crashing mid-fit
                eprintln!("xla objective error: {e:#}");
                grad.fill(0.0);
                f64::INFINITY
            }
        }
    }
}
