//! Versioned, deterministic, zero-dependency persistence for fitted
//! models and coreset sketches — ROADMAP item 1's "fit once, serve
//! forever" pillar.
//!
//! Two artifact kinds share one container format:
//!
//! * **model** — everything [`crate::api::FittedModel`] needs to answer
//!   queries: the model shape (J, d), the free parameter vector x
//!   (β then λ — the cached ϑ and σ are pure bitwise-deterministic
//!   functions of x, so they are recomputed on load, never stored), the
//!   min–max [`crate::basis::Scaler`] state, and the fit / coreset
//!   summary that [`crate::api::Diagnostics`] reports.
//! * **sketch** — a persisted [`crate::api::CoresetReport`]: coreset
//!   rows, weights, hull provenance (`n_hull`), stream provenance
//!   (`n_seen`, method, requested budget) and — on the batch path — the
//!   full-data scaler, which is what lets [`crate::api::Session::refit`]
//!   reproduce a direct fit bit-for-bit without re-reading the data.
//!
//! # Format (v1)
//!
//! Line-oriented ASCII. Every `f64` is serialized as the 16-hex-digit
//! big-endian rendering of [`f64::to_bits`], so round-trips are
//! **bitwise lossless** (including −0.0, subnormals, and the exact FP
//! values determinism pins care about) and the writer is a pure
//! function of the logical content — `save(load(save(m))) == save(m)`
//! byte for byte. Wall-clock fields (`seconds`, `fit_seconds`) and
//! run-local observability (stream stats, degradation counters, batch
//! indices) are deliberately **not** part of the artifact: they vary
//! across runs of the same seed and would break byte-determinism.
//!
//! ```text
//! mctm-artifact v1 model\n     header: magic, version, kind
//! j 2\n                        …typed key-prefixed fields…
//! x 17 3ff0000000000000 …\n    vectors: count then hex words
//! end 0123456789abcdef\n       FNV-1a 64 checksum of every prior byte
//! ```
//!
//! The trailing checksum makes corruption and truncation first-class,
//! typed failures ([`crate::api::ApiError::Artifact`]) instead of
//! garbage models: a reader first verifies the `end` line, then parses
//! strictly (every line's leading token must match the expected key).
//! [`Artifact::save`] writes to a temp file and renames, so a killed
//! process can never leave a half-written artifact under the final
//! name.
//!
//! Compatibility promise: v1 artifacts will remain loadable; any
//! incompatible change bumps the version token and readers keep
//! understanding older versions (an *unknown, newer* version is a typed
//! error naming both versions).

use crate::api::ApiError;
use crate::linalg::Mat;
use std::fmt::Write as _;
use std::path::Path;

/// Format version written by this build (the `v1` header token).
pub const ARTIFACT_VERSION: u32 = 1;

/// Magic token opening every artifact file.
pub const ARTIFACT_MAGIC: &str = "mctm-artifact";

/// Persisted min–max scaler state (`basis::Scaler` without behavior).
#[derive(Clone, Debug, PartialEq)]
pub struct ScalerState {
    pub eps: f64,
    pub mins: Vec<f64>,
    pub maxs: Vec<f64>,
}

/// Persisted query state of a fitted model. See the module doc for
/// what is (and deliberately is not) included.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelArtifact {
    /// number of output components J
    pub j: usize,
    /// Bernstein basis size d
    pub d: usize,
    /// free parameter vector x (β row-major, then λ lower-triangular)
    pub x: Vec<f64>,
    /// min–max scaler fitted with the model
    pub scaler: ScalerState,
    /// final NLL on the (weighted) coreset
    pub fit_nll: f64,
    pub fit_iters: usize,
    pub converged: bool,
    /// registry name of the sampling method that built the coreset
    pub method: String,
    /// requested coreset budget k
    pub requested: usize,
    /// actual coreset size
    pub size: usize,
    /// hull-provenance count
    pub n_hull: usize,
    /// raw rows consumed to build the coreset
    pub n_seen: usize,
    /// Σ coreset weights
    pub total_weight: f64,
}

/// Persisted coreset sketch: what [`crate::api::Session::refit`]
/// consumes to serve new scenarios without re-reading data.
#[derive(Clone, Debug, PartialEq)]
pub struct SketchArtifact {
    /// registry name of the sampling method
    pub method: String,
    /// requested budget k
    pub requested: usize,
    /// hull-provenance count
    pub n_hull: usize,
    /// raw rows consumed to build this sketch
    pub n_seen: usize,
    /// coreset rows on the original data scale
    pub rows: Mat,
    /// per-row weights aligned with `rows`
    pub weights: Vec<f64>,
    /// the full-data scaler (batch sketches; `None` for streamed
    /// sketches, whose direct fit scales on the coreset rows themselves)
    pub scaler: Option<ScalerState>,
}

/// A parsed artifact of either kind.
#[derive(Clone, Debug, PartialEq)]
pub enum Artifact {
    Model(ModelArtifact),
    Sketch(SketchArtifact),
}

impl Artifact {
    /// The kind token written into the header line.
    pub fn kind(&self) -> &'static str {
        match self {
            Artifact::Model(_) => "model",
            Artifact::Sketch(_) => "sketch",
        }
    }

    /// Canonical serialized bytes (pure function of the content).
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = String::new();
        // infallible: fmt::Write on String never errors
        let _ = writeln!(out, "{ARTIFACT_MAGIC} v{ARTIFACT_VERSION} {}", self.kind());
        match self {
            Artifact::Model(m) => write_model(&mut out, m),
            Artifact::Sketch(s) => write_sketch(&mut out, s),
        }
        let crc = fnv1a64(out.as_bytes());
        let _ = writeln!(out, "end {crc:016x}");
        out.into_bytes()
    }

    /// Parse serialized bytes: checksum first, then a strict
    /// line-by-line read. Every failure — wrong magic, newer version,
    /// unknown kind, truncation, bit flips, malformed fields — is a
    /// typed [`ApiError::Artifact`], never a panic.
    pub fn from_bytes(bytes: &[u8]) -> Result<Artifact, ApiError> {
        let text = std::str::from_utf8(bytes)
            .map_err(|_| bad("artifact is not valid UTF-8 (corrupted?)"))?;
        // the `end <crc>` trailer guards everything before it
        let end_at = text
            .rfind("\nend ")
            .ok_or_else(|| bad("truncated artifact: missing `end <checksum>` trailer"))?;
        let body = &text[..end_at + 1]; // includes the trailing '\n'
        let trailer = &text[end_at + 1..];
        let crc_hex = trailer
            .strip_prefix("end ")
            .and_then(|t| t.strip_suffix('\n'))
            .ok_or_else(|| bad("malformed `end` trailer"))?;
        let stored = u64::from_str_radix(crc_hex.trim(), 16)
            .map_err(|_| bad("malformed checksum in `end` trailer"))?;
        let actual = fnv1a64(body.as_bytes());
        if stored != actual {
            return Err(bad(format!(
                "checksum mismatch (stored {stored:016x}, computed {actual:016x}) — \
                 artifact is corrupted or truncated"
            )));
        }
        let mut r = Reader { lines: body.lines() };
        let header = r.raw_line("header")?;
        let mut h = header.split_whitespace();
        match h.next() {
            Some(ARTIFACT_MAGIC) => {}
            _ => return Err(bad(format!("bad magic (expected `{ARTIFACT_MAGIC}`)"))),
        }
        match h.next() {
            Some(v) if v == format!("v{ARTIFACT_VERSION}") => {}
            Some(other) => {
                return Err(bad(format!(
                    "unsupported artifact version `{other}` (this build reads \
                     v{ARTIFACT_VERSION} and older)"
                )))
            }
            None => return Err(bad("header missing version token")),
        }
        let artifact = match h.next() {
            Some("model") => Artifact::Model(read_model(&mut r)?),
            Some("sketch") => Artifact::Sketch(read_sketch(&mut r)?),
            Some(other) => return Err(bad(format!("unknown artifact kind `{other}`"))),
            None => return Err(bad("header missing kind token")),
        };
        if let Some(extra) = r.lines.next() {
            return Err(bad(format!("trailing data after artifact body: `{extra}`")));
        }
        Ok(artifact)
    }

    /// Write atomically: serialize, write `<path>.tmp`, rename into
    /// place — a killed process never leaves a truncated file under the
    /// final name.
    pub fn save(&self, path: &Path) -> Result<(), ApiError> {
        let bytes = self.to_bytes();
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, &bytes).map_err(|e| {
            bad(format!("writing {}: {e}", tmp.display()))
        })?;
        std::fs::rename(&tmp, path).map_err(|e| {
            bad(format!("renaming {} into place: {e}", path.display()))
        })?;
        Ok(())
    }

    /// Read and parse `path`.
    pub fn load(path: &Path) -> Result<Artifact, ApiError> {
        let bytes = std::fs::read(path)
            .map_err(|e| bad(format!("reading {}: {e}", path.display())))?;
        Self::from_bytes(&bytes)
            .map_err(|e| bad(format!("{}: {e}", path.display())))
    }
}

fn bad(reason: impl Into<String>) -> ApiError {
    ApiError::Artifact(reason.into())
}

/// FNV-1a 64-bit — tiny, dependency-free, and plenty to catch the
/// truncation / bit-flip corruption the loader guards against (this is
/// an integrity check, not a cryptographic one).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

// ---- writers ---------------------------------------------------------

fn hex(v: f64) -> String {
    format!("{:016x}", v.to_bits())
}

fn write_vec(out: &mut String, key: &str, v: &[f64]) {
    let _ = write!(out, "{key} {}", v.len());
    for x in v {
        let _ = write!(out, " {}", hex(*x));
    }
    out.push('\n');
}

fn write_scaler(out: &mut String, s: &ScalerState) {
    let _ = writeln!(out, "eps {}", hex(s.eps));
    write_vec(out, "mins", &s.mins);
    write_vec(out, "maxs", &s.maxs);
}

fn write_model(out: &mut String, m: &ModelArtifact) {
    let _ = writeln!(out, "j {}", m.j);
    let _ = writeln!(out, "d {}", m.d);
    write_vec(out, "x", &m.x);
    write_scaler(out, &m.scaler);
    let _ = writeln!(out, "fit_nll {}", hex(m.fit_nll));
    let _ = writeln!(out, "fit_iters {}", m.fit_iters);
    let _ = writeln!(out, "converged {}", u8::from(m.converged));
    let _ = writeln!(out, "method {}", m.method);
    let _ = writeln!(out, "requested {}", m.requested);
    let _ = writeln!(out, "size {}", m.size);
    let _ = writeln!(out, "n_hull {}", m.n_hull);
    let _ = writeln!(out, "n_seen {}", m.n_seen);
    let _ = writeln!(out, "total_weight {}", hex(m.total_weight));
}

fn write_sketch(out: &mut String, s: &SketchArtifact) {
    let _ = writeln!(out, "method {}", s.method);
    let _ = writeln!(out, "requested {}", s.requested);
    let _ = writeln!(out, "n_hull {}", s.n_hull);
    let _ = writeln!(out, "n_seen {}", s.n_seen);
    let _ = writeln!(out, "rows {} {}", s.rows.rows, s.rows.cols);
    for r in 0..s.rows.rows {
        let row = s.rows.row(r);
        for (c, x) in row.iter().enumerate() {
            if c > 0 {
                out.push(' ');
            }
            out.push_str(&hex(*x));
        }
        out.push('\n');
    }
    write_vec(out, "weights", &s.weights);
    match &s.scaler {
        None => {
            let _ = writeln!(out, "scaler 0");
        }
        Some(sc) => {
            let _ = writeln!(out, "scaler 1");
            write_scaler(out, sc);
        }
    }
}

// ---- strict reader ---------------------------------------------------

struct Reader<'a> {
    lines: std::str::Lines<'a>,
}

impl<'a> Reader<'a> {
    fn raw_line(&mut self, what: &str) -> Result<&'a str, ApiError> {
        self.lines
            .next()
            .ok_or_else(|| bad(format!("unexpected end of artifact (wanted {what})")))
    }

    /// Next line, validated to start with `key`; returns the remaining
    /// whitespace-separated tokens.
    fn field(&mut self, key: &str) -> Result<std::str::SplitWhitespace<'a>, ApiError> {
        let line = self.raw_line(key)?;
        let mut toks = line.split_whitespace();
        match toks.next() {
            Some(k) if k == key => Ok(toks),
            Some(other) => Err(bad(format!("expected field `{key}`, found `{other}`"))),
            None => Err(bad(format!("expected field `{key}`, found empty line"))),
        }
    }

    fn usize_field(&mut self, key: &str) -> Result<usize, ApiError> {
        let mut toks = self.field(key)?;
        let tok = toks
            .next()
            .ok_or_else(|| bad(format!("field `{key}` missing its value")))?;
        tok.parse()
            .map_err(|_| bad(format!("field `{key}`: `{tok}` is not a count")))
    }

    fn f64_field(&mut self, key: &str) -> Result<f64, ApiError> {
        let mut toks = self.field(key)?;
        let tok = toks
            .next()
            .ok_or_else(|| bad(format!("field `{key}` missing its value")))?;
        parse_hex_f64(key, tok)
    }

    fn bool_field(&mut self, key: &str) -> Result<bool, ApiError> {
        match self.usize_field(key)? {
            0 => Ok(false),
            1 => Ok(true),
            other => Err(bad(format!("field `{key}`: `{other}` is not a 0/1 flag"))),
        }
    }

    fn string_field(&mut self, key: &str) -> Result<String, ApiError> {
        let mut toks = self.field(key)?;
        let tok = toks
            .next()
            .ok_or_else(|| bad(format!("field `{key}` missing its value")))?;
        Ok(tok.to_string())
    }

    fn vec_field(&mut self, key: &str) -> Result<Vec<f64>, ApiError> {
        let mut toks = self.field(key)?;
        let n: usize = toks
            .next()
            .and_then(|t| t.parse().ok())
            .ok_or_else(|| bad(format!("field `{key}` missing its element count")))?;
        if n > MAX_ELEMS {
            return Err(bad(format!("field `{key}`: count {n} is implausibly large")));
        }
        let mut out = Vec::with_capacity(n);
        for tok in toks {
            out.push(parse_hex_f64(key, tok)?);
        }
        if out.len() != n {
            return Err(bad(format!(
                "field `{key}`: declared {n} elements, found {}",
                out.len()
            )));
        }
        Ok(out)
    }

    fn scaler(&mut self) -> Result<ScalerState, ApiError> {
        let eps = self.f64_field("eps")?;
        let mins = self.vec_field("mins")?;
        let maxs = self.vec_field("maxs")?;
        if mins.len() != maxs.len() {
            return Err(bad("scaler mins/maxs length mismatch"));
        }
        Ok(ScalerState { eps, mins, maxs })
    }
}

/// Upper bound on any serialized element count — generous (a 1e8-cell
/// sketch) but finite, so a corrupted count can't trigger an absurd
/// allocation before the per-line length check catches it.
const MAX_ELEMS: usize = 100_000_000;

fn parse_hex_f64(key: &str, tok: &str) -> Result<f64, ApiError> {
    if tok.len() != 16 {
        return Err(bad(format!(
            "field `{key}`: `{tok}` is not a 16-hex-digit f64"
        )));
    }
    u64::from_str_radix(tok, 16)
        .map(f64::from_bits)
        .map_err(|_| bad(format!("field `{key}`: `{tok}` is not a 16-hex-digit f64")))
}

fn read_model(r: &mut Reader) -> Result<ModelArtifact, ApiError> {
    let j = r.usize_field("j")?;
    let d = r.usize_field("d")?;
    let x = r.vec_field("x")?;
    let scaler = r.scaler()?;
    let fit_nll = r.f64_field("fit_nll")?;
    let fit_iters = r.usize_field("fit_iters")?;
    let converged = r.bool_field("converged")?;
    let method = r.string_field("method")?;
    let requested = r.usize_field("requested")?;
    let size = r.usize_field("size")?;
    let n_hull = r.usize_field("n_hull")?;
    let n_seen = r.usize_field("n_seen")?;
    let total_weight = r.f64_field("total_weight")?;
    // shape coherence — catches artifacts assembled by hand or damaged
    // in ways the checksum can't see (it only covers the stored bytes)
    if j == 0 || d < 2 {
        return Err(bad(format!("implausible model shape J={j}, d={d}")));
    }
    let expect = j * d + j * (j - 1) / 2;
    if x.len() != expect {
        return Err(bad(format!(
            "parameter vector has {} entries, shape J={j} d={d} needs {expect}",
            x.len()
        )));
    }
    if scaler.mins.len() != j {
        return Err(bad(format!(
            "scaler covers {} columns, model has J={j}",
            scaler.mins.len()
        )));
    }
    Ok(ModelArtifact {
        j,
        d,
        x,
        scaler,
        fit_nll,
        fit_iters,
        converged,
        method,
        requested,
        size,
        n_hull,
        n_seen,
        total_weight,
    })
}

fn read_sketch(r: &mut Reader) -> Result<SketchArtifact, ApiError> {
    let method = r.string_field("method")?;
    let requested = r.usize_field("requested")?;
    let n_hull = r.usize_field("n_hull")?;
    let n_seen = r.usize_field("n_seen")?;
    let mut dims = r.field("rows")?;
    let n: usize = dims
        .next()
        .and_then(|t| t.parse().ok())
        .ok_or_else(|| bad("field `rows` missing its row count"))?;
    let cols: usize = dims
        .next()
        .and_then(|t| t.parse().ok())
        .ok_or_else(|| bad("field `rows` missing its column count"))?;
    let cells = n
        .checked_mul(cols)
        .filter(|&c| c <= MAX_ELEMS)
        .ok_or_else(|| bad(format!("implausible sketch shape {n} × {cols}")))?;
    let mut data = Vec::with_capacity(cells);
    for i in 0..n {
        let line = r.raw_line("a sketch row")?;
        let before = data.len();
        for tok in line.split_whitespace() {
            data.push(parse_hex_f64("rows", tok)?);
        }
        if data.len() - before != cols {
            return Err(bad(format!(
                "sketch row {i} has {} values, expected {cols}",
                data.len() - before
            )));
        }
    }
    let rows = Mat::from_vec(n, cols, data);
    let weights = r.vec_field("weights")?;
    if weights.len() != n {
        return Err(bad(format!(
            "sketch has {n} rows but {} weights",
            weights.len()
        )));
    }
    let scaler = if r.bool_field("scaler")? {
        let sc = r.scaler()?;
        if sc.mins.len() != cols {
            return Err(bad(format!(
                "sketch scaler covers {} columns, rows have {cols}",
                sc.mins.len()
            )));
        }
        Some(sc)
    } else {
        None
    };
    Ok(SketchArtifact {
        method,
        requested,
        n_hull,
        n_seen,
        rows,
        weights,
        scaler,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_model() -> ModelArtifact {
        ModelArtifact {
            j: 2,
            d: 3,
            x: vec![-2.0, 0.5, 0.5, -2.0, 0.5, 0.5, 0.25],
            scaler: ScalerState {
                eps: 0.01,
                mins: vec![-1.0, -3.5],
                maxs: vec![2.0, 4.5],
            },
            fit_nll: 1.2345678901234567,
            fit_iters: 42,
            converged: true,
            method: "l2-hull".into(),
            requested: 100,
            size: 104,
            n_hull: 20,
            n_seen: 10_000,
            total_weight: 9_999.5,
        }
    }

    fn sample_sketch(scaler: bool) -> SketchArtifact {
        SketchArtifact {
            method: "ellipsoid-hull".into(),
            requested: 3,
            n_hull: 1,
            n_seen: 77,
            rows: Mat::from_vec(3, 2, vec![0.1, -0.2, 1.5, f64::MIN_POSITIVE, -0.0, 3.25]),
            weights: vec![10.0, 20.5, 46.5],
            scaler: scaler.then(|| ScalerState {
                eps: 0.01,
                mins: vec![-0.0, -0.2],
                maxs: vec![1.5, 3.25],
            }),
        }
    }

    #[test]
    fn model_roundtrip_is_byte_identical() {
        let a = Artifact::Model(sample_model());
        let bytes = a.to_bytes();
        let b = Artifact::from_bytes(&bytes).unwrap();
        assert_eq!(a, b);
        assert_eq!(bytes, b.to_bytes());
    }

    #[test]
    fn sketch_roundtrip_is_byte_identical_with_and_without_scaler() {
        for with_scaler in [false, true] {
            let a = Artifact::Sketch(sample_sketch(with_scaler));
            let bytes = a.to_bytes();
            let b = Artifact::from_bytes(&bytes).unwrap();
            assert_eq!(a, b);
            assert_eq!(bytes, b.to_bytes());
        }
    }

    #[test]
    fn special_float_values_survive_bitwise() {
        let mut m = sample_model();
        m.x[0] = -0.0;
        m.x[1] = f64::MIN_POSITIVE / 2.0; // subnormal
        m.fit_nll = f64::INFINITY;
        m.total_weight = f64::NAN;
        let bytes = Artifact::Model(m).to_bytes();
        let Artifact::Model(back) = Artifact::from_bytes(&bytes).unwrap() else {
            panic!("kind changed");
        };
        assert_eq!(back.x[0].to_bits(), (-0.0f64).to_bits());
        assert_eq!(back.x[1].to_bits(), (f64::MIN_POSITIVE / 2.0).to_bits());
        assert!(back.fit_nll.is_infinite());
        assert!(back.total_weight.is_nan());
    }

    #[test]
    fn truncation_at_every_prefix_is_a_typed_error() {
        let bytes = Artifact::Model(sample_model()).to_bytes();
        for cut in 0..bytes.len() {
            match Artifact::from_bytes(&bytes[..cut]) {
                Err(ApiError::Artifact(_)) => {}
                other => panic!("prefix of {cut} bytes: expected typed error, got {other:?}"),
            }
        }
    }

    #[test]
    fn bit_flips_are_typed_errors_or_exact_field_rejections() {
        let bytes = Artifact::Sketch(sample_sketch(true)).to_bytes();
        // flip a hex digit inside the body: checksum must catch it
        let mut corrupt = bytes.clone();
        let at = bytes.len() / 2;
        corrupt[at] = if corrupt[at] == b'0' { b'1' } else { b'0' };
        assert!(matches!(
            Artifact::from_bytes(&corrupt),
            Err(ApiError::Artifact(_))
        ));
    }

    #[test]
    fn wrong_magic_version_and_kind_are_typed_errors() {
        let good = String::from_utf8(Artifact::Model(sample_model()).to_bytes()).unwrap();
        for (from, to) in [
            ("mctm-artifact v1 model", "wrong-magic v1 model"),
            ("mctm-artifact v1 model", "mctm-artifact v99 model"),
            ("mctm-artifact v1 model", "mctm-artifact v1 flavor"),
        ] {
            let mangled = good.replacen(from, to, 1);
            // re-seal so only the header is wrong, not the checksum
            let body_end = mangled.rfind("\nend ").unwrap() + 1;
            let mut resealed = mangled[..body_end].to_string();
            let crc = fnv1a64(resealed.as_bytes());
            resealed.push_str(&format!("end {crc:016x}\n"));
            match Artifact::from_bytes(resealed.as_bytes()) {
                Err(ApiError::Artifact(msg)) => {
                    assert!(
                        msg.contains("magic") || msg.contains("version") || msg.contains("kind"),
                        "unexpected message: {msg}"
                    );
                }
                other => panic!("expected typed error, got {other:?}"),
            }
        }
    }

    #[test]
    fn save_load_through_disk() {
        let dir = std::env::temp_dir().join("mctm_artifact_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("m.mctm");
        let a = Artifact::Model(sample_model());
        a.save(&path).unwrap();
        assert_eq!(Artifact::load(&path).unwrap(), a);
        // temp file must not linger
        assert!(!path.with_extension("tmp").exists());
        // missing file is typed, names the path
        let missing = dir.join("nope.mctm");
        match Artifact::load(&missing) {
            Err(ApiError::Artifact(msg)) => assert!(msg.contains("nope.mctm")),
            other => panic!("expected typed error, got {other:?}"),
        }
    }
}
