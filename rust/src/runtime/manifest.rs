//! Parse `artifacts/manifest.json` (written by aot.py). No `serde`
//! offline, so this is a purpose-built parser for exactly the JSON the
//! build emits — flat objects, string/number/array-of-int fields.

use crate::anyhow;
use crate::util::error::{Context, Result};
use std::path::{Path, PathBuf};

/// One AOT-compiled entry point.
#[derive(Clone, Debug)]
pub struct ManifestEntry {
    pub name: String,
    pub kind: String,
    pub j: usize,
    pub d: usize,
    pub dim: usize,
    pub tile: usize,
    pub n_params: usize,
}

/// The artifact registry.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub tile: usize,
    pub entries: Vec<ManifestEntry>,
}

impl Manifest {
    /// Load from `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest JSON text.
    pub fn parse(text: &str, dir: &Path) -> Result<Self> {
        let arr_start = text
            .find("\"entries\"")
            .ok_or_else(|| anyhow!("manifest missing entries"))?;
        // The top-level "tile" must be searched OUTSIDE the entries
        // array: JSON key order is not guaranteed, and when "entries"
        // precedes the top-level "tile" a whole-document scan would
        // silently pick the first entry's per-kernel tile instead.
        let tile = extract_usize(&text[..arr_start], "\"tile\"")
            .or_else(|| {
                // entries listed first: the top-level key lives after
                // the array, so resume the scan past its MATCHING ']'
                // (entries hold nested arrays like "inputs": [[15]],
                // so the first ']' is not the array's end)
                let after = skip_array(text, arr_start)?;
                extract_usize(&text[after..], "\"tile\"")
            })
            .ok_or_else(|| anyhow!("manifest missing top-level tile"))?;
        let mut entries = Vec::new();
        // entries are objects inside the "entries" array; split on '{'
        // after the array opens
        let body = &text[arr_start..];
        for obj in body.split('{').skip(1) {
            let end = obj.find('}').unwrap_or(obj.len());
            let obj = &obj[..end];
            let name = extract_string(obj, "\"name\"")
                .ok_or_else(|| anyhow!("entry missing name"))?;
            let kind = extract_string(obj, "\"kind\"")
                .ok_or_else(|| anyhow!("entry missing kind"))?;
            entries.push(ManifestEntry {
                name,
                kind,
                j: extract_usize(obj, "\"j\"").unwrap_or(0),
                d: extract_usize(obj, "\"d\"").unwrap_or(0),
                dim: extract_usize(obj, "\"dim\"").unwrap_or(0),
                tile: extract_usize(obj, "\"tile\"").unwrap_or(tile),
                n_params: extract_usize(obj, "\"n_params\"").unwrap_or(0),
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), tile, entries })
    }

    /// Find the nll_grad entry for a model shape.
    pub fn nll_grad(&self, j: usize, d: usize) -> Option<&ManifestEntry> {
        self.entries
            .iter()
            .find(|e| e.kind == "nll_grad" && e.j == j && e.d == d)
    }

    /// Find the nll_eval entry for a model shape.
    pub fn nll_eval(&self, j: usize, d: usize) -> Option<&ManifestEntry> {
        self.entries
            .iter()
            .find(|e| e.kind == "nll_eval" && e.j == j && e.d == d)
    }

    /// Find gram / leverage entries for stacked dimension D.
    pub fn gram(&self, dim: usize) -> Option<&ManifestEntry> {
        self.entries.iter().find(|e| e.kind == "gram" && e.dim == dim)
    }

    pub fn leverage(&self, dim: usize) -> Option<&ManifestEntry> {
        self.entries
            .iter()
            .find(|e| e.kind == "leverage" && e.dim == dim)
    }

    /// Absolute path of an entry's HLO text.
    pub fn path_of(&self, e: &ManifestEntry) -> PathBuf {
        self.dir.join(format!("{}.hlo.txt", e.name))
    }
}

/// Index just past the `]` closing the JSON array whose key starts at
/// `key_at`. Tracks nesting depth (entries hold nested arrays like
/// `"inputs": [[15]]`) and string literals (so a bracket inside a name
/// can't unbalance the scan). `None` if the array never opens or never
/// closes.
fn skip_array(text: &str, key_at: usize) -> Option<usize> {
    let bytes = text.as_bytes();
    let open = key_at + text[key_at..].find('[')?;
    let mut depth = 0usize;
    let mut in_string = false;
    let mut escaped = false;
    for (off, &b) in bytes[open..].iter().enumerate() {
        if in_string {
            if escaped {
                escaped = false;
            } else if b == b'\\' {
                escaped = true;
            } else if b == b'"' {
                in_string = false;
            }
            continue;
        }
        match b {
            b'"' => in_string = true,
            b'[' => depth += 1,
            b']' => {
                depth -= 1;
                if depth == 0 {
                    return Some(open + off + 1);
                }
            }
            _ => {}
        }
    }
    None
}

fn extract_string(obj: &str, key: &str) -> Option<String> {
    let at = obj.find(key)?;
    let rest = &obj[at + key.len()..];
    let colon = rest.find(':')?;
    let rest = rest[colon + 1..].trim_start();
    let rest = rest.strip_prefix('"')?;
    let end = rest.find('"')?;
    Some(rest[..end].to_string())
}

fn extract_usize(obj: &str, key: &str) -> Option<usize> {
    let at = obj.find(key)?;
    let rest = &obj[at + key.len()..];
    let colon = rest.find(':')?;
    let rest = rest[colon + 1..].trim_start();
    let end = rest
        .find(|c: char| !c.is_ascii_digit())
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "dtype": "f64", "tile": 512,
      "entries": [
        {"name": "nll_grad_j2_d7_t512", "kind": "nll_grad", "j": 2, "d": 7,
         "tile": 512, "n_params": 15, "inputs": [[15],[512,2],[512]],
         "outputs": [[],[15]]},
        {"name": "gram_d14_t512", "kind": "gram", "dim": 14, "tile": 512,
         "inputs": [[512,14]], "outputs": [[14,14]]}
      ]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.tile, 512);
        assert_eq!(m.entries.len(), 2);
        let e = m.nll_grad(2, 7).unwrap();
        assert_eq!(e.n_params, 15);
        assert_eq!(e.tile, 512);
        let g = m.gram(14).unwrap();
        assert_eq!(g.dim, 14);
        assert!(m.nll_grad(5, 7).is_none());
        assert_eq!(
            m.path_of(e),
            PathBuf::from("/tmp/a/nll_grad_j2_d7_t512.hlo.txt")
        );
    }

    /// Key-order permutation regression: `entries` listed BEFORE the
    /// top-level `tile` (valid JSON — key order is never guaranteed).
    /// The old whole-document scan silently picked the first entry's
    /// per-kernel tile (1024 here) instead of the top-level 512.
    const SAMPLE_ENTRIES_FIRST: &str = r#"{
      "entries": [
        {"name": "nll_grad_j2_d7_t1024", "kind": "nll_grad", "j": 2, "d": 7,
         "tile": 1024, "n_params": 15, "inputs": [[15],[1024,2],[1024]],
         "outputs": [[],[15]]},
        {"name": "gram_d14_t1024", "kind": "gram", "dim": 14, "tile": 1024,
         "inputs": [[1024,14]], "outputs": [[14,14]]}
      ],
      "dtype": "f64", "tile": 512
    }"#;

    #[test]
    fn entries_before_toplevel_tile_parses_the_right_tile() {
        let m = Manifest::parse(SAMPLE_ENTRIES_FIRST, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.tile, 512, "must not pick an entry's per-kernel tile");
        assert_eq!(m.entries.len(), 2);
        // per-entry tiles keep their own values
        assert_eq!(m.nll_grad(2, 7).unwrap().tile, 1024);
        assert_eq!(m.gram(14).unwrap().tile, 1024);
    }

    #[test]
    fn missing_toplevel_tile_is_an_error_not_an_entry_tile() {
        // entries have tiles but the document has no top-level tile at
        // all: must error, not silently adopt 1024
        let text = r#"{
          "entries": [
            {"name": "gram_d14_t1024", "kind": "gram", "dim": 14,
             "tile": 1024, "inputs": [[1024,14]], "outputs": [[14,14]]}
          ]
        }"#;
        let err = Manifest::parse(text, Path::new("/tmp/a")).unwrap_err();
        assert!(format!("{err:#}").contains("top-level tile"));
    }

    #[test]
    fn skip_array_handles_nesting_and_strings() {
        let text = r#""entries": [[1,2],["a]b",[3]]] , "tile": 7"#;
        let after = skip_array(text, 0).unwrap();
        assert_eq!(&text[after..after + 2], " ,");
        // unterminated array
        assert!(skip_array(r#""entries": [[1,2]"#, 0).is_none());
        // no array at all
        assert!(skip_array(r#""entries": 3"#, 0).is_none());
    }

    #[test]
    fn real_manifest_parses_when_built() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.nll_grad(2, 7).is_some());
            assert!(m.gram(14).is_some());
        }
    }
}
