//! PJRT runtime (L3 ↔ L2 bridge): loads the HLO-text artifacts emitted
//! by `python/compile/aot.py`, compiles them once on the PJRT CPU
//! client, and exposes typed, tile-padded execution to the coordinator.
//! Python is never on this path — the binary is self-contained once
//! `make artifacts` has run.

pub mod artifact;
pub mod engine;
pub mod manifest;
pub mod objective;

pub use artifact::{Artifact, ModelArtifact, ScalerState, SketchArtifact};
pub use engine::{Engine, TiledNll};
pub use manifest::{Manifest, ManifestEntry};
pub use objective::XlaNll;
