//! The PJRT engine: compile-once executable cache + tile-padded
//! execution of the AOT entry points.
//!
//! Pattern from /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! Artifacts have fixed shapes (tile T rows); the tiled runners pad the
//! last tile with zero-weight rows, so any n works.
//!
//! The `xla` crate is not available in the offline registry, so the
//! whole PJRT surface is behind the `xla` cargo feature. Without it a
//! stub `Engine` with the same signatures is compiled whose constructor
//! reports "runtime unavailable" — every caller (CLI `check`, the xla
//! backend, the benches) already degrades gracefully on that error.

use super::manifest::{Manifest, ManifestEntry};
use crate::anyhow;
use crate::util::error::Result;
use std::path::Path;

#[cfg(feature = "xla")]
use crate::util::error::Context;
#[cfg(feature = "xla")]
use std::collections::HashMap;

/// Compile-once cache of PJRT executables, keyed by artifact name.
#[cfg(feature = "xla")]
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: std::cell::RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
}

#[cfg(feature = "xla")]
impl Engine {
    /// Create a CPU engine over an artifact directory.
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, manifest, cache: Default::default() })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Get (compiling if needed) the executable for a manifest entry.
    pub fn executable(
        &self,
        entry: &ManifestEntry,
    ) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(&entry.name) {
            return Ok(exe.clone());
        }
        let path = self.manifest.path_of(entry);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .with_context(|| format!("loading {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::rc::Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {}", entry.name))?,
        );
        self.cache
            .borrow_mut()
            .insert(entry.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Execute an entry with f64 input buffers of the given shapes and
    /// return the flat f64 outputs (the AOT side lowers with
    /// return_tuple=True).
    pub fn run_f64(
        &self,
        entry: &ManifestEntry,
        inputs: &[(&[f64], &[i64])],
    ) -> Result<Vec<Vec<f64>>> {
        let exe = self.executable(entry)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, shape)| {
                xla::Literal::vec1(data)
                    .reshape(shape)
                    .context("shaping input literal")
            })
            .collect::<Result<_>>()?;
        let result = exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .context("fetching result")?;
        let parts = result.to_tuple().context("untupling result")?;
        parts
            .into_iter()
            .map(|lit| lit.to_vec::<f64>().context("reading f64 output"))
            .collect()
    }
}

/// Stub engine compiled when the `xla` feature is off: same public
/// surface, but the constructor always reports the runtime as
/// unavailable (and the methods are therefore unreachable at runtime).
#[cfg(not(feature = "xla"))]
pub struct Engine {
    pub manifest: Manifest,
}

#[cfg(not(feature = "xla"))]
impl Engine {
    /// Always fails: the binary was built without the `xla` feature.
    pub fn new(artifact_dir: &Path) -> Result<Self> {
        let _ = artifact_dir;
        Err(anyhow!(
            "PJRT runtime unavailable: built without the `xla` cargo feature \
             (rebuild with `--features xla` in an environment that has the xla crate)"
        ))
    }

    pub fn platform(&self) -> String {
        "unavailable (xla feature disabled)".to_string()
    }

    pub fn executable(&self, entry: &ManifestEntry) -> Result<()> {
        Err(anyhow!("cannot compile {}: built without the `xla` feature", entry.name))
    }

    pub fn run_f64(
        &self,
        entry: &ManifestEntry,
        inputs: &[(&[f64], &[i64])],
    ) -> Result<Vec<Vec<f64>>> {
        let _ = inputs;
        Err(anyhow!("cannot run {}: built without the `xla` feature", entry.name))
    }
}

/// Tiled weighted-NLL (+grad) runner over an arbitrary-n design:
/// splits (y, w) into fixed-size tiles, pads the last tile with
/// weight-0 rows, accumulates value and gradient. Tiles are built
/// lazily one at a time (peak memory stays O(tile), not O(n)); the
/// padding is memcpy-bound and PJRT execution is single-threaded, so
/// there is nothing for the worker pool to win here.
pub struct TiledNll<'a> {
    pub engine: &'a Engine,
    pub j: usize,
    pub d: usize,
    grad_entry: ManifestEntry,
    eval_entry: Option<ManifestEntry>,
    pub tile: usize,
    pub n_params: usize,
}

impl<'a> TiledNll<'a> {
    pub fn new(engine: &'a Engine, j: usize, d: usize) -> Result<Self> {
        let grad_entry = engine
            .manifest
            .nll_grad(j, d)
            .ok_or_else(|| {
                anyhow!("no nll_grad artifact for J={j}, d={d}; re-run aot with --configs")
            })?
            .clone();
        let eval_entry = engine.manifest.nll_eval(j, d).cloned();
        Ok(TiledNll {
            engine,
            j,
            d,
            tile: grad_entry.tile,
            n_params: grad_entry.n_params,
            grad_entry,
            eval_entry,
        })
    }

    /// Weighted NLL + gradient over scaled data rows `y` (n × J,
    /// row-major flat) with weights `w` (empty = unweighted).
    pub fn nll_grad(&self, params: &[f64], y: &[f64], w: &[f64]) -> Result<(f64, Vec<f64>)> {
        assert_eq!(params.len(), self.n_params);
        let n = y.len() / self.j;
        let mut total = 0.0;
        let mut grad = vec![0.0; self.n_params];
        for (ty, tw) in self.build_tiles(y, w, n) {
            let outs = self.engine.run_f64(
                &self.grad_entry,
                &[
                    (params, &[self.n_params as i64]),
                    (&ty, &[self.tile as i64, self.j as i64]),
                    (&tw, &[self.tile as i64]),
                ],
            )?;
            total += outs[0][0];
            for (g, o) in grad.iter_mut().zip(&outs[1]) {
                *g += o;
            }
        }
        Ok((total, grad))
    }

    /// Forward-only weighted NLL through the fused Pallas kernel.
    pub fn nll_eval(&self, params: &[f64], y: &[f64], w: &[f64]) -> Result<f64> {
        let entry = self
            .eval_entry
            .as_ref()
            .ok_or_else(|| anyhow!("no nll_eval artifact for J={}, d={}", self.j, self.d))?;
        let n = y.len() / self.j;
        let mut total = 0.0;
        for (ty, tw) in self.build_tiles(y, w, n) {
            let outs = self.engine.run_f64(
                entry,
                &[
                    (params, &[self.n_params as i64]),
                    (&ty, &[self.tile as i64, self.j as i64]),
                    (&tw, &[self.tile as i64]),
                ],
            )?;
            total += outs[0][0];
        }
        Ok(total)
    }

    /// Iterate padded tiles lazily: (y_tile flat T·J, w_tile T).
    fn build_tiles<'b>(
        &'b self,
        y: &'b [f64],
        w: &'b [f64],
        n: usize,
    ) -> impl Iterator<Item = (Vec<f64>, Vec<f64>)> + 'b {
        let t = self.tile;
        let j = self.j;
        let n_tiles = n.div_ceil(t);
        (0..n_tiles).map(move |k| {
            let lo = k * t;
            let hi = ((k + 1) * t).min(n);
            let mut ty = vec![0.5; t * j]; // pad with interior value 0.5
            let mut tw = vec![0.0; t];
            ty[..(hi - lo) * j].copy_from_slice(&y[lo * j..hi * j]);
            for i in lo..hi {
                tw[i - lo] = if w.is_empty() { 1.0 } else { w[i] };
            }
            (ty, tw)
        })
    }
}

/// Tiled leverage-score pipeline over the stacked matrix (n × D):
/// pass 1 accumulates the Gram via the `gram` artifact, pass 2 scores
/// all rows via the `leverage` artifact given L⁻¹ from the coordinator.
pub struct TiledLeverage<'a> {
    pub engine: &'a Engine,
    gram_entry: ManifestEntry,
    lev_entry: ManifestEntry,
    pub dim: usize,
    pub tile: usize,
}

impl<'a> TiledLeverage<'a> {
    pub fn new(engine: &'a Engine, dim: usize) -> Result<Self> {
        let gram_entry = engine
            .manifest
            .gram(dim)
            .ok_or_else(|| anyhow!("no gram artifact for D={dim}"))?
            .clone();
        let lev_entry = engine
            .manifest
            .leverage(dim)
            .ok_or_else(|| anyhow!("no leverage artifact for D={dim}"))?
            .clone();
        let tile = gram_entry.tile;
        Ok(TiledLeverage { engine, gram_entry, lev_entry, dim, tile })
    }

    /// Pass 1: Gram matrix (D×D, row-major flat) of the n×D matrix `x`.
    pub fn gram(&self, x: &[f64]) -> Result<Vec<f64>> {
        let n = x.len() / self.dim;
        let mut g = vec![0.0; self.dim * self.dim];
        for tx in self.build_tiles(x, n) {
            let outs = self.engine.run_f64(
                &self.gram_entry,
                &[(&tx, &[self.tile as i64, self.dim as i64])],
            )?;
            for (gi, o) in g.iter_mut().zip(&outs[0]) {
                *gi += o;
            }
        }
        Ok(g)
    }

    /// Pass 2: leverage scores of all n rows given L⁻¹ (D×D flat).
    pub fn scores(&self, x: &[f64], linv: &[f64]) -> Result<Vec<f64>> {
        let n = x.len() / self.dim;
        let mut out = Vec::with_capacity(n);
        let mut taken = 0usize;
        for tx in self.build_tiles(x, n) {
            let outs = self.engine.run_f64(
                &self.lev_entry,
                &[
                    (&tx, &[self.tile as i64, self.dim as i64]),
                    (linv, &[self.dim as i64, self.dim as i64]),
                ],
            )?;
            let remain = n - taken;
            let take = remain.min(self.tile);
            out.extend_from_slice(&outs[0][..take]);
            taken += take;
        }
        Ok(out)
    }

    /// Iterate padded tiles lazily (zero rows add nothing to the Gram
    /// and score as 0); peak memory stays O(tile).
    fn build_tiles<'b>(&'b self, x: &'b [f64], n: usize) -> impl Iterator<Item = Vec<f64>> + 'b {
        let t = self.tile;
        let d = self.dim;
        let n_tiles = n.div_ceil(t);
        (0..n_tiles).map(move |k| {
            let lo = k * t;
            let hi = ((k + 1) * t).min(n);
            let mut tx = vec![0.0; t * d];
            tx[..(hi - lo) * d].copy_from_slice(&x[lo * d..hi * d]);
            tx
        })
    }
}
