//! Bernstein polynomial basis for the semi-parametric marginal
//! transformations h̃_j(y) = a_j(y)ᵀ ϑ_j (paper §1.1).
//!
//! The basis of degree m = d−1 on [0,1] is
//!   b_{k,m}(x) = C(m,k) x^k (1−x)^{m−k},  k = 0..m,
//! with derivative  b'_{k,m}(x) = m (b_{k−1,m−1}(x) − b_{k,m−1}(x)).
//! With monotonically increasing coefficients ϑ the expansion is strictly
//! increasing and a'(x)ᵀϑ > 0 — which is what keeps the log term of the
//! MCTM likelihood finite.
//!
//! Raw data is min–max scaled into [eps, 1−eps] per output component
//! (the paper's "negative value correction" practice, footnote 1/3).

use crate::linalg::Mat;
use crate::util::parallel::{Pool, ROW_CHUNK};

/// Bernstein basis of fixed degree `m` (so `d = m + 1` basis functions).
#[derive(Clone, Copy, Debug)]
pub struct Bernstein {
    /// polynomial degree m
    pub degree: usize,
}

impl Bernstein {
    pub fn new(degree: usize) -> Self {
        assert!(degree >= 1, "Bernstein degree must be ≥ 1");
        Bernstein { degree }
    }

    /// Number of basis functions d = m + 1.
    #[inline]
    pub fn dim(&self) -> usize {
        self.degree + 1
    }

    /// Evaluate all d basis functions at x ∈ [0,1] into `out`.
    ///
    /// Uses the stable iterative scheme: powers of x forward, powers of
    /// (1−x) backward, binomials by recurrence — no factorial overflow up
    /// to degree ~50.
    pub fn eval_into(&self, x: f64, out: &mut [f64]) {
        let m = self.degree;
        debug_assert_eq!(out.len(), m + 1);
        let xc = 1.0 - x;
        // out[k] = C(m,k) x^k (1-x)^(m-k)
        // accumulate forward: start with (1-x)^m, multiply by x/(1-x)·C-ratio.
        // To avoid dividing by (1-x)=0, do two passes instead:
        // pass 1: out[k] = C(m,k) x^k ; pass 2: multiply by xc^{m-k}.
        let mut binom = 1.0f64; // C(m,0)
        let mut xpow = 1.0f64; // x^0
        for k in 0..=m {
            out[k] = binom * xpow;
            binom = binom * (m - k) as f64 / (k + 1) as f64;
            xpow *= x;
        }
        let mut cpow = 1.0f64; // xc^0
        for k in (0..=m).rev() {
            out[k] *= cpow;
            cpow *= xc;
        }
    }

    /// Evaluate all d basis-function **derivatives** at x into `out`:
    /// b'_{k,m} = m (b_{k−1,m−1} − b_{k,m−1}).
    pub fn deriv_into(&self, x: f64, out: &mut [f64], scratch: &mut [f64]) {
        let m = self.degree;
        debug_assert_eq!(out.len(), m + 1);
        debug_assert!(scratch.len() >= m);
        let lower = Bernstein { degree: m - 1 };
        lower.eval_into(x, &mut scratch[..m]);
        let mf = m as f64;
        out[0] = -mf * scratch[0];
        for k in 1..m {
            out[k] = mf * (scratch[k - 1] - scratch[k]);
        }
        out[m] = mf * scratch[m - 1];
    }

    /// Convenience: allocate and evaluate.
    pub fn eval(&self, x: f64) -> Vec<f64> {
        let mut out = vec![0.0; self.dim()];
        self.eval_into(x, &mut out);
        out
    }

    pub fn deriv(&self, x: f64) -> Vec<f64> {
        let mut out = vec![0.0; self.dim()];
        let mut scratch = vec![0.0; self.degree];
        self.deriv_into(x, &mut out, &mut scratch);
        out
    }
}

/// Per-column min–max scaler into [eps, 1−eps]; the chain-rule factor
/// (1−2eps)/(max−min) is kept so densities on the original scale stay
/// correct.
#[derive(Clone, Debug)]
pub struct Scaler {
    pub mins: Vec<f64>,
    pub maxs: Vec<f64>,
    pub eps: f64,
}

impl Scaler {
    /// Fit on an (n × J) data matrix.
    pub fn fit(data: &Mat, eps: f64) -> Self {
        let j = data.cols;
        let mut mins = vec![f64::INFINITY; j];
        let mut maxs = vec![f64::NEG_INFINITY; j];
        for r in 0..data.rows {
            let row = data.row(r);
            for (c, &v) in row.iter().enumerate() {
                mins[c] = mins[c].min(v);
                maxs[c] = maxs[c].max(v);
            }
        }
        for c in 0..j {
            if maxs[c] - mins[c] < 1e-12 {
                // degenerate column: widen artificially
                maxs[c] = mins[c] + 1.0;
            }
        }
        Scaler { mins, maxs, eps }
    }

    /// Scale a single value of column c.
    #[inline]
    pub fn scale(&self, c: usize, v: f64) -> f64 {
        let t = (v - self.mins[c]) / (self.maxs[c] - self.mins[c]);
        let t = t.clamp(0.0, 1.0);
        self.eps + (1.0 - 2.0 * self.eps) * t
    }

    /// d(scaled)/d(raw) for column c — the Jacobian factor for densities.
    #[inline]
    pub fn dscale(&self, c: usize) -> f64 {
        (1.0 - 2.0 * self.eps) / (self.maxs[c] - self.mins[c])
    }

    /// Inverse of [`Scaler::scale`] (without the clamp): map a scaled
    /// coordinate x ∈ [0, 1] back to the raw axis of column c. Values
    /// outside [ε, 1 − ε] extrapolate linearly beyond the fitted range —
    /// the quantile/sampling queries use this to report support edges.
    #[inline]
    pub fn unscale(&self, c: usize, x: f64) -> f64 {
        let t = (x - self.eps) / (1.0 - 2.0 * self.eps);
        self.mins[c] + t * (self.maxs[c] - self.mins[c])
    }

    /// Apply to a full matrix (returns a new matrix).
    pub fn transform(&self, data: &Mat) -> Mat {
        let mut out = data.clone();
        for r in 0..out.rows {
            for c in 0..out.cols {
                *out.at_mut(r, c) = self.scale(c, data.at(r, c));
            }
        }
        out
    }
}

/// Precomputed basis design tensors for a dataset in **plane-major
/// layout**: `a` and `ad` are stored as J contiguous (n × d) planes —
/// element (i, j, k) lives at `j·n·d + i·d + k`. This is the "apply the
/// basis functions once" step the coreset construction operates on
/// (paper §2: data points a_ij = a_j(y_ij), a'_ij = a'_j(y_ij)).
///
/// The plane layout makes per-margin work a unit-stride pass: the
/// blocked NLL/gradient kernels (`mctm::model`) and the plane-direct
/// leverage scoring (`coreset::leverage`) read each margin's panel
/// `A_j` contiguously instead of striding through an interleaved
/// (n, J, d) tensor. The row accessors ([`Design::a_row`] /
/// [`Design::ad_row`]) and the materializing views ([`Design::stacked`],
/// [`Design::deriv_points`]) keep their pre-plane semantics, so callers
/// that think in rows are unaffected.
#[derive(Clone, Debug)]
pub struct Design {
    pub n: usize,
    pub j: usize,
    pub d: usize,
    /// basis values, length n·J·d, plane-major: J planes of (n × d)
    pub a: Vec<f64>,
    /// basis derivative values, same plane-major layout as `a`
    pub ad: Vec<f64>,
    pub scaler: Scaler,
}

impl Design {
    /// Build from raw data (n × J) with Bernstein degree d−1.
    pub fn build(data: &Mat, d: usize, eps: f64) -> Self {
        let scaler = Scaler::fit(data, eps);
        Self::build_with_scaler(data, d, scaler)
    }

    /// [`Design::build`] on an explicit pool.
    pub fn build_on(data: &Mat, d: usize, eps: f64, pool: &Pool) -> Self {
        let scaler = Scaler::fit(data, eps);
        Self::build_with_scaler_on(data, d, scaler, pool)
    }

    /// Build with a *given* scaler — required whenever parameters fitted
    /// on one dataset (e.g. a streamed coreset) are evaluated on another:
    /// the transformation h̃ is defined on the scaled axis, so both
    /// designs must share the scaling.
    pub fn build_with_scaler(data: &Mat, d: usize, scaler: Scaler) -> Self {
        Self::build_with_scaler_on(data, d, scaler, &Pool::current())
    }

    /// [`Design::build_with_scaler`] on an explicit pool. Every plane
    /// row's basis values depend only on one (observation, margin)
    /// pair, so the work items — fixed `ROW_CHUNK` row slices of each
    /// of the J planes — fill disjoint output chunks with per-worker
    /// scratch, and the output is identical for any thread count.
    pub fn build_with_scaler_on(data: &Mat, d: usize, scaler: Scaler, pool: &Pool) -> Self {
        let basis = Bernstein::new(d - 1);
        let (n, j) = (data.rows, data.cols);
        let mut a = vec![0.0; n * j * d];
        let mut ad = vec![0.0; n * j * d];
        let plane = n * d;
        if plane > 0 && j > 0 {
            let mut items: Vec<(usize, usize, &mut [f64], &mut [f64])> = Vec::new();
            for (jj, (pa, pad)) in a.chunks_mut(plane).zip(ad.chunks_mut(plane)).enumerate() {
                for (ci, (ca, cad)) in pa
                    .chunks_mut(ROW_CHUNK * d)
                    .zip(pad.chunks_mut(ROW_CHUNK * d))
                    .enumerate()
                {
                    items.push((jj, ci, ca, cad));
                }
            }
            pool.for_items(items, |_, (jj, ci, a_chunk, ad_chunk)| {
                let lo = ci * ROW_CHUNK;
                let rows = a_chunk.len() / d;
                let mut scratch = vec![0.0; d.saturating_sub(1).max(1)];
                for off in 0..rows {
                    let x = scaler.scale(jj, data.at(lo + off, jj));
                    let at = off * d;
                    basis.eval_into(x, &mut a_chunk[at..at + d]);
                    basis.deriv_into(x, &mut ad_chunk[at..at + d], &mut scratch);
                }
            });
        }
        Design { n, j, d, a, ad, scaler }
    }

    /// The contiguous (n × d) basis panel A_j of margin `j` — the view
    /// the blocked kernels stream with unit stride.
    #[inline]
    pub fn a_plane(&self, j: usize) -> &[f64] {
        let plane = self.n * self.d;
        &self.a[j * plane..(j + 1) * plane]
    }

    /// The contiguous (n × d) derivative panel A'_j of margin `j`.
    #[inline]
    pub fn ad_plane(&self, j: usize) -> &[f64] {
        let plane = self.n * self.d;
        &self.ad[j * plane..(j + 1) * plane]
    }

    /// Basis row a_{ij} (length d).
    #[inline]
    pub fn a_row(&self, i: usize, j: usize) -> &[f64] {
        let off = (j * self.n + i) * self.d;
        &self.a[off..off + self.d]
    }

    /// Derivative row a'_{ij} (length d).
    #[inline]
    pub fn ad_row(&self, i: usize, j: usize) -> &[f64] {
        let off = (j * self.n + i) * self.d;
        &self.ad[off..off + self.d]
    }

    /// Gather the stacked row b_i = (a_1(y_i1), …, a_J(y_iJ)) into a
    /// caller-owned buffer of length dJ — the zero-materialization view
    /// the plane-direct leverage kernels use instead of [`Self::stacked`].
    #[inline]
    pub fn stacked_row_into(&self, i: usize, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.j * self.d);
        for jj in 0..self.j {
            out[jj * self.d..(jj + 1) * self.d].copy_from_slice(self.a_row(i, jj));
        }
    }

    /// The stacked matrix Ab ∈ R^{n × dJ} with rows
    /// b_i = (a_1(y_i1), …, a_J(y_iJ)) whose row leverage scores equal the
    /// leverage scores of the paper's block matrix B (see DESIGN.md §2).
    /// Materializes a copy; the hot leverage path gathers rows straight
    /// from the planes instead (`coreset::leverage`).
    pub fn stacked(&self) -> Mat {
        let dj = self.d * self.j;
        let mut m = Mat::zeros(self.n, dj);
        for i in 0..self.n {
            self.stacked_row_into(i, m.row_mut(i));
        }
        m
    }

    /// All derivative points {a'_ij} as an (nJ × d) matrix — the input of
    /// the convex-hull component. Row order is (i·J + j), matching the
    /// pre-plane layout, so hull point indices map back to observations
    /// as `p / J` exactly as before.
    pub fn deriv_points(&self) -> Mat {
        let mut m = Mat::zeros(self.n * self.j, self.d);
        for i in 0..self.n {
            for jj in 0..self.j {
                m.row_mut(i * self.j + jj).copy_from_slice(self.ad_row(i, jj));
            }
        }
        m
    }

    /// Restrict to a subset of observations (coreset restriction).
    pub fn select(&self, idx: &[usize]) -> Design {
        let mut out = Design {
            n: 0,
            j: self.j,
            d: self.d,
            a: Vec::new(),
            ad: Vec::new(),
            scaler: self.scaler.clone(),
        };
        self.select_into(idx, &mut out);
        out
    }

    /// [`Design::select`] into a caller-owned `Design`, reusing its
    /// buffers — the bootstrap replicate loop calls this with one
    /// hoisted sub-design so resampling allocates nothing once the
    /// buffers reach capacity (`tests/fit_alloc.rs`). Same gather as
    /// `select`, so the result is identical.
    pub fn select_into(&self, idx: &[usize], out: &mut Design) {
        let (j, d) = (self.j, self.d);
        let m = idx.len();
        out.n = m;
        out.j = j;
        out.d = d;
        out.a.resize(m * j * d, 0.0);
        out.ad.resize(m * j * d, 0.0);
        for jj in 0..j {
            for (t, &i) in idx.iter().enumerate() {
                let at = (jj * m + t) * d;
                out.a[at..at + d].copy_from_slice(self.a_row(i, jj));
                out.ad[at..at + d].copy_from_slice(self.ad_row(i, jj));
            }
        }
        out.scaler.mins.clone_from(&self.scaler.mins);
        out.scaler.maxs.clone_from(&self.scaler.maxs);
        out.scaler.eps = self.scaler.eps;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn partition_of_unity() {
        let b = Bernstein::new(6);
        for &x in &[0.0, 0.1, 0.33, 0.5, 0.99, 1.0] {
            let v = b.eval(x);
            let s: f64 = v.iter().sum();
            assert!((s - 1.0).abs() < 1e-12, "x={x} sum={s}");
            assert!(v.iter().all(|&bi| bi >= -1e-15));
        }
    }

    #[test]
    fn endpoint_values() {
        let b = Bernstein::new(5);
        let v0 = b.eval(0.0);
        let v1 = b.eval(1.0);
        assert!((v0[0] - 1.0).abs() < 1e-12);
        assert!(v0[1..].iter().all(|&x| x.abs() < 1e-12));
        assert!((v1[5] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn derivative_matches_finite_difference() {
        let b = Bernstein::new(6);
        let h = 1e-6;
        for &x in &[0.1, 0.37, 0.5, 0.81] {
            let d = b.deriv(x);
            let fp = b.eval(x + h);
            let fm = b.eval(x - h);
            for k in 0..b.dim() {
                let fd = (fp[k] - fm[k]) / (2.0 * h);
                assert!((d[k] - fd).abs() < 1e-6, "k={k} x={x}: {} vs {fd}", d[k]);
            }
        }
    }

    #[test]
    fn derivative_sums_to_zero() {
        // d/dx Σ b_k = d/dx 1 = 0
        let b = Bernstein::new(7);
        for &x in &[0.2, 0.6, 0.9] {
            let s: f64 = b.deriv(x).iter().sum();
            assert!(s.abs() < 1e-12);
        }
    }

    #[test]
    fn monotone_coefficients_give_positive_derivative() {
        let b = Bernstein::new(6);
        let theta: Vec<f64> = (0..7).map(|k| -2.0 + 0.7 * k as f64).collect();
        for i in 0..100 {
            let x = i as f64 / 99.0;
            let d = b.deriv(x);
            let hd: f64 = d.iter().zip(&theta).map(|(a, t)| a * t).sum();
            assert!(hd > 0.0, "x={x} hd={hd}");
        }
    }

    #[test]
    fn scaler_range_and_jacobian() {
        let data = Mat::from_rows(&[vec![-5.0, 10.0], vec![5.0, 20.0], vec![0.0, 15.0]]);
        let s = Scaler::fit(&data, 0.01);
        for r in 0..3 {
            for c in 0..2 {
                let v = s.scale(c, data.at(r, c));
                assert!((0.01..=0.99).contains(&v));
            }
        }
        assert!((s.scale(0, -5.0) - 0.01).abs() < 1e-12);
        assert!((s.scale(0, 5.0) - 0.99).abs() < 1e-12);
        assert!((s.dscale(0) - 0.98 / 10.0).abs() < 1e-12);
        // unscale inverts scale inside the data range
        for &v in &[-5.0, -1.3, 0.0, 2.7, 5.0] {
            let back = s.unscale(0, s.scale(0, v));
            assert!((back - v).abs() < 1e-9, "{v} → {back}");
        }
    }

    #[test]
    fn design_shapes_and_rows() {
        let mut rng = Rng::new(10);
        let data = Mat::from_vec(20, 3, (0..60).map(|_| rng.normal()).collect());
        let dz = Design::build(&data, 5, 0.01);
        assert_eq!(dz.a.len(), 20 * 3 * 5);
        assert_eq!(dz.a_row(7, 2).len(), 5);
        let stacked = dz.stacked();
        assert_eq!((stacked.rows, stacked.cols), (20, 15));
        // stacked row i is the concat of a_rows
        for jj in 0..3 {
            assert_eq!(&stacked.row(4)[jj * 5..(jj + 1) * 5], dz.a_row(4, jj));
        }
        let dp = dz.deriv_points();
        assert_eq!((dp.rows, dp.cols), (60, 5));
        // deriv_points keeps the (i·J + j) row order of the pre-plane layout
        assert_eq!(dp.row(4 * 3 + 2), dz.ad_row(4, 2));
        let sel = dz.select(&[3, 19]);
        assert_eq!(sel.n, 2);
        assert_eq!(sel.a_row(1, 1), dz.a_row(19, 1));
        assert_eq!(sel.ad_row(0, 2), dz.ad_row(3, 2));
    }

    #[test]
    fn planes_are_contiguous_margin_panels() {
        let mut rng = Rng::new(11);
        let data = Mat::from_vec(17, 3, (0..51).map(|_| rng.normal()).collect());
        let dz = Design::build(&data, 4, 0.01);
        for jj in 0..3 {
            let (pa, pad) = (dz.a_plane(jj), dz.ad_plane(jj));
            assert_eq!(pa.len(), 17 * 4);
            for i in 0..17 {
                assert_eq!(&pa[i * 4..(i + 1) * 4], dz.a_row(i, jj));
                assert_eq!(&pad[i * 4..(i + 1) * 4], dz.ad_row(i, jj));
            }
        }
        // gather-row view matches the materialized stacked matrix
        let stacked = dz.stacked();
        let mut buf = vec![0.0; 12];
        for i in [0usize, 7, 16] {
            dz.stacked_row_into(i, &mut buf);
            assert_eq!(&buf[..], stacked.row(i));
        }
    }
}
