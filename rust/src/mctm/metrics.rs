//! Evaluation metrics matching the paper's tables: parameter ℓ₂ distance
//! (on ϑ), λ error, log-likelihood ratio with the paper's normalization
//! shift log 𝒩 = nJ(ln c + 1), and the relative-improvement aggregate
//! defined in the notes under Tables 3/4.

use super::params::Params;

/// Lipschitz-type constant c of the paper's assumption g(i,j) ≤ c. The
/// shift only has to make the NLL positive so a ratio is meaningful; it
/// never changes the argmin. c = e gives shift 2nJ.
pub const DEFAULT_C: f64 = std::f64::consts::E;

/// ℓ₂ distance between the materialized ϑ vectors of two fits.
pub fn theta_l2(a: &Params, b: &Params) -> f64 {
    assert_eq!(a.spec, b.spec);
    let ta = a.theta();
    let tb = b.theta();
    ta.iter()
        .zip(&tb)
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        .sqrt()
}

/// ℓ₂ distance between the λ blocks (the dependence structure).
pub fn lambda_error(a: &Params, b: &Params) -> f64 {
    assert_eq!(a.spec, b.spec);
    a.lambda_block()
        .iter()
        .zip(b.lambda_block())
        .map(|(x, y)| (x - y) * (x - y))
        .sum::<f64>()
        .sqrt()
}

/// Log-likelihood ratio of a coreset fit against the full fit, both
/// evaluated on the FULL data, after the paper's normalization shift
/// log 𝒩 = nJ(ln c + 1): values ≥ 1, closer to 1 is better.
pub fn loglik_ratio(nll_coreset_on_full: f64, nll_full: f64, n: usize, j: usize) -> f64 {
    let mut shift = n as f64 * j as f64 * (DEFAULT_C.ln() + 1.0);
    // the Lipschitz constant is an assumption, not a computation — if the
    // fitted NLL still lands below −shift (pathological), enlarge until
    // the denominator is positive, mirroring "choose c large enough".
    let mut denom = nll_full + shift;
    while denom <= 0.0 {
        shift *= 2.0;
        denom = nll_full + shift;
    }
    (nll_coreset_on_full + shift) / denom
}

/// The paper's "Relative Improvement" aggregate over (ϑ-error, λ-error,
/// LR): errors improve as (base − m)/base·100, LR as
/// (|base−1| − |m−1|)/|base−1|·100; negatives clamp to 0 per table note;
/// the three are averaged.
pub fn relative_improvement(
    method: (f64, f64, f64),
    baseline: (f64, f64, f64),
) -> f64 {
    let (m_l2, m_lam, m_lr) = method;
    let (b_l2, b_lam, b_lr) = baseline;
    let imp_err = |m: f64, b: f64| -> f64 {
        if b.abs() < 1e-300 {
            0.0
        } else {
            ((b - m) / b * 100.0).max(0.0)
        }
    };
    let imp_lr = {
        let db = (b_lr - 1.0).abs();
        if db < 1e-300 {
            0.0
        } else {
            (((db - (m_lr - 1.0).abs()) / db) * 100.0).max(0.0)
        }
    };
    (imp_err(m_l2, b_l2) + imp_err(m_lam, b_lam) + imp_lr) / 3.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mctm::params::ModelSpec;

    #[test]
    fn distances_zero_on_identical() {
        let spec = ModelSpec::new(3, 5);
        let p = Params::init(spec);
        assert_eq!(theta_l2(&p, &p), 0.0);
        assert_eq!(lambda_error(&p, &p), 0.0);
    }

    #[test]
    fn lambda_error_sees_only_lambda() {
        let spec = ModelSpec::new(2, 4);
        let a = Params::init(spec);
        let mut xb = a.x.clone();
        let li = spec.j * spec.d; // first λ slot
        xb[li] = 0.5;
        let b = Params::new(spec, xb);
        assert!((lambda_error(&a, &b) - 0.5).abs() < 1e-12);
        assert_eq!(theta_l2(&a, &b), 0.0);
    }

    #[test]
    fn lr_identity_and_order() {
        let lr = loglik_ratio(-100.0, -100.0, 50, 2);
        assert!((lr - 1.0).abs() < 1e-12);
        // a worse (larger) NLL gives LR > 1
        assert!(loglik_ratio(-90.0, -100.0, 50, 2) > 1.0);
    }

    #[test]
    fn relative_improvement_matches_paper_rule() {
        // method strictly better on all three
        let imp = relative_improvement((1.0, 0.1, 1.1), (2.0, 0.2, 1.3));
        let expect = (50.0 + 50.0 + ((0.3 - 0.1) / 0.3 * 100.0)) / 3.0;
        assert!((imp - expect).abs() < 1e-9);
        // worse clamps to 0
        assert_eq!(relative_improvement((4.0, 0.4, 3.0), (2.0, 0.2, 1.3)), 0.0);
    }
}
