//! The MCTM negative log-likelihood (paper Eq. (1)) and its analytic
//! gradient, over precomputed Bernstein design tensors.
//!
//! Per observation i:
//!
//! ```text
//! z_{ij} = h̃_j(y_{ij}) + Σ_{l<j} λ_{jl} h̃_l(y_{il}),
//! loss_i = Σ_j ½ z_{ij}² − log h̃'_j(y_{ij}),
//! ```
//!
//! with h̃_j = a_{ij}ᵀ ϑ_j, h̃'_j = a'_{ij}ᵀ ϑ_j. Weighted sums (coreset
//! weights w_i) everywhere; the unweighted case is w ≡ 1. The coreset
//! analysis additionally splits the loss as f = f₁ − f₂ + f₃
//! ([`NllParts`]): squared part, positive log part, negative log part —
//! [`nll_parts`] evaluates that split with the same blocked kernel.
//!
//! ## Blocked evaluation over the plane-major design
//!
//! This is the hot inner loop of model fitting: the L-BFGS driver calls
//! it hundreds of times per fit. Since the plane-major refactor
//! (`basis::Design` stores J contiguous (n × d) panels) evaluation is
//! structured as fused blocked kernels per fixed `ROW_CHUNK` shard:
//!
//! 1. **Panels** — H = A_j·θ_j and H' = A'_j·θ_j for every margin j via
//!    [`crate::linalg::panel_matvec`] (4-row blocked GEMV over the
//!    unit-stride plane panel).
//! 2. **Triangular λ combination + loss** on the whole chunk, rows in
//!    order.
//! 3. **Gradient** — per-row coefficient panels c_a = w·∂loss/∂h̃ and
//!    c_ad = −w/h̃', then the transposed-panel accumulation
//!    ∂θ_j += A_jᵀ·c_a + A'_jᵀ·c_ad via
//!    [`crate::linalg::panel_accum_t`]; θ → β chaining happens once on
//!    the merged gradient.
//!
//! Shards merge by fixed-shape tree reduction, so results are
//! bit-identical for any thread count. The kernels themselves dispatch
//! per [`crate::linalg::simd::KernelBackend`] (PR 8): on the **Scalar**
//! backend every per-element accumulation order matches the
//! pre-refactor row-at-a-time kernel (kept as [`nll_grad_reference`]),
//! so values and gradients agree with it to the bit — pinned by
//! `tests/nll_kernel.rs` at threads {1, 2, 8}; on the **Simd** backend
//! (AVX2+FMA lanes fork the FP summation order) agreement with the
//! reference is ≤ 1e-12 relative, while thread-count bit-identity still
//! holds because the lane grouping depends only on the problem shape.
//! The facade-level consumer pins live in `tests/pipeline_e2e.rs`. See
//! EXPERIMENTS.md §Perf iteration 7 for the blocked-kernel
//! measurements; the earlier scratch-reuse finding this loop started
//! from is §Perf iteration 1.

use super::params::{ModelSpec, Params};
use crate::basis::Design;
use crate::linalg::{panel_accum_t, panel_matvec};
use crate::util::parallel::{add_assign, tree_reduce, Pool, ROW_CHUNK};

/// Floor for the log argument — the model-side D(η) guard. With the
/// monotone reparametrization h̃' > 0 always holds, but the coreset
/// theory evaluates the loss at *arbitrary* (ϑ, λ), where the paper
/// restricts to ⟨ϑ_j, a'_ij⟩ > η.
pub const ETA_FLOOR: f64 = 1e-12;

/// The f₁/f₂/f₃ decomposition of the loss used by the coreset analysis
/// (paper §2): squared part, positive log part, negative log part, so
/// that f = f₁ − f₂ + f₃.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct NllParts {
    pub f1: f64,
    pub f2: f64,
    pub f3: f64,
}

impl NllParts {
    #[inline]
    pub fn total(&self) -> f64 {
        self.f1 - self.f2 + self.f3
    }
}

/// Reusable per-call scratch of the blocked NLL kernel: the ϑ
/// materialization buffer and the hoisted λ row offsets. The optimizer
/// loop holds one `NllScratch` per objective (`fit::NativeNll`), so
/// repeated `value_grad_into` evaluations allocate nothing at this
/// layer — per-chunk worker buffers below the pool remain, amortized
/// over `ROW_CHUNK` rows each.
pub struct NllScratch {
    theta: Vec<f64>,
    /// λ row offsets: lam_off[j] = j(j−1)/2 (hoisted because
    /// `lambda_index` costs a mul+shift per call — ~15% of the J=10 row
    /// cost back when this was a per-row lookup; §Perf iteration 1)
    lam_off: Vec<usize>,
}

impl NllScratch {
    pub fn new(spec: ModelSpec) -> Self {
        NllScratch {
            theta: vec![0.0; spec.j * spec.d],
            lam_off: (0..spec.j).map(|jj| jj * jj.saturating_sub(1) / 2).collect(),
        }
    }
}

/// Per-chunk partial of the weighted NLL and its gradient; merged by a
/// fixed-shape tree reduction so accumulation order — and therefore the
/// result, bit for bit — is independent of the thread count.
struct NllPartial {
    total: f64,
    grad_theta: Vec<f64>,
    grad_lambda: Vec<f64>,
}

#[inline]
fn dot(a: &[f64], b: &[f64]) -> f64 {
    let mut s = 0.0;
    for i in 0..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Weighted NLL Σ_i w_i loss_i at free parameters `p` (β-parametrized).
/// `weights` of length `design.n`, or empty for unweighted.
pub fn nll(design: &Design, weights: &[f64], p: &Params) -> f64 {
    nll_with(design, weights, p, &Pool::current())
}

/// [`nll`] on an explicit pool.
pub fn nll_with(design: &Design, weights: &[f64], p: &Params, pool: &Pool) -> f64 {
    let mut scratch = NllScratch::new(p.spec);
    nll_impl(design, weights, p, None, &mut scratch, pool)
}

/// [`nll`] through a caller-owned [`NllScratch`] — the allocation-free
/// value path of the optimizer loop.
pub fn nll_with_scratch(
    design: &Design,
    weights: &[f64],
    p: &Params,
    scratch: &mut NllScratch,
    pool: &Pool,
) -> f64 {
    nll_impl(design, weights, p, None, scratch, pool)
}

/// Weighted NLL and gradient w.r.t. the free parameter vector x.
pub fn nll_grad(design: &Design, weights: &[f64], p: &Params) -> (f64, Vec<f64>) {
    nll_grad_with(design, weights, p, &Pool::current())
}

/// [`nll_grad`] on an explicit pool.
pub fn nll_grad_with(
    design: &Design,
    weights: &[f64],
    p: &Params,
    pool: &Pool,
) -> (f64, Vec<f64>) {
    let mut grad = vec![0.0; p.spec.n_params()];
    let mut scratch = NllScratch::new(p.spec);
    let v = nll_grad_into_with(design, weights, p, &mut grad, &mut scratch, pool);
    (v, grad)
}

/// [`nll_grad`] writing into a caller-owned gradient buffer through a
/// reusable [`NllScratch`] — the path `fit::Objective::value_grad_into`
/// drives, with zero heap allocation above the worker pool.
pub fn nll_grad_into_with(
    design: &Design,
    weights: &[f64],
    p: &Params,
    grad: &mut [f64],
    scratch: &mut NllScratch,
    pool: &Pool,
) -> f64 {
    assert_eq!(grad.len(), p.spec.n_params(), "gradient buffer length");
    nll_impl(design, weights, p, Some(grad), scratch, pool)
}

/// The fused blocked evaluation (see the module doc): per fixed
/// `ROW_CHUNK` shard, margin panels H/H' via blocked GEMV, the
/// triangular λ combination + loss on the whole chunk, and the
/// transposed-panel gradient accumulation; partials merge by
/// fixed-shape tree reduction, and θ → β chaining happens once on the
/// merged gradient. Every accumulator's floating-point order equals the
/// row-at-a-time reference ([`nll_grad_reference`]), bit for bit.
fn nll_impl(
    design: &Design,
    weights: &[f64],
    p: &Params,
    grad: Option<&mut [f64]>,
    scratch: &mut NllScratch,
    pool: &Pool,
) -> f64 {
    let spec = p.spec;
    let (j, d) = (spec.j, spec.d);
    assert_eq!(design.j, j, "design J mismatch");
    assert_eq!(design.d, d, "design d mismatch");
    assert!(
        weights.is_empty() || weights.len() == design.n,
        "weights length"
    );
    assert_eq!(scratch.theta.len(), j * d, "scratch spec mismatch");

    p.theta_into(&mut scratch.theta);
    let theta: &[f64] = &scratch.theta;
    let lam_off: &[usize] = &scratch.lam_off;
    let lam = p.lambda_block();
    let want_grad = grad.is_some();
    let n_lam = spec.n_lambda();

    let partials = pool.map_chunks(design.n, ROW_CHUNK, |_, range| {
        let lo = range.start;
        let cl = range.len();
        // margin panels over this chunk: margin jj occupies
        // [jj·cl, (jj+1)·cl) of each buffer
        let mut h = vec![0.0; j * cl];
        let mut hd = vec![0.0; j * cl];
        for jj in 0..j {
            let th = &theta[jj * d..(jj + 1) * d];
            let pa = &design.a_plane(jj)[lo * d..(lo + cl) * d];
            let pad = &design.ad_plane(jj)[lo * d..(lo + cl) * d];
            panel_matvec(pa, d, th, &mut h[jj * cl..(jj + 1) * cl]);
            panel_matvec(pad, d, th, &mut hd[jj * cl..(jj + 1) * cl]);
        }
        let mut part = NllPartial {
            total: 0.0,
            grad_theta: vec![0.0; if want_grad { j * d } else { 0 }],
            grad_lambda: vec![0.0; if want_grad { n_lam } else { 0 }],
        };
        let mut z = vec![0.0; if want_grad { j * cl } else { 0 }];

        // triangular λ combination + loss, rows in chunk order
        for r in 0..cl {
            let w = if weights.is_empty() { 1.0 } else { weights[lo + r] };
            if w == 0.0 {
                continue;
            }
            let mut li = 0usize;
            let mut loss = 0.0;
            for jj in 0..j {
                let mut zv = h[jj * cl + r];
                for ll in 0..jj {
                    zv += lam[li + ll] * h[ll * cl + r];
                }
                if want_grad {
                    z[jj * cl + r] = zv;
                }
                let hdv = hd[jj * cl + r].max(ETA_FLOOR);
                loss += 0.5 * zv * zv - hdv.ln();
                li += jj;
            }
            part.total += w * loss;
        }

        if want_grad {
            // per-row coefficient panels (c_a via the back-propagated
            // ∂loss/∂h̃_l = z_l + Σ_{j>l} λ_jl z_j) and the λ gradient —
            // O(J²) per row; the O(J·d) work happens in the panels below
            let mut ca = vec![0.0; j * cl];
            let mut cad = vec![0.0; j * cl];
            for r in 0..cl {
                let w = if weights.is_empty() { 1.0 } else { weights[lo + r] };
                if w == 0.0 {
                    continue; // excluded from the panel runs below too
                }
                for ll in 0..j {
                    let mut gh = z[ll * cl + r];
                    for jj in (ll + 1)..j {
                        gh += lam[lam_off[jj] + ll] * z[jj * cl + r];
                    }
                    ca[ll * cl + r] = w * gh;
                }
                for jj in 0..j {
                    let hdv = hd[jj * cl + r].max(ETA_FLOOR);
                    cad[jj * cl + r] = -w / hdv;
                }
                // λ gradient: ∂loss/∂λ_jl = z_j · h̃_l
                let mut li = 0usize;
                for jj in 1..j {
                    for ll in 0..jj {
                        part.grad_lambda[li + ll] += w * z[jj * cl + r] * h[ll * cl + r];
                    }
                    li += jj;
                }
            }
            // transposed-panel accumulation ∂θ_j += A_jᵀ·c_a + A'_jᵀ·c_ad,
            // over maximal runs of nonzero-weight rows: rows the
            // row-at-a-time kernel skips contribute nothing here either
            // (their raw basis values may be anything — a masked-out NaN
            // observation must not poison the gradient via 0·NaN), and
            // within a run the adds stay row-sequential, so the result
            // is bit-identical to the reference for any weight pattern
            let mut runs: Vec<(usize, usize)> = Vec::new();
            if weights.is_empty() {
                runs.push((0, cl));
            } else {
                let mut s = 0usize;
                while s < cl {
                    if weights[lo + s] == 0.0 {
                        s += 1;
                        continue;
                    }
                    let mut e = s + 1;
                    while e < cl && weights[lo + e] != 0.0 {
                        e += 1;
                    }
                    runs.push((s, e));
                    s = e;
                }
            }
            for jj in 0..j {
                let pa = design.a_plane(jj);
                let pad = design.ad_plane(jj);
                for &(s, e) in &runs {
                    panel_accum_t(
                        &pa[(lo + s) * d..(lo + e) * d],
                        &pad[(lo + s) * d..(lo + e) * d],
                        d,
                        &ca[jj * cl + s..jj * cl + e],
                        &cad[jj * cl + s..jj * cl + e],
                        &mut part.grad_theta[jj * d..(jj + 1) * d],
                    );
                }
            }
        }
        part
    });

    let merged = tree_reduce(partials, |mut x, y| {
        x.total += y.total;
        add_assign(&mut x.grad_theta, &y.grad_theta);
        add_assign(&mut x.grad_lambda, &y.grad_lambda);
        x
    })
    .unwrap_or_else(|| NllPartial {
        total: 0.0,
        grad_theta: vec![0.0; if want_grad { j * d } else { 0 }],
        grad_lambda: vec![0.0; if want_grad { n_lam } else { 0 }],
    });

    if let Some(g) = grad {
        // chain θ → β on the merged partial, then assemble g = (β, λ)
        let mut gt = merged.grad_theta;
        p.grad_theta_to_beta(&mut gt);
        g[..j * d].copy_from_slice(&gt);
        g[j * d..].copy_from_slice(&merged.grad_lambda);
    }
    merged.total
}

/// The pre-plane row-at-a-time kernel, kept verbatim (modulo the row
/// accessors) as the agreement baseline: `tests/nll_kernel.rs` pins the
/// blocked kernel against it and `benches/perf_hotpath.rs` uses it as
/// the serial reference row of the nll_grad sweep. Like the engine it
/// preserves, it processes fixed `ROW_CHUNK` shards row-at-a-time and
/// tree-reduces the per-shard partials — serially, in chunk order —
/// so its floating-point accumulation shape is exactly the old
/// kernel's (at any thread count, since that shape never depended on
/// threads). Single-threaded by construction; do not use on a hot path.
pub fn nll_grad_reference(design: &Design, weights: &[f64], p: &Params) -> (f64, Vec<f64>) {
    let spec = p.spec;
    let (j, d) = (spec.j, spec.d);
    assert_eq!(design.j, j, "design J mismatch");
    assert_eq!(design.d, d, "design d mismatch");
    assert!(
        weights.is_empty() || weights.len() == design.n,
        "weights length"
    );
    let theta = p.theta();
    let lam = p.lambda_block();
    let lam_off: Vec<usize> = (0..j).map(|jj| jj * jj.saturating_sub(1) / 2).collect();

    let partials: Vec<NllPartial> = Pool::chunk_ranges(design.n, ROW_CHUNK)
        .into_iter()
        .map(|range| {
            let mut part = NllPartial {
                total: 0.0,
                grad_theta: vec![0.0; j * d],
                grad_lambda: vec![0.0; spec.n_lambda()],
            };
            let (mut htil, mut hd, mut z, mut ghtil) =
                (vec![0.0; j], vec![0.0; j], vec![0.0; j], vec![0.0; j]);
            for i in range {
                let w = if weights.is_empty() { 1.0 } else { weights[i] };
                if w == 0.0 {
                    continue;
                }
                for jj in 0..j {
                    let th = &theta[jj * d..(jj + 1) * d];
                    htil[jj] = dot(design.a_row(i, jj), th);
                    hd[jj] = dot(design.ad_row(i, jj), th);
                }
                let mut li = 0usize;
                for jj in 0..j {
                    let mut zv = htil[jj];
                    for ll in 0..jj {
                        zv += lam[li + ll] * htil[ll];
                    }
                    z[jj] = zv;
                    li += jj;
                }
                let mut loss = 0.0;
                for jj in 0..j {
                    let hdv = hd[jj].max(ETA_FLOOR);
                    loss += 0.5 * z[jj] * z[jj] - hdv.ln();
                }
                part.total += w * loss;

                for ll in 0..j {
                    let mut gh = z[ll];
                    for jj in (ll + 1)..j {
                        gh += lam[lam_off[jj] + ll] * z[jj];
                    }
                    ghtil[ll] = gh;
                }
                for jj in 0..j {
                    let hdv = hd[jj].max(ETA_FLOOR);
                    let coef_a = w * ghtil[jj];
                    let coef_ad = -w / hdv;
                    let gt = &mut part.grad_theta[jj * d..(jj + 1) * d];
                    let arow = design.a_row(i, jj);
                    let adrow = design.ad_row(i, jj);
                    for k in 0..d {
                        gt[k] += coef_a * arow[k] + coef_ad * adrow[k];
                    }
                }
                let mut li = 0usize;
                for jj in 1..j {
                    for ll in 0..jj {
                        part.grad_lambda[li + ll] += w * z[jj] * htil[ll];
                    }
                    li += jj;
                }
            }
            part
        })
        .collect();
    let merged = tree_reduce(partials, |mut x, y| {
        x.total += y.total;
        add_assign(&mut x.grad_theta, &y.grad_theta);
        add_assign(&mut x.grad_lambda, &y.grad_lambda);
        x
    })
    .unwrap_or_else(|| NllPartial {
        total: 0.0,
        grad_theta: vec![0.0; j * d],
        grad_lambda: vec![0.0; spec.n_lambda()],
    });
    let mut grad_theta = merged.grad_theta;
    p.grad_theta_to_beta(&mut grad_theta);
    let mut grad = vec![0.0; spec.n_params()];
    grad[..j * d].copy_from_slice(&grad_theta);
    grad[j * d..].copy_from_slice(&merged.grad_lambda);
    (merged.total, grad)
}

/// Evaluate the f₁/f₂/f₃ split at **raw** (ϑ, λ) — the objects the
/// coreset guarantees are stated for. `theta` row-major (j,k), `lam` the
/// strictly-lower-triangular block.
pub fn nll_parts(
    design: &Design,
    weights: &[f64],
    theta: &[f64],
    lam: &[f64],
) -> NllParts {
    nll_parts_with(design, weights, theta, lam, &Pool::current())
}

/// [`nll_parts`] on an explicit pool, evaluated with the same blocked
/// panel kernel as [`nll`]: per shard, H/H' panels via blocked GEMV,
/// then the per-row λ combination splits into f₁/f₂/f₃ partials which
/// merge in fixed tree order — the split is bit-identical for any
/// thread count.
pub fn nll_parts_with(
    design: &Design,
    weights: &[f64],
    theta: &[f64],
    lam: &[f64],
    pool: &Pool,
) -> NllParts {
    let (j, d) = (design.j, design.d);
    assert_eq!(theta.len(), j * d);
    assert!(
        weights.is_empty() || weights.len() == design.n,
        "weights length"
    );
    let partials = pool.map_chunks(design.n, ROW_CHUNK, |_, range| {
        let lo = range.start;
        let cl = range.len();
        let mut h = vec![0.0; j * cl];
        let mut hd = vec![0.0; j * cl];
        for jj in 0..j {
            let th = &theta[jj * d..(jj + 1) * d];
            let pa = &design.a_plane(jj)[lo * d..(lo + cl) * d];
            let pad = &design.ad_plane(jj)[lo * d..(lo + cl) * d];
            panel_matvec(pa, d, th, &mut h[jj * cl..(jj + 1) * cl]);
            panel_matvec(pad, d, th, &mut hd[jj * cl..(jj + 1) * cl]);
        }
        let mut parts = NllParts::default();
        for r in 0..cl {
            let w = if weights.is_empty() { 1.0 } else { weights[lo + r] };
            if w == 0.0 {
                continue;
            }
            let mut li = 0usize;
            for jj in 0..j {
                let mut z = h[jj * cl + r];
                for ll in 0..jj {
                    z += lam[li + ll] * h[ll * cl + r];
                }
                parts.f1 += w * 0.5 * z * z;
                let lg = hd[jj * cl + r].max(ETA_FLOOR).ln();
                if lg > 0.0 {
                    parts.f2 += w * lg;
                } else {
                    parts.f3 += w * (-lg);
                }
                li += jj;
            }
        }
        parts
    });
    tree_reduce(partials, |a, b| NllParts {
        f1: a.f1 + b.f1,
        f2: a.f2 + b.f2,
        f3: a.f3 + b.f3,
    })
    .unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::mctm::params::ModelSpec;
    use crate::util::rng::Rng;

    fn toy_design(n: usize, j: usize, d: usize, seed: u64) -> Design {
        let mut rng = Rng::new(seed);
        let data = Mat::from_vec(n, j, (0..n * j).map(|_| rng.normal()).collect());
        Design::build(&data, d, 0.01)
    }

    fn random_params(spec: ModelSpec, seed: u64) -> Params {
        let mut rng = Rng::new(seed);
        let x: Vec<f64> = (0..spec.n_params()).map(|_| 0.5 * rng.normal()).collect();
        Params::new(spec, x)
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let spec = ModelSpec::new(3, 5);
        let design = toy_design(25, 3, 5, 42);
        let p = random_params(spec, 7);
        let (_, grad) = nll_grad(&design, &[], &p);
        let h = 1e-6;
        for k in 0..spec.n_params() {
            let mut xp = p.x.clone();
            xp[k] += h;
            let mut xm = p.x.clone();
            xm[k] -= h;
            let fp = nll(&design, &[], &Params::new(spec, xp));
            let fm = nll(&design, &[], &Params::new(spec, xm));
            let fd = (fp - fm) / (2.0 * h);
            assert!(
                (grad[k] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                "param {k}: analytic {} vs fd {fd}",
                grad[k]
            );
        }
    }

    #[test]
    fn blocked_matches_reference_bitwise() {
        // the Scalar blocked kernel preserves every accumulation order
        // of the row-at-a-time reference, so values AND gradients agree
        // to the bit; on the Simd backend (forked FP order) the pin is
        // the backend contract of ≤ 1e-12 relative (the cross-shape
        // randomized sweep is tests/nll_kernel.rs)
        use crate::linalg::simd::{backend, KernelBackend};
        let spec = ModelSpec::new(3, 6);
        let design = toy_design(120, 3, 6, 77);
        let p = random_params(spec, 78);
        let (v_ref, g_ref) = nll_grad_reference(&design, &[], &p);
        let (v, g) = nll_grad_with(&design, &[], &p, &Pool::new(1));
        if backend() == KernelBackend::Scalar {
            assert_eq!(v.to_bits(), v_ref.to_bits());
            for (k, (a, b)) in g.iter().zip(&g_ref).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "grad[{k}]: {a} vs {b}");
            }
        } else {
            assert!((v - v_ref).abs() <= 1e-12 * v_ref.abs().max(1.0));
            for (k, (a, b)) in g.iter().zip(&g_ref).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-12 * b.abs().max(1.0),
                    "grad[{k}]: {a} vs {b}"
                );
            }
        }
    }

    #[test]
    fn weighted_equals_replication() {
        // weight 2 on a row == duplicating the row
        let spec = ModelSpec::new(2, 4);
        let design = toy_design(10, 2, 4, 1);
        let p = random_params(spec, 2);
        let mut w = vec![1.0; 10];
        w[3] = 2.0;
        let weighted = nll(&design, &w, &p);
        let mut idx: Vec<usize> = (0..10).collect();
        idx.push(3);
        let dup = design.select(&idx);
        let plain = nll(&dup, &[], &p);
        assert!((weighted - plain).abs() < 1e-10);
    }

    #[test]
    fn zero_weights_skip_rows() {
        let spec = ModelSpec::new(2, 4);
        let design = toy_design(8, 2, 4, 3);
        let p = random_params(spec, 4);
        let mut w = vec![1.0; 8];
        w[0] = 0.0;
        w[7] = 0.0;
        let v = nll(&design, &w, &p);
        let sub = design.select(&(1..7).collect::<Vec<_>>());
        assert!((v - nll(&sub, &[], &p)).abs() < 1e-10);
        // the gradient skips them too — bitwise vs the reference on the
        // Scalar backend, ≤ 1e-12 relative on Simd
        use crate::linalg::simd::{backend, KernelBackend};
        let (vg, g) = nll_grad(&design, &w, &p);
        let (vr, gr) = nll_grad_reference(&design, &w, &p);
        if backend() == KernelBackend::Scalar {
            assert_eq!(vg.to_bits(), vr.to_bits());
            for (a, b) in g.iter().zip(&gr) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        } else {
            assert!((vg - vr).abs() <= 1e-12 * vr.abs().max(1.0));
            for (a, b) in g.iter().zip(&gr) {
                assert!((a - b).abs() <= 1e-12 * b.abs().max(1.0), "{a} vs {b}");
            }
        }
    }

    #[test]
    fn parts_sum_to_total() {
        let spec = ModelSpec::new(3, 5);
        let design = toy_design(30, 3, 5, 9);
        let p = random_params(spec, 10);
        let theta = p.theta();
        let lam = p.lambda_block().to_vec();
        let parts = nll_parts(&design, &[], &theta, &lam);
        let total = nll(&design, &[], &p);
        assert!(
            (parts.total() - total).abs() < 1e-9,
            "{} vs {total}",
            parts.total()
        );
        assert!(parts.f1 >= 0.0 && parts.f2 >= 0.0 && parts.f3 >= 0.0);
    }

    #[test]
    fn lambda_zero_decouples_components() {
        // with λ = 0 the NLL is the sum of univariate NLLs ⇒ permuting
        // one column's rows leaves the total invariant
        let spec = ModelSpec::new(2, 4);
        let mut rng = Rng::new(5);
        let data = Mat::from_vec(12, 2, (0..24).map(|_| rng.normal()).collect());
        let design = Design::build(&data, 4, 0.01);
        let mut p = Params::init(spec);
        // λ already 0 in init
        let v = nll(&design, &[], &p);
        // permute column 1
        let mut permuted = data.clone();
        for r in 0..12 {
            *permuted.at_mut(r, 1) = data.at(11 - r, 1);
        }
        let design2 = Design::build(&permuted, 4, 0.01);
        let v2 = nll(&design2, &[], &mut p);
        assert!((v - v2).abs() < 1e-9);
    }
}
