//! The MCTM negative log-likelihood (paper Eq. (1)) and its analytic
//! gradient, over precomputed Bernstein design tensors.
//!
//! Per observation i:
//!
//! ```text
//! z_{ij} = h̃_j(y_{ij}) + Σ_{l<j} λ_{jl} h̃_l(y_{il}),
//! loss_i = Σ_j ½ z_{ij}² − log h̃'_j(y_{ij}),
//! ```
//!
//! with h̃_j = a_{ij}ᵀ ϑ_j, h̃'_j = a'_{ij}ᵀ ϑ_j. Weighted sums (coreset
//! weights w_i) everywhere; the unweighted case is w ≡ 1.
//!
//! This is the hot inner loop of model fitting; see EXPERIMENTS.md §Perf
//! for the optimization history of this function.

use super::params::Params;
use crate::basis::Design;
use crate::util::parallel::{add_assign, tree_reduce, Pool, ROW_CHUNK};

/// Floor for the log argument — the model-side D(η) guard. With the
/// monotone reparametrization h̃' > 0 always holds, but the coreset
/// theory evaluates the loss at *arbitrary* (ϑ, λ), where the paper
/// restricts to ⟨ϑ_j, a'_ij⟩ > η.
pub const ETA_FLOOR: f64 = 1e-12;

/// The f₁/f₂/f₃ decomposition of the loss used by the coreset analysis
/// (paper §2): squared part, positive log part, negative log part, so
/// that f = f₁ − f₂ + f₃.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct NllParts {
    pub f1: f64,
    pub f2: f64,
    pub f3: f64,
}

impl NllParts {
    #[inline]
    pub fn total(&self) -> f64 {
        self.f1 - self.f2 + self.f3
    }
}

/// Per-worker scratch buffers reused across the rows of one shard (the
/// optimizer calls the NLL hundreds of times; allocation in the inner
/// loop was the first perf finding — see EXPERIMENTS.md §Perf L3-b).
/// Each worker of the row-sharded evaluation owns one `Workspace`, so
/// the shards never contend on scratch memory.
pub struct Workspace {
    htil: Vec<f64>,
    hd: Vec<f64>,
    z: Vec<f64>,
    ghtil: Vec<f64>,
}

impl Workspace {
    pub fn new(j: usize) -> Self {
        Workspace {
            htil: vec![0.0; j],
            hd: vec![0.0; j],
            z: vec![0.0; j],
            ghtil: vec![0.0; j],
        }
    }
}

/// Per-chunk partial of the weighted NLL and its gradient; merged by a
/// fixed-shape tree reduction so accumulation order — and therefore the
/// result, bit for bit — is independent of the thread count.
struct NllPartial {
    total: f64,
    grad_theta: Vec<f64>,
    grad_lambda: Vec<f64>,
}

#[inline]
fn dot(a: &[f64], b: &[f64]) -> f64 {
    let mut s = 0.0;
    for i in 0..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Weighted NLL Σ_i w_i loss_i at free parameters `p` (β-parametrized).
/// `weights` of length `design.n`, or empty for unweighted.
pub fn nll(design: &Design, weights: &[f64], p: &Params) -> f64 {
    nll_with(design, weights, p, &Pool::current())
}

/// [`nll`] on an explicit pool.
pub fn nll_with(design: &Design, weights: &[f64], p: &Params, pool: &Pool) -> f64 {
    nll_impl(design, weights, p, None, pool)
}

/// Weighted NLL and gradient w.r.t. the free parameter vector x.
pub fn nll_grad(design: &Design, weights: &[f64], p: &Params) -> (f64, Vec<f64>) {
    nll_grad_with(design, weights, p, &Pool::current())
}

/// [`nll_grad`] on an explicit pool.
pub fn nll_grad_with(
    design: &Design,
    weights: &[f64],
    p: &Params,
    pool: &Pool,
) -> (f64, Vec<f64>) {
    let mut grad = vec![0.0; p.spec.n_params()];
    let v = nll_impl(design, weights, p, Some(&mut grad), pool);
    (v, grad)
}

/// Row-sharded evaluation: each chunk of rows is processed by one
/// worker with its own `Workspace` and accumulates a private
/// (`total`, ∂θ, ∂λ) partial; partials merge by fixed-shape tree
/// reduction, and θ → β chaining happens once on the merged gradient.
fn nll_impl(
    design: &Design,
    weights: &[f64],
    p: &Params,
    grad: Option<&mut Vec<f64>>,
    pool: &Pool,
) -> f64 {
    let spec = p.spec;
    let (j, d) = (spec.j, spec.d);
    assert_eq!(design.j, j, "design J mismatch");
    assert_eq!(design.d, d, "design d mismatch");
    assert!(
        weights.is_empty() || weights.len() == design.n,
        "weights length"
    );

    let theta = p.theta();
    let lam = p.lambda_block();
    // λ row offsets hoisted out of the per-row loops (lambda_index does
    // a mul+shift per call — ~15% of the J=10 row cost; §Perf L3-b)
    let lam_off: Vec<usize> = (0..j).map(|jj| jj * jj.saturating_sub(1) / 2).collect();

    let want_grad = grad.is_some();
    let n_lam = spec.n_lambda();
    let stride = j * d;

    let partials = pool.map_chunks(design.n, ROW_CHUNK, |_, range| {
        let mut ws = Workspace::new(j);
        let mut part = NllPartial {
            total: 0.0,
            grad_theta: vec![0.0; if want_grad { j * d } else { 0 }],
            grad_lambda: vec![0.0; if want_grad { n_lam } else { 0 }],
        };
        for i in range {
            let w = if weights.is_empty() { 1.0 } else { weights[i] };
            if w == 0.0 {
                continue;
            }
            let a = &design.a[i * stride..(i + 1) * stride];
            let ad = &design.ad[i * stride..(i + 1) * stride];

            // marginal transforms and derivatives
            for jj in 0..j {
                let th = &theta[jj * d..(jj + 1) * d];
                ws.htil[jj] = dot(&a[jj * d..(jj + 1) * d], th);
                ws.hd[jj] = dot(&ad[jj * d..(jj + 1) * d], th);
            }

            // copula combination z_j = h̃_j + Σ_{l<j} λ_jl h̃_l
            let mut li = 0usize;
            for jj in 0..j {
                let mut z = ws.htil[jj];
                for ll in 0..jj {
                    z += lam[li + ll] * ws.htil[ll];
                }
                ws.z[jj] = z;
                li += jj;
            }

            // loss
            let mut loss = 0.0;
            for jj in 0..j {
                let hd = ws.hd[jj].max(ETA_FLOOR);
                loss += 0.5 * ws.z[jj] * ws.z[jj] - hd.ln();
            }
            part.total += w * loss;

            if want_grad {
                // ∂loss/∂h̃_l = z_l + Σ_{j>l} λ_jl z_j
                for ll in 0..j {
                    let mut gh = ws.z[ll];
                    for jj in (ll + 1)..j {
                        gh += lam[lam_off[jj] + ll] * ws.z[jj];
                    }
                    ws.ghtil[ll] = gh;
                }
                // θ gradient (accumulated, chained to β once at the end)
                for jj in 0..j {
                    let hd = ws.hd[jj].max(ETA_FLOOR);
                    let coef_a = w * ws.ghtil[jj];
                    let coef_ad = -w / hd;
                    let gt = &mut part.grad_theta[jj * d..(jj + 1) * d];
                    let arow = &a[jj * d..(jj + 1) * d];
                    let adrow = &ad[jj * d..(jj + 1) * d];
                    for k in 0..d {
                        gt[k] += coef_a * arow[k] + coef_ad * adrow[k];
                    }
                }
                // λ gradient: ∂loss/∂λ_jl = z_j · h̃_l
                let mut li = 0usize;
                for jj in 1..j {
                    for ll in 0..jj {
                        part.grad_lambda[li + ll] += w * ws.z[jj] * ws.htil[ll];
                    }
                    li += jj;
                }
            }
        }
        part
    });

    let merged = tree_reduce(partials, |mut x, y| {
        x.total += y.total;
        add_assign(&mut x.grad_theta, &y.grad_theta);
        add_assign(&mut x.grad_lambda, &y.grad_lambda);
        x
    })
    .unwrap_or_else(|| NllPartial {
        total: 0.0,
        grad_theta: vec![0.0; if want_grad { j * d } else { 0 }],
        grad_lambda: vec![0.0; if want_grad { n_lam } else { 0 }],
    });

    if let Some(g) = grad {
        // chain θ → β on the merged partial, then assemble g = (β, λ)
        let mut gt = merged.grad_theta;
        p.grad_theta_to_beta(&mut gt);
        g[..j * d].copy_from_slice(&gt);
        g[j * d..].copy_from_slice(&merged.grad_lambda);
    }
    merged.total
}

/// Evaluate the f₁/f₂/f₃ split at **raw** (ϑ, λ) — the objects the
/// coreset guarantees are stated for. `theta` row-major (j,k), `lam` the
/// strictly-lower-triangular block.
pub fn nll_parts(
    design: &Design,
    weights: &[f64],
    theta: &[f64],
    lam: &[f64],
) -> NllParts {
    nll_parts_with(design, weights, theta, lam, &Pool::current())
}

/// [`nll_parts`] on an explicit pool: row shards accumulate private
/// f₁/f₂/f₃ partials which merge in fixed tree order, so the split is
/// bit-identical for any thread count.
pub fn nll_parts_with(
    design: &Design,
    weights: &[f64],
    theta: &[f64],
    lam: &[f64],
    pool: &Pool,
) -> NllParts {
    let (j, d) = (design.j, design.d);
    assert_eq!(theta.len(), j * d);
    assert!(
        weights.is_empty() || weights.len() == design.n,
        "weights length"
    );
    let stride = j * d;
    let partials = pool.map_chunks(design.n, ROW_CHUNK, |_, range| {
        let mut parts = NllParts::default();
        let mut htil = vec![0.0; j];
        for i in range {
            let w = if weights.is_empty() { 1.0 } else { weights[i] };
            if w == 0.0 {
                continue;
            }
            let a = &design.a[i * stride..(i + 1) * stride];
            let ad = &design.ad[i * stride..(i + 1) * stride];
            for jj in 0..j {
                htil[jj] = dot(&a[jj * d..(jj + 1) * d], &theta[jj * d..(jj + 1) * d]);
            }
            let mut li = 0usize;
            for jj in 0..j {
                let mut z = htil[jj];
                for ll in 0..jj {
                    z += lam[li + ll] * htil[ll];
                }
                parts.f1 += w * 0.5 * z * z;
                let hd = dot(&ad[jj * d..(jj + 1) * d], &theta[jj * d..(jj + 1) * d]);
                let lg = hd.max(ETA_FLOOR).ln();
                if lg > 0.0 {
                    parts.f2 += w * lg;
                } else {
                    parts.f3 += w * (-lg);
                }
                li += jj;
            }
        }
        parts
    });
    tree_reduce(partials, |a, b| NllParts {
        f1: a.f1 + b.f1,
        f2: a.f2 + b.f2,
        f3: a.f3 + b.f3,
    })
    .unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::mctm::params::ModelSpec;
    use crate::util::rng::Rng;

    fn toy_design(n: usize, j: usize, d: usize, seed: u64) -> Design {
        let mut rng = Rng::new(seed);
        let data = Mat::from_vec(n, j, (0..n * j).map(|_| rng.normal()).collect());
        Design::build(&data, d, 0.01)
    }

    fn random_params(spec: ModelSpec, seed: u64) -> Params {
        let mut rng = Rng::new(seed);
        let x: Vec<f64> = (0..spec.n_params()).map(|_| 0.5 * rng.normal()).collect();
        Params::new(spec, x)
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let spec = ModelSpec::new(3, 5);
        let design = toy_design(25, 3, 5, 42);
        let p = random_params(spec, 7);
        let (_, grad) = nll_grad(&design, &[], &p);
        let h = 1e-6;
        for k in 0..spec.n_params() {
            let mut xp = p.x.clone();
            xp[k] += h;
            let mut xm = p.x.clone();
            xm[k] -= h;
            let fp = nll(&design, &[], &Params::new(spec, xp));
            let fm = nll(&design, &[], &Params::new(spec, xm));
            let fd = (fp - fm) / (2.0 * h);
            assert!(
                (grad[k] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                "param {k}: analytic {} vs fd {fd}",
                grad[k]
            );
        }
    }

    #[test]
    fn weighted_equals_replication() {
        // weight 2 on a row == duplicating the row
        let spec = ModelSpec::new(2, 4);
        let design = toy_design(10, 2, 4, 1);
        let p = random_params(spec, 2);
        let mut w = vec![1.0; 10];
        w[3] = 2.0;
        let weighted = nll(&design, &w, &p);
        let mut idx: Vec<usize> = (0..10).collect();
        idx.push(3);
        let dup = design.select(&idx);
        let plain = nll(&dup, &[], &p);
        assert!((weighted - plain).abs() < 1e-10);
    }

    #[test]
    fn zero_weights_skip_rows() {
        let spec = ModelSpec::new(2, 4);
        let design = toy_design(8, 2, 4, 3);
        let p = random_params(spec, 4);
        let mut w = vec![1.0; 8];
        w[0] = 0.0;
        w[7] = 0.0;
        let v = nll(&design, &w, &p);
        let sub = design.select(&(1..7).collect::<Vec<_>>());
        assert!((v - nll(&sub, &[], &p)).abs() < 1e-10);
    }

    #[test]
    fn parts_sum_to_total() {
        let spec = ModelSpec::new(3, 5);
        let design = toy_design(30, 3, 5, 9);
        let p = random_params(spec, 10);
        let theta = p.theta();
        let lam = p.lambda_block().to_vec();
        let parts = nll_parts(&design, &[], &theta, &lam);
        let total = nll(&design, &[], &p);
        assert!(
            (parts.total() - total).abs() < 1e-9,
            "{} vs {total}",
            parts.total()
        );
        assert!(parts.f1 >= 0.0 && parts.f2 >= 0.0 && parts.f3 >= 0.0);
    }

    #[test]
    fn lambda_zero_decouples_components() {
        // with λ = 0 the NLL is the sum of univariate NLLs ⇒ permuting
        // one column's rows leaves the total invariant
        let spec = ModelSpec::new(2, 4);
        let mut rng = Rng::new(5);
        let data = Mat::from_vec(12, 2, (0..24).map(|_| rng.normal()).collect());
        let design = Design::build(&data, 4, 0.01);
        let mut p = Params::init(spec);
        // λ already 0 in init
        let v = nll(&design, &[], &p);
        // permute column 1
        let mut permuted = data.clone();
        for r in 0..12 {
            *permuted.at_mut(r, 1) = data.at(11 - r, 1);
        }
        let design2 = Design::build(&permuted, 4, 0.01);
        let v2 = nll(&design2, &[], &mut p);
        assert!((v - v2).abs() < 1e-9);
    }
}
