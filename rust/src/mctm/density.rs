//! Density evaluation for a fitted MCTM.
//!
//! With Z = Λ h̃(Y) ~ N(0, I_J) and unit-lower-triangular Λ, we have
//! h̃(Y) ~ N(0, Σ) with Σ = Λ⁻¹ Λ⁻ᵀ. The marginal density of component
//! j on the ORIGINAL data scale is therefore
//!   f_j(y) = φ(h̃_j(s_j(y)) / σ_j) / σ_j · h̃'_j(s_j(y)) · s'_j ,
//! where σ_j² = Σ_jj and s_j is the min–max scaling. The joint density
//! follows the usual transformation formula with the triangular Jacobian
//! (paper Appendix D). Used by the Figure 10/11 benches.

use super::params::Params;
use crate::basis::{Bernstein, Scaler};
use crate::linalg::{unit_lower_inverse, Mat};
use crate::util::special::norm_pdf;

/// Materialize the unit-lower-triangular Λ of a parameter vector.
pub fn lambda_matrix(p: &Params) -> Mat {
    let j = p.spec.j;
    let mut l = Mat::eye(j);
    for jj in 1..j {
        for ll in 0..jj {
            *l.at_mut(jj, ll) = p.lambda(jj, ll);
        }
    }
    l
}

/// Marginal standard deviations σ_j = sqrt((Λ⁻¹Λ⁻ᵀ)_jj).
pub fn marginal_sigmas(p: &Params) -> Vec<f64> {
    let l = lambda_matrix(p);
    let linv = unit_lower_inverse(&l);
    let j = p.spec.j;
    (0..j)
        .map(|jj| {
            let row = linv.row(jj);
            row.iter().map(|x| x * x).sum::<f64>().sqrt()
        })
        .collect()
}

/// Marginal density f_j(y) on the original data scale at raw value `y`.
pub fn marginal_density(p: &Params, scaler: &Scaler, j: usize, y: f64) -> f64 {
    marginal_density_with_sigma(
        &p.theta(),
        p.spec.d,
        scaler,
        j,
        y,
        marginal_sigmas(p)[j],
    )
}

/// [`marginal_density`] with the materialized ϑ and a precomputed σ_j —
/// the single formula behind both the free function above and the
/// facade's `FittedModel::marginal_density` (which caches ϑ and the
/// σ's across queries).
pub fn marginal_density_with_sigma(
    theta: &[f64],
    d: usize,
    scaler: &Scaler,
    j: usize,
    y: f64,
    sigma: f64,
) -> f64 {
    let basis = Bernstein::new(d - 1);
    let th = &theta[j * d..(j + 1) * d];
    let x = scaler.scale(j, y);
    // one basis buffer reused for value and derivative (plus the
    // lower-degree scratch `deriv_into` needs) — two allocations per
    // query instead of two per margin
    let mut buf = vec![0.0; d];
    let mut scratch = vec![0.0; d.saturating_sub(1).max(1)];
    basis.eval_into(x, &mut buf);
    let htil: f64 = buf.iter().zip(th).map(|(ai, ti)| ai * ti).sum();
    basis.deriv_into(x, &mut buf, &mut scratch);
    let hd: f64 = buf.iter().zip(th).map(|(ai, ti)| ai * ti).sum();
    norm_pdf(htil / sigma) / sigma * hd.max(0.0) * scaler.dscale(j)
}

/// Joint **log**-density at a raw J-vector — the numerically safe form
/// the facade's `FittedModel::log_density` serves (far-tail queries
/// underflow `joint_density` but stay finite here). The per-margin
/// basis evaluations share one reused buffer, mirroring how the fit
/// path's blocked kernel streams one margin panel at a time.
pub fn log_joint_density(p: &Params, scaler: &Scaler, y: &[f64]) -> f64 {
    let (j, d) = (p.spec.j, p.spec.d);
    assert_eq!(y.len(), j);
    let basis = Bernstein::new(d - 1);
    let theta = p.theta();
    let mut htil = vec![0.0; j];
    let mut buf = vec![0.0; d];
    let mut scratch = vec![0.0; d.saturating_sub(1).max(1)];
    let mut log_jac = 0.0;
    for jj in 0..j {
        let x = scaler.scale(jj, y[jj]);
        let th = &theta[jj * d..(jj + 1) * d];
        basis.eval_into(x, &mut buf);
        htil[jj] = buf.iter().zip(th).map(|(ai, ti)| ai * ti).sum();
        basis.deriv_into(x, &mut buf, &mut scratch);
        let hd: f64 = buf.iter().zip(th).map(|(ai, ti)| ai * ti).sum();
        log_jac += hd.max(1e-300).ln() + scaler.dscale(jj).ln();
    }
    // z = Λ h̃, φ_J(z) = Π φ(z_j); |det Λ| = 1
    let mut logphi = 0.0;
    for jj in 0..j {
        let mut z = htil[jj];
        for ll in 0..jj {
            z += p.lambda(jj, ll) * htil[ll];
        }
        logphi += -0.5 * z * z - 0.5 * (2.0 * std::f64::consts::PI).ln();
    }
    logphi + log_jac
}

/// Joint density at a raw J-vector.
pub fn joint_density(p: &Params, scaler: &Scaler, y: &[f64]) -> f64 {
    log_joint_density(p, scaler, y).exp()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::basis::Design;
    use crate::mctm::params::ModelSpec;
    use crate::util::rng::Rng;

    fn scaler_for(n: usize, j: usize, seed: u64) -> (Scaler, Mat) {
        let mut rng = Rng::new(seed);
        let data = Mat::from_vec(n, j, (0..n * j).map(|_| rng.normal()).collect());
        (Scaler::fit(&data, 0.01), data)
    }

    #[test]
    fn marginal_density_integrates_to_one() {
        let spec = ModelSpec::new(2, 6);
        let p = Params::init(spec);
        let (scaler, _) = scaler_for(200, 2, 1);
        // trapezoid over the data range (init model has mass inside)
        let (lo, hi) = (scaler.mins[0] - 1.0, scaler.maxs[0] + 1.0);
        let m = 4000;
        let mut total = 0.0;
        for i in 0..m {
            let y = lo + (hi - lo) * (i as f64 + 0.5) / m as f64;
            total += marginal_density(&p, &scaler, 0, y) * (hi - lo) / m as f64;
        }
        assert!((total - 1.0).abs() < 0.05, "integral {total}");
    }

    #[test]
    fn joint_density_nonnegative_and_consistent() {
        let spec = ModelSpec::new(2, 5);
        let mut p = Params::init(spec);
        // couple the components
        let li = spec.j * spec.d;
        p.x[li] = -0.6;
        let (scaler, data) = scaler_for(50, 2, 3);
        for r in 0..10 {
            let y = [data.at(r, 0), data.at(r, 1)];
            let f = joint_density(&p, &scaler, &y);
            assert!(f >= 0.0 && f.is_finite());
        }
    }

    #[test]
    fn joint_matches_nll_per_point() {
        // −log joint (on the SCALED scale, i.e. dropping the scaler
        // Jacobian) equals the per-observation NLL contribution plus the
        // normal constant
        let spec = ModelSpec::new(2, 5);
        let mut rng = Rng::new(4);
        let data = Mat::from_vec(30, 2, (0..60).map(|_| rng.normal()).collect());
        let design = Design::build(&data, 5, 0.01);
        let mut p = Params::init(spec);
        p.x[spec.j * spec.d] = 0.4;
        let r = 11;
        let single = design.select(&[r]);
        let nll_val = crate::mctm::model::nll(&single, &[], &p);
        let y = [data.at(r, 0), data.at(r, 1)];
        let logf = joint_density(&p, &design.scaler, &y).ln();
        let log_scale_jac: f64 =
            (0..2).map(|c| design.scaler.dscale(c).ln()).sum();
        let normal_const = 2.0 * 0.5 * (2.0 * std::f64::consts::PI).ln();
        // −log f = nll + const − scaleJac
        assert!(
            (-logf - (nll_val + normal_const - log_scale_jac)).abs() < 1e-9,
            "{} vs {}",
            -logf,
            nll_val + normal_const - log_scale_jac
        );
    }

    #[test]
    fn sigmas_identity_when_lambda_zero() {
        let spec = ModelSpec::new(3, 4);
        let p = Params::init(spec);
        for s in marginal_sigmas(&p) {
            assert!((s - 1.0).abs() < 1e-12);
        }
    }
}
