//! Multivariate conditional transformation models (MCTMs), Klein et al.
//! (2022): the negative log-likelihood of Eq. (1) in the paper, its
//! analytic gradient, the monotone reparametrization, the f₁/f₂/f₃ split
//! the coreset analysis operates on, marginal-density evaluation and the
//! evaluation metrics used by the experiment tables.

pub mod bootstrap;
pub mod conditional;
pub mod density;
pub mod metrics;
pub mod model;
pub mod params;

pub use bootstrap::{bootstrap_ci, BootstrapResult};
pub use density::marginal_density;
pub use metrics::{lambda_error, loglik_ratio, relative_improvement, theta_l2};
pub use model::{
    nll, nll_grad, nll_grad_into_with, nll_grad_reference, nll_grad_with, nll_parts,
    nll_parts_with, nll_with, nll_with_scratch, NllParts, NllScratch,
};
pub use params::{ModelSpec, Params};
