//! Conditional MCTM extension (paper §4: "Extending our methods to
//! conditional transformation models would be straightforward for a
//! linear conditional structure; it only increases the dimension
//! dependence by the number of features conditioned on").
//!
//! Linear conditional structure: each marginal transformation gets a
//! feature-linear shift on the latent scale,
//!   h̃_j(y | x) = a_j(y)ᵀ ϑ_j + xᵀ γ_j ,
//! with the derivative (and hence the log term) unchanged. The coreset
//! machinery carries over verbatim with the stacked rows extended to
//! b_i = (a_1(y_i1), …, a_J(y_iJ), x_i) ∈ R^{dJ+q} — exactly the
//! claimed +q dimension dependence.
//!
//! ## Blocked evaluation
//!
//! Since PR 8 the conditional NLL runs the same fused blocked engine as
//! the unconditional kernel (`mctm::model::nll_impl`): per `ROW_CHUNK`
//! shard, margin panels H/H' via [`crate::linalg::panel_matvec`], the
//! feature shift X·γ_j through the SAME panel GEMV over the contiguous
//! feature rows, the triangular λ combination + loss on the whole
//! chunk, and the gradient via [`crate::linalg::panel_accum_t`]
//! (θ block) / [`crate::linalg::panel_accum_t1`] (Γ block) over maximal
//! nonzero-weight runs. The pre-PR-8 row-at-a-time kernel is retained as
//! [`cond_nll_grad_reference`]; on the Scalar backend the blocked path
//! reproduces it bit for bit at any thread count (pinned in
//! `tests/simd_kernels.rs`), on the Simd backend agreement is ≤ 1e-12
//! relative (see `linalg::simd`). [`CondNll`] holds a reusable
//! [`CondScratch`] so the optimizer loop — and every bootstrap
//! replicate reusing the objective — allocates nothing per evaluation
//! above the worker pool (`tests/fit_alloc.rs`).

use super::model::ETA_FLOOR;
use super::params::{sigmoid, softplus, ModelSpec};
use crate::basis::Design;
use crate::linalg::{panel_accum_t, panel_accum_t1, panel_matvec, Mat};
use crate::util::parallel::{add_assign, tree_reduce, Pool, ROW_CHUNK};
use std::cell::RefCell;

/// Shape of a conditional MCTM: J outputs, d basis functions, q
/// features.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CondSpec {
    pub j: usize,
    pub d: usize,
    pub q: usize,
}

impl CondSpec {
    pub fn new(j: usize, d: usize, q: usize) -> Self {
        assert!(j >= 1 && d >= 2);
        CondSpec { j, d, q }
    }

    /// Free parameters: β (J·d), Γ (J·q), λ (J(J−1)/2).
    pub fn n_params(&self) -> usize {
        self.j * self.d + self.j * self.q + self.j * (self.j - 1) / 2
    }

    pub fn unconditional(&self) -> ModelSpec {
        ModelSpec::new(self.j, self.d)
    }

    /// Start of the Γ block in the free vector (β | Γ | λ).
    #[inline]
    pub fn gamma_off(&self) -> usize {
        self.j * self.d
    }

    /// Start of the λ block in the free vector (β | Γ | λ).
    #[inline]
    pub fn lambda_off(&self) -> usize {
        self.j * self.d + self.j * self.q
    }
}

/// A conditional design: the output basis design + the feature matrix.
pub struct CondDesign {
    pub design: Design,
    /// features (n × q)
    pub x: Mat,
}

impl CondDesign {
    pub fn build(y: &Mat, x: &Mat, d: usize, eps: f64) -> Self {
        assert_eq!(y.rows, x.rows, "y and x row mismatch");
        CondDesign { design: Design::build(y, d, eps), x: x.clone() }
    }

    /// The extended stacked matrix [a₁ … a_J | x] ∈ R^{n×(dJ+q)} whose
    /// leverage scores drive the conditional coreset.
    pub fn stacked(&self) -> Mat {
        let base = self.design.stacked();
        let (n, dj, q) = (base.rows, base.cols, self.x.cols);
        let mut m = Mat::zeros(n, dj + q);
        for i in 0..n {
            m.row_mut(i)[..dj].copy_from_slice(base.row(i));
            m.row_mut(i)[dj..].copy_from_slice(self.x.row(i));
        }
        m
    }

    pub fn select(&self, idx: &[usize]) -> CondDesign {
        CondDesign { design: self.design.select(idx), x: self.x.select_rows(idx) }
    }
}

/// Reusable per-call scratch of the blocked conditional kernel: the ϑ
/// materialization buffer and the hoisted λ row offsets — the
/// conditional twin of `mctm::model::NllScratch`. [`CondNll`] holds one
/// per objective so repeated evaluations (optimizer iterations,
/// bootstrap replicates) allocate nothing at this layer.
pub struct CondScratch {
    theta: Vec<f64>,
    lam_off: Vec<usize>,
}

impl CondScratch {
    pub fn new(spec: CondSpec) -> Self {
        CondScratch {
            theta: vec![0.0; spec.j * spec.d],
            lam_off: (0..spec.j).map(|jj| jj * jj.saturating_sub(1) / 2).collect(),
        }
    }
}

/// Per-chunk partial of the conditional NLL/gradient; merged by the
/// same fixed-shape tree reduction as the unconditional kernel so
/// results are bit-identical for any thread count.
struct CondPartial {
    total: f64,
    grad_theta: Vec<f64>,
    grad_gamma: Vec<f64>,
    grad_lambda: Vec<f64>,
}

/// Weighted conditional NLL and gradient w.r.t. the free vector
/// (β | Γ | λ). Same loss as Eq. (1) with the shifted h̃. Allocating
/// convenience over [`cond_nll_grad_into_with`] on the ambient pool.
pub fn cond_nll_grad(
    cd: &CondDesign,
    weights: &[f64],
    spec: CondSpec,
    params: &[f64],
) -> (f64, Vec<f64>) {
    cond_nll_grad_with(cd, weights, spec, params, &Pool::current())
}

/// [`cond_nll_grad`] on an explicit pool.
pub fn cond_nll_grad_with(
    cd: &CondDesign,
    weights: &[f64],
    spec: CondSpec,
    params: &[f64],
    pool: &Pool,
) -> (f64, Vec<f64>) {
    let mut grad = vec![0.0; spec.n_params()];
    let mut scratch = CondScratch::new(spec);
    let v = cond_nll_grad_into_with(cd, weights, spec, params, &mut grad, &mut scratch, pool);
    (v, grad)
}

/// [`cond_nll_grad`] writing into a caller-owned gradient buffer
/// through a reusable [`CondScratch`] — the allocation-free path
/// `CondNll::value_grad_into` drives.
pub fn cond_nll_grad_into_with(
    cd: &CondDesign,
    weights: &[f64],
    spec: CondSpec,
    params: &[f64],
    grad: &mut [f64],
    scratch: &mut CondScratch,
    pool: &Pool,
) -> f64 {
    assert_eq!(grad.len(), spec.n_params(), "gradient buffer length");
    cond_nll_impl(cd, weights, spec, params, Some(grad), scratch, pool)
}

/// Value-only conditional NLL through caller-owned scratch — the
/// allocation-free value path (`CondNll::value`).
pub fn cond_nll_with_scratch(
    cd: &CondDesign,
    weights: &[f64],
    spec: CondSpec,
    params: &[f64],
    scratch: &mut CondScratch,
    pool: &Pool,
) -> f64 {
    cond_nll_impl(cd, weights, spec, params, None, scratch, pool)
}

/// The fused blocked conditional evaluation (see the module doc): the
/// unconditional engine plus a feature-shift panel X·γ_j added onto H
/// and a Γ-gradient panel Xᵀ·c_a per margin. Every accumulator's
/// floating-point order equals the row-at-a-time reference
/// ([`cond_nll_grad_reference`]) on the Scalar backend.
fn cond_nll_impl(
    cd: &CondDesign,
    weights: &[f64],
    spec: CondSpec,
    params: &[f64],
    grad: Option<&mut [f64]>,
    scratch: &mut CondScratch,
    pool: &Pool,
) -> f64 {
    let (j, d, q) = (spec.j, spec.d, spec.q);
    assert_eq!(params.len(), spec.n_params());
    let design = &cd.design;
    assert_eq!(design.j, j, "design J mismatch");
    assert_eq!(design.d, d, "design d mismatch");
    assert_eq!(cd.x.cols, q, "feature width mismatch");
    assert_eq!(cd.x.rows, design.n, "feature rows mismatch");
    assert!(
        weights.is_empty() || weights.len() == design.n,
        "weights length"
    );
    assert_eq!(scratch.theta.len(), j * d, "scratch spec mismatch");

    // θ from β (cumulative softplus, as unconditional)
    for jj in 0..j {
        let b = &params[jj * d..(jj + 1) * d];
        let t = &mut scratch.theta[jj * d..(jj + 1) * d];
        t[0] = b[0];
        for k in 1..d {
            t[k] = t[k - 1] + softplus(b[k]);
        }
    }
    let theta: &[f64] = &scratch.theta;
    let lam_off: &[usize] = &scratch.lam_off;
    let gamma = &params[spec.gamma_off()..spec.lambda_off()];
    let lam = &params[spec.lambda_off()..];
    let want_grad = grad.is_some();
    let n_lam = j * (j - 1) / 2;

    let partials = pool.map_chunks(design.n, ROW_CHUNK, |_, range| {
        let lo = range.start;
        let cl = range.len();
        // margin panels over this chunk, then the feature shift X·γ_j
        // added elementwise — htil[jj·cl + r] = a_rᵀθ_j + x_rᵀγ_j with
        // the shift dot in the same order as the reference row loop
        let mut h = vec![0.0; j * cl];
        let mut hd = vec![0.0; j * cl];
        let mut sh = vec![0.0; cl];
        let xchunk = &cd.x.data[lo * q..(lo + cl) * q];
        for jj in 0..j {
            let th = &theta[jj * d..(jj + 1) * d];
            let pa = &design.a_plane(jj)[lo * d..(lo + cl) * d];
            let pad = &design.ad_plane(jj)[lo * d..(lo + cl) * d];
            panel_matvec(pa, d, th, &mut h[jj * cl..(jj + 1) * cl]);
            panel_matvec(pad, d, th, &mut hd[jj * cl..(jj + 1) * cl]);
            panel_matvec(xchunk, q, &gamma[jj * q..(jj + 1) * q], &mut sh);
            let hj = &mut h[jj * cl..(jj + 1) * cl];
            for r in 0..cl {
                hj[r] += sh[r];
            }
        }
        let mut part = CondPartial {
            total: 0.0,
            grad_theta: vec![0.0; if want_grad { j * d } else { 0 }],
            grad_gamma: vec![0.0; if want_grad { j * q } else { 0 }],
            grad_lambda: vec![0.0; if want_grad { n_lam } else { 0 }],
        };
        let mut z = vec![0.0; if want_grad { j * cl } else { 0 }];

        // triangular λ combination + loss, rows in chunk order
        for r in 0..cl {
            let w = if weights.is_empty() { 1.0 } else { weights[lo + r] };
            if w == 0.0 {
                continue;
            }
            let mut li = 0usize;
            let mut loss = 0.0;
            for jj in 0..j {
                let mut zv = h[jj * cl + r];
                for ll in 0..jj {
                    zv += lam[li + ll] * h[ll * cl + r];
                }
                if want_grad {
                    z[jj * cl + r] = zv;
                }
                let hdv = hd[jj * cl + r].max(ETA_FLOOR);
                loss += 0.5 * zv * zv - hdv.ln();
                li += jj;
            }
            part.total += w * loss;
        }

        if want_grad {
            // per-row coefficient panels + λ gradient (O(J²) per row)
            let mut ca = vec![0.0; j * cl];
            let mut cad = vec![0.0; j * cl];
            for r in 0..cl {
                let w = if weights.is_empty() { 1.0 } else { weights[lo + r] };
                if w == 0.0 {
                    continue; // excluded from the panel runs below too
                }
                for ll in 0..j {
                    let mut gh = z[ll * cl + r];
                    for jj in (ll + 1)..j {
                        gh += lam[lam_off[jj] + ll] * z[jj * cl + r];
                    }
                    ca[ll * cl + r] = w * gh;
                }
                for jj in 0..j {
                    let hdv = hd[jj * cl + r].max(ETA_FLOOR);
                    cad[jj * cl + r] = -w / hdv;
                }
                // λ gradient: ∂loss/∂λ_jl = z_j · h̃_l
                let mut li = 0usize;
                for jj in 1..j {
                    for ll in 0..jj {
                        part.grad_lambda[li + ll] += w * z[jj * cl + r] * h[ll * cl + r];
                    }
                    li += jj;
                }
            }
            // maximal nonzero-weight runs: zero-weight rows contribute
            // nothing (their raw basis/feature values may be anything —
            // a masked-out NaN must not poison the gradient via 0·NaN)
            let mut runs: Vec<(usize, usize)> = Vec::new();
            if weights.is_empty() {
                runs.push((0, cl));
            } else {
                let mut s = 0usize;
                while s < cl {
                    if weights[lo + s] == 0.0 {
                        s += 1;
                        continue;
                    }
                    let mut e = s + 1;
                    while e < cl && weights[lo + e] != 0.0 {
                        e += 1;
                    }
                    runs.push((s, e));
                    s = e;
                }
            }
            for jj in 0..j {
                let pa = design.a_plane(jj);
                let pad = design.ad_plane(jj);
                for &(s, e) in &runs {
                    // θ_j += A_jᵀ·c_a + A'_jᵀ·c_ad
                    panel_accum_t(
                        &pa[(lo + s) * d..(lo + e) * d],
                        &pad[(lo + s) * d..(lo + e) * d],
                        d,
                        &ca[jj * cl + s..jj * cl + e],
                        &cad[jj * cl + s..jj * cl + e],
                        &mut part.grad_theta[jj * d..(jj + 1) * d],
                    );
                    // Γ_j += Xᵀ·c_a (∂h̃_j/∂γ_j = x); the single-panel
                    // kernel so no zero second panel risks 0·NaN
                    panel_accum_t1(
                        &cd.x.data[(lo + s) * q..(lo + e) * q],
                        q,
                        &ca[jj * cl + s..jj * cl + e],
                        &mut part.grad_gamma[jj * q..(jj + 1) * q],
                    );
                }
            }
        }
        part
    });

    let merged = tree_reduce(partials, |mut x, y| {
        x.total += y.total;
        add_assign(&mut x.grad_theta, &y.grad_theta);
        add_assign(&mut x.grad_gamma, &y.grad_gamma);
        add_assign(&mut x.grad_lambda, &y.grad_lambda);
        x
    })
    .unwrap_or_else(|| CondPartial {
        total: 0.0,
        grad_theta: vec![0.0; if want_grad { j * d } else { 0 }],
        grad_gamma: vec![0.0; if want_grad { j * q } else { 0 }],
        grad_lambda: vec![0.0; if want_grad { n_lam } else { 0 }],
    });

    if let Some(g) = grad {
        // chain θ → β (suffix sums + sigmoid) on the merged partial,
        // then assemble g = (β | Γ | λ)
        let mut gt = merged.grad_theta;
        for jj in 0..j {
            let b = &params[jj * d..(jj + 1) * d];
            let gj = &mut gt[jj * d..(jj + 1) * d];
            for k in (0..d - 1).rev() {
                gj[k] += gj[k + 1];
            }
            for k in 1..d {
                gj[k] *= sigmoid(b[k]);
            }
        }
        g[..j * d].copy_from_slice(&gt);
        g[spec.gamma_off()..spec.lambda_off()].copy_from_slice(&merged.grad_gamma);
        g[spec.lambda_off()..].copy_from_slice(&merged.grad_lambda);
    }
    merged.total
}

/// The pre-PR-8 row-at-a-time conditional kernel, retained as the
/// agreement baseline (the conditional twin of
/// `mctm::model::nll_grad_reference`): fixed `ROW_CHUNK` shards
/// processed row-at-a-time with naive dots, partials tree-reduced
/// serially in chunk order — the exact floating-point accumulation
/// shape the blocked kernel reproduces bit for bit on the Scalar
/// backend. Single-threaded by construction; not a hot path.
pub fn cond_nll_grad_reference(
    cd: &CondDesign,
    weights: &[f64],
    spec: CondSpec,
    params: &[f64],
) -> (f64, Vec<f64>) {
    let (j, d, q) = (spec.j, spec.d, spec.q);
    assert_eq!(params.len(), spec.n_params());
    let design = &cd.design;
    assert_eq!(design.j, j);
    assert_eq!(design.d, d);
    assert_eq!(cd.x.cols, q);

    let mut theta = vec![0.0; j * d];
    for jj in 0..j {
        let b = &params[jj * d..(jj + 1) * d];
        let t = &mut theta[jj * d..(jj + 1) * d];
        t[0] = b[0];
        for k in 1..d {
            t[k] = t[k - 1] + softplus(b[k]);
        }
    }
    let gamma = &params[spec.gamma_off()..spec.lambda_off()];
    let lam = &params[spec.lambda_off()..];
    let lam_off: Vec<usize> = (0..j).map(|jj| jj * jj.saturating_sub(1) / 2).collect();
    let n_lam = j * (j - 1) / 2;

    let partials: Vec<CondPartial> = Pool::chunk_ranges(design.n, ROW_CHUNK)
        .into_iter()
        .map(|range| {
            let mut part = CondPartial {
                total: 0.0,
                grad_theta: vec![0.0; j * d],
                grad_gamma: vec![0.0; j * q],
                grad_lambda: vec![0.0; n_lam],
            };
            let (mut htil, mut hd, mut z, mut ghtil) =
                (vec![0.0; j], vec![0.0; j], vec![0.0; j], vec![0.0; j]);
            for i in range {
                let w = if weights.is_empty() { 1.0 } else { weights[i] };
                if w == 0.0 {
                    continue;
                }
                let xi = cd.x.row(i);
                for jj in 0..j {
                    let th = &theta[jj * d..(jj + 1) * d];
                    let (arow, adrow) = (design.a_row(i, jj), design.ad_row(i, jj));
                    let mut ha = 0.0;
                    let mut hb = 0.0;
                    for k in 0..d {
                        ha += arow[k] * th[k];
                        hb += adrow[k] * th[k];
                    }
                    let g = &gamma[jj * q..(jj + 1) * q];
                    let mut shift = 0.0;
                    for c in 0..q {
                        shift += g[c] * xi[c];
                    }
                    htil[jj] = ha + shift;
                    hd[jj] = hb;
                }
                let mut li = 0usize;
                for jj in 0..j {
                    let mut zz = htil[jj];
                    for ll in 0..jj {
                        zz += lam[li + ll] * htil[ll];
                    }
                    z[jj] = zz;
                    li += jj;
                }
                let mut loss = 0.0;
                for jj in 0..j {
                    let hdv = hd[jj].max(ETA_FLOOR);
                    loss += 0.5 * z[jj] * z[jj] - hdv.ln();
                }
                part.total += w * loss;

                for ll in 0..j {
                    let mut gh = z[ll];
                    for jj in (ll + 1)..j {
                        gh += lam[lam_off[jj] + ll] * z[jj];
                    }
                    ghtil[ll] = gh;
                }
                for jj in 0..j {
                    let hdv = hd[jj].max(ETA_FLOOR);
                    let ca = w * ghtil[jj];
                    let cad = -w / hdv;
                    let gt = &mut part.grad_theta[jj * d..(jj + 1) * d];
                    let (arow, adrow) = (design.a_row(i, jj), design.ad_row(i, jj));
                    for k in 0..d {
                        gt[k] += ca * arow[k] + cad * adrow[k];
                    }
                    // Γ gradient: ∂h̃_j/∂γ_j = x
                    let gg = &mut part.grad_gamma[jj * q..(jj + 1) * q];
                    for c in 0..q {
                        gg[c] += ca * xi[c];
                    }
                }
                let mut li = 0usize;
                for jj in 1..j {
                    for ll in 0..jj {
                        part.grad_lambda[li + ll] += w * z[jj] * htil[ll];
                    }
                    li += jj;
                }
            }
            part
        })
        .collect();
    let merged = tree_reduce(partials, |mut x, y| {
        x.total += y.total;
        add_assign(&mut x.grad_theta, &y.grad_theta);
        add_assign(&mut x.grad_gamma, &y.grad_gamma);
        add_assign(&mut x.grad_lambda, &y.grad_lambda);
        x
    })
    .unwrap_or_else(|| CondPartial {
        total: 0.0,
        grad_theta: vec![0.0; j * d],
        grad_gamma: vec![0.0; j * q],
        grad_lambda: vec![0.0; n_lam],
    });

    // chain θ → β (suffix sums + sigmoid), assemble (β | Γ | λ)
    let mut gt = merged.grad_theta;
    for jj in 0..j {
        let b = &params[jj * d..(jj + 1) * d];
        let gj = &mut gt[jj * d..(jj + 1) * d];
        for k in (0..d - 1).rev() {
            gj[k] += gj[k + 1];
        }
        for k in 1..d {
            gj[k] *= sigmoid(b[k]);
        }
    }
    let mut grad = vec![0.0; spec.n_params()];
    grad[..j * d].copy_from_slice(&gt);
    grad[spec.gamma_off()..spec.lambda_off()].copy_from_slice(&merged.grad_gamma);
    grad[spec.lambda_off()..].copy_from_slice(&merged.grad_lambda);
    (merged.total, grad)
}

/// Objective adapter for the generic optimizers. Holds a reusable
/// [`CondScratch`] behind a `RefCell` (the `Objective` surface is
/// `&self`) so repeated evaluations — optimizer iterations, bootstrap
/// replicates — never re-allocate the ϑ buffer or λ offsets.
pub struct CondNll<'a> {
    pub spec: CondSpec,
    pub cd: &'a CondDesign,
    pub weights: Vec<f64>,
    state: RefCell<CondScratch>,
}

impl<'a> CondNll<'a> {
    pub fn new(spec: CondSpec, cd: &'a CondDesign, weights: Vec<f64>) -> Self {
        assert!(weights.is_empty() || weights.len() == cd.design.n);
        CondNll { spec, cd, weights, state: RefCell::new(CondScratch::new(spec)) }
    }
}

impl crate::fit::Objective for CondNll<'_> {
    fn dim(&self) -> usize {
        self.spec.n_params()
    }

    fn value_grad_into(&self, x: &[f64], grad: &mut [f64]) -> f64 {
        let mut st = self.state.borrow_mut();
        cond_nll_grad_into_with(
            self.cd,
            &self.weights,
            self.spec,
            x,
            grad,
            &mut st,
            &Pool::current(),
        )
    }

    fn value(&self, x: &[f64]) -> f64 {
        let mut st = self.state.borrow_mut();
        cond_nll_with_scratch(self.cd, &self.weights, self.spec, x, &mut st, &Pool::current())
    }
}

/// Initialization mirroring the unconditional default (Γ = 0, λ = 0).
pub fn cond_init(spec: CondSpec) -> Vec<f64> {
    let base = super::params::Params::init(spec.unconditional());
    let mut x = vec![0.0; spec.n_params()];
    x[..spec.j * spec.d].copy_from_slice(&base.x[..spec.j * spec.d]);
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fit::{minimize, FitOptions};
    use crate::util::rng::Rng;

    fn toy(n: usize, q: usize, seed: u64) -> (Mat, Mat) {
        // y₁ | x shifted by 2·x₁; y₂ independent
        let mut rng = Rng::new(seed);
        let x = Mat::from_vec(n, q, (0..n * q).map(|_| rng.normal()).collect());
        let mut y = Mat::zeros(n, 2);
        for i in 0..n {
            *y.at_mut(i, 0) = 2.0 * x.at(i, 0) + rng.normal();
            *y.at_mut(i, 1) = rng.normal();
        }
        (y, x)
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let (y, x) = toy(20, 2, 1);
        let cd = CondDesign::build(&y, &x, 5, 0.01);
        let spec = CondSpec::new(2, 5, 2);
        let mut rng = Rng::new(2);
        let params: Vec<f64> = (0..spec.n_params()).map(|_| 0.4 * rng.normal()).collect();
        let (_, g) = cond_nll_grad(&cd, &[], spec, &params);
        let h = 1e-6;
        for k in 0..spec.n_params() {
            let mut pp = params.clone();
            pp[k] += h;
            let mut pm = params.clone();
            pm[k] -= h;
            let (fp, _) = cond_nll_grad(&cd, &[], spec, &pp);
            let (fm, _) = cond_nll_grad(&cd, &[], spec, &pm);
            let fd = (fp - fm) / (2.0 * h);
            assert!(
                (g[k] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                "param {k}: {} vs {fd}",
                g[k]
            );
        }
    }

    #[test]
    fn blocked_matches_reference_per_backend() {
        use crate::linalg::simd::{backend, KernelBackend};
        // spans two ROW_CHUNK shards; masked + weighted rows; on the
        // Scalar backend the blocked kernel must reproduce the
        // row-at-a-time reference bit for bit at any thread count, on
        // Simd to ≤1e-12 relative (the full cross-backend pin lives in
        // tests/simd_kernels.rs)
        let n = 2_500;
        let (y, x) = toy(n, 2, 9);
        let cd = CondDesign::build(&y, &x, 5, 0.01);
        let spec = CondSpec::new(2, 5, 2);
        let mut rng = Rng::new(10);
        let params: Vec<f64> = (0..spec.n_params()).map(|_| 0.3 * rng.normal()).collect();
        let mut w: Vec<f64> = (0..n).map(|_| rng.uniform(0.5, 2.0)).collect();
        w[100] = 0.0;
        w[2300] = 0.0; // masked rows in both chunks
        let (vr, gr) = cond_nll_grad_reference(&cd, &w, spec, &params);
        for t in [1usize, 2, 8] {
            let pool = Pool::new(t);
            let (vb, gb) = cond_nll_grad_with(&cd, &w, spec, &params, &pool);
            if backend() == KernelBackend::Scalar {
                assert_eq!(vb.to_bits(), vr.to_bits(), "t={t} value");
                for (k, (a, b)) in gb.iter().zip(&gr).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "t={t} grad {k}");
                }
            } else {
                assert!((vb - vr).abs() <= 1e-12 * vr.abs().max(1.0), "t={t}: {vb} vs {vr}");
                for (k, (a, b)) in gb.iter().zip(&gr).enumerate() {
                    assert!(
                        (a - b).abs() <= 1e-12 * b.abs().max(1.0),
                        "t={t} grad {k}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn recovers_conditional_shift() {
        let (y, x) = toy(3_000, 1, 3);
        let cd = CondDesign::build(&y, &x, 6, 0.01);
        let spec = CondSpec::new(2, 6, 1);
        let obj = CondNll::new(spec, &cd, Vec::new());
        let opts = FitOptions { max_iters: 200, ..Default::default() };
        let (fit, nll_cond, _, _) = minimize(&obj, cond_init(spec), &opts);
        // γ₁ must be clearly non-zero (y₁ depends on x) and γ₂ ≈ 0
        let g1 = fit[spec.gamma_off()];
        let g2 = fit[spec.gamma_off() + 1];
        assert!(g1.abs() > 5.0 * g2.abs().max(0.02), "γ₁={g1} γ₂={g2}");
        // conditioning must improve the likelihood vs Γ forced to 0
        let mut nocond = fit.clone();
        nocond[spec.gamma_off()] = 0.0;
        nocond[spec.gamma_off() + 1] = 0.0;
        let (nll_nocond, _) = cond_nll_grad(&cd, &[], spec, &nocond);
        assert!(
            nll_cond < nll_nocond - 100.0,
            "conditioning should help: {nll_cond} vs {nll_nocond}"
        );
    }

    #[test]
    fn conditional_coreset_through_extended_stacked_matrix() {
        use crate::coreset::leverage::leverage_scores;
        use crate::util::rng::AliasTable;
        let (y, x) = toy(2_000, 1, 5);
        let cd = CondDesign::build(&y, &x, 5, 0.01);
        let spec = CondSpec::new(2, 5, 1);
        let opts = FitOptions { max_iters: 150, ..Default::default() };

        // full conditional fit
        let obj = CondNll::new(spec, &cd, Vec::new());
        let (full, _, _, _) = minimize(&obj, cond_init(spec), &opts);

        // leverage on the EXTENDED stacked matrix (dJ + q columns)
        let stacked = cd.stacked();
        assert_eq!(stacked.cols, 2 * 5 + 1);
        let u = leverage_scores(&stacked).unwrap();
        let n = cd.design.n;
        let s: Vec<f64> = u.iter().map(|ui| ui + 1.0 / n as f64).collect();
        let table = AliasTable::new(&s);
        let mut rng = Rng::new(7);
        let k = 200;
        let mut idx = Vec::new();
        let mut w = Vec::new();
        for _ in 0..k {
            let i = table.sample(&mut rng);
            idx.push(i);
            w.push(1.0 / (k as f64 * table.p(i)));
        }
        let sub = cd.select(&idx);
        let obj_sub = CondNll::new(spec, &sub, w);
        let (coreset_fit, _, _, _) = minimize(&obj_sub, cond_init(spec), &opts);

        // the conditional effect must survive the coreset
        let gf = full[spec.gamma_off()];
        let gc = coreset_fit[spec.gamma_off()];
        assert!(
            (gf - gc).abs() < 0.35 * gf.abs().max(0.1),
            "γ full {gf} vs coreset {gc}"
        );
    }
}
