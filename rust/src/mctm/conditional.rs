//! Conditional MCTM extension (paper §4: "Extending our methods to
//! conditional transformation models would be straightforward for a
//! linear conditional structure; it only increases the dimension
//! dependence by the number of features conditioned on").
//!
//! Linear conditional structure: each marginal transformation gets a
//! feature-linear shift on the latent scale,
//!   h̃_j(y | x) = a_j(y)ᵀ ϑ_j + xᵀ γ_j ,
//! with the derivative (and hence the log term) unchanged. The coreset
//! machinery carries over verbatim with the stacked rows extended to
//! b_i = (a_1(y_i1), …, a_J(y_iJ), x_i) ∈ R^{dJ+q} — exactly the
//! claimed +q dimension dependence.

use super::params::{softplus, ModelSpec};
use crate::basis::Design;
use crate::linalg::Mat;

/// Shape of a conditional MCTM: J outputs, d basis functions, q
/// features.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CondSpec {
    pub j: usize,
    pub d: usize,
    pub q: usize,
}

impl CondSpec {
    pub fn new(j: usize, d: usize, q: usize) -> Self {
        assert!(j >= 1 && d >= 2);
        CondSpec { j, d, q }
    }

    /// Free parameters: β (J·d), Γ (J·q), λ (J(J−1)/2).
    pub fn n_params(&self) -> usize {
        self.j * self.d + self.j * self.q + self.j * (self.j - 1) / 2
    }

    pub fn unconditional(&self) -> ModelSpec {
        ModelSpec::new(self.j, self.d)
    }

    #[inline]
    fn gamma_off(&self) -> usize {
        self.j * self.d
    }

    #[inline]
    fn lambda_off(&self) -> usize {
        self.j * self.d + self.j * self.q
    }
}

/// A conditional design: the output basis design + the feature matrix.
pub struct CondDesign {
    pub design: Design,
    /// features (n × q)
    pub x: Mat,
}

impl CondDesign {
    pub fn build(y: &Mat, x: &Mat, d: usize, eps: f64) -> Self {
        assert_eq!(y.rows, x.rows, "y and x row mismatch");
        CondDesign { design: Design::build(y, d, eps), x: x.clone() }
    }

    /// The extended stacked matrix [a₁ … a_J | x] ∈ R^{n×(dJ+q)} whose
    /// leverage scores drive the conditional coreset.
    pub fn stacked(&self) -> Mat {
        let base = self.design.stacked();
        let (n, dj, q) = (base.rows, base.cols, self.x.cols);
        let mut m = Mat::zeros(n, dj + q);
        for i in 0..n {
            m.row_mut(i)[..dj].copy_from_slice(base.row(i));
            m.row_mut(i)[dj..].copy_from_slice(self.x.row(i));
        }
        m
    }

    pub fn select(&self, idx: &[usize]) -> CondDesign {
        CondDesign { design: self.design.select(idx), x: self.x.select_rows(idx) }
    }
}

/// Weighted conditional NLL and gradient w.r.t. the free vector
/// (β | Γ | λ). Same loss as Eq. (1) with the shifted h̃.
pub fn cond_nll_grad(
    cd: &CondDesign,
    weights: &[f64],
    spec: CondSpec,
    params: &[f64],
) -> (f64, Vec<f64>) {
    let (j, d, q) = (spec.j, spec.d, spec.q);
    assert_eq!(params.len(), spec.n_params());
    let design = &cd.design;
    assert_eq!(design.j, j);
    assert_eq!(design.d, d);
    assert_eq!(cd.x.cols, q);

    // θ from β (cumulative softplus, as unconditional)
    let mut theta = vec![0.0; j * d];
    for jj in 0..j {
        let b = &params[jj * d..(jj + 1) * d];
        let t = &mut theta[jj * d..(jj + 1) * d];
        t[0] = b[0];
        for k in 1..d {
            t[k] = t[k - 1] + softplus(b[k]);
        }
    }
    let gamma = &params[spec.gamma_off()..spec.lambda_off()];
    let lam = &params[spec.lambda_off()..];
    let lam_off: Vec<usize> = (0..j).map(|jj| jj * jj.saturating_sub(1) / 2).collect();

    let mut total = 0.0;
    let mut grad = vec![0.0; spec.n_params()];
    let mut grad_theta = vec![0.0; j * d];
    let (mut htil, mut hd, mut z, mut ghtil) =
        (vec![0.0; j], vec![0.0; j], vec![0.0; j], vec![0.0; j]);

    for i in 0..design.n {
        let w = if weights.is_empty() { 1.0 } else { weights[i] };
        if w == 0.0 {
            continue;
        }
        let xi = cd.x.row(i);
        for jj in 0..j {
            let th = &theta[jj * d..(jj + 1) * d];
            let (arow, adrow) = (design.a_row(i, jj), design.ad_row(i, jj));
            let mut ha = 0.0;
            let mut hb = 0.0;
            for k in 0..d {
                ha += arow[k] * th[k];
                hb += adrow[k] * th[k];
            }
            let g = &gamma[jj * q..(jj + 1) * q];
            let mut shift = 0.0;
            for c in 0..q {
                shift += g[c] * xi[c];
            }
            htil[jj] = ha + shift;
            hd[jj] = hb;
        }
        for jj in 0..j {
            let mut zz = htil[jj];
            for ll in 0..jj {
                zz += lam[lam_off[jj] + ll] * htil[ll];
            }
            z[jj] = zz;
        }
        let mut loss = 0.0;
        for jj in 0..j {
            let hdv = hd[jj].max(super::model::ETA_FLOOR);
            loss += 0.5 * z[jj] * z[jj] - hdv.ln();
        }
        total += w * loss;

        // gradients
        for ll in 0..j {
            let mut gh = z[ll];
            for jj in (ll + 1)..j {
                gh += lam[lam_off[jj] + ll] * z[jj];
            }
            ghtil[ll] = gh;
        }
        for jj in 0..j {
            let hdv = hd[jj].max(super::model::ETA_FLOOR);
            let ca = w * ghtil[jj];
            let cad = -w / hdv;
            let gt = &mut grad_theta[jj * d..(jj + 1) * d];
            let (arow, adrow) = (design.a_row(i, jj), design.ad_row(i, jj));
            for k in 0..d {
                gt[k] += ca * arow[k] + cad * adrow[k];
            }
            // Γ gradient: ∂h̃_j/∂γ_j = x
            let gg = &mut grad[spec.gamma_off() + jj * q..spec.gamma_off() + (jj + 1) * q];
            for c in 0..q {
                gg[c] += ca * xi[c];
            }
        }
        let goff = spec.lambda_off();
        for jj in 1..j {
            for ll in 0..jj {
                grad[goff + lam_off[jj] + ll] += w * z[jj] * htil[ll];
            }
        }
    }

    // chain θ → β (suffix sums + sigmoid), write into the β block
    for jj in 0..j {
        let b = &params[jj * d..(jj + 1) * d];
        let g = &mut grad_theta[jj * d..(jj + 1) * d];
        for k in (0..d - 1).rev() {
            g[k] += g[k + 1];
        }
        for k in 1..d {
            g[k] *= super::params::sigmoid(b[k]);
        }
    }
    grad[..j * d].copy_from_slice(&grad_theta);
    (total, grad)
}

/// Objective adapter for the generic optimizers.
pub struct CondNll<'a> {
    pub spec: CondSpec,
    pub cd: &'a CondDesign,
    pub weights: Vec<f64>,
}

impl crate::fit::Objective for CondNll<'_> {
    fn dim(&self) -> usize {
        self.spec.n_params()
    }
    fn value_grad_into(&self, x: &[f64], grad: &mut [f64]) -> f64 {
        let (v, g) = cond_nll_grad(self.cd, &self.weights, self.spec, x);
        grad.copy_from_slice(&g);
        v
    }
}

/// Initialization mirroring the unconditional default (Γ = 0, λ = 0).
pub fn cond_init(spec: CondSpec) -> Vec<f64> {
    let base = super::params::Params::init(spec.unconditional());
    let mut x = vec![0.0; spec.n_params()];
    x[..spec.j * spec.d].copy_from_slice(&base.x[..spec.j * spec.d]);
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fit::{minimize, FitOptions};
    use crate::util::rng::Rng;

    fn toy(n: usize, q: usize, seed: u64) -> (Mat, Mat) {
        // y₁ | x shifted by 2·x₁; y₂ independent
        let mut rng = Rng::new(seed);
        let x = Mat::from_vec(n, q, (0..n * q).map(|_| rng.normal()).collect());
        let mut y = Mat::zeros(n, 2);
        for i in 0..n {
            *y.at_mut(i, 0) = 2.0 * x.at(i, 0) + rng.normal();
            *y.at_mut(i, 1) = rng.normal();
        }
        (y, x)
    }

    #[test]
    fn gradient_matches_finite_difference() {
        let (y, x) = toy(20, 2, 1);
        let cd = CondDesign::build(&y, &x, 5, 0.01);
        let spec = CondSpec::new(2, 5, 2);
        let mut rng = Rng::new(2);
        let params: Vec<f64> = (0..spec.n_params()).map(|_| 0.4 * rng.normal()).collect();
        let (_, g) = cond_nll_grad(&cd, &[], spec, &params);
        let h = 1e-6;
        for k in 0..spec.n_params() {
            let mut pp = params.clone();
            pp[k] += h;
            let mut pm = params.clone();
            pm[k] -= h;
            let (fp, _) = cond_nll_grad(&cd, &[], spec, &pp);
            let (fm, _) = cond_nll_grad(&cd, &[], spec, &pm);
            let fd = (fp - fm) / (2.0 * h);
            assert!(
                (g[k] - fd).abs() < 1e-4 * (1.0 + fd.abs()),
                "param {k}: {} vs {fd}",
                g[k]
            );
        }
    }

    #[test]
    fn recovers_conditional_shift() {
        let (y, x) = toy(3_000, 1, 3);
        let cd = CondDesign::build(&y, &x, 6, 0.01);
        let spec = CondSpec::new(2, 6, 1);
        let obj = CondNll { spec, cd: &cd, weights: Vec::new() };
        let opts = FitOptions { max_iters: 200, ..Default::default() };
        let (fit, nll_cond, _, _) = minimize(&obj, cond_init(spec), &opts);
        // γ₁ must be clearly non-zero (y₁ depends on x) and γ₂ ≈ 0
        let g1 = fit[spec.gamma_off()];
        let g2 = fit[spec.gamma_off() + 1];
        assert!(g1.abs() > 5.0 * g2.abs().max(0.02), "γ₁={g1} γ₂={g2}");
        // conditioning must improve the likelihood vs Γ forced to 0
        let mut nocond = fit.clone();
        nocond[spec.gamma_off()] = 0.0;
        nocond[spec.gamma_off() + 1] = 0.0;
        let (nll_nocond, _) = cond_nll_grad(&cd, &[], spec, &nocond);
        assert!(
            nll_cond < nll_nocond - 100.0,
            "conditioning should help: {nll_cond} vs {nll_nocond}"
        );
    }

    #[test]
    fn conditional_coreset_through_extended_stacked_matrix() {
        use crate::coreset::leverage::leverage_scores;
        use crate::util::rng::AliasTable;
        let (y, x) = toy(2_000, 1, 5);
        let cd = CondDesign::build(&y, &x, 5, 0.01);
        let spec = CondSpec::new(2, 5, 1);
        let opts = FitOptions { max_iters: 150, ..Default::default() };

        // full conditional fit
        let obj = CondNll { spec, cd: &cd, weights: Vec::new() };
        let (full, _, _, _) = minimize(&obj, cond_init(spec), &opts);

        // leverage on the EXTENDED stacked matrix (dJ + q columns)
        let stacked = cd.stacked();
        assert_eq!(stacked.cols, 2 * 5 + 1);
        let u = leverage_scores(&stacked).unwrap();
        let n = cd.design.n;
        let s: Vec<f64> = u.iter().map(|ui| ui + 1.0 / n as f64).collect();
        let table = AliasTable::new(&s);
        let mut rng = Rng::new(7);
        let k = 200;
        let mut idx = Vec::new();
        let mut w = Vec::new();
        for _ in 0..k {
            let i = table.sample(&mut rng);
            idx.push(i);
            w.push(1.0 / (k as f64 * table.p(i)));
        }
        let sub = cd.select(&idx);
        let obj_sub = CondNll { spec, cd: &sub, weights: w };
        let (coreset_fit, _, _, _) = minimize(&obj_sub, cond_init(spec), &opts);

        // the conditional effect must survive the coreset
        let gf = full[spec.gamma_off()];
        let gc = coreset_fit[spec.gamma_off()];
        assert!(
            (gf - gc).abs() < 0.35 * gf.abs().max(0.1),
            "γ full {gf} vs coreset {gc}"
        );
    }
}
