//! Parameter layout and the monotone reparametrization.
//!
//! Free parameters x ∈ R^p (p = J·d + J(J−1)/2):
//!   x[0 .. J·d]            — β, row-major (j, k): basis pre-coefficients
//!   x[J·d ..]              — λ, the strictly-lower-triangular copula
//!                            entries in row-major order (1,0), (2,0),
//!                            (2,1), (3,0), …
//! The Bernstein coefficients are ϑ_{j,0} = β_{j,0},
//! ϑ_{j,k} = ϑ_{j,k−1} + softplus(β_{j,k}), which makes every marginal
//! transformation strictly increasing and keeps log h̃' finite — the
//! model-side counterpart of the paper's D(η) domain restriction.

/// Static shape of an MCTM: J output components, d basis functions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ModelSpec {
    pub j: usize,
    pub d: usize,
}

impl ModelSpec {
    pub fn new(j: usize, d: usize) -> Self {
        assert!(j >= 1 && d >= 2);
        ModelSpec { j, d }
    }

    /// Number of free λ entries.
    #[inline]
    pub fn n_lambda(&self) -> usize {
        self.j * (self.j - 1) / 2
    }

    /// Total free-parameter dimension p.
    #[inline]
    pub fn n_params(&self) -> usize {
        self.j * self.d + self.n_lambda()
    }

    /// Index of λ_{jl} (j > l) within the λ block.
    #[inline]
    pub fn lambda_index(&self, j: usize, l: usize) -> usize {
        debug_assert!(l < j && j < self.j);
        j * (j - 1) / 2 + l
    }
}

/// Numerically stable softplus ln(1 + eˣ).
#[inline]
pub fn softplus(x: f64) -> f64 {
    if x > 30.0 {
        x
    } else if x < -30.0 {
        x.exp()
    } else {
        x.exp().ln_1p()
    }
}

/// Logistic sigmoid σ(x) = softplus′(x).
#[inline]
pub fn sigmoid(x: f64) -> f64 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

/// Inverse softplus (for initialisation): y = ln(eˣ − 1).
#[inline]
pub fn softplus_inv(y: f64) -> f64 {
    assert!(y > 0.0);
    if y > 30.0 {
        y
    } else {
        (y.exp() - 1.0).ln()
    }
}

/// A parameter vector view with conversion helpers.
#[derive(Clone, Debug)]
pub struct Params {
    pub spec: ModelSpec,
    /// the free vector (β then λ)
    pub x: Vec<f64>,
}

impl Params {
    pub fn new(spec: ModelSpec, x: Vec<f64>) -> Self {
        assert_eq!(x.len(), spec.n_params());
        Params { spec, x }
    }

    /// Sensible default initialisation: each marginal transformation is
    /// (approximately) the affine map [0,1] → [−2, 2], λ = 0. With
    /// min–max-scaled inputs this makes z roughly standard-normal at the
    /// start, which keeps early optimizer steps well-conditioned.
    pub fn init(spec: ModelSpec) -> Self {
        let d = spec.d;
        let step = 4.0 / (d - 1) as f64;
        let mut x = vec![0.0; spec.n_params()];
        for j in 0..spec.j {
            x[j * d] = -2.0;
            for k in 1..d {
                x[j * d + k] = softplus_inv(step);
            }
        }
        Params { spec, x }
    }

    /// β block view for component j.
    #[inline]
    pub fn beta(&self, j: usize) -> &[f64] {
        &self.x[j * self.spec.d..(j + 1) * self.spec.d]
    }

    /// λ_{jl} for j > l.
    #[inline]
    pub fn lambda(&self, j: usize, l: usize) -> f64 {
        self.x[self.spec.j * self.spec.d + self.spec.lambda_index(j, l)]
    }

    /// λ block as a slice.
    #[inline]
    pub fn lambda_block(&self) -> &[f64] {
        &self.x[self.spec.j * self.spec.d..]
    }

    /// Materialize the monotone coefficients ϑ (row-major (j,k)).
    pub fn theta(&self) -> Vec<f64> {
        let mut theta = vec![0.0; self.spec.j * self.spec.d];
        self.theta_into(&mut theta);
        theta
    }

    /// [`Params::theta`] into a caller-owned buffer (length J·d) — the
    /// allocation-free path the optimizer-loop evaluation reuses
    /// (`mctm::model::NllScratch`).
    pub fn theta_into(&self, theta: &mut [f64]) {
        let (j, d) = (self.spec.j, self.spec.d);
        debug_assert_eq!(theta.len(), j * d);
        for jj in 0..j {
            let b = self.beta(jj);
            let t = &mut theta[jj * d..(jj + 1) * d];
            t[0] = b[0];
            for k in 1..d {
                t[k] = t[k - 1] + softplus(b[k]);
            }
        }
    }

    /// Chain-rule: pull a gradient w.r.t. ϑ back to β **in place**
    /// (reverse cumulative sums + sigmoid factors).
    pub fn grad_theta_to_beta(&self, grad_theta: &mut [f64]) {
        let (j, d) = (self.spec.j, self.spec.d);
        debug_assert_eq!(grad_theta.len(), j * d);
        for jj in 0..j {
            let b = self.beta(jj);
            let g = &mut grad_theta[jj * d..(jj + 1) * d];
            // suffix sums: s_k = Σ_{k' ≥ k} ∂L/∂ϑ_{k'}
            for k in (0..d - 1).rev() {
                g[k] += g[k + 1];
            }
            // ∂ϑ_{k'}/∂β_0 = 1 ∀k' ⇒ g[0] already the full sum;
            // ∂ϑ_{k'}/∂β_k = σ(β_k) for k ≤ k', k ≥ 1
            for k in 1..d {
                g[k] *= sigmoid(b[k]);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_counts() {
        let s = ModelSpec::new(3, 7);
        assert_eq!(s.n_lambda(), 3);
        assert_eq!(s.n_params(), 24);
        assert_eq!(s.lambda_index(1, 0), 0);
        assert_eq!(s.lambda_index(2, 0), 1);
        assert_eq!(s.lambda_index(2, 1), 2);
    }

    #[test]
    fn softplus_stable() {
        assert!((softplus(0.0) - 2.0f64.ln()).abs() < 1e-12);
        assert!((softplus(100.0) - 100.0).abs() < 1e-12);
        assert!(softplus(-100.0) > 0.0);
        assert!((softplus_inv(softplus(1.3)) - 1.3).abs() < 1e-9);
    }

    #[test]
    fn theta_is_monotone() {
        let spec = ModelSpec::new(2, 6);
        let mut x = vec![0.0; spec.n_params()];
        for (i, v) in x.iter_mut().enumerate() {
            *v = (i as f64 * 0.7).sin() * 2.0;
        }
        let p = Params::new(spec, x);
        let theta = p.theta();
        for j in 0..2 {
            for k in 1..6 {
                assert!(theta[j * 6 + k] > theta[j * 6 + k - 1]);
            }
        }
    }

    #[test]
    fn init_spans_minus2_to_2() {
        let spec = ModelSpec::new(2, 7);
        let p = Params::init(spec);
        let theta = p.theta();
        assert!((theta[0] + 2.0).abs() < 1e-9);
        assert!((theta[6] - 2.0).abs() < 1e-6);
        assert!(p.lambda_block().iter().all(|&l| l == 0.0));
    }

    #[test]
    fn grad_chain_rule_matches_fd() {
        // finite-difference check of grad_theta_to_beta through a toy
        // scalar function L(ϑ) = Σ c_k ϑ_k
        let spec = ModelSpec::new(1, 5);
        let x = vec![0.3, -0.7, 1.1, 0.2, -0.4];
        let p = Params::new(spec, x.clone());
        let c = [0.5, -1.0, 2.0, 0.1, 0.9];
        let f = |xs: &[f64]| -> f64 {
            let pp = Params::new(spec, xs.to_vec());
            pp.theta().iter().zip(&c).map(|(t, ci)| t * ci).sum()
        };
        let mut g = c.to_vec();
        p.grad_theta_to_beta(&mut g);
        let h = 1e-6;
        for k in 0..5 {
            let mut xp = x.clone();
            xp[k] += h;
            let mut xm = x.clone();
            xm[k] -= h;
            let fd = (f(&xp) - f(&xm)) / (2.0 * h);
            assert!((g[k] - fd).abs() < 1e-6, "k={k}: {} vs {fd}", g[k]);
        }
    }
}
