//! Bootstrap confidence intervals for MCTM parameters (paper §1.3:
//! "MCTMs are likelihood-based and therefore yield access to confidence
//! intervals via bootstrapping") — implemented over the *coreset*, so
//! interval estimation inherits the same n → k reduction as point
//! estimation: each replicate resamples the weighted coreset
//! (multinomial with probabilities ∝ weights, preserving total mass)
//! and refits.

use super::params::{ModelSpec, Params};
use crate::basis::Design;
use crate::fit::{minimize, FitOptions, NativeNll};
use crate::util::rng::{AliasTable, Rng};

/// A per-parameter percentile interval.
#[derive(Clone, Debug)]
pub struct ParamInterval {
    pub lo: f64,
    pub hi: f64,
    pub point: f64,
}

/// Bootstrap result: intervals for every free parameter and for the
/// materialized ϑ coefficients.
#[derive(Clone, Debug)]
pub struct BootstrapResult {
    pub spec: ModelSpec,
    /// intervals on the free vector (β then λ)
    pub free: Vec<ParamInterval>,
    /// intervals on the monotone ϑ (row-major j,k)
    pub theta: Vec<ParamInterval>,
    pub replicates: usize,
}

impl BootstrapResult {
    /// Interval for λ_{jl}.
    pub fn lambda(&self, j: usize, l: usize) -> &ParamInterval {
        &self.free[self.spec.j * self.spec.d + self.spec.lambda_index(j, l)]
    }
}

/// Percentile bootstrap over a weighted (coreset) design.
///
/// `level` is the two-sided coverage (e.g. 0.95). Replicates draw
/// `design.n` rows with probabilities ∝ weights and weight n/k each
/// (total mass preserved), then refit from the point estimate.
pub fn bootstrap_ci(
    design: &Design,
    weights: &[f64],
    point: &Params,
    replicates: usize,
    level: f64,
    opts: &FitOptions,
    rng: &mut Rng,
) -> BootstrapResult {
    assert!(replicates >= 8, "need a handful of replicates");
    assert!((0.5..1.0).contains(&level));
    let spec = point.spec;
    let n = design.n;
    let w = if weights.is_empty() {
        vec![1.0; n]
    } else {
        weights.to_vec()
    };
    let total_w: f64 = w.iter().sum();
    let table = AliasTable::new(&w);

    // warm-started refits: start each replicate from the point estimate
    let mut warm_opts = opts.clone();
    warm_opts.max_iters = opts.max_iters.min(120);

    // hoisted replicate state: the resample index buffer, the
    // sub-design (gathered in place via `Design::select_into`), the
    // uniform replicate weights and the cold-start vector are allocated
    // once and reused across every replicate — `tests/fit_alloc.rs`
    // pins that per-replicate allocations stay flat
    let m = n; // resample size = coreset size
    let init_x = Params::init(spec).x;
    let rw = vec![total_w / m as f64; m];
    let mut idx: Vec<usize> = Vec::with_capacity(m);
    let mut sub = design.select(&[]);

    let mut free_samples: Vec<Vec<f64>> = Vec::with_capacity(replicates);
    let mut theta_samples: Vec<Vec<f64>> = Vec::with_capacity(replicates);
    for _ in 0..replicates {
        idx.clear();
        for _ in 0..m {
            idx.push(table.sample(rng));
        }
        design.select_into(&idx, &mut sub);
        // one objective per replicate, two starts — cold (the default
        // init, as `fit_native` would) and warm (the point estimate) —
        // keeping whichever converges lower
        let obj = NativeNll::new(spec, &sub, rw.clone());
        let (xc, nll_c, _, _) = minimize(&obj, init_x.clone(), &warm_opts);
        let (xw, nll_w, _, _) = minimize(&obj, point.x.clone(), &warm_opts);
        let params = if nll_w.is_finite() && nll_w <= nll_c {
            Params::new(spec, xw)
        } else {
            Params::new(spec, xc)
        };
        theta_samples.push(params.theta());
        free_samples.push(params.x);
    }

    let alpha = (1.0 - level) / 2.0;
    let make = |samples: &[Vec<f64>], points: &[f64]| -> Vec<ParamInterval> {
        let p = points.len();
        (0..p)
            .map(|k| {
                let mut vals: Vec<f64> = samples.iter().map(|s| s[k]).collect();
                vals.sort_by(f64::total_cmp);
                let lo_i = ((vals.len() as f64) * alpha).floor() as usize;
                let hi_i =
                    (((vals.len() as f64) * (1.0 - alpha)).ceil() as usize).min(vals.len()) - 1;
                ParamInterval { lo: vals[lo_i], hi: vals[hi_i], point: points[k] }
            })
            .collect()
    };
    let theta_point = point.theta();
    BootstrapResult {
        spec,
        free: make(&free_samples, &point.x),
        theta: make(&theta_samples, &theta_point),
        replicates,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::experiment::design_of;
    use crate::coreset::samplers::build_coreset_on;
    use crate::coreset::Method;
    use crate::data::dgp::Dgp;
    use crate::fit::fit_native;
    use crate::util::parallel::Pool;

    fn quick_opts() -> FitOptions {
        FitOptions { max_iters: 80, ..Default::default() }
    }

    #[test]
    fn lambda_interval_covers_truth_and_excludes_zero() {
        // ρ = 0.7 Gaussian ⇒ λ₂₁ strongly negative; a 90% interval from
        // a k = 200 coreset must exclude 0 and contain the full-data fit
        let mut rng = Rng::new(1);
        let data = Dgp::BivariateNormal.generate(5_000, &mut rng);
        let design = design_of(&data, 6);
        let spec = ModelSpec::new(2, 6);
        let full = fit_native(spec, &design, Vec::new(), &quick_opts());

        let cs = build_coreset_on(
            &design,
            Method::L2Hull,
            200,
            &mut rng,
            &Pool::current(),
            &crate::util::degrade::DegradeSink::new(),
        );
        let sub = design.select(&cs.indices);
        let point = fit_native(spec, &sub, cs.weights.clone(), &quick_opts());
        let boot = bootstrap_ci(
            &sub,
            &cs.weights,
            &point.params,
            24,
            0.9,
            &quick_opts(),
            &mut rng,
        );
        let ci = boot.lambda(1, 0);
        assert!(ci.hi < 0.0, "interval should exclude 0: [{}, {}]", ci.lo, ci.hi);
        let truth = full.params.lambda(1, 0);
        assert!(
            ci.lo - 0.2 <= truth && truth <= ci.hi + 0.2,
            "full-fit λ {truth} far outside [{}, {}]",
            ci.lo,
            ci.hi
        );
    }

    #[test]
    fn intervals_are_ordered_and_contain_percentile_mass() {
        let mut rng = Rng::new(2);
        let data = Dgp::Sinusoidal.generate(1_000, &mut rng);
        let design = design_of(&data, 5);
        let spec = ModelSpec::new(2, 5);
        let point = fit_native(spec, &design, Vec::new(), &quick_opts());
        let boot = bootstrap_ci(&design, &[], &point.params, 12, 0.8, &quick_opts(), &mut rng);
        assert_eq!(boot.free.len(), spec.n_params());
        assert_eq!(boot.theta.len(), spec.j * spec.d);
        for ci in boot.free.iter().chain(&boot.theta) {
            assert!(ci.lo <= ci.hi, "[{}, {}]", ci.lo, ci.hi);
            assert!(ci.lo.is_finite() && ci.hi.is_finite());
        }
    }

    #[test]
    fn wider_level_gives_wider_interval() {
        let mut rng = Rng::new(3);
        let data = Dgp::BivariateNormal.generate(1_500, &mut rng);
        let design = design_of(&data, 5);
        let spec = ModelSpec::new(2, 5);
        let point = fit_native(spec, &design, Vec::new(), &quick_opts());
        let narrow =
            bootstrap_ci(&design, &[], &point.params, 16, 0.5, &quick_opts(), &mut Rng::new(9));
        let wide =
            bootstrap_ci(&design, &[], &point.params, 16, 0.95, &quick_opts(), &mut Rng::new(9));
        let li = spec.j * spec.d;
        let (n_ci, w_ci) = (&narrow.free[li], &wide.free[li]);
        assert!(
            w_ci.hi - w_ci.lo >= n_ci.hi - n_ci.lo - 1e-12,
            "95% [{}, {}] vs 50% [{}, {}]",
            w_ci.lo,
            w_ci.hi,
            n_ci.lo,
            n_ci.hi
        );
    }
}
