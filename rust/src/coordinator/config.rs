//! Experiment configuration: defaults + a minimal `key = value` config
//! file format + CLI-style overrides. (No external TOML crate offline;
//! the format is the flat subset of TOML the launcher needs.)
//!
//! Every failure path — unknown keys, unparsable numbers, unknown
//! method names — surfaces as a typed [`ApiError`] (PR 4), not an
//! ad-hoc string chain; `ExperimentConfig::session` turns a validated
//! config into a facade [`Session`].

use crate::api::{ApiError, Session, SessionBuilder};
use crate::coreset::Method;
use crate::fit::{FitOptions, OptimizerKind};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Everything the launcher needs to run one experiment.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// dataset / DGP name (see data::dgp::Dgp::name or "covertype" /
    /// "stocks10" / "stocks20")
    pub dataset: String,
    /// number of observations to generate
    pub n: usize,
    /// coreset size
    pub k: usize,
    /// sampling method
    pub method: Method,
    /// Bernstein basis size d (degree d−1)
    pub d: usize,
    /// repetitions (for mean ± std reporting)
    pub reps: usize,
    /// RNG seed
    pub seed: u64,
    /// fitting backend: "native" or "xla"
    pub backend: String,
    /// artifact directory for the xla backend
    pub artifacts: PathBuf,
    /// optimizer settings
    pub fit: FitOptions,
    /// output directory for CSV/JSON results
    pub out_dir: PathBuf,
    /// worker threads for the parallel kernels; 0 = auto (MCTM_THREADS
    /// env var if set, else available parallelism). Thread count never
    /// changes results — kernels are deterministic by construction.
    pub threads: usize,
    /// transient-fault retry budget: shard reads in streaming runs and
    /// per-worker transport attempts in `dist-fit` (must be ≥ 1)
    pub retry_limit: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            dataset: "bivariate-normal".into(),
            n: 10_000,
            k: 30,
            method: Method::L2Hull,
            d: 7,
            reps: 10,
            seed: 42,
            backend: "native".into(),
            artifacts: PathBuf::from("artifacts"),
            fit: FitOptions::default(),
            out_dir: PathBuf::from("results"),
            threads: 0,
            retry_limit: crate::coordinator::pipeline::SHARD_RETRY_LIMIT,
        }
    }
}

/// Parse a numeric config value, reporting the key on failure.
fn parse_num<T: std::str::FromStr>(key: &str, value: &str) -> Result<T, ApiError>
where
    T::Err: std::fmt::Display,
{
    value
        .parse()
        .map_err(|e| ApiError::config(key, format!("`{value}`: {e}")))
}

impl ExperimentConfig {
    /// Parse a `key = value` config file (lines starting with `#` are
    /// comments), then apply `overrides` (same syntax, e.g. from CLI
    /// `--set k=100`).
    pub fn load(path: Option<&Path>, overrides: &[String]) -> Result<Self, ApiError> {
        let mut cfg = ExperimentConfig::default();
        let mut kv: HashMap<String, String> = HashMap::new();
        if let Some(p) = path {
            let text = std::fs::read_to_string(p)
                .map_err(|e| ApiError::Io(format!("reading config {}: {e}", p.display())))?;
            parse_kv(&text, &mut kv)?;
        }
        for ov in overrides {
            parse_kv(ov, &mut kv)?;
        }
        for (key, value) in kv {
            cfg.set(&key, &value)?;
        }
        Ok(cfg)
    }

    /// Apply one key.
    pub fn set(&mut self, key: &str, value: &str) -> Result<(), ApiError> {
        match key {
            "dataset" => self.dataset = value.to_string(),
            "n" => self.n = parse_num(key, value)?,
            "k" => self.k = parse_num(key, value)?,
            "d" => self.d = parse_num(key, value)?,
            "reps" => self.reps = parse_num(key, value)?,
            "seed" => self.seed = parse_num(key, value)?,
            "backend" => {
                if value != "native" && value != "xla" {
                    return Err(ApiError::config(key, format!(
                        "must be native|xla, got `{value}`"
                    )));
                }
                self.backend = value.to_string();
            }
            "artifacts" => self.artifacts = PathBuf::from(value),
            "out_dir" => self.out_dir = PathBuf::from(value),
            // the strategy registry owns name → method resolution (and
            // the typed error lists every valid name)
            "method" => {
                self.method =
                    Method::parse(value).map_err(|_| ApiError::unknown_method(value))?
            }
            "optimizer" => {
                self.fit.optimizer = match value {
                    "adam" => OptimizerKind::Adam,
                    "lbfgs" => OptimizerKind::Lbfgs,
                    other => {
                        return Err(ApiError::config(key, format!(
                            "must be lbfgs|adam, got `{other}`"
                        )))
                    }
                };
            }
            "threads" => self.threads = parse_num(key, value)?,
            "retry_limit" => self.retry_limit = parse_num(key, value)?,
            "max_iters" => self.fit.max_iters = parse_num(key, value)?,
            "tol" => self.fit.tol = parse_num(key, value)?,
            "learning_rate" => self.fit.learning_rate = parse_num(key, value)?,
            other => {
                return Err(ApiError::config(other, "unknown config key"));
            }
        }
        Ok(())
    }

    /// Turn this (already validated) config into a facade [`Session`]:
    /// the single place where CLI knobs map onto builder knobs.
    pub fn session(&self) -> Result<Session, ApiError> {
        let mut b = SessionBuilder::new()
            .method_tag(self.method)
            .budget(self.k)
            .basis_size(self.d)
            .seed(self.seed)
            .shard_retry_limit(self.retry_limit)
            .fit_options(self.fit.clone());
        if self.threads > 0 {
            b = b.threads(self.threads);
        }
        b.build()
    }
}

fn parse_kv(text: &str, kv: &mut HashMap<String, String>) -> Result<(), ApiError> {
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (k, v) = line.split_once('=').ok_or_else(|| {
            ApiError::config(line, "expected `key = value`")
        })?;
        kv.insert(k.trim().to_string(), v.trim().trim_matches('"').to_string());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_and_overrides() {
        let cfg = ExperimentConfig::load(
            None,
            &["k = 100".into(), "method = uniform".into(), "backend = xla".into()],
        )
        .unwrap();
        assert_eq!(cfg.k, 100);
        assert_eq!(cfg.method, Method::Uniform);
        assert_eq!(cfg.backend, "xla");
        assert_eq!(cfg.n, 10_000); // default preserved
    }

    #[test]
    fn file_then_override_precedence() {
        let dir = std::env::temp_dir().join("mctm_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("exp.conf");
        std::fs::write(&p, "# comment\nn = 500\nk = 20\noptimizer = adam\n").unwrap();
        let cfg =
            ExperimentConfig::load(Some(&p), &["k = 40".into()]).unwrap();
        assert_eq!(cfg.n, 500);
        assert_eq!(cfg.k, 40); // override wins
        assert_eq!(cfg.fit.optimizer, crate::fit::OptimizerKind::Adam);
    }

    #[test]
    fn rejects_unknown_keys_with_typed_errors() {
        assert!(matches!(
            ExperimentConfig::load(None, &["bogus = 1".into()]).unwrap_err(),
            ApiError::Config { .. }
        ));
        assert!(matches!(
            ExperimentConfig::load(None, &["method = nope".into()]).unwrap_err(),
            ApiError::UnknownMethod { .. }
        ));
        assert!(matches!(
            ExperimentConfig::load(None, &["k = banana".into()]).unwrap_err(),
            ApiError::Config { .. }
        ));
    }

    #[test]
    fn method_roundtrip_every_registered_name() {
        // parse → name() → parse is the identity for the whole registry
        for m in Method::all() {
            let cfg =
                ExperimentConfig::load(None, &[format!("method = {}", m.name())]).unwrap();
            assert_eq!(cfg.method, m);
            assert_eq!(cfg.method.name(), m.name());
        }
    }

    #[test]
    fn unknown_method_error_lists_valid_names() {
        let err = ExperimentConfig::load(None, &["method = not-a-method".into()]).unwrap_err();
        let msg = format!("{err:#}");
        for m in Method::all() {
            assert!(msg.contains(m.name()), "error should list {}: {msg}", m.name());
        }
    }

    #[test]
    fn retry_limit_key_maps_onto_the_builder_knob() {
        let cfg = ExperimentConfig::load(None, &["retry_limit = 7".into()]).unwrap();
        assert_eq!(cfg.retry_limit, 7);
        assert!(cfg.session().is_ok());
        // zero is rejected by the builder's validation, as a typed error
        let bad = ExperimentConfig::load(None, &["retry_limit = 0".into()]).unwrap();
        match bad.session().unwrap_err() {
            ApiError::Config { key, .. } => assert_eq!(key, "shard_retry_limit"),
            other => panic!("expected Config error, got {other:?}"),
        }
    }

    #[test]
    fn config_maps_onto_a_session() {
        let cfg = ExperimentConfig::load(
            None,
            &["k = 77".into(), "method = ellipsoid".into(), "threads = 2".into()],
        )
        .unwrap();
        let session = cfg.session().unwrap();
        assert_eq!(session.budget(), 77);
        assert_eq!(session.method(), Method::Ellipsoid);
        // an invalid budget surfaces as a typed builder error
        let mut bad = cfg.clone();
        bad.k = 0;
        assert!(matches!(bad.session().unwrap_err(), ApiError::Config { .. }));
    }
}
