//! Experiment configuration: defaults + a minimal `key = value` config
//! file format + CLI-style overrides. (No external TOML crate offline;
//! the format is the flat subset of TOML the launcher needs.)

use crate::coreset::Method;
use crate::fit::{FitOptions, OptimizerKind};
use crate::anyhow;
use crate::util::error::Result;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Everything the launcher needs to run one experiment.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// dataset / DGP name (see data::dgp::Dgp::name or "covertype" /
    /// "stocks10" / "stocks20")
    pub dataset: String,
    /// number of observations to generate
    pub n: usize,
    /// coreset size
    pub k: usize,
    /// sampling method
    pub method: Method,
    /// Bernstein basis size d (degree d−1)
    pub d: usize,
    /// repetitions (for mean ± std reporting)
    pub reps: usize,
    /// RNG seed
    pub seed: u64,
    /// fitting backend: "native" or "xla"
    pub backend: String,
    /// artifact directory for the xla backend
    pub artifacts: PathBuf,
    /// optimizer settings
    pub fit: FitOptions,
    /// output directory for CSV/JSON results
    pub out_dir: PathBuf,
    /// worker threads for the parallel kernels; 0 = auto (MCTM_THREADS
    /// env var if set, else available parallelism). Thread count never
    /// changes results — kernels are deterministic by construction.
    pub threads: usize,
}

impl Default for ExperimentConfig {
    fn default() -> Self {
        ExperimentConfig {
            dataset: "bivariate-normal".into(),
            n: 10_000,
            k: 30,
            method: Method::L2Hull,
            d: 7,
            reps: 10,
            seed: 42,
            backend: "native".into(),
            artifacts: PathBuf::from("artifacts"),
            fit: FitOptions::default(),
            out_dir: PathBuf::from("results"),
            threads: 0,
        }
    }
}

impl ExperimentConfig {
    /// Parse a `key = value` config file (lines starting with `#` are
    /// comments), then apply `overrides` (same syntax, e.g. from CLI
    /// `--set k=100`).
    pub fn load(path: Option<&Path>, overrides: &[String]) -> Result<Self> {
        let mut cfg = ExperimentConfig::default();
        let mut kv: HashMap<String, String> = HashMap::new();
        if let Some(p) = path {
            let text = std::fs::read_to_string(p)
                .map_err(|e| anyhow!("reading config {}: {e}", p.display()))?;
            parse_kv(&text, &mut kv)?;
        }
        for ov in overrides {
            parse_kv(ov, &mut kv)?;
        }
        for (key, value) in kv {
            cfg.set(&key, &value)?;
        }
        Ok(cfg)
    }

    /// Apply one key.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        match key {
            "dataset" => self.dataset = value.to_string(),
            "n" => self.n = value.parse()?,
            "k" => self.k = value.parse()?,
            "d" => self.d = value.parse()?,
            "reps" => self.reps = value.parse()?,
            "seed" => self.seed = value.parse()?,
            "backend" => {
                if value != "native" && value != "xla" {
                    return Err(anyhow!("backend must be native|xla, got {value}"));
                }
                self.backend = value.to_string();
            }
            "artifacts" => self.artifacts = PathBuf::from(value),
            "out_dir" => self.out_dir = PathBuf::from(value),
            // the strategy registry owns name → method resolution (and
            // its error lists every valid name)
            "method" => self.method = Method::parse(value)?,
            "optimizer" => {
                self.fit.optimizer = match value {
                    "adam" => OptimizerKind::Adam,
                    "lbfgs" => OptimizerKind::Lbfgs,
                    other => return Err(anyhow!("unknown optimizer {other}")),
                };
            }
            "threads" => self.threads = value.parse()?,
            "max_iters" => self.fit.max_iters = value.parse()?,
            "tol" => self.fit.tol = value.parse()?,
            "learning_rate" => self.fit.learning_rate = value.parse()?,
            other => return Err(anyhow!("unknown config key {other}")),
        }
        Ok(())
    }
}

fn parse_kv(text: &str, kv: &mut HashMap<String, String>) -> Result<()> {
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| anyhow!("expected key = value, got `{line}`"))?;
        kv.insert(k.trim().to_string(), v.trim().trim_matches('"').to_string());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_and_overrides() {
        let cfg = ExperimentConfig::load(
            None,
            &["k = 100".into(), "method = uniform".into(), "backend = xla".into()],
        )
        .unwrap();
        assert_eq!(cfg.k, 100);
        assert_eq!(cfg.method, Method::Uniform);
        assert_eq!(cfg.backend, "xla");
        assert_eq!(cfg.n, 10_000); // default preserved
    }

    #[test]
    fn file_then_override_precedence() {
        let dir = std::env::temp_dir().join("mctm_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("exp.conf");
        std::fs::write(&p, "# comment\nn = 500\nk = 20\noptimizer = adam\n").unwrap();
        let cfg =
            ExperimentConfig::load(Some(&p), &["k = 40".into()]).unwrap();
        assert_eq!(cfg.n, 500);
        assert_eq!(cfg.k, 40); // override wins
        assert_eq!(cfg.fit.optimizer, crate::fit::OptimizerKind::Adam);
    }

    #[test]
    fn rejects_unknown_keys() {
        assert!(ExperimentConfig::load(None, &["bogus = 1".into()]).is_err());
        assert!(ExperimentConfig::load(None, &["method = nope".into()]).is_err());
    }

    #[test]
    fn method_roundtrip_every_registered_name() {
        // parse → name() → parse is the identity for the whole registry
        for m in Method::all() {
            let cfg =
                ExperimentConfig::load(None, &[format!("method = {}", m.name())]).unwrap();
            assert_eq!(cfg.method, m);
            assert_eq!(cfg.method.name(), m.name());
        }
    }

    #[test]
    fn unknown_method_error_lists_valid_names() {
        let err = ExperimentConfig::load(None, &["method = not-a-method".into()]).unwrap_err();
        let msg = format!("{err:#}");
        for m in Method::all() {
            assert!(msg.contains(m.name()), "error should list {}: {msg}", m.name());
        }
    }
}
