//! L3 coordinator: the experiment harness behind every paper table and
//! figure, the streaming coreset pipeline (bounded-queue backpressure +
//! Merge & Reduce), the configuration system and the CLI.

pub mod cli;
pub mod config;
pub mod experiment;
pub mod pipeline;

pub use config::ExperimentConfig;
pub use experiment::{run_method, summarize, FullFit, MethodStats};
pub use pipeline::{StreamError, StreamStats, StreamingPipeline, SHARD_RETRY_LIMIT};
