//! Streaming coreset pipeline (the data-pipeline face of the paper,
//! §4): a producer thread generates/reads data shards, a bounded
//! channel applies backpressure (the producer blocks when the reducers
//! fall behind — no unbounded buffering), and a **fan-out of consumer
//! workers** leaf-reduces shards in parallel before a single reducer
//! folds them into the Merge & Reduce coreset tree. Each shard's leaf
//! reduce uses an RNG seeded by (pipeline seed, shard sequence number)
//! and leaves are folded in sequence order through a reorder buffer, so
//! the final coreset is identical for any number of consumers. The
//! final coreset is fitted exactly like an in-memory one.
//!
//! Fault tolerance (ISSUE 6):
//!
//! * `ShardSource::next_shard` returns `Result`; **transient** read
//!   errors are retried up to [`SHARD_RETRY_LIMIT`] times with
//!   attempt-count (not wall-clock) backoff, and a retried read does
//!   **not** consume a sequence number — so a run that recovers from
//!   transient faults is bit-identical to the fault-free run.
//! * **Fatal** errors (and transient ones that exhaust the budget)
//!   trigger an orderly shutdown: an abort flag stops the producer,
//!   consumers drain out of their channel/condvar waits, every lock is
//!   poison-recovering, and the first error (smallest shard sequence)
//!   surfaces as a typed [`StreamError`] instead of a panic or hang.
//! * Empty shards are skipped without consuming a sequence number;
//!   non-finite cells are handled per the session's
//!   [`InvalidPolicy`](crate::data::InvalidPolicy) by the producer in
//!   sequence order (deterministic at any consumer count). Every such
//!   event is recorded into the run's shared
//!   [`DegradeSink`](crate::util::degrade::DegradeSink).
//!
//! The pipeline holds only a `Method` tag; every per-method decision
//! inside the leaf/tree reduces (scores, hull budget) dispatches
//! through the strategy registry (`coreset::strategy`), so any
//! registered method — the §4 ellipsoid ones included — streams end to
//! end with the same determinism guarantees (pinned at consumers
//! {1, 4} by `tests/pipeline_e2e.rs` and `tests/fault_injection.rs`).

use crate::coreset::merge_reduce::{reduce_with, MergeReduce, WeightedRows};
use crate::coreset::Method;
use crate::data::{scrub_invalid, InvalidPolicy, ShardError, ShardSource};
use crate::linalg::Mat;
use crate::util::degrade::DegradeSink;
use crate::util::parallel;
use crate::util::rng::Rng;
use crate::util::Stopwatch;
use std::collections::BTreeMap;
use std::fmt;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::sync_channel;
use std::sync::{Condvar, Mutex};

/// How many times a [`ShardError::Transient`] read is retried before it
/// is escalated to a fatal stream error. Retries are attempt-counted,
/// never slept — wall-clock backoff would not help a deterministic
/// in-process source and would make runs timing-dependent.
pub const SHARD_RETRY_LIMIT: usize = 3;

/// A typed streaming failure: what went wrong, at which shard, and (for
/// consumer-side failures) on which consumer. Converted to
/// `ApiError::Stream` at the facade boundary.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StreamError {
    /// Sequence number of the shard being handled when the error hit
    /// (`None` for failures not attributable to one shard, e.g. the
    /// final tree collapse).
    pub shard_seq: Option<usize>,
    /// Index of the consumer worker that failed (`None` for
    /// producer-side and reducer-side failures).
    pub consumer: Option<usize>,
    /// Human-readable cause.
    pub message: String,
}

impl fmt::Display for StreamError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "stream error")?;
        if let Some(seq) = self.shard_seq {
            write!(f, " at shard {seq}")?;
        }
        if let Some(c) = self.consumer {
            write!(f, " (consumer {c})")?;
        }
        write!(f, ": {}", self.message)
    }
}

impl std::error::Error for StreamError {}

/// Diagnostics from a streaming run.
#[derive(Clone, Debug)]
pub struct StreamStats {
    pub n_seen: usize,
    pub n_shards: usize,
    pub n_reduces: usize,
    pub coreset_size: usize,
    pub seconds: f64,
    /// max shard-queue depth observed at any send (backpressure
    /// indicator: a value pinned at `queue_cap` means the consumers
    /// were the bottleneck; never exceeds `queue_cap`)
    pub peak_queue: usize,
    /// max reorder-buffer depth observed: how far the fastest consumer
    /// ran ahead of the in-order tree reducer (≤ queue_cap + consumers)
    pub peak_reorder: usize,
}

/// The streaming coordinator.
pub struct StreamingPipeline {
    pub method: Method,
    pub k: usize,
    pub d: usize,
    /// min–max scaling margin ε used inside every reduce's design build
    pub eps: f64,
    /// bounded-queue capacity (shards in flight)
    pub queue_cap: usize,
    pub seed: u64,
    /// Merge & Reduce intermediate-level size multiplier
    pub buffer_factor: usize,
    /// consumer workers running leaf reduces in parallel (defaults to
    /// the global worker count; results do not depend on this)
    pub consumers: usize,
    /// what to do with non-finite cells at ingestion (producer-side,
    /// sequence order — deterministic at any consumer count)
    pub on_invalid: InvalidPolicy,
    /// transient-read retry budget per shard (defaults to
    /// [`SHARD_RETRY_LIMIT`]; configured via
    /// `SessionBuilder::shard_retry_limit`)
    pub retry_limit: usize,
    /// degradation accounting shared with the whole run (retries, empty
    /// shards, scrubbed rows, reduce-side numerical fallbacks)
    pub(crate) sink: DegradeSink,
}

impl StreamingPipeline {
    /// Crate-internal constructor behind `api::Session` (the pre-0.3
    /// `StreamingPipeline::new` shim has been removed — configure
    /// streaming through `SessionBuilder`).
    pub(crate) fn assemble(method: Method, k: usize, d: usize) -> Self {
        StreamingPipeline {
            method,
            k,
            d,
            eps: 0.01,
            queue_cap: 4,
            seed: 0xC0FF_EE,
            buffer_factor: 4,
            consumers: parallel::threads(),
            on_invalid: InvalidPolicy::default(),
            retry_limit: SHARD_RETRY_LIMIT,
            sink: DegradeSink::new(),
        }
    }

    /// Consume a shard source to a final weighted coreset.
    ///
    /// The producer runs on its own thread; `sync_channel(queue_cap)`
    /// blocks it when the reducers are busy — bounded memory regardless
    /// of stream length. Consumers pull shards from the shared channel,
    /// leaf-reduce them with deterministic per-shard RNGs, and send the
    /// leaves to the in-order tree reducer.
    ///
    /// On failure (fatal shard read, exhausted retries, invalid data
    /// under [`InvalidPolicy::Error`], a reduce that cannot proceed)
    /// every thread is signalled to stop, the bounded channels drain,
    /// and the first error in sequence order is returned — the run
    /// never panics or deadlocks on a faulty source.
    pub fn run(
        &self,
        mut source: impl ShardSource + Send + 'static,
    ) -> Result<(WeightedRows, StreamStats), StreamError> {
        let sw = Stopwatch::start();
        let consumers = self.consumers.max(1);
        let (shard_tx, shard_rx) = sync_channel::<(usize, Mat)>(self.queue_cap);

        // shared failure state: the first error in *sequence order* wins
        // (deterministic at any consumer count); the abort flag tells
        // every thread to wind down
        let error: Mutex<Option<StreamError>> = Mutex::new(None);
        let abort = AtomicBool::new(false);
        // Bounded reorder window: a consumer may not start reducing a
        // shard more than `window` sequence numbers ahead of the
        // in-order reducer, so the reorder buffer — and with it total
        // memory — stays bounded even when one early shard is slow and
        // the other consumers race ahead. The consumer holding the
        // next-to-fold sequence never waits (seq < folded + window),
        // so the window cannot deadlock.
        let window = self.queue_cap + consumers;
        let progress = (Mutex::new(0usize), Condvar::new());

        let mut mr = MergeReduce::new(self.method, self.k, self.d, self.eps, self.seed);
        mr.buffer_factor = self.buffer_factor;
        mr.sink = self.sink.clone();
        // reducer-side merges run concurrently with busy consumers — the
        // consumers are the parallelism, so the tree reduces stay serial
        mr.pool = crate::util::parallel::Pool::new(1);
        let k_buffer = self.buffer_factor * self.k;
        let (method, d, eps, base_seed) = (self.method, self.d, self.eps, self.seed);
        let on_invalid = self.on_invalid;
        let sink = self.sink.clone();

        // the consumers ARE the parallelism when fanned out — but a
        // single consumer may use the full worker pool inside its leaf
        // reduces (basis, leverage, hull selection). Every kernel is
        // bit-identical for any pool width, so this cannot change the
        // coreset — only wall-clock (pinned by
        // `streaming_hull_deterministic_across_consumers`).
        let leaf_pool = if consumers == 1 {
            parallel::Pool::current()
        } else {
            parallel::Pool::new(1)
        };

        let mut n_shards = 0usize;
        let mut peak_reorder = 0usize;
        // measured shard-queue occupancy: the producer bumps the depth
        // before each send and records the post-send high-water mark;
        // consumers decrement after each take. The depth can lag a
        // take by one (item received, counter not yet decremented), so
        // the recorded peak is clamped at `queue_cap` — the bounded
        // channel itself can never hold more.
        let q_depth = AtomicUsize::new(0);
        let q_peak = AtomicUsize::new(0);
        let shard_rx = Mutex::new(shard_rx);
        let (leaf_tx, leaf_rx) =
            sync_channel::<(usize, WeightedRows, usize)>(self.queue_cap + consumers);

        // record an error (keeping the one with the smallest shard
        // sequence — deterministic regardless of which thread loses the
        // race) and signal everyone to stop. Declared outside the scope
        // so scoped threads can borrow it.
        let fail = |err: StreamError| {
            let mut slot = lock_ok(&error);
            let replace = match &*slot {
                None => true,
                Some(old) => seq_rank(err.shard_seq) < seq_rank(old.shard_seq),
            };
            if replace {
                *slot = Some(err);
            }
            drop(slot);
            abort.store(true, Ordering::SeqCst);
            // wake consumers parked on the reorder window — take the
            // window lock first so a waiter has either already observed
            // the abort flag under the lock or is parked on the condvar
            // and receives this notification. Notifying without the
            // lock could fire between a waiter's abort check and its
            // wait(), leaving it asleep forever (lost wakeup: the
            // sleeper's leaf_tx clone would keep the reducer's recv
            // loop alive and deadlock the run).
            let _window = lock_ok(&progress.0);
            progress.1.notify_all();
        };

        let (out, n_seen) = std::thread::scope(|s| {
            // ---- producer: read shards, retry transients, scrub ----
            let producer = s.spawn({
                let fail = &fail;
                let abort = &abort;
                let sink = sink.clone();
                let (q_depth, q_peak) = (&q_depth, &q_peak);
                let queue_cap = self.queue_cap;
                let retry_limit = self.retry_limit;
                move || {
                    let j = source.dim();
                    let mut produced = 0usize;
                    let mut seq = 0usize;
                    'stream: loop {
                        if abort.load(Ordering::SeqCst) {
                            break;
                        }
                        // bounded, attempt-counted retry: a transient
                        // fault re-requests the SAME shard, so seq (and
                        // with it every downstream RNG) is untouched
                        let mut attempts = 0usize;
                        let shard = loop {
                            match source.next_shard() {
                                Ok(s) => {
                                    // count retries only once the read
                                    // has recovered — exhausted budgets
                                    // surface as a typed stream error,
                                    // not as recorded retries
                                    if attempts > 0 {
                                        sink.shard_retries(attempts);
                                    }
                                    break s;
                                }
                                Err(ShardError::Transient(_)) if attempts < retry_limit => {
                                    attempts += 1;
                                }
                                Err(e) => {
                                    let kind = match e {
                                        ShardError::Transient(_) => "transient (retries exhausted)",
                                        ShardError::Fatal(_) => "fatal",
                                    };
                                    fail(StreamError {
                                        shard_seq: Some(seq),
                                        consumer: None,
                                        message: format!("{kind} shard read error: {}", e.message()),
                                    });
                                    break 'stream;
                                }
                            }
                        };
                        let Some(shard) = shard else { break };
                        // spurious empty shards are skipped without
                        // consuming a sequence number, so they cannot
                        // shift downstream RNG streams
                        if shard.rows == 0 {
                            sink.empty_shard_skipped();
                            continue;
                        }
                        if shard.cols != j {
                            fail(StreamError {
                                shard_seq: Some(seq),
                                consumer: None,
                                message: format!(
                                    "shard dimension mismatch: {} columns, source dim {j}",
                                    shard.cols
                                ),
                            });
                            break;
                        }
                        // invalid-cell policy runs here, in sequence
                        // order, so scrubbing is deterministic at any
                        // consumer count
                        let shard = match scrub_invalid(shard, on_invalid, &sink) {
                            Ok(m) => m,
                            Err((row, col)) => {
                                fail(StreamError {
                                    shard_seq: Some(seq),
                                    consumer: None,
                                    message: format!(
                                        "non-finite value at shard {seq}, row {row}, column {col} \
                                         (policy: error; set on_invalid to mask or drop)"
                                    ),
                                });
                                break;
                            }
                        };
                        if shard.rows == 0 {
                            // every row dropped: nothing to stream
                            sink.empty_shard_skipped();
                            continue;
                        }
                        produced += shard.rows;
                        q_depth.fetch_add(1, Ordering::SeqCst);
                        if shard_tx.send((seq, shard)).is_err() {
                            break; // consumers dropped (downstream abort)
                        }
                        let depth = q_depth.load(Ordering::SeqCst);
                        q_peak.fetch_max(depth.min(queue_cap), Ordering::SeqCst);
                        seq += 1;
                    }
                    produced
                }
            });

            // ---- consumers: leaf-reduce shards in parallel ----
            for ci in 0..consumers {
                let shard_rx = &shard_rx;
                let leaf_tx = leaf_tx.clone();
                let progress = &progress;
                let abort = &abort;
                let fail = &fail;
                let leaf_pool = &leaf_pool;
                let sink = sink.clone();
                let q_depth = &q_depth;
                s.spawn(move || {
                    'work: loop {
                        if abort.load(Ordering::SeqCst) {
                            break;
                        }
                        // recv under the lock serializes the *take*, not
                        // the reduce — workers overlap on the expensive
                        // part
                        let msg = lock_ok(shard_rx).recv();
                        match msg {
                            Ok((seq, shard)) => {
                                q_depth.fetch_sub(1, Ordering::SeqCst);
                                // bounded reorder window: don't run too
                                // far ahead of the in-order reducer
                                {
                                    let (folded, cv) = progress;
                                    let mut guard = lock_ok_guarded(folded);
                                    while seq >= *guard + window
                                        && !abort.load(Ordering::SeqCst)
                                    {
                                        guard = cv
                                            .wait(guard)
                                            .unwrap_or_else(|e| e.into_inner());
                                    }
                                }
                                if abort.load(Ordering::SeqCst) {
                                    break 'work;
                                }
                                let n_raw = shard.rows;
                                let mut rng = Rng::new(shard_seed(base_seed, seq));
                                let leaf = match reduce_with(
                                    &WeightedRows::new(shard, vec![1.0; n_raw]),
                                    method,
                                    k_buffer,
                                    d,
                                    eps,
                                    &mut rng,
                                    leaf_pool,
                                    &sink,
                                ) {
                                    Ok(l) => l,
                                    Err(e) => {
                                        fail(StreamError {
                                            shard_seq: Some(seq),
                                            consumer: Some(ci),
                                            message: format!("leaf reduce failed: {e}"),
                                        });
                                        break 'work;
                                    }
                                };
                                if leaf_tx.send((seq, leaf, n_raw)).is_err() {
                                    break 'work;
                                }
                            }
                            Err(_) => break 'work, // producer done, drained
                        }
                    }
                    // abort path: keep draining the shard queue so a
                    // producer blocked on the full bounded channel can
                    // observe the abort flag and exit — without this,
                    // a fatal consumer-side error could deadlock the
                    // producer on `send`
                    while abort.load(Ordering::SeqCst) {
                        if lock_ok(shard_rx).recv().is_err() {
                            break;
                        }
                        q_depth.fetch_sub(1, Ordering::SeqCst);
                    }
                });
            }
            drop(leaf_tx); // only worker clones remain

            // ---- reducer: fold leaves in sequence order ----
            // reorder buffer: fold leaves into the tree in shard order,
            // so the merge RNG stream is independent of scheduling. The
            // recv loop keeps draining after an abort so no consumer
            // stays blocked on the bounded leaf channel.
            let mut pending: BTreeMap<usize, (WeightedRows, usize)> = BTreeMap::new();
            let mut next_seq = 0usize;
            for (seq, leaf, n_raw) in leaf_rx.iter() {
                n_shards += 1;
                if abort.load(Ordering::SeqCst) {
                    continue; // drain without folding
                }
                pending.insert(seq, (leaf, n_raw));
                peak_reorder = peak_reorder.max(pending.len());
                if pending.contains_key(&next_seq) {
                    while let Some((leaf, n_raw)) = pending.remove(&next_seq) {
                        if let Err(e) = mr.push_reduced(leaf, n_raw) {
                            fail(StreamError {
                                shard_seq: Some(next_seq),
                                consumer: None,
                                message: format!("tree reduce failed: {e}"),
                            });
                            break;
                        }
                        next_seq += 1;
                    }
                    // publish progress and wake consumers waiting on the
                    // reorder window
                    let (folded, cv) = &progress;
                    *lock_ok_guarded(folded) = next_seq;
                    cv.notify_all();
                }
            }
            if !pending.is_empty() && lock_ok(&error).is_none() {
                // gaps with no recorded failure would mean lost shards —
                // surface it as a typed error rather than asserting
                fail(StreamError {
                    shard_seq: Some(next_seq),
                    consumer: None,
                    message: format!(
                        "lost shard sequence numbers: reducer stalled at {next_seq} with {} \
                         leaves pending",
                        pending.len()
                    ),
                });
            }

            let n_seen = match producer.join() {
                Ok(n) => n,
                Err(_) => {
                    fail(StreamError {
                        shard_seq: None,
                        consumer: None,
                        message: "producer thread panicked".into(),
                    });
                    0
                }
            };
            (mr, n_seen)
        });

        if let Some(err) = lock_ok(&error).take() {
            return Err(err);
        }
        let n_reduces = out.n_reduces;
        let coreset = out.finish().map_err(|e| StreamError {
            shard_seq: None,
            consumer: None,
            message: format!("final tree collapse failed: {e}"),
        })?;
        let stats = StreamStats {
            n_seen,
            n_shards,
            n_reduces,
            coreset_size: coreset.len(),
            seconds: sw.secs(),
            peak_queue: q_peak.load(Ordering::SeqCst),
            peak_reorder,
        };
        Ok((coreset, stats))
    }
}

/// Rank a shard sequence for "first error wins": attributable errors
/// order by shard, unattributable ones (`None`) sort last.
fn seq_rank(seq: Option<usize>) -> u64 {
    match seq {
        Some(s) => s as u64,
        None => u64::MAX,
    }
}

/// Poison-recovering lock: a worker that panicked while holding the
/// mutex must not cascade into every other thread — the protected state
/// (channel handle, error slot, progress counter) stays valid.
fn lock_ok<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Same as [`lock_ok`]; separate name where the guard is held across a
/// condvar wait (documentation aid only).
fn lock_ok_guarded<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Deterministic per-shard RNG seed: mixes the pipeline seed with the
/// shard's sequence number (SplitMix-style odd multiplier) so shard
/// reduces are independent of which worker runs them and of each other.
/// Crate-visible: the distributed workers (`crate::dist`) must seed
/// their leaf reduces identically for an N-worker run to be
/// bit-identical to the in-process pipeline.
pub(crate) fn shard_seed(base: u64, seq: usize) -> u64 {
    base ^ (seq as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dgp::Dgp;
    use crate::data::faulty::{FaultPlan, FaultySource};
    use crate::data::GenShards;
    use crate::util::rng::Rng;

    #[test]
    fn stream_matches_batch_quality() {
        // streaming coreset of a 20k stream should be a valid bounded
        // coreset with total weight ≈ n
        let pipeline = StreamingPipeline::assemble(Method::L2Hull, 60, 5);
        let mut rng = Rng::new(11);
        let source = GenShards::new(
            move |n| Dgp::BivariateNormal.generate(n, &mut rng),
            2,
            20_000,
            2_000,
        );
        let (coreset, stats) = pipeline.run(source).unwrap();
        assert_eq!(stats.n_seen, 20_000);
        assert_eq!(stats.n_shards, 10);
        assert!(stats.n_reduces >= 10);
        assert!(coreset.len() <= 60);
        let tot: f64 = coreset.weights.iter().sum();
        assert!(tot > 2_000.0 && tot < 200_000.0, "total weight {tot}");
    }

    #[test]
    fn consumer_fanout_is_deterministic() {
        // identical stream, 1 vs 8 consumers → bit-identical coreset:
        // per-shard RNGs are seeded by sequence number and leaves fold
        // in order through the reorder buffer
        let make_source = |seed: u64| {
            let mut rng = Rng::new(seed);
            GenShards::new(
                move |n| Dgp::BivariateNormal.generate(n, &mut rng),
                2,
                8_000,
                1_000,
            )
        };
        let mut p1 = StreamingPipeline::assemble(Method::L2Hull, 40, 5);
        p1.consumers = 1;
        let mut p8 = StreamingPipeline::assemble(Method::L2Hull, 40, 5);
        p8.consumers = 8;
        let (c1, s1) = p1.run(make_source(99)).unwrap();
        let (c8, s8) = p8.run(make_source(99)).unwrap();
        assert_eq!(s1.n_seen, s8.n_seen);
        assert_eq!(c1.weights, c8.weights);
        assert_eq!(c1.rows.data, c8.rows.data);
    }

    #[test]
    fn empty_stream_is_empty_coreset() {
        let pipeline = StreamingPipeline::assemble(Method::Uniform, 10, 5);
        let source = GenShards::new(|n| Mat::zeros(n, 2), 2, 0, 100);
        let (coreset, stats) = pipeline.run(source).unwrap();
        assert_eq!(stats.n_seen, 0);
        assert_eq!(coreset.len(), 0);
    }

    #[test]
    fn transient_faults_recover_bit_identically() {
        // the headline invariant at the pipeline level: recovered
        // transient faults leave no trace in the coreset
        let make_source = |seed: u64| {
            let mut rng = Rng::new(seed);
            GenShards::new(
                move |n| Dgp::BivariateNormal.generate(n, &mut rng),
                2,
                6_000,
                1_000,
            )
        };
        let pipeline = StreamingPipeline::assemble(Method::L2Hull, 40, 5);
        let (clean, _) = pipeline.run(make_source(7)).unwrap();

        let faulty = FaultySource::new(
            make_source(7),
            FaultPlan::new(13).with_transients(2, SHARD_RETRY_LIMIT),
        );
        let pipeline2 = StreamingPipeline::assemble(Method::L2Hull, 40, 5);
        let (recovered, _) = pipeline2.run(faulty).unwrap();
        assert_eq!(clean.weights, recovered.weights);
        assert_eq!(clean.rows.data, recovered.rows.data);
        assert!(pipeline2.sink.snapshot().shard_retries > 0);
    }

    #[test]
    fn retry_limit_is_configurable() {
        // a fault that needs more retries than the default budget
        // succeeds under a raised limit and keeps the bytes identical
        let make_source = |seed: u64| {
            let mut rng = Rng::new(seed);
            GenShards::new(
                move |n| Dgp::BivariateNormal.generate(n, &mut rng),
                2,
                4_000,
                1_000,
            )
        };
        let clean_pipeline = StreamingPipeline::assemble(Method::L2Hull, 30, 5);
        let (clean, _) = clean_pipeline.run(make_source(17)).unwrap();

        let deep_fault = || {
            FaultySource::new(
                make_source(17),
                FaultPlan::new(9).with_transients(3, SHARD_RETRY_LIMIT + 2),
            )
        };
        // default budget: exhausted, typed error
        let default_pipeline = StreamingPipeline::assemble(Method::L2Hull, 30, 5);
        let err = default_pipeline.run(deep_fault()).unwrap_err();
        assert!(err.message.contains("retries exhausted"), "{err}");
        // exhausted budgets record nothing (success-only accounting)
        assert_eq!(default_pipeline.sink.snapshot().shard_retries, 0);

        // raised budget: recovers bit-identically and records retries
        let mut patient = StreamingPipeline::assemble(Method::L2Hull, 30, 5);
        patient.retry_limit = SHARD_RETRY_LIMIT + 2;
        let (recovered, _) = patient.run(deep_fault()).unwrap();
        assert_eq!(clean.weights, recovered.weights);
        assert_eq!(clean.rows.data, recovered.rows.data);
        assert!(patient.sink.snapshot().shard_retries > 0);
    }

    #[test]
    fn fatal_fault_is_typed_not_panic() {
        let mut rng = Rng::new(3);
        let source = GenShards::new(
            move |n| Dgp::BivariateNormal.generate(n, &mut rng),
            2,
            6_000,
            1_000,
        );
        let faulty = FaultySource::new(source, FaultPlan::new(5).with_fatal_at(2));
        let pipeline = StreamingPipeline::assemble(Method::L2Hull, 40, 5);
        let err = pipeline.run(faulty).unwrap_err();
        assert_eq!(err.shard_seq, Some(2));
        assert!(err.message.contains("fatal"), "{err}");
    }
}
