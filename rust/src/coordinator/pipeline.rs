//! Streaming coreset pipeline (the data-pipeline face of the paper,
//! §4): a producer thread generates/reads data shards, a bounded
//! channel applies backpressure (the producer blocks when the reducers
//! fall behind — no unbounded buffering), and a **fan-out of consumer
//! workers** leaf-reduces shards in parallel before a single reducer
//! folds them into the Merge & Reduce coreset tree. Each shard's leaf
//! reduce uses an RNG seeded by (pipeline seed, shard sequence number)
//! and leaves are folded in sequence order through a reorder buffer, so
//! the final coreset is identical for any number of consumers. The
//! final coreset is fitted exactly like an in-memory one.
//!
//! The pipeline holds only a `Method` tag; every per-method decision
//! inside the leaf/tree reduces (scores, hull budget) dispatches
//! through the strategy registry (`coreset::strategy`), so any
//! registered method — the §4 ellipsoid ones included — streams end to
//! end with the same determinism guarantees (pinned at consumers
//! {1, 4} by `tests/pipeline_e2e.rs`).

use crate::coreset::merge_reduce::{reduce_with, MergeReduce, WeightedRows};
use crate::coreset::Method;
use crate::data::ShardSource;
use crate::linalg::Mat;
use crate::util::parallel;
use crate::util::rng::Rng;
use crate::util::Stopwatch;
use std::collections::BTreeMap;
use std::sync::mpsc::sync_channel;
use std::sync::{Condvar, Mutex};

/// Diagnostics from a streaming run.
#[derive(Clone, Debug)]
pub struct StreamStats {
    pub n_seen: usize,
    pub n_shards: usize,
    pub n_reduces: usize,
    pub coreset_size: usize,
    pub seconds: f64,
    /// upper bound on the shard-queue depth (backpressure indicator:
    /// never exceeds `queue_cap` — the bounded channel guarantees it)
    pub peak_queue: usize,
    /// max reorder-buffer depth observed: how far the fastest consumer
    /// ran ahead of the in-order tree reducer (≤ queue_cap + consumers)
    pub peak_reorder: usize,
}

/// The streaming coordinator.
pub struct StreamingPipeline {
    pub method: Method,
    pub k: usize,
    pub d: usize,
    /// min–max scaling margin ε used inside every reduce's design build
    pub eps: f64,
    /// bounded-queue capacity (shards in flight)
    pub queue_cap: usize,
    pub seed: u64,
    /// Merge & Reduce intermediate-level size multiplier
    pub buffer_factor: usize,
    /// consumer workers running leaf reduces in parallel (defaults to
    /// the global worker count; results do not depend on this)
    pub consumers: usize,
}

impl StreamingPipeline {
    /// Deprecated public constructor — configure streaming through the
    /// facade instead (`SessionBuilder::queue_cap` / `buffer_factor` /
    /// `consumers`, then `Session::fit` on a shard source). The shim
    /// stays for one release.
    #[deprecated(
        since = "0.2.0",
        note = "use mctm_coreset::prelude::SessionBuilder and feed Session::fit a shard \
                source; this constructor will be removed next release"
    )]
    pub fn new(method: Method, k: usize, d: usize) -> Self {
        Self::assemble(method, k, d)
    }

    /// Crate-internal constructor behind `api::Session` (and the shim
    /// above).
    pub(crate) fn assemble(method: Method, k: usize, d: usize) -> Self {
        StreamingPipeline {
            method,
            k,
            d,
            eps: 0.01,
            queue_cap: 4,
            seed: 0xC0FF_EE,
            buffer_factor: 4,
            consumers: parallel::threads(),
        }
    }

    /// Consume a shard source to a final weighted coreset.
    ///
    /// The producer runs on its own thread; `sync_channel(queue_cap)`
    /// blocks it when the reducers are busy — bounded memory regardless
    /// of stream length. Consumers pull shards from the shared channel,
    /// leaf-reduce them with deterministic per-shard RNGs, and send the
    /// leaves to the in-order tree reducer.
    pub fn run(&self, mut source: impl ShardSource + Send + 'static) -> (WeightedRows, StreamStats) {
        let sw = Stopwatch::start();
        let consumers = self.consumers.max(1);
        let (shard_tx, shard_rx) = sync_channel::<(usize, Mat)>(self.queue_cap);
        let producer = std::thread::spawn(move || {
            let mut produced = 0usize;
            for seq in 0usize.. {
                match source.next_shard() {
                    Some(shard) => {
                        produced += shard.rows;
                        if shard_tx.send((seq, shard)).is_err() {
                            break; // consumers dropped
                        }
                    }
                    None => break,
                }
            }
            produced
        });

        let mut mr = MergeReduce::new(self.method, self.k, self.d, self.eps, self.seed);
        mr.buffer_factor = self.buffer_factor;
        // reducer-side merges run concurrently with busy consumers — the
        // consumers are the parallelism, so the tree reduces stay serial
        mr.pool = crate::util::parallel::Pool::new(1);
        let k_buffer = self.buffer_factor * self.k;
        let (method, d, eps, base_seed) = (self.method, self.d, self.eps, self.seed);

        // the consumers ARE the parallelism when fanned out — but a
        // single consumer may use the full worker pool inside its leaf
        // reduces (basis, leverage, hull selection). Every kernel is
        // bit-identical for any pool width, so this cannot change the
        // coreset — only wall-clock (pinned by
        // `streaming_hull_deterministic_across_consumers`).
        let leaf_pool = if consumers == 1 {
            parallel::Pool::current()
        } else {
            parallel::Pool::new(1)
        };

        let mut n_shards = 0usize;
        let mut peak_reorder = 0usize;
        let shard_rx = Mutex::new(shard_rx);
        let (leaf_tx, leaf_rx) =
            sync_channel::<(usize, WeightedRows, usize)>(self.queue_cap + consumers);
        // Bounded reorder window: a consumer may not start reducing a
        // shard more than `window` sequence numbers ahead of the
        // in-order reducer, so the reorder buffer — and with it total
        // memory — stays bounded even when one early shard is slow and
        // the other consumers race ahead. The consumer holding the
        // next-to-fold sequence never waits (seq < folded + window),
        // so the window cannot deadlock.
        let window = self.queue_cap + consumers;
        let progress = (Mutex::new(0usize), Condvar::new());
        std::thread::scope(|s| {
            for _ in 0..consumers {
                let shard_rx = &shard_rx;
                let leaf_tx = leaf_tx.clone();
                let progress = &progress;
                s.spawn(move || loop {
                    // recv under the lock serializes the *take*, not the
                    // reduce — workers overlap on the expensive part
                    let msg = shard_rx.lock().expect("shard queue poisoned").recv();
                    match msg {
                        Ok((seq, shard)) => {
                            {
                                let (folded, cv) = progress;
                                let mut guard = folded.lock().expect("progress poisoned");
                                while seq >= *guard + window {
                                    guard = cv.wait(guard).expect("progress poisoned");
                                }
                            }
                            let n_raw = shard.rows;
                            let mut rng = Rng::new(shard_seed(base_seed, seq));
                            let leaf = reduce_with(
                                &WeightedRows::new(shard, vec![1.0; n_raw]),
                                method,
                                k_buffer,
                                d,
                                eps,
                                &mut rng,
                                &leaf_pool,
                            );
                            if leaf_tx.send((seq, leaf, n_raw)).is_err() {
                                break;
                            }
                        }
                        Err(_) => break, // producer done, channel drained
                    }
                });
            }
            drop(leaf_tx); // only worker clones remain

            // reorder buffer: fold leaves into the tree in shard order,
            // so the merge RNG stream is independent of scheduling
            let mut pending: BTreeMap<usize, (WeightedRows, usize)> = BTreeMap::new();
            let mut next_seq = 0usize;
            for (seq, leaf, n_raw) in leaf_rx.iter() {
                n_shards += 1;
                pending.insert(seq, (leaf, n_raw));
                peak_reorder = peak_reorder.max(pending.len());
                if pending.contains_key(&next_seq) {
                    while let Some((leaf, n_raw)) = pending.remove(&next_seq) {
                        mr.push_reduced(leaf, n_raw);
                        next_seq += 1;
                    }
                    // publish progress and wake consumers waiting on the
                    // reorder window
                    let (folded, cv) = &progress;
                    *folded.lock().expect("progress poisoned") = next_seq;
                    cv.notify_all();
                }
            }
            assert!(pending.is_empty(), "lost shard sequence numbers");
        });

        let n_seen = producer.join().expect("producer panicked");
        let n_reduces = mr.n_reduces;
        let out = mr.finish();
        let stats = StreamStats {
            n_seen,
            n_shards,
            n_reduces,
            coreset_size: out.len(),
            seconds: sw.secs(),
            // the bounded channel caps in-flight shards at queue_cap;
            // report the same conservative bound the serial reducer did
            peak_queue: self.queue_cap.min(n_shards),
            peak_reorder,
        };
        (out, stats)
    }
}

/// Deterministic per-shard RNG seed: mixes the pipeline seed with the
/// shard's sequence number (SplitMix-style odd multiplier) so shard
/// reduces are independent of which worker runs them and of each other.
fn shard_seed(base: u64, seq: usize) -> u64 {
    base ^ (seq as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dgp::Dgp;
    use crate::data::GenShards;
    use crate::util::rng::Rng;

    #[test]
    fn stream_matches_batch_quality() {
        // streaming coreset of a 20k stream should be a valid bounded
        // coreset with total weight ≈ n
        let pipeline = StreamingPipeline::assemble(Method::L2Hull, 60, 5);
        let mut rng = Rng::new(11);
        let source = GenShards::new(
            move |n| Dgp::BivariateNormal.generate(n, &mut rng),
            2,
            20_000,
            2_000,
        );
        let (coreset, stats) = pipeline.run(source);
        assert_eq!(stats.n_seen, 20_000);
        assert_eq!(stats.n_shards, 10);
        assert!(stats.n_reduces >= 10);
        assert!(coreset.len() <= 60);
        let tot: f64 = coreset.weights.iter().sum();
        assert!(tot > 2_000.0 && tot < 200_000.0, "total weight {tot}");
    }

    #[test]
    fn consumer_fanout_is_deterministic() {
        // identical stream, 1 vs 8 consumers → bit-identical coreset:
        // per-shard RNGs are seeded by sequence number and leaves fold
        // in order through the reorder buffer
        let make_source = |seed: u64| {
            let mut rng = Rng::new(seed);
            GenShards::new(
                move |n| Dgp::BivariateNormal.generate(n, &mut rng),
                2,
                8_000,
                1_000,
            )
        };
        let mut p1 = StreamingPipeline::assemble(Method::L2Hull, 40, 5);
        p1.consumers = 1;
        let mut p8 = StreamingPipeline::assemble(Method::L2Hull, 40, 5);
        p8.consumers = 8;
        let (c1, s1) = p1.run(make_source(99));
        let (c8, s8) = p8.run(make_source(99));
        assert_eq!(s1.n_seen, s8.n_seen);
        assert_eq!(c1.weights, c8.weights);
        assert_eq!(c1.rows.data, c8.rows.data);
    }

    #[test]
    fn empty_stream_is_empty_coreset() {
        let pipeline = StreamingPipeline::assemble(Method::Uniform, 10, 5);
        let source = GenShards::new(|n| Mat::zeros(n, 2), 2, 0, 100);
        let (coreset, stats) = pipeline.run(source);
        assert_eq!(stats.n_seen, 0);
        assert_eq!(coreset.len(), 0);
    }
}
