//! Streaming coreset pipeline (the data-pipeline face of the paper,
//! §4): a producer thread generates/reads data shards, a bounded
//! channel applies backpressure (the producer blocks when the reducer
//! falls behind — no unbounded buffering), and the consumer folds
//! shards into a Merge & Reduce coreset tree. The final coreset is
//! fitted exactly like an in-memory one.

use crate::coreset::merge_reduce::{MergeReduce, WeightedRows};
use crate::coreset::Method;
use crate::data::ShardSource;
use crate::linalg::Mat;
use crate::util::Stopwatch;
use std::sync::mpsc::sync_channel;

/// Diagnostics from a streaming run.
#[derive(Clone, Debug)]
pub struct StreamStats {
    pub n_seen: usize,
    pub n_shards: usize,
    pub n_reduces: usize,
    pub coreset_size: usize,
    pub seconds: f64,
    /// max queue depth observed (backpressure indicator)
    pub peak_queue: usize,
}

/// The streaming coordinator.
pub struct StreamingPipeline {
    pub method: Method,
    pub k: usize,
    pub d: usize,
    /// bounded-queue capacity (shards in flight)
    pub queue_cap: usize,
    pub seed: u64,
    /// Merge & Reduce intermediate-level size multiplier
    pub buffer_factor: usize,
}

impl StreamingPipeline {
    pub fn new(method: Method, k: usize, d: usize) -> Self {
        StreamingPipeline { method, k, d, queue_cap: 4, seed: 0xC0FF_EE, buffer_factor: 4 }
    }

    /// Consume a shard source to a final weighted coreset.
    ///
    /// The producer runs on its own thread; `sync_channel(queue_cap)`
    /// blocks it when the reducer is busy — bounded memory regardless
    /// of stream length.
    pub fn run(&self, mut source: impl ShardSource + Send + 'static) -> (WeightedRows, StreamStats) {
        let sw = Stopwatch::start();
        let (tx, rx) = sync_channel::<Mat>(self.queue_cap);
        let producer = std::thread::spawn(move || {
            let mut produced = 0usize;
            while let Some(shard) = source.next_shard() {
                produced += shard.rows;
                if tx.send(shard).is_err() {
                    break; // consumer dropped
                }
            }
            produced
        });

        let mut mr = MergeReduce::new(self.method, self.k, self.d, 0.01, self.seed);
        mr.buffer_factor = self.buffer_factor;
        let mut n_shards = 0usize;
        let mut peak_queue = 0usize;
        for shard in rx.iter() {
            n_shards += 1;
            // the channel has no len(); track an upper bound via the
            // bounded capacity (diagnostic only)
            peak_queue = peak_queue.max(self.queue_cap.min(n_shards));
            mr.push_shard(shard);
        }
        let n_seen = producer.join().expect("producer panicked");
        let n_reduces = mr.n_reduces;
        let out = mr.finish();
        let stats = StreamStats {
            n_seen,
            n_shards,
            n_reduces,
            coreset_size: out.len(),
            seconds: sw.secs(),
            peak_queue,
        };
        (out, stats)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dgp::Dgp;
    use crate::data::GenShards;
    use crate::util::rng::Rng;

    #[test]
    fn stream_matches_batch_quality() {
        // streaming coreset of a 20k stream should be a valid bounded
        // coreset with total weight ≈ n
        let pipeline = StreamingPipeline::new(Method::L2Hull, 60, 5);
        let mut rng = Rng::new(11);
        let source = GenShards::new(
            move |n| Dgp::BivariateNormal.generate(n, &mut rng),
            2,
            20_000,
            2_000,
        );
        let (coreset, stats) = pipeline.run(source);
        assert_eq!(stats.n_seen, 20_000);
        assert_eq!(stats.n_shards, 10);
        assert!(stats.n_reduces >= 10);
        assert!(coreset.len() <= 60);
        let tot: f64 = coreset.weights.iter().sum();
        assert!(tot > 2_000.0 && tot < 200_000.0, "total weight {tot}");
    }

    #[test]
    fn empty_stream_is_empty_coreset() {
        let pipeline = StreamingPipeline::new(Method::Uniform, 10, 5);
        let source = GenShards::new(|n| Mat::zeros(n, 2), 2, 0, 100);
        let (coreset, stats) = pipeline.run(source);
        assert_eq!(stats.n_seen, 0);
        assert_eq!(coreset.len(), 0);
    }
}
