//! The experiment harness: full-data baseline fit, per-method coreset
//! runs with the paper's metrics (ϑ-ℓ₂, λ error, log-likelihood ratio,
//! relative improvement, sampling/optimization time split), aggregated
//! as mean ± std over repetitions — the machinery behind Tables 1–6 and
//! Figures 1, 7–13.
//!
//! Since PR 4 every per-rep coreset build + fit goes through the facade
//! (`SessionBuilder` → `Session::fit`), so the harness measures exactly
//! what library users run. The per-rep session seed reproduces the
//! pre-facade RNG mixing, so sampled coresets are bit-identical to the
//! old direct path.

use crate::api::SessionBuilder;
use crate::basis::Design;
use crate::coreset::Method;
use crate::fit::{fit_native, FitOptions, FitResult};
use crate::linalg::Mat;
use crate::mctm::{self, lambda_error, loglik_ratio, theta_l2, ModelSpec};
use crate::util::{fmt_ms, mean, Stopwatch};

/// The cached full-data baseline.
pub struct FullFit {
    pub spec: ModelSpec,
    pub fit: FitResult,
    pub seconds: f64,
}

/// Fit the full data (the benchmark row of Table 2).
pub fn full_fit(design: &Design, spec: ModelSpec, opts: &FitOptions) -> FullFit {
    let sw = Stopwatch::start();
    let fit = fit_native(spec, design, Vec::new(), opts);
    FullFit { spec, fit, seconds: sw.secs() }
}

/// Raw per-repetition results for one (method, k).
#[derive(Clone, Debug, Default)]
pub struct MethodStats {
    pub method_name: &'static str,
    pub k: usize,
    pub theta_l2: Vec<f64>,
    pub lambda_err: Vec<f64>,
    pub lr: Vec<f64>,
    pub sample_secs: Vec<f64>,
    pub fit_secs: Vec<f64>,
    pub n_hull: Vec<f64>,
}

impl MethodStats {
    pub fn total_secs(&self) -> Vec<f64> {
        self.sample_secs
            .iter()
            .zip(&self.fit_secs)
            .map(|(a, b)| a + b)
            .collect()
    }

    /// (mean ϑ-ℓ₂, mean λ-err, mean LR) triple for relative improvement.
    pub fn triple(&self) -> (f64, f64, f64) {
        (mean(&self.theta_l2), mean(&self.lambda_err), mean(&self.lr))
    }
}

/// Run `reps` repetitions of: build coreset → fit on coreset → compare
/// against the full fit on the full data. Each repetition is one
/// facade session (`SessionBuilder` → `Session::fit`) with a per-rep
/// seed mixing identical to the pre-facade harness, so results are
/// bit-compatible with the old direct `build_coreset` path.
pub fn run_method(
    data: &Mat,
    full: &FullFit,
    method: Method,
    k: usize,
    reps: usize,
    seed: u64,
    opts: &FitOptions,
) -> MethodStats {
    let mut stats = MethodStats {
        method_name: method.name(),
        k,
        ..Default::default()
    };
    let d = full.spec.d;
    for rep in 0..reps {
        let rep_seed = seed ^ (0x9E37_79B9_7F4A_7C15u64.wrapping_mul(rep as u64 + 1));
        // the harness constructs its own knobs (validated tags, k ≥ 1)
        // and feeds non-empty in-memory matrices, so these two cannot
        // fail; a panic here is a harness bug, not a user-input error
        #[allow(clippy::expect_used)]
        let session = SessionBuilder::new()
            .method_tag(method)
            .budget(k)
            .basis_size(d)
            .seed(rep_seed)
            .fit_options(opts.clone())
            .build()
            .expect("harness session knobs are valid by construction");
        #[allow(clippy::expect_used)]
        let model = session
            .fit(data)
            .expect("harness data sources are non-empty");
        let diag = model.diagnostics();

        // metrics vs the full fit, NLL of coreset params ON FULL DATA
        let nll_on_full = model.nll(data);
        stats
            .lr
            .push(loglik_ratio(nll_on_full, full.fit.nll, data.rows, data.cols));
        stats
            .theta_l2
            .push(theta_l2(model.params(), &full.fit.params));
        stats
            .lambda_err
            .push(lambda_error(model.params(), &full.fit.params));
        stats.sample_secs.push(diag.coreset.seconds);
        stats.fit_secs.push(diag.fit_seconds);
        stats.n_hull.push(diag.coreset.n_hull as f64);
    }
    stats
}

/// One formatted table row: method, ϑ-ℓ₂, λ err, LR, rel.impr, time.
pub fn summarize(stats: &MethodStats, baseline: &MethodStats) -> Vec<String> {
    let imp = mctm::relative_improvement(stats.triple(), baseline.triple());
    vec![
        stats.method_name.to_string(),
        fmt_ms(&stats.theta_l2),
        fmt_ms(&stats.lambda_err),
        fmt_ms(&stats.lr),
        if std::ptr::eq(stats, baseline) {
            "baseline".to_string()
        } else {
            format!("{imp:.1}")
        },
        fmt_ms(&stats.total_secs()),
    ]
}

/// Build the design once from raw data (shared scaling for all methods).
pub fn design_of(data: &Mat, d: usize) -> Design {
    Design::build(data, d, 0.01)
}

/// Convenience wrapper: everything Table-3-style benches need for one
/// dataset: full fit once, then each method at one k (each run through
/// the facade — see [`run_method`]).
pub struct TableRunner {
    pub data: Mat,
    pub design: Design,
    pub spec: ModelSpec,
    pub full: FullFit,
    pub opts: FitOptions,
    pub seed: u64,
}

impl TableRunner {
    pub fn new(data: &Mat, d: usize, opts: FitOptions, seed: u64) -> Self {
        let design = design_of(data, d);
        let spec = ModelSpec::new(data.cols, d);
        let full = full_fit(&design, spec, &opts);
        TableRunner { data: data.clone(), design, spec, full, opts, seed }
    }

    pub fn run(&self, method: Method, k: usize, reps: usize) -> MethodStats {
        run_method(&self.data, &self.full, method, k, reps, self.seed, &self.opts)
    }

    /// Run every registered method at one k (registry order; Uniform is
    /// last, so callers can use `.last()` as the baseline row). New
    /// strategies appear in the tables without touching any bench.
    pub fn run_all(&self, k: usize, reps: usize) -> Vec<MethodStats> {
        Method::all()
            .into_iter()
            .map(|m| self.run(m, k, reps))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dgp::Dgp;
    use crate::util::rng::Rng;

    fn quick_opts() -> FitOptions {
        FitOptions { max_iters: 60, ..Default::default() }
    }

    #[test]
    fn full_fit_beats_init() {
        let mut rng = Rng::new(1);
        let data = Dgp::BivariateNormal.generate(400, &mut rng);
        let design = design_of(&data, 5);
        let spec = ModelSpec::new(2, 5);
        let init_nll = mctm::nll(&design, &[], &mctm::Params::init(spec));
        let full = full_fit(&design, spec, &quick_opts());
        assert!(full.fit.nll < init_nll, "{} !< {init_nll}", full.fit.nll);
    }

    #[test]
    fn full_fit_recovers_correlation() {
        // ρ = 0.7 Gaussian: optimal λ_21 ≈ −ρ/√(1−ρ²)·(σ ratio)… the sign
        // must be negative (z₂ = h̃₂ + λ h̃₁ whitens positive dependence)
        let mut rng = Rng::new(2);
        let data = Dgp::BivariateNormal.generate(3000, &mut rng);
        let design = design_of(&data, 6);
        let spec = ModelSpec::new(2, 6);
        let full = full_fit(&design, spec, &FitOptions::default());
        let lam = full.fit.params.lambda(1, 0);
        assert!(lam < -0.4, "λ₂₁ = {lam} should be clearly negative");
    }

    #[test]
    fn coreset_run_produces_metrics() {
        let mut rng = Rng::new(3);
        let data = Dgp::NormalMixture.generate(800, &mut rng);
        let runner = TableRunner::new(&data, 5, quick_opts(), 7);
        let stats = runner.run(Method::L2Hull, 40, 3);
        assert_eq!(stats.lr.len(), 3);
        assert!(stats.lr.iter().all(|&x| x.is_finite() && x > 0.9));
        assert!(stats.theta_l2.iter().all(|&x| x.is_finite() && x >= 0.0));
        // trivial coreset of everything reproduces the full fit ⇒ LR ≈ 1
        let all = runner.run(Method::Uniform, 800, 1);
        assert!(
            (all.lr[0] - 1.0).abs() < 0.02,
            "identity coreset LR {}",
            all.lr[0]
        );
    }

    #[test]
    fn summary_rows_shape() {
        let mut rng = Rng::new(4);
        let data = Dgp::BivariateNormal.generate(500, &mut rng);
        let runner = TableRunner::new(&data, 5, quick_opts(), 9);
        let a = runner.run(Method::L2Hull, 30, 2);
        let b = runner.run(Method::Uniform, 30, 2);
        let row = summarize(&a, &b);
        assert_eq!(row.len(), 6);
        assert_eq!(row[0], "l2-hull");
        let base_row = summarize(&b, &b);
        assert_eq!(base_row[4], "baseline");
    }
}
