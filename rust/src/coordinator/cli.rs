//! Command-line launcher (no `clap` offline — a small hand-rolled
//! parser). Subcommands:
//!
//!   fit        run one coreset experiment (dataset × method × k)
//!   stream     run the streaming Merge & Reduce pipeline
//!   import     convert a CSV/generated dataset to an on-disk column store
//!   save       fit once and persist the model (and optionally the sketch)
//!   load       inspect a persisted artifact
//!   serve      serve persisted/fitted models over HTTP
//!   check      smoke-test the PJRT runtime against every artifact
//!   help       usage
//!
//! Any `key=value` accepted by `ExperimentConfig::set` can be passed as
//! `--set key=value`; `--config FILE` loads a key=value file first.
//!
//! Since PR 4 the launcher is a thin shell over the facade: a config
//! maps onto an `api::Session` (`ExperimentConfig::session`), `fit` and
//! `stream` drive `Session::fit`/`Session::coreset`, and every parse /
//! validation failure is a typed `ApiError`.

use super::config::ExperimentConfig;
use super::experiment::TableRunner;
use crate::api::{ApiError, NamedSource};
use crate::linalg::Mat;
use crate::mctm::ModelSpec;
use crate::util::report::Table;
use crate::util::rng::Rng;
use crate::util::Stopwatch;
use crate::anyhow;
use crate::runtime::Artifact;
use crate::server::{ModelRegistry, Server};
use crate::util::error::Result;
use std::path::{Path, PathBuf};
use std::sync::Arc;

/// Usage text. The method list renders from the strategy registry, so
/// `help` can never drift from the registered strategies.
pub fn usage() -> String {
    let methods = crate::coreset::strategy::method_names().join(" | ");
    let method_lines = crate::coreset::Method::all()
        .into_iter()
        .map(|m| format!("  {:<14} {}", m.name(), m.describe()))
        .collect::<Vec<_>>()
        .join("\n");
    format!(
        "\
mctm-coreset — scalable learning of multivariate distributions via coresets

USAGE:
  mctm-coreset fit    [--config FILE] [--set key=value]... [--threads N]
  mctm-coreset stream [--config FILE] [--set key=value]... [--shards N] [--shard-size N] [--threads N] [--out FILE.mctm] [--sketch FILE.mctm]
  mctm-coreset work   --listen HOST:PORT
  mctm-coreset dist-fit --workers A,B,... [--shards N] [--shard-size N] [--out FILE.mctm] [--sketch FILE.mctm] [--config FILE] [--set key=value]...
  mctm-coreset import --out FILE.store [--chunk-rows N] [--config FILE] [--set key=value]...
  mctm-coreset save   --out FILE.mctm [--sketch FILE.mctm] [--config FILE] [--set key=value]...
  mctm-coreset load   FILE.mctm
  mctm-coreset serve  [--models DIR] [--fit [--name NAME]] [--addr HOST:PORT] [--set key=value]...
  mctm-coreset check  [--artifacts DIR]
  mctm-coreset help

OUT-OF-CORE:
  `import` converts the configured dataset (`dataset=file:/path.csv`
  streams the file line by line; DGP/covertype/stocks names generate
  `n` rows chunk by chunk) into a chunked, checksummed binary column
  store at --out, holding one chunk (--chunk-rows rows, default 2048)
  in memory at a time. Fit it with `dataset=store:/path.store` — the
  fit then streams the store at O(budget + chunk) peak memory.

DISTRIBUTED:
  `work --listen HOST:PORT` starts a sketching worker (`:0` picks a
  free port; the bound address is printed as `worker listening on …`).
  `dist-fit --workers a:p1,b:p2,...` assigns each worker a disjoint
  shard range of the configured dataset, folds the returned leaves in
  fixed sequence order, and fits — byte-identical to a single-process
  `stream` run of the same config at any worker count, even when a
  worker dies mid-run and its range is reassigned (recoveries are
  counted, never silent). The per-worker transport retry budget is the
  `retry_limit` config key (default 3).

PERSIST & SERVE:
  `save` fits per the config and writes a versioned, checksummed model
  artifact (plus, with --sketch, the coreset sketch a later
  `Session::refit` can re-optimize without the data). `serve` loads
  every *.mctm model in --models (named by file stem) and/or fits one
  fresh with --fit (registered as --name, default the dataset name),
  then answers density / cdf / quantile / sample / conditional queries
  over HTTP until killed (default --addr 127.0.0.1:7878, `:0` picks a
  free port; the bound address is printed as `serving on http://…`).

CONFIG KEYS (defaults in parentheses):
  dataset (bivariate-normal) — one of the 14 DGP names, covertype,
                               stocks10, stocks20
  n (10000)  k (30)  d (7)  reps (10)  seed (42)
  method (l2-hull) — {methods}
  backend (native) — native | xla      artifacts (artifacts)
  optimizer (lbfgs) — lbfgs | adam     max_iters (300)
  out_dir (results)
  threads (0 = auto) — worker threads for the parallel kernels
                       (`--threads N` is shorthand; the MCTM_THREADS
                       env var pins the auto default; results are
                       bit-identical for any thread count)

METHODS (registry `coreset::strategy`):
{method_lines}

Tables/figures of the paper are regenerated by `cargo bench` — one bench
target per table/figure (see DESIGN.md §4)."
    )
}

/// Generate the named dataset (or load `file:/path.csv`). Thin wrapper
/// over the facade's dataset registry (`api::load_dataset`).
pub fn load_dataset(name: &str, n: usize, rng: &mut Rng) -> Result<Mat> {
    Ok(crate::api::load_dataset(name, n, rng)?)
}

/// Parsed CLI invocation.
pub struct Cli {
    pub command: String,
    pub config: ExperimentConfig,
    pub shards: usize,
    pub shard_size: usize,
    /// positional arguments after the subcommand (e.g. `load FILE`)
    pub positional: Vec<String>,
    /// `save --out FILE` — model artifact destination
    pub out: Option<PathBuf>,
    /// `save --sketch FILE` — optional sketch artifact destination
    pub sketch: Option<PathBuf>,
    /// `serve --models DIR` — artifact directory to serve
    pub models_dir: Option<PathBuf>,
    /// `serve --addr HOST:PORT` (`:0` picks a free port)
    pub addr: String,
    /// `serve --fit` — fit a model from the config and register it
    pub serve_fit: bool,
    /// `serve --name NAME` — registry name for the `--fit` model
    pub model_name: Option<String>,
    /// `import --chunk-rows N` — rows per store chunk
    pub chunk_rows: usize,
    /// `work --listen HOST:PORT` (`:0` picks a free port)
    pub listen: String,
    /// `dist-fit --workers a,b,c` — worker addresses, comma-separated
    pub workers: Vec<String>,
}

impl Cli {
    pub fn parse(args: &[String]) -> std::result::Result<Cli, ApiError> {
        let command = args.first().cloned().unwrap_or_else(|| "help".into());
        let mut config_file: Option<PathBuf> = None;
        let mut overrides = Vec::new();
        let mut shards = 8usize;
        let mut shard_size = 5_000usize;
        let mut positional = Vec::new();
        let mut out: Option<PathBuf> = None;
        let mut sketch: Option<PathBuf> = None;
        let mut models_dir: Option<PathBuf> = None;
        let mut addr = "127.0.0.1:7878".to_string();
        let mut serve_fit = false;
        let mut model_name: Option<String> = None;
        let mut chunk_rows = crate::data::store::DEFAULT_CHUNK_ROWS;
        let mut listen = "127.0.0.1:7900".to_string();
        let mut workers: Vec<String> = Vec::new();
        let flag_value = |args: &[String], i: usize, flag: &str| {
            args.get(i + 1)
                .cloned()
                .ok_or_else(|| ApiError::Usage(format!("{flag} needs a value")))
        };
        let mut i = 1;
        while i < args.len() {
            match args[i].as_str() {
                "--config" => {
                    config_file = Some(PathBuf::from(flag_value(args, i, "--config")?));
                    i += 2;
                }
                "--set" => {
                    overrides.push(flag_value(args, i, "--set")?);
                    i += 2;
                }
                "--artifacts" => {
                    overrides.push(format!("artifacts={}", flag_value(args, i, "--artifacts")?));
                    i += 2;
                }
                "--shards" => {
                    let v = flag_value(args, i, "--shards")?;
                    shards = v
                        .parse()
                        .map_err(|e| ApiError::config("--shards", format!("`{v}`: {e}")))?;
                    i += 2;
                }
                "--shard-size" => {
                    let v = flag_value(args, i, "--shard-size")?;
                    shard_size = v
                        .parse()
                        .map_err(|e| ApiError::config("--shard-size", format!("`{v}`: {e}")))?;
                    i += 2;
                }
                "--threads" => {
                    overrides.push(format!("threads={}", flag_value(args, i, "--threads")?));
                    i += 2;
                }
                "--out" => {
                    out = Some(PathBuf::from(flag_value(args, i, "--out")?));
                    i += 2;
                }
                "--sketch" => {
                    sketch = Some(PathBuf::from(flag_value(args, i, "--sketch")?));
                    i += 2;
                }
                "--models" => {
                    models_dir = Some(PathBuf::from(flag_value(args, i, "--models")?));
                    i += 2;
                }
                "--addr" => {
                    addr = flag_value(args, i, "--addr")?;
                    i += 2;
                }
                "--chunk-rows" => {
                    let v = flag_value(args, i, "--chunk-rows")?;
                    chunk_rows = v
                        .parse()
                        .map_err(|e| ApiError::config("--chunk-rows", format!("`{v}`: {e}")))?;
                    i += 2;
                }
                "--listen" => {
                    listen = flag_value(args, i, "--listen")?;
                    i += 2;
                }
                "--workers" => {
                    workers = flag_value(args, i, "--workers")?
                        .split(',')
                        .map(|s| s.trim().to_string())
                        .filter(|s| !s.is_empty())
                        .collect();
                    i += 2;
                }
                "--fit" => {
                    serve_fit = true;
                    i += 1;
                }
                "--name" => {
                    model_name = Some(flag_value(args, i, "--name")?);
                    i += 2;
                }
                other if !other.starts_with('-') => {
                    positional.push(other.to_string());
                    i += 1;
                }
                other => {
                    return Err(ApiError::Usage(format!("unknown flag {other}\n\n{}", usage())))
                }
            }
        }
        let config = ExperimentConfig::load(config_file.as_deref(), &overrides)?;
        Ok(Cli {
            command,
            config,
            shards,
            shard_size,
            positional,
            out,
            sketch,
            models_dir,
            addr,
            serve_fit,
            model_name,
            chunk_rows,
            listen,
            workers,
        })
    }

    pub fn run(&self) -> Result<()> {
        if self.config.threads > 0 {
            crate::util::parallel::set_threads(self.config.threads);
        }
        match self.command.as_str() {
            "fit" => cmd_fit(&self.config),
            "stream" => cmd_stream(
                &self.config,
                self.shards,
                self.shard_size,
                self.out.as_deref(),
                self.sketch.as_deref(),
            ),
            "work" => cmd_work(&self.listen),
            "dist-fit" => cmd_dist_fit(
                &self.config,
                &self.workers,
                self.shards,
                self.shard_size,
                self.out.as_deref(),
                self.sketch.as_deref(),
            ),
            "import" => cmd_import(&self.config, self.out.as_deref(), self.chunk_rows),
            "save" => cmd_save(&self.config, self.out.as_deref(), self.sketch.as_deref()),
            "load" => cmd_load(&self.positional),
            "serve" => cmd_serve(
                &self.config,
                self.models_dir.as_deref(),
                &self.addr,
                self.serve_fit,
                self.model_name.as_deref(),
            ),
            "check" => cmd_check(&self.config),
            "help" | "--help" | "-h" => {
                println!("{}", usage());
                Ok(())
            }
            other => Err(anyhow!("unknown command {other}\n\n{}", usage())),
        }
    }
}

fn cmd_fit(cfg: &ExperimentConfig) -> Result<()> {
    let mut rng = Rng::new(cfg.seed);
    let data = load_dataset(&cfg.dataset, cfg.n, &mut rng)?;
    println!(
        "dataset={} n={} J={} d={} k={} method={} backend={} threads={}",
        cfg.dataset, data.rows, data.cols, cfg.d, cfg.k,
        cfg.method.name(), cfg.backend,
        crate::util::parallel::threads()
    );
    if cfg.backend == "xla" {
        return cmd_fit_xla(cfg, &data);
    }
    // the headline run goes through the facade: config → session →
    // fitted model with its diagnostics
    let session = cfg.session()?;
    let model = session.fit(&data)?;
    let diag = model.diagnostics();
    println!(
        "coreset fit  : {} points ({} hull), nll={:.4} iters={} sample={:.2}s fit={:.2}s",
        diag.coreset.size,
        diag.coreset.n_hull,
        diag.fit_nll,
        diag.fit_iters,
        diag.coreset.seconds,
        diag.fit_seconds
    );
    // paper-style comparison table (full fit + reps, method vs uniform)
    let runner = TableRunner::new(&data, cfg.d, cfg.fit.clone(), cfg.seed);
    println!(
        "full fit: nll={:.4} iters={} time={:.2}s",
        runner.full.fit.nll, runner.full.fit.iters, runner.full.seconds
    );
    let stats = runner.run(cfg.method, cfg.k, cfg.reps);
    let baseline = runner.run(crate::coreset::Method::Uniform, cfg.k, cfg.reps);
    let mut table = Table::new(
        &format!("{} (k = {})", cfg.dataset, cfg.k),
        &["method", "theta L2", "lambda err", "LR", "impr(%)", "time(s)"],
    );
    table.row(super::experiment::summarize(&stats, &baseline));
    table.row(super::experiment::summarize(&baseline, &baseline));
    table.emit(Some(&cfg.out_dir.join("fit.csv")));
    Ok(())
}

fn cmd_fit_xla(cfg: &ExperimentConfig, data: &Mat) -> Result<()> {
    use crate::fit::fit_with;
    use crate::runtime::{Engine, XlaNll};
    let engine = Engine::new(&cfg.artifacts)?;
    println!("PJRT platform: {}", engine.platform());
    // only the scaler is needed here — the session builds its own
    // design for the coreset, so a second full basis build would be
    // wasted work
    let scaler = crate::basis::Scaler::fit(data, 0.01);
    let spec = ModelSpec::new(data.cols, cfg.d);
    let scaled = scaler.transform(data);

    // coreset via the facade's sketching half, fit through the XLA
    // objective (same design/scaler as the batch path)
    let session = cfg.session()?;
    let cs = session.coreset(data)?;
    let indices = cs
        .indices
        .as_deref()
        .ok_or_else(|| anyhow!("internal: batch coreset carried no indices"))?;
    let sub_scaled = scaled.select_rows(indices);
    let obj = XlaNll::from_scaled(&engine, spec.j, cfg.d, &sub_scaled, cs.weights.clone())?;
    let sw = Stopwatch::start();
    let fit = fit_with(&obj, spec, &cfg.fit);
    println!(
        "xla coreset fit: nll={:.4} iters={} time={:.2}s (k={}, hull={})",
        fit.nll,
        fit.iters,
        sw.secs(),
        cs.size,
        cs.n_hull
    );
    // evaluate on the full data through the fused pallas artifact
    let full_obj = XlaNll::from_scaled(&engine, spec.j, cfg.d, &scaled, Vec::new())?;
    let nll_full_at_coreset = full_obj.eval(&fit.params.x)?;
    println!("nll(full data | coreset params) = {nll_full_at_coreset:.4}");
    Ok(())
}

fn cmd_stream(
    cfg: &ExperimentConfig,
    shards: usize,
    shard_size: usize,
    out: Option<&Path>,
    sketch: Option<&Path>,
) -> Result<()> {
    let session = cfg.session()?;
    let source = NamedSource::stream(&cfg.dataset, shards * shard_size, shard_size);
    let model = session.fit(source)?;
    let diag = model.diagnostics();
    let stream = diag
        .coreset
        .stream
        .as_ref()
        .ok_or_else(|| anyhow!("internal: shard source did not take the streaming path"))?;
    println!(
        "stream: n={} shards={} reduces={} coreset={} total_weight={:.0} time={:.2}s",
        stream.n_seen,
        stream.n_shards,
        stream.n_reduces,
        diag.coreset.size,
        diag.coreset.total_weight,
        stream.seconds
    );
    println!(
        "fit on streamed coreset: nll={:.4} iters={}",
        diag.fit_nll, diag.fit_iters
    );
    save_fitted(&model, out, sketch)
}

/// Persist a fitted model / its sketch when the flags ask for it —
/// shared by `stream` and `dist-fit` so the smoke script can `cmp`
/// their artifacts byte for byte.
fn save_fitted(
    model: &crate::api::FittedModel,
    out: Option<&Path>,
    sketch: Option<&Path>,
) -> Result<()> {
    let diag = model.diagnostics();
    if let Some(p) = out {
        model.save(p)?;
        println!("saved model  : -> {}", p.display());
    }
    if let Some(p) = sketch {
        diag.coreset.save(p)?;
        println!("saved sketch : -> {}", p.display());
    }
    Ok(())
}

/// `work`: serve shard-range sketching jobs forever (the worker half
/// of the distributed mode — see `dist::worker`). The bound address is
/// announced on stdout for harnesses that listen on port 0.
fn cmd_work(listen: &str) -> Result<()> {
    use std::io::Write as _;
    let worker = crate::dist::Worker::bind(listen)?;
    println!("worker listening on {}", worker.local_addr()?);
    // the announce line must cross a pipe before any coordinator can
    // connect — piped stdout is block-buffered, so flush explicitly
    let _ = std::io::stdout().flush();
    worker.run();
    Ok(())
}

/// `dist-fit`: the coordinator half — sketch the configured dataset on
/// the given workers, fold, fit, and report exactly like `stream`
/// (whose output it must reproduce byte for byte).
fn cmd_dist_fit(
    cfg: &ExperimentConfig,
    workers: &[String],
    shards: usize,
    shard_size: usize,
    out: Option<&Path>,
    sketch: Option<&Path>,
) -> Result<()> {
    if workers.is_empty() {
        return Err(anyhow!("dist-fit needs --workers A,B,... (at least one address)"));
    }
    let session = cfg.session()?;
    let model = session.dist_fit(workers, &cfg.dataset, shards * shard_size, shard_size)?;
    let diag = model.diagnostics();
    let stream = diag
        .coreset
        .stream
        .as_ref()
        .ok_or_else(|| anyhow!("internal: distributed sketch carried no stream stats"))?;
    println!(
        "dist-fit: workers={} n={} shards={} reduces={} coreset={} total_weight={:.0} time={:.2}s",
        workers.len(),
        stream.n_seen,
        stream.n_shards,
        stream.n_reduces,
        diag.coreset.size,
        diag.coreset.total_weight,
        stream.seconds
    );
    println!(
        "fit on distributed coreset: nll={:.4} iters={}",
        diag.fit_nll, diag.fit_iters
    );
    if !diag.coreset.degradations.is_clean() {
        println!("recoveries: {}", diag.coreset.degradations);
    }
    save_fitted(&model, out, sketch)
}

/// `import`: convert the configured dataset to an on-disk column store
/// (`data::store`) in one bounded-memory pass — `dataset=file:` streams
/// the CSV line by line, generator-backed names produce one chunk at a
/// time with a single persistent RNG (matching `NamedSource::stream`'s
/// generator semantics, so `import` + `dataset=store:` replays the same
/// rows a direct stream would see when `--chunk-rows` equals the shard
/// size).
fn cmd_import(cfg: &ExperimentConfig, out: Option<&Path>, chunk_rows: usize) -> Result<()> {
    use crate::data::store::{import_csv, StoreWriter};
    let out = out.ok_or_else(|| anyhow!("import needs --out FILE.store"))?;
    if chunk_rows == 0 {
        return Err(anyhow!("--chunk-rows must be ≥ 1"));
    }
    if cfg.dataset.starts_with("store:") {
        return Err(anyhow!("dataset {} is already a store", cfg.dataset));
    }
    let sw = Stopwatch::start();
    let (rows, cols) = if let Some(path) = cfg.dataset.strip_prefix("file:") {
        import_csv(Path::new(path), out, chunk_rows)?
    } else {
        // validate the name (and learn the width) before touching disk
        let cols = load_dataset(&cfg.dataset, 1, &mut Rng::new(cfg.seed))?.cols;
        let mut rng = Rng::new(cfg.seed);
        let mut w = StoreWriter::create(out, cols, chunk_rows)?;
        let mut remaining = cfg.n;
        while remaining > 0 {
            let take = chunk_rows.min(remaining);
            let m = load_dataset(&cfg.dataset, take, &mut rng)?;
            w.push_mat(&m)?;
            remaining -= take;
        }
        (w.finish()?, cols)
    };
    println!(
        "imported {} -> {}: {} rows x {} cols (chunk_rows={}) in {:.2}s",
        cfg.dataset,
        out.display(),
        rows,
        cols,
        chunk_rows,
        sw.secs()
    );
    Ok(())
}

/// `save`: fit once per the config, persist the model artifact (and,
/// with `--sketch`, the coreset sketch for later `Session::refit`s).
fn cmd_save(cfg: &ExperimentConfig, out: Option<&Path>, sketch: Option<&Path>) -> Result<()> {
    let out = out.ok_or_else(|| anyhow!("save needs --out FILE.mctm"))?;
    let mut rng = Rng::new(cfg.seed);
    let data = load_dataset(&cfg.dataset, cfg.n, &mut rng)?;
    let session = cfg.session()?;
    let model = session.fit(&data)?;
    model.save(out)?;
    let diag = model.diagnostics();
    println!(
        "saved model  : J={} d={} method={} coreset={} nll={:.4} -> {}",
        model.spec().j,
        model.spec().d,
        diag.coreset.method,
        diag.coreset.size,
        diag.fit_nll,
        out.display()
    );
    if let Some(sp) = sketch {
        diag.coreset.save(sp)?;
        println!(
            "saved sketch : {} rows x {} cols (scaler: {}) -> {}",
            diag.coreset.rows.rows,
            diag.coreset.rows.cols,
            if diag.coreset.scaler.is_some() { "full-data" } else { "from-rows" },
            sp.display()
        );
    }
    Ok(())
}

/// `load FILE`: parse a persisted artifact and print its summary —
/// the quick integrity/inspection tool for either artifact kind.
fn cmd_load(positional: &[String]) -> Result<()> {
    let path = positional
        .first()
        .ok_or_else(|| anyhow!("load needs a FILE argument"))?;
    let path = Path::new(path);
    match Artifact::load(path)? {
        Artifact::Model(a) => {
            println!(
                "model artifact: J={} d={} params={} method={} k={} coreset={} \
                 ({} hull) n_seen={} nll={:.4} iters={} converged={}",
                a.j,
                a.d,
                a.x.len(),
                a.method,
                a.requested,
                a.size,
                a.n_hull,
                a.n_seen,
                a.fit_nll,
                a.fit_iters,
                a.converged
            );
        }
        Artifact::Sketch(a) => {
            println!(
                "sketch artifact: {} rows x {} cols method={} k={} ({} hull) \
                 n_seen={} total_weight={:.0} scaler={}",
                a.rows.rows,
                a.rows.cols,
                a.method,
                a.requested,
                a.n_hull,
                a.n_seen,
                a.weights.iter().sum::<f64>(),
                if a.scaler.is_some() { "full-data" } else { "from-rows" }
            );
        }
    }
    Ok(())
}

/// `serve`: load persisted models and/or fit one fresh, then answer
/// HTTP queries until killed.
fn cmd_serve(
    cfg: &ExperimentConfig,
    models_dir: Option<&Path>,
    addr: &str,
    do_fit: bool,
    name: Option<&str>,
) -> Result<()> {
    let registry = Arc::new(ModelRegistry::new());
    if let Some(dir) = models_dir {
        let n = registry.load_dir(dir)?;
        println!("loaded {n} model(s) from {}", dir.display());
    }
    if do_fit {
        let mut rng = Rng::new(cfg.seed);
        let data = load_dataset(&cfg.dataset, cfg.n, &mut rng)?;
        let session = cfg.session()?;
        let model = session.fit(&data)?;
        let name = name.unwrap_or(&cfg.dataset);
        println!(
            "fitted `{name}`: J={} d={} method={} coreset={} nll={:.4}",
            model.spec().j,
            model.spec().d,
            model.diagnostics().coreset.method,
            model.diagnostics().coreset.size,
            model.diagnostics().fit_nll
        );
        registry.insert(name, model);
    }
    if registry.is_empty() {
        return Err(anyhow!("nothing to serve: pass --models DIR and/or --fit"));
    }
    let server = Server::bind(addr, registry)?;
    // smoke scripts parse this exact line to find the bound port
    println!("serving on http://{}", server.local_addr());
    server.run();
    Ok(())
}

fn cmd_check(cfg: &ExperimentConfig) -> Result<()> {
    use crate::runtime::Engine;
    let engine = Engine::new(&cfg.artifacts)?;
    println!(
        "platform={} artifacts={} entries={}",
        engine.platform(),
        cfg.artifacts.display(),
        engine.manifest.entries.len()
    );
    let mut ok = 0;
    for entry in engine.manifest.entries.clone() {
        let sw = Stopwatch::start();
        engine.executable(&entry)?;
        println!("  compiled {:<28} in {:.2}s", entry.name, sw.secs());
        ok += 1;
    }
    // numeric smoke: run nll_grad for the smallest config against the
    // native backend
    if let Some(e) = engine.manifest.entries.iter().find(|e| e.kind == "nll_grad") {
        let (j, d) = (e.j, e.d);
        let runner = crate::runtime::TiledNll::new(&engine, j, d)?;
        let mut rng = Rng::new(7);
        let n = 100;
        let data = Mat::from_vec(n, j, (0..n * j).map(|_| rng.normal()).collect());
        let design = super::experiment::design_of(&data, d);
        let scaled = design.scaler.transform(&data);
        let spec = ModelSpec::new(j, d);
        let p = crate::mctm::Params::init(spec);
        let (xv, xg) = runner.nll_grad(&p.x, &scaled.data, &[])?;
        let (nv, ng) = crate::mctm::nll_grad(&design, &[], &p);
        let gerr = xg
            .iter()
            .zip(&ng)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f64, f64::max);
        println!(
            "  numeric check nll_grad J={j} d={d}: |Δnll|={:.2e} max|Δgrad|={gerr:.2e}",
            (xv - nv).abs()
        );
        if (xv - nv).abs() > 1e-6 * (1.0 + nv.abs()) || gerr > 1e-6 {
            return Err(anyhow!("xla/native mismatch"));
        }
    }
    println!("check OK ({ok} artifacts)");
    Ok(())
}
