//! Launcher for the mctm-coreset coordinator. See `mctm-coreset help`.

use mctm_coreset::coordinator::cli::Cli;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = match Cli::parse(&args) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("{e:#}");
            std::process::exit(2);
        }
    };
    if let Err(e) = cli.run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
