//! # mctm-coreset
//!
//! Scalable learning of multivariate distributions via coresets — a
//! three-layer Rust + JAX + Pallas reproduction. See DESIGN.md.

pub mod basis;
pub mod benchsupport;
pub mod coordinator;
pub mod coreset;
pub mod data;
pub mod fit;
pub mod linalg;
pub mod mctm;
pub mod runtime;
pub mod util;
