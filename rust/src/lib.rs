//! # mctm-coreset
//!
//! Scalable learning of multivariate distributions via coresets — a
//! three-layer Rust + JAX + Pallas reproduction. See DESIGN.md.

// User-reachable library code must not panic on fallible paths: every
// unwrap/expect outside tests either converts to a typed error or
// carries an #[allow] with a proof of unreachability. `make ci` runs
// clippy with -D warnings, so a bare unwrap fails the build.
#![cfg_attr(not(test), warn(clippy::unwrap_used, clippy::expect_used))]

pub mod api;
pub mod basis;
pub mod benchsupport;
pub mod coordinator;
pub mod coreset;
pub mod data;
pub mod dist;
pub mod fit;
pub mod linalg;
pub mod mctm;
pub mod runtime;
pub mod server;
pub mod util;

/// The one-stop import for the public facade: builder → session →
/// fitted model, plus the data sources, method tags and metrics the
/// top layer (CLI, benches, integration tests, examples) needs.
///
/// ```no_run
/// use mctm_coreset::prelude::*;
///
/// let session = SessionBuilder::new()
///     .method("l2-hull")
///     .budget(100)
///     .seed(42)
///     .build()?;
/// let model = session.fit(DgpSource::batch(Dgp::BivariateNormal, 10_000))?;
/// let median = model.marginal_quantile(0, 0.5);
/// # let _ = median;
/// # Ok::<(), mctm_coreset::prelude::ApiError>(())
/// ```
pub mod prelude {
    pub use crate::api::{
        load_dataset, ApiError, CoresetReport, DataSource, DgpSource, Diagnostics,
        FittedModel, NamedSource, Session, SessionBuilder, SourceInput, StoreSource,
    };
    pub use crate::coordinator::cli::Cli;
    pub use crate::coordinator::config::ExperimentConfig;
    pub use crate::coordinator::pipeline::{StreamError, StreamStats, SHARD_RETRY_LIMIT};
    pub use crate::coreset::{Coreset, Method};
    pub use crate::data::dgp::Dgp;
    pub use crate::data::faulty::{FaultPlan, FaultySource};
    pub use crate::data::sparse::SparseMat;
    pub use crate::data::store::{StoreReader, StoreWriter};
    pub use crate::data::{GenShards, InvalidPolicy, MatShards, ShardError, ShardSource};
    pub use crate::dist::{
        run_distributed, DistConfig, TransportError, TransportFaultPlan, Worker, WorkerHandle,
    };
    pub use crate::fit::{FitOptions, FitResult, OptimizerKind};
    pub use crate::linalg::simd::{simd_available, KernelBackend};
    pub use crate::linalg::Mat;
    pub use crate::mctm::{lambda_error, loglik_ratio, theta_l2, ModelSpec, Params};
    pub use crate::runtime::artifact::{
        Artifact, ModelArtifact, ScalerState, SketchArtifact, ARTIFACT_MAGIC, ARTIFACT_VERSION,
    };
    pub use crate::server::{Metrics, MetricsSnapshot, ModelRegistry, Server, ServerHandle};
    pub use crate::util::degrade::{DegradeSink, Degradations};
    pub use crate::util::rng::Rng;
    pub use crate::util::{fmt_ms, mean, median, std_dev, Stopwatch};
}
