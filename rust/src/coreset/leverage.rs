//! ℓ₂ leverage scores for the MCTM coreset (paper Lemma 2.1).
//!
//! The paper samples rows of the block matrix B ∈ R^{nJ×dJ²}. Each of
//! B's column blocks is touched by exactly one row per observation, with
//! content b_i = (a_1(y_i1), …, a_J(y_iJ)); hence the leverage score of
//! B's row (i, j) equals the leverage score of row i of the stacked
//! matrix Ab ∈ R^{n×dJ} (proof in DESIGN.md §2). That reduction makes
//! the computation O(n·(dJ)² + (dJ)³) via Gram + Cholesky instead of
//! operating on the dJ²-wide block matrix.
//!
//! These kernels feed the `l2` / `ridge` / `root` score families of the
//! strategy registry (`coreset::strategy`); samplers never call them
//! directly.

use crate::basis::Design;
use crate::data::sparse::SparseMat;
use crate::linalg::{cholesky_ridge_ladder, Cholesky, LinalgError, Mat};
use crate::util::degrade::DegradeSink;
use crate::util::parallel::{Pool, ROW_CHUNK};

/// Relative ridge added to the Gram matrix before factorization. Keeps
/// rank-deficient designs (piecewise/circular DGPs can produce nearly
/// collinear basis columns) factorizable; perturbation is ~1e-10·mean
/// eigenvalue, far below sampling noise.
const GRAM_RIDGE_REL: f64 = 1e-10;

/// Leverage scores u_i of the rows of `x` via Gram + Cholesky.
pub fn leverage_scores(x: &Mat) -> Result<Vec<f64>, LinalgError> {
    leverage_scores_ridged(x, 0.0)
}

/// Ridge leverage scores u_i(γ) = x_iᵀ (XᵀX + γI)⁻¹ x_i.
/// `gamma` is the absolute ridge; the tiny stabilizer is always added.
pub fn leverage_scores_ridged(x: &Mat, gamma: f64) -> Result<Vec<f64>, LinalgError> {
    leverage_scores_ridged_with(x, gamma, &Pool::current())
}

/// Factor a (stabilized) Gram matrix, recovering from `NotPosDef`
/// through the escalating ridge-jitter ladder
/// (`linalg::cholesky_ridge_ladder`). A first-attempt success factors
/// the matrix exactly as given — bit-identical to a plain
/// `Cholesky::new` — so clean runs are unaffected; a recovery is
/// recorded into `sink` (rung included) so degraded scores are
/// observable in `Diagnostics`/`CoresetReport`.
fn factor_gram(g: &Mat, sink: &DegradeSink) -> Result<Cholesky, LinalgError> {
    let (ch, rung) = cholesky_ridge_ladder(g)?;
    if rung > 0 {
        sink.gram_ridge_recovery(rung);
    }
    Ok(ch)
}

/// [`leverage_scores_ridged`] on an explicit pool. The Gram pass is
/// row-sharded with a deterministic tree reduction and the scoring pass
/// writes disjoint row chunks, so scores are bit-identical for any
/// thread count; each worker reuses the one shared L⁻¹.
pub fn leverage_scores_ridged_with(
    x: &Mat,
    gamma: f64,
    pool: &Pool,
) -> Result<Vec<f64>, LinalgError> {
    leverage_scores_ridged_sink(x, gamma, pool, &DegradeSink::new())
}

/// [`leverage_scores_ridged_with`] with degradation accounting: a Gram
/// matrix that fails to factor retries through the ridge ladder and
/// records the recovery into `sink` instead of erroring outright.
pub fn leverage_scores_ridged_sink(
    x: &Mat,
    gamma: f64,
    pool: &Pool,
    sink: &DegradeSink,
) -> Result<Vec<f64>, LinalgError> {
    let mut g = x.gram_with(pool);
    let d = g.rows;
    let stab = GRAM_RIDGE_REL * g.trace().max(1e-300) / d as f64;
    for i in 0..d {
        *g.at_mut(i, i) += gamma + stab;
    }
    let ch = factor_gram(&g, sink)?;
    // score via an explicit L⁻¹ triangular matvec instead of a
    // forward-solve per row: same FLOPs, but no divisions in the inner
    // loop and contiguous row access — 2.1× on the J=10 pipeline (see
    // EXPERIMENTS.md §Perf L3-a). Each score depends only on its own
    // row, so the row shards write disjoint output chunks.
    let linv = ch.l_inverse();
    let mut scores = vec![0.0; x.rows];
    let items: Vec<&mut [f64]> = scores.chunks_mut(ROW_CHUNK).collect();
    pool.for_items(items, |ci, chunk| {
        let lo = ci * ROW_CHUNK;
        for (off, out) in chunk.iter_mut().enumerate() {
            *out = linv_quad_form(&linv, x.row(lo + off));
        }
    });
    Ok(scores)
}

/// ‖L⁻¹ b‖² through the materialized triangular L⁻¹ — the per-row
/// scoring formula shared by the materialized-stacked path above and
/// the plane-direct path below, so their floating-point order is
/// identical by construction (the bitwise pin between the two paths
/// depends on it).
#[inline]
fn linv_quad_form(linv: &Mat, xi: &[f64]) -> f64 {
    let mut acc = 0.0;
    for r in 0..linv.rows {
        let lrow = &linv.row(r)[..=r];
        let mut z = 0.0;
        for (c, &l) in lrow.iter().enumerate() {
            z += l * xi[c];
        }
        acc += z * z;
    }
    acc
}

/// Leverage scores of a CSR matrix (one-hot-heavy designs like the
/// Covertype encoding — see `data::sparse`). Bit-identical to
/// `leverage_scores(&x.to_dense())` without ever materializing the
/// dense matrix.
pub fn sparse_leverage_scores(x: &SparseMat) -> Result<Vec<f64>, LinalgError> {
    sparse_leverage_scores_ridged_with(x, 0.0, &Pool::current())
}

/// Ridge variant of [`sparse_leverage_scores`] on an explicit pool.
pub fn sparse_leverage_scores_ridged_with(
    x: &SparseMat,
    gamma: f64,
    pool: &Pool,
) -> Result<Vec<f64>, LinalgError> {
    sparse_leverage_scores_ridged_sink(x, gamma, pool, &DegradeSink::new())
}

/// [`sparse_leverage_scores_ridged_with`] with degradation accounting —
/// the sparse twin of [`leverage_scores_ridged_sink`]. Both passes
/// gather each CSR row into a dense scratch row (bitwise the row the
/// dense matrix holds: kept values keep their bits, dropped `+0.0`
/// cells are refilled as `+0.0`) and feed the SAME kernels in the SAME
/// order — `syrk_upper_rows4`/`syrk_upper_row1` on the identical chunk
/// grid with the identical tree reduction for the Gram,
/// `linv_quad_form` per row for the scores — so the result is
/// **bit-identical** to densifying first. The win is cost, not values:
/// the gather touches O(nnz) cells per pass and the SYRK row kernels
/// skip zero multipliers, so one-hot blocks cost what they contain.
pub fn sparse_leverage_scores_ridged_sink(
    x: &SparseMat,
    gamma: f64,
    pool: &Pool,
    sink: &DegradeSink,
) -> Result<Vec<f64>, LinalgError> {
    let mut g = sparse_gram_with(x, pool);
    let d = g.rows;
    let stab = GRAM_RIDGE_REL * g.trace().max(1e-300) / d as f64;
    for i in 0..d {
        *g.at_mut(i, i) += gamma + stab;
    }
    let ch = factor_gram(&g, sink)?;
    let linv = ch.l_inverse();
    let mut scores = vec![0.0; x.rows];
    let items: Vec<&mut [f64]> = scores.chunks_mut(ROW_CHUNK).collect();
    pool.for_items(items, |ci, chunk| {
        let lo = ci * ROW_CHUNK;
        let mut xi = vec![0.0; x.cols];
        for (off, out) in chunk.iter_mut().enumerate() {
            x.gather_row_into(lo + off, &mut xi);
            *out = linv_quad_form(&linv, &xi);
        }
    });
    Ok(scores)
}

/// Gram XᵀX of a CSR matrix: per `ROW_CHUNK` shard, four rows at a
/// time are gathered into a dense scratch panel and fed through the
/// same SYRK block updates as [`Mat::gram_with`] — identical chunk
/// grid, 4-row blocking, accumulation order and tree reduction, so the
/// result is bit-identical to `x.to_dense().gram_with(pool)` while the
/// per-row work scales with the stored non-zeros.
fn sparse_gram_with(x: &SparseMat, pool: &Pool) -> Mat {
    use crate::linalg::{syrk_upper_row1, syrk_upper_rows4};
    use crate::util::parallel::{add_assign, tree_reduce};
    let d = x.cols;
    let partials = pool.map_chunks(x.rows, ROW_CHUNK, |_, range| {
        let mut g = vec![0.0; d * d];
        let (lo, hi) = (range.start, range.end);
        let mut rows = vec![0.0; 4 * d];
        let mut r = lo;
        while r + 4 <= hi {
            for t in 0..4 {
                x.gather_row_into(r + t, &mut rows[t * d..(t + 1) * d]);
            }
            let (r0, rest) = rows.split_at(d);
            let (r1, rest) = rest.split_at(d);
            let (r2, r3) = rest.split_at(d);
            syrk_upper_rows4(r0, r1, r2, r3, &mut g);
            r += 4;
        }
        while r < hi {
            x.gather_row_into(r, &mut rows[..d]);
            syrk_upper_row1(&rows[..d], &mut g);
            r += 1;
        }
        g
    });
    let upper = tree_reduce(partials, |mut a, b| {
        add_assign(&mut a, &b);
        a
    })
    .unwrap_or_else(|| vec![0.0; d * d]);
    let mut g = Mat::from_vec(d, d, upper);
    for i in 0..d {
        for j in (i + 1)..d {
            g.data[j * d + i] = g.data[i * d + j];
        }
    }
    g
}

/// Leverage scores of the rows of `x` under **prior row weights** `w`:
/// u_i(w) = w_i · x_iᵀ (XᵀWX)⁻¹ x_i — the row sensitivities of the
/// weighted least-squares problem, which is what a Merge & Reduce
/// reduce step actually resamples (each kept row stands for w_i raw
/// rows). Implemented by scaling row i by √w_i and reusing the
/// unweighted kernel: the scaled row's leverage is exactly w_i·ũ_i,
/// and with w ≡ 1 the scaling multiplies by 1.0, so the result is
/// **bit-identical** to [`leverage_scores_ridged`] at γ = 0 — the
/// property the strategy layer's unweighted call sites rely on.
///
/// This materializing variant serves generic `Mat` inputs; the MCTM
/// hot path (the strategy layer's ℓ₂ reduces) uses the plane-direct
/// [`weighted_mctm_leverage_scores_with`] instead, which is pinned
/// bit-identical to this one on the stacked design.
pub fn weighted_leverage_scores_with(
    x: &Mat,
    w: &[f64],
    pool: &Pool,
) -> Result<Vec<f64>, LinalgError> {
    assert_eq!(x.rows, w.len(), "weights length");
    let mut scaled = x.clone();
    for i in 0..scaled.rows {
        let s = w[i].max(0.0).sqrt();
        for v in scaled.row_mut(i) {
            *v *= s;
        }
    }
    leverage_scores_ridged_with(&scaled, 0.0, pool)
}

/// The standard heuristic ridge for "ridge leverage scores" baselines:
/// γ = tr(XᵀX)/d · ρ with ρ = 0.01.
pub fn default_ridge(x: &Mat) -> f64 {
    default_ridge_with(x, &Pool::current())
}

/// [`default_ridge`] on an explicit pool (the Gram pass dominates).
pub fn default_ridge_with(x: &Mat, pool: &Pool) -> f64 {
    let g = x.gram_with(pool);
    0.01 * g.trace() / g.rows as f64
}

/// Leverage scores of the MCTM design (scores of B's rows, one value per
/// observation — identical across the J block-rows of one observation).
pub fn mctm_leverage_scores(design: &Design) -> Result<Vec<f64>, LinalgError> {
    mctm_leverage_scores_with(design, &Pool::current())
}

/// [`mctm_leverage_scores`] on an explicit pool (used by callers that
/// already provide their own parallelism, e.g. the streaming consumers
/// pass `Pool::new(1)` to avoid nested fan-out).
///
/// Runs **directly on the plane-major design**: both the Gram pass and
/// the scoring pass gather each stacked row b_i from the J basis
/// planes into a small per-worker buffer instead of materializing the
/// (n × dJ) stacked matrix. The weighted twin
/// [`weighted_mctm_leverage_scores_with`] does the same for the
/// streaming Merge & Reduce reduces, where that copy used to be the
/// largest transient allocation. Every floating-point operation and
/// its order match
/// `leverage_scores_ridged_with(&design.stacked(), 0.0, …)`, so scores
/// are bit-identical to the materialized path (pinned by the
/// `plane_direct_matches_stacked_bitwise` test below) and therefore to
/// every coreset drawn before the refactor.
pub fn mctm_leverage_scores_with(
    design: &Design,
    pool: &Pool,
) -> Result<Vec<f64>, LinalgError> {
    plane_leverage_scores(design, None, pool, &DegradeSink::new())
}

/// [`mctm_leverage_scores_with`] with degradation accounting (ridge
/// ladder recoveries recorded into `sink` — see [`factor_gram`]).
pub fn mctm_leverage_scores_sink(
    design: &Design,
    pool: &Pool,
    sink: &DegradeSink,
) -> Result<Vec<f64>, LinalgError> {
    plane_leverage_scores(design, None, pool, sink)
}

/// Weighted MCTM leverage scores u_i(w) = w_i · b_iᵀ(Σ w b bᵀ)⁻¹ b_i,
/// plane-direct: stacked rows are gathered from the planes and scaled
/// by √w_i on the fly — this is what every streaming Merge & Reduce
/// reduce runs (`ScoreStrategy::weighted_scores` for the ℓ₂ family),
/// so the per-reduce n × dJ stacked materialization (plus its scaled
/// clone) is gone from the streaming hot path too. Bit-identical to
/// `weighted_leverage_scores_with(&design.stacked(), w, …)` — the √w
/// multiply hits the same values either way — and with w ≡ 1 the
/// scaling multiplies by 1.0 (bit-exact), reproducing
/// [`mctm_leverage_scores_with`] to the bit, which is the contract the
/// strategy layer's determinism pins rely on.
pub fn weighted_mctm_leverage_scores_with(
    design: &Design,
    w: &[f64],
    pool: &Pool,
) -> Result<Vec<f64>, LinalgError> {
    weighted_mctm_leverage_scores_sink(design, w, pool, &DegradeSink::new())
}

/// [`weighted_mctm_leverage_scores_with`] with degradation accounting
/// (ridge ladder recoveries recorded into `sink`).
pub fn weighted_mctm_leverage_scores_sink(
    design: &Design,
    w: &[f64],
    pool: &Pool,
    sink: &DegradeSink,
) -> Result<Vec<f64>, LinalgError> {
    assert_eq!(design.n, w.len(), "weights length");
    let sqrt_w: Vec<f64> = w.iter().map(|wi| wi.max(0.0).sqrt()).collect();
    plane_leverage_scores(design, Some(&sqrt_w), pool, sink)
}

/// Gather stacked row i from the planes, scaled by `sqrt_w[i]` when
/// weights are present — the one row view both plane-direct passes
/// (Gram and scoring) read, so they cannot disagree on the scaling.
#[inline]
fn gather_stacked_row(design: &Design, i: usize, sqrt_w: Option<&[f64]>, out: &mut [f64]) {
    design.stacked_row_into(i, out);
    if let Some(s) = sqrt_w {
        let si = s[i];
        for v in out.iter_mut() {
            *v *= si;
        }
    }
}

/// The shared plane-direct kernel behind [`mctm_leverage_scores_with`]
/// (no weights) and [`weighted_mctm_leverage_scores_with`] (√w-scaled
/// gather).
fn plane_leverage_scores(
    design: &Design,
    sqrt_w: Option<&[f64]>,
    pool: &Pool,
    sink: &DegradeSink,
) -> Result<Vec<f64>, LinalgError> {
    let dj = design.j * design.d;
    if design.n == 0 || dj == 0 {
        return Ok(vec![0.0; design.n]);
    }
    let mut g = stacked_gram_with(design, sqrt_w, pool);
    let stab = GRAM_RIDGE_REL * g.trace().max(1e-300) / dj as f64;
    for i in 0..dj {
        *g.at_mut(i, i) += stab;
    }
    let ch = factor_gram(&g, sink)?;
    let linv = ch.l_inverse();
    let mut scores = vec![0.0; design.n];
    let items: Vec<&mut [f64]> = scores.chunks_mut(ROW_CHUNK).collect();
    pool.for_items(items, |ci, chunk| {
        let lo = ci * ROW_CHUNK;
        let mut xi = vec![0.0; dj];
        for (off, out) in chunk.iter_mut().enumerate() {
            gather_stacked_row(design, lo + off, sqrt_w, &mut xi);
            *out = linv_quad_form(&linv, &xi);
        }
    });
    Ok(scores)
}

/// Minimum stacked width dJ at which [`stacked_gram_with`] switches to
/// the L2-tiled SYRK path. Below this the dJ×dJ accumulator already
/// fits comfortably in L2 and tiling is pure overhead.
const GRAM_TILE_GATE: usize = 80;
/// Rows gathered per panel in the tiled path. Must be a multiple of 4
/// so panel boundaries align with the 4-row SYRK blocks — that
/// alignment is what keeps the tiled accumulation order bit-identical
/// to the untiled sweep.
const GRAM_PANEL_ROWS: usize = 128;
/// Column tile width for the tiled path: a GRAM_TILE×GRAM_TILE f64
/// tile of G is 32 KiB, so tile + row panel stay L2-resident.
const GRAM_TILE: usize = 64;

/// Gram of the stacked design BᵀB ∈ R^{dJ×dJ} computed straight from
/// the basis planes: per `ROW_CHUNK` shard, four stacked rows at a
/// time are gathered into a scratch panel and fed through the SAME
/// syrk block updates as [`Mat::gram_with`]
/// (`linalg::syrk_upper_rows4`/`syrk_upper_row1` — one definition, not
/// a copy) — identical chunk grid, 4-row blocking, per-entry
/// accumulation order and tree reduction, so the result is
/// bit-identical to `design.stacked().gram_with(pool)` without the
/// n × dJ copy. With `sqrt_w` it computes the weighted Gram
/// Σ w·b bᵀ by scaling each gathered row — bit-identical to scaling a
/// materialized stacked matrix first.
///
/// At dJ ≥ [`GRAM_TILE_GATE`] the per-chunk sweep is additionally
/// L2-tiled: [`GRAM_PANEL_ROWS`] stacked rows are gathered into a
/// panel once, then the upper triangle of G is updated one
/// [`GRAM_TILE`]-wide (i, j) tile at a time via the `_range` SYRK
/// kernels, replaying the panel per tile so the G working set stays
/// cache-resident. Because the panel height is a multiple of 4, each G
/// entry still sees the same ascending 4-row blocks with the same
/// 4-term update expression, so the tiled path is bit-identical to the
/// untiled one (on either kernel backend) — the gate is perf-only.
fn stacked_gram_with(
    design: &Design,
    sqrt_w: Option<&[f64]>,
    pool: &Pool,
) -> crate::linalg::Mat {
    use crate::linalg::{
        syrk_upper_row1, syrk_upper_row1_range, syrk_upper_rows4, syrk_upper_rows4_range,
    };
    use crate::util::parallel::{add_assign, tree_reduce};
    let dj = design.j * design.d;
    let tiled = dj >= GRAM_TILE_GATE;
    let partials = pool.map_chunks(design.n, ROW_CHUNK, |_, range| {
        let mut g = vec![0.0; dj * dj];
        let (lo, hi) = (range.start, range.end);
        if tiled {
            let mut panel = vec![0.0; GRAM_PANEL_ROWS * dj];
            let ntiles = dj.div_ceil(GRAM_TILE);
            let mut plo = lo;
            while plo < hi {
                let phi = (plo + GRAM_PANEL_ROWS).min(hi);
                let prows = phi - plo;
                for t in 0..prows {
                    gather_stacked_row(
                        design,
                        plo + t,
                        sqrt_w,
                        &mut panel[t * dj..(t + 1) * dj],
                    );
                }
                for it in 0..ntiles {
                    let ir = it * GRAM_TILE..((it + 1) * GRAM_TILE).min(dj);
                    for jt in it..ntiles {
                        let jr = jt * GRAM_TILE..((jt + 1) * GRAM_TILE).min(dj);
                        let mut t = 0;
                        while t + 4 <= prows {
                            let blk = &panel[t * dj..(t + 4) * dj];
                            let (r0, rest) = blk.split_at(dj);
                            let (r1, rest) = rest.split_at(dj);
                            let (r2, r3) = rest.split_at(dj);
                            syrk_upper_rows4_range(
                                r0,
                                r1,
                                r2,
                                r3,
                                ir.clone(),
                                jr.clone(),
                                &mut g,
                            );
                            t += 4;
                        }
                        while t < prows {
                            syrk_upper_row1_range(
                                &panel[t * dj..(t + 1) * dj],
                                ir.clone(),
                                jr.clone(),
                                &mut g,
                            );
                            t += 1;
                        }
                    }
                }
                plo = phi;
            }
        } else {
            let mut rows = vec![0.0; 4 * dj];
            let mut r = lo;
            while r + 4 <= hi {
                for t in 0..4 {
                    gather_stacked_row(design, r + t, sqrt_w, &mut rows[t * dj..(t + 1) * dj]);
                }
                let (r0, rest) = rows.split_at(dj);
                let (r1, rest) = rest.split_at(dj);
                let (r2, r3) = rest.split_at(dj);
                syrk_upper_rows4(r0, r1, r2, r3, &mut g);
                r += 4;
            }
            while r < hi {
                gather_stacked_row(design, r, sqrt_w, &mut rows[..dj]);
                syrk_upper_row1(&rows[..dj], &mut g);
                r += 1;
            }
        }
        g
    });
    let upper = tree_reduce(partials, |mut a, b| {
        add_assign(&mut a, &b);
        a
    })
    .unwrap_or_else(|| vec![0.0; dj * dj]);
    let mut g = crate::linalg::Mat::from_vec(dj, dj, upper);
    for i in 0..dj {
        for q in (i + 1)..dj {
            g.data[q * dj + i] = g.data[i * dj + q];
        }
    }
    g
}

/// Sensitivity upper bounds s_i = u_i + 1/n (Algorithm 1 "sensitivity
/// proxy"): the uniform term covers the positive-log part's uniform
/// component (Lemma 2.2/2.3).
pub fn sensitivity_scores(design: &Design) -> Result<Vec<f64>, LinalgError> {
    sensitivity_scores_with(design, &Pool::current())
}

/// [`sensitivity_scores`] on an explicit pool.
pub fn sensitivity_scores_with(
    design: &Design,
    pool: &Pool,
) -> Result<Vec<f64>, LinalgError> {
    sensitivity_scores_sink(design, pool, &DegradeSink::new())
}

/// [`sensitivity_scores_with`] with degradation accounting.
pub fn sensitivity_scores_sink(
    design: &Design,
    pool: &Pool,
    sink: &DegradeSink,
) -> Result<Vec<f64>, LinalgError> {
    let u = mctm_leverage_scores_sink(design, pool, sink)?;
    let n = design.n as f64;
    Ok(u.into_iter().map(|ui| ui + 1.0 / n).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_design(n: usize, j: usize, d: usize, seed: u64) -> Design {
        let mut rng = Rng::new(seed);
        let data = Mat::from_vec(n, j, (0..n * j).map(|_| rng.normal()).collect());
        Design::build(&data, d, 0.01)
    }

    #[test]
    fn leverage_sums_to_rank() {
        let mut rng = Rng::new(21);
        let x = Mat::from_vec(200, 6, (0..1200).map(|_| rng.normal()).collect());
        let u = leverage_scores(&x).unwrap();
        let total: f64 = u.iter().sum();
        assert!((total - 6.0).abs() < 1e-6, "sum {total}");
        assert!(u.iter().all(|&ui| (0.0..=1.0 + 1e-9).contains(&ui)));
    }

    #[test]
    fn mctm_scores_sum_near_dj() {
        // Bernstein columns per block sum to 1 (partition of unity), so
        // the stacked matrix has rank dJ − (J − 1) (one shared constant
        // direction); the sum of leverage equals the rank.
        let design = random_design(300, 2, 5, 22);
        let u = mctm_leverage_scores(&design).unwrap();
        let total: f64 = u.iter().sum();
        let expected = (2 * 5 - (2 - 1)) as f64;
        assert!(
            (total - expected).abs() < 0.5,
            "sum {total} expected ≈ {expected}"
        );
    }

    #[test]
    fn outlier_gets_high_leverage() {
        let mut rng = Rng::new(23);
        let mut data: Vec<f64> = (0..400).map(|_| rng.normal()).collect();
        // one far outlier in both coordinates
        data[0] = 40.0;
        data[1] = -40.0;
        let m = Mat::from_vec(200, 2, data);
        let design = Design::build(&m, 6, 0.01);
        let u = mctm_leverage_scores(&design).unwrap();
        let max = u.iter().cloned().fold(f64::MIN, f64::max);
        assert_eq!(
            u.iter().position(|&x| x == max).unwrap(),
            0,
            "outlier should have max leverage"
        );
        let mean = u.iter().sum::<f64>() / u.len() as f64;
        assert!(max > 5.0 * mean);
    }

    #[test]
    fn ridge_shrinks_scores() {
        let mut rng = Rng::new(24);
        let x = Mat::from_vec(100, 4, (0..400).map(|_| rng.normal()).collect());
        let plain = leverage_scores(&x).unwrap();
        let ridged = leverage_scores_ridged(&x, default_ridge(&x)).unwrap();
        for (p, r) in plain.iter().zip(&ridged) {
            assert!(r <= p, "ridge must shrink: {r} > {p}");
        }
    }

    #[test]
    fn sensitivity_includes_uniform_term() {
        let design = random_design(50, 2, 4, 25);
        let u = mctm_leverage_scores(&design).unwrap();
        let s = sensitivity_scores(&design).unwrap();
        for (ui, si) in u.iter().zip(&s) {
            assert!((si - ui - 1.0 / 50.0).abs() < 1e-12);
        }
    }

    #[test]
    fn plane_direct_matches_stacked_bitwise() {
        // the plane-direct Gram + scoring must reproduce the
        // materialized-stacked path to the bit — this is what keeps
        // every coreset draw identical to the pre-plane layout
        for (n, j, d, seed) in [(150usize, 2usize, 5usize, 41u64), (2100, 3, 4, 43)] {
            let design = random_design(n, j, d, seed);
            for t in [1usize, 2, 8] {
                let pool = Pool::new(t);
                let direct = mctm_leverage_scores_with(&design, &pool).unwrap();
                let stacked = design.stacked();
                let via_mat = leverage_scores_ridged_with(&stacked, 0.0, &pool).unwrap();
                for (i, (a, b)) in direct.iter().zip(&via_mat).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "n={n} t={t} row {i}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn tiled_stacked_gram_matches_materialized_bitwise() {
        // dJ = 90 crosses GRAM_TILE_GATE, so this drives the L2-tiled
        // SYRK path against the untiled materialized Gram; n = 2102
        // spans two ROW_CHUNK shards with a non-multiple-of-4 tail and
        // a short final panel
        let design = random_design(2102, 10, 9, 47);
        assert!(design.j * design.d >= GRAM_TILE_GATE);
        let mut rng = Rng::new(48);
        let sw: Vec<f64> = (0..2102).map(|_| rng.uniform(0.25, 3.0).sqrt()).collect();
        for t in [1usize, 2] {
            let pool = Pool::new(t);
            let tiled = stacked_gram_with(&design, None, &pool);
            let full = design.stacked().gram_with(&pool);
            for (k, (a, b)) in tiled.data.iter().zip(&full.data).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "t={t} entry {k}");
            }
            // weighted: scale a materialized stacked copy first
            let wtiled = stacked_gram_with(&design, Some(&sw), &pool);
            let mut sm = design.stacked();
            for i in 0..sm.rows {
                for c in 0..sm.cols {
                    sm.data[i * sm.cols + c] *= sw[i];
                }
            }
            let wfull = sm.gram_with(&pool);
            for (k, (a, b)) in wtiled.data.iter().zip(&wfull.data).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "t={t} weighted entry {k}");
            }
        }
    }

    #[test]
    fn weighted_plane_direct_matches_stacked_bitwise() {
        // the √w-scaled plane-direct path (what the streaming ℓ₂
        // reduces run) must reproduce scaling a materialized stacked
        // matrix, bit for bit, for unit AND non-trivial weights
        let design = random_design(500, 2, 5, 45);
        let mut rng = Rng::new(46);
        let mut w: Vec<f64> = (0..500).map(|_| rng.uniform(0.5, 4.0)).collect();
        w[7] = 1.0;
        w[123] = 250.0; // a heavy merged-coreset weight
        for t in [1usize, 4] {
            let pool = Pool::new(t);
            let direct = weighted_mctm_leverage_scores_with(&design, &w, &pool).unwrap();
            let stacked = design.stacked();
            let via_mat = weighted_leverage_scores_with(&stacked, &w, &pool).unwrap();
            for (i, (a, b)) in direct.iter().zip(&via_mat).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "t={t} row {i}: {a} vs {b}");
            }
        }
        // w ≡ 1 reproduces the unweighted plane-direct path to the bit
        let ones = vec![1.0; 500];
        let pool = Pool::new(1);
        let wdirect = weighted_mctm_leverage_scores_with(&design, &ones, &pool).unwrap();
        let plain = mctm_leverage_scores_with(&design, &pool).unwrap();
        for (a, b) in wdirect.iter().zip(&plain) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn sparse_scores_match_densified_bitwise() {
        // one-hot-heavy design: 4 continuous columns + 6 indicator
        // columns, plus a stored -0.0 to pin the exact-bits contract;
        // n = 2100 spans two ROW_CHUNK shards with a non-multiple-of-4
        // tail
        let mut rng = Rng::new(31);
        let (n, d) = (2100usize, 10usize);
        let mut data = vec![0.0f64; n * d];
        for (r, row) in data.chunks_mut(d).enumerate() {
            for v in row.iter_mut().take(4) {
                *v = rng.normal();
            }
            row[4 + rng.usize(6)] = 1.0;
            if r == 17 {
                row[5] = -0.0; // kept by from_dense, must survive
            }
        }
        let dense = Mat::from_vec(n, d, data);
        let sparse = SparseMat::from_dense(&dense);
        assert!(sparse.density() < 0.55, "{}", sparse.density());
        for gamma in [0.0, default_ridge(&dense)] {
            for t in [1usize, 2] {
                let pool = Pool::new(t);
                let via_dense = leverage_scores_ridged_with(&dense, gamma, &pool).unwrap();
                let via_sparse =
                    sparse_leverage_scores_ridged_with(&sparse, gamma, &pool).unwrap();
                for (i, (a, b)) in via_dense.iter().zip(&via_sparse).enumerate() {
                    assert_eq!(
                        a.to_bits(),
                        b.to_bits(),
                        "gamma={gamma} t={t} row {i}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn weighted_leverage_unit_weights_bit_identical() {
        let mut rng = Rng::new(27);
        let x = Mat::from_vec(150, 5, (0..750).map(|_| rng.normal()).collect());
        let pool = Pool::new(1);
        let plain = leverage_scores_ridged_with(&x, 0.0, &pool).unwrap();
        let weighted = weighted_leverage_scores_with(&x, &[1.0; 150], &pool).unwrap();
        for (i, (a, b)) in plain.iter().zip(&weighted).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "row {i}: {a} vs {b}");
        }
    }

    #[test]
    fn weighted_leverage_matches_replication() {
        // integer weight w_i = 2 ≡ duplicating row i: the weighted score
        // equals the sum of the duplicates' unweighted scores
        let mut rng = Rng::new(28);
        let n = 120;
        let x = Mat::from_vec(n, 4, (0..n * 4).map(|_| rng.normal()).collect());
        let pool = Pool::new(1);
        let mut w = vec![1.0; n];
        w[9] = 2.0;
        let weighted = weighted_leverage_scores_with(&x, &w, &pool).unwrap();
        let mut idx: Vec<usize> = (0..n).collect();
        idx.push(9);
        let dup = x.select_rows(&idx);
        let plain = leverage_scores_ridged_with(&dup, 0.0, &pool).unwrap();
        let rhs = plain[9] + plain[n];
        assert!(
            (weighted[9] - rhs).abs() < 1e-8 * (1.0 + rhs.abs()),
            "{} vs {rhs}",
            weighted[9]
        );
    }

    #[test]
    fn negative_ridge_recovers_through_ladder() {
        // gamma is caller-controlled; a gamma more negative than the
        // Gram diagonal makes the shifted matrix indefinite. The plain
        // factorization fails, the ridge ladder recovers, and the
        // recovery (with its rung) lands in the sink.
        let mut rows = Vec::new();
        for _ in 0..5 {
            rows.push(vec![1.0, 0.0]);
            rows.push(vec![0.0, 1.0]);
        }
        let x = Mat::from_rows(&rows); // Gram = diag(5, 5)
        let pool = Pool::new(1);
        let sink = DegradeSink::new();
        let u = leverage_scores_ridged_sink(&x, -6.0, &pool, &sink).unwrap();
        assert!(u.iter().all(|v| v.is_finite()));
        let d = sink.snapshot();
        assert_eq!(d.gram_ridge_recoveries, 1, "{d}");
        assert!(d.gram_ridge_max_rung >= 1, "{d}");
        // the sink-free wrapper still recovers (silently)
        let u2 = leverage_scores_ridged_with(&x, -6.0, &pool).unwrap();
        assert_eq!(u.len(), u2.len());
    }

    #[test]
    fn sink_variant_is_bit_identical_on_clean_data() {
        // attempt 0 of the ladder factors the untouched matrix, so the
        // sink-threaded path cannot perturb clean runs
        let mut rng = Rng::new(29);
        let x = Mat::from_vec(100, 4, (0..400).map(|_| rng.normal()).collect());
        let pool = Pool::new(1);
        let sink = DegradeSink::new();
        let a = leverage_scores_ridged_with(&x, 0.0, &pool).unwrap();
        let b = leverage_scores_ridged_sink(&x, 0.0, &pool, &sink).unwrap();
        for (p, q) in a.iter().zip(&b) {
            assert_eq!(p.to_bits(), q.to_bits());
        }
        assert!(sink.snapshot().is_clean());
    }

    #[test]
    fn leverage_invariant_to_column_scaling() {
        // leverage scores are invariant under right-multiplication by an
        // invertible matrix; scaling a column is such an operation
        let mut rng = Rng::new(26);
        let x = Mat::from_vec(80, 3, (0..240).map(|_| rng.normal()).collect());
        let mut x2 = x.clone();
        for r in 0..80 {
            *x2.at_mut(r, 1) *= 100.0;
        }
        let u1 = leverage_scores(&x).unwrap();
        let u2 = leverage_scores(&x2).unwrap();
        for (a, b) in u1.iter().zip(&u2) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
    }
}
