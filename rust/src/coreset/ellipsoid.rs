//! John-ellipsoid sensitivity scores — the paper's §4 extension for
//! copulas beyond the Gaussian: "we can find a John ellipsoid E that is
//! enclosed in a level set and its expansion √d·E encloses the same
//! level set. Then, we can derive leverage scores from the quadratic
//! form that describes the ellipsoid as in (Tukan et al., 2020)".
//!
//! We compute a (1+ε)-approximate **minimum-volume enclosing ellipsoid**
//! of the (lifted) data with Khachiyan's barycentric-coordinate-descent
//! algorithm and score each point by its ellipsoid quadratic form
//! q_iᵀ M⁻¹ q_i — an upper bound on the directional extremeness that
//! replaces the Gram-based leverage when the level sets are merely
//! log-concave rather than elliptical-Gaussian.

use crate::linalg::{Cholesky, Mat};

/// Result of the MVEE computation.
pub struct JohnEllipsoid {
    /// barycentric weights over the input rows (sum to 1)
    pub u: Vec<f64>,
    /// lifted second-moment matrix M = Σ u_i q_i q_iᵀ, q = (x, 1)
    pub m: Mat,
    /// iterations used
    pub iters: usize,
}

/// Khachiyan's algorithm on the lifted points q_i = (x_i, 1) ∈ R^{d+1}:
/// maximize log det Σ u_i q_i q_iᵀ over the simplex. Converges when
/// max_i q_iᵀ M⁻¹ q_i ≤ (1+ε)(d+1).
pub fn john_ellipsoid(x: &Mat, eps: f64, max_iters: usize) -> JohnEllipsoid {
    let (n, d) = (x.rows, x.cols);
    assert!(n > d, "need more points than dimensions");
    let dl = d + 1; // lifted dimension
    let mut u = vec![1.0 / n as f64; n];
    let mut q = Mat::zeros(n, dl);
    for i in 0..n {
        q.row_mut(i)[..d].copy_from_slice(x.row(i));
        q.row_mut(i)[d] = 1.0;
    }
    let mut iters = 0;
    let mut m = weighted_moment(&q, &u);
    for it in 0..max_iters {
        iters = it + 1;
        // M with a tiny stabilizer, factor once per iteration
        let mut ms = m.clone();
        let stab = 1e-12 * ms.trace().max(1e-300) / dl as f64;
        for k in 0..dl {
            *ms.at_mut(k, k) += stab;
        }
        let ch = match Cholesky::new(&ms) {
            Ok(c) => c,
            Err(_) => break,
        };
        // find the most violating point
        let mut kappa_max = f64::NEG_INFINITY;
        let mut arg = 0usize;
        let mut scratch = Vec::new();
        for i in 0..n {
            let k = ch.quad_form_inv(q.row(i), &mut scratch);
            if k > kappa_max {
                kappa_max = k;
                arg = i;
            }
        }
        if kappa_max <= (1.0 + eps) * dl as f64 {
            break;
        }
        // Khachiyan step toward the violator
        let step = (kappa_max - dl as f64) / (dl as f64 * (kappa_max - 1.0));
        for ui in u.iter_mut() {
            *ui *= 1.0 - step;
        }
        u[arg] += step;
        m = weighted_moment(&q, &u);
    }
    JohnEllipsoid { u, m, iters }
}

fn weighted_moment(q: &Mat, u: &[f64]) -> Mat {
    let dl = q.cols;
    let mut m = Mat::zeros(dl, dl);
    for i in 0..q.rows {
        let w = u[i];
        if w == 0.0 {
            continue;
        }
        let row = q.row(i);
        for a in 0..dl {
            let ra = w * row[a];
            for b in a..dl {
                *m.at_mut(a, b) += ra * row[b];
            }
        }
    }
    for a in 0..dl {
        for b in (a + 1)..dl {
            let v = m.at(a, b);
            *m.at_mut(b, a) = v;
        }
    }
    m
}

/// Ellipsoid sensitivity scores: s_i = q_iᵀ M⁻¹ q_i / (d+1) + 1/n —
/// normalized so Σ of the quadratic-form term over the ellipsoid's
/// support points is ≈ d+1 (John's theorem), mirroring the
/// leverage-plus-uniform shape of Algorithm 1.
pub fn ellipsoid_scores(x: &Mat, eps: f64) -> Vec<f64> {
    let n = x.rows;
    let je = john_ellipsoid(x, eps, 200);
    let dl = x.cols + 1;
    let mut ms = je.m.clone();
    let stab = 1e-12 * ms.trace().max(1e-300) / dl as f64;
    for k in 0..dl {
        *ms.at_mut(k, k) += stab;
    }
    let ch = match Cholesky::new(&ms) {
        Ok(c) => c,
        Err(_) => return vec![1.0; n],
    };
    let mut scratch = Vec::new();
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let mut q = x.row(i).to_vec();
        q.push(1.0);
        let k = ch.quad_form_inv(&q, &mut scratch);
        out.push(k / dl as f64 + 1.0 / n as f64);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cloud(n: usize, d: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_vec(n, d, (0..n * d).map(|_| rng.normal()).collect())
    }

    #[test]
    fn mvee_contains_all_points() {
        let x = cloud(200, 3, 1);
        let eps = 0.05;
        let je = john_ellipsoid(&x, eps, 500);
        let dl = 4;
        let mut ms = je.m.clone();
        for k in 0..dl {
            *ms.at_mut(k, k) += 1e-12;
        }
        let ch = Cholesky::new(&ms).unwrap();
        let mut scratch = Vec::new();
        for i in 0..x.rows {
            let mut q = x.row(i).to_vec();
            q.push(1.0);
            let kq = ch.quad_form_inv(&q, &mut scratch);
            assert!(
                kq <= (1.0 + eps) * dl as f64 + 1e-6,
                "point {i} outside: {kq}"
            );
        }
    }

    #[test]
    fn weights_on_simplex() {
        let x = cloud(100, 2, 2);
        let je = john_ellipsoid(&x, 0.05, 500);
        let total: f64 = je.u.iter().sum();
        assert!((total - 1.0).abs() < 1e-9);
        assert!(je.u.iter().all(|&u| u >= 0.0));
    }

    #[test]
    fn extreme_point_scores_highest() {
        let mut x = cloud(300, 2, 3);
        *x.at_mut(0, 0) = 30.0;
        *x.at_mut(0, 1) = -30.0;
        let s = ellipsoid_scores(&x, 0.05);
        // the planted outlier must be on the ellipsoid boundary — i.e.
        // among the top scores (the MVEE has several support points, so
        // strict argmax is not guaranteed) and far above the bulk
        let mut sorted = s.clone();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        assert!(s[0] >= sorted[7], "outlier score {} rank too low", s[0]);
        let med = crate::util::median(&s);
        assert!(s[0] > 1.5 * med, "outlier {} vs median {med}", s[0]);
    }

    #[test]
    fn scores_correlate_with_leverage_on_gaussian() {
        // for elliptical data, ellipsoid scores and ℓ₂ leverage should
        // rank points similarly (the paper's argument that the Gaussian
        // case is recovered)
        let x = cloud(400, 3, 4);
        let ell = ellipsoid_scores(&x, 0.05);
        let lev = crate::coreset::leverage::leverage_scores(&x).unwrap();
        // rank correlation on a coarse level: top decile overlap
        let top = |v: &[f64]| -> std::collections::HashSet<usize> {
            let mut idx: Vec<usize> = (0..v.len()).collect();
            idx.sort_by(|&a, &b| v[b].partial_cmp(&v[a]).unwrap());
            idx[..40].iter().cloned().collect()
        };
        let overlap = top(&ell).intersection(&top(&lev)).count();
        assert!(overlap >= 15, "top-decile overlap {overlap}/40");
    }
}
