//! John-ellipsoid sensitivity scores — the paper's §4 extension for
//! copulas beyond the Gaussian: "we can find a John ellipsoid E that is
//! enclosed in a level set and its expansion √d·E encloses the same
//! level set. Then, we can derive leverage scores from the quadratic
//! form that describes the ellipsoid as in (Tukan et al., 2020)".
//!
//! We compute a (1+ε)-approximate **minimum-volume enclosing ellipsoid**
//! of the (lifted) data with Khachiyan's barycentric-coordinate-descent
//! algorithm and score each point by its ellipsoid quadratic form
//! q_iᵀ M⁻¹ q_i — an upper bound on the directional extremeness that
//! replaces the Gram-based leverage when the level sets are merely
//! log-concave rather than elliptical-Gaussian.
//!
//! Parallelism (ISSUE 2): the two O(n·d²) rounding scans per Khachiyan
//! iteration — the weighted second-moment rebuild and the
//! most-violating-point search — are row-sharded on the deterministic
//! pool. Partial moments merge by fixed-shape tree reduction and the
//! violator argmax merges with strict `>` (earlier rows win ties), so
//! the whole rounding loop is **bit-identical for any thread count**
//! (pinned by `tests/hull_properties.rs`).
//!
//! Wiring (ISSUE 3): `ellipsoid_scores_with` backs the registered
//! `ellipsoid` / `ellipsoid-hull` methods through
//! `strategy::EllipsoidScores`, so the rounding here runs end to end —
//! CLI flag → batch builds → streaming Merge & Reduce — not just in the
//! perf bench.

use crate::linalg::{cholesky_ridge_ladder, Mat};
use crate::util::degrade::DegradeSink;
use crate::util::parallel::{add_assign, tree_reduce, Pool, ROW_CHUNK};

/// Result of the MVEE computation.
pub struct JohnEllipsoid {
    /// barycentric weights over the input rows (sum to 1)
    pub u: Vec<f64>,
    /// lifted second-moment matrix M = Σ u_i q_i q_iᵀ, q = (x, 1)
    pub m: Mat,
    /// iterations used
    pub iters: usize,
    /// whether the (1+ε) optimality criterion was met (false when the
    /// iteration budget ran out or the moment matrix stopped factoring —
    /// the ellipsoid is still usable, just not certified)
    pub converged: bool,
}

/// Khachiyan's algorithm on the lifted points q_i = (x_i, 1) ∈ R^{d+1}:
/// maximize log det Σ u_i q_i q_iᵀ over the simplex. Converges when
/// max_i q_iᵀ M⁻¹ q_i ≤ (1+ε)(d+1).
pub fn john_ellipsoid(x: &Mat, eps: f64, max_iters: usize) -> JohnEllipsoid {
    john_ellipsoid_with(x, eps, max_iters, &Pool::current())
}

/// [`john_ellipsoid`] on an explicit pool.
pub fn john_ellipsoid_with(x: &Mat, eps: f64, max_iters: usize, pool: &Pool) -> JohnEllipsoid {
    john_ellipsoid_sink(x, eps, max_iters, pool, &DegradeSink::new())
}

/// [`john_ellipsoid_with`] with degradation accounting: a moment matrix
/// that fails to factor retries through the ridge ladder (recovery
/// recorded); a terminal factor failure or an exhausted iteration
/// budget is recorded instead of silently proceeding, and is also
/// visible on the returned `converged` flag.
pub fn john_ellipsoid_sink(
    x: &Mat,
    eps: f64,
    max_iters: usize,
    pool: &Pool,
    sink: &DegradeSink,
) -> JohnEllipsoid {
    let (n, d) = (x.rows, x.cols);
    assert!(n > d, "need more points than dimensions");
    let dl = d + 1; // lifted dimension
    let mut u = vec![1.0 / n as f64; n];
    let mut q = Mat::zeros(n, dl);
    for i in 0..n {
        q.row_mut(i)[..d].copy_from_slice(x.row(i));
        q.row_mut(i)[d] = 1.0;
    }
    let mut iters = 0;
    let mut converged = false;
    let mut m = weighted_moment_with(&q, &u, pool);
    for it in 0..max_iters {
        iters = it + 1;
        // M with a tiny stabilizer; the ladder's first attempt factors
        // exactly this matrix, so clean runs are bit-identical
        let mut ms = m.clone();
        let stab = 1e-12 * ms.trace().max(1e-300) / dl as f64;
        for k in 0..dl {
            *ms.at_mut(k, k) += stab;
        }
        let ch = match cholesky_ridge_ladder(&ms) {
            Ok((c, rung)) => {
                if rung > 0 {
                    sink.gram_ridge_recovery(rung);
                }
                c
            }
            Err(_) => {
                // keep the last factorable iterate rather than panic;
                // record that rounding stopped on a factor break
                sink.mvee_factor_break();
                break;
            }
        };
        // most violating point: row-sharded argmax with per-worker
        // scratch, merged in fixed tree order (earlier rows win ties)
        let (kappa_max, arg) = {
            let ch = &ch;
            let q_ref = &q;
            tree_reduce(
                pool.map_chunks(n, ROW_CHUNK, |_, range| {
                    let mut scratch = Vec::new();
                    let mut best = (f64::NEG_INFINITY, usize::MAX);
                    for i in range {
                        let kq = ch.quad_form_inv(q_ref.row(i), &mut scratch);
                        if kq > best.0 {
                            best = (kq, i);
                        }
                    }
                    best
                }),
                |a, b| if b.0 > a.0 { b } else { a },
            )
            .unwrap_or((f64::NEG_INFINITY, usize::MAX))
        };
        if arg == usize::MAX || kappa_max <= (1.0 + eps) * dl as f64 {
            converged = true;
            break;
        }
        // Khachiyan step toward the violator
        let step = (kappa_max - dl as f64) / (dl as f64 * (kappa_max - 1.0));
        for ui in u.iter_mut() {
            *ui *= 1.0 - step;
        }
        u[arg] += step;
        m = weighted_moment_with(&q, &u, pool);
    }
    if !converged {
        sink.mvee_nonconverged();
    }
    JohnEllipsoid { u, m, iters, converged }
}

/// Row-sharded M = Σ u_i q_i q_iᵀ: per-chunk upper-triangle partials in
/// fixed row order, merged by tree reduction — summation order depends
/// only on n, never on the thread count.
fn weighted_moment_with(q: &Mat, u: &[f64], pool: &Pool) -> Mat {
    let dl = q.cols;
    let partials = pool.map_chunks(q.rows, ROW_CHUNK, |_, range| {
        let mut acc = vec![0.0f64; dl * dl];
        for i in range {
            let w = u[i];
            if w == 0.0 {
                continue;
            }
            let row = q.row(i);
            for a in 0..dl {
                let ra = w * row[a];
                let mrow = &mut acc[a * dl..(a + 1) * dl];
                for b in a..dl {
                    mrow[b] += ra * row[b];
                }
            }
        }
        acc
    });
    let data = tree_reduce(partials, |mut a, b| {
        add_assign(&mut a, &b);
        a
    })
    .unwrap_or_else(|| vec![0.0; dl * dl]);
    let mut m = Mat::from_vec(dl, dl, data);
    for a in 0..dl {
        for b in (a + 1)..dl {
            let v = m.at(a, b);
            *m.at_mut(b, a) = v;
        }
    }
    m
}

/// Ellipsoid sensitivity scores: s_i = q_iᵀ M⁻¹ q_i / (d+1) + 1/n —
/// normalized so Σ of the quadratic-form term over the ellipsoid's
/// support points is ≈ d+1 (John's theorem), mirroring the
/// leverage-plus-uniform shape of Algorithm 1.
pub fn ellipsoid_scores(x: &Mat, eps: f64) -> Vec<f64> {
    ellipsoid_scores_with(x, eps, &Pool::current())
}

/// [`ellipsoid_scores`] on an explicit pool: the final scoring pass
/// writes disjoint row chunks with per-worker scratch, sharing the one
/// factorization — same disjoint-write pattern as the leverage kernel.
pub fn ellipsoid_scores_with(x: &Mat, eps: f64, pool: &Pool) -> Vec<f64> {
    ellipsoid_scores_sink(x, eps, pool, &DegradeSink::new())
}

/// [`ellipsoid_scores_with`] with degradation accounting: rounding
/// non-convergence, factor-break recoveries, and the uniform-score
/// fallback are all recorded into `sink` instead of passing silently.
pub fn ellipsoid_scores_sink(x: &Mat, eps: f64, pool: &Pool, sink: &DegradeSink) -> Vec<f64> {
    let n = x.rows;
    let je = john_ellipsoid_sink(x, eps, 200, pool, sink);
    let dl = x.cols + 1;
    let mut ms = je.m.clone();
    let stab = 1e-12 * ms.trace().max(1e-300) / dl as f64;
    for k in 0..dl {
        *ms.at_mut(k, k) += stab;
    }
    let ch = match cholesky_ridge_ladder(&ms) {
        Ok((c, rung)) => {
            if rung > 0 {
                sink.gram_ridge_recovery(rung);
            }
            c
        }
        Err(_) => {
            // uniform scores keep the sampler total-order valid; the
            // fallback is visible in the run's degradation record
            sink.score_fallback();
            return vec![1.0; n];
        }
    };
    let mut out = vec![0.0; n];
    {
        let ch = &ch;
        let items: Vec<&mut [f64]> = out.chunks_mut(ROW_CHUNK).collect();
        pool.for_items(items, |ci, chunk| {
            let lo = ci * ROW_CHUNK;
            let mut scratch = Vec::new();
            let mut qb = vec![0.0; dl];
            for (off, o) in chunk.iter_mut().enumerate() {
                qb[..dl - 1].copy_from_slice(x.row(lo + off));
                qb[dl - 1] = 1.0;
                let kq = ch.quad_form_inv(&qb, &mut scratch);
                *o = kq / dl as f64 + 1.0 / n as f64;
            }
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Cholesky;
    use crate::util::rng::Rng;

    fn cloud(n: usize, d: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_vec(n, d, (0..n * d).map(|_| rng.normal()).collect())
    }

    #[test]
    fn mvee_contains_all_points() {
        let x = cloud(200, 3, 1);
        let eps = 0.05;
        let je = john_ellipsoid(&x, eps, 500);
        let dl = 4;
        let mut ms = je.m.clone();
        for k in 0..dl {
            *ms.at_mut(k, k) += 1e-12;
        }
        let ch = Cholesky::new(&ms).unwrap();
        let mut scratch = Vec::new();
        for i in 0..x.rows {
            let mut q = x.row(i).to_vec();
            q.push(1.0);
            let kq = ch.quad_form_inv(&q, &mut scratch);
            assert!(
                kq <= (1.0 + eps) * dl as f64 + 1e-6,
                "point {i} outside: {kq}"
            );
        }
    }

    #[test]
    fn weights_on_simplex() {
        let x = cloud(100, 2, 2);
        let je = john_ellipsoid(&x, 0.05, 500);
        let total: f64 = je.u.iter().sum();
        assert!((total - 1.0).abs() < 1e-9);
        assert!(je.u.iter().all(|&u| u >= 0.0));
    }

    #[test]
    fn nonconvergence_is_recorded_not_silent() {
        let x = cloud(200, 3, 5);
        // one iteration cannot meet the (1+ε) certificate on a real cloud
        let sink = DegradeSink::new();
        let je = john_ellipsoid_sink(&x, 0.001, 1, &Pool::new(1), &sink);
        assert!(!je.converged);
        assert_eq!(sink.snapshot().mvee_nonconverged, 1);
        // a generous budget converges and records nothing
        let sink2 = DegradeSink::new();
        let je2 = john_ellipsoid_sink(&x, 0.05, 500, &Pool::new(1), &sink2);
        assert!(je2.converged);
        assert!(sink2.snapshot().is_clean());
    }

    #[test]
    fn extreme_point_scores_highest() {
        let mut x = cloud(300, 2, 3);
        *x.at_mut(0, 0) = 30.0;
        *x.at_mut(0, 1) = -30.0;
        let s = ellipsoid_scores(&x, 0.05);
        // the planted outlier must be on the ellipsoid boundary — i.e.
        // among the top scores (the MVEE has several support points, so
        // strict argmax is not guaranteed) and far above the bulk
        let mut sorted = s.clone();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        assert!(s[0] >= sorted[7], "outlier score {} rank too low", s[0]);
        let med = crate::util::median(&s);
        assert!(s[0] > 1.5 * med, "outlier {} vs median {med}", s[0]);
    }

    #[test]
    fn scores_correlate_with_leverage_on_gaussian() {
        // for elliptical data, ellipsoid scores and ℓ₂ leverage should
        // rank points similarly (the paper's argument that the Gaussian
        // case is recovered)
        let x = cloud(400, 3, 4);
        let ell = ellipsoid_scores(&x, 0.05);
        let lev = crate::coreset::leverage::leverage_scores(&x).unwrap();
        // rank correlation on a coarse level: top decile overlap
        let top = |v: &[f64]| -> std::collections::HashSet<usize> {
            let mut idx: Vec<usize> = (0..v.len()).collect();
            idx.sort_by(|&a, &b| v[b].partial_cmp(&v[a]).unwrap());
            idx[..40].iter().cloned().collect()
        };
        let overlap = top(&ell).intersection(&top(&lev)).count();
        assert!(overlap >= 15, "top-decile overlap {overlap}/40");
    }
}
