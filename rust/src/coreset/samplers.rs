//! Coreset sampling strategies (paper Algorithm 1 + the baselines of
//! §3): the hybrid ℓ₂-hull construction, plain ℓ₂ leverage sampling,
//! uniform subsampling, ridge leverage scores and root leverage scores.

use super::hull::select_hull_points_with;
use super::leverage::{
    default_ridge_with, leverage_scores_ridged_with, mctm_leverage_scores_with,
    sensitivity_scores_with,
};
use crate::basis::Design;
use crate::util::parallel::Pool;
use crate::util::rng::{AliasTable, Rng};

/// Fraction of the budget spent on the sensitivity sample in the hybrid
/// method; the rest goes to convex-hull points (Algorithm 1: α = 0.8).
pub const HULL_SPLIT: f64 = 0.8;

/// The sampling strategies compared in the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// uniform subsampling without replacement, weights n/k
    Uniform,
    /// pure ℓ₂ leverage-score (sensitivity proxy) sampling
    L2Only,
    /// the paper's ℓ₂-hull hybrid: sensitivity sample + convex hull of a'
    L2Hull,
    /// ridge leverage scores baseline (Table 2)
    RidgeLss,
    /// root leverage scores baseline (Table 2): p_i ∝ √u_i
    RootL2,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Uniform => "uniform",
            Method::L2Only => "l2-only",
            Method::L2Hull => "l2-hull",
            Method::RidgeLss => "ridge-lss",
            Method::RootL2 => "root-l2",
        }
    }

    pub fn all() -> [Method; 5] {
        [
            Method::L2Hull,
            Method::L2Only,
            Method::RidgeLss,
            Method::RootL2,
            Method::Uniform,
        ]
    }
}

/// A weighted coreset: observation indices (into the design) + weights.
/// Indices may repeat (i.i.d. sensitivity sampling); fitting code treats
/// (index, weight) pairs independently, which is equivalent.
#[derive(Clone, Debug)]
pub struct Coreset {
    pub indices: Vec<usize>,
    pub weights: Vec<f64>,
    /// diagnostics: how many points came from the hull component
    pub n_hull: usize,
    /// sampling probabilities used (empty for uniform/hull-only parts)
    pub method: Method,
}

impl Coreset {
    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Total weight — for an unbiased construction E[total] = n.
    pub fn total_weight(&self) -> f64 {
        self.weights.iter().sum()
    }
}

/// Draw `k` i.i.d. indices with probabilities ∝ scores; weight 1/(k p).
fn importance_sample(scores: &[f64], k: usize, rng: &mut Rng, method: Method) -> Coreset {
    let table = AliasTable::new(scores);
    let mut indices = Vec::with_capacity(k);
    let mut weights = Vec::with_capacity(k);
    for _ in 0..k {
        let i = table.sample(rng);
        indices.push(i);
        weights.push(1.0 / (k as f64 * table.p(i)));
    }
    Coreset { indices, weights, n_hull: 0, method }
}

/// Build a coreset of target size `k` from a design, per `method`.
///
/// Falls back to uniform sampling if the leverage computation fails
/// (degenerate design) — mirroring the robustness behaviour of the
/// reference implementation.
pub fn build_coreset(design: &Design, method: Method, k: usize, rng: &mut Rng) -> Coreset {
    build_coreset_with(design, method, k, rng, &Pool::current())
}

/// [`build_coreset`] on an explicit pool: every score/hull kernel inside
/// (leverage, Gram, hull selection) runs on `pool`, and all of them are
/// bit-identical for any thread count — so the sampled coreset depends
/// only on `rng`, never on the pool width. Streaming consumers pass
/// `Pool::new(1)` to avoid nesting workers.
pub fn build_coreset_with(
    design: &Design,
    method: Method,
    k: usize,
    rng: &mut Rng,
    pool: &Pool,
) -> Coreset {
    let n = design.n;
    assert!(k >= 1);
    if k >= n {
        // trivial coreset: everything, weight 1
        return Coreset {
            indices: (0..n).collect(),
            weights: vec![1.0; n],
            n_hull: 0,
            method,
        };
    }
    match method {
        Method::Uniform => {
            let indices = rng.sample_without_replacement(n, k);
            let w = n as f64 / k as f64;
            Coreset {
                weights: vec![w; indices.len()],
                indices,
                n_hull: 0,
                method,
            }
        }
        Method::L2Only => match sensitivity_scores_with(design, pool) {
            Ok(s) => importance_sample(&s, k, rng, method),
            Err(_) => build_coreset_with(design, Method::Uniform, k, rng, pool),
        },
        Method::RidgeLss => {
            let stacked = design.stacked();
            let gamma = default_ridge_with(&stacked, pool);
            match leverage_scores_ridged_with(&stacked, gamma, pool) {
                Ok(mut u) => {
                    let unif = 1.0 / n as f64;
                    u.iter_mut().for_each(|x| *x += unif);
                    importance_sample(&u, k, rng, method)
                }
                Err(_) => build_coreset_with(design, Method::Uniform, k, rng, pool),
            }
        }
        Method::RootL2 => match mctm_leverage_scores_with(design, pool) {
            Ok(u) => {
                let s: Vec<f64> =
                    u.iter().map(|&x| x.max(0.0).sqrt() + 1.0 / n as f64).collect();
                importance_sample(&s, k, rng, method)
            }
            Err(_) => build_coreset_with(design, Method::Uniform, k, rng, pool),
        },
        Method::L2Hull => {
            let k1 = ((HULL_SPLIT * k as f64).floor() as usize).clamp(1, k);
            let k2 = k - k1;
            let mut cs = match sensitivity_scores_with(design, pool) {
                Ok(s) => importance_sample(&s, k1, rng, method),
                Err(_) => {
                    let mut u = build_coreset_with(design, Method::Uniform, k1, rng, pool);
                    u.method = method;
                    u
                }
            };
            if k2 > 0 {
                // hull over derivative points {a'_ij}: map point index
                // (i·J + j) back to observation index i
                let dp = design.deriv_points();
                let hull_pts = select_hull_points_with(&dp, k2, rng, pool);
                let mut seen: std::collections::HashSet<usize> =
                    cs.indices.iter().cloned().collect();
                for p in hull_pts {
                    let obs = p / design.j;
                    if seen.insert(obs) {
                        cs.indices.push(obs);
                        cs.weights.push(1.0); // hull points get weight 1
                        cs.n_hull += 1;
                    }
                }
            }
            cs
        }
    }
}

/// Extract the weight vector aligned with `design.select(&coreset.indices)`:
/// fitting uses (subset design, weights).
pub fn coreset_weights(cs: &Coreset) -> Vec<f64> {
    cs.weights.clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    fn toy_design(n: usize, seed: u64) -> Design {
        let mut rng = Rng::new(seed);
        let data = Mat::from_vec(n, 2, (0..n * 2).map(|_| rng.normal()).collect());
        Design::build(&data, 5, 0.01)
    }

    #[test]
    fn uniform_weights_are_n_over_k() {
        let design = toy_design(100, 1);
        let mut rng = Rng::new(2);
        let cs = build_coreset(&design, Method::Uniform, 10, &mut rng);
        assert_eq!(cs.len(), 10);
        assert!(cs.weights.iter().all(|&w| (w - 10.0).abs() < 1e-12));
        // no duplicates for uniform-without-replacement
        let set: std::collections::HashSet<_> = cs.indices.iter().collect();
        assert_eq!(set.len(), 10);
    }

    #[test]
    fn importance_weights_unbiased_total() {
        // E[Σ w] = n; check the empirical mean over repetitions
        let design = toy_design(200, 3);
        let mut rng = Rng::new(4);
        let mut totals = Vec::new();
        for _ in 0..50 {
            let cs = build_coreset(&design, Method::L2Only, 30, &mut rng);
            totals.push(cs.total_weight());
        }
        let mean = totals.iter().sum::<f64>() / totals.len() as f64;
        assert!(
            (mean - 200.0).abs() < 30.0,
            "importance sampling total weight biased: {mean}"
        );
    }

    #[test]
    fn l2hull_contains_hull_points() {
        let design = toy_design(300, 5);
        let mut rng = Rng::new(6);
        let cs = build_coreset(&design, Method::L2Hull, 30, &mut rng);
        assert!(cs.n_hull > 0, "expected hull augmentation");
        // hull points have weight exactly 1 at the tail
        let tail = &cs.weights[cs.weights.len() - cs.n_hull..];
        assert!(tail.iter().all(|&w| w == 1.0));
        assert!(cs.len() >= 30 - 5 && cs.len() <= 30);
    }

    #[test]
    fn k_geq_n_returns_identity() {
        let design = toy_design(20, 7);
        let mut rng = Rng::new(8);
        let cs = build_coreset(&design, Method::L2Hull, 50, &mut rng);
        assert_eq!(cs.len(), 20);
        assert!(cs.weights.iter().all(|&w| w == 1.0));
    }

    #[test]
    fn preserves_f1_within_factor() {
        // the subspace-embedding property behind Lemma 2.1: the weighted
        // coreset f₁ stays within a modest factor of the full f₁ for a
        // fixed parameter choice (statistical check, generous bound)
        use crate::mctm::{nll_parts, ModelSpec, Params};
        let design = toy_design(2000, 9);
        let spec = ModelSpec::new(2, 5);
        let mut p = Params::init(spec);
        p.x[spec.j * spec.d] = 0.5;
        let theta = p.theta();
        let lam = p.lambda_block().to_vec();
        let full = nll_parts(&design, &[], &theta, &lam);
        let mut rng = Rng::new(10);
        let mut ratios = Vec::new();
        for _ in 0..10 {
            let cs = build_coreset(&design, Method::L2Only, 200, &mut rng);
            let sub = design.select(&cs.indices);
            let part = nll_parts(&sub, &cs.weights, &theta, &lam);
            ratios.push(part.f1 / full.f1);
        }
        let mean = ratios.iter().sum::<f64>() / ratios.len() as f64;
        assert!((mean - 1.0).abs() < 0.25, "f1 ratio mean {mean}");
    }
}
