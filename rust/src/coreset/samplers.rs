//! Coreset sampling entry points (paper Algorithm 1 + the baselines of
//! §3 and the §4 ellipsoid extension): the `Method` tags and the
//! `build_coreset` front door. All per-method behaviour — scores,
//! budget splits, names — lives in the strategy registry
//! (`coreset::strategy`); this module never matches on `Method`.

use super::strategy;
use crate::basis::Design;
use crate::util::degrade::DegradeSink;
use crate::util::parallel::Pool;
use crate::util::rng::Rng;

/// Fraction of the budget spent on the sensitivity sample in the hybrid
/// methods; the rest goes to convex-hull points (Algorithm 1: α = 0.8).
pub const HULL_SPLIT: f64 = 0.8;

/// Registry tags for the sampling strategies compared in the paper.
///
/// A tag is a lightweight `Copy` handle; everything behind it — name,
/// description, score strategy, hull split, Merge & Reduce behaviour —
/// is defined by the matching `strategy::REGISTRY` row. Adding a method
/// means adding a variant here and one registry row there; no other
/// code enumerates methods.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// uniform subsampling without replacement, weights n/k
    Uniform,
    /// pure ℓ₂ leverage-score (sensitivity proxy) sampling
    L2Only,
    /// the paper's ℓ₂-hull hybrid: sensitivity sample + convex hull of a'
    L2Hull,
    /// ridge leverage scores baseline (Table 2)
    RidgeLss,
    /// root leverage scores baseline (Table 2): p_i ∝ √u_i
    RootL2,
    /// John-ellipsoid scores (§4, non-Gaussian log-concave copulas)
    Ellipsoid,
    /// ellipsoid scores + convex hull under the α = 0.8 split
    EllipsoidHull,
}

impl Method {
    /// Canonical CLI/config name (registry-driven).
    pub fn name(&self) -> &'static str {
        strategy::method_name(*self)
    }

    /// One-line description for `--help` and docs (registry-driven).
    pub fn describe(&self) -> &'static str {
        strategy::method_describe(*self)
    }

    /// Every registered method, registry order (Uniform last — table
    /// drivers use the last entry as the baseline row).
    pub fn all() -> Vec<Method> {
        strategy::all_methods()
    }

    /// Parse a CLI/config name; the error lists all valid names.
    pub fn parse(name: &str) -> crate::util::error::Result<Method> {
        strategy::parse_method(name)
    }
}

/// A weighted coreset: observation indices (into the design) + weights.
/// Indices may repeat (i.i.d. sensitivity sampling); fitting code treats
/// (index, weight) pairs independently, which is equivalent.
#[derive(Clone, Debug)]
pub struct Coreset {
    pub indices: Vec<usize>,
    pub weights: Vec<f64>,
    /// diagnostics: how many points came from the hull component
    pub n_hull: usize,
    /// which registered sampling method built this coreset
    pub method: Method,
}

impl Coreset {
    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Total weight — for an unbiased construction `E[total] = n`.
    pub fn total_weight(&self) -> f64 {
        self.weights.iter().sum()
    }
}

/// Crate-internal coreset construction on an explicit pool: every
/// score/hull kernel inside (leverage, ellipsoid rounding, Gram, hull
/// selection) runs on `pool`, and all of them are bit-identical for any
/// thread count — so the sampled coreset depends only on `rng`, never
/// on the pool width. Streaming consumers pass `Pool::new(1)` to avoid
/// nesting workers.
///
/// Dispatch goes through the strategy registry: the trivial `k ≥ n`
/// identity coreset is handled here, everything else by the method's
/// registered [`strategy::MethodSampler`]. Numerical fallbacks taken
/// during scoring/sampling are recorded into `sink`. Public callers
/// reach this through `api::Session` (the pre-0.3 free-function shims
/// `build_coreset` / `build_coreset_with` are gone).
pub(crate) fn build_coreset_on(
    design: &Design,
    method: Method,
    k: usize,
    rng: &mut Rng,
    pool: &Pool,
    sink: &DegradeSink,
) -> Coreset {
    let n = design.n;
    assert!(k >= 1);
    if k >= n {
        // trivial coreset: everything, weight 1
        return Coreset {
            indices: (0..n).collect(),
            weights: vec![1.0; n],
            n_hull: 0,
            method,
        };
    }
    strategy::sampler(method).sample(design, method, k, rng, pool, sink)
}

/// Extract the weight vector aligned with `design.select(&coreset.indices)`:
/// fitting uses (subset design, weights).
pub fn coreset_weights(cs: &Coreset) -> Vec<f64> {
    cs.weights.clone()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    fn bc(design: &Design, method: Method, k: usize, rng: &mut Rng) -> Coreset {
        build_coreset_on(design, method, k, rng, &Pool::current(), &DegradeSink::new())
    }

    fn toy_design(n: usize, seed: u64) -> Design {
        let mut rng = Rng::new(seed);
        let data = Mat::from_vec(n, 2, (0..n * 2).map(|_| rng.normal()).collect());
        Design::build(&data, 5, 0.01)
    }

    #[test]
    fn uniform_weights_are_n_over_k() {
        let design = toy_design(100, 1);
        let mut rng = Rng::new(2);
        let cs = bc(&design, Method::Uniform, 10, &mut rng);
        assert_eq!(cs.len(), 10);
        assert!(cs.weights.iter().all(|&w| (w - 10.0).abs() < 1e-12));
        // no duplicates for uniform-without-replacement
        let set: std::collections::HashSet<_> = cs.indices.iter().collect();
        assert_eq!(set.len(), 10);
    }

    #[test]
    fn importance_weights_unbiased_total() {
        // E[Σ w] = n; check the empirical mean over repetitions
        let design = toy_design(200, 3);
        let mut rng = Rng::new(4);
        let mut totals = Vec::new();
        for _ in 0..50 {
            let cs = bc(&design, Method::L2Only, 30, &mut rng);
            totals.push(cs.total_weight());
        }
        let mean = totals.iter().sum::<f64>() / totals.len() as f64;
        assert!(
            (mean - 200.0).abs() < 30.0,
            "importance sampling total weight biased: {mean}"
        );
    }

    #[test]
    fn l2hull_contains_hull_points() {
        let design = toy_design(300, 5);
        let mut rng = Rng::new(6);
        let cs = bc(&design, Method::L2Hull, 30, &mut rng);
        assert!(cs.n_hull > 0, "expected hull augmentation");
        // hull points have weight exactly 1 at the tail
        let tail = &cs.weights[cs.weights.len() - cs.n_hull..];
        assert!(tail.iter().all(|&w| w == 1.0));
        assert!(cs.len() >= 30 - 5 && cs.len() <= 30);
    }

    #[test]
    fn ellipsoid_hull_contains_hull_points() {
        // the hull composition comes from HybridSampler, so the new
        // ellipsoid-hull method inherits the same augmentation shape
        let design = toy_design(300, 11);
        let mut rng = Rng::new(12);
        let cs = bc(&design, Method::EllipsoidHull, 30, &mut rng);
        assert!(cs.n_hull > 0, "expected hull augmentation");
        let tail = &cs.weights[cs.weights.len() - cs.n_hull..];
        assert!(tail.iter().all(|&w| w == 1.0));
        assert!(cs.len() >= 30 - 5 && cs.len() <= 30);
        assert_eq!(cs.method, Method::EllipsoidHull);
    }

    #[test]
    fn k_geq_n_returns_identity() {
        let design = toy_design(20, 7);
        let mut rng = Rng::new(8);
        let cs = bc(&design, Method::L2Hull, 50, &mut rng);
        assert_eq!(cs.len(), 20);
        assert!(cs.weights.iter().all(|&w| w == 1.0));
    }

    #[test]
    fn preserves_f1_within_factor() {
        // the subspace-embedding property behind Lemma 2.1: the weighted
        // coreset f₁ stays within a modest factor of the full f₁ for a
        // fixed parameter choice (statistical check, generous bound)
        use crate::mctm::{nll_parts, ModelSpec, Params};
        let design = toy_design(2000, 9);
        let spec = ModelSpec::new(2, 5);
        let mut p = Params::init(spec);
        p.x[spec.j * spec.d] = 0.5;
        let theta = p.theta();
        let lam = p.lambda_block().to_vec();
        let full = nll_parts(&design, &[], &theta, &lam);
        let mut rng = Rng::new(10);
        let mut ratios = Vec::new();
        for _ in 0..10 {
            let cs = bc(&design, Method::L2Only, 200, &mut rng);
            let sub = design.select(&cs.indices);
            let part = nll_parts(&sub, &cs.weights, &theta, &lam);
            ratios.push(part.f1 / full.f1);
        }
        let mean = ratios.iter().sum::<f64>() / ratios.len() as f64;
        assert!((mean - 1.0).abs() < 0.25, "f1 ratio mean {mean}");
    }
}
