//! Sparse convex-hull approximation — paper Algorithm 2 (Blum,
//! Har-Peled & Raichel 2019, "sparse approximation via generating point
//! sets") over the derivative points {a'_ij} ⊂ R^d.
//!
//! Role in the coreset (Lemma 2.3 / Theorem 2.4): the negative-log part
//! f₃ blows up where ⟨ϑ_j, a'⟩ → 0; adding the extreme points of the
//! derivative cloud keeps every direction's minimum inner product
//! represented in the coreset, so minimizers stay inside D(η).
//!
//! Two pieces:
//!  * `dist_to_hull` — the paper's inner loop: Frank–Wolfe-style
//!    projection of a query onto conv(S) (iteratively project onto the
//!    segment towards the extremal point in the residual direction).
//!  * `select_hull_points` — greedy generating-set construction: seed
//!    with the two/three-point initialization of Algorithm 2, then
//!    repeatedly add the candidate farthest from the current approximate
//!    hull, until k₂ points (or the hull error drops below tol).
//!
//! For large n the candidate set is pre-filtered to directional support
//! points (extremal in R random directions) — only possible hull
//! vertices survive, making selection O(R·n) instead of O(k₂·n·M·|S|).
//! This is the η-kernel style mildness assumption discussed in §4.
//!
//! Parallelism (ISSUE 2 / ROADMAP L3-c): both hot scans run on the
//! deterministic worker pool of `util/parallel.rs` — the support-
//! direction pass is row-sharded with a fixed-shape tree-reduced
//! per-direction argmax, and the greedy selection's distance scans are
//! chunked over candidates with a tree-reduced argmax whose ties break
//! towards the lowest candidate position. Chunk grids depend only on
//! problem sizes, so results are **bit-identical for any thread count**
//! (pinned by `tests/hull_properties.rs`).
//!
//! Selection is shared by every hybrid method through
//! `strategy::HybridSampler` (Algorithm 1's α-split): `l2-hull` and
//! `ellipsoid-hull` both pin hull points of the derivative cloud, only
//! their score families differ.

use crate::linalg::Mat;
use crate::util::parallel::{tree_reduce, Pool, ROW_CHUNK};
use crate::util::rng::Rng;

/// Frank–Wolfe iterations for a hull-distance query (the paper's
/// M = O(1/ε²); 64 gives ε ≈ 0.125 relative which is plenty for greedy
/// *selection* where only the argmax matters).
const FW_ITERS: usize = 64;

/// Candidates per selection-scan chunk. Each candidate costs a full
/// Frank–Wolfe projection (|S|·M·d flops), so chunks are much smaller
/// than `ROW_CHUNK` to fan out even the ~260-candidate prefiltered case.
const SCAN_CHUNK: usize = 32;

/// Reusable Frank–Wolfe projection state: both buffers are fully
/// overwritten per query, so reuse across a batch changes no bits —
/// it only removes the two allocations from the inner loop.
struct FwScratch {
    t: Vec<f64>,
    v: Vec<f64>,
}

impl FwScratch {
    fn new(d: usize) -> FwScratch {
        FwScratch { t: vec![0.0; d], v: vec![0.0; d] }
    }
}

/// Squared distance of `q` to conv of the rows of `points` restricted to
/// `hull_idx`, via the Algorithm-2 projection loop.
pub fn dist_to_hull(points: &Mat, hull_idx: &[usize], q: &[f64]) -> f64 {
    let mut ws = FwScratch::new(points.cols);
    dist_to_hull_into(points, hull_idx, q, &mut ws)
}

/// [`dist_to_hull`] with caller-owned scratch (the batch/selection inner
/// loop) — identical arithmetic, no per-query allocation.
fn dist_to_hull_into(points: &Mat, hull_idx: &[usize], q: &[f64], ws: &mut FwScratch) -> f64 {
    debug_assert!(!hull_idx.is_empty());
    let d = points.cols;
    // t₀ ← closest hull point to q
    {
        let mut best = f64::INFINITY;
        let mut best_row = hull_idx[0];
        for &i in hull_idx {
            let dist = sq_dist(points.row(i), q);
            if dist < best {
                best = dist;
                best_row = i;
            }
        }
        ws.t.copy_from_slice(points.row(best_row));
    }
    let t = &mut ws.t;
    let v = &mut ws.v;
    for _ in 0..FW_ITERS {
        // v ← q − t; p ← extremal hull point in direction v
        for k in 0..d {
            v[k] = q[k] - t[k];
        }
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 < 1e-24 {
            return 0.0;
        }
        let mut best_dot = f64::NEG_INFINITY;
        let mut best_row = hull_idx[0];
        for &i in hull_idx {
            let dot = dot(points.row(i), &v);
            if dot > best_dot {
                best_dot = dot;
                best_row = i;
            }
        }
        let p = points.row(best_row);
        // if p does not improve beyond t in direction v, t is optimal
        let t_dot = dot(&t, &v);
        if best_dot - t_dot <= 1e-14 * (1.0 + t_dot.abs()) {
            break;
        }
        // project q onto segment [t, p]
        let mut tp_norm2 = 0.0;
        let mut qt_dot_tp = 0.0;
        for k in 0..d {
            let tp = p[k] - t[k];
            tp_norm2 += tp * tp;
            qt_dot_tp += (q[k] - t[k]) * tp;
        }
        if tp_norm2 < 1e-300 {
            break;
        }
        let alpha = (qt_dot_tp / tp_norm2).clamp(0.0, 1.0);
        for k in 0..d {
            t[k] += alpha * (p[k] - t[k]);
        }
        if alpha == 0.0 {
            break;
        }
    }
    sq_dist(&t, q)
}

#[inline]
fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    let mut s = 0.0;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        s += d * d;
    }
    s
}

#[inline]
fn dot(a: &[f64], b: &[f64]) -> f64 {
    let mut s = 0.0;
    for i in 0..a.len() {
        s += a[i] * b[i];
    }
    s
}

/// Batched hull-distance queries: squared distance of every row of
/// `queries` to `conv(points[hull_idx])`. Rows are chunked across the
/// pool's workers (fixed `ROW_CHUNK` grid, disjoint output chunks) and
/// each worker amortizes one Frank–Wolfe scratch across its queries, so
/// the result is bit-identical to per-query [`dist_to_hull`] calls at
/// any thread count.
pub fn dist_to_hull_batch(
    points: &Mat,
    hull_idx: &[usize],
    queries: &Mat,
    pool: &Pool,
) -> Vec<f64> {
    assert!(!hull_idx.is_empty(), "hull must be non-empty");
    assert_eq!(points.cols, queries.cols, "query dimension mismatch");
    let mut out = vec![0.0; queries.rows];
    let items: Vec<&mut [f64]> = out.chunks_mut(ROW_CHUNK).collect();
    pool.for_items(items, |ci, chunk| {
        let lo = ci * ROW_CHUNK;
        let mut ws = FwScratch::new(points.cols);
        for (off, o) in chunk.iter_mut().enumerate() {
            *o = dist_to_hull_into(points, hull_idx, queries.row(lo + off), &mut ws);
        }
    });
    out
}

/// Directional support-point prefilter: the extremal row in each of
/// `n_dirs` random directions (plus ± coordinate directions). Every
/// returned index is a vertex of conv(points); for "mild" data this
/// covers the hull (DESIGN.md §2, paper §4 "mildness").
pub fn support_candidates(points: &Mat, n_dirs: usize, rng: &mut Rng) -> Vec<usize> {
    support_candidates_with(points, n_dirs, rng, &Pool::current())
}

/// [`support_candidates`] on an explicit pool: the point stream is
/// row-sharded; each shard keeps a private per-direction argmax and the
/// partials merge in fixed tree order with strict `>` (earlier rows win
/// ties), reproducing the serial scan bit for bit.
pub fn support_candidates_with(
    points: &Mat,
    n_dirs: usize,
    rng: &mut Rng,
    pool: &Pool,
) -> Vec<usize> {
    let d = points.cols;
    let mut dirs: Vec<Vec<f64>> = Vec::with_capacity(n_dirs + 2 * d);
    for k in 0..d {
        let mut e = vec![0.0; d];
        e[k] = 1.0;
        dirs.push(e.clone());
        e[k] = -1.0;
        dirs.push(e);
    }
    for _ in 0..n_dirs {
        let mut v: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let n = v.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-300);
        v.iter_mut().for_each(|x| *x /= n);
        dirs.push(v);
    }
    // one pass over the points with all directions resident in cache,
    // written as an axpy over the direction axis so LLVM vectorizes the
    // inner loop (the naive direction-outer order re-streams the whole
    // point set per direction — 270× the memory traffic; see
    // EXPERIMENTS.md §Perf L3-c).
    let ndirs = dirs.len();
    // dirs transposed: dirs_t[c][k] contiguous over k
    let mut dirs_t = vec![0.0f64; d * ndirs];
    for (k, dir) in dirs.iter().enumerate() {
        for c in 0..d {
            dirs_t[c * ndirs + k] = dir[c];
        }
    }
    let dirs_t = &dirs_t;
    let partials = pool.map_chunks(points.rows, ROW_CHUNK, |_, range| {
        let mut best_val = vec![f64::NEG_INFINITY; ndirs];
        let mut best_row = vec![0usize; ndirs];
        let mut dp = vec![0.0f64; ndirs];
        for i in range {
            let row = points.row(i);
            dp.iter_mut().for_each(|x| *x = 0.0);
            for c in 0..d {
                let rc = row[c];
                let dt = &dirs_t[c * ndirs..(c + 1) * ndirs];
                for k in 0..ndirs {
                    dp[k] += rc * dt[k];
                }
            }
            for k in 0..ndirs {
                if dp[k] > best_val[k] {
                    best_val[k] = dp[k];
                    best_row[k] = i;
                }
            }
        }
        (best_val, best_row)
    });
    let best_row = match tree_reduce(partials, |mut a, b| {
        for k in 0..ndirs {
            if b.0[k] > a.0[k] {
                a.0[k] = b.0[k];
                a.1[k] = b.1[k];
            }
        }
        a
    }) {
        Some((_, rows)) => rows,
        None => return Vec::new(),
    };
    let mut seen = std::collections::HashSet::new();
    let mut out = Vec::new();
    for &row in &best_row {
        if seen.insert(row) {
            out.push(row);
        }
    }
    out
}

/// Greedy sparse hull selection: returns up to `k` row indices of
/// `points` approximating its convex hull (Algorithm 2 outer loop).
pub fn select_hull_points(points: &Mat, k: usize, rng: &mut Rng) -> Vec<usize> {
    select_hull_points_with(points, k, rng, &Pool::current())
}

/// [`select_hull_points`] on an explicit pool.
///
/// PARALLEL LAZY GREEDY (see EXPERIMENTS.md §Perf L3-c): dist_to_hull
/// is non-increasing as the hull grows, so cached distances are upper
/// bounds. Candidates are split into fixed `SCAN_CHUNK` chunks; each
/// chunk walks its candidates in position order, skipping any whose
/// cached bound cannot beat the chunk's current best and refreshing the
/// rest against the CURRENT hull — the classic lazy-evaluation pruning,
/// now per chunk so the chunks are independent work items. Chunk
/// results merge through a fixed-shape tree-reduced argmax with strict
/// `>` (ties break to the lowest candidate position), so the selection
/// is **bit-identical for any thread count** — the RNG is consumed only
/// by the prefilter and the seed choice, identically on every path.
pub fn select_hull_points_with(
    points: &Mat,
    k: usize,
    rng: &mut Rng,
    pool: &Pool,
) -> Vec<usize> {
    let n = points.rows;
    if n == 0 || k == 0 {
        return Vec::new();
    }
    if k >= n {
        return (0..n).collect();
    }

    // prefilter candidates for large inputs
    let candidates: Vec<usize> = if n > 4096 {
        support_candidates_with(points, 256, rng, pool)
    } else {
        (0..n).collect()
    };

    // initialization per Algorithm 2: random a₀; every later point is
    // the farthest from the current approximate hull.
    let a0 = candidates[rng.usize(candidates.len())];
    let mut hull = vec![a0];

    // cached upper bounds on dist_to_hull, by candidate position
    let mut ub = vec![f64::INFINITY; candidates.len()];
    let n_chunks = candidates.len().div_ceil(SCAN_CHUNK);

    let target = k.min(candidates.len());
    while hull.len() < target {
        let mut round_best: Vec<(f64, usize)> =
            vec![(f64::NEG_INFINITY, usize::MAX); n_chunks];
        {
            let hull_ref = &hull;
            let cand = &candidates;
            let items: Vec<(&mut [f64], &mut (f64, usize))> = ub
                .chunks_mut(SCAN_CHUNK)
                .zip(round_best.iter_mut())
                .collect();
            pool.for_items(items, |ci, (ub_chunk, out)| {
                let lo = ci * SCAN_CHUNK;
                let mut ws = FwScratch::new(points.cols);
                let mut best = (f64::NEG_INFINITY, usize::MAX);
                for (off, ub_i) in ub_chunk.iter_mut().enumerate() {
                    if *ub_i <= best.0 {
                        continue; // cached bound cannot beat the chunk best
                    }
                    let pos = lo + off;
                    let fresh =
                        dist_to_hull_into(points, hull_ref, points.row(cand[pos]), &mut ws);
                    *ub_i = fresh;
                    if fresh > best.0 {
                        best = (fresh, pos);
                    }
                }
                *out = best;
            });
        }
        let (dist, pos) = tree_reduce(round_best, |a, b| if b.0 > a.0 { b } else { a })
            .unwrap_or((f64::NEG_INFINITY, usize::MAX));
        if pos == usize::MAX || dist <= 1e-20 {
            break; // hull fully captured (or no candidates left)
        }
        hull.push(candidates[pos]);
        // −∞ (not 0): the skip check `ub ≤ chunk best` prunes the
        // selected candidate unconditionally, even while the chunk best
        // is still 0 — saves one full re-projection per chunk per round
        ub[pos] = f64::NEG_INFINITY;
    }
    hull
}

/// Exact 2-D convex hull (Andrew's monotone chain) — used in tests as an
/// oracle for the greedy approximation.
pub fn exact_hull_2d(points: &Mat) -> Vec<usize> {
    assert_eq!(points.cols, 2);
    let n = points.rows;
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| {
        let (pa, pb) = (points.row(a), points.row(b));
        // total_cmp: NaN coordinates sort deterministically instead of
        // panicking the comparator
        pa[0].total_cmp(&pb[0]).then(pa[1].total_cmp(&pb[1]))
    });
    let cross = |o: &[f64], a: &[f64], b: &[f64]| -> f64 {
        (a[0] - o[0]) * (b[1] - o[1]) - (a[1] - o[1]) * (b[0] - o[0])
    };
    let mut hull: Vec<usize> = Vec::new();
    // lower
    for &i in &idx {
        while hull.len() >= 2 {
            let o = points.row(hull[hull.len() - 2]);
            let a = points.row(hull[hull.len() - 1]);
            if cross(o, a, points.row(i)) <= 0.0 {
                hull.pop();
            } else {
                break;
            }
        }
        hull.push(i);
    }
    // upper
    let lower_len = hull.len() + 1;
    for &i in idx.iter().rev() {
        while hull.len() >= lower_len {
            let o = points.row(hull[hull.len() - 2]);
            let a = points.row(hull[hull.len() - 1]);
            if cross(o, a, points.row(i)) <= 0.0 {
                hull.pop();
            } else {
                break;
            }
        }
        hull.push(i);
    }
    hull.pop();
    hull.sort_unstable();
    hull.dedup();
    hull
}

#[cfg(test)]
mod tests {
    use super::*;

    fn square_with_interior() -> Mat {
        // 4 corners + interior points
        Mat::from_rows(&[
            vec![0.0, 0.0],
            vec![1.0, 0.0],
            vec![1.0, 1.0],
            vec![0.0, 1.0],
            vec![0.5, 0.5],
            vec![0.3, 0.7],
            vec![0.6, 0.2],
        ])
    }

    #[test]
    fn dist_zero_for_hull_member() {
        let pts = square_with_interior();
        let hull = vec![0, 1, 2, 3];
        for &i in &hull {
            assert!(dist_to_hull(&pts, &hull, pts.row(i)) < 1e-12);
        }
    }

    #[test]
    fn dist_zero_for_interior_point() {
        let pts = square_with_interior();
        let hull = vec![0, 1, 2, 3];
        assert!(dist_to_hull(&pts, &hull, &[0.5, 0.5]) < 1e-6);
        assert!(dist_to_hull(&pts, &hull, &[0.9, 0.1]) < 1e-6);
    }

    #[test]
    fn dist_positive_for_exterior_point() {
        let pts = square_with_interior();
        let hull = vec![0, 1, 2, 3];
        let d = dist_to_hull(&pts, &hull, &[2.0, 0.5]);
        assert!((d - 1.0).abs() < 1e-6, "sq dist {d}");
    }

    #[test]
    fn greedy_recovers_square_corners() {
        let pts = square_with_interior();
        let mut rng = Rng::new(31);
        let sel = select_hull_points(&pts, 4, &mut rng);
        let mut sorted = sel.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3], "got {sel:?}");
    }

    #[test]
    fn greedy_covers_exact_hull_2d() {
        let mut rng = Rng::new(33);
        let mut rows = Vec::new();
        for _ in 0..300 {
            rows.push(vec![rng.normal(), rng.normal()]);
        }
        let pts = Mat::from_rows(&rows);
        let exact = exact_hull_2d(&pts);
        let sel = select_hull_points(&pts, exact.len() + 5, &mut rng);
        // every exact-hull vertex must be within tiny distance of the
        // selected hull
        for &v in &exact {
            let d = dist_to_hull(&pts, &sel, pts.row(v));
            assert!(d < 0.05, "vertex {v} distance {d}");
        }
    }

    #[test]
    fn support_candidates_are_vertices() {
        let mut rng = Rng::new(35);
        let mut rows = Vec::new();
        for _ in 0..500 {
            rows.push(vec![rng.normal(), rng.normal()]);
        }
        let pts = Mat::from_rows(&rows);
        let exact: std::collections::HashSet<usize> =
            exact_hull_2d(&pts).into_iter().collect();
        let cands = support_candidates(&pts, 64, &mut rng);
        for &c in &cands {
            assert!(exact.contains(&c), "candidate {c} not a hull vertex");
        }
    }

    #[test]
    fn exact_hull_square() {
        let pts = square_with_interior();
        assert_eq!(exact_hull_2d(&pts), vec![0, 1, 2, 3]);
    }

    #[test]
    fn select_handles_degenerate_inputs() {
        // all-identical points
        let rows: Vec<Vec<f64>> = (0..10).map(|_| vec![1.0, 1.0]).collect();
        let pts = Mat::from_rows(&rows);
        let mut rng = Rng::new(36);
        let sel = select_hull_points(&pts, 5, &mut rng);
        assert!(!sel.is_empty() && sel.len() <= 5);
        // k ≥ n returns everything
        let pts2 = Mat::from_rows(&[vec![0.0, 0.0], vec![1.0, 0.0]]);
        assert_eq!(select_hull_points(&pts2, 10, &mut rng).len(), 2);
    }
}
