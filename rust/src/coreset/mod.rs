//! The paper's contribution: coreset constructions for MCTMs.
//!
//! * `leverage` — ℓ₂ leverage scores of the paper's block matrix B
//!   (computed through the equivalent stacked matrix, see DESIGN.md §2),
//!   plus ridge and root variants used as real-data baselines.
//! * `hull` — sparse convex-hull approximation (Blum, Har-Peled &
//!   Raichel 2019, paper Algorithm 2) over the derivative points a'.
//! * `samplers` — Algorithm 1: the hybrid ℓ₂-hull construction and all
//!   baselines behind one `Method` enum.
//! * `merge_reduce` — the streaming / distributed composition (§4).
//! * `ellipsoid` — John-ellipsoid scores (§4 extension for non-Gaussian
//!   log-concave copulas, Tukan et al. 2020).

pub mod ellipsoid;
pub mod hull;
pub mod leverage;
pub mod merge_reduce;
pub mod samplers;

pub use samplers::{build_coreset, build_coreset_with, Coreset, Method};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::basis::Design;
    use crate::linalg::Mat;
    use crate::util::rng::Rng;

    #[test]
    fn all_methods_produce_valid_coresets() {
        let mut rng = Rng::new(77);
        let data = Mat::from_vec(500, 2, (0..1000).map(|_| rng.normal()).collect());
        let design = Design::build(&data, 5, 0.01);
        for method in [
            Method::Uniform,
            Method::L2Only,
            Method::L2Hull,
            Method::RidgeLss,
            Method::RootL2,
        ] {
            let cs = build_coreset(&design, method, 40, &mut rng);
            assert!(!cs.indices.is_empty(), "{method:?} empty");
            assert!(cs.indices.len() <= 40 + 5, "{method:?} oversize");
            assert_eq!(cs.indices.len(), cs.weights.len());
            assert!(cs.weights.iter().all(|&w| w > 0.0), "{method:?} weights");
            assert!(cs.indices.iter().all(|&i| i < 500), "{method:?} range");
        }
    }
}
