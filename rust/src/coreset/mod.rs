//! The paper's contribution: coreset constructions for MCTMs.
//!
//! * `leverage` — ℓ₂ leverage scores of the paper's block matrix B
//!   (computed through the equivalent stacked matrix, see DESIGN.md §2),
//!   plus ridge and root variants used as real-data baselines.
//! * `hull` — sparse convex-hull approximation (Blum, Har-Peled &
//!   Raichel 2019, paper Algorithm 2) over the derivative points a'.
//! * `ellipsoid` — John-ellipsoid rounding + quadratic-form scores
//!   (§4 extension for non-Gaussian log-concave copulas, Tukan et al.
//!   2020).
//! * `strategy` — the sampling-strategy layer: a [`ScoreStrategy`]
//!   trait (uniform/ℓ₂/ridge/root/ellipsoid score families), a generic
//!   hybrid sampler composing any score family with the hull component
//!   under Algorithm 1's α = 0.8 split, and the string-keyed registry
//!   that config, CLI, pipeline, merge-reduce and the benches all
//!   dispatch through. `l2-hull` and `ellipsoid-hull` are two instances
//!   of the same hybrid.
//! * `samplers` — the `Method` tags and the crate-internal
//!   `build_coreset_on` construction. The public front door is the
//!   facade (`mctm_coreset::prelude::SessionBuilder`); the pre-0.3
//!   deprecated free-function shims have been removed.
//! * `merge_reduce` — the streaming / distributed composition (§4);
//!   per-method behaviour is dispatched through `strategy`, so every
//!   registered method streams end to end.

pub mod ellipsoid;
pub mod hull;
pub mod leverage;
pub mod merge_reduce;
pub mod samplers;
pub mod strategy;

pub use samplers::{Coreset, Method};
pub use strategy::{MethodSampler, ScoreStrategy};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::basis::Design;
    use crate::linalg::Mat;
    use crate::util::parallel::Pool;
    use crate::util::rng::Rng;

    #[test]
    fn all_methods_produce_valid_coresets() {
        let mut rng = Rng::new(77);
        let data = Mat::from_vec(500, 2, (0..1000).map(|_| rng.normal()).collect());
        let design = Design::build(&data, 5, 0.01);
        // registry-driven: new strategies (the ellipsoid pair included)
        // are covered here automatically, no hand-kept list
        let sink = crate::util::degrade::DegradeSink::new();
        for method in Method::all() {
            let cs = samplers::build_coreset_on(
                &design,
                method,
                40,
                &mut rng,
                &Pool::current(),
                &sink,
            );
            assert!(!cs.indices.is_empty(), "{method:?} empty");
            assert!(cs.indices.len() <= 40 + 5, "{method:?} oversize");
            assert_eq!(cs.indices.len(), cs.weights.len());
            assert!(cs.weights.iter().all(|&w| w > 0.0), "{method:?} weights");
            assert!(cs.indices.iter().all(|&i| i < 500), "{method:?} range");
            assert_eq!(cs.method, method, "{method:?} tag");
        }
    }
}
