//! Merge & Reduce composition of coresets for streaming / distributed
//! data (paper §4, "Data streams and distributed data"; Geppert et al.
//! 2020): coresets of shards are merged pairwise up a binary tree and
//! re-reduced, so n insertions need O(log(n/B)) levels and working
//! memory O(k·log(n/B)).
//!
//! Each shard keeps its raw rows + weights (a weighted sub-design), so
//! the reduce step can recompute sensitivity scores on the weighted
//! union — scores are recomputed *locally*, which upper-bounds the
//! global scores after reweighting (standard Merge & Reduce argument).
//!
//! Per-method behaviour (which scores, whether a hull budget is pinned)
//! is dispatched through the strategy registry (`coreset::strategy`),
//! so every registered method — including the §4 ellipsoid ones —
//! streams through this tree without this module naming any of them.

use super::samplers::Method;
use super::strategy;
use crate::anyhow;
use crate::basis::Design;
use crate::linalg::Mat;
use crate::util::degrade::DegradeSink;
use crate::util::error::Result;
use crate::util::parallel::Pool;
use crate::util::rng::Rng;

/// A weighted set of raw observations (rows on the original data scale).
#[derive(Clone, Debug)]
pub struct WeightedRows {
    pub rows: Mat,
    pub weights: Vec<f64>,
    /// Provenance: how many of these rows were pinned by the convex-hull
    /// component of the reduce that produced them. A fresh (raw) set has
    /// 0; [`reduce`] overwrites it with its own hull count (resampling
    /// invalidates older provenance); [`WeightedRows::merge`] adds,
    /// since concatenation keeps every row. This is what the facade's
    /// `CoresetReport.n_hull` reports on the streaming path.
    pub n_hull: usize,
}

impl WeightedRows {
    pub fn new(rows: Mat, weights: Vec<f64>) -> Self {
        assert_eq!(rows.rows, weights.len());
        WeightedRows { rows, weights, n_hull: 0 }
    }

    pub fn len(&self) -> usize {
        self.rows.rows
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Concatenate two weighted sets (the Merge step).
    pub fn merge(mut self, other: WeightedRows) -> WeightedRows {
        assert_eq!(self.rows.cols, other.rows.cols);
        self.rows.data.extend_from_slice(&other.rows.data);
        self.rows.rows += other.rows.rows;
        self.weights.extend_from_slice(&other.weights);
        self.n_hull += other.n_hull;
        self
    }
}

/// Reduce a weighted set to a coreset of ≤ k rows.
///
/// Prior weights enter the **score computation itself** via
/// `ScoreStrategy::weighted_scores` (ℓ₂ re-derives leverage under the
/// weighted Gram Σ w·b bᵀ; other families fall back to s_i·w_i — the
/// variance-optimal importance design for a weighted sum), and the new
/// weight w_i/(k₁·p_i) keeps the estimator unbiased for any positive
/// score choice: E[Σ ŵ f] = Σ w_i f_i.
pub fn reduce(
    set: &WeightedRows,
    method: Method,
    k: usize,
    d: usize,
    eps: f64,
    rng: &mut Rng,
    sink: &DegradeSink,
) -> Result<WeightedRows> {
    reduce_with(set, method, k, d, eps, rng, &Pool::current(), sink)
}

/// [`reduce`] on an explicit pool: callers that already fan out (the
/// streaming consumers) pass `Pool::new(1)` so the basis/leverage
/// kernels inside don't nest another layer of worker threads.
///
/// `Err` is reserved for unrecoverable numerical states (a sampling
/// distribution that stays non-finite after every fallback); ordinary
/// score failures degrade to weighted-uniform sampling and are recorded
/// into `sink` instead.
#[allow(clippy::too_many_arguments)]
pub fn reduce_with(
    set: &WeightedRows,
    method: Method,
    k: usize,
    d: usize,
    eps: f64,
    rng: &mut Rng,
    pool: &Pool,
    sink: &DegradeSink,
) -> Result<WeightedRows> {
    if set.len() <= k {
        return Ok(set.clone());
    }
    let design = Design::build_on(&set.rows, d, eps, pool);
    let n = set.len();

    // per-row scores and hull budget via the strategy registry — the
    // reduce step works unchanged for ANY registered method. The prior
    // weights feed the score computation itself (ℓ₂ re-derives leverage
    // under the weighted Gram; other families multiply scores by w),
    // and the returned scores already include the weight factor, so
    // they ARE the sampling probabilities up to normalization.
    let sampler = strategy::sampler(method);
    let sens = sampler.reduce_scores(&design, &set.weights, pool, sink);
    let hull_budget = match sampler.hull_fraction() {
        Some(frac) => (frac * k as f64).ceil() as usize,
        None => 0,
    };

    // hull points are kept EXACTLY (with their prior weights); the
    // sampled part then represents only the complement's mass —
    // otherwise the hull mass is double-counted and the estimator is
    // biased upward by Σ_H w (found as a systematic +10..35% f₁ bias in
    // the streaming pipeline; see EXPERIMENTS.md §Perf notes).
    let mut hull_set: std::collections::HashSet<usize> = Default::default();
    if hull_budget > 0 {
        let dp = design.deriv_points();
        for p in crate::coreset::hull::select_hull_points_with(&dp, hull_budget, rng, pool) {
            hull_set.insert(p / design.j);
        }
    }
    let k1 = k.saturating_sub(hull_set.len()).max(1);

    // weighted importance sample over the complement (the weight factor
    // is already inside `sens` — see MethodSampler::reduce_scores)
    let mut scaled: Vec<f64> = (0..n)
        .map(|i| if hull_set.contains(&i) { 0.0 } else { sens[i] })
        .collect();
    // a score vector the strategy layer could not keep finite and
    // non-negative degrades to weighted-uniform; if even the prior
    // weights are non-finite there is nothing sound to sample from
    if scaled.iter().any(|x| !x.is_finite() || *x < 0.0) {
        sink.score_fallback();
        for (i, s) in scaled.iter_mut().enumerate() {
            *s = if hull_set.contains(&i) {
                0.0
            } else {
                set.weights[i].max(0.0)
            };
        }
        if scaled.iter().any(|x| !x.is_finite()) {
            return Err(anyhow!(
                "reduce step: non-finite prior weights, cannot build a sampling distribution"
            ));
        }
    }
    // sort for determinism: HashSet order varies per process, and the
    // row order feeds the next level's RNG-driven sampling
    let mut indices: Vec<usize> = hull_set.iter().cloned().collect();
    indices.sort_unstable();
    let mut weights: Vec<f64> = indices.iter().map(|&i| set.weights[i]).collect();
    if scaled.iter().any(|&x| x > 0.0) {
        let table = crate::util::rng::AliasTable::new(&scaled);
        for _ in 0..k1 {
            let i = table.sample(rng);
            indices.push(i);
            weights.push(set.weights[i] / (k1 as f64 * table.p(i)));
        }
    }
    let rows = set.rows.select_rows(&indices);
    let mut out = WeightedRows::new(rows, weights);
    // fresh provenance: the hull points this reduce pinned exactly (the
    // resampled complement replaces any earlier provenance)
    out.n_hull = hull_set.len();
    Ok(out)
}

/// Merge & Reduce accumulator: push shards, get the final coreset.
pub struct MergeReduce {
    /// `buckets[l]` holds at most one reduced set per tree level l
    buckets: Vec<Option<WeightedRows>>,
    pub method: Method,
    pub k: usize,
    pub d: usize,
    pub eps: f64,
    rng: Rng,
    pub n_seen: usize,
    pub n_reduces: usize,
    /// intermediate-level size multiplier (accuracy vs memory)
    pub buffer_factor: usize,
    /// pool for the kernels inside this accumulator's reduces; callers
    /// that fan out around the accumulator (the streaming pipeline)
    /// set `Pool::new(1)` so reducer-side merges don't pile a second
    /// layer of workers on top of busy consumer threads
    pub pool: Pool,
    /// degradation accounting for every reduce this accumulator runs;
    /// the streaming pipeline hands in the run's shared sink
    pub sink: DegradeSink,
}

impl MergeReduce {
    pub fn new(method: Method, k: usize, d: usize, eps: f64, seed: u64) -> Self {
        MergeReduce {
            buckets: Vec::new(),
            method,
            k,
            d,
            eps,
            rng: Rng::new(seed),
            n_seen: 0,
            n_reduces: 0,
            buffer_factor: 4,
            pool: Pool::current(),
            sink: DegradeSink::new(),
        }
    }

    /// Intermediate-level coreset size: levels keep `buffer_factor`·k
    /// rows so the resampling error of the tree does not compound (the
    /// standard Merge & Reduce accuracy/memory trade-off); only
    /// `finish()` reduces to the final k.
    fn k_buffer(&self) -> usize {
        self.buffer_factor * self.k
    }

    /// Insert one shard of raw rows (weight 1 each).
    pub fn push_shard(&mut self, rows: Mat) -> Result<()> {
        let n_raw = rows.rows;
        let w = vec![1.0; n_raw];
        let leaf = reduce_with(
            &WeightedRows::new(rows, w),
            self.method,
            self.k_buffer(),
            self.d,
            self.eps,
            &mut self.rng,
            &self.pool,
            &self.sink,
        )?;
        self.push_reduced(leaf, n_raw)
    }

    /// Insert a shard that was already leaf-reduced (to `k_buffer()`
    /// rows) elsewhere — the entry point for the parallel streaming
    /// consumers, which run the leaf reduce on worker threads with
    /// per-shard RNGs and hand the results back in shard order.
    /// `n_raw` is the raw row count the leaf represents.
    pub fn push_reduced(&mut self, leaf: WeightedRows, n_raw: usize) -> Result<()> {
        self.n_seen += n_raw;
        let mut carry = leaf;
        self.n_reduces += 1;
        let mut level = 0usize;
        loop {
            if level == self.buckets.len() {
                self.buckets.push(Some(carry));
                break;
            }
            match self.buckets[level].take() {
                None => {
                    self.buckets[level] = Some(carry);
                    break;
                }
                Some(existing) => {
                    let merged = existing.merge(carry);
                    carry = reduce_with(
                        &merged,
                        self.method,
                        self.k_buffer(),
                        self.d,
                        self.eps,
                        &mut self.rng,
                        &self.pool,
                        &self.sink,
                    )?;
                    self.n_reduces += 1;
                    level += 1;
                }
            }
        }
        Ok(())
    }

    /// Collapse all levels into the final coreset (≤ k rows).
    pub fn finish(mut self) -> Result<WeightedRows> {
        let mut acc: Option<WeightedRows> = None;
        for b in self.buckets.drain(..).flatten() {
            acc = Some(match acc {
                None => b,
                Some(a) => a.merge(b),
            });
        }
        let acc = acc.unwrap_or_else(|| WeightedRows::new(Mat::zeros(0, 0), vec![]));
        if acc.len() > self.k {
            reduce_with(
                &acc,
                self.method,
                self.k,
                self.d,
                self.eps,
                &mut self.rng,
                &self.pool,
                &self.sink,
            )
        } else {
            Ok(acc)
        }
    }

    /// Number of active tree levels (memory diagnostic).
    pub fn levels(&self) -> usize {
        self.buckets.iter().filter(|b| b.is_some()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn random_rows(n: usize, j: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        Mat::from_vec(n, j, (0..n * j).map(|_| rng.normal()).collect())
    }

    #[test]
    fn final_size_bounded() {
        let mut mr = MergeReduce::new(Method::L2Hull, 50, 5, 0.01, 1);
        for s in 0..8 {
            mr.push_shard(random_rows(400, 2, 100 + s)).unwrap();
        }
        assert_eq!(mr.n_seen, 3200);
        let out = mr.finish().unwrap();
        assert!(out.len() <= 50, "final size {}", out.len());
        assert!(out.len() > 10);
    }

    #[test]
    fn total_weight_tracks_n() {
        let mut mr = MergeReduce::new(Method::L2Only, 60, 5, 0.01, 2);
        for s in 0..4 {
            mr.push_shard(random_rows(500, 2, 200 + s)).unwrap();
        }
        let out = mr.finish().unwrap();
        let total: f64 = out.weights.iter().sum();
        // unbiased in expectation; tree depth adds variance
        assert!(
            total > 600.0 && total < 6000.0,
            "total weight {total} should be near 2000"
        );
    }

    #[test]
    fn levels_grow_logarithmically() {
        let mut mr = MergeReduce::new(Method::Uniform, 30, 5, 0.01, 3);
        for s in 0..16 {
            mr.push_shard(random_rows(100, 2, 300 + s)).unwrap();
        }
        // 16 shards → tree of depth log2(16)+1 = 5 max
        assert!(mr.levels() <= 5, "levels {}", mr.levels());
    }

    #[test]
    fn small_stream_passes_through() {
        let mut mr = MergeReduce::new(Method::L2Hull, 100, 5, 0.01, 4);
        mr.push_shard(random_rows(40, 2, 5)).unwrap();
        let out = mr.finish().unwrap();
        assert_eq!(out.len(), 40);
        assert!(out.weights.iter().all(|&w| w == 1.0));
        // nothing was reduced, so nothing carries hull provenance
        assert_eq!(out.n_hull, 0);
    }

    #[test]
    fn hull_provenance_threads_through_reduces() {
        // hull methods report a non-zero hull-pinned count after a real
        // reduce; score-only methods stay at zero
        let mut mr = MergeReduce::new(Method::L2Hull, 40, 5, 0.01, 6);
        for s in 0..6 {
            mr.push_shard(random_rows(400, 2, 400 + s)).unwrap();
        }
        let out = mr.finish().unwrap();
        assert!(out.len() <= 40);
        assert!(out.n_hull > 0, "hull reduce lost its provenance");
        assert!(out.n_hull <= out.len());

        let mut plain = MergeReduce::new(Method::L2Only, 40, 5, 0.01, 6);
        for s in 0..6 {
            plain.push_shard(random_rows(400, 2, 500 + s)).unwrap();
        }
        assert_eq!(plain.finish().unwrap().n_hull, 0);

        // merge adds provenance counts; reduce replaces them
        let a = {
            let mut w = WeightedRows::new(random_rows(10, 2, 9), vec![1.0; 10]);
            w.n_hull = 3;
            w
        };
        let b = {
            let mut w = WeightedRows::new(random_rows(10, 2, 10), vec![1.0; 10]);
            w.n_hull = 2;
            w
        };
        assert_eq!(a.merge(b).n_hull, 5);
    }
}
