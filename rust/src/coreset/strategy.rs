//! The sampling-strategy layer (ISSUE 3): every coreset method in the
//! system — score computation, budgeted sampling, Merge & Reduce
//! behaviour, CLI/config name — flows through the string-keyed registry
//! in this module. It replaces the closed `match`-on-`Method` dispatch
//! that used to be copy-pasted across config, CLI, pipeline,
//! merge-reduce and the benches.
//!
//! Two traits split the concerns the way Huggins et al. ("Coresets for
//! Scalable Bayesian Logistic Regression") separate them:
//!
//! * [`ScoreStrategy`] — a per-observation sensitivity score family
//!   (ℓ₂ leverage, ridge, root, John-ellipsoid). Pure function of the
//!   design; no randomness.
//! * [`MethodSampler`] — how a budgeted coreset is drawn from those
//!   scores, and how a weighted Merge & Reduce `reduce` step scores and
//!   splits its budget. [`HybridSampler`] composes any score strategy
//!   with the convex-hull component under Algorithm 1's α-split, so
//!   `l2-hull` is one instance and `ellipsoid-hull` comes for free.
//!
//! Every implementation must be **deterministic given (design, rng)** —
//! independent of the worker-pool width — so streaming coresets stay
//! bit-identical at any thread/consumer count (pinned by
//! `tests/coreset_invariants.rs` and `tests/pipeline_e2e.rs`).
//!
//! Adding a method = one `Method` tag + one [`REGISTRY`] row. Nothing
//! else in the codebase enumerates methods by hand.

use super::ellipsoid::ellipsoid_scores_sink;
use super::hull::select_hull_points_with;
use super::leverage::{
    default_ridge_with, leverage_scores_ridged_sink, mctm_leverage_scores_sink,
    sensitivity_scores_sink, weighted_mctm_leverage_scores_sink,
};
use super::samplers::{Coreset, Method, HULL_SPLIT};
use crate::basis::Design;
use crate::linalg::LinalgError;
use crate::util::degrade::DegradeSink;
use crate::util::parallel::Pool;
use crate::util::rng::{AliasTable, Rng};

/// Khachiyan rounding tolerance for the ellipsoid strategies: the
/// (1+ε)-approximate MVEE of the stacked design rows.
pub const ELLIPSOID_EPS: f64 = 0.05;

/// A per-observation sensitivity-score family.
///
/// `Err` means the design is degenerate for this family (rank-deficient
/// Gram, too few rows for the ellipsoid lift, …); samplers fall back to
/// uniform, mirroring the robustness of the reference implementation.
pub trait ScoreStrategy: Sync {
    /// Short key naming the score family (diagnostics / bench labels).
    fn key(&self) -> &'static str;

    /// Per-observation sampling scores (higher ⇒ more likely kept).
    /// Numerical fallbacks taken along the way (ridge-ladder Gram
    /// recoveries, MVEE non-convergence, …) are recorded into `sink`.
    fn scores(
        &self,
        design: &Design,
        pool: &Pool,
        sink: &DegradeSink,
    ) -> Result<Vec<f64>, LinalgError>;

    /// Per-observation sampling scores under **prior row weights** —
    /// the Merge & Reduce reduce step feeds each row's accumulated
    /// weight in, so the score computation itself can see the mass it
    /// represents (ROADMAP PR-3 follow-up). The returned scores INCLUDE
    /// the weight factor: the reduce samples with `p_i ∝ weighted_scores[i]`
    /// directly.
    ///
    /// Default: `scores(design) · w` — exactly the pre-PR-4 behaviour
    /// (weights enter only the sampling probabilities), and bit-identical
    /// to it for any weights. Families that can do better (ℓ₂ leverage
    /// re-derives the Gram under the weights) override this; with
    /// w ≡ 1 every implementation MUST reproduce `scores` bit for bit,
    /// which keeps the unweighted call sites and the streaming
    /// determinism pins unchanged.
    fn weighted_scores(
        &self,
        design: &Design,
        weights: &[f64],
        pool: &Pool,
        sink: &DegradeSink,
    ) -> Result<Vec<f64>, LinalgError> {
        let scores = self.scores(design, pool, sink)?;
        Ok(scores.iter().zip(weights).map(|(s, w)| s * w).collect())
    }
}

/// ℓ₂ sensitivity proxy s_i = u_i + 1/n (paper Lemmas 2.1/2.2).
pub struct L2Sensitivity;

impl ScoreStrategy for L2Sensitivity {
    fn key(&self) -> &'static str {
        "l2"
    }

    fn scores(
        &self,
        design: &Design,
        pool: &Pool,
        sink: &DegradeSink,
    ) -> Result<Vec<f64>, LinalgError> {
        sensitivity_scores_sink(design, pool, sink)
    }

    /// Weighted ℓ₂ sensitivities: leverage of the √w-scaled stacked
    /// rows — i.e. w_i·b_iᵀ(Σ w b bᵀ)⁻¹b_i, the exact sensitivity of
    /// the weighted sum — plus the weighted uniform term w_i/n.
    /// Computed plane-direct (√w scaling happens while gathering rows
    /// from the basis planes), so the streaming Merge & Reduce reduces
    /// that call this per shard no longer materialize an n × dJ
    /// stacked matrix. With w ≡ 1 the row scaling multiplies by 1.0
    /// (bit-exact identity), so this reproduces `scores` to the bit,
    /// as the trait requires.
    fn weighted_scores(
        &self,
        design: &Design,
        weights: &[f64],
        pool: &Pool,
        sink: &DegradeSink,
    ) -> Result<Vec<f64>, LinalgError> {
        let u = weighted_mctm_leverage_scores_sink(design, weights, pool, sink)?;
        let n = design.n as f64;
        Ok(u.iter()
            .zip(weights)
            .map(|(ui, wi)| ui + wi * (1.0 / n))
            .collect())
    }
}

/// Ridge leverage scores u_i(γ) + 1/n (Table 2 baseline).
pub struct RidgeLeverage;

impl ScoreStrategy for RidgeLeverage {
    fn key(&self) -> &'static str {
        "ridge"
    }

    fn scores(
        &self,
        design: &Design,
        pool: &Pool,
        sink: &DegradeSink,
    ) -> Result<Vec<f64>, LinalgError> {
        let stacked = design.stacked();
        let gamma = default_ridge_with(&stacked, pool);
        let mut u = leverage_scores_ridged_sink(&stacked, gamma, pool, sink)?;
        let unif = 1.0 / design.n as f64;
        u.iter_mut().for_each(|x| *x += unif);
        Ok(u)
    }
}

/// Root leverage scores p_i ∝ √u_i + 1/n (Table 2 baseline).
pub struct RootLeverage;

impl ScoreStrategy for RootLeverage {
    fn key(&self) -> &'static str {
        "root"
    }

    fn scores(
        &self,
        design: &Design,
        pool: &Pool,
        sink: &DegradeSink,
    ) -> Result<Vec<f64>, LinalgError> {
        let u = mctm_leverage_scores_sink(design, pool, sink)?;
        let n = design.n as f64;
        Ok(u.iter().map(|&x| x.max(0.0).sqrt() + 1.0 / n).collect())
    }
}

/// John-ellipsoid scores (paper §4, non-Gaussian log-concave copulas):
/// the quadratic form of the (1+ε)-MVEE of the stacked design rows,
/// normalized as q_iᵀM⁻¹q_i/(dJ+1) + 1/n — the Tukan et al. (2020)
/// replacement for Gram leverage when level sets are merely log-concave
/// rather than elliptical. Runs the parallel Khachiyan rounding of
/// `coreset::ellipsoid`, bit-identical at any pool width.
pub struct EllipsoidScores;

impl ScoreStrategy for EllipsoidScores {
    fn key(&self) -> &'static str {
        "ellipsoid"
    }

    fn scores(
        &self,
        design: &Design,
        pool: &Pool,
        sink: &DegradeSink,
    ) -> Result<Vec<f64>, LinalgError> {
        let stacked = design.stacked();
        // the Khachiyan lift needs strictly more rows than lifted
        // dimensions; shorter designs fall back to uniform upstream
        if stacked.rows <= stacked.cols + 1 {
            return Err(LinalgError::Dim(format!(
                "ellipsoid scores need n > dJ + 1 = {}, got n = {}",
                stacked.cols + 1,
                stacked.rows
            )));
        }
        Ok(ellipsoid_scores_sink(&stacked, ELLIPSOID_EPS, pool, sink))
    }
}

/// A registered sampling method: budgeted coreset draws plus the two
/// hooks the Merge & Reduce `reduce` step needs.
///
/// `sample` is called with `1 ≤ k < design.n` (the trivial `k ≥ n`
/// identity coreset is handled by `build_coreset_on`); `method` is the
/// registry tag recorded on the result (`Coreset::method`).
pub trait MethodSampler: Sync {
    /// Draw a coreset of target size `k`. Score failures degrade to
    /// uniform sampling; every such fallback (and every numerical
    /// recovery inside the score computation) is recorded into `sink`.
    fn sample(
        &self,
        design: &Design,
        method: Method,
        k: usize,
        rng: &mut Rng,
        pool: &Pool,
        sink: &DegradeSink,
    ) -> Coreset;

    /// Per-row sampling scores for the weighted reduce step
    /// (`merge_reduce`), INCLUDING the prior-weight factor: the reduce
    /// samples with `p_i ∝ reduce_scores[i]` and reweights by
    /// w_i/(k₁·p_i), which stays unbiased for any positive scores.
    /// `weights.len() == design.n`. Degenerate designs fall back to the
    /// weights themselves (≡ weighted-uniform), recorded into `sink`.
    fn reduce_scores(
        &self,
        design: &Design,
        weights: &[f64],
        pool: &Pool,
        sink: &DegradeSink,
    ) -> Vec<f64>;

    /// Fraction of the reduce budget pinned to convex-hull points
    /// (`None` for non-hybrid methods).
    fn hull_fraction(&self) -> Option<f64> {
        None
    }
}

/// Uniform subsampling without replacement, weights n/k — both the
/// baseline method and the fallback every hybrid degrades to when its
/// score computation fails.
pub struct UniformSampler;

impl MethodSampler for UniformSampler {
    fn sample(
        &self,
        design: &Design,
        method: Method,
        k: usize,
        rng: &mut Rng,
        _pool: &Pool,
        _sink: &DegradeSink,
    ) -> Coreset {
        let n = design.n;
        let indices = rng.sample_without_replacement(n, k);
        let w = n as f64 / k as f64;
        Coreset {
            weights: vec![w; indices.len()],
            indices,
            n_hull: 0,
            method,
        }
    }

    fn reduce_scores(
        &self,
        _design: &Design,
        weights: &[f64],
        _pool: &Pool,
        _sink: &DegradeSink,
    ) -> Vec<f64> {
        // uniform over mass: p ∝ w (identical to the pre-weighted-score
        // behaviour, where all-ones scores were multiplied by w)
        weights.to_vec()
    }
}

/// The generic budgeted sampler behind every score-driven method:
/// importance sampling on a [`ScoreStrategy`], optionally composed with
/// Algorithm 1's convex-hull component under the α-budget split
/// (`split = Some(α)` spends ⌊α·k⌋ on the score sample and the rest on
/// hull points of the derivative cloud).
pub struct HybridSampler {
    pub scores: &'static dyn ScoreStrategy,
    pub split: Option<f64>,
}

impl MethodSampler for HybridSampler {
    fn sample(
        &self,
        design: &Design,
        method: Method,
        k: usize,
        rng: &mut Rng,
        pool: &Pool,
        sink: &DegradeSink,
    ) -> Coreset {
        let (k1, k2) = match self.split {
            Some(alpha) => {
                let k1 = ((alpha * k as f64).floor() as usize).clamp(1, k);
                (k1, k - k1)
            }
            None => (k, 0),
        };
        let mut cs = match self.scores.scores(design, pool, sink) {
            Ok(s) => importance_sample(&s, k1, rng, method, sink),
            Err(_) => {
                sink.score_fallback();
                UniformSampler.sample(design, method, k1, rng, pool, sink)
            }
        };
        if k2 > 0 {
            // hull over derivative points {a'_ij}: map point index
            // (i·J + j) back to observation index i
            let dp = design.deriv_points();
            let hull_pts = select_hull_points_with(&dp, k2, rng, pool);
            let mut seen: std::collections::HashSet<usize> =
                cs.indices.iter().cloned().collect();
            for p in hull_pts {
                let obs = p / design.j;
                if seen.insert(obs) {
                    cs.indices.push(obs);
                    cs.weights.push(1.0); // hull points get weight 1
                    cs.n_hull += 1;
                }
            }
        }
        cs
    }

    fn reduce_scores(
        &self,
        design: &Design,
        weights: &[f64],
        pool: &Pool,
        sink: &DegradeSink,
    ) -> Vec<f64> {
        self.scores
            .weighted_scores(design, weights, pool, sink)
            .unwrap_or_else(|_| {
                sink.score_fallback();
                weights.to_vec()
            })
    }

    fn hull_fraction(&self) -> Option<f64> {
        self.split.map(|alpha| 1.0 - alpha)
    }
}

/// Draw `k` i.i.d. indices with probabilities ∝ scores; weight 1/(k p).
///
/// A degenerate score vector (non-finite entries, negatives, or zero
/// total — e.g. after masked rows zeroed every observation) degrades to
/// uniform probabilities instead of panicking inside the alias-table
/// build; the fallback is recorded into `sink`.
fn importance_sample(
    scores: &[f64],
    k: usize,
    rng: &mut Rng,
    method: Method,
    sink: &DegradeSink,
) -> Coreset {
    let total: f64 = scores.iter().sum();
    let degenerate =
        !(total.is_finite() && total > 0.0) || scores.iter().any(|s| !s.is_finite() || *s < 0.0);
    if degenerate {
        sink.score_fallback();
        let n = scores.len();
        let mut indices = Vec::with_capacity(k);
        for _ in 0..k {
            indices.push(rng.usize(n));
        }
        return Coreset {
            weights: vec![n as f64 / k as f64; k],
            indices,
            n_hull: 0,
            method,
        };
    }
    let table = AliasTable::new(scores);
    let mut indices = Vec::with_capacity(k);
    let mut weights = Vec::with_capacity(k);
    for _ in 0..k {
        let i = table.sample(rng);
        indices.push(i);
        weights.push(1.0 / (k as f64 * table.p(i)));
    }
    Coreset {
        indices,
        weights,
        n_hull: 0,
        method,
    }
}

/// One registry row: the `Method` tag, its canonical CLI/config name, a
/// one-line description (drives `--help` and the README table) and the
/// sampler implementing it.
pub struct StrategyEntry {
    pub method: Method,
    pub name: &'static str,
    pub describe: &'static str,
    pub sampler: &'static dyn MethodSampler,
}

static L2_HULL: HybridSampler = HybridSampler {
    scores: &L2Sensitivity,
    split: Some(HULL_SPLIT),
};
static L2_ONLY: HybridSampler = HybridSampler {
    scores: &L2Sensitivity,
    split: None,
};
static RIDGE_LSS: HybridSampler = HybridSampler {
    scores: &RidgeLeverage,
    split: None,
};
static ROOT_L2: HybridSampler = HybridSampler {
    scores: &RootLeverage,
    split: None,
};
static ELLIPSOID: HybridSampler = HybridSampler {
    scores: &EllipsoidScores,
    split: None,
};
static ELLIPSOID_HULL: HybridSampler = HybridSampler {
    scores: &EllipsoidScores,
    split: Some(HULL_SPLIT),
};
static UNIFORM: UniformSampler = UniformSampler;

/// The registry — the single source of truth for which methods exist.
/// Order is the order benches and tables iterate (`Method::all()`);
/// Uniform stays last because table drivers use the last row as the
/// baseline.
pub static REGISTRY: &[StrategyEntry] = &[
    StrategyEntry {
        method: Method::L2Hull,
        name: "l2-hull",
        describe: "Algorithm 1 hybrid: ℓ₂ sensitivity sample + convex hull of a' (α = 0.8)",
        sampler: &L2_HULL,
    },
    StrategyEntry {
        method: Method::L2Only,
        name: "l2-only",
        describe: "pure ℓ₂ leverage-score (sensitivity proxy) importance sampling",
        sampler: &L2_ONLY,
    },
    StrategyEntry {
        method: Method::RidgeLss,
        name: "ridge-lss",
        describe: "ridge leverage scores baseline (Table 2)",
        sampler: &RIDGE_LSS,
    },
    StrategyEntry {
        method: Method::RootL2,
        name: "root-l2",
        describe: "root leverage scores baseline: p_i ∝ √u_i",
        sampler: &ROOT_L2,
    },
    StrategyEntry {
        method: Method::Ellipsoid,
        name: "ellipsoid",
        describe: "John-ellipsoid quadratic-form scores (§4, non-Gaussian log-concave copulas)",
        sampler: &ELLIPSOID,
    },
    StrategyEntry {
        method: Method::EllipsoidHull,
        name: "ellipsoid-hull",
        describe: "ellipsoid scores + convex hull of a' under the α = 0.8 split",
        sampler: &ELLIPSOID_HULL,
    },
    StrategyEntry {
        method: Method::Uniform,
        name: "uniform",
        describe: "uniform subsampling without replacement, weights n/k",
        sampler: &UNIFORM,
    },
];

fn entry(method: Method) -> &'static StrategyEntry {
    // a Method variant without a REGISTRY row is a compile-time-adjacent
    // programming error (the registry test enumerates all_methods()),
    // not a runtime condition a caller could handle
    #[allow(clippy::expect_used)]
    REGISTRY
        .iter()
        .find(|e| e.method == method)
        .expect("method missing from strategy registry")
}

/// Registry-driven enumeration (replaces the hard-coded `[Method; 5]`).
pub fn all_methods() -> Vec<Method> {
    REGISTRY.iter().map(|e| e.method).collect()
}

/// Canonical CLI/config name of a method.
pub fn method_name(method: Method) -> &'static str {
    entry(method).name
}

/// One-line description of a method.
pub fn method_describe(method: Method) -> &'static str {
    entry(method).describe
}

/// All registered names, registry order.
pub fn method_names() -> Vec<&'static str> {
    REGISTRY.iter().map(|e| e.name).collect()
}

/// Parse a config/CLI method string. The error lists every valid name
/// so `--set method=typo` is self-explaining.
pub fn parse_method(name: &str) -> crate::util::error::Result<Method> {
    REGISTRY
        .iter()
        .find(|e| e.name == name)
        .map(|e| e.method)
        .ok_or_else(|| {
            crate::anyhow!(
                "unknown method `{name}` (valid: {})",
                method_names().join(", ")
            )
        })
}

/// The sampler behind a method tag — the system's only dispatch point.
pub fn sampler(method: Method) -> &'static dyn MethodSampler {
    entry(method).sampler
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    fn toy_design(n: usize, seed: u64) -> Design {
        let mut rng = Rng::new(seed);
        let data = Mat::from_vec(n, 2, (0..n * 2).map(|_| rng.normal()).collect());
        Design::build(&data, 5, 0.01)
    }

    #[test]
    fn registry_names_are_unique_and_roundtrip() {
        let names = method_names();
        let set: std::collections::HashSet<_> = names.iter().collect();
        assert_eq!(set.len(), names.len(), "duplicate registry names");
        for m in all_methods() {
            assert_eq!(parse_method(method_name(m)).unwrap(), m);
            assert!(!method_describe(m).is_empty());
        }
    }

    #[test]
    fn parse_error_lists_every_valid_name() {
        let err = parse_method("not-a-method").unwrap_err();
        let msg = format!("{err:#}");
        for name in method_names() {
            assert!(msg.contains(name), "error should list `{name}`: {msg}");
        }
    }

    #[test]
    fn uniform_is_last_for_table_baselines() {
        assert_eq!(all_methods().last(), Some(&Method::Uniform));
    }

    #[test]
    fn every_strategy_scores_a_healthy_design() {
        let design = toy_design(300, 5);
        let pool = Pool::new(1);
        for s in [
            &L2Sensitivity as &dyn ScoreStrategy,
            &RidgeLeverage,
            &RootLeverage,
            &EllipsoidScores,
        ] {
            let scores = s.scores(&design, &pool, &DegradeSink::new()).unwrap();
            assert_eq!(scores.len(), 300, "{} length", s.key());
            assert!(
                scores.iter().all(|&x| x.is_finite() && x > 0.0),
                "{} scores must be positive",
                s.key()
            );
        }
    }

    #[test]
    fn ellipsoid_rejects_short_designs() {
        // n = 8 ≤ dJ + 1 = 11 → Err, so samplers fall back to uniform
        let design = toy_design(8, 6);
        assert!(EllipsoidScores
            .scores(&design, &Pool::new(1), &DegradeSink::new())
            .is_err());
    }

    #[test]
    fn hull_fraction_complements_split() {
        assert_eq!(L2_ONLY.hull_fraction(), None);
        let f = L2_HULL.hull_fraction().unwrap();
        assert!((f - (1.0 - HULL_SPLIT)).abs() < 1e-15);
    }

    #[test]
    fn unit_weights_reproduce_unweighted_scores_bitwise() {
        // the contract every ScoreStrategy must honour: w ≡ 1 ⇒
        // weighted_scores == scores to the bit (keeps all unweighted
        // call sites and the streaming leaf reduces pinned)
        let design = toy_design(300, 7);
        let pool = Pool::new(1);
        let ones = vec![1.0; design.n];
        for s in [
            &L2Sensitivity as &dyn ScoreStrategy,
            &RidgeLeverage,
            &RootLeverage,
            &EllipsoidScores,
        ] {
            let sink = DegradeSink::new();
            let plain = s.scores(&design, &pool, &sink).unwrap();
            let weighted = s.weighted_scores(&design, &ones, &pool, &sink).unwrap();
            for (i, (a, b)) in plain.iter().zip(&weighted).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{} row {i}: {a} vs {b} under unit weights",
                    s.key()
                );
            }
        }
    }

    #[test]
    fn l2_weighted_scores_match_row_replication() {
        // weight 2 on a row ≈ duplicating it: the weighted sensitivity
        // of the doubled row must equal the SUM of the two duplicates'
        // unweighted sensitivities (leverage under the same Gram)
        let n = 200;
        let design = toy_design(n, 8);
        let pool = Pool::new(1);
        let mut w = vec![1.0; n];
        w[17] = 2.0;
        let weighted = L2Sensitivity
            .weighted_scores(&design, &w, &pool, &DegradeSink::new())
            .unwrap();

        // replicated design: row 17 appears twice
        let mut idx: Vec<usize> = (0..n).collect();
        idx.push(17);
        let dup = design.select(&idx);
        let dup_scores = L2Sensitivity.scores(&dup, &pool, &DegradeSink::new()).unwrap();
        // strip the uniform terms (1/n vs 1/(n+1) differ by design)
        let lhs = weighted[17] - 2.0 / n as f64;
        let rhs = (dup_scores[17] - 1.0 / (n + 1) as f64)
            + (dup_scores[n] - 1.0 / (n + 1) as f64);
        assert!(
            (lhs - rhs).abs() < 1e-8 * (1.0 + rhs.abs()),
            "weighted {lhs} vs replicated {rhs}"
        );
        // untouched rows keep leverage of the (slightly) reweighted Gram:
        // finite, positive, close to the replicated design's values
        for i in [0usize, 50, 199] {
            let a = weighted[i] - 1.0 / n as f64;
            let b = dup_scores[i] - 1.0 / (n + 1) as f64;
            assert!(
                (a - b).abs() < 1e-8 * (1.0 + b.abs()),
                "row {i}: {a} vs {b}"
            );
        }
    }

    #[test]
    fn reduce_scores_fall_back_to_weights() {
        // ellipsoid on a too-short design errs ⇒ the hybrid's reduce
        // scores degrade to the prior weights (weighted-uniform), never
        // to unweighted ones
        let design = toy_design(8, 9);
        let w: Vec<f64> = (0..8).map(|i| 1.0 + i as f64).collect();
        let sink = DegradeSink::new();
        let got = ELLIPSOID.reduce_scores(&design, &w, &Pool::new(1), &sink);
        assert_eq!(got, w);
        // the fallback is recorded, not silent
        assert_eq!(sink.snapshot().score_fallbacks, 1);
    }

    #[test]
    fn degenerate_scores_degrade_to_uniform_not_panic() {
        let sink = DegradeSink::new();
        let mut rng = Rng::new(11);
        // all-zero and NaN-bearing score vectors must not reach the
        // alias-table assertions
        for scores in [vec![0.0; 10], vec![1.0, f64::NAN, 1.0, 1.0]] {
            let cs = importance_sample(&scores, 4, &mut rng, Method::L2Only, &sink);
            assert_eq!(cs.indices.len(), 4);
            assert!(cs.indices.iter().all(|&i| i < scores.len()));
            assert!(cs.weights.iter().all(|w| w.is_finite() && *w > 0.0));
        }
        assert_eq!(sink.snapshot().score_fallbacks, 2);
    }
}
