//! Shared helpers for the `cargo bench` targets (hand-rolled harness —
//! `criterion` is unavailable offline; see DESIGN.md §5).
//!
//! The table drivers run every (method, k, rep) through the facade:
//! `TableRunner` → `run_method` → `SessionBuilder`/`Session::fit`, so
//! benches measure exactly the public entry point (PR 4).
//!
//! Scaling: benches honour `MCTM_BENCH_SCALE`:
//!   * `fast` — smallest sizes (CI smoke)
//!   * `paper` — the paper's full sizes (n=300k Covertype etc.)
//!   * anything else / unset — `default`, sized for a small container
//! Every bench prints the paper-style table AND writes CSV under
//! `results/`.

use crate::fit::FitOptions;
use crate::util::{median, Stopwatch};
use std::path::PathBuf;

/// Bench scale knob.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    Fast,
    Default,
    Paper,
}

impl Scale {
    pub fn from_env() -> Scale {
        match std::env::var("MCTM_BENCH_SCALE").as_deref() {
            Ok("fast") => Scale::Fast,
            Ok("paper") => Scale::Paper,
            _ => Scale::Default,
        }
    }

    /// Pick (fast, default, paper).
    pub fn pick<T: Copy>(&self, fast: T, default: T, paper: T) -> T {
        match self {
            Scale::Fast => fast,
            Scale::Default => default,
            Scale::Paper => paper,
        }
    }
}

/// Standard fit options for benches (bounded iterations so a bench run
/// has predictable duration).
pub fn bench_fit_options(scale: Scale) -> FitOptions {
    FitOptions {
        max_iters: scale.pick(60, 200, 400),
        ..Default::default()
    }
}

/// Methods a table bench compares: the paper's headline trio by
/// default. `MCTM_BENCH_METHODS=name,name,…` (registry names, baseline
/// last) overrides — e.g. `MCTM_BENCH_METHODS=ellipsoid-hull,ellipsoid,uniform`
/// reruns any table under the §4 ellipsoid strategies without touching
/// bench code.
pub fn bench_methods() -> Vec<crate::coreset::Method> {
    use crate::coreset::Method;
    match std::env::var("MCTM_BENCH_METHODS") {
        Ok(spec) => spec
            .split(',')
            .map(|name| {
                Method::parse(name.trim())
                    .unwrap_or_else(|e| panic!("MCTM_BENCH_METHODS: {e:#}"))
            })
            .collect(),
        Err(_) => vec![Method::L2Hull, Method::L2Only, Method::Uniform],
    }
}

/// Results directory.
pub fn results_dir() -> PathBuf {
    let p = PathBuf::from("results");
    let _ = std::fs::create_dir_all(&p);
    p
}

/// Median wall time (seconds) of `iters` runs of `f` after one warmup.
pub fn time_median<F: FnMut()>(iters: usize, mut f: F) -> f64 {
    f(); // warmup
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let sw = Stopwatch::start();
        f();
        times.push(sw.secs());
    }
    median(&times)
}

/// Pretty banner for bench output.
pub fn banner(name: &str, detail: &str) {
    println!("\n================================================================");
    println!("BENCH {name} — {detail}");
    println!("scale = {:?} (set MCTM_BENCH_SCALE=fast|paper to change)", Scale::from_env());
    println!(
        "threads = {} (set MCTM_THREADS=N to pin the worker count)",
        crate::util::parallel::threads()
    );
    println!("================================================================");
}

/// Shared driver for the simulation tables (Tables 1/3 at k=30, Table 4
/// at k=100): all 14 DGPs × {ℓ₂-hull, ℓ₂-only, uniform}.
pub fn run_sim_table(title: &str, k: usize, csv: &str) {
    use crate::coordinator::experiment::{summarize, TableRunner};
    use crate::data::dgp::Dgp;
    use crate::util::report::Table;
    use crate::util::rng::Rng;

    let scale = Scale::from_env();
    let n = scale.pick(1_000, 10_000, 10_000);
    let reps = scale.pick(2, 5, 10);
    let dgps: Vec<Dgp> = if scale == Scale::Fast {
        Dgp::table1().to_vec()
    } else {
        Dgp::all().to_vec()
    };
    let methods = bench_methods();
    banner(title, &format!("n={n}, k={k}, reps={reps}, {} DGPs", dgps.len()));

    let mut table = Table::new(
        title,
        &["DGP", "method", "theta L2", "lambda err", "LR", "impr(%)", "time(s)"],
    );
    for dgp in dgps {
        let mut rng = Rng::new(0xD6 ^ dgp.name().len() as u64);
        let data = dgp.generate(n, &mut rng);
        let runner = TableRunner::new(&data, 7, bench_fit_options(scale), 0xBEEF);
        let all: Vec<_> = methods.iter().map(|&m| runner.run(m, k, reps)).collect();
        // bench_methods() returns a fixed non-empty slice, so a missing
        // baseline is a harness bug worth a loud stop, not a user error
        #[allow(clippy::expect_used)]
        let baseline = all.last().expect("bench_methods is non-empty");
        for stats in &all {
            let mut row = vec![dgp.name().to_string()];
            row.extend(summarize(stats, baseline));
            table.row(row);
        }
        println!("  done {}", dgp.name());
    }
    table.emit(Some(&results_dir().join(csv)));
}

/// Shared driver for the equity tables (Tables 5/6): k sweep with all
/// three headline methods.
pub fn run_equity_table(title: &str, n_stocks: usize, csv: &str) {
    use crate::coordinator::experiment::{summarize, TableRunner};
    use crate::data::equity;
    use crate::util::report::Table;
    use crate::util::rng::Rng;

    let scale = Scale::from_env();
    let n = scale.pick(1_000, 10_000, 10_000);
    let reps = scale.pick(2, 3, 5);
    let ks: Vec<usize> = match scale {
        Scale::Fast => vec![50, 100],
        _ => vec![50, 100, 200, 300],
    };
    let methods = bench_methods();
    banner(title, &format!("{n_stocks} stocks, n={n} days, reps={reps}"));

    let mut rng = Rng::new(1985);
    let data = equity::generate(n, n_stocks, &mut rng);
    let runner = TableRunner::new(&data, 7, bench_fit_options(scale), 2025);
    println!(
        "  full fit: nll={:.2} iters={} time={:.1}s",
        runner.full.fit.nll, runner.full.fit.iters, runner.full.seconds
    );
    let mut table = Table::new(
        title,
        &["k", "method", "theta L2", "lambda err", "LR", "impr(%)", "time(s)"],
    );
    for &k in &ks {
        let all: Vec<_> = methods.iter().map(|&m| runner.run(m, k, reps)).collect();
        // bench_methods() returns a fixed non-empty slice, so a missing
        // baseline is a harness bug worth a loud stop, not a user error
        #[allow(clippy::expect_used)]
        let baseline = all.last().expect("bench_methods is non-empty");
        for stats in &all {
            let mut row = vec![format!("{k}")];
            row.extend(summarize(stats, baseline));
            table.row(row);
        }
        println!("  done k={k}");
    }
    table.emit(Some(&results_dir().join(csv)));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_pick() {
        assert_eq!(Scale::Fast.pick(1, 2, 3), 1);
        assert_eq!(Scale::Default.pick(1, 2, 3), 2);
        assert_eq!(Scale::Paper.pick(1, 2, 3), 3);
    }

    #[test]
    fn time_median_positive() {
        let t = time_median(3, || {
            std::hint::black_box((0..1000).sum::<usize>());
        });
        assert!(t >= 0.0);
    }
}
