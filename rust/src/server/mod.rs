//! The serving layer (ROADMAP item 1): fit once, serve forever.
//!
//! A [`ModelRegistry`] holds named, immutable [`FittedModel`]s behind
//! `Arc`s (the model is `Send + Sync`, so queries never lock anything
//! but the registry map itself). [`Server`] is a hand-rolled HTTP/1.1
//! front end on `std::net` — the crate is zero-dependency, so there is
//! no hyper/axum here, just a request-line parser, a bounded header
//! read, and thread-per-worker connection handling sized by
//! [`crate::util::parallel::threads`].
//!
//! Endpoints (GET only, JSON responses, `Connection: close`):
//!
//! | path | query | answer |
//! |------|-------|--------|
//! | `/health` | — | `{"status":"ok","models":N}` |
//! | `/metrics` | — | per-endpoint request counters |
//! | `/v1/models` | — | registered models + shape summary |
//! | `/v1/models/{name}/density` | `y=a,b,…` | joint log-density + density |
//! | `/v1/models/{name}/cdf` | `j=0&y=1.5` | marginal CDF |
//! | `/v1/models/{name}/quantile` | `j=0&p=0.5` | marginal quantile |
//! | `/v1/models/{name}/sample` | `n=10&seed=1` | joint draws |
//! | `/v1/models/{name}/conditional` | `given=a,b&n=5&seed=2` | conditional draws |
//!
//! Determinism: sampling endpoints take an explicit `seed` and build a
//! fresh [`Rng`] per request, so the same request returns the same
//! bytes no matter which worker serves it or how many requests ran
//! before. Floats render through Rust's shortest round-trip `Display`,
//! so a client that parses a JSON number back gets the exact bits the
//! model computed (non-finite values arrive as the strings `"NaN"`,
//! `"inf"`, `"-inf"` — JSON has no literals for them).
//!
//! Invalid queries (bad `p`, NaN `y`, wrong dimension) are HTTP 400
//! with the [`ApiError::Query`] message — the pinned edge semantics of
//! [`FittedModel::try_cdf`] / [`FittedModel::try_quantile`] mean a
//! malformed request can never panic a worker.

use crate::api::{ApiError, FittedModel};
use crate::util::parallel;
use crate::util::rng::Rng;
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::Duration;

/// Largest request head (request line + headers) the server reads.
const MAX_REQUEST_BYTES: usize = 8 * 1024;

/// Cap on `n` for the sampling endpoints — a serving guard, not a
/// model limit (one request must not allocate unbounded matrices).
const MAX_SAMPLES_PER_REQUEST: usize = 100_000;

/// Per-connection socket read timeout.
const READ_TIMEOUT: Duration = Duration::from_secs(5);

/// Named, shared, immutable fitted models. `insert` replaces; readers
/// clone the `Arc` out so queries run entirely outside the lock.
#[derive(Default)]
pub struct ModelRegistry {
    models: RwLock<HashMap<String, Arc<FittedModel>>>,
}

impl ModelRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register (or replace) a model under `name`.
    pub fn insert(&self, name: &str, model: FittedModel) {
        let mut map = write_lock(&self.models);
        map.insert(name.to_string(), Arc::new(model));
    }

    /// Shared handle to a registered model.
    pub fn get(&self, name: &str) -> Option<Arc<FittedModel>> {
        read_lock(&self.models).get(name).cloned()
    }

    /// Registered names, sorted (stable listings for clients & tests).
    pub fn names(&self) -> Vec<String> {
        let mut names: Vec<String> = read_lock(&self.models).keys().cloned().collect();
        names.sort();
        names
    }

    pub fn len(&self) -> usize {
        read_lock(&self.models).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Load every `*.mctm` model artifact in `dir`, registered under its
    /// file stem. Any unreadable/corrupt artifact is a typed error —
    /// a serving process must not come up with silently missing models.
    pub fn load_dir(&self, dir: &Path) -> Result<usize, ApiError> {
        let entries = std::fs::read_dir(dir)
            .map_err(|e| ApiError::Artifact(format!("reading {}: {e}", dir.display())))?;
        let mut loaded = 0;
        for entry in entries {
            let entry =
                entry.map_err(|e| ApiError::Artifact(format!("reading {}: {e}", dir.display())))?;
            let path = entry.path();
            if path.extension().and_then(|e| e.to_str()) != Some("mctm") {
                continue;
            }
            let name = path
                .file_stem()
                .and_then(|s| s.to_str())
                .ok_or_else(|| {
                    ApiError::Artifact(format!("{}: non-UTF-8 file stem", path.display()))
                })?
                .to_string();
            let model = FittedModel::load(&path)?;
            self.insert(&name, model);
            loaded += 1;
        }
        Ok(loaded)
    }
}

fn read_lock<T>(lock: &RwLock<T>) -> std::sync::RwLockReadGuard<'_, T> {
    lock.read().unwrap_or_else(|e| e.into_inner())
}

fn write_lock<T>(lock: &RwLock<T>) -> std::sync::RwLockWriteGuard<'_, T> {
    lock.write().unwrap_or_else(|e| e.into_inner())
}

/// Per-endpoint request counters (lock-free; relaxed ordering is fine
/// for monotone counters read after the fact).
#[derive(Default)]
pub struct Metrics {
    pub density: AtomicU64,
    pub cdf: AtomicU64,
    pub quantile: AtomicU64,
    pub sample: AtomicU64,
    pub conditional: AtomicU64,
    pub models: AtomicU64,
    pub health: AtomicU64,
    pub metrics: AtomicU64,
    /// every non-2xx response
    pub errors: AtomicU64,
}

/// A plain-value copy of [`Metrics`] for assertions and reports.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MetricsSnapshot {
    pub density: u64,
    pub cdf: u64,
    pub quantile: u64,
    pub sample: u64,
    pub conditional: u64,
    pub models: u64,
    pub health: u64,
    pub metrics: u64,
    pub errors: u64,
}

impl Metrics {
    pub fn snapshot(&self) -> MetricsSnapshot {
        let get = |c: &AtomicU64| c.load(Ordering::Relaxed);
        MetricsSnapshot {
            density: get(&self.density),
            cdf: get(&self.cdf),
            quantile: get(&self.quantile),
            sample: get(&self.sample),
            conditional: get(&self.conditional),
            models: get(&self.models),
            health: get(&self.health),
            metrics: get(&self.metrics),
            errors: get(&self.errors),
        }
    }

    fn to_json(&self) -> String {
        let s = self.snapshot();
        format!(
            "{{\"density\":{},\"cdf\":{},\"quantile\":{},\"sample\":{},\
             \"conditional\":{},\"models\":{},\"health\":{},\"metrics\":{},\
             \"errors\":{}}}",
            s.density,
            s.cdf,
            s.quantile,
            s.sample,
            s.conditional,
            s.models,
            s.health,
            s.metrics,
            s.errors
        )
    }
}

/// The HTTP front end. Bind, then either [`Server::run`] on the current
/// thread or [`Server::spawn`] for a background server with a
/// [`ServerHandle`] to stop it.
pub struct Server {
    listener: TcpListener,
    addr: SocketAddr,
    registry: Arc<ModelRegistry>,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
}

/// Handle to a background server: its bound address, live metrics, and
/// an orderly [`ServerHandle::stop`].
pub struct ServerHandle {
    addr: SocketAddr,
    metrics: Arc<Metrics>,
    stop: Arc<AtomicBool>,
    join: JoinHandle<()>,
}

impl ServerHandle {
    /// The actual bound address (resolves `:0` to the kernel's pick).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    /// Signal the accept loop, unblock it with a self-connection, and
    /// join the server thread.
    pub fn stop(self) {
        self.stop.store(true, Ordering::SeqCst);
        // accept() is blocking; a throwaway connection wakes it so it
        // can observe the flag
        if let Ok(s) = TcpStream::connect(self.addr) {
            drop(s);
        }
        let _ = self.join.join();
    }
}

impl Server {
    /// Bind to `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port).
    pub fn bind(addr: &str, registry: Arc<ModelRegistry>) -> Result<Server, ApiError> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| ApiError::Server(format!("bind {addr}: {e}")))?;
        let local = listener
            .local_addr()
            .map_err(|e| ApiError::Server(format!("local_addr: {e}")))?;
        Ok(Server {
            listener,
            addr: local,
            registry,
            metrics: Arc::new(Metrics::default()),
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }

    /// Serve until the stop flag is raised (see [`Server::spawn`] /
    /// [`ServerHandle::stop`]). Connections are distributed to
    /// [`parallel::threads`] worker threads over a channel; each worker
    /// handles one connection at a time end-to-end (requests are small
    /// and responses computed in-memory, so per-connection threads
    /// would only add churn).
    pub fn run(&self) {
        let workers = parallel::threads().max(1);
        let (tx, rx) = mpsc::channel::<TcpStream>();
        let rx = Arc::new(Mutex::new(rx));
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            let rx = Arc::clone(&rx);
            let registry = Arc::clone(&self.registry);
            let metrics = Arc::clone(&self.metrics);
            handles.push(std::thread::spawn(move || loop {
                let next = {
                    let guard = rx.lock().unwrap_or_else(|e| e.into_inner());
                    guard.recv()
                };
                match next {
                    Ok(stream) => handle_connection(stream, &registry, &metrics),
                    Err(_) => break, // sender dropped: server is stopping
                }
            }));
        }
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    if self.stop.load(Ordering::SeqCst) {
                        break;
                    }
                    // a send can only fail if every worker died; drop
                    // the connection rather than crash the acceptor
                    let _ = tx.send(stream);
                }
                Err(_) => {
                    if self.stop.load(Ordering::SeqCst) {
                        break;
                    }
                    // transient accept failure (EMFILE, aborted
                    // handshake): keep serving
                }
            }
        }
        drop(tx);
        for h in handles {
            let _ = h.join();
        }
    }

    /// Run on a background thread; the returned handle stops it.
    pub fn spawn(self) -> ServerHandle {
        let addr = self.addr;
        let metrics = Arc::clone(&self.metrics);
        let stop = Arc::clone(&self.stop);
        let join = std::thread::spawn(move || self.run());
        ServerHandle { addr, metrics, stop, join }
    }
}

/// One request–response exchange (`Connection: close` framing).
fn handle_connection(mut stream: TcpStream, registry: &ModelRegistry, metrics: &Metrics) {
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let (status, body) = match read_request_head(&mut stream) {
        Ok(head) => route(&head, registry, metrics),
        Err(msg) => (400, format!("{{\"error\":{}}}", json_string(&msg))),
    };
    if status >= 400 {
        metrics.errors.fetch_add(1, Ordering::Relaxed);
    }
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        _ => "Error",
    };
    let response = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    let _ = stream.write_all(response.as_bytes());
    let _ = stream.flush();
}

/// Read until the end-of-headers blank line, bounded by
/// [`MAX_REQUEST_BYTES`]. Only the request line is ever inspected.
fn read_request_head(stream: &mut TcpStream) -> Result<String, String> {
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    loop {
        if buf.windows(4).any(|w| w == b"\r\n\r\n") {
            break;
        }
        if buf.len() >= MAX_REQUEST_BYTES {
            return Err("request head exceeds 8 KiB".into());
        }
        match stream.read(&mut chunk) {
            Ok(0) => break, // peer closed; parse what we have
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) => return Err(format!("read: {e}")),
        }
    }
    String::from_utf8(buf).map_err(|_| "request is not UTF-8".into())
}

/// Dispatch a parsed request head to an endpoint handler.
fn route(head: &str, registry: &ModelRegistry, metrics: &Metrics) -> (u16, String) {
    let request_line = head.lines().next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let (method, target) = match (parts.next(), parts.next()) {
        (Some(m), Some(t)) => (m, t),
        _ => return bad_request("malformed request line"),
    };
    if method != "GET" {
        return (
            405,
            format!(
                "{{\"error\":{}}}",
                json_string(&format!("method {method} not allowed (GET only)"))
            ),
        );
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    match path {
        "/health" => {
            metrics.health.fetch_add(1, Ordering::Relaxed);
            (
                200,
                format!("{{\"status\":\"ok\",\"models\":{}}}", registry.len()),
            )
        }
        "/metrics" => {
            metrics.metrics.fetch_add(1, Ordering::Relaxed);
            (200, metrics.to_json())
        }
        "/v1/models" => {
            metrics.models.fetch_add(1, Ordering::Relaxed);
            let items: Vec<String> = registry
                .names()
                .iter()
                .filter_map(|name| {
                    registry.get(name).map(|m| {
                        let spec = m.spec();
                        format!(
                            "{{\"name\":{},\"j\":{},\"d\":{},\"method\":{},\"coreset_size\":{}}}",
                            json_string(name),
                            spec.j,
                            spec.d,
                            json_string(m.diagnostics().coreset.method),
                            m.diagnostics().coreset.size
                        )
                    })
                })
                .collect();
            (200, format!("{{\"models\":[{}]}}", items.join(",")))
        }
        _ => route_model_query(path, query, registry, metrics),
    }
}

/// `/v1/models/{name}/{endpoint}` queries.
fn route_model_query(
    path: &str,
    query: &str,
    registry: &ModelRegistry,
    metrics: &Metrics,
) -> (u16, String) {
    let rest = match path.strip_prefix("/v1/models/") {
        Some(r) => r,
        None => return not_found(path),
    };
    let (name, endpoint) = match rest.split_once('/') {
        Some((n, e)) => (n, e),
        None => return not_found(path),
    };
    let model = match registry.get(name) {
        Some(m) => m,
        None => {
            return (
                404,
                format!(
                    "{{\"error\":{}}}",
                    json_string(&format!("no model named `{name}`"))
                ),
            )
        }
    };
    let params = parse_query(query);
    let result = match endpoint {
        "density" => {
            metrics.density.fetch_add(1, Ordering::Relaxed);
            q_density(&model, &params)
        }
        "cdf" => {
            metrics.cdf.fetch_add(1, Ordering::Relaxed);
            q_cdf(&model, &params)
        }
        "quantile" => {
            metrics.quantile.fetch_add(1, Ordering::Relaxed);
            q_quantile(&model, &params)
        }
        "sample" => {
            metrics.sample.fetch_add(1, Ordering::Relaxed);
            q_sample(&model, &params)
        }
        "conditional" => {
            metrics.conditional.fetch_add(1, Ordering::Relaxed);
            q_conditional(&model, &params)
        }
        _ => return not_found(path),
    };
    match result {
        Ok(body) => (200, body),
        Err(msg) => bad_request(&msg),
    }
}

fn q_density(model: &FittedModel, params: &[(String, String)]) -> Result<String, String> {
    let y = f64_list_param(params, "y")?;
    let j = model.spec().j;
    if y.len() != j {
        return Err(format!("`y` has {} components, model has J = {j}", y.len()));
    }
    if y.iter().any(|v| v.is_nan()) {
        return Err("`y` contains NaN".into());
    }
    let ld = model.log_density(&y);
    Ok(format!(
        "{{\"y\":{},\"log_density\":{},\"density\":{}}}",
        json_f64_array(&y),
        json_f64(ld),
        json_f64(ld.exp())
    ))
}

fn q_cdf(model: &FittedModel, params: &[(String, String)]) -> Result<String, String> {
    let j = usize_param(params, "j", 0)?;
    let y = f64_param(params, "y")?;
    let v = model.try_cdf(j, y).map_err(|e| e.to_string())?;
    Ok(format!(
        "{{\"j\":{j},\"y\":{},\"cdf\":{}}}",
        json_f64(y),
        json_f64(v)
    ))
}

fn q_quantile(model: &FittedModel, params: &[(String, String)]) -> Result<String, String> {
    let j = usize_param(params, "j", 0)?;
    let p = f64_param(params, "p")?;
    let v = model.try_quantile(j, p).map_err(|e| e.to_string())?;
    Ok(format!(
        "{{\"j\":{j},\"p\":{},\"quantile\":{}}}",
        json_f64(p),
        json_f64(v)
    ))
}

fn q_sample(model: &FittedModel, params: &[(String, String)]) -> Result<String, String> {
    let n = usize_param(params, "n", 1)?;
    let seed = u64_param(params, "seed", 0)?;
    check_sample_count(n)?;
    let mut rng = Rng::new(seed);
    let draws = model.sample(n, &mut rng);
    Ok(format!(
        "{{\"n\":{n},\"seed\":{seed},\"rows\":{}}}",
        json_mat(&draws)
    ))
}

fn q_conditional(model: &FittedModel, params: &[(String, String)]) -> Result<String, String> {
    let given = f64_list_param(params, "given")?;
    let n = usize_param(params, "n", 1)?;
    let seed = u64_param(params, "seed", 0)?;
    check_sample_count(n)?;
    let j = model.spec().j;
    if given.len() > j {
        return Err(format!(
            "`given` conditions on {} components, model has J = {j}",
            given.len()
        ));
    }
    if given.iter().any(|v| !v.is_finite()) {
        return Err("`given` contains non-finite values".into());
    }
    let mut rng = Rng::new(seed);
    let draws = model.sample_conditional(&given, n, &mut rng);
    Ok(format!(
        "{{\"given\":{},\"n\":{n},\"seed\":{seed},\"rows\":{}}}",
        json_f64_array(&given),
        json_mat(&draws)
    ))
}

fn check_sample_count(n: usize) -> Result<(), String> {
    if n == 0 {
        return Err("`n` must be ≥ 1".into());
    }
    if n > MAX_SAMPLES_PER_REQUEST {
        return Err(format!("`n` = {n} exceeds per-request cap {MAX_SAMPLES_PER_REQUEST}"));
    }
    Ok(())
}

fn bad_request(msg: &str) -> (u16, String) {
    (400, format!("{{\"error\":{}}}", json_string(msg)))
}

fn not_found(path: &str) -> (u16, String) {
    (
        404,
        format!(
            "{{\"error\":{}}}",
            json_string(&format!("no endpoint at `{path}`"))
        ),
    )
}

/// Split a query string into key/value pairs. No percent-decoding: the
/// grammar of every parameter (numbers, commas, model names) never
/// needs it, and rejecting early beats decoding wrong.
fn parse_query(query: &str) -> Vec<(String, String)> {
    query
        .split('&')
        .filter(|kv| !kv.is_empty())
        .map(|kv| match kv.split_once('=') {
            Some((k, v)) => (k.to_string(), v.to_string()),
            None => (kv.to_string(), String::new()),
        })
        .collect()
}

fn str_param<'a>(params: &'a [(String, String)], key: &str) -> Option<&'a str> {
    params.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
}

fn f64_param(params: &[(String, String)], key: &str) -> Result<f64, String> {
    let raw = str_param(params, key).ok_or_else(|| format!("missing parameter `{key}`"))?;
    raw.parse::<f64>().map_err(|_| format!("`{key}`: `{raw}` is not a number"))
}

fn f64_list_param(params: &[(String, String)], key: &str) -> Result<Vec<f64>, String> {
    let raw = str_param(params, key).ok_or_else(|| format!("missing parameter `{key}`"))?;
    raw.split(',')
        .filter(|t| !t.is_empty())
        .map(|t| t.parse::<f64>().map_err(|_| format!("`{key}`: `{t}` is not a number")))
        .collect()
}

fn usize_param(params: &[(String, String)], key: &str, default: usize) -> Result<usize, String> {
    match str_param(params, key) {
        None => Ok(default),
        Some(raw) => raw
            .parse::<usize>()
            .map_err(|_| format!("`{key}`: `{raw}` is not a non-negative integer")),
    }
}

fn u64_param(params: &[(String, String)], key: &str, default: u64) -> Result<u64, String> {
    match str_param(params, key) {
        None => Ok(default),
        Some(raw) => raw
            .parse::<u64>()
            .map_err(|_| format!("`{key}`: `{raw}` is not a non-negative integer")),
    }
}

/// JSON number via shortest round-trip `Display`; non-finite values as
/// strings (JSON has no literals for them).
fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else if v.is_nan() {
        "\"NaN\"".into()
    } else if v > 0.0 {
        "\"inf\"".into()
    } else {
        "\"-inf\"".into()
    }
}

fn json_f64_array(vs: &[f64]) -> String {
    let items: Vec<String> = vs.iter().map(|&v| json_f64(v)).collect();
    format!("[{}]", items.join(","))
}

fn json_mat(m: &crate::linalg::Mat) -> String {
    let rows: Vec<String> = (0..m.rows).map(|r| json_f64_array(m.row(r))).collect();
    format!("[{}]", rows.join(","))
}

/// Minimal JSON string escaping (quotes, backslash, control bytes).
fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_f64_round_trips_and_handles_non_finite() {
        for &v in &[0.1, -0.0, 1.0 / 3.0, 1e-300, f64::MIN_POSITIVE, 12345.6789] {
            let s = json_f64(v);
            let back: f64 = s.parse().unwrap();
            assert_eq!(back.to_bits(), v.to_bits(), "{s}");
        }
        assert_eq!(json_f64(f64::NAN), "\"NaN\"");
        assert_eq!(json_f64(f64::INFINITY), "\"inf\"");
        assert_eq!(json_f64(f64::NEG_INFINITY), "\"-inf\"");
    }

    #[test]
    fn json_string_escapes() {
        assert_eq!(json_string("a\"b\\c\n"), "\"a\\\"b\\\\c\\n\"");
        assert_eq!(json_string("\u{1}"), "\"\\u0001\"");
    }

    #[test]
    fn query_parsing() {
        let p = parse_query("j=1&y=2.5&flag");
        assert_eq!(usize_param(&p, "j", 0).unwrap(), 1);
        assert_eq!(f64_param(&p, "y").unwrap(), 2.5);
        assert_eq!(str_param(&p, "flag"), Some(""));
        assert!(f64_param(&p, "missing").is_err());
        assert_eq!(usize_param(&p, "missing", 7).unwrap(), 7);
        assert_eq!(
            f64_list_param(&parse_query("y=1.5,-2,inf"), "y").unwrap(),
            vec![1.5, -2.0, f64::INFINITY]
        );
        assert!(f64_list_param(&parse_query("y=1.5,abc"), "y").is_err());
    }

    #[test]
    fn registry_basics() {
        let reg = ModelRegistry::new();
        assert!(reg.is_empty());
        assert!(reg.get("nope").is_none());
        assert_eq!(reg.names(), Vec::<String>::new());
    }

    #[test]
    fn metrics_snapshot_counts() {
        let m = Metrics::default();
        m.density.fetch_add(3, Ordering::Relaxed);
        m.errors.fetch_add(1, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.density, 3);
        assert_eq!(s.errors, 1);
        assert_eq!(s.cdf, 0);
        assert!(m.to_json().contains("\"density\":3"));
    }
}
