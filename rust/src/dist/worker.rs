//! Distributed sketching worker: binds a TCP listener, accepts one
//! coordinator connection at a time, and executes [`JobSpec`] shard
//! ranges **bit-identically to the in-process pipeline producer +
//! consumer**. The worker walks the dataset's shard stream from the
//! start (generator streams are sequential), assigns sequence numbers
//! with exactly the producer's rules — empty and fully-scrubbed shards
//! are skipped without consuming a number, transient reads are retried
//! up to the job's budget without consuming a number — and leaf-reduces
//! only the shards whose sequence falls in its `[lo, hi)` range, each
//! with `Rng::new(shard_seed(seed, seq))` and a width-1 pool. Because
//! seq assignment and leaf RNGs depend only on the data and the seed,
//! any worker (or a re-execution after a crash) reproduces exactly the
//! bytes the in-process run would have produced for that range.
//!
//! Degradation accounting is **range-gated for exactly-once totals**:
//! producer-side events (empty shards, scrubbed cells, shard retries)
//! are recorded only when the current sequence counter lies in
//! `[lo, hi)`, so an event seen by every worker walking the shared
//! stream prefix is attributed to exactly one range and the run total
//! equals the single-process run's. The per-range record travels back
//! in the `Done` frame and is merged by the coordinator **only at
//! range completion** — a failed or abandoned attempt records nothing
//! (the PR-6 success-only rule, extended across the network).
//!
//! While sketching, a scoped heartbeat thread sends `Ping` frames at
//! half the coordinator's read-timeout period, so a healthy worker on
//! a slow range never gets declared dead.

use crate::api::error::ApiError;
use crate::api::session::source_seed;
use crate::api::source::{DataSource, NamedSource, SourceInput};
use crate::coordinator::pipeline::shard_seed;
use crate::coreset::merge_reduce::{reduce_with, WeightedRows};
use crate::coreset::Method;
use crate::data::{scrub_invalid, ShardError};
use crate::dist::protocol::{
    check_hello, hello_payload, read_frame, write_frame, DoneReport, FrameKind, JobSpec, WireError,
};
use crate::util::degrade::DegradeSink;
use crate::util::parallel::Pool;
use crate::util::rng::Rng;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// How long an idle connection may sit between frames before the
/// worker gives up on it and returns to `accept` (a vanished
/// coordinator must not wedge the worker forever).
const IDLE_TIMEOUT: Duration = Duration::from_secs(60);

/// A job failed before completing its range.
enum JobFail {
    /// The connection died mid-range (a leaf write failed). There is
    /// nobody to report to — abandon silently; the coordinator's own
    /// read failure types this as transient and re-executes the range.
    ConnectionLost,
    /// The job itself failed; reported back as a typed `Error` frame
    /// with the worker's shard-sequence provenance.
    Fault { fatal: bool, seq: Option<usize>, message: String },
}

fn fault(fatal: bool, seq: Option<usize>, message: String) -> JobFail {
    JobFail::Fault { fatal, seq, message }
}

/// A bound-but-not-yet-running worker. [`Worker::run`] serves forever
/// on the calling thread (the `mctm-coreset work` subcommand);
/// [`Worker::spawn`] serves on a background thread and returns a
/// stoppable [`WorkerHandle`] (tests, smoke scripts).
pub struct Worker {
    listener: TcpListener,
    stop: Arc<AtomicBool>,
}

impl Worker {
    /// Bind the listening socket (use port 0 for an OS-assigned port;
    /// read it back via [`Worker::local_addr`]).
    pub fn bind(addr: &str) -> Result<Worker, ApiError> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| ApiError::Server(format!("binding worker listener on {addr}: {e}")))?;
        Ok(Worker { listener, stop: Arc::new(AtomicBool::new(false)) })
    }

    /// The bound address (resolves port 0 to the real port).
    pub fn local_addr(&self) -> Result<SocketAddr, ApiError> {
        self.listener
            .local_addr()
            .map_err(|e| ApiError::Server(format!("reading worker listener address: {e}")))
    }

    /// Accept-and-serve loop: one coordinator connection at a time
    /// (each coordinator thread drives exactly one worker, so there is
    /// nothing to multiplex). Returns only once [`WorkerHandle::stop`]
    /// has been called.
    pub fn run(&self) {
        loop {
            let conn = self.listener.accept();
            if self.stop.load(Ordering::SeqCst) {
                return;
            }
            match conn {
                Ok((stream, _)) => serve_connection(stream),
                // transient accept failure (e.g. EMFILE): keep serving
                Err(_) => std::thread::sleep(Duration::from_millis(10)),
            }
        }
    }

    /// Serve on a background thread; the returned handle stops the
    /// worker (and joins the thread) on [`WorkerHandle::stop`] or drop.
    pub fn spawn(self) -> Result<WorkerHandle, ApiError> {
        let addr = self.local_addr()?;
        let stop = Arc::clone(&self.stop);
        let thread = std::thread::spawn(move || self.run());
        Ok(WorkerHandle { addr, stop, thread: Some(thread) })
    }
}

/// Handle to a background [`Worker`]; stopping is idempotent and also
/// runs on drop, so tests cannot leak serving threads.
pub struct WorkerHandle {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl WorkerHandle {
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Signal the accept loop and join the serving thread. A self-
    /// connection unblocks a worker parked in `accept` (the same idiom
    /// `server::ServerHandle` uses).
    pub fn stop(&mut self) {
        if let Some(thread) = self.thread.take() {
            self.stop.store(true, Ordering::SeqCst);
            let _ = TcpStream::connect_timeout(&self.addr, Duration::from_secs(1));
            let _ = thread.join();
        }
    }
}

impl Drop for WorkerHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Serve one coordinator connection until `Release`, EOF, idle
/// timeout, or a protocol violation. All writes go through a shared
/// `Mutex<TcpStream>` so mid-job heartbeats never interleave bytes
/// with leaf frames; reads only ever happen between jobs, when no
/// heartbeat is running.
fn serve_connection(stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(IDLE_TIMEOUT));
    let _ = stream.set_nodelay(true);
    let mut reader = stream;
    let writer = match reader.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(_) => return,
    };
    let send = |kind: FrameKind, payload: &[u8]| -> bool {
        let mut w = writer.lock().unwrap_or_else(|e| e.into_inner());
        write_frame(&mut w, kind, payload).is_ok()
    };
    loop {
        let frame = match read_frame(&mut reader) {
            Ok(f) => f,
            // EOF / timeout / corruption: nothing useful to answer on
            // this connection — close it and let the coordinator's
            // typed transport error drive the retry
            Err(_) => return,
        };
        match frame.kind {
            FrameKind::Hello => {
                if let Err(e) = check_hello(&frame.payload) {
                    let err = WireError { fatal: true, seq: None, message: e.message().to_string() };
                    send(FrameKind::Error, &err.to_payload());
                    return;
                }
                if !send(FrameKind::Hello, &hello_payload()) {
                    return;
                }
            }
            FrameKind::Ping => {
                if !send(FrameKind::Pong, &[]) {
                    return;
                }
            }
            FrameKind::Release => return,
            FrameKind::Job => {
                let spec = match JobSpec::from_payload(&frame.payload) {
                    Ok(s) => s,
                    Err(e) => {
                        let err =
                            WireError { fatal: true, seq: None, message: e.message().to_string() };
                        send(FrameKind::Error, &err.to_payload());
                        return;
                    }
                };
                if !run_job(&writer, &spec) {
                    return;
                }
            }
            // Leaf/Done/Pong/Error arriving at a worker is a protocol
            // violation; drop the connection rather than guess
            _ => return,
        }
    }
}

/// Execute one job with a heartbeat running, then report `Done` or a
/// typed `Error`. Returns false when the connection is dead.
fn run_job(writer: &Arc<Mutex<TcpStream>>, spec: &JobSpec) -> bool {
    let running = AtomicBool::new(true);
    let result = std::thread::scope(|s| {
        // heartbeat at half the coordinator's read-timeout period, in
        // 50 ms slices so job completion stops it promptly
        s.spawn(|| {
            let period = Duration::from_millis(spec.heartbeat_ms.max(2) / 2);
            loop {
                let start = Instant::now();
                while start.elapsed() < period {
                    if !running.load(Ordering::SeqCst) {
                        return;
                    }
                    std::thread::sleep(Duration::from_millis(50));
                }
                let mut w = writer.lock().unwrap_or_else(|e| e.into_inner());
                if write_frame(&mut w, FrameKind::Ping, &[]).is_err() {
                    // peer gone; the job's own leaf/done write will
                    // discover the same thing and abandon
                    return;
                }
            }
        });
        let result = sketch_range(spec, writer);
        running.store(false, Ordering::SeqCst);
        result
    });
    match result {
        Ok(done) => {
            let mut w = writer.lock().unwrap_or_else(|e| e.into_inner());
            write_frame(&mut w, FrameKind::Done, &done.to_payload()).is_ok()
        }
        Err(JobFail::ConnectionLost) => false,
        Err(JobFail::Fault { fatal, seq, message }) => {
            let err = WireError { fatal, seq, message };
            let mut w = writer.lock().unwrap_or_else(|e| e.into_inner());
            write_frame(&mut w, FrameKind::Error, &err.to_payload()).is_ok()
        }
    }
}

/// Walk the dataset's shard stream and leaf-reduce the `[lo, hi)`
/// slice of sequence numbers, streaming each leaf back as it is
/// reduced. This mirrors the in-process producer loop statement for
/// statement (retry budget, empty-shard skips, sequence-order
/// scrubbing, per-seq RNGs) — the mirror IS the determinism guarantee.
fn sketch_range(spec: &JobSpec, writer: &Arc<Mutex<TcpStream>>) -> Result<DoneReport, JobFail> {
    let method = Method::parse(&spec.method)
        .map_err(|e| fault(true, None, format!("unknown sketch method in job: {e:#}")))?;
    let input = NamedSource::stream(&spec.dataset, spec.total, spec.shard)
        .into_input(source_seed(spec.seed))
        .map_err(|e| fault(true, None, format!("resolving dataset `{}`: {e}", spec.dataset)))?;
    let mut source = match input {
        SourceInput::Stream(s) => s,
        SourceInput::Batch(_) => {
            return Err(fault(
                true,
                None,
                format!("dataset `{}` did not resolve to a shard stream", spec.dataset),
            ))
        }
    };
    let j = source.dim();
    let k_buffer = spec.buffer_factor * spec.k;
    let pool = Pool::new(1);
    // in-range events land in the job sink (travels back in `Done`);
    // off-range events were already attributed to another range's
    // worker, so they drain into a throwaway sink
    let sink = DegradeSink::new();
    let off_range = DegradeSink::new();
    let mut leaves = 0usize;
    let mut seq = 0usize;
    loop {
        if seq >= spec.hi {
            break;
        }
        let in_range = seq >= spec.lo;
        let gate = if in_range { &sink } else { &off_range };
        let mut attempts = 0usize;
        let shard = loop {
            match source.next_shard() {
                Ok(s) => {
                    if attempts > 0 {
                        gate.shard_retries(attempts);
                    }
                    break s;
                }
                Err(ShardError::Transient(_)) if attempts < spec.retry_limit => {
                    attempts += 1;
                }
                Err(e) => {
                    let kind = match e {
                        ShardError::Transient(_) => "transient (retries exhausted)",
                        ShardError::Fatal(_) => "fatal",
                    };
                    return Err(fault(
                        true,
                        Some(seq),
                        format!("{kind} shard read error: {}", e.message()),
                    ));
                }
            }
        };
        let Some(shard) = shard else { break };
        if shard.rows == 0 {
            gate.empty_shard_skipped();
            continue;
        }
        if shard.cols != j {
            return Err(fault(
                true,
                Some(seq),
                format!("shard dimension mismatch: {} columns, source dim {j}", shard.cols),
            ));
        }
        let shard = match scrub_invalid(shard, spec.on_invalid, gate) {
            Ok(m) => m,
            Err((row, col)) => {
                return Err(fault(
                    true,
                    Some(seq),
                    format!(
                        "non-finite value at shard {seq}, row {row}, column {col} \
                         (policy: error; set on_invalid to mask or drop)"
                    ),
                ));
            }
        };
        if shard.rows == 0 {
            gate.empty_shard_skipped();
            continue;
        }
        if in_range {
            let n_raw = shard.rows;
            let mut rng = Rng::new(shard_seed(spec.seed, seq));
            let leaf = reduce_with(
                &WeightedRows::new(shard, vec![1.0; n_raw]),
                method,
                k_buffer,
                spec.d,
                spec.eps,
                &mut rng,
                &pool,
                gate,
            )
            .map_err(|e| fault(true, Some(seq), format!("leaf reduce failed: {e:#}")))?;
            let payload =
                crate::dist::protocol::leaf_payload(seq, n_raw, &leaf, &spec.method, spec.k);
            let mut w = writer.lock().unwrap_or_else(|e| e.into_inner());
            if write_frame(&mut w, FrameKind::Leaf, &payload).is_err() {
                return Err(JobFail::ConnectionLost);
            }
            drop(w);
            leaves += 1;
        }
        seq += 1;
    }
    Ok(DoneReport { leaves, degradations: sink.snapshot() })
}
