//! Seeded transport-fault injection for the distributed coordinator —
//! the network-layer twin of `data::faulty` (PR 6). A
//! [`TransportFaultPlan`] targets one worker connection and fires each
//! configured fault exactly once, at a deterministic received-frame
//! ordinal, so `tests/dist_fault_injection.rs` can pin that every
//! failure mode either recovers to the exact fault-free bytes or
//! surfaces a typed error — never a hang, partial result, or panic.
//!
//! Faults are injected on the **coordinator's receive path** (the only
//! place the crate can see a worker's bytes without patching the OS):
//!
//! * **corrupt** — read the frame's real wire bytes, flip one seeded
//!   bit in the payload/checksum region, then parse: the FNV-1a
//!   checksum catches it and types it transient, exactly as on-the-wire
//!   corruption would surface. (The header region is left alone on
//!   purpose — a corrupted length would desynchronize the stream, which
//!   the connection-drop fault already covers.)
//! * **drop** — shut the socket down mid-conversation, modeling a
//!   worker crash / network partition between frames.
//! * **stall** — surface the read-timeout error a heartbeat-less worker
//!   would cause, without spending wall-clock on a real timeout.

use crate::dist::protocol::{parse_frame, read_frame_raw, Frame, TransportError};
use std::net::TcpStream;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

/// A deterministic plan of transport faults against one worker
/// connection (by worker index in the coordinator's worker list).
/// Ordinals count frames *received from* that worker, across
/// reconnects; each fault fires exactly once.
#[derive(Clone, Debug, Default)]
pub struct TransportFaultPlan {
    seed: u64,
    worker: usize,
    corrupt_at: Option<usize>,
    drop_at: Option<usize>,
    stall_at: Option<usize>,
}

impl TransportFaultPlan {
    /// A plan with no faults, targeting worker 0. `seed` drives which
    /// bit the corruption flips.
    pub fn new(seed: u64) -> Self {
        TransportFaultPlan { seed, ..TransportFaultPlan::default() }
    }

    /// Target worker `i` (index into `DistConfig::workers`).
    pub fn on_worker(mut self, i: usize) -> Self {
        self.worker = i;
        self
    }

    /// Flip one seeded bit in the `n`-th received frame's
    /// payload/checksum bytes.
    pub fn with_corrupt_at(mut self, n: usize) -> Self {
        self.corrupt_at = Some(n);
        self
    }

    /// Kill the connection just before receiving the `n`-th frame.
    pub fn with_drop_at(mut self, n: usize) -> Self {
        self.drop_at = Some(n);
        self
    }

    /// Simulate a stalled (heartbeat-silent) worker at the `n`-th
    /// receive: the read times out without spending real wall-clock.
    pub fn with_stall_at(mut self, n: usize) -> Self {
        self.stall_at = Some(n);
        self
    }
}

/// Shared runtime state for one coordinator run: the frame ordinal
/// counter plus once-only latches, so a re-executed range does not
/// re-fire a fault that already did its damage.
pub(crate) struct FaultState {
    plan: TransportFaultPlan,
    frames: AtomicUsize,
    corrupt_done: AtomicBool,
    drop_done: AtomicBool,
    stall_done: AtomicBool,
}

impl FaultState {
    pub(crate) fn new(plan: TransportFaultPlan) -> Self {
        FaultState {
            plan,
            frames: AtomicUsize::new(0),
            corrupt_done: AtomicBool::new(false),
            drop_done: AtomicBool::new(false),
            stall_done: AtomicBool::new(false),
        }
    }

    /// Receive one frame from worker `widx`, injecting this plan's
    /// faults at their ordinals. Non-targeted workers read normally.
    pub(crate) fn recv(
        &self,
        stream: &mut TcpStream,
        widx: usize,
    ) -> Result<Frame, TransportError> {
        if widx != self.plan.worker {
            return parse_frame(&read_frame_raw(stream)?);
        }
        let ordinal = self.frames.fetch_add(1, Ordering::SeqCst);
        if self.plan.drop_at == Some(ordinal) && !self.drop_done.swap(true, Ordering::SeqCst) {
            let _ = stream.shutdown(std::net::Shutdown::Both);
            return Err(TransportError::Transient(
                "injected connection drop (worker crash / partition)".into(),
            ));
        }
        if self.plan.stall_at == Some(ordinal) && !self.stall_done.swap(true, Ordering::SeqCst) {
            return Err(TransportError::Transient(
                "injected worker stall: heartbeat read timed out".into(),
            ));
        }
        let mut raw = read_frame_raw(stream)?;
        if self.plan.corrupt_at == Some(ordinal) && !self.corrupt_done.swap(true, Ordering::SeqCst)
        {
            // flip a seeded bit anywhere in payload+crc: the checksum
            // covers both, so the mismatch is caught and typed
            // transient whichever side of the trailer the flip lands on
            let span = raw.len() - 9; // payload + 8-byte crc
            let off = 9 + (self.plan.seed as usize) % span;
            raw[off] ^= 1 << ((self.plan.seed >> 32) % 8);
        }
        parse_frame(&raw)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_builder_composes() {
        let plan = TransportFaultPlan::new(7).on_worker(2).with_corrupt_at(1).with_drop_at(4);
        assert_eq!(plan.worker, 2);
        assert_eq!(plan.corrupt_at, Some(1));
        assert_eq!(plan.drop_at, Some(4));
        assert_eq!(plan.stall_at, None);
    }
}
