//! Distributed Merge & Reduce (ROADMAP item 4): coordinator/worker
//! sketching over a hand-rolled TCP protocol, built so that **failure
//! recovery is invisible in the output**. The paper's merge-and-reduce
//! construction is associative with per-shard seeding, which means a
//! shard range is a pure function of `(dataset, seed, range)` — any
//! worker, or a re-execution after a crash, produces the same leaf
//! bytes. The coordinator exploits exactly that: an N-worker
//! [`run_distributed`] is bit-identical to the in-process pipeline at
//! `consumers = N`, and stays bit-identical when workers are killed
//! mid-sketch and their ranges are reassigned.
//!
//! Module map:
//!
//! * [`protocol`] — length-prefixed FNV-1a-checksummed frames; sketch
//!   payloads ride in the existing `Artifact::Sketch` serialization;
//!   typed transient/fatal [`protocol::TransportError`].
//! * [`worker`] — `mctm-coreset work --listen ADDR`: executes shard
//!   ranges with exactly the in-process producer/consumer semantics,
//!   heartbeating while it sketches.
//! * [`coordinator`] — `mctm-coreset dist-fit --workers a,b,c`:
//!   assigns ranges, bounded retry-with-backoff per worker, reassigns
//!   dead workers' ranges, folds leaves in fixed sequence order.
//! * [`faulty`] — seeded transport-fault injection (frame corruption,
//!   connection drops, stalls) for `tests/dist_fault_injection.rs`.
//!
//! Every recovery is counted in
//! [`Degradations`](crate::util::degrade::Degradations)
//! (`worker_retries`, `range_reassignments`) and surfaced through
//! `CoresetReport::degradations` — recovery is silent in the bytes,
//! never in the accounting.

pub mod coordinator;
pub mod faulty;
pub mod protocol;
pub mod worker;

pub use coordinator::{run_distributed, DistConfig};
pub use faulty::TransportFaultPlan;
pub use protocol::TransportError;
pub use worker::{Worker, WorkerHandle};
