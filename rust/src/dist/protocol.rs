//! Length-prefixed, checksummed TCP framing for the coordinator/worker
//! protocol — hand-rolled on `std::net`, zero dependencies, reusing the
//! FNV-1a checksum idiom from `runtime::artifact` and `data::store`.
//!
//! Wire layout of one frame:
//!
//! ```text
//! [ kind: u8 ][ len: u64 LE ][ payload: len bytes ][ crc: u64 LE ]
//! ```
//!
//! where `crc = fnv1a64(kind ‖ len ‖ payload)` — the checksum covers
//! the header too, so a corrupted kind or length cannot masquerade as
//! a valid frame. Payloads are the same line-ASCII the artifact format
//! uses (`f64` as 16-hex `to_bits`, so values round-trip bitwise); the
//! `Leaf` payload embeds a full `Artifact::Sketch`, reusing its
//! serialization and its own `end <crc>` trailer unchanged.
//!
//! Failure taxonomy ([`TransportError`]): connection-level problems —
//! IO errors, timeouts, checksum mismatches, short reads — are
//! **transient** (a reconnect + full-range re-execution can recover
//! bit-identically); protocol violations — unknown frame kind, version
//! mismatch, oversized frame, malformed payload schema — are **fatal**
//! (retrying the same bytes cannot help). The coordinator folds these
//! into the `ShardError`/`ApiError::Stream` taxonomy from PR 6.

use crate::coreset::merge_reduce::WeightedRows;
use crate::data::InvalidPolicy;
use crate::runtime::artifact::{fnv1a64, Artifact, SketchArtifact};
use crate::util::degrade::Degradations;
use std::io::{Read, Write};
use std::net::TcpStream;

/// Protocol revision; both ends exchange it in the `Hello` handshake
/// and a mismatch is a fatal (non-retryable) error.
pub const PROTOCOL_VERSION: u32 = 1;

/// Handshake payload (version-bearing).
pub(crate) fn hello_payload() -> Vec<u8> {
    format!("mctm-dist v{PROTOCOL_VERSION}").into_bytes()
}

/// Guard against a corrupted length field asking for an absurd
/// allocation: no legitimate sketch payload approaches this.
const MAX_FRAME_LEN: u64 = 1 << 30;

/// Frame kinds on the wire (the `u8` tag is the wire value).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FrameKind {
    /// version handshake, both directions
    Hello = 1,
    /// coordinator → worker: sketch this shard range
    Job = 2,
    /// worker → coordinator: one reduced leaf of the range
    Leaf = 3,
    /// worker → coordinator: range complete (degradation accounting)
    Done = 4,
    /// liveness heartbeat (worker → coordinator while sketching)
    Ping = 5,
    /// heartbeat response
    Pong = 6,
    /// coordinator → worker: no more jobs on this connection
    Release = 7,
    /// worker → coordinator: the job failed (typed transient/fatal)
    Error = 8,
}

impl FrameKind {
    fn from_wire(tag: u8) -> Option<FrameKind> {
        Some(match tag {
            1 => FrameKind::Hello,
            2 => FrameKind::Job,
            3 => FrameKind::Leaf,
            4 => FrameKind::Done,
            5 => FrameKind::Ping,
            6 => FrameKind::Pong,
            7 => FrameKind::Release,
            8 => FrameKind::Error,
            _ => return None,
        })
    }
}

/// One protocol frame (kind + raw payload bytes).
#[derive(Clone, Debug)]
pub struct Frame {
    pub kind: FrameKind,
    pub payload: Vec<u8>,
}

/// Typed transport failure: `Transient` means a reconnect + full-range
/// re-execution may recover (IO error, timeout, checksum mismatch);
/// `Fatal` means retrying cannot help (protocol violation, version
/// mismatch, malformed schema, worker-reported fatal job error).
#[derive(Clone, Debug)]
pub enum TransportError {
    Transient(String),
    Fatal(String),
}

impl TransportError {
    pub fn message(&self) -> &str {
        match self {
            TransportError::Transient(m) | TransportError::Fatal(m) => m,
        }
    }

    pub fn is_fatal(&self) -> bool {
        matches!(self, TransportError::Fatal(_))
    }
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Transient(m) => write!(f, "transient transport error: {m}"),
            TransportError::Fatal(m) => write!(f, "fatal transport error: {m}"),
        }
    }
}

fn transient(msg: impl Into<String>) -> TransportError {
    TransportError::Transient(msg.into())
}

fn fatal(msg: impl Into<String>) -> TransportError {
    TransportError::Fatal(msg.into())
}

/// Serialize one frame into its full wire bytes (header + payload +
/// trailing checksum).
pub fn frame_bytes(kind: FrameKind, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(9 + payload.len() + 8);
    out.push(kind as u8);
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(payload);
    let crc = fnv1a64(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// Write one frame. IO failures are transient — the peer may simply
/// have gone away, and the range is re-executable.
pub fn write_frame(
    stream: &mut TcpStream,
    kind: FrameKind,
    payload: &[u8],
) -> Result<(), TransportError> {
    let bytes = frame_bytes(kind, payload);
    stream
        .write_all(&bytes)
        .and_then(|_| stream.flush())
        .map_err(|e| transient(format!("writing {kind:?} frame: {e}")))
}

/// Read one frame's raw wire bytes (header + payload + checksum),
/// without validating the checksum — [`parse_frame`] does that. Split
/// out so the transport fault injector can corrupt the exact bytes a
/// flaky wire would.
pub fn read_frame_raw(stream: &mut TcpStream) -> Result<Vec<u8>, TransportError> {
    let mut header = [0u8; 9];
    stream
        .read_exact(&mut header)
        .map_err(|e| transient(format!("reading frame header: {e}")))?;
    let len = u64::from_le_bytes([
        header[1], header[2], header[3], header[4], header[5], header[6], header[7], header[8],
    ]);
    if len > MAX_FRAME_LEN {
        // a length this large is a corrupted or hostile header, and
        // the stream position is now unrecoverable on this connection
        return Err(transient(format!(
            "frame length {len} exceeds the {MAX_FRAME_LEN}-byte cap (corrupted header?)"
        )));
    }
    let mut bytes = vec![0u8; 9 + len as usize + 8];
    bytes[..9].copy_from_slice(&header);
    stream
        .read_exact(&mut bytes[9..])
        .map_err(|e| transient(format!("reading frame body: {e}")))?;
    Ok(bytes)
}

/// Validate and decode raw frame bytes: checksum first (mismatch is
/// transient — wire corruption), then the kind tag (unknown is fatal —
/// a protocol violation retrying cannot fix).
pub fn parse_frame(bytes: &[u8]) -> Result<Frame, TransportError> {
    if bytes.len() < 17 {
        return Err(transient("frame shorter than header + checksum"));
    }
    let body = &bytes[..bytes.len() - 8];
    let mut crc_bytes = [0u8; 8];
    crc_bytes.copy_from_slice(&bytes[bytes.len() - 8..]);
    if fnv1a64(body) != u64::from_le_bytes(crc_bytes) {
        return Err(transient("frame checksum mismatch (corrupted on the wire)"));
    }
    let kind = FrameKind::from_wire(bytes[0])
        .ok_or_else(|| fatal(format!("unknown frame kind {}", bytes[0])))?;
    Ok(Frame { kind, payload: bytes[9..bytes.len() - 8].to_vec() })
}

/// Read + validate one frame.
pub fn read_frame(stream: &mut TcpStream) -> Result<Frame, TransportError> {
    parse_frame(&read_frame_raw(stream)?)
}

/// Check a received `Hello` payload against ours.
pub(crate) fn check_hello(payload: &[u8]) -> Result<(), TransportError> {
    if payload == hello_payload().as_slice() {
        Ok(())
    } else {
        Err(fatal(format!(
            "protocol version mismatch: peer sent `{}`, this build speaks `mctm-dist v{PROTOCOL_VERSION}`",
            String::from_utf8_lossy(payload)
        )))
    }
}

// ---------------------------------------------------------------------
// Job payload
// ---------------------------------------------------------------------

/// Everything a worker needs to sketch one shard range bit-identically
/// to the in-process pipeline: the dataset registry name, the stream
/// geometry, the sketch knobs, and the half-open sequence range
/// `[lo, hi)` this worker owns (`hi = usize::MAX` means "to the end of
/// the stream").
#[derive(Clone, Debug, PartialEq)]
pub struct JobSpec {
    pub dataset: String,
    pub total: usize,
    pub shard: usize,
    pub lo: usize,
    pub hi: usize,
    /// method registry name (resolved through `Method::parse` on the
    /// worker, so an unregistered name is a typed fatal error)
    pub method: String,
    pub k: usize,
    pub d: usize,
    pub eps: f64,
    pub seed: u64,
    pub buffer_factor: usize,
    pub on_invalid: InvalidPolicy,
    pub retry_limit: usize,
    /// coordinator read-timeout in ms; the worker heartbeats at half
    /// this period while sketching so a healthy slow range never trips
    /// the coordinator's liveness check
    pub heartbeat_ms: u64,
}

fn policy_name(p: InvalidPolicy) -> &'static str {
    match p {
        InvalidPolicy::Error => "error",
        InvalidPolicy::MaskRow => "mask",
        InvalidPolicy::DropRow => "drop",
    }
}

fn policy_parse(s: &str) -> Result<InvalidPolicy, TransportError> {
    match s {
        "error" => Ok(InvalidPolicy::Error),
        "mask" => Ok(InvalidPolicy::MaskRow),
        "drop" => Ok(InvalidPolicy::DropRow),
        other => Err(fatal(format!("unknown on_invalid policy `{other}` in job"))),
    }
}

impl JobSpec {
    pub fn to_payload(&self) -> Vec<u8> {
        // the artifact idiom: line-ASCII, f64 as 16-hex to_bits so eps
        // round-trips bitwise
        format!(
            "job v1\ndataset {}\ntotal {}\nshard {}\nlo {}\nhi {}\nmethod {}\nk {}\nd {}\n\
             eps {:016x}\nseed {}\nbuffer_factor {}\non_invalid {}\nretry_limit {}\n\
             heartbeat_ms {}\n",
            self.dataset,
            self.total,
            self.shard,
            self.lo,
            self.hi,
            self.method,
            self.k,
            self.d,
            self.eps.to_bits(),
            self.seed,
            self.buffer_factor,
            policy_name(self.on_invalid),
            self.retry_limit,
            self.heartbeat_ms,
        )
        .into_bytes()
    }

    pub fn from_payload(payload: &[u8]) -> Result<JobSpec, TransportError> {
        let text = std::str::from_utf8(payload)
            .map_err(|_| fatal("job payload is not valid UTF-8"))?;
        let mut lines = text.lines();
        if lines.next() != Some("job v1") {
            return Err(fatal("job payload missing `job v1` header"));
        }
        let mut fields = std::collections::HashMap::new();
        for line in lines {
            if let Some((key, value)) = line.split_once(' ') {
                fields.insert(key.to_string(), value.to_string());
            }
        }
        let get = |key: &str| {
            fields
                .get(key)
                .cloned()
                .ok_or_else(|| fatal(format!("job payload missing `{key}`")))
        };
        let num = |key: &str| -> Result<usize, TransportError> {
            get(key)?
                .parse()
                .map_err(|_| fatal(format!("job field `{key}` is not a number")))
        };
        let eps_bits = u64::from_str_radix(&get("eps")?, 16)
            .map_err(|_| fatal("job field `eps` is not 16-hex f64 bits"))?;
        Ok(JobSpec {
            dataset: get("dataset")?,
            total: num("total")?,
            shard: num("shard")?,
            lo: num("lo")?,
            hi: num("hi")?,
            method: get("method")?,
            k: num("k")?,
            d: num("d")?,
            eps: f64::from_bits(eps_bits),
            seed: get("seed")?
                .parse()
                .map_err(|_| fatal("job field `seed` is not a u64"))?,
            buffer_factor: num("buffer_factor")?,
            on_invalid: policy_parse(&get("on_invalid")?)?,
            retry_limit: num("retry_limit")?,
            heartbeat_ms: get("heartbeat_ms")?
                .parse()
                .map_err(|_| fatal("job field `heartbeat_ms` is not a u64"))?,
        })
    }
}

// ---------------------------------------------------------------------
// Leaf payload
// ---------------------------------------------------------------------

/// Encode one reduced leaf: a `seq` line, then the leaf as a full
/// `Artifact::Sketch` — the existing serialization (16-hex f64 rows
/// and weights, `end <crc>` trailer) carries the payload bit-exactly,
/// and `n_seen` doubles as the leaf's raw row count `n_raw`.
pub fn leaf_payload(seq: usize, n_raw: usize, leaf: &WeightedRows, method: &str, k: usize) -> Vec<u8> {
    let art = Artifact::Sketch(SketchArtifact {
        method: method.to_string(),
        requested: k,
        n_hull: leaf.n_hull,
        n_seen: n_raw,
        rows: leaf.rows.clone(),
        weights: leaf.weights.clone(),
        scaler: None,
    });
    let mut out = format!("seq {seq}\n").into_bytes();
    out.extend_from_slice(&art.to_bytes());
    out
}

/// Decode a leaf payload back to `(seq, leaf, n_raw)`. Malformed
/// artifact bytes inside a checksum-valid frame are still treated as
/// transient: the leaf is re-executable, and the artifact parser's own
/// trailer check is a second corruption line of defence.
pub fn parse_leaf(payload: &[u8]) -> Result<(usize, WeightedRows, usize), TransportError> {
    let newline = payload
        .iter()
        .position(|&b| b == b'\n')
        .ok_or_else(|| transient("leaf payload missing seq line"))?;
    let head = std::str::from_utf8(&payload[..newline])
        .map_err(|_| transient("leaf seq line is not UTF-8"))?;
    let seq: usize = head
        .strip_prefix("seq ")
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| transient(format!("malformed leaf seq line `{head}`")))?;
    match Artifact::from_bytes(&payload[newline + 1..]) {
        Ok(Artifact::Sketch(a)) => Ok((
            seq,
            WeightedRows { n_hull: a.n_hull, rows: a.rows, weights: a.weights },
            a.n_seen,
        )),
        Ok(Artifact::Model(_)) => Err(fatal("leaf frame carried a model artifact")),
        Err(e) => Err(transient(format!("leaf artifact failed to parse: {e}"))),
    }
}

// ---------------------------------------------------------------------
// Done payload
// ---------------------------------------------------------------------

/// Range-completion report: how many leaves the worker sent (the
/// coordinator cross-checks its received count) and the range's
/// degradation accounting, merged into the run's sink only here — at
/// range completion — so a failed attempt records nothing (the PR-6
/// success-only rule, extended to transport).
#[derive(Clone, Debug, Default)]
pub struct DoneReport {
    pub leaves: usize,
    pub degradations: Degradations,
}

/// Field order is the struct's declaration order; both ends are built
/// from this crate, so the codec and the struct cannot drift apart.
const DEGRADE_FIELDS: usize = 14;

fn degrade_counters(d: &Degradations) -> [usize; DEGRADE_FIELDS] {
    [
        d.gram_ridge_recoveries,
        d.gram_ridge_max_rung,
        d.mvee_nonconverged,
        d.mvee_factor_breaks,
        d.score_fallbacks,
        d.line_search_failures,
        d.nonfinite_starts,
        d.invalid_cells,
        d.rows_masked,
        d.rows_dropped,
        d.shard_retries,
        d.empty_shards_skipped,
        d.worker_retries,
        d.range_reassignments,
    ]
}

impl DoneReport {
    pub fn to_payload(&self) -> Vec<u8> {
        let counters = degrade_counters(&self.degradations)
            .iter()
            .map(|c| c.to_string())
            .collect::<Vec<_>>()
            .join(" ");
        format!("done v1\nleaves {}\ndegrade {}\n", self.leaves, counters).into_bytes()
    }

    pub fn from_payload(payload: &[u8]) -> Result<DoneReport, TransportError> {
        let text = std::str::from_utf8(payload)
            .map_err(|_| fatal("done payload is not valid UTF-8"))?;
        let mut lines = text.lines();
        if lines.next() != Some("done v1") {
            return Err(fatal("done payload missing `done v1` header"));
        }
        let leaves: usize = lines
            .next()
            .and_then(|l| l.strip_prefix("leaves "))
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| fatal("done payload missing `leaves`"))?;
        let counters: Vec<usize> = lines
            .next()
            .and_then(|l| l.strip_prefix("degrade "))
            .map(|s| s.split(' ').filter_map(|t| t.parse().ok()).collect())
            .ok_or_else(|| fatal("done payload missing `degrade`"))?;
        if counters.len() != DEGRADE_FIELDS {
            return Err(fatal(format!(
                "done payload has {} degradation counters, this build expects {DEGRADE_FIELDS}",
                counters.len()
            )));
        }
        let mut d = Degradations::default();
        [
            &mut d.gram_ridge_recoveries,
            &mut d.gram_ridge_max_rung,
            &mut d.mvee_nonconverged,
            &mut d.mvee_factor_breaks,
            &mut d.score_fallbacks,
            &mut d.line_search_failures,
            &mut d.nonfinite_starts,
            &mut d.invalid_cells,
            &mut d.rows_masked,
            &mut d.rows_dropped,
            &mut d.shard_retries,
            &mut d.empty_shards_skipped,
            &mut d.worker_retries,
            &mut d.range_reassignments,
        ]
        .into_iter()
        .zip(&counters)
        .for_each(|(slot, &v)| *slot = v);
        Ok(DoneReport { leaves, degradations: d })
    }
}

// ---------------------------------------------------------------------
// Error payload
// ---------------------------------------------------------------------

/// A worker-side job failure, carried back typed: transient failures
/// invite a retry/reassignment, fatal ones fail the run with the
/// worker's shard-sequence provenance attached.
#[derive(Clone, Debug)]
pub struct WireError {
    pub fatal: bool,
    /// shard sequence the worker was handling, when attributable
    pub seq: Option<usize>,
    pub message: String,
}

impl WireError {
    pub fn to_payload(&self) -> Vec<u8> {
        format!(
            "{}\nseq {}\n{}",
            if self.fatal { "fatal" } else { "transient" },
            self.seq.map_or_else(|| "-".to_string(), |s| s.to_string()),
            self.message
        )
        .into_bytes()
    }

    pub fn from_payload(payload: &[u8]) -> Result<WireError, TransportError> {
        let text = std::str::from_utf8(payload)
            .map_err(|_| fatal("error payload is not valid UTF-8"))?;
        let mut lines = text.splitn(3, '\n');
        let fatal_flag = match lines.next() {
            Some("fatal") => true,
            Some("transient") => false,
            _ => return Err(fatal("error payload missing transient|fatal line")),
        };
        let seq = match lines.next().and_then(|l| l.strip_prefix("seq ")) {
            Some("-") => None,
            Some(s) => Some(
                s.parse()
                    .map_err(|_| fatal("error payload has malformed seq"))?,
            ),
            None => return Err(fatal("error payload missing seq line")),
        };
        Ok(WireError {
            fatal: fatal_flag,
            seq,
            message: lines.next().unwrap_or("").to_string(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;

    #[test]
    fn frame_roundtrip_and_corruption_is_transient() {
        let bytes = frame_bytes(FrameKind::Job, b"payload bytes");
        let f = parse_frame(&bytes).unwrap();
        assert_eq!(f.kind, FrameKind::Job);
        assert_eq!(f.payload, b"payload bytes");

        // flip one payload bit: checksum catches it, typed transient
        let mut corrupted = bytes.clone();
        corrupted[10] ^= 0x40;
        match parse_frame(&corrupted) {
            Err(TransportError::Transient(m)) => assert!(m.contains("checksum"), "{m}"),
            other => panic!("expected transient checksum error, got {other:?}"),
        }

        // unknown kind is a protocol violation — fatal, not retryable
        let mut bad_kind = frame_bytes(FrameKind::Ping, b"");
        bad_kind[0] = 99;
        let crc = fnv1a64(&bad_kind[..bad_kind.len() - 8]);
        let n = bad_kind.len();
        bad_kind[n - 8..].copy_from_slice(&crc.to_le_bytes());
        assert!(matches!(parse_frame(&bad_kind), Err(TransportError::Fatal(_))));
    }

    #[test]
    fn job_spec_roundtrips_bitwise() {
        let spec = JobSpec {
            dataset: "store:/tmp/x.store".into(),
            total: 12_345,
            shard: 678,
            lo: 3,
            hi: usize::MAX,
            method: "l2-hull".into(),
            k: 40,
            d: 6,
            eps: 0.012_345_678_9,
            seed: 0xDEAD_BEEF_CAFE,
            buffer_factor: 4,
            on_invalid: InvalidPolicy::DropRow,
            retry_limit: 5,
            heartbeat_ms: 10_000,
        };
        let back = JobSpec::from_payload(&spec.to_payload()).unwrap();
        assert_eq!(back, spec);
        assert_eq!(back.eps.to_bits(), spec.eps.to_bits());
    }

    #[test]
    fn leaf_payload_roundtrips_bitwise() {
        let rows = Mat::from_vec(3, 2, vec![0.1, -2.5, 3.25, 1e-300, f64::MIN_POSITIVE, 7.0]);
        let mut leaf = WeightedRows::new(rows, vec![1.5, 2.5, 0.25]);
        leaf.n_hull = 2;
        let payload = leaf_payload(17, 1_000, &leaf, "l2-hull", 40);
        let (seq, back, n_raw) = parse_leaf(&payload).unwrap();
        assert_eq!(seq, 17);
        assert_eq!(n_raw, 1_000);
        assert_eq!(back.n_hull, 2);
        for (a, b) in back.rows.data.iter().zip(&leaf.rows.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in back.weights.iter().zip(&leaf.weights) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn done_and_error_payloads_roundtrip() {
        let d = Degradations {
            shard_retries: 3,
            empty_shards_skipped: 1,
            rows_dropped: 7,
            ..Degradations::default()
        };
        let done = DoneReport { leaves: 12, degradations: d.clone() };
        let back = DoneReport::from_payload(&done.to_payload()).unwrap();
        assert_eq!(back.leaves, 12);
        assert_eq!(back.degradations, d);

        let err = WireError { fatal: true, seq: Some(5), message: "boom\nwith detail".into() };
        let back = WireError::from_payload(&err.to_payload()).unwrap();
        assert!(back.fatal);
        assert_eq!(back.seq, Some(5));
        assert_eq!(back.message, "boom\nwith detail");

        let err = WireError { fatal: false, seq: None, message: "flaky".into() };
        let back = WireError::from_payload(&err.to_payload()).unwrap();
        assert!(!back.fatal && back.seq.is_none());
    }
}
