//! Distributed sketching coordinator: splits a dataset's shard-
//! sequence space into one contiguous range per worker, drives each
//! worker over TCP, and folds the returned leaves **in the same fixed
//! sequence order as the in-process reducer** — so an N-worker run is
//! bit-identical to `consumers = N` in one process, and (because leaf
//! bytes depend only on `(data, seed, seq)`) stays bit-identical when
//! a worker dies and its range is re-executed elsewhere.
//!
//! Failure semantics, in order of escalation:
//!
//! 1. **Transient transport faults** (connect refused, read timeout,
//!    checksum mismatch, mid-range disconnect, worker-reported
//!    transient job error) → reconnect and re-execute the whole range
//!    on the same worker, up to the session's `shard_retry_limit`,
//!    with short attempt-counted backoff. Counted into
//!    [`Degradations::worker_retries`] — only once the range completes.
//! 2. **Budget exhausted** → the worker is declared dead; its range
//!    goes back on the shared queue and a healthy worker re-executes
//!    it (deterministic reassignment, counted into
//!    [`Degradations::range_reassignments`] at completion).
//! 3. **Fatal faults** (protocol violation, version mismatch, unknown
//!    dataset/method, exhausted *data* retries on the worker) → the
//!    run aborts orderly and surfaces [`ApiError::Stream`] with
//!    worker/range provenance. Idle workers are woken and exit; the
//!    coordinator's `Release` frames (and worker-side idle timeouts)
//!    leave no connection wedged.
//! 4. **Every worker dead** with ranges unfinished → a typed error
//!    naming the last failure, never a hang.
//!
//! [`Degradations::worker_retries`]: crate::util::degrade::Degradations::worker_retries
//! [`Degradations::range_reassignments`]: crate::util::degrade::Degradations::range_reassignments

use crate::api::error::ApiError;
use crate::coordinator::pipeline::{StreamError, StreamStats, SHARD_RETRY_LIMIT};
use crate::coreset::merge_reduce::{MergeReduce, WeightedRows};
use crate::coreset::Method;
use crate::data::InvalidPolicy;
use crate::dist::faulty::{FaultState, TransportFaultPlan};
use crate::dist::protocol::{
    check_hello, hello_payload, parse_leaf, read_frame, write_frame, DoneReport, Frame, FrameKind,
    JobSpec, TransportError, WireError,
};
use crate::util::degrade::DegradeSink;
use crate::util::parallel::Pool;
use crate::util::Stopwatch;
use std::collections::{BTreeMap, VecDeque};
use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Everything a distributed sketch needs: worker addresses, the
/// dataset (any `NamedSource` name — generator, `file:`, `store:`),
/// the stream geometry, and the sketch knobs. Field-for-field these
/// mirror the in-process `Pipeline`, because the contract is that the
/// outputs are interchangeable.
#[derive(Clone, Debug)]
pub struct DistConfig {
    /// worker addresses (`host:port`); one coordinator thread each
    pub workers: Vec<String>,
    /// dataset registry name, resolved identically on every worker
    pub dataset: String,
    /// total rows requested from the stream
    pub total: usize,
    /// rows per shard
    pub shard: usize,
    pub method: Method,
    pub k: usize,
    pub d: usize,
    pub eps: f64,
    pub seed: u64,
    /// Merge & Reduce intermediate-level size multiplier
    pub buffer_factor: usize,
    /// non-finite-cell policy, applied by workers in sequence order
    pub on_invalid: InvalidPolicy,
    /// per-worker transport retry budget (and the workers' own data
    /// retry budget) — the session's `shard_retry_limit` knob
    pub retry_limit: usize,
    /// read timeout per worker; workers heartbeat at half this period,
    /// so only a dead or wedged worker ever trips it
    pub heartbeat: Duration,
    /// seeded transport-fault injection (tests only)
    pub fault: Option<TransportFaultPlan>,
}

impl DistConfig {
    pub fn new(
        workers: Vec<String>,
        dataset: impl Into<String>,
        total: usize,
        shard: usize,
        method: Method,
        k: usize,
        d: usize,
        eps: f64,
    ) -> Self {
        DistConfig {
            workers,
            dataset: dataset.into(),
            total,
            shard,
            method,
            k,
            d,
            eps,
            seed: 0xC0FF_EE,
            buffer_factor: 4,
            on_invalid: InvalidPolicy::default(),
            retry_limit: SHARD_RETRY_LIMIT,
            heartbeat: Duration::from_secs(10),
            fault: None,
        }
    }
}

/// One shard-sequence range awaiting execution. `hi = usize::MAX` on
/// the last range absorbs the tail of the stream (the shard count is
/// an estimate — empty shards consume no sequence numbers).
#[derive(Clone, Debug)]
struct RangeJob {
    lo: usize,
    hi: usize,
    /// how many owners this range has already outlived
    reassignments: usize,
}

impl RangeJob {
    fn describe(&self) -> String {
        if self.hi == usize::MAX {
            format!("[{}, end)", self.lo)
        } else {
            format!("[{}, {})", self.lo, self.hi)
        }
    }
}

/// Shared work-queue state (guarded by one mutex, signalled by one
/// condvar — same discipline as the in-process reorder buffer).
struct Queue {
    pending: VecDeque<RangeJob>,
    completed: usize,
    total: usize,
}

/// Run a distributed sketch: returns the final coreset and stream
/// stats, bit-identical to the in-process pipeline on the same
/// `(dataset, total, shard, knobs, seed)`. All degradation events —
/// the workers' data-level ones and the coordinator's transport-level
/// ones — are recorded into `sink`, each only once its range/run
/// actually completes.
pub fn run_distributed(
    cfg: &DistConfig,
    sink: &DegradeSink,
) -> Result<(WeightedRows, StreamStats), ApiError> {
    if cfg.workers.is_empty() {
        return Err(ApiError::config("workers", "at least one worker address is required"));
    }
    if cfg.shard == 0 {
        return Err(ApiError::config("shard", "shard size must be ≥ 1"));
    }
    if cfg.retry_limit == 0 {
        return Err(ApiError::config("retry_limit", "must be ≥ 1"));
    }
    let sw = Stopwatch::start();

    // one contiguous range per worker (fewer if the stream is short);
    // contiguous ranges keep every worker's stream walk a single
    // prefix + slice, and the fold below re-serializes them in order
    let est_shards = cfg.total.div_ceil(cfg.shard).max(1);
    let n_ranges = cfg.workers.len().min(est_shards);
    let span = est_shards.div_ceil(n_ranges);
    let jobs: VecDeque<RangeJob> = (0..n_ranges)
        .map(|i| RangeJob {
            lo: i * span,
            hi: if i + 1 == n_ranges { usize::MAX } else { (i + 1) * span },
            reassignments: 0,
        })
        .collect();

    let queue = Mutex::new(Queue { pending: jobs, completed: 0, total: n_ranges });
    let work_cv = Condvar::new();
    let abort = AtomicBool::new(false);
    let alive = AtomicUsize::new(cfg.workers.len());
    // first fatal error wins; later ones are dropped (the run is
    // already aborting)
    let error: Mutex<Option<ApiError>> = Mutex::new(None);
    // seq → (leaf, n_raw); duplicate re-executions are bit-identical,
    // so or_insert keeps whichever landed first
    let leaves: Mutex<BTreeMap<usize, (WeightedRows, usize)>> = Mutex::new(BTreeMap::new());
    let fault = cfg.fault.clone().map(FaultState::new);

    std::thread::scope(|s| {
        for (widx, addr) in cfg.workers.iter().enumerate() {
            let queue = &queue;
            let work_cv = &work_cv;
            let abort = &abort;
            let alive = &alive;
            let error = &error;
            let leaves = &leaves;
            let fault = fault.as_ref();
            s.spawn(move || {
                drive_worker(
                    cfg, addr, widx, queue, work_cv, abort, alive, error, leaves, fault, sink,
                );
            });
        }
    });

    if let Some(err) = error.lock().unwrap_or_else(|e| e.into_inner()).take() {
        return Err(err);
    }

    // fold in strict sequence order — the same fixed tree as the
    // in-process reducer, with the same serial reducer pool
    let collected = std::mem::take(&mut *leaves.lock().unwrap_or_else(|e| e.into_inner()));
    let mut mr = MergeReduce::new(cfg.method, cfg.k, cfg.d, cfg.eps, cfg.seed);
    mr.buffer_factor = cfg.buffer_factor;
    mr.sink = sink.clone();
    mr.pool = Pool::new(1);
    let n_shards = collected.len();
    for (expect, (&seq, _)) in collected.iter().enumerate() {
        if seq != expect {
            return Err(StreamError {
                shard_seq: Some(expect),
                consumer: None,
                message: format!(
                    "lost shard sequence numbers: expected {expect}, next collected leaf is {seq}"
                ),
            }
            .into());
        }
    }
    for (seq, (leaf, n_raw)) in collected {
        mr.push_reduced(leaf, n_raw).map_err(|e| {
            ApiError::from(StreamError {
                shard_seq: Some(seq),
                consumer: None,
                message: format!("tree reduce failed: {e}"),
            })
        })?;
    }
    let (n_seen, n_reduces) = (mr.n_seen, mr.n_reduces);
    let coreset = mr.finish().map_err(|e| {
        ApiError::from(StreamError {
            shard_seq: None,
            consumer: None,
            message: format!("final tree collapse failed: {e}"),
        })
    })?;
    let stats = StreamStats {
        n_seen,
        n_shards,
        n_reduces,
        coreset_size: coreset.len(),
        seconds: sw.secs(),
        // queue/reorder depth are in-process backpressure gauges; the
        // distributed path has neither structure
        peak_queue: 0,
        peak_reorder: 0,
    };
    Ok((coreset, stats))
}

/// One coordinator thread: pop ranges off the shared queue and drive
/// one worker through them until the work is done, the run aborts, or
/// this worker exhausts its transport budget (→ reassignment).
#[allow(clippy::too_many_arguments)]
fn drive_worker(
    cfg: &DistConfig,
    addr: &str,
    widx: usize,
    queue: &Mutex<Queue>,
    work_cv: &Condvar,
    abort: &AtomicBool,
    alive: &AtomicUsize,
    error: &Mutex<Option<ApiError>>,
    leaves: &Mutex<BTreeMap<usize, (WeightedRows, usize)>>,
    fault: Option<&FaultState>,
    sink: &DegradeSink,
) {
    loop {
        // ---- claim a range (or find the run finished/aborted) ----
        let job = {
            let mut q = queue.lock().unwrap_or_else(|e| e.into_inner());
            loop {
                if abort.load(Ordering::SeqCst) || q.completed >= q.total {
                    return;
                }
                if let Some(job) = q.pending.pop_front() {
                    break job;
                }
                q = work_cv.wait(q).unwrap_or_else(|e| e.into_inner());
            }
        };

        // ---- execute it with a bounded transport-retry budget ----
        let mut outcome = Err(TransportError::Transient("no attempt ran".into()));
        let mut retries = 0usize;
        for attempt in 0..=cfg.retry_limit {
            if abort.load(Ordering::SeqCst) {
                return;
            }
            if attempt > 0 {
                // short, bounded backoff: a crashed worker needs a
                // moment to matter either way, but wall-clock must stay
                // off the determinism path (and it does — timing only
                // decides WHO re-executes, and re-execution is
                // bit-identical)
                std::thread::sleep(Duration::from_millis((50 << (attempt - 1)).min(500)));
            }
            match attempt_range(cfg, addr, widx, &job, fault) {
                Ok((range_leaves, done)) => {
                    retries = attempt;
                    outcome = Ok((range_leaves, done));
                    break;
                }
                Err(TransportError::Fatal(m)) => {
                    outcome = Err(TransportError::Fatal(m));
                    break;
                }
                Err(TransportError::Transient(m)) => {
                    outcome = Err(TransportError::Transient(m));
                }
            }
        }

        match outcome {
            Ok((range_leaves, done)) => {
                {
                    let mut lv = leaves.lock().unwrap_or_else(|e| e.into_inner());
                    for (seq, leaf, n_raw) in range_leaves {
                        lv.entry(seq).or_insert((leaf, n_raw));
                    }
                }
                // success-only accounting, in one batch per range: the
                // worker's data-level record, then this range's
                // transport recoveries — nothing reaches the run's
                // sink until the range is actually delivered
                sink.merge_record(&done.degradations);
                if retries > 0 {
                    sink.worker_retries(retries);
                }
                if job.reassignments > 0 {
                    sink.range_reassignments(job.reassignments);
                }
                let mut q = queue.lock().unwrap_or_else(|e| e.into_inner());
                q.completed += 1;
                drop(q);
                work_cv.notify_all();
            }
            Err(TransportError::Fatal(msg)) => {
                set_error(
                    error,
                    ApiError::Stream {
                        shard_seq: Some(job.lo),
                        consumer: Some(widx),
                        source: Box::new(ApiError::Data(format!(
                            "worker {addr}, range {}: {msg}",
                            job.describe()
                        ))),
                    },
                );
                abort.store(true, Ordering::SeqCst);
                // take the queue lock before notifying so a thread
                // between its abort check and its wait cannot miss the
                // wakeup (same discipline as the pipeline's fail())
                let _q = queue.lock().unwrap_or_else(|e| e.into_inner());
                drop(_q);
                work_cv.notify_all();
                return;
            }
            Err(TransportError::Transient(msg)) => {
                if abort.load(Ordering::SeqCst) {
                    return;
                }
                // budget exhausted: this worker is dead. Reassign its
                // range — unless it was the last one standing, in which
                // case surface a typed error rather than spin forever.
                let remaining = alive.fetch_sub(1, Ordering::SeqCst) - 1;
                let mut q = queue.lock().unwrap_or_else(|e| e.into_inner());
                let incomplete = q.completed < q.total;
                q.pending.push_back(RangeJob {
                    reassignments: job.reassignments + 1,
                    ..job
                });
                if remaining == 0 && incomplete {
                    set_error(
                        error,
                        ApiError::Stream {
                            shard_seq: Some(job.lo),
                            consumer: Some(widx),
                            source: Box::new(ApiError::Data(format!(
                                "every worker exhausted its transport retry budget \
                                 (last failure on {addr}, range {}: {msg})",
                                job.describe()
                            ))),
                        },
                    );
                    abort.store(true, Ordering::SeqCst);
                }
                drop(q);
                work_cv.notify_all();
                return;
            }
        }
    }
}

/// One connection attempt at one range: connect, handshake, send the
/// job, collect leaves until `Done`, release the worker.
fn attempt_range(
    cfg: &DistConfig,
    addr: &str,
    widx: usize,
    job: &RangeJob,
    fault: Option<&FaultState>,
) -> Result<(Vec<(usize, WeightedRows, usize)>, DoneReport), TransportError> {
    let target = resolve(addr)?;
    let mut stream = TcpStream::connect_timeout(&target, cfg.heartbeat)
        .map_err(|e| TransportError::Transient(format!("connecting to {addr}: {e}")))?;
    stream
        .set_read_timeout(Some(cfg.heartbeat))
        .and_then(|_| stream.set_write_timeout(Some(cfg.heartbeat)))
        .map_err(|e| TransportError::Transient(format!("configuring socket to {addr}: {e}")))?;
    let _ = stream.set_nodelay(true);

    write_frame(&mut stream, FrameKind::Hello, &hello_payload())?;
    let reply = recv(&mut stream, fault, widx)?;
    match reply.kind {
        FrameKind::Hello => check_hello(&reply.payload)?,
        FrameKind::Error => return Err(wire_error(&reply)?),
        other => {
            return Err(TransportError::Fatal(format!(
                "expected Hello reply from {addr}, got {other:?}"
            )))
        }
    }

    let spec = JobSpec {
        dataset: cfg.dataset.clone(),
        total: cfg.total,
        shard: cfg.shard,
        lo: job.lo,
        hi: job.hi,
        method: cfg.method.name().to_string(),
        k: cfg.k,
        d: cfg.d,
        eps: cfg.eps,
        seed: cfg.seed,
        buffer_factor: cfg.buffer_factor,
        on_invalid: cfg.on_invalid,
        retry_limit: cfg.retry_limit,
        heartbeat_ms: cfg.heartbeat.as_millis().max(2) as u64,
    };
    write_frame(&mut stream, FrameKind::Job, &spec.to_payload())?;

    let mut out = Vec::new();
    loop {
        let frame = recv(&mut stream, fault, widx)?;
        match frame.kind {
            // worker liveness while it sketches a long range
            FrameKind::Ping => write_frame(&mut stream, FrameKind::Pong, &[])?,
            FrameKind::Pong => {}
            FrameKind::Leaf => out.push(parse_leaf(&frame.payload)?),
            FrameKind::Done => {
                let done = DoneReport::from_payload(&frame.payload)?;
                if done.leaves != out.len() {
                    // a frame went missing without tripping the
                    // checksum path — treat the range as not delivered
                    return Err(TransportError::Transient(format!(
                        "worker sent {} leaves but reported {} — range re-executes",
                        out.len(),
                        done.leaves
                    )));
                }
                // best-effort: a failed release only costs the worker
                // its idle timeout
                let _ = write_frame(&mut stream, FrameKind::Release, &[]);
                return Ok((out, done));
            }
            FrameKind::Error => return Err(wire_error(&frame)?),
            other => {
                return Err(TransportError::Fatal(format!(
                    "unexpected {other:?} frame from worker {addr}"
                )))
            }
        }
    }
}

fn recv(
    stream: &mut TcpStream,
    fault: Option<&FaultState>,
    widx: usize,
) -> Result<Frame, TransportError> {
    match fault {
        Some(f) => f.recv(stream, widx),
        None => read_frame(stream),
    }
}

/// Decode a worker's `Error` frame into the matching transport error
/// (preserving its transient/fatal type and shard provenance).
fn wire_error(frame: &Frame) -> Result<TransportError, TransportError> {
    let we = WireError::from_payload(&frame.payload)?;
    let msg = match we.seq {
        Some(seq) => format!("worker job failed at shard {seq}: {}", we.message),
        None => format!("worker job failed: {}", we.message),
    };
    Ok(if we.fatal { TransportError::Fatal(msg) } else { TransportError::Transient(msg) })
}

fn resolve(addr: &str) -> Result<SocketAddr, TransportError> {
    // resolution failures are fatal: retrying a name that doesn't
    // parse cannot succeed, and a typo should fail loudly
    addr.to_socket_addrs()
        .map_err(|e| TransportError::Fatal(format!("unresolvable worker address `{addr}`: {e}")))?
        .next()
        .ok_or_else(|| {
            TransportError::Fatal(format!("worker address `{addr}` resolved to nothing"))
        })
}

fn set_error(slot: &Mutex<Option<ApiError>>, err: ApiError) {
    let mut guard = slot.lock().unwrap_or_else(|e| e.into_inner());
    if guard.is_none() {
        *guard = Some(err);
    }
}
