//! The builder → session → fitted-model pipeline: one typed entry point
//! from a data source to a query-serving fitted MCTM.
//!
//! * [`SessionBuilder`] — validated knobs (method via the strategy
//!   registry, budget, threads, seed, streaming queue/buffer, basis
//!   options, the invalid-data policy `on_invalid`). `build()` returns
//!   a typed [`ApiError`] instead of panicking or stringly failing.
//! * [`Session`] — an immutable, reusable recipe. `fit(source)` picks
//!   the batch or the Merge & Reduce path automatically from what the
//!   [`DataSource`] resolves to; `coreset(source)` runs only the
//!   sketching half (no optimization) and returns a [`CoresetReport`].
//! * [`FittedModel`] — the query surface: joint log-density, full-data
//!   NLL, per-margin CDF / quantile, (conditional) sampling, and
//!   [`Diagnostics`] carrying the coreset + stream statistics. It owns
//!   all of its state (`Send + Sync`), so one fitted model can serve
//!   concurrent read-side queries from many threads.
//!
//! Determinism: a session is a pure function of (knobs, source). The
//! same seed gives bit-identical coresets at any `threads` /
//! `consumers` setting — the worker pool only changes wall-clock time,
//! never results (pinned by `tests/api_facade.rs` and the invariant
//! suites).

use super::error::ApiError;
use super::source::{DataSource, SourceInput};
use crate::basis::{Bernstein, Design, Scaler};
use crate::coordinator::pipeline::{StreamingPipeline, StreamStats};
use crate::coreset::samplers::build_coreset_on;
use crate::coreset::{Coreset, Method};
use crate::data::{scrub_invalid, InvalidPolicy};
use crate::fit::{fit_native_with_sink, FitOptions, OptimizerKind};
use crate::linalg::Mat;
use crate::util::degrade::{DegradeSink, Degradations};
use crate::mctm::{self, density, ModelSpec, Params};
use crate::util::parallel::{self, Pool};
use crate::util::rng::Rng;
use crate::util::special::{norm_cdf, norm_quantile};
use crate::util::Stopwatch;
use std::borrow::Cow;

/// Builder for a [`Session`]. Every knob is validated in [`Self::build`];
/// invalid values surface as typed [`ApiError::Config`] /
/// [`ApiError::UnknownMethod`] instead of panics.
#[derive(Clone, Debug)]
pub struct SessionBuilder {
    method_name: Option<String>,
    method_tag: Method,
    budget: usize,
    basis_size: usize,
    scale_eps: f64,
    seed: u64,
    threads: Option<usize>,
    consumers: Option<usize>,
    queue_cap: usize,
    buffer_factor: usize,
    on_invalid: InvalidPolicy,
    fit: FitOptions,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        SessionBuilder {
            method_name: None,
            method_tag: Method::L2Hull,
            budget: 100,
            basis_size: 7,
            scale_eps: 0.01,
            seed: 0xC0FF_EE,
            threads: None,
            consumers: None,
            queue_cap: 4,
            buffer_factor: 4,
            on_invalid: InvalidPolicy::Error,
            fit: FitOptions::default(),
        }
    }
}

impl SessionBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Sampling method by registry name (`"l2-hull"`, `"ellipsoid"`, …).
    /// Resolution happens in [`Self::build`]; an unknown name fails with
    /// an error listing every registered name.
    pub fn method(mut self, name: &str) -> Self {
        self.method_name = Some(name.to_string());
        self
    }

    /// Sampling method by tag (for callers that already hold a
    /// validated [`Method`], e.g. the experiment harness).
    pub fn method_tag(mut self, method: Method) -> Self {
        self.method_name = None;
        self.method_tag = method;
        self
    }

    /// Coreset budget k (target number of kept observations).
    pub fn budget(mut self, k: usize) -> Self {
        self.budget = k;
        self
    }

    /// Bernstein basis size d (degree d − 1) per margin.
    pub fn basis_size(mut self, d: usize) -> Self {
        self.basis_size = d;
        self
    }

    /// Min–max scaling margin ε: raw data maps into [ε, 1 − ε] (the
    /// paper's negative-value correction).
    pub fn scale_eps(mut self, eps: f64) -> Self {
        self.scale_eps = eps;
        self
    }

    /// RNG seed — the only source of randomness in a session.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Worker threads for the parallel kernels. Omit for auto
    /// (`MCTM_THREADS` / available parallelism). Thread count never
    /// changes results, only wall-clock time.
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = Some(n);
        self
    }

    /// Streaming consumer workers running leaf reduces in parallel.
    /// Omit for auto. Results do not depend on this.
    pub fn consumers(mut self, n: usize) -> Self {
        self.consumers = Some(n);
        self
    }

    /// Bounded shard-queue capacity (streaming backpressure).
    pub fn queue_cap(mut self, cap: usize) -> Self {
        self.queue_cap = cap;
        self
    }

    /// Merge & Reduce intermediate-level size multiplier (accuracy vs
    /// memory).
    pub fn buffer_factor(mut self, f: usize) -> Self {
        self.buffer_factor = f;
        self
    }

    /// What to do with non-finite (NaN/±inf) cells at ingestion: reject
    /// the run with a typed error naming the offending shard/row/column
    /// (the default), zero out affected rows, or drop them. Every
    /// masked/dropped row is counted into
    /// [`CoresetReport::degradations`].
    pub fn on_invalid(mut self, policy: InvalidPolicy) -> Self {
        self.on_invalid = policy;
        self
    }

    /// Full optimizer configuration.
    pub fn fit_options(mut self, opts: FitOptions) -> Self {
        self.fit = opts;
        self
    }

    /// Optimizer choice (shorthand for the common `fit_options` edit).
    pub fn optimizer(mut self, kind: OptimizerKind) -> Self {
        self.fit.optimizer = kind;
        self
    }

    /// Iteration cap (shorthand for the common `fit_options` edit).
    pub fn max_iters(mut self, n: usize) -> Self {
        self.fit.max_iters = n;
        self
    }

    /// Validate every knob and produce the immutable [`Session`].
    pub fn build(self) -> Result<Session, ApiError> {
        let method = match &self.method_name {
            Some(name) => {
                Method::parse(name).map_err(|_| ApiError::unknown_method(name.clone()))?
            }
            None => self.method_tag,
        };
        if self.budget == 0 {
            return Err(ApiError::config("budget", "must be ≥ 1"));
        }
        if self.basis_size < 2 {
            return Err(ApiError::config("basis_size", "must be ≥ 2"));
        }
        if self.scale_eps <= 0.0 || self.scale_eps >= 0.5 {
            return Err(ApiError::config("scale_eps", "must lie in (0, 0.5)"));
        }
        if self.threads == Some(0) {
            return Err(ApiError::config(
                "threads",
                "must be ≥ 1 (omit the call for auto)",
            ));
        }
        if self.consumers == Some(0) {
            return Err(ApiError::config(
                "consumers",
                "must be ≥ 1 (omit the call for auto)",
            ));
        }
        if self.queue_cap == 0 {
            return Err(ApiError::config("queue_cap", "must be ≥ 1"));
        }
        if self.buffer_factor == 0 {
            return Err(ApiError::config("buffer_factor", "must be ≥ 1"));
        }
        if self.fit.max_iters == 0 {
            return Err(ApiError::config("max_iters", "must be ≥ 1"));
        }
        Ok(Session {
            method,
            budget: self.budget,
            d: self.basis_size,
            eps: self.scale_eps,
            seed: self.seed,
            threads: self.threads.unwrap_or(0),
            consumers: self.consumers.unwrap_or(0),
            queue_cap: self.queue_cap,
            buffer_factor: self.buffer_factor,
            on_invalid: self.on_invalid,
            fit: self.fit,
        })
    }
}

/// An immutable, reusable fitting recipe produced by [`SessionBuilder`].
#[derive(Clone, Debug)]
pub struct Session {
    method: Method,
    budget: usize,
    d: usize,
    eps: f64,
    seed: u64,
    /// 0 = auto
    threads: usize,
    /// 0 = auto
    consumers: usize,
    queue_cap: usize,
    buffer_factor: usize,
    on_invalid: InvalidPolicy,
    fit: FitOptions,
}

/// Salted seed for resolving generator-backed sources: the RNG stream
/// that realizes the data must be independent of the stream that
/// samples the coreset (both derive from the session seed, but through
/// different expansions — `Rng::new` seeds via SplitMix64, so any
/// distinct input yields an uncorrelated sequence).
fn source_seed(seed: u64) -> u64 {
    seed ^ 0xA076_1D64_78BD_642F
}

/// What the sketching half produced, before any optimization. The
/// batch variant keeps the source's [`Cow`]: borrowed sources flow
/// through the report zero-copy.
enum Sketch<'a> {
    Batch {
        data: Cow<'a, Mat>,
        design: Design,
        cs: Coreset,
        seconds: f64,
    },
    Stream {
        rows: Mat,
        weights: Vec<f64>,
        /// hull-provenance count threaded up from the reduce tree
        n_hull: usize,
        stats: StreamStats,
        j: usize,
        seconds: f64,
    },
}

impl Session {
    /// Entry point mirroring [`SessionBuilder::new`].
    pub fn builder() -> SessionBuilder {
        SessionBuilder::new()
    }

    pub fn method(&self) -> Method {
        self.method
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    fn pool(&self) -> Pool {
        if self.threads > 0 {
            Pool::new(self.threads)
        } else {
            Pool::current()
        }
    }

    /// Build only the coreset — the sketching half of [`Self::fit`],
    /// without the optimization. Batch sources get a one-shot
    /// importance sample over the full design; shard sources stream
    /// through Merge & Reduce with bounded memory.
    pub fn coreset<S: DataSource>(&self, source: S) -> Result<CoresetReport, ApiError> {
        let sink = DegradeSink::new();
        Ok(match self.sketch(source, &sink)? {
            Sketch::Batch { data, cs, seconds, .. } => {
                self.batch_report(&data, &cs, seconds, &sink)
            }
            Sketch::Stream { rows, weights, n_hull, stats, seconds, .. } => {
                self.stream_report(rows, weights, n_hull, stats, seconds, &sink)
            }
        })
    }

    /// Build the coreset, fit the MCTM on it, and return the
    /// query-serving [`FittedModel`].
    ///
    /// The reports are assembled *after* the optimization, so
    /// [`CoresetReport::degradations`] covers the whole run: sketch-side
    /// events (ridge-jitter recoveries, scrubbed rows, shard retries)
    /// and fit-side ones (line-search failures) alike.
    pub fn fit<S: DataSource>(&self, source: S) -> Result<FittedModel, ApiError> {
        let sink = DegradeSink::new();
        match self.sketch(source, &sink)? {
            Sketch::Batch { data, design, cs, seconds } => {
                let spec = ModelSpec::new(design.j, self.d);
                let sub = design.select(&cs.indices);
                let fit =
                    fit_native_with_sink(spec, &sub, cs.weights.clone(), &self.fit, &sink);
                let report = self.batch_report(&data, &cs, seconds, &sink);
                Ok(FittedModel::assemble(spec, fit, design.scaler.clone(), report))
            }
            Sketch::Stream { rows, weights, n_hull, stats, j, seconds } => {
                let pool = self.pool();
                let design = Design::build_on(&rows, self.d, self.eps, &pool);
                let spec = ModelSpec::new(j, self.d);
                let fit =
                    fit_native_with_sink(spec, &design, weights.clone(), &self.fit, &sink);
                let scaler = design.scaler.clone();
                let report = self.stream_report(rows, weights, n_hull, stats, seconds, &sink);
                Ok(FittedModel::assemble(spec, fit, scaler, report))
            }
        }
    }

    fn sketch<'a, S: DataSource + 'a>(
        &self,
        source: S,
        sink: &DegradeSink,
    ) -> Result<Sketch<'a>, ApiError> {
        match source.into_input(source_seed(self.seed))? {
            SourceInput::Batch(data) => {
                if data.rows == 0 {
                    return Err(ApiError::Data("batch source produced no rows".into()));
                }
                if data.cols == 0 {
                    return Err(ApiError::Data("batch source has zero columns".into()));
                }
                let data = scrub_batch(data, self.on_invalid, sink)?;
                if data.rows == 0 {
                    return Err(ApiError::Data(
                        "batch source has no finite rows left after drop-row scrubbing".into(),
                    ));
                }
                let pool = self.pool();
                let design = Design::build_on(&data, self.d, self.eps, &pool);
                // time only the sampling itself (scores + draw), keeping
                // the paper tables' sampling-time column comparable with
                // the pre-facade harness, which shared one design build
                let sw = Stopwatch::start();
                let mut rng = Rng::new(self.seed);
                let cs =
                    build_coreset_on(&design, self.method, self.budget, &mut rng, &pool, sink);
                let seconds = sw.secs();
                Ok(Sketch::Batch { data, design, cs, seconds })
            }
            SourceInput::Stream(shards) => {
                let j = shards.dim();
                if j == 0 {
                    return Err(ApiError::Data("shard source has zero columns".into()));
                }
                let sw = Stopwatch::start();
                let mut pipeline =
                    StreamingPipeline::assemble(self.method, self.budget, self.d);
                pipeline.eps = self.eps;
                pipeline.seed = self.seed;
                pipeline.queue_cap = self.queue_cap;
                pipeline.buffer_factor = self.buffer_factor;
                pipeline.on_invalid = self.on_invalid;
                pipeline.sink = sink.clone();
                pipeline.consumers = if self.consumers > 0 {
                    self.consumers
                } else if self.threads > 0 {
                    self.threads
                } else {
                    parallel::threads()
                };
                // a StreamError converts into ApiError::Stream with its
                // shard/consumer provenance intact
                let (out, stats) = pipeline.run(shards)?;
                let seconds = sw.secs();
                if out.is_empty() {
                    return Err(ApiError::Data("shard stream produced no rows".into()));
                }
                Ok(Sketch::Stream {
                    n_hull: out.n_hull,
                    rows: out.rows,
                    weights: out.weights,
                    stats,
                    j,
                    seconds,
                })
            }
        }
    }

    fn batch_report(
        &self,
        data: &Mat,
        cs: &Coreset,
        seconds: f64,
        sink: &DegradeSink,
    ) -> CoresetReport {
        CoresetReport {
            method: cs.method.name(),
            requested: self.budget,
            size: cs.len(),
            n_hull: cs.n_hull,
            total_weight: cs.total_weight(),
            n_seen: data.rows,
            indices: Some(cs.indices.clone()),
            rows: data.select_rows(&cs.indices),
            weights: cs.weights.clone(),
            stream: None,
            degradations: sink.snapshot(),
            seconds,
        }
    }

    fn stream_report(
        &self,
        rows: Mat,
        weights: Vec<f64>,
        n_hull: usize,
        stats: StreamStats,
        seconds: f64,
        sink: &DegradeSink,
    ) -> CoresetReport {
        CoresetReport {
            method: self.method.name(),
            requested: self.budget,
            size: rows.rows,
            n_hull,
            total_weight: weights.iter().sum(),
            n_seen: stats.n_seen,
            indices: None,
            rows,
            weights,
            stream: Some(stats),
            degradations: sink.snapshot(),
            seconds,
        }
    }
}

/// Apply the session's [`InvalidPolicy`] to a batch source. Clean data
/// passes through untouched (borrowed sources stay zero-copy — the scan
/// never writes); dirty data is scrubbed on an owned copy, or rejected
/// with a typed error under [`InvalidPolicy::Error`].
fn scrub_batch<'a>(
    data: Cow<'a, Mat>,
    policy: InvalidPolicy,
    sink: &DegradeSink,
) -> Result<Cow<'a, Mat>, ApiError> {
    if data.data.iter().all(|x| x.is_finite()) {
        return Ok(data);
    }
    match scrub_invalid(data.into_owned(), policy, sink) {
        Ok(m) => Ok(Cow::Owned(m)),
        Err((row, col)) => Err(ApiError::Data(format!(
            "non-finite value at row {row}, column {col} \
             (policy: error; set on_invalid to mask or drop)"
        ))),
    }
}

/// What the sketching phase produced: the weighted coreset itself plus
/// the statistics both test pins and dashboards want.
#[derive(Clone, Debug)]
pub struct CoresetReport {
    /// registry name of the sampling method
    pub method: &'static str,
    /// the requested budget k
    pub requested: usize,
    /// actual coreset size (≤ k + hull augmentation slack)
    pub size: usize,
    /// points contributed by the convex-hull component. On the batch
    /// path this is the one-shot sampler's hull augmentation; on the
    /// streaming path it is the hull-pinned count of the last reduce
    /// that produced each surviving row, threaded up through the Merge
    /// & Reduce tree (`WeightedRows::n_hull`)
    pub n_hull: usize,
    /// Σ weights — ≈ n for an unbiased construction
    pub total_weight: f64,
    /// raw rows consumed to build this coreset
    pub n_seen: usize,
    /// observation indices into the batch source (`None` when streamed)
    pub indices: Option<Vec<usize>>,
    /// the coreset rows on the original data scale
    pub rows: Mat,
    /// per-row weights aligned with `rows`
    pub weights: Vec<f64>,
    /// streaming statistics (`None` on the batch path)
    pub stream: Option<StreamStats>,
    /// Numerical/robustness fallbacks taken during the run: ridge-jitter
    /// Cholesky recoveries, MVEE non-convergence, uniform score
    /// fallbacks, scrubbed rows, shard retries, … A clean run reports
    /// [`Degradations::is_clean`] — anything else means the result is
    /// still valid but was produced through a documented degradation,
    /// visible here instead of a log line or a panic.
    pub degradations: Degradations,
    /// wall-clock seconds spent sampling: the score computation + draw
    /// on the batch path (excluding the design build, matching the
    /// paper tables' sampling-time column), the whole pipeline run on
    /// the streaming path
    pub seconds: f64,
}

/// Coreset + fit statistics carried by every [`FittedModel`].
#[derive(Clone, Debug)]
pub struct Diagnostics {
    pub coreset: CoresetReport,
    /// NLL of the fitted parameters on the (weighted) coreset
    pub fit_nll: f64,
    pub fit_iters: usize,
    pub fit_seconds: f64,
    pub converged: bool,
}

/// A fitted MCTM with its query surface. Owns all of its state — no
/// borrowed designs, no pool handles — so it is `Send + Sync` and can
/// serve concurrent read-side queries (`log_density`, CDFs, quantiles,
/// sampling with caller-owned RNGs) from many threads at once.
#[derive(Clone, Debug)]
pub struct FittedModel {
    spec: ModelSpec,
    params: Params,
    scaler: Scaler,
    /// cached monotone coefficients ϑ (row-major (j, k))
    theta: Vec<f64>,
    /// cached marginal standard deviations σ_j of h̃(Y)
    sigmas: Vec<f64>,
    diagnostics: Diagnostics,
}

impl FittedModel {
    fn assemble(
        spec: ModelSpec,
        fit: crate::fit::FitResult,
        scaler: Scaler,
        coreset: CoresetReport,
    ) -> FittedModel {
        let theta = fit.params.theta();
        let sigmas = density::marginal_sigmas(&fit.params);
        FittedModel {
            spec,
            theta,
            sigmas,
            scaler,
            diagnostics: Diagnostics {
                coreset,
                fit_nll: fit.nll,
                fit_iters: fit.iters,
                fit_seconds: fit.seconds,
                converged: fit.converged,
            },
            params: fit.params,
        }
    }

    pub fn spec(&self) -> ModelSpec {
        self.spec
    }

    pub fn params(&self) -> &Params {
        &self.params
    }

    pub fn scaler(&self) -> &Scaler {
        &self.scaler
    }

    pub fn diagnostics(&self) -> &Diagnostics {
        &self.diagnostics
    }

    /// Joint log-density at a raw J-vector (original data scale).
    pub fn log_density(&self, y: &[f64]) -> f64 {
        density::log_joint_density(&self.params, &self.scaler, y)
    }

    /// Joint density at a raw J-vector.
    pub fn density(&self, y: &[f64]) -> f64 {
        self.log_density(y).exp()
    }

    /// Marginal density of component `j` at raw value `y` (the shared
    /// formula in `mctm::density`, fed from the cached ϑ and σ).
    pub fn marginal_density(&self, j: usize, y: f64) -> f64 {
        assert!(j < self.spec.j, "margin {j} out of range");
        density::marginal_density_with_sigma(
            &self.theta,
            self.spec.d,
            &self.scaler,
            j,
            y,
            self.sigmas[j],
        )
    }

    /// Marginal CDF F_j(y) of component `j` at raw value `y`.
    pub fn marginal_cdf(&self, j: usize, y: f64) -> f64 {
        assert!(j < self.spec.j, "margin {j} out of range");
        let h = self.htilde(j, self.scaler.scale(j, y));
        norm_cdf(h / self.sigmas[j])
    }

    /// Marginal quantile F_j⁻¹(p) of component `j` (p ∈ (0, 1)). The
    /// transformation lives on the scaled axis, so extreme p saturate
    /// at its endpoints — which [`Scaler::unscale`] maps ~ε/(1 − 2ε)
    /// (≈ 1% at the default ε) beyond the observed data min/max, not
    /// exactly at it. The same applies to tail draws of `sample` /
    /// `sample_conditional`.
    pub fn marginal_quantile(&self, j: usize, p: f64) -> f64 {
        assert!(j < self.spec.j, "margin {j} out of range");
        assert!(p > 0.0 && p < 1.0, "quantile level {p} outside (0, 1)");
        let target = self.sigmas[j] * norm_quantile(p);
        let x = self.invert_htilde(j, target);
        self.scaler.unscale(j, x)
    }

    /// Draw `n` joint samples on the original data scale.
    pub fn sample(&self, n: usize, rng: &mut Rng) -> Mat {
        self.sample_conditional(&[], n, rng)
    }

    /// Draw `n` samples of the remaining components given the first
    /// `given.len()` components (the MCTM's triangular structure makes
    /// this exact: conditioning fixes h̃ of the given margins, and the
    /// remaining latent z's stay independent standard normals). Returns
    /// full J-column rows with the given values copied into place.
    pub fn sample_conditional(&self, given: &[f64], n: usize, rng: &mut Rng) -> Mat {
        let j = self.spec.j;
        let m = given.len();
        assert!(m <= j, "conditioning on {m} > J = {j} components");
        let mut buf = vec![0.0; self.spec.d];
        let mut base_h = vec![0.0; j];
        for (l, &y) in given.iter().enumerate() {
            base_h[l] = self.htilde_into(l, self.scaler.scale(l, y), &mut buf);
        }
        let mut out = Mat::zeros(n, j);
        let mut h = vec![0.0; j];
        for r in 0..n {
            h.copy_from_slice(&base_h);
            for (l, &y) in given.iter().enumerate() {
                *out.at_mut(r, l) = y;
            }
            for jj in m..j {
                let mut target = rng.normal();
                for l in 0..jj {
                    target -= self.params.lambda(jj, l) * h[l];
                }
                let x = self.invert_htilde(jj, target);
                h[jj] = self.htilde_into(jj, x, &mut buf);
                *out.at_mut(r, jj) = self.scaler.unscale(jj, x);
            }
        }
        out
    }

    /// Weighted-sum NLL of this model's parameters on `data` (original
    /// scale, `data.cols == J`). The design is rebuilt with the model's
    /// own scaler, so parameters fitted on a streamed coreset evaluate
    /// correctly on any other sample of the same distribution.
    pub fn nll(&self, data: &Mat) -> f64 {
        assert_eq!(data.cols, self.spec.j, "data J mismatch");
        let design = Design::build_with_scaler(data, self.spec.d, self.scaler.clone());
        mctm::nll(&design, &[], &self.params)
    }

    #[inline]
    fn theta_row(&self, j: usize) -> &[f64] {
        &self.theta[j * self.spec.d..(j + 1) * self.spec.d]
    }

    /// h̃_j at scaled coordinate x ∈ [0, 1].
    fn htilde(&self, j: usize, x: f64) -> f64 {
        let mut buf = vec![0.0; self.spec.d];
        self.htilde_into(j, x, &mut buf)
    }

    /// h̃_j evaluated through a caller-owned basis buffer (`len == d`),
    /// so the bisection and sampling loops reuse one allocation across
    /// all their iterations.
    #[inline]
    fn htilde_into(&self, j: usize, x: f64, buf: &mut [f64]) -> f64 {
        Bernstein::new(self.spec.d - 1).eval_into(x, buf);
        buf.iter().zip(self.theta_row(j)).map(|(ai, ti)| ai * ti).sum()
    }

    /// Invert the strictly increasing h̃_j over the scaled axis by
    /// bisection; targets outside the transformation's range clamp to
    /// the support edges.
    fn invert_htilde(&self, j: usize, target: f64) -> f64 {
        let th = self.theta_row(j);
        // Bernstein endpoints: h̃(0) = ϑ_0, h̃(1) = ϑ_{d−1}, monotone
        // in between because ϑ is increasing
        if target <= th[0] {
            return 0.0;
        }
        if target >= th[th.len() - 1] {
            return 1.0;
        }
        let mut buf = vec![0.0; self.spec.d];
        let (mut lo, mut hi) = (0.0f64, 1.0f64);
        for _ in 0..64 {
            let mid = 0.5 * (lo + hi);
            if self.htilde_into(j, mid, &mut buf) < target {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        0.5 * (lo + hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dgp::Dgp;

    #[test]
    fn builder_rejects_bad_knobs_with_typed_errors() {
        assert!(matches!(
            SessionBuilder::new().budget(0).build().unwrap_err(),
            ApiError::Config { .. }
        ));
        assert!(matches!(
            SessionBuilder::new().threads(0).build().unwrap_err(),
            ApiError::Config { .. }
        ));
        assert!(matches!(
            SessionBuilder::new().basis_size(1).build().unwrap_err(),
            ApiError::Config { .. }
        ));
        assert!(matches!(
            SessionBuilder::new().scale_eps(0.7).build().unwrap_err(),
            ApiError::Config { .. }
        ));
        assert!(matches!(
            SessionBuilder::new().queue_cap(0).build().unwrap_err(),
            ApiError::Config { .. }
        ));
        let err = SessionBuilder::new().method("not-a-method").build().unwrap_err();
        match &err {
            ApiError::UnknownMethod { valid, .. } => {
                assert_eq!(valid, &crate::coreset::strategy::method_names());
            }
            other => panic!("expected UnknownMethod, got {other:?}"),
        }
    }

    #[test]
    fn builder_resolves_every_registered_name() {
        for m in Method::all() {
            let s = SessionBuilder::new().method(m.name()).build().unwrap();
            assert_eq!(s.method(), m);
        }
    }

    #[test]
    fn session_is_reusable_and_deterministic() {
        let mut rng = Rng::new(5);
        let data = Dgp::NormalMixture.generate(400, &mut rng);
        let session = SessionBuilder::new().budget(40).basis_size(5).seed(11).build().unwrap();
        let a = session.coreset(&data).unwrap();
        let b = session.coreset(&data).unwrap();
        assert_eq!(a.indices, b.indices);
        assert_eq!(a.weights, b.weights);
        assert_eq!(a.n_seen, 400);
        assert!(a.size <= 40 + 5 && a.size > 0);
        assert!(a.stream.is_none());
    }

    #[test]
    fn empty_sources_are_typed_errors() {
        let session = SessionBuilder::new().build().unwrap();
        assert!(matches!(
            session.coreset(Mat::zeros(0, 2)).unwrap_err(),
            ApiError::Data(_)
        ));
    }

    #[test]
    fn fitted_model_is_send_sync() {
        fn check<T: Send + Sync>() {}
        check::<FittedModel>();
        check::<Session>();
        check::<Diagnostics>();
    }

    #[test]
    fn quantile_inverts_cdf() {
        let mut rng = Rng::new(21);
        let data = Dgp::BivariateNormal.generate(2_000, &mut rng);
        let session = SessionBuilder::new()
            .budget(2_000) // identity coreset: fastest exact fit
            .basis_size(6)
            .max_iters(120)
            .seed(3)
            .build()
            .unwrap();
        let model = session.fit(&data).unwrap();
        for &p in &[0.1, 0.25, 0.5, 0.9] {
            for j in 0..2 {
                let y = model.marginal_quantile(j, p);
                let back = model.marginal_cdf(j, y);
                assert!(
                    (back - p).abs() < 1e-3,
                    "margin {j}: F(F⁻¹({p})) = {back}"
                );
            }
        }
        // CDF is monotone and spans (0, 1) over the data range
        assert!(model.marginal_cdf(0, -4.0) < 0.05);
        assert!(model.marginal_cdf(0, 4.0) > 0.95);
    }

    #[test]
    fn sampling_matches_fitted_marginals() {
        let mut rng = Rng::new(33);
        let data = Dgp::BivariateNormal.generate(3_000, &mut rng);
        let session = SessionBuilder::new()
            .budget(3_000)
            .basis_size(6)
            .max_iters(150)
            .seed(4)
            .build()
            .unwrap();
        let model = session.fit(&data).unwrap();
        let draws = model.sample(4_000, &mut rng);
        assert_eq!((draws.rows, draws.cols), (4_000, 2));
        // empirical median of margin 0 ≈ model median
        let mut col: Vec<f64> = (0..draws.rows).map(|r| draws.at(r, 0)).collect();
        col.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let emp_median = col[col.len() / 2];
        let model_median = model.marginal_quantile(0, 0.5);
        assert!(
            (emp_median - model_median).abs() < 0.15,
            "median {emp_median} vs {model_median}"
        );
        // correlated DGP (ρ = 0.7): conditioning on a high y₁ must shift
        // the conditional mean of y₂ upward vs conditioning on a low y₁
        let hi = model.sample_conditional(&[1.5], 800, &mut rng);
        let lo = model.sample_conditional(&[-1.5], 800, &mut rng);
        let mean = |m: &Mat| (0..m.rows).map(|r| m.at(r, 1)).sum::<f64>() / m.rows as f64;
        assert!(hi.rows == 800 && hi.at(0, 0) == 1.5);
        assert!(
            mean(&hi) > mean(&lo) + 0.5,
            "conditional shift missing: {} vs {}",
            mean(&hi),
            mean(&lo)
        );
    }
}
