//! The builder → session → fitted-model pipeline: one typed entry point
//! from a data source to a query-serving fitted MCTM.
//!
//! * [`SessionBuilder`] — validated knobs (method via the strategy
//!   registry, budget, threads, seed, streaming queue/buffer, basis
//!   options, the invalid-data policy `on_invalid`). `build()` returns
//!   a typed [`ApiError`] instead of panicking or stringly failing.
//! * [`Session`] — an immutable, reusable recipe. `fit(source)` picks
//!   the batch or the Merge & Reduce path automatically from what the
//!   [`DataSource`] resolves to; `coreset(source)` runs only the
//!   sketching half (no optimization) and returns a [`CoresetReport`].
//! * [`FittedModel`] — the query surface: joint log-density, full-data
//!   NLL, per-margin CDF / quantile, (conditional) sampling, and
//!   [`Diagnostics`] carrying the coreset + stream statistics. It owns
//!   all of its state (`Send + Sync`), so one fitted model can serve
//!   concurrent read-side queries from many threads.
//!
//! Determinism: a session is a pure function of (knobs, source). The
//! same seed gives bit-identical coresets at any `threads` /
//! `consumers` setting — the worker pool only changes wall-clock time,
//! never results (pinned by `tests/api_facade.rs` and the invariant
//! suites).

use super::error::ApiError;
use super::source::{DataSource, SourceInput};
use crate::basis::{Bernstein, Design, Scaler};
use crate::coordinator::pipeline::{StreamingPipeline, StreamStats};
use crate::coreset::samplers::build_coreset_on;
use crate::coreset::{Coreset, Method};
use crate::data::{scrub_invalid, InvalidPolicy};
use crate::fit::{fit_native_warm_with_sink, fit_native_with_sink, FitOptions, OptimizerKind};
use crate::linalg::simd::{self, KernelBackend};
use crate::linalg::Mat;
use crate::runtime::artifact::{Artifact, ModelArtifact, ScalerState, SketchArtifact};
use crate::util::degrade::{DegradeSink, Degradations};
use crate::mctm::{self, density, ModelSpec, Params};
use crate::util::parallel::{self, Pool};
use crate::util::rng::Rng;
use crate::util::special::{norm_cdf, norm_quantile};
use crate::util::Stopwatch;
use std::borrow::Cow;
use std::path::Path;

/// Builder for a [`Session`]. Every knob is validated in [`Self::build`];
/// invalid values surface as typed [`ApiError::Config`] /
/// [`ApiError::UnknownMethod`] instead of panics.
#[derive(Clone, Debug)]
pub struct SessionBuilder {
    method_name: Option<String>,
    method_tag: Method,
    budget: usize,
    basis_size: usize,
    scale_eps: f64,
    seed: u64,
    threads: Option<usize>,
    consumers: Option<usize>,
    queue_cap: usize,
    buffer_factor: usize,
    shard_retry_limit: usize,
    on_invalid: InvalidPolicy,
    fit: FitOptions,
    kernel_backend: Option<KernelBackend>,
}

impl Default for SessionBuilder {
    fn default() -> Self {
        SessionBuilder {
            method_name: None,
            method_tag: Method::L2Hull,
            budget: 100,
            basis_size: 7,
            scale_eps: 0.01,
            seed: 0xC0FF_EE,
            threads: None,
            consumers: None,
            queue_cap: 4,
            buffer_factor: 4,
            shard_retry_limit: crate::coordinator::pipeline::SHARD_RETRY_LIMIT,
            on_invalid: InvalidPolicy::Error,
            fit: FitOptions::default(),
            kernel_backend: None,
        }
    }
}

impl SessionBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Sampling method by registry name (`"l2-hull"`, `"ellipsoid"`, …).
    /// Resolution happens in [`Self::build`]; an unknown name fails with
    /// an error listing every registered name.
    pub fn method(mut self, name: &str) -> Self {
        self.method_name = Some(name.to_string());
        self
    }

    /// Sampling method by tag (for callers that already hold a
    /// validated [`Method`], e.g. the experiment harness).
    pub fn method_tag(mut self, method: Method) -> Self {
        self.method_name = None;
        self.method_tag = method;
        self
    }

    /// Coreset budget k (target number of kept observations).
    pub fn budget(mut self, k: usize) -> Self {
        self.budget = k;
        self
    }

    /// Bernstein basis size d (degree d − 1) per margin.
    pub fn basis_size(mut self, d: usize) -> Self {
        self.basis_size = d;
        self
    }

    /// Min–max scaling margin ε: raw data maps into [ε, 1 − ε] (the
    /// paper's negative-value correction).
    pub fn scale_eps(mut self, eps: f64) -> Self {
        self.scale_eps = eps;
        self
    }

    /// RNG seed — the only source of randomness in a session.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Worker threads for the parallel kernels. Omit for auto
    /// (`MCTM_THREADS` / available parallelism). Thread count never
    /// changes results, only wall-clock time.
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = Some(n);
        self
    }

    /// Streaming consumer workers running leaf reduces in parallel.
    /// Omit for auto. Results do not depend on this.
    pub fn consumers(mut self, n: usize) -> Self {
        self.consumers = Some(n);
        self
    }

    /// Bounded shard-queue capacity (streaming backpressure).
    pub fn queue_cap(mut self, cap: usize) -> Self {
        self.queue_cap = cap;
        self
    }

    /// Merge & Reduce intermediate-level size multiplier (accuracy vs
    /// memory).
    pub fn buffer_factor(mut self, f: usize) -> Self {
        self.buffer_factor = f;
        self
    }

    /// How many times a transient shard-read error is retried before it
    /// escalates to a fatal stream error (default
    /// [`SHARD_RETRY_LIMIT`](crate::coordinator::pipeline::SHARD_RETRY_LIMIT)).
    /// Retries are attempt-counted, never slept, so retried runs stay
    /// bit-identical to fault-free runs. Also the per-worker transport
    /// retry budget of [`Session::dist_fit`]. Must be ≥ 1.
    pub fn shard_retry_limit(mut self, n: usize) -> Self {
        self.shard_retry_limit = n;
        self
    }

    /// What to do with non-finite (NaN/±inf) cells at ingestion: reject
    /// the run with a typed error naming the offending shard/row/column
    /// (the default), zero out affected rows, or drop them. Every
    /// masked/dropped row is counted into
    /// [`CoresetReport::degradations`].
    pub fn on_invalid(mut self, policy: InvalidPolicy) -> Self {
        self.on_invalid = policy;
        self
    }

    /// Kernel backend for the blocked linear-algebra kernels:
    /// [`KernelBackend::Scalar`] is the bit-exact reference (every
    /// bitwise determinism pin holds), [`KernelBackend::Simd`] the
    /// AVX2+FMA lane kernels (≤ 1e-12 relative agreement, internally
    /// deterministic). Omit for auto (`MCTM_SIMD` env override, else
    /// runtime feature detection). The selection is applied at
    /// [`Self::build`] and is process-global — it pins the dispatch for
    /// every session in this process; a `Simd` request on a host
    /// without AVX2+FMA clamps to `Scalar`.
    pub fn kernel_backend(mut self, backend: KernelBackend) -> Self {
        self.kernel_backend = Some(backend);
        self
    }

    /// Full optimizer configuration.
    pub fn fit_options(mut self, opts: FitOptions) -> Self {
        self.fit = opts;
        self
    }

    /// Optimizer choice (shorthand for the common `fit_options` edit).
    pub fn optimizer(mut self, kind: OptimizerKind) -> Self {
        self.fit.optimizer = kind;
        self
    }

    /// Iteration cap (shorthand for the common `fit_options` edit).
    pub fn max_iters(mut self, n: usize) -> Self {
        self.fit.max_iters = n;
        self
    }

    /// Validate every knob and produce the immutable [`Session`].
    pub fn build(self) -> Result<Session, ApiError> {
        let method = match &self.method_name {
            Some(name) => {
                Method::parse(name).map_err(|_| ApiError::unknown_method(name.clone()))?
            }
            None => self.method_tag,
        };
        if self.budget == 0 {
            return Err(ApiError::config("budget", "must be ≥ 1"));
        }
        if self.basis_size < 2 {
            return Err(ApiError::config("basis_size", "must be ≥ 2"));
        }
        if self.scale_eps <= 0.0 || self.scale_eps >= 0.5 {
            return Err(ApiError::config("scale_eps", "must lie in (0, 0.5)"));
        }
        if self.threads == Some(0) {
            return Err(ApiError::config(
                "threads",
                "must be ≥ 1 (omit the call for auto)",
            ));
        }
        if self.consumers == Some(0) {
            return Err(ApiError::config(
                "consumers",
                "must be ≥ 1 (omit the call for auto)",
            ));
        }
        if self.queue_cap == 0 {
            return Err(ApiError::config("queue_cap", "must be ≥ 1"));
        }
        if self.buffer_factor == 0 {
            return Err(ApiError::config("buffer_factor", "must be ≥ 1"));
        }
        if self.shard_retry_limit == 0 {
            return Err(ApiError::config(
                "shard_retry_limit",
                "must be ≥ 1 (a zero budget would turn every transient fault fatal)",
            ));
        }
        if self.fit.max_iters == 0 {
            return Err(ApiError::config("max_iters", "must be ≥ 1"));
        }
        if let Some(b) = self.kernel_backend {
            simd::set_backend(b);
        }
        Ok(Session {
            method,
            budget: self.budget,
            d: self.basis_size,
            eps: self.scale_eps,
            seed: self.seed,
            threads: self.threads.unwrap_or(0),
            consumers: self.consumers.unwrap_or(0),
            queue_cap: self.queue_cap,
            buffer_factor: self.buffer_factor,
            shard_retry_limit: self.shard_retry_limit,
            on_invalid: self.on_invalid,
            fit: self.fit,
        })
    }
}

/// An immutable, reusable fitting recipe produced by [`SessionBuilder`].
#[derive(Clone, Debug)]
pub struct Session {
    method: Method,
    budget: usize,
    d: usize,
    eps: f64,
    seed: u64,
    /// 0 = auto
    threads: usize,
    /// 0 = auto
    consumers: usize,
    queue_cap: usize,
    buffer_factor: usize,
    shard_retry_limit: usize,
    on_invalid: InvalidPolicy,
    fit: FitOptions,
}

/// Salted seed for resolving generator-backed sources: the RNG stream
/// that realizes the data must be independent of the stream that
/// samples the coreset (both derive from the session seed, but through
/// different expansions — `Rng::new` seeds via SplitMix64, so any
/// distinct input yields an uncorrelated sequence).
/// Crate-visible: distributed workers (`crate::dist`) resolve their
/// dataset through the same salt so an N-worker run replays the exact
/// shard stream the in-process pipeline would see.
pub(crate) fn source_seed(seed: u64) -> u64 {
    seed ^ 0xA076_1D64_78BD_642F
}

/// What the sketching half produced, before any optimization. The
/// batch variant keeps the source's [`Cow`]: borrowed sources flow
/// through the report zero-copy.
enum Sketch<'a> {
    Batch {
        data: Cow<'a, Mat>,
        design: Design,
        cs: Coreset,
        seconds: f64,
    },
    Stream {
        rows: Mat,
        weights: Vec<f64>,
        /// hull-provenance count threaded up from the reduce tree
        n_hull: usize,
        stats: StreamStats,
        j: usize,
        seconds: f64,
    },
}

impl Session {
    /// Entry point mirroring [`SessionBuilder::new`].
    pub fn builder() -> SessionBuilder {
        SessionBuilder::new()
    }

    pub fn method(&self) -> Method {
        self.method
    }

    pub fn budget(&self) -> usize {
        self.budget
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    fn pool(&self) -> Pool {
        if self.threads > 0 {
            Pool::new(self.threads)
        } else {
            Pool::current()
        }
    }

    /// Build only the coreset — the sketching half of [`Self::fit`],
    /// without the optimization. Batch sources get a one-shot
    /// importance sample over the full design; shard sources stream
    /// through Merge & Reduce with bounded memory.
    pub fn coreset<S: DataSource>(&self, source: S) -> Result<CoresetReport, ApiError> {
        let sink = DegradeSink::new();
        Ok(match self.sketch(source, &sink)? {
            Sketch::Batch { data, design, cs, seconds } => {
                self.batch_report(&data, &design.scaler, &cs, seconds, &sink)
            }
            Sketch::Stream { rows, weights, n_hull, stats, seconds, .. } => {
                self.stream_report(rows, weights, n_hull, stats, seconds, &sink)
            }
        })
    }

    /// Build the coreset, fit the MCTM on it, and return the
    /// query-serving [`FittedModel`].
    ///
    /// The reports are assembled *after* the optimization, so
    /// [`CoresetReport::degradations`] covers the whole run: sketch-side
    /// events (ridge-jitter recoveries, scrubbed rows, shard retries)
    /// and fit-side ones (line-search failures) alike.
    pub fn fit<S: DataSource>(&self, source: S) -> Result<FittedModel, ApiError> {
        let sink = DegradeSink::new();
        match self.sketch(source, &sink)? {
            Sketch::Batch { data, design, cs, seconds } => {
                let spec = ModelSpec::new(design.j, self.d);
                let sub = design.select(&cs.indices);
                let fit =
                    fit_native_with_sink(spec, &sub, cs.weights.clone(), &self.fit, &sink);
                let report = self.batch_report(&data, &design.scaler, &cs, seconds, &sink);
                Ok(FittedModel::assemble(spec, fit, design.scaler.clone(), report))
            }
            Sketch::Stream { rows, weights, n_hull, stats, j, seconds } => {
                self.fit_streamed(rows, weights, n_hull, stats, j, seconds, &sink)
            }
        }
    }

    /// Fit on an already-streamed coreset (shared by the in-process
    /// streaming path and the distributed one — the inputs are
    /// bit-identical by construction, so the fits are too).
    #[allow(clippy::too_many_arguments)]
    fn fit_streamed(
        &self,
        rows: Mat,
        weights: Vec<f64>,
        n_hull: usize,
        stats: StreamStats,
        j: usize,
        seconds: f64,
        sink: &DegradeSink,
    ) -> Result<FittedModel, ApiError> {
        let pool = self.pool();
        let design = Design::build_on(&rows, self.d, self.eps, &pool);
        let spec = ModelSpec::new(j, self.d);
        let fit = fit_native_with_sink(spec, &design, weights.clone(), &self.fit, sink);
        let scaler = design.scaler.clone();
        let report = self.stream_report(rows, weights, n_hull, stats, seconds, sink);
        Ok(FittedModel::assemble(spec, fit, scaler, report))
    }

    /// Sketch a named dataset on remote workers (see [`crate::dist`])
    /// — the distributed twin of `coreset(NamedSource::stream(..))`.
    /// Bit-identical to the in-process run at any worker count, with
    /// transport recoveries counted in
    /// [`CoresetReport::degradations`].
    pub fn dist_coreset(
        &self,
        workers: &[String],
        dataset: &str,
        total: usize,
        shard: usize,
    ) -> Result<CoresetReport, ApiError> {
        let sink = DegradeSink::new();
        let (out, stats, seconds) = self.dist_sketch(workers, dataset, total, shard, &sink)?;
        Ok(self.stream_report(out.rows, out.weights, out.n_hull, stats, seconds, &sink))
    }

    /// Sketch a named dataset on remote workers and fit the MCTM on
    /// the gathered coreset — the distributed twin of
    /// `fit(NamedSource::stream(..))`, bit-identical to it even when
    /// workers die mid-run and their ranges are reassigned.
    pub fn dist_fit(
        &self,
        workers: &[String],
        dataset: &str,
        total: usize,
        shard: usize,
    ) -> Result<FittedModel, ApiError> {
        let sink = DegradeSink::new();
        let (out, stats, seconds) = self.dist_sketch(workers, dataset, total, shard, &sink)?;
        let j = out.rows.cols;
        self.fit_streamed(out.rows, out.weights, out.n_hull, stats, j, seconds, &sink)
    }

    /// Shared distributed-sketch driver: session knobs → `DistConfig`
    /// → `run_distributed`, with the same empty-stream check the
    /// in-process path applies.
    fn dist_sketch(
        &self,
        workers: &[String],
        dataset: &str,
        total: usize,
        shard: usize,
        sink: &DegradeSink,
    ) -> Result<(crate::coreset::merge_reduce::WeightedRows, StreamStats, f64), ApiError> {
        let mut cfg = crate::dist::DistConfig::new(
            workers.to_vec(),
            dataset,
            total,
            shard,
            self.method,
            self.budget,
            self.d,
            self.eps,
        );
        cfg.seed = self.seed;
        cfg.buffer_factor = self.buffer_factor;
        cfg.on_invalid = self.on_invalid;
        cfg.retry_limit = self.shard_retry_limit;
        let sw = Stopwatch::start();
        let (out, stats) = crate::dist::run_distributed(&cfg, sink)?;
        let seconds = sw.secs();
        if out.is_empty() {
            return Err(ApiError::Data("shard stream produced no rows".into()));
        }
        Ok((out, stats, seconds))
    }

    fn sketch<'a, S: DataSource + 'a>(
        &self,
        source: S,
        sink: &DegradeSink,
    ) -> Result<Sketch<'a>, ApiError> {
        match source.into_input(source_seed(self.seed))? {
            SourceInput::Batch(data) => {
                if data.rows == 0 {
                    return Err(ApiError::Data("batch source produced no rows".into()));
                }
                if data.cols == 0 {
                    return Err(ApiError::Data("batch source has zero columns".into()));
                }
                let data = scrub_batch(data, self.on_invalid, sink)?;
                if data.rows == 0 {
                    return Err(ApiError::Data(
                        "batch source has no finite rows left after drop-row scrubbing".into(),
                    ));
                }
                let pool = self.pool();
                let design = Design::build_on(&data, self.d, self.eps, &pool);
                // time only the sampling itself (scores + draw), keeping
                // the paper tables' sampling-time column comparable with
                // the pre-facade harness, which shared one design build
                let sw = Stopwatch::start();
                let mut rng = Rng::new(self.seed);
                let cs =
                    build_coreset_on(&design, self.method, self.budget, &mut rng, &pool, sink);
                let seconds = sw.secs();
                Ok(Sketch::Batch { data, design, cs, seconds })
            }
            SourceInput::Stream(shards) => {
                let j = shards.dim();
                if j == 0 {
                    return Err(ApiError::Data("shard source has zero columns".into()));
                }
                let sw = Stopwatch::start();
                let mut pipeline =
                    StreamingPipeline::assemble(self.method, self.budget, self.d);
                pipeline.eps = self.eps;
                pipeline.seed = self.seed;
                pipeline.queue_cap = self.queue_cap;
                pipeline.buffer_factor = self.buffer_factor;
                pipeline.on_invalid = self.on_invalid;
                pipeline.retry_limit = self.shard_retry_limit;
                pipeline.sink = sink.clone();
                pipeline.consumers = if self.consumers > 0 {
                    self.consumers
                } else if self.threads > 0 {
                    self.threads
                } else {
                    parallel::threads()
                };
                // a StreamError converts into ApiError::Stream with its
                // shard/consumer provenance intact
                let (out, stats) = pipeline.run(shards)?;
                let seconds = sw.secs();
                if out.is_empty() {
                    return Err(ApiError::Data("shard stream produced no rows".into()));
                }
                Ok(Sketch::Stream {
                    n_hull: out.n_hull,
                    rows: out.rows,
                    weights: out.weights,
                    stats,
                    j,
                    seconds,
                })
            }
        }
    }

    fn batch_report(
        &self,
        data: &Mat,
        scaler: &Scaler,
        cs: &Coreset,
        seconds: f64,
        sink: &DegradeSink,
    ) -> CoresetReport {
        CoresetReport {
            method: cs.method.name(),
            requested: self.budget,
            size: cs.len(),
            n_hull: cs.n_hull,
            total_weight: cs.total_weight(),
            n_seen: data.rows,
            indices: Some(cs.indices.clone()),
            rows: data.select_rows(&cs.indices),
            weights: cs.weights.clone(),
            // the full-data scaler: what a refit needs to rebuild the
            // sub-design bit-identically without the original data
            scaler: Some(scaler.clone()),
            stream: None,
            degradations: sink.snapshot(),
            seconds,
        }
    }

    fn stream_report(
        &self,
        rows: Mat,
        weights: Vec<f64>,
        n_hull: usize,
        stats: StreamStats,
        seconds: f64,
        sink: &DegradeSink,
    ) -> CoresetReport {
        CoresetReport {
            method: self.method.name(),
            requested: self.budget,
            size: rows.rows,
            n_hull,
            total_weight: weights.iter().sum(),
            n_seen: stats.n_seen,
            indices: None,
            rows,
            weights,
            // streamed fits scale on the coreset rows themselves, so a
            // refit can (and does) rebuild the scaler from `rows`
            scaler: None,
            stream: Some(stats),
            degradations: sink.snapshot(),
            seconds,
        }
    }

    /// Re-fit this session's model from a persisted sketch — the "fit
    /// once, serve forever" path (ROADMAP item 1): load a
    /// [`CoresetReport`] with [`CoresetReport::load`] and serve new
    /// scenarios without ever re-reading the original data.
    ///
    /// Reproducibility: a batch sketch carries the full-data scaler, so
    /// `refit` rebuilds the exact sub-design of the direct
    /// [`Session::fit`] and — for the same session knobs — returns
    /// **bit-identical** parameters. A streamed sketch refits the way
    /// the direct streaming fit does (scaler fit on the coreset rows),
    /// which is likewise bit-identical to it.
    pub fn refit(&self, sketch: &CoresetReport) -> Result<FittedModel, ApiError> {
        self.refit_inner(sketch, None)
    }

    /// [`Session::refit`] warm-started from a previous optimum — the
    /// scenario-serving fast path: load one sketch, then fit many
    /// stress shifts / what-if variants (different `fit_options`,
    /// optimizer budgets, …) cheaply, each starting from the last
    /// model's parameters instead of from scratch. `warm.spec` must
    /// match the sketch's J and this session's basis size.
    pub fn refit_warm(
        &self,
        sketch: &CoresetReport,
        warm: &Params,
    ) -> Result<FittedModel, ApiError> {
        self.refit_inner(sketch, Some(warm))
    }

    fn refit_inner(
        &self,
        sketch: &CoresetReport,
        warm: Option<&Params>,
    ) -> Result<FittedModel, ApiError> {
        let j = sketch.rows.cols;
        if sketch.rows.rows == 0 || j == 0 {
            return Err(ApiError::Data("sketch has no rows to refit on".into()));
        }
        if sketch.weights.len() != sketch.rows.rows {
            return Err(ApiError::Data(format!(
                "sketch has {} rows but {} weights",
                sketch.rows.rows,
                sketch.weights.len()
            )));
        }
        let spec = ModelSpec::new(j, self.d);
        if let Some(p) = warm {
            if p.spec != spec {
                return Err(ApiError::Query(format!(
                    "warm-start params have shape J={} d={}, refit needs J={j} d={}",
                    p.spec.j, p.spec.d, self.d
                )));
            }
        }
        let pool = self.pool();
        let design = match &sketch.scaler {
            Some(s) => {
                if s.mins.len() != j {
                    return Err(ApiError::Data(format!(
                        "sketch scaler covers {} columns, rows have {j}",
                        s.mins.len()
                    )));
                }
                Design::build_with_scaler_on(&sketch.rows, self.d, s.clone(), &pool)
            }
            None => Design::build_on(&sketch.rows, self.d, self.eps, &pool),
        };
        let sink = DegradeSink::new();
        let fit = match warm {
            Some(p) => fit_native_warm_with_sink(
                spec,
                &design,
                sketch.weights.clone(),
                p.x.clone(),
                &self.fit,
                &sink,
            ),
            None => {
                fit_native_with_sink(spec, &design, sketch.weights.clone(), &self.fit, &sink)
            }
        };
        // the refit's diagnostics carry the sketch's provenance plus
        // whatever the optimizer degraded through this run
        let mut report = sketch.clone();
        report.degradations.merge(&sink.snapshot());
        let scaler = design.scaler.clone();
        Ok(FittedModel::assemble(spec, fit, scaler, report))
    }
}

/// Apply the session's [`InvalidPolicy`] to a batch source. Clean data
/// passes through untouched (borrowed sources stay zero-copy — the scan
/// never writes); dirty data is scrubbed on an owned copy, or rejected
/// with a typed error under [`InvalidPolicy::Error`].
fn scrub_batch<'a>(
    data: Cow<'a, Mat>,
    policy: InvalidPolicy,
    sink: &DegradeSink,
) -> Result<Cow<'a, Mat>, ApiError> {
    if data.data.iter().all(|x| x.is_finite()) {
        return Ok(data);
    }
    match scrub_invalid(data.into_owned(), policy, sink) {
        Ok(m) => Ok(Cow::Owned(m)),
        Err((row, col)) => Err(ApiError::Data(format!(
            "non-finite value at row {row}, column {col} \
             (policy: error; set on_invalid to mask or drop)"
        ))),
    }
}

/// What the sketching phase produced: the weighted coreset itself plus
/// the statistics both test pins and dashboards want.
#[derive(Clone, Debug)]
pub struct CoresetReport {
    /// registry name of the sampling method
    pub method: &'static str,
    /// the requested budget k
    pub requested: usize,
    /// actual coreset size (≤ k + hull augmentation slack)
    pub size: usize,
    /// points contributed by the convex-hull component. On the batch
    /// path this is the one-shot sampler's hull augmentation; on the
    /// streaming path it is the hull-pinned count of the last reduce
    /// that produced each surviving row, threaded up through the Merge
    /// & Reduce tree (`WeightedRows::n_hull`)
    pub n_hull: usize,
    /// Σ weights — ≈ n for an unbiased construction
    pub total_weight: f64,
    /// raw rows consumed to build this coreset
    pub n_seen: usize,
    /// observation indices into the batch source (`None` when streamed)
    pub indices: Option<Vec<usize>>,
    /// the coreset rows on the original data scale
    pub rows: Mat,
    /// per-row weights aligned with `rows`
    pub weights: Vec<f64>,
    /// streaming statistics (`None` on the batch path)
    pub stream: Option<StreamStats>,
    /// Numerical/robustness fallbacks taken during the run: ridge-jitter
    /// Cholesky recoveries, MVEE non-convergence, uniform score
    /// fallbacks, scrubbed rows, shard retries, … A clean run reports
    /// [`Degradations::is_clean`] — anything else means the result is
    /// still valid but was produced through a documented degradation,
    /// visible here instead of a log line or a panic.
    pub degradations: Degradations,
    /// Full-data scaler on the batch path (what [`Session::refit`]
    /// needs to rebuild the exact sub-design without the original
    /// data); `None` on the streaming path, where the direct fit
    /// scales on the coreset rows themselves.
    pub scaler: Option<Scaler>,
    /// wall-clock seconds spent sampling: the score computation + draw
    /// on the batch path (excluding the design build, matching the
    /// paper tables' sampling-time column), the whole pipeline run on
    /// the streaming path
    pub seconds: f64,
}

/// `basis::Scaler` → persisted state.
fn scaler_state(s: &Scaler) -> ScalerState {
    ScalerState { eps: s.eps, mins: s.mins.clone(), maxs: s.maxs.clone() }
}

/// Persisted state → `basis::Scaler`.
fn scaler_from_state(s: &ScalerState) -> Scaler {
    Scaler { mins: s.mins.clone(), maxs: s.maxs.clone(), eps: s.eps }
}

/// Resolve a persisted method name against the strategy registry,
/// recovering the `&'static str` the in-memory reports carry.
fn method_name_from_artifact(name: &str) -> Result<&'static str, ApiError> {
    Method::parse(name)
        .map(|m| m.name())
        .map_err(|_| {
            ApiError::Artifact(format!(
                "artifact names unknown sampling method `{name}` \
                 (written by a newer build?)"
            ))
        })
}

impl CoresetReport {
    /// Persisted form of this sketch. Wall-clock fields (`seconds`,
    /// `stream` timings), `indices`, and `degradations` are run
    /// ephemera, deliberately excluded so the artifact bytes are a pure
    /// function of the sketch content (same seed ⇒ same bytes).
    pub fn to_artifact(&self) -> SketchArtifact {
        SketchArtifact {
            method: self.method.to_string(),
            requested: self.requested,
            n_hull: self.n_hull,
            n_seen: self.n_seen,
            rows: self.rows.clone(),
            weights: self.weights.clone(),
            scaler: self.scaler.as_ref().map(scaler_state),
        }
    }

    /// Rebuild a report from its persisted form. Ephemeral fields come
    /// back empty (`seconds = 0`, no `indices` / `stream` /
    /// `degradations`); everything a [`Session::refit`] needs survives.
    /// `total_weight` is recomputed with the same summation the
    /// streaming report uses, so it is bitwise-stable across the trip.
    pub fn from_artifact(a: &SketchArtifact) -> Result<CoresetReport, ApiError> {
        if a.rows.rows == 0 || a.rows.cols == 0 {
            return Err(ApiError::Artifact("sketch artifact has no rows".into()));
        }
        if a.weights.len() != a.rows.rows {
            return Err(ApiError::Artifact(format!(
                "sketch artifact has {} rows but {} weights",
                a.rows.rows,
                a.weights.len()
            )));
        }
        if let Some(s) = &a.scaler {
            if s.mins.len() != a.rows.cols || s.maxs.len() != a.rows.cols {
                return Err(ApiError::Artifact(format!(
                    "sketch artifact scaler covers {} columns, rows have {}",
                    s.mins.len(),
                    a.rows.cols
                )));
            }
        }
        Ok(CoresetReport {
            method: method_name_from_artifact(&a.method)?,
            requested: a.requested,
            size: a.rows.rows,
            n_hull: a.n_hull,
            total_weight: a.weights.iter().sum(),
            n_seen: a.n_seen,
            indices: None,
            rows: a.rows.clone(),
            weights: a.weights.clone(),
            scaler: a.scaler.as_ref().map(scaler_from_state),
            stream: None,
            degradations: Degradations::default(),
            seconds: 0.0,
        })
    }

    /// Persist this sketch (atomic write, checksummed format v1).
    pub fn save(&self, path: &Path) -> Result<(), ApiError> {
        Artifact::Sketch(self.to_artifact()).save(path)
    }

    /// Load a sketch persisted by [`CoresetReport::save`]. A model
    /// artifact at `path` is a typed error, never a misparse.
    pub fn load(path: &Path) -> Result<CoresetReport, ApiError> {
        match Artifact::load(path)? {
            Artifact::Sketch(a) => CoresetReport::from_artifact(&a),
            Artifact::Model(_) => Err(ApiError::Artifact(format!(
                "{} holds a model artifact, not a sketch \
                 (load it with FittedModel::load)",
                path.display()
            ))),
        }
    }
}

/// Coreset + fit statistics carried by every [`FittedModel`].
#[derive(Clone, Debug)]
pub struct Diagnostics {
    pub coreset: CoresetReport,
    /// NLL of the fitted parameters on the (weighted) coreset
    pub fit_nll: f64,
    pub fit_iters: usize,
    pub fit_seconds: f64,
    pub converged: bool,
}

/// A fitted MCTM with its query surface. Owns all of its state — no
/// borrowed designs, no pool handles — so it is `Send + Sync` and can
/// serve concurrent read-side queries (`log_density`, CDFs, quantiles,
/// sampling with caller-owned RNGs) from many threads at once.
#[derive(Clone, Debug)]
pub struct FittedModel {
    spec: ModelSpec,
    params: Params,
    scaler: Scaler,
    /// cached monotone coefficients ϑ (row-major (j, k))
    theta: Vec<f64>,
    /// cached marginal standard deviations σ_j of h̃(Y)
    sigmas: Vec<f64>,
    diagnostics: Diagnostics,
}

impl FittedModel {
    fn assemble(
        spec: ModelSpec,
        fit: crate::fit::FitResult,
        scaler: Scaler,
        coreset: CoresetReport,
    ) -> FittedModel {
        let theta = fit.params.theta();
        let sigmas = density::marginal_sigmas(&fit.params);
        FittedModel {
            spec,
            theta,
            sigmas,
            scaler,
            diagnostics: Diagnostics {
                coreset,
                fit_nll: fit.nll,
                fit_iters: fit.iters,
                fit_seconds: fit.seconds,
                converged: fit.converged,
            },
            params: fit.params,
        }
    }

    pub fn spec(&self) -> ModelSpec {
        self.spec
    }

    pub fn params(&self) -> &Params {
        &self.params
    }

    pub fn scaler(&self) -> &Scaler {
        &self.scaler
    }

    pub fn diagnostics(&self) -> &Diagnostics {
        &self.diagnostics
    }

    /// Persisted form of this model's query state: the free parameter
    /// vector x (ϑ and σ are pure bitwise functions of x, recomputed on
    /// load), the scaler, and the coreset's summary provenance.
    /// Wall-clock fields, coreset rows, and degradation counters are
    /// run ephemera and deliberately excluded, so the artifact bytes
    /// are a pure function of the fitted state (same seed ⇒ same
    /// bytes).
    pub fn to_artifact(&self) -> ModelArtifact {
        let c = &self.diagnostics.coreset;
        ModelArtifact {
            j: self.spec.j,
            d: self.spec.d,
            x: self.params.x.clone(),
            scaler: scaler_state(&self.scaler),
            fit_nll: self.diagnostics.fit_nll,
            fit_iters: self.diagnostics.fit_iters,
            converged: self.diagnostics.converged,
            method: c.method.to_string(),
            requested: c.requested,
            size: c.size,
            n_hull: c.n_hull,
            n_seen: c.n_seen,
            total_weight: c.total_weight,
        }
    }

    /// Rebuild a query-serving model from its persisted form. ϑ and σ
    /// are recomputed from x through the same code the original fit
    /// used, so every query (`log_density`, CDF, quantile, sampling
    /// with the same RNG) is **bitwise identical** to the model that
    /// was saved. Shape-incoherent artifacts are typed errors — this
    /// never panics on bad content.
    pub fn from_artifact(a: &ModelArtifact) -> Result<FittedModel, ApiError> {
        if a.j == 0 || a.d < 2 {
            return Err(ApiError::Artifact(format!(
                "model artifact has invalid shape J={} d={}",
                a.j, a.d
            )));
        }
        let n_params = a.j * a.d + a.j * (a.j - 1) / 2;
        if a.x.len() != n_params {
            return Err(ApiError::Artifact(format!(
                "model artifact J={} d={} needs {n_params} parameters, has {}",
                a.j,
                a.d,
                a.x.len()
            )));
        }
        if a.scaler.mins.len() != a.j || a.scaler.maxs.len() != a.j {
            return Err(ApiError::Artifact(format!(
                "model artifact scaler covers {} columns, model has J={}",
                a.scaler.mins.len(),
                a.j
            )));
        }
        let method = method_name_from_artifact(&a.method)?;
        let spec = ModelSpec::new(a.j, a.d);
        let params = Params::new(spec, a.x.clone());
        let theta = params.theta();
        let sigmas = density::marginal_sigmas(&params);
        Ok(FittedModel {
            spec,
            params,
            scaler: scaler_from_state(&a.scaler),
            theta,
            sigmas,
            diagnostics: Diagnostics {
                coreset: CoresetReport {
                    method,
                    requested: a.requested,
                    size: a.size,
                    n_hull: a.n_hull,
                    total_weight: a.total_weight,
                    n_seen: a.n_seen,
                    indices: None,
                    rows: Mat::zeros(0, a.j),
                    weights: Vec::new(),
                    scaler: None,
                    stream: None,
                    degradations: Degradations::default(),
                    seconds: 0.0,
                },
                fit_nll: a.fit_nll,
                fit_iters: a.fit_iters,
                fit_seconds: 0.0,
                converged: a.converged,
            },
        })
    }

    /// Persist this model (atomic write, checksummed format v1).
    /// `save(load(save(m))) == save(m)` byte for byte.
    pub fn save(&self, path: &Path) -> Result<(), ApiError> {
        Artifact::Model(self.to_artifact()).save(path)
    }

    /// Load a model persisted by [`FittedModel::save`]. A sketch
    /// artifact at `path` is a typed error pointing at the right API.
    pub fn load(path: &Path) -> Result<FittedModel, ApiError> {
        match Artifact::load(path)? {
            Artifact::Model(a) => FittedModel::from_artifact(&a),
            Artifact::Sketch(_) => Err(ApiError::Artifact(format!(
                "{} holds a sketch artifact, not a model \
                 (load it with CoresetReport::load and fit via Session::refit)",
                path.display()
            ))),
        }
    }

    /// Joint log-density at a raw J-vector (original data scale).
    pub fn log_density(&self, y: &[f64]) -> f64 {
        density::log_joint_density(&self.params, &self.scaler, y)
    }

    /// Joint density at a raw J-vector.
    pub fn density(&self, y: &[f64]) -> f64 {
        self.log_density(y).exp()
    }

    /// Marginal density of component `j` at raw value `y` (the shared
    /// formula in `mctm::density`, fed from the cached ϑ and σ).
    pub fn marginal_density(&self, j: usize, y: f64) -> f64 {
        assert!(j < self.spec.j, "margin {j} out of range");
        density::marginal_density_with_sigma(
            &self.theta,
            self.spec.d,
            &self.scaler,
            j,
            y,
            self.sigmas[j],
        )
    }

    /// Marginal CDF F_j(y) of component `j` at raw value `y`.
    ///
    /// Pinned edge behavior: `y = +∞` returns exactly `1.0` and
    /// `y = −∞` returns exactly `0.0` (any distribution's CDF limits),
    /// rather than whatever the clamp-then-transform pipeline happens
    /// to produce. `NaN` propagates to a `NaN` result — use
    /// [`Self::try_cdf`] to get a typed error instead.
    pub fn marginal_cdf(&self, j: usize, y: f64) -> f64 {
        assert!(j < self.spec.j, "margin {j} out of range");
        if y == f64::INFINITY {
            return 1.0;
        }
        if y == f64::NEG_INFINITY {
            return 0.0;
        }
        let h = self.htilde(j, self.scaler.scale(j, y));
        norm_cdf(h / self.sigmas[j])
    }

    /// [`Self::marginal_cdf`] with a typed-error surface instead of
    /// panics / NaN propagation: an out-of-range margin or a `NaN`
    /// input is an [`ApiError::Query`]. ±∞ are valid inputs (exact
    /// 1.0 / 0.0, as documented on `marginal_cdf`). This is what the
    /// serving layer calls.
    pub fn try_cdf(&self, j: usize, y: f64) -> Result<f64, ApiError> {
        if j >= self.spec.j {
            return Err(ApiError::Query(format!(
                "margin {j} out of range (model has J = {})",
                self.spec.j
            )));
        }
        if y.is_nan() {
            return Err(ApiError::Query("cdf input is NaN".into()));
        }
        Ok(self.marginal_cdf(j, y))
    }

    /// Marginal quantile F_j⁻¹(p) of component `j` (p ∈ (0, 1)). The
    /// transformation lives on the scaled axis, so extreme p saturate
    /// at its endpoints — which [`Scaler::unscale`] maps ~ε/(1 − 2ε)
    /// (≈ 1% at the default ε) beyond the observed data min/max, not
    /// exactly at it. The same applies to tail draws of `sample` /
    /// `sample_conditional`.
    ///
    /// Panics on p outside (0, 1) — including `NaN` — and on an
    /// out-of-range margin; [`Self::try_quantile`] is the non-panicking
    /// surface with pinned p = 0 / p = 1 semantics.
    pub fn marginal_quantile(&self, j: usize, p: f64) -> f64 {
        assert!(j < self.spec.j, "margin {j} out of range");
        assert!(p > 0.0 && p < 1.0, "quantile level {p} outside (0, 1)");
        let target = self.sigmas[j] * norm_quantile(p);
        let x = self.invert_htilde(j, target);
        self.scaler.unscale(j, x)
    }

    /// [`Self::marginal_quantile`] with pinned edge behavior and a
    /// typed-error surface (what the serving layer calls):
    ///
    /// * `NaN` or p outside [0, 1] → [`ApiError::Query`] — never a
    ///   panic, never a silently nonsensical number.
    /// * p = 0 / p = 1 → the model's support edges
    ///   `scaler.unscale(j, 0.0)` / `unscale(j, 1.0)` — the exact
    ///   saturation limits of `marginal_quantile(j, p)` as p → 0⁺ / 1⁻
    ///   (~ε/(1 − 2ε) beyond the observed data min/max), so the edge
    ///   continuously extends the open-interval behavior.
    pub fn try_quantile(&self, j: usize, p: f64) -> Result<f64, ApiError> {
        if j >= self.spec.j {
            return Err(ApiError::Query(format!(
                "margin {j} out of range (model has J = {})",
                self.spec.j
            )));
        }
        // NaN fails this containment check too — no separate test
        if !(0.0..=1.0).contains(&p) {
            return Err(ApiError::Query(format!(
                "quantile level {p} outside [0, 1]"
            )));
        }
        if p == 0.0 {
            return Ok(self.scaler.unscale(j, 0.0));
        }
        if p == 1.0 {
            return Ok(self.scaler.unscale(j, 1.0));
        }
        Ok(self.marginal_quantile(j, p))
    }

    /// Draw `n` joint samples on the original data scale.
    pub fn sample(&self, n: usize, rng: &mut Rng) -> Mat {
        self.sample_conditional(&[], n, rng)
    }

    /// Draw `n` samples of the remaining components given the first
    /// `given.len()` components (the MCTM's triangular structure makes
    /// this exact: conditioning fixes h̃ of the given margins, and the
    /// remaining latent z's stay independent standard normals). Returns
    /// full J-column rows with the given values copied into place.
    pub fn sample_conditional(&self, given: &[f64], n: usize, rng: &mut Rng) -> Mat {
        let j = self.spec.j;
        let m = given.len();
        assert!(m <= j, "conditioning on {m} > J = {j} components");
        let mut buf = vec![0.0; self.spec.d];
        let mut base_h = vec![0.0; j];
        for (l, &y) in given.iter().enumerate() {
            base_h[l] = self.htilde_into(l, self.scaler.scale(l, y), &mut buf);
        }
        let mut out = Mat::zeros(n, j);
        let mut h = vec![0.0; j];
        for r in 0..n {
            h.copy_from_slice(&base_h);
            for (l, &y) in given.iter().enumerate() {
                *out.at_mut(r, l) = y;
            }
            for jj in m..j {
                let mut target = rng.normal();
                for l in 0..jj {
                    target -= self.params.lambda(jj, l) * h[l];
                }
                let x = self.invert_htilde(jj, target);
                h[jj] = self.htilde_into(jj, x, &mut buf);
                *out.at_mut(r, jj) = self.scaler.unscale(jj, x);
            }
        }
        out
    }

    /// Weighted-sum NLL of this model's parameters on `data` (original
    /// scale, `data.cols == J`). The design is rebuilt with the model's
    /// own scaler, so parameters fitted on a streamed coreset evaluate
    /// correctly on any other sample of the same distribution.
    pub fn nll(&self, data: &Mat) -> f64 {
        assert_eq!(data.cols, self.spec.j, "data J mismatch");
        let design = Design::build_with_scaler(data, self.spec.d, self.scaler.clone());
        mctm::nll(&design, &[], &self.params)
    }

    #[inline]
    fn theta_row(&self, j: usize) -> &[f64] {
        &self.theta[j * self.spec.d..(j + 1) * self.spec.d]
    }

    /// h̃_j at scaled coordinate x ∈ [0, 1].
    fn htilde(&self, j: usize, x: f64) -> f64 {
        let mut buf = vec![0.0; self.spec.d];
        self.htilde_into(j, x, &mut buf)
    }

    /// h̃_j evaluated through a caller-owned basis buffer (`len == d`),
    /// so the bisection and sampling loops reuse one allocation across
    /// all their iterations.
    #[inline]
    fn htilde_into(&self, j: usize, x: f64, buf: &mut [f64]) -> f64 {
        Bernstein::new(self.spec.d - 1).eval_into(x, buf);
        buf.iter().zip(self.theta_row(j)).map(|(ai, ti)| ai * ti).sum()
    }

    /// Invert the strictly increasing h̃_j over the scaled axis by
    /// bisection; targets outside the transformation's range clamp to
    /// the support edges.
    fn invert_htilde(&self, j: usize, target: f64) -> f64 {
        let th = self.theta_row(j);
        // Bernstein endpoints: h̃(0) = ϑ_0, h̃(1) = ϑ_{d−1}, monotone
        // in between because ϑ is increasing
        if target <= th[0] {
            return 0.0;
        }
        if target >= th[th.len() - 1] {
            return 1.0;
        }
        let mut buf = vec![0.0; self.spec.d];
        let (mut lo, mut hi) = (0.0f64, 1.0f64);
        for _ in 0..64 {
            let mid = 0.5 * (lo + hi);
            if self.htilde_into(j, mid, &mut buf) < target {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        0.5 * (lo + hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dgp::Dgp;

    #[test]
    fn builder_rejects_bad_knobs_with_typed_errors() {
        assert!(matches!(
            SessionBuilder::new().budget(0).build().unwrap_err(),
            ApiError::Config { .. }
        ));
        assert!(matches!(
            SessionBuilder::new().threads(0).build().unwrap_err(),
            ApiError::Config { .. }
        ));
        assert!(matches!(
            SessionBuilder::new().basis_size(1).build().unwrap_err(),
            ApiError::Config { .. }
        ));
        assert!(matches!(
            SessionBuilder::new().scale_eps(0.7).build().unwrap_err(),
            ApiError::Config { .. }
        ));
        assert!(matches!(
            SessionBuilder::new().queue_cap(0).build().unwrap_err(),
            ApiError::Config { .. }
        ));
        match SessionBuilder::new().shard_retry_limit(0).build().unwrap_err() {
            ApiError::Config { key, .. } => assert_eq!(key, "shard_retry_limit"),
            other => panic!("expected Config error, got {other:?}"),
        }
        let err = SessionBuilder::new().method("not-a-method").build().unwrap_err();
        match &err {
            ApiError::UnknownMethod { valid, .. } => {
                assert_eq!(valid, &crate::coreset::strategy::method_names());
            }
            other => panic!("expected UnknownMethod, got {other:?}"),
        }
    }

    #[test]
    fn builder_resolves_every_registered_name() {
        for m in Method::all() {
            let s = SessionBuilder::new().method(m.name()).build().unwrap();
            assert_eq!(s.method(), m);
        }
    }

    #[test]
    fn session_is_reusable_and_deterministic() {
        let mut rng = Rng::new(5);
        let data = Dgp::NormalMixture.generate(400, &mut rng);
        let session = SessionBuilder::new().budget(40).basis_size(5).seed(11).build().unwrap();
        let a = session.coreset(&data).unwrap();
        let b = session.coreset(&data).unwrap();
        assert_eq!(a.indices, b.indices);
        assert_eq!(a.weights, b.weights);
        assert_eq!(a.n_seen, 400);
        assert!(a.size <= 40 + 5 && a.size > 0);
        assert!(a.stream.is_none());
    }

    #[test]
    fn empty_sources_are_typed_errors() {
        let session = SessionBuilder::new().build().unwrap();
        assert!(matches!(
            session.coreset(Mat::zeros(0, 2)).unwrap_err(),
            ApiError::Data(_)
        ));
    }

    #[test]
    fn fitted_model_is_send_sync() {
        fn check<T: Send + Sync>() {}
        check::<FittedModel>();
        check::<Session>();
        check::<Diagnostics>();
    }

    #[test]
    fn quantile_inverts_cdf() {
        let mut rng = Rng::new(21);
        let data = Dgp::BivariateNormal.generate(2_000, &mut rng);
        let session = SessionBuilder::new()
            .budget(2_000) // identity coreset: fastest exact fit
            .basis_size(6)
            .max_iters(120)
            .seed(3)
            .build()
            .unwrap();
        let model = session.fit(&data).unwrap();
        for &p in &[0.1, 0.25, 0.5, 0.9] {
            for j in 0..2 {
                let y = model.marginal_quantile(j, p);
                let back = model.marginal_cdf(j, y);
                assert!(
                    (back - p).abs() < 1e-3,
                    "margin {j}: F(F⁻¹({p})) = {back}"
                );
            }
        }
        // CDF is monotone and spans (0, 1) over the data range
        assert!(model.marginal_cdf(0, -4.0) < 0.05);
        assert!(model.marginal_cdf(0, 4.0) > 0.95);
    }

    #[test]
    fn sampling_matches_fitted_marginals() {
        let mut rng = Rng::new(33);
        let data = Dgp::BivariateNormal.generate(3_000, &mut rng);
        let session = SessionBuilder::new()
            .budget(3_000)
            .basis_size(6)
            .max_iters(150)
            .seed(4)
            .build()
            .unwrap();
        let model = session.fit(&data).unwrap();
        let draws = model.sample(4_000, &mut rng);
        assert_eq!((draws.rows, draws.cols), (4_000, 2));
        // empirical median of margin 0 ≈ model median
        let mut col: Vec<f64> = (0..draws.rows).map(|r| draws.at(r, 0)).collect();
        col.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let emp_median = col[col.len() / 2];
        let model_median = model.marginal_quantile(0, 0.5);
        assert!(
            (emp_median - model_median).abs() < 0.15,
            "median {emp_median} vs {model_median}"
        );
        // correlated DGP (ρ = 0.7): conditioning on a high y₁ must shift
        // the conditional mean of y₂ upward vs conditioning on a low y₁
        let hi = model.sample_conditional(&[1.5], 800, &mut rng);
        let lo = model.sample_conditional(&[-1.5], 800, &mut rng);
        let mean = |m: &Mat| (0..m.rows).map(|r| m.at(r, 1)).sum::<f64>() / m.rows as f64;
        assert!(hi.rows == 800 && hi.at(0, 0) == 1.5);
        assert!(
            mean(&hi) > mean(&lo) + 0.5,
            "conditional shift missing: {} vs {}",
            mean(&hi),
            mean(&lo)
        );
    }
}
