//! The typed error surface of the public facade.
//!
//! One enum replaces the ad-hoc `Result<_, String>` / `anyhow!`-chain /
//! panic paths that used to live in the config parser and the CLI:
//! every way the builder → session → fitted-model pipeline can be
//! misconfigured or fed bad data has a variant here, so callers can
//! match on the failure instead of grepping a message string.
//!
//! `ApiError` implements [`std::error::Error`], so it converts into the
//! in-tree anyhow-style [`crate::util::error::Error`] via `?` wherever
//! the coordinator still speaks that dialect.

use std::fmt;

/// Everything that can go wrong on the public facade.
#[derive(Clone, Debug, PartialEq)]
pub enum ApiError {
    /// A builder / config knob failed validation.
    Config {
        /// which knob (`"budget"`, `"threads"`, `"--shards"`, …)
        key: String,
        /// what was wrong with it
        reason: String,
    },
    /// A sampling-method name not present in the strategy registry.
    /// `valid` lists every registered name.
    UnknownMethod {
        name: String,
        valid: Vec<&'static str>,
    },
    /// A dataset name the data registry cannot resolve.
    UnknownDataset {
        name: String,
        /// human-readable summary of what IS resolvable
        known: String,
    },
    /// The data source was empty or otherwise unusable.
    Data(String),
    /// Filesystem / IO failure (config files, CSV sources).
    Io(String),
    /// A backend (XLA runtime, …) rejected the request.
    Backend(String),
    /// Malformed command-line invocation.
    Usage(String),
    /// A persisted artifact could not be saved, loaded, or parsed:
    /// IO failure, bad magic/version/kind, checksum mismatch from
    /// corruption or truncation, or shape-incoherent content. The
    /// loader never panics on bad bytes — every failure is this
    /// variant.
    Artifact(String),
    /// The serving layer failed (socket bind/accept, malformed request
    /// framing). Per-request problems are HTTP-level responses, not
    /// errors; this variant is for failures of the server itself.
    Server(String),
    /// A query against a [`crate::api::FittedModel`] was invalid:
    /// non-finite or out-of-range quantile level, NaN CDF input,
    /// margin index out of range, dimension mismatch.
    Query(String),
    /// The streaming pipeline failed mid-run (fatal shard read,
    /// exhausted transient retries, invalid data under
    /// `InvalidPolicy::Error`, a reduce that could not proceed). Carries
    /// shard/consumer provenance from the pipeline's orderly shutdown.
    Stream {
        /// sequence number of the shard being handled when the error
        /// hit (`None` for failures not attributable to one shard)
        shard_seq: Option<usize>,
        /// consumer worker index (`None` for producer/reducer-side
        /// failures)
        consumer: Option<usize>,
        /// the underlying failure
        source: Box<ApiError>,
    },
}

impl ApiError {
    /// Shorthand for a knob-validation failure.
    pub fn config(key: impl Into<String>, reason: impl fmt::Display) -> Self {
        ApiError::Config {
            key: key.into(),
            reason: reason.to_string(),
        }
    }

    /// Unknown-method error carrying every registered name (the
    /// registry is the single source of truth, so the message can never
    /// drift from the strategies that actually exist).
    pub fn unknown_method(name: impl Into<String>) -> Self {
        ApiError::UnknownMethod {
            name: name.into(),
            valid: crate::coreset::strategy::method_names(),
        }
    }
}

impl fmt::Display for ApiError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ApiError::Config { key, reason } => write!(f, "invalid `{key}`: {reason}"),
            ApiError::UnknownMethod { name, valid } => {
                write!(f, "unknown method `{name}` (valid: {})", valid.join(", "))
            }
            ApiError::UnknownDataset { name, known } => {
                write!(f, "unknown dataset `{name}` ({known})")
            }
            ApiError::Data(msg) => write!(f, "data source error: {msg}"),
            ApiError::Artifact(msg) => write!(f, "artifact error: {msg}"),
            ApiError::Server(msg) => write!(f, "server error: {msg}"),
            ApiError::Query(msg) => write!(f, "invalid query: {msg}"),
            ApiError::Io(msg) => write!(f, "{msg}"),
            ApiError::Backend(msg) => write!(f, "backend error: {msg}"),
            ApiError::Usage(msg) => write!(f, "{msg}"),
            ApiError::Stream { shard_seq, consumer, source } => {
                write!(f, "stream failure")?;
                if let Some(seq) = shard_seq {
                    write!(f, " at shard {seq}")?;
                }
                if let Some(c) = consumer {
                    write!(f, " (consumer {c})")?;
                }
                write!(f, ": {source}")
            }
        }
    }
}

impl std::error::Error for ApiError {}

impl From<crate::coordinator::StreamError> for ApiError {
    fn from(e: crate::coordinator::StreamError) -> Self {
        ApiError::Stream {
            shard_seq: e.shard_seq,
            consumer: e.consumer,
            source: Box::new(ApiError::Data(e.message)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_method_lists_every_registered_name() {
        let err = ApiError::unknown_method("nope");
        let msg = format!("{err}");
        for name in crate::coreset::strategy::method_names() {
            assert!(msg.contains(name), "message should list `{name}`: {msg}");
        }
    }

    #[test]
    fn converts_into_util_error_chain() {
        fn fails() -> Result<(), ApiError> {
            Err(ApiError::config("budget", "must be ≥ 1"))
        }
        fn inner() -> crate::util::error::Result<()> {
            fails()?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(format!("{e:#}").contains("budget"));
    }

    #[test]
    fn display_is_actionable() {
        let e = ApiError::config("threads", "must be ≥ 1 (omit the call for auto)");
        assert_eq!(
            format!("{e}"),
            "invalid `threads`: must be ≥ 1 (omit the call for auto)"
        );
    }
}
