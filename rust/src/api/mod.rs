//! The public facade (PR 4): **one typed entry point** from a data
//! source to a query-serving fitted model.
//!
//! ```text
//! SessionBuilder ──build()──▶ Session ──fit(source)──▶ FittedModel
//!      knobs                   recipe                  query surface
//! ```
//!
//! * [`SessionBuilder`] validates every knob (method names resolve
//!   through the strategy registry, budgets/threads must be positive)
//!   and returns typed [`ApiError`]s instead of panicking.
//! * [`Session::fit`] accepts anything implementing [`DataSource`] —
//!   an in-memory [`crate::linalg::Mat`], a DGP generator, a named
//!   dataset, or any streaming [`crate::data::ShardSource`] — and picks
//!   the batch or the Merge & Reduce path automatically.
//! * [`FittedModel`] exposes the read-side query surface (joint
//!   log-density, full-data NLL, per-margin CDF / quantile, conditional
//!   sampling) and is `Send + Sync`, so one model serves many
//!   concurrent scenario queries.
//!
//! Failure semantics: the streaming path retries transient shard reads
//! deterministically, shuts down orderly on fatal errors (surfacing
//! [`ApiError::Stream`] with shard/consumer provenance), and records
//! every numerical fallback — ridge-jitter Cholesky recoveries, MVEE
//! non-convergence, scrubbed rows — into
//! [`CoresetReport::degradations`]. Non-finite input cells are handled
//! per `SessionBuilder::on_invalid`
//! ([`crate::data::InvalidPolicy`]: error / mask / drop).
//!
//! The pre-0.3 deprecated shims (`build_coreset`, `build_coreset_with`,
//! `StreamingPipeline::new`) have been removed; use [`crate::prelude`].

pub mod error;
pub mod session;
pub mod source;

pub use error::ApiError;
pub use session::{CoresetReport, Diagnostics, FittedModel, Session, SessionBuilder};
pub use source::{load_dataset, DataSource, DgpSource, NamedSource, SourceInput, StoreSource};
