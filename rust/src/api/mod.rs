//! The public facade (PR 4): **one typed entry point** from a data
//! source to a query-serving fitted model.
//!
//! ```text
//! SessionBuilder ──build()──▶ Session ──fit(source)──▶ FittedModel
//!      knobs                   recipe                  query surface
//! ```
//!
//! * [`SessionBuilder`] validates every knob (method names resolve
//!   through the strategy registry, budgets/threads must be positive)
//!   and returns typed [`ApiError`]s instead of panicking.
//! * [`Session::fit`] accepts anything implementing [`DataSource`] —
//!   an in-memory [`crate::linalg::Mat`], a DGP generator, a named
//!   dataset, or any streaming [`crate::data::ShardSource`] — and picks
//!   the batch or the Merge & Reduce path automatically.
//! * [`FittedModel`] exposes the read-side query surface (joint
//!   log-density, full-data NLL, per-margin CDF / quantile, conditional
//!   sampling) and is `Send + Sync`, so one model serves many
//!   concurrent scenario queries.
//!
//! The pre-facade free functions (`build_coreset`,
//! `StreamingPipeline::new`, …) remain as `#[deprecated]` shims for one
//! release; use [`crate::prelude`] for new code.

pub mod error;
pub mod session;
pub mod source;

pub use error::ApiError;
pub use session::{CoresetReport, Diagnostics, FittedModel, Session, SessionBuilder};
pub use source::{load_dataset, DataSource, DgpSource, NamedSource, SourceInput};
