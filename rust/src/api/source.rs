//! Data sources for the facade: one [`DataSource`] trait unifies
//! in-memory matrices, DGP generators, named datasets and streaming
//! [`ShardSource`]s, so [`crate::api::Session::fit`] can pick the batch
//! or the Merge & Reduce path automatically — callers never choose a
//! code path by hand.
//!
//! * [`Mat`] / `&Mat` → batch: design + one-shot coreset on all rows.
//! * [`MatShards`] / [`GenShards`] / any boxed [`ShardSource`] →
//!   streaming: bounded-memory Merge & Reduce over the shard stream.
//! * [`DgpSource`] / [`NamedSource`] → either, chosen at construction
//!   (`batch` vs `stream`), with generation seeded from the session.
//! * [`StoreSource`] → streaming from an on-disk column store
//!   (`data::store`), one chunk in memory at a time.

use super::error::ApiError;
use crate::data::dgp::Dgp;
use crate::data::store::StoreReader;
use crate::data::{covertype, equity, GenShards, MatShards, ShardSource};
use crate::linalg::Mat;
use crate::util::rng::Rng;
use std::borrow::Cow;
use std::path::PathBuf;

/// The concrete input [`crate::api::Session::fit`] consumes: either a
/// fully materialized matrix (batch path) or a shard stream (Merge &
/// Reduce path).
///
/// The batch variant carries a [`Cow`], so borrowed sources (`&Mat`)
/// flow through the whole sketch **zero-copy** — the experiment
/// harness used to clone the data matrix once per repetition — while
/// owned sources (generated DGP draws, loaded files) move in without
/// an extra copy either.
pub enum SourceInput<'a> {
    /// materialized rows — batch coreset construction
    Batch(Cow<'a, Mat>),
    /// a shard stream — bounded-memory streaming construction
    Stream(Box<dyn ShardSource + Send>),
}

/// Anything the session can fit. `into_input` resolves the source into
/// a [`SourceInput`]; `seed` is the session seed, so generator-backed
/// sources derive their randomness from the session configuration and
/// a given (session, source) pair is fully deterministic. The output
/// lifetime is bounded by the source itself (`Self: 'a`), which is what
/// lets `&Mat` resolve to a borrowed batch input.
pub trait DataSource {
    /// Resolve into the concrete input the session consumes.
    fn into_input<'a>(self, seed: u64) -> Result<SourceInput<'a>, ApiError>
    where
        Self: 'a;
}

impl DataSource for Mat {
    fn into_input<'a>(self, _seed: u64) -> Result<SourceInput<'a>, ApiError>
    where
        Self: 'a,
    {
        Ok(SourceInput::Batch(Cow::Owned(self)))
    }
}

impl DataSource for &Mat {
    fn into_input<'a>(self, _seed: u64) -> Result<SourceInput<'a>, ApiError>
    where
        Self: 'a,
    {
        Ok(SourceInput::Batch(Cow::Borrowed(self)))
    }
}

impl DataSource for MatShards {
    fn into_input<'a>(self, _seed: u64) -> Result<SourceInput<'a>, ApiError>
    where
        Self: 'a,
    {
        Ok(SourceInput::Stream(Box::new(self)))
    }
}

impl<F: FnMut(usize) -> Mat + Send + 'static> DataSource for GenShards<F> {
    fn into_input<'a>(self, _seed: u64) -> Result<SourceInput<'a>, ApiError>
    where
        Self: 'a,
    {
        Ok(SourceInput::Stream(Box::new(self)))
    }
}

impl DataSource for Box<dyn ShardSource + Send> {
    fn into_input<'a>(self, _seed: u64) -> Result<SourceInput<'a>, ApiError>
    where
        Self: 'a,
    {
        Ok(SourceInput::Stream(self))
    }
}

impl<'b> DataSource for SourceInput<'b> {
    fn into_input<'a>(self, _seed: u64) -> Result<SourceInput<'a>, ApiError>
    where
        Self: 'a,
    {
        // `Self: 'a` bounds 'b: 'a, and `SourceInput` is covariant in
        // its lifetime, so the subtype coercion is implicit
        Ok(self)
    }
}

/// A simulation DGP as a data source: `batch` materializes `n` rows up
/// front, `stream` feeds them through the pipeline in shards of
/// `shard` rows (nothing materialized — the "data never fits in
/// memory" path). Generation is seeded from the session seed.
#[derive(Clone, Copy, Debug)]
pub struct DgpSource {
    dgp: Dgp,
    n: usize,
    shard: Option<usize>,
}

impl DgpSource {
    /// Materialize `n` samples of `dgp` (batch coreset path).
    pub fn batch(dgp: Dgp, n: usize) -> Self {
        DgpSource { dgp, n, shard: None }
    }

    /// Stream `total` samples of `dgp` in shards of `shard` rows
    /// (Merge & Reduce path).
    pub fn stream(dgp: Dgp, total: usize, shard: usize) -> Self {
        DgpSource { dgp, n: total, shard: Some(shard) }
    }
}

impl DataSource for DgpSource {
    fn into_input<'a>(self, seed: u64) -> Result<SourceInput<'a>, ApiError>
    where
        Self: 'a,
    {
        if let Some(shard) = self.shard {
            if shard == 0 {
                return Err(ApiError::config("shard", "shard size must be ≥ 1"));
            }
            let dgp = self.dgp;
            // derive J from a probe draw rather than assuming the
            // current all-bivariate DGP catalogue stays that way
            let j = dgp.generate(1, &mut Rng::new(seed)).cols;
            let mut rng = Rng::new(seed);
            return Ok(SourceInput::Stream(Box::new(GenShards::new(
                move |m| dgp.generate(m, &mut rng),
                j,
                self.n,
                shard,
            ))));
        }
        let mut rng = Rng::new(seed);
        Ok(SourceInput::Batch(Cow::Owned(self.dgp.generate(self.n, &mut rng))))
    }
}

/// An on-disk column store (`data::store`) as a data source: always
/// the streaming path — the reader holds one chunk in memory at a
/// time, so `Session::fit`/`coreset` run at O(budget + chunk) peak no
/// matter how many rows the store holds. The store's own chunk
/// geometry is the shard size; a store written with `chunk_rows` equal
/// to an in-memory run's shard size produces a **bitwise-identical**
/// coreset (pinned by `tests/store_roundtrip.rs`).
#[derive(Clone, Debug)]
pub struct StoreSource {
    path: PathBuf,
}

impl StoreSource {
    /// Stream the store file at `path` (as written by `mctm import` or
    /// [`crate::data::store::StoreWriter`]).
    pub fn new(path: impl Into<PathBuf>) -> Self {
        StoreSource { path: path.into() }
    }
}

impl DataSource for StoreSource {
    fn into_input<'a>(self, _seed: u64) -> Result<SourceInput<'a>, ApiError>
    where
        Self: 'a,
    {
        let reader = StoreReader::open(&self.path).map_err(|e| {
            ApiError::Io(format!("opening store {}: {e:#}", self.path.display()))
        })?;
        Ok(SourceInput::Stream(Box::new(reader)))
    }
}

/// A dataset addressed by its registry name (any of the 14 DGP names,
/// `covertype`, `stocks10`, `stocks20`, `file:/path.csv`, or
/// `store:/path.store`) — what the CLI `dataset` config key resolves
/// through.
#[derive(Clone, Debug)]
pub struct NamedSource {
    name: String,
    n: usize,
    shard: Option<usize>,
}

impl NamedSource {
    /// Materialize `n` rows of the named dataset (batch path).
    pub fn batch(name: impl Into<String>, n: usize) -> Self {
        NamedSource { name: name.into(), n, shard: None }
    }

    /// Stream `total` rows of the named dataset in shards of `shard`
    /// rows (Merge & Reduce path).
    pub fn stream(name: impl Into<String>, total: usize, shard: usize) -> Self {
        NamedSource { name: name.into(), n: total, shard: Some(shard) }
    }
}

impl DataSource for NamedSource {
    fn into_input<'a>(self, seed: u64) -> Result<SourceInput<'a>, ApiError>
    where
        Self: 'a,
    {
        if let Some(shard) = self.shard {
            if shard == 0 {
                return Err(ApiError::config("shard", "shard size must be ≥ 1"));
            }
            if let Some(path) = self.name.strip_prefix("store:") {
                // a store carries its own chunk geometry — stream it
                // directly (the reader is the shard source; `shard` and
                // the row total are generator parameters and don't
                // apply to a file whose layout is fixed on disk)
                return StoreSource::new(path).into_input(seed);
            }
            if self.name.starts_with("file:") {
                // a CSV file does not re-generate rows per request the
                // way the DGP sources do — load it once (capped to the
                // requested total) and shard the materialized rows;
                // otherwise every shard would replay the file's leading
                // rows
                let mut rng = Rng::new(seed);
                let m = load_dataset(&self.name, self.n, &mut rng)?;
                return Ok(SourceInput::Stream(Box::new(MatShards::new(m, shard))));
            }
            // validate the name (and learn J) before spawning a stream,
            // so a typo fails fast with the full dataset listing
            let mut probe = Rng::new(seed);
            let j = load_dataset(&self.name, 2, &mut probe)?.cols;
            let name = self.name.clone();
            let mut rng = Rng::new(seed);
            // the name resolved during the probe draw above and the
            // registry is static, so this lookup cannot fail by the time
            // the stream is pulled
            #[allow(clippy::expect_used)]
            let gen = move |m| {
                load_dataset(&name, m, &mut rng)
                    .expect("dataset name validated before streaming")
            };
            return Ok(SourceInput::Stream(Box::new(GenShards::new(
                gen, j, self.n, shard,
            ))));
        }
        let mut rng = Rng::new(seed);
        Ok(SourceInput::Batch(Cow::Owned(load_dataset(
            &self.name, self.n, &mut rng,
        )?)))
    }
}

/// Resolve a dataset name to `n` materialized rows: the 14 DGP names
/// (`Dgp::name`), the synthetic `covertype` / `stocks10` / `stocks20`
/// generators, `file:/path.csv`, or `store:/path.store` (files capped
/// to the first `n` rows).
pub fn load_dataset(name: &str, n: usize, rng: &mut Rng) -> Result<Mat, ApiError> {
    if let Some(path) = name.strip_prefix("store:") {
        let m = crate::data::store::read_all(std::path::Path::new(path))
            .map_err(|e| ApiError::Io(format!("loading {path}: {e:#}")))?;
        // honour the n cap, like file: (batch callers materialize; the
        // streaming path above never does)
        if m.rows > n {
            let idx: Vec<usize> = (0..n).collect();
            return Ok(m.select_rows(&idx));
        }
        return Ok(m);
    }
    if let Some(path) = name.strip_prefix("file:") {
        let m = crate::data::csv::load_csv(std::path::Path::new(path))
            .map_err(|e| ApiError::Io(format!("loading {path}: {e:#}")))?;
        // honour the n cap (subsample deterministically from the front)
        if m.rows > n {
            let idx: Vec<usize> = (0..n).collect();
            return Ok(m.select_rows(&idx));
        }
        return Ok(m);
    }
    if name == "covertype" {
        return Ok(covertype::generate(n, rng));
    }
    if name == "stocks10" {
        return Ok(equity::generate(n, 10, rng));
    }
    if name == "stocks20" {
        return Ok(equity::generate(n, 20, rng));
    }
    for dgp in Dgp::all() {
        if dgp.name() == name {
            return Ok(dgp.generate(n, rng));
        }
    }
    Err(ApiError::UnknownDataset {
        name: name.to_string(),
        known: format!(
            "DGP names: {}; plus covertype, stocks10, stocks20, file:/path.csv, store:/path.store",
            Dgp::all().map(|d| d.name()).join(", ")
        ),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat_resolves_to_batch() {
        let m = Mat::zeros(10, 2);
        match m.into_input(1).unwrap() {
            SourceInput::Batch(b) => {
                assert_eq!((b.rows, b.cols), (10, 2));
                assert!(matches!(b, Cow::Owned(_)));
            }
            SourceInput::Stream(_) => panic!("expected batch"),
        }
    }

    #[test]
    fn borrowed_mat_resolves_without_a_copy() {
        let m = Mat::from_vec(6, 2, (0..12).map(|x| x as f64).collect());
        match (&m).into_input(1).unwrap() {
            SourceInput::Batch(b) => {
                assert!(matches!(b, Cow::Borrowed(_)));
                // the borrow points at the caller's buffer, not a clone
                assert!(std::ptr::eq(b.as_ref(), &m));
            }
            SourceInput::Stream(_) => panic!("expected batch"),
        }
    }

    #[test]
    fn shards_resolve_to_stream_and_cover_rows() {
        let m = Mat::from_vec(10, 2, (0..20).map(|x| x as f64).collect());
        match MatShards::new(m, 4).into_input(1).unwrap() {
            SourceInput::Stream(mut s) => {
                assert_eq!(s.dim(), 2);
                let mut total = 0;
                while let Some(shard) = s.next_shard().unwrap() {
                    total += shard.rows;
                }
                assert_eq!(total, 10);
            }
            SourceInput::Batch(_) => panic!("expected stream"),
        }
    }

    #[test]
    fn dgp_source_is_seed_deterministic() {
        let a = match DgpSource::batch(Dgp::Spiral, 50).into_input(9).unwrap() {
            SourceInput::Batch(m) => m,
            _ => unreachable!(),
        };
        let b = match DgpSource::batch(Dgp::Spiral, 50).into_input(9).unwrap() {
            SourceInput::Batch(m) => m,
            _ => unreachable!(),
        };
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn named_source_rejects_unknown_names() {
        let err = NamedSource::batch("nope", 10).into_input(1).unwrap_err();
        assert!(matches!(err, ApiError::UnknownDataset { .. }));
        let err = NamedSource::stream("nope", 100, 10).into_input(1).unwrap_err();
        assert!(matches!(err, ApiError::UnknownDataset { .. }));
    }

    #[test]
    fn store_source_resolves_to_stream_and_covers_rows() {
        use crate::data::store::StoreWriter;
        let dir = std::env::temp_dir()
            .join(format!("mctm_src_store_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s.store");
        let m = Mat::from_vec(10, 2, (0..20).map(|x| x as f64 + 0.5).collect());
        let mut w = StoreWriter::create(&path, 2, 4).unwrap();
        w.push_mat(&m).unwrap();
        w.finish().unwrap();

        match StoreSource::new(&path).into_input(1).unwrap() {
            SourceInput::Stream(mut s) => {
                assert_eq!(s.dim(), 2);
                let mut total = 0;
                while let Some(shard) = s.next_shard().unwrap() {
                    total += shard.rows;
                }
                assert_eq!(total, 10);
            }
            SourceInput::Batch(_) => panic!("expected stream"),
        }

        // the registry's store: prefix reaches the same reader (stream)
        // and materializes bitwise on the batch path, honouring the cap
        let name = format!("store:{}", path.display());
        match NamedSource::stream(&name, 999, 3).into_input(1).unwrap() {
            SourceInput::Stream(mut s) => {
                // shard geometry comes from the store, not the request
                assert_eq!(s.next_shard().unwrap().unwrap().rows, 4);
            }
            SourceInput::Batch(_) => panic!("expected stream"),
        }
        let mut rng = Rng::new(1);
        let full = load_dataset(&name, 100, &mut rng).unwrap();
        for (a, b) in full.data.iter().zip(&m.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(load_dataset(&name, 3, &mut rng).unwrap().rows, 3);

        let err = StoreSource::new(dir.join("missing.store"))
            .into_input(1)
            .unwrap_err();
        assert!(matches!(err, ApiError::Io(_)));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn dataset_registry_resolves_every_dgp() {
        let mut rng = Rng::new(3);
        for dgp in Dgp::all() {
            let m = load_dataset(dgp.name(), 20, &mut rng).unwrap();
            assert_eq!((m.rows, m.cols), (20, 2));
        }
        assert_eq!(load_dataset("covertype", 15, &mut rng).unwrap().cols, 10);
        assert_eq!(load_dataset("stocks10", 15, &mut rng).unwrap().cols, 10);
        assert_eq!(load_dataset("stocks20", 15, &mut rng).unwrap().cols, 20);
    }
}
