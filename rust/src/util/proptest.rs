//! Minimal randomized property-test harness.
//!
//! The `proptest` crate is unavailable in the offline registry, so tests
//! use this generator-based harness instead: run a property over `cases`
//! random inputs drawn from user-provided generators; on failure, report
//! the seed + case index so the exact input reproduces deterministically.
//! (No shrinking — cases are kept small instead.)

use super::rng::Rng;

/// Run `prop` over `cases` inputs from `gen`, panicking with a
/// reproducible seed on the first failure.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    seed: u64,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property `{name}` failed at case {case} (seed {seed}):\n  \
                 input: {input:?}\n  reason: {msg}"
            );
        }
    }
}

/// Generator helpers.
pub mod gen {
    use super::Rng;

    /// Random vector of length `n` with entries in [lo, hi).
    pub fn vec_in(rng: &mut Rng, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| rng.uniform(lo, hi)).collect()
    }

    /// Random standard-normal vector.
    pub fn vec_normal(rng: &mut Rng, n: usize) -> Vec<f64> {
        (0..n).map(|_| rng.normal()).collect()
    }

    /// Random size in [lo, hi].
    pub fn size(rng: &mut Rng, lo: usize, hi: usize) -> usize {
        lo + rng.usize(hi - lo + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check(
            "abs is non-negative",
            1,
            100,
            |rng| rng.normal(),
            |x| {
                if x.abs() >= 0.0 {
                    Ok(())
                } else {
                    Err("negative abs".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property `always fails`")]
    fn reports_failure() {
        check(
            "always fails",
            2,
            10,
            |rng| rng.f64(),
            |_| Err("no".into()),
        );
    }
}
