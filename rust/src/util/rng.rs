//! Deterministic, dependency-free pseudo-random number generation.
//!
//! The offline build has no `rand` crate, so we ship a small, well-tested
//! xoshiro256++ generator (Blackman & Vigna) seeded through SplitMix64,
//! plus the sampling routines the data-generation processes and the
//! coreset samplers need: uniforms, normals (Box–Muller), gamma
//! (Marsaglia–Tsang), Student-t, chi-square, exponential, and weighted
//! index sampling via Walker's alias method.

/// xoshiro256++ PRNG. Deterministic given a seed; period 2^256 − 1.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second normal from Box–Muller
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[inline]
fn rotl(x: u64, k: u32) -> u64 {
    x.rotate_left(k)
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = rotl(s[0].wrapping_add(s[3]), 23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = rotl(s[3], 45);
        result
    }

    /// Uniform f64 in [0, 1) with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in (0, 1] — safe as a log() argument.
    #[inline]
    pub fn f64_open(&mut self) -> f64 {
        1.0 - self.f64()
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) (Lemire-style rejection-free for our use).
    #[inline]
    pub fn usize(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply avoids modulo bias well below any statistical
        // resolution we care about at n << 2^64.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (caches the second variate).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        let u1 = self.f64_open();
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (std::f64::consts::TAU * u2).sin_cos();
        self.spare_normal = Some(r * s);
        r * c
    }

    /// Normal with given mean and standard deviation.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, sd: f64) -> f64 {
        mean + sd * self.normal()
    }

    /// Exponential(rate).
    #[inline]
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.f64_open().ln() / rate
    }

    /// Gamma(shape k, scale θ) via Marsaglia–Tsang (k ≥ 1) with the
    /// standard boost for k < 1.
    pub fn gamma(&mut self, shape: f64, scale: f64) -> f64 {
        if shape < 1.0 {
            let u = self.f64_open();
            return self.gamma(shape + 1.0, scale) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = 1.0 + c * x;
            if v <= 0.0 {
                continue;
            }
            let v3 = v * v * v;
            let u = self.f64_open();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln())
            {
                return d * v3 * scale;
            }
        }
    }

    /// Chi-square with ν degrees of freedom.
    #[inline]
    pub fn chi2(&mut self, nu: f64) -> f64 {
        self.gamma(nu / 2.0, 2.0)
    }

    /// Student-t with ν degrees of freedom.
    #[inline]
    pub fn student_t(&mut self, nu: f64) -> f64 {
        self.normal() / (self.chi2(nu) / nu).sqrt()
    }

    /// Log-normal(μ, σ) (parameters on the log scale).
    #[inline]
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` indices uniformly **without** replacement from [0, n).
    pub fn sample_without_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n} without replacement");
        // Floyd's algorithm: O(k) expected, no O(n) allocation.
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.usize(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }
}

/// Walker's alias method for O(1) weighted index sampling after O(n) setup.
///
/// Used by the sensitivity sampler, which draws k₁ i.i.d. indices with
/// probabilities p_i ∝ leverage + uniform term (paper Algorithm 1 step
/// "Sampling phase").
#[derive(Clone, Debug)]
pub struct AliasTable {
    prob: Vec<f64>,
    alias: Vec<usize>,
    /// the normalized probabilities (kept for weight computation 1/(k p_i))
    p: Vec<f64>,
}

impl AliasTable {
    /// Build from non-negative weights (not necessarily normalized).
    pub fn new(weights: &[f64]) -> Self {
        let n = weights.len();
        assert!(n > 0, "alias table needs at least one weight");
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "alias table needs positive total weight");
        let p: Vec<f64> = weights.iter().map(|w| w / total).collect();
        let mut prob = vec![0.0f64; n];
        let mut alias = vec![0usize; n];
        let mut small = Vec::new();
        let mut large = Vec::new();
        let scaled: Vec<f64> = p.iter().map(|&x| x * n as f64).collect();
        let mut scaled = scaled;
        for (i, &s) in scaled.iter().enumerate() {
            if s < 1.0 {
                small.push(i)
            } else {
                large.push(i)
            }
        }
        while let (Some(&s), Some(&l)) = (small.last(), large.last()) {
            small.pop();
            prob[s] = scaled[s];
            alias[s] = l;
            scaled[l] = (scaled[l] + scaled[s]) - 1.0;
            if scaled[l] < 1.0 {
                large.pop();
                small.push(l);
            }
        }
        for &l in &large {
            prob[l] = 1.0;
        }
        for &s in &small {
            prob[s] = 1.0;
        }
        AliasTable { prob, alias, p }
    }

    /// Draw one index.
    #[inline]
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let i = rng.usize(self.prob.len());
        if rng.f64() < self.prob[i] {
            i
        } else {
            self.alias[i]
        }
    }

    /// Probability of index i (normalized).
    #[inline]
    pub fn p(&self, i: usize) -> f64 {
        self.p[i]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_mean_and_range() {
        let mut rng = Rng::new(42);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(7);
        let n = 200_000;
        let (mut m, mut v) = (0.0, 0.0);
        for _ in 0..n {
            let z = rng.normal();
            m += z;
            v += z * z;
        }
        m /= n as f64;
        v = v / n as f64 - m * m;
        assert!(m.abs() < 0.02, "mean {m}");
        assert!((v - 1.0).abs() < 0.03, "var {v}");
    }

    #[test]
    fn gamma_moments() {
        let mut rng = Rng::new(9);
        let (shape, scale) = (2.0, 1.5);
        let n = 100_000;
        let mut m = 0.0;
        for _ in 0..n {
            m += rng.gamma(shape, scale);
        }
        m /= n as f64;
        assert!((m - shape * scale).abs() < 0.05, "gamma mean {m}");
    }

    #[test]
    fn student_t_symmetric_heavy() {
        let mut rng = Rng::new(11);
        let n = 100_000;
        let mut m = 0.0;
        let mut extreme = 0usize;
        for _ in 0..n {
            let t = rng.student_t(3.0);
            m += t;
            if t.abs() > 6.0 {
                extreme += 1;
            }
        }
        assert!((m / n as f64).abs() < 0.05);
        // t(3) has visibly heavier tails than normal: P(|T|>6) ≈ 0.46%
        // per tail-pair; normal would give ~2e-9.
        assert!(extreme > 100, "extreme count {extreme}");
    }

    #[test]
    fn chi2_mean() {
        let mut rng = Rng::new(13);
        let n = 50_000;
        let mut m = 0.0;
        for _ in 0..n {
            m += rng.chi2(4.0);
        }
        assert!((m / n as f64 - 4.0).abs() < 0.1);
    }

    #[test]
    fn alias_table_matches_weights() {
        let weights = vec![1.0, 2.0, 3.0, 4.0];
        let table = AliasTable::new(&weights);
        let mut rng = Rng::new(5);
        let mut counts = [0usize; 4];
        let n = 400_000;
        for _ in 0..n {
            counts[table.sample(&mut rng)] += 1;
        }
        for (i, &c) in counts.iter().enumerate() {
            let expected = weights[i] / 10.0;
            let got = c as f64 / n as f64;
            assert!((got - expected).abs() < 0.01, "idx {i}: {got} vs {expected}");
            assert!((table.p(i) - expected).abs() < 1e-12);
        }
    }

    #[test]
    fn without_replacement_is_a_set() {
        let mut rng = Rng::new(3);
        let picks = rng.sample_without_replacement(100, 30);
        let set: std::collections::HashSet<_> = picks.iter().collect();
        assert_eq!(set.len(), 30);
        assert!(picks.iter().all(|&i| i < 100));
    }

    #[test]
    fn deterministic_given_seed() {
        let a: Vec<u64> = {
            let mut r = Rng::new(99);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Rng::new(99);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
    }
}
