//! Degradation accounting: every graceful-fallback path in the crate
//! (ridge-jitter recovery of a `NotPosDef` Gram, MVEE non-convergence,
//! score-fallback-to-uniform, line-search failure, invalid-cell
//! scrubbing, shard retries) records itself into a [`Degradations`]
//! record instead of proceeding silently. The record is threaded into
//! `CoresetReport`/`Diagnostics` by the session layer, so a degraded
//! run is observable — never silent — while a clean run reports
//! [`Degradations::is_clean`].
//!
//! All fields are **order-independent counters** (sums, plus one max).
//! Consumer threads record concurrently, but because the set of events
//! is determined by the data and the fixed Merge & Reduce tree shape —
//! never by scheduling — the final record is deterministic for a given
//! seed and source, at any thread/consumer count. This keeps the
//! repo's bitwise-determinism pins intact.

use std::fmt;
use std::sync::{Arc, Mutex};

/// Counters for every graceful-degradation path taken during one
/// session run (sketch + fit). All zeros ⇔ the run was clean.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Degradations {
    /// Gram factorizations that failed `NotPosDef` on the first attempt
    /// and recovered via the escalating ridge-jitter ladder
    /// (`linalg::cholesky_ridge_ladder`).
    pub gram_ridge_recoveries: usize,
    /// Deepest ladder rung (1-based) any recovery needed; 0 if none.
    pub gram_ridge_max_rung: usize,
    /// Khachiyan MVEE runs that hit the iteration cap without reaching
    /// the (1+ε) certificate — scores are still usable, just coarser.
    pub mvee_nonconverged: usize,
    /// MVEE iterations abandoned because the moment matrix would not
    /// factor even after the ridge ladder (scores fall back to the last
    /// valid ellipsoid, or uniform).
    pub mvee_factor_breaks: usize,
    /// Score computations that fell back to uniform/previous weights
    /// (strategy score error, degenerate sampling weights, guarded
    /// small-n ellipsoid path).
    pub score_fallbacks: usize,
    /// L-BFGS line searches that failed to find an acceptable step
    /// (the optimizer stops at the best point seen so far).
    pub line_search_failures: usize,
    /// Optimizer starts with a non-finite objective that had to be
    /// shrunk toward the origin before iterating.
    pub nonfinite_starts: usize,
    /// Non-finite cells seen at ingestion (before masking/dropping).
    pub invalid_cells: usize,
    /// Rows zeroed by `InvalidPolicy::MaskRow`.
    pub rows_masked: usize,
    /// Rows removed by `InvalidPolicy::DropRow`.
    pub rows_dropped: usize,
    /// Transient shard-read errors that were retried (and succeeded —
    /// exhausted retries surface as a typed stream error instead).
    pub shard_retries: usize,
    /// Zero-row shards skipped by the producer without consuming a
    /// sequence number (so determinism is unaffected).
    pub empty_shards_skipped: usize,
    /// Distributed-mode transport retries (reconnect + full-range
    /// re-execution) after which the SAME worker delivered its range.
    /// Recorded only when the range eventually completes — a failed run
    /// leaves this at its pre-attempt value.
    pub worker_retries: usize,
    /// Distributed-mode ranges completed by a DIFFERENT worker after
    /// their original owner was declared dead. Re-executed ranges
    /// reproduce identical bytes, so each reassignment is a recovery,
    /// never a perturbation. Recorded only on range completion.
    pub range_reassignments: usize,
}

impl Degradations {
    /// True iff no fallback of any kind was taken.
    pub fn is_clean(&self) -> bool {
        *self == Degradations::default()
    }

    /// Accumulate another record into this one (counter sums; the
    /// ladder rung takes the max). Order-independent by construction.
    pub fn merge(&mut self, other: &Degradations) {
        self.gram_ridge_recoveries += other.gram_ridge_recoveries;
        self.gram_ridge_max_rung = self.gram_ridge_max_rung.max(other.gram_ridge_max_rung);
        self.mvee_nonconverged += other.mvee_nonconverged;
        self.mvee_factor_breaks += other.mvee_factor_breaks;
        self.score_fallbacks += other.score_fallbacks;
        self.line_search_failures += other.line_search_failures;
        self.nonfinite_starts += other.nonfinite_starts;
        self.invalid_cells += other.invalid_cells;
        self.rows_masked += other.rows_masked;
        self.rows_dropped += other.rows_dropped;
        self.shard_retries += other.shard_retries;
        self.empty_shards_skipped += other.empty_shards_skipped;
        self.worker_retries += other.worker_retries;
        self.range_reassignments += other.range_reassignments;
    }
}

impl fmt::Display for Degradations {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.is_clean() {
            return write!(f, "clean");
        }
        let mut parts: Vec<String> = Vec::new();
        let mut push = |name: &str, v: usize| {
            if v > 0 {
                parts.push(format!("{name}={v}"));
            }
        };
        push("gram_ridge_recoveries", self.gram_ridge_recoveries);
        push("gram_ridge_max_rung", self.gram_ridge_max_rung);
        push("mvee_nonconverged", self.mvee_nonconverged);
        push("mvee_factor_breaks", self.mvee_factor_breaks);
        push("score_fallbacks", self.score_fallbacks);
        push("line_search_failures", self.line_search_failures);
        push("nonfinite_starts", self.nonfinite_starts);
        push("invalid_cells", self.invalid_cells);
        push("rows_masked", self.rows_masked);
        push("rows_dropped", self.rows_dropped);
        push("shard_retries", self.shard_retries);
        push("empty_shards_skipped", self.empty_shards_skipped);
        push("worker_retries", self.worker_retries);
        push("range_reassignments", self.range_reassignments);
        write!(f, "{}", parts.join(" "))
    }
}

/// Cheap-to-clone handle that accumulates [`Degradations`] from any
/// thread. The lock is poison-recovering (`into_inner` on a poisoned
/// guard): a panicking worker elsewhere must never turn degradation
/// *accounting* into a second panic.
#[derive(Clone, Debug, Default)]
pub struct DegradeSink {
    inner: Arc<Mutex<Degradations>>,
}

impl DegradeSink {
    pub fn new() -> Self {
        DegradeSink::default()
    }

    /// Copy of the accumulated record so far.
    pub fn snapshot(&self) -> Degradations {
        self.with(|d| d.clone())
    }

    fn with<R>(&self, f: impl FnOnce(&mut Degradations) -> R) -> R {
        // counters stay meaningful even if a holder panicked mid-update
        let mut guard = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        f(&mut guard)
    }

    /// A Gram factorization recovered at ladder `rung` (1-based).
    pub fn gram_ridge_recovery(&self, rung: usize) {
        self.with(|d| {
            d.gram_ridge_recoveries += 1;
            d.gram_ridge_max_rung = d.gram_ridge_max_rung.max(rung);
        });
    }

    pub fn mvee_nonconverged(&self) {
        self.with(|d| d.mvee_nonconverged += 1);
    }

    pub fn mvee_factor_break(&self) {
        self.with(|d| d.mvee_factor_breaks += 1);
    }

    pub fn score_fallback(&self) {
        self.with(|d| d.score_fallbacks += 1);
    }

    pub fn line_search_failure(&self) {
        self.with(|d| d.line_search_failures += 1);
    }

    pub fn nonfinite_start(&self) {
        self.with(|d| d.nonfinite_starts += 1);
    }

    /// `cells` non-finite cells were found in one row.
    pub fn invalid_cells(&self, cells: usize) {
        self.with(|d| d.invalid_cells += cells);
    }

    pub fn rows_masked(&self, rows: usize) {
        self.with(|d| d.rows_masked += rows);
    }

    pub fn rows_dropped(&self, rows: usize) {
        self.with(|d| d.rows_dropped += rows);
    }

    /// `n` transient shard-read retries that ended in a successful
    /// read. The producer calls this once per recovered shard, after
    /// the retry loop succeeds — exhausted budgets never land here.
    pub fn shard_retries(&self, n: usize) {
        self.with(|d| d.shard_retries += n);
    }

    pub fn empty_shard_skipped(&self) {
        self.with(|d| d.empty_shards_skipped += 1);
    }

    /// `n` transport retries after which the same worker delivered its
    /// range. The distributed coordinator calls this once per completed
    /// range — ranges lost with the run record nothing.
    pub fn worker_retries(&self, n: usize) {
        self.with(|d| d.worker_retries += n);
    }

    /// `n` times a range changed owners before the owner that finally
    /// completed it. Called only at range completion.
    pub fn range_reassignments(&self, n: usize) {
        self.with(|d| d.range_reassignments += n);
    }

    /// Fold a whole record in (used by the distributed coordinator to
    /// absorb a worker's per-range accounting at range completion).
    pub fn merge_record(&self, other: &Degradations) {
        self.with(|d| d.merge(other));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_by_default_and_display() {
        let sink = DegradeSink::new();
        let d = sink.snapshot();
        assert!(d.is_clean());
        assert_eq!(format!("{d}"), "clean");
    }

    #[test]
    fn counters_accumulate_and_merge() {
        let sink = DegradeSink::new();
        sink.gram_ridge_recovery(2);
        sink.gram_ridge_recovery(1);
        sink.shard_retries(1);
        sink.invalid_cells(3);
        sink.rows_dropped(2);
        let d = sink.snapshot();
        assert_eq!(d.gram_ridge_recoveries, 2);
        assert_eq!(d.gram_ridge_max_rung, 2);
        assert_eq!(d.shard_retries, 1);
        assert_eq!(d.invalid_cells, 3);
        assert!(!d.is_clean());

        let mut acc = Degradations::default();
        acc.merge(&d);
        acc.merge(&d);
        assert_eq!(acc.gram_ridge_recoveries, 4);
        assert_eq!(acc.gram_ridge_max_rung, 2);
        assert_eq!(acc.rows_dropped, 4);
        let s = format!("{acc}");
        assert!(s.contains("gram_ridge_recoveries=4"), "{s}");
    }

    #[test]
    fn sink_is_shared_across_clones() {
        let sink = DegradeSink::new();
        let clone = sink.clone();
        clone.score_fallback();
        assert_eq!(sink.snapshot().score_fallbacks, 1);
    }
}
