//! Zero-dependency parallel execution layer (the `rayon` crate is
//! unavailable offline): a scoped worker pool over `std::thread::scope`
//! with **fixed, thread-count-independent chunking** and deterministic
//! reduction order, so every parallel kernel in the crate returns
//! bit-identical results for any number of threads — including 1.
//!
//! Design rules that make determinism hold:
//!   * Work is split into chunks of a fixed size (`ROW_CHUNK` for row
//!     sharding) that depends only on the problem size, never on the
//!     thread count. Workers pull chunk indices from an atomic counter,
//!     so *which* thread computes a chunk varies — but each chunk's
//!     result does not.
//!   * Per-chunk partial results are collected **in chunk order** and
//!     combined by [`tree_reduce`], whose pairing shape depends only on
//!     the number of chunks. Floating-point summation order is therefore
//!     fixed.
//!   * Kernels that write per-row outputs receive disjoint `&mut`
//!     chunk slices (see [`Pool::for_items`]), so outputs land in fixed
//!     locations regardless of scheduling.
//!
//! The global thread count defaults to `std::thread::available_parallelism`,
//! can be pinned by the `MCTM_THREADS` environment variable (benches use
//! this), and overridden at runtime via [`set_threads`] (the CLI
//! `--threads` flag). Hot paths use [`Pool::current`]; tests that prove
//! bit-identity construct explicit [`Pool::new`] instances instead so
//! they don't race on the global.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Fixed rows-per-chunk for the row-sharded kernels. Big enough that
/// per-chunk overhead (spawn amortization, partial-result merging) is
/// negligible, small enough that a 20k-row problem still fans out to
/// ~10 chunks.
pub const ROW_CHUNK: usize = 2048;

/// 0 = uninitialised (resolve from env / hardware on first use).
static GLOBAL_THREADS: AtomicUsize = AtomicUsize::new(0);

fn resolve_default_threads() -> usize {
    if let Ok(v) = std::env::var("MCTM_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Override the global worker count (CLI `--threads`). Thread count
/// never changes results — only wall-clock time — so this is safe to
/// call at any point.
pub fn set_threads(n: usize) {
    GLOBAL_THREADS.store(n.max(1), Ordering::SeqCst);
}

/// The global worker count: `MCTM_THREADS` env var if set, else the
/// machine's available parallelism, else whatever [`set_threads`] chose.
pub fn threads() -> usize {
    match GLOBAL_THREADS.load(Ordering::SeqCst) {
        0 => {
            let n = resolve_default_threads();
            // compare_exchange so a lazy initialiser can never clobber a
            // concurrent explicit set_threads() — whoever wrote first wins
            match GLOBAL_THREADS.compare_exchange(0, n, Ordering::SeqCst, Ordering::SeqCst) {
                Ok(_) => n,
                Err(current) => current,
            }
        }
        n => n,
    }
}

/// A scoped worker pool: holds only the worker count; threads are
/// spawned per call via `std::thread::scope`, which lets kernels borrow
/// stack data without `'static` bounds or unsafe.
#[derive(Clone, Copy, Debug)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    /// Pool with an explicit worker count (clamped to ≥ 1).
    pub fn new(threads: usize) -> Pool {
        Pool { threads: threads.max(1) }
    }

    /// Pool at the global worker count.
    pub fn current() -> Pool {
        Pool::new(threads())
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Fixed chunk grid over `[0, len)` — depends only on `len` and
    /// `chunk`, never on the thread count.
    pub fn chunk_ranges(len: usize, chunk: usize) -> Vec<Range<usize>> {
        assert!(chunk > 0, "chunk size must be positive");
        (0..len.div_ceil(chunk))
            .map(|c| c * chunk..((c + 1) * chunk).min(len))
            .collect()
    }

    /// Map every fixed chunk of `[0, len)` through `f(chunk_idx, range)`
    /// and return the per-chunk results **in chunk order**. The
    /// single-thread path runs inline (no spawn), so `Pool::new(1)` is
    /// the serial reference the determinism tests compare against.
    pub fn map_chunks<R, F>(&self, len: usize, chunk: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize, Range<usize>) -> R + Sync,
    {
        let ranges = Self::chunk_ranges(len, chunk);
        let n = ranges.len();
        let t = self.threads.min(n);
        if t <= 1 {
            return ranges
                .into_iter()
                .enumerate()
                .map(|(i, r)| f(i, r))
                .collect();
        }
        let next = AtomicUsize::new(0);
        let mut parts: Vec<(usize, R)> = Vec::with_capacity(n);
        std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(t);
            for _ in 0..t {
                let next = &next;
                let ranges = &ranges;
                let f = &f;
                handles.push(s.spawn(move || {
                    let mut local = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= ranges.len() {
                            break;
                        }
                        local.push((i, f(i, ranges[i].clone())));
                    }
                    local
                }));
            }
            for h in handles {
                // a worker panic means its chunk's result is gone — there
                // is nothing sound to substitute, so propagate the panic
                // rather than return silently wrong aggregates
                #[allow(clippy::expect_used)]
                parts.extend(h.join().expect("parallel worker panicked"));
            }
        });
        parts.sort_unstable_by_key(|(i, _)| *i);
        parts.into_iter().map(|(_, r)| r).collect()
    }

    /// Map fixed chunks and tree-reduce the partials in one call.
    pub fn reduce_chunks<R, F, M>(&self, len: usize, chunk: usize, f: F, merge: M) -> Option<R>
    where
        R: Send,
        F: Fn(usize, Range<usize>) -> R + Sync,
        M: FnMut(R, R) -> R,
    {
        tree_reduce(self.map_chunks(len, chunk, f), merge)
    }

    /// Run `f(item_idx, item)` over owned work items — typically
    /// disjoint `&mut` chunk slices of an output buffer. Items are
    /// dispatched through a shared queue, so any thread may process any
    /// item; callers must make item results independent of scheduling
    /// (disjoint writes are).
    pub fn for_items<I, F>(&self, items: Vec<I>, f: F)
    where
        I: Send,
        F: Fn(usize, I) + Sync,
    {
        let n = items.len();
        let t = self.threads.min(n);
        if t <= 1 {
            for (i, item) in items.into_iter().enumerate() {
                f(i, item);
            }
            return;
        }
        let queue = Mutex::new(items.into_iter().enumerate());
        std::thread::scope(|s| {
            for _ in 0..t {
                let queue = &queue;
                let f = &f;
                s.spawn(move || loop {
                    // a panicked peer poisons the queue lock, but the
                    // iterator state underneath is still valid — recover
                    // it so the remaining workers drain the queue instead
                    // of cascading the panic
                    let item = queue
                        .lock()
                        .unwrap_or_else(|e| e.into_inner())
                        .next();
                    match item {
                        Some((i, it)) => f(i, it),
                        None => break,
                    }
                });
            }
        });
    }
}

/// Deterministic pairwise tree reduction: pairs (0,1), (2,3), … are
/// merged level by level, so the combination shape (and therefore the
/// floating-point rounding) depends only on `parts.len()` — never on
/// thread scheduling. Returns `None` for an empty input.
pub fn tree_reduce<T>(mut parts: Vec<T>, mut merge: impl FnMut(T, T) -> T) -> Option<T> {
    while parts.len() > 1 {
        let mut next = Vec::with_capacity(parts.len().div_ceil(2));
        let mut it = parts.into_iter();
        while let Some(a) = it.next() {
            match it.next() {
                Some(b) => next.push(merge(a, b)),
                None => next.push(a),
            }
        }
        parts = next;
    }
    parts.pop()
}

/// Element-wise `acc += other` for merging vector-shaped partials.
pub fn add_assign(acc: &mut [f64], other: &[f64]) {
    debug_assert_eq!(acc.len(), other.len());
    for (a, b) in acc.iter_mut().zip(other) {
        *a += b;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_grid_is_fixed_and_covering() {
        let ranges = Pool::chunk_ranges(10, 3);
        assert_eq!(ranges, vec![0..3, 3..6, 6..9, 9..10]);
        assert_eq!(Pool::chunk_ranges(0, 3).len(), 0);
        assert_eq!(Pool::chunk_ranges(3, 3), vec![0..3]);
    }

    #[test]
    fn map_chunks_order_is_chunk_order() {
        for t in [1, 2, 4, 8] {
            let pool = Pool::new(t);
            let out = pool.map_chunks(100, 7, |i, r| (i, r.start, r.end));
            assert_eq!(out.len(), 15);
            for (i, item) in out.iter().enumerate() {
                assert_eq!(item.0, i);
                assert_eq!(item.1, i * 7);
            }
        }
    }

    #[test]
    fn reduce_is_bit_identical_across_thread_counts() {
        // adversarial mix of magnitudes so summation order matters
        let xs: Vec<f64> = (0..10_000)
            .map(|i| ((i * 2654435761_usize) % 1000) as f64 * 1e-3 + 1e9 * ((i % 7) as f64))
            .collect();
        let sum_with = |t: usize| {
            Pool::new(t)
                .reduce_chunks(
                    xs.len(),
                    ROW_CHUNK,
                    |_, r| xs[r].iter().sum::<f64>(),
                    |a, b| a + b,
                )
                .unwrap()
        };
        let reference = sum_with(1);
        for t in [2, 3, 8, 17] {
            let got = sum_with(t);
            assert_eq!(got.to_bits(), reference.to_bits(), "threads={t}");
        }
    }

    #[test]
    fn for_items_disjoint_writes() {
        let mut out = vec![0usize; 1000];
        let items: Vec<(usize, &mut [usize])> = {
            let mut v = Vec::new();
            for (ci, chunk) in out.chunks_mut(64).enumerate() {
                v.push((ci, chunk));
            }
            v
        };
        Pool::new(4).for_items(items, |_, (ci, chunk)| {
            for (k, x) in chunk.iter_mut().enumerate() {
                *x = ci * 64 + k;
            }
        });
        for (i, x) in out.iter().enumerate() {
            assert_eq!(*x, i);
        }
    }

    #[test]
    fn tree_reduce_shape_fixed() {
        // with integers the reduction is exact; check coverage
        let parts: Vec<u64> = (0..13).collect();
        assert_eq!(tree_reduce(parts, |a, b| a + b), Some(78));
        assert_eq!(tree_reduce(Vec::<u64>::new(), |a, b| a + b), None);
        assert_eq!(tree_reduce(vec![5u64], |a, b| a + b), Some(5));
    }

    #[test]
    fn env_and_override() {
        set_threads(3);
        assert_eq!(threads(), 3);
        assert_eq!(Pool::current().threads(), 3);
        set_threads(0); // clamps to 1
        assert_eq!(threads(), 1);
        // restore auto for other tests in this process
        set_threads(resolve_default_threads());
    }
}
