//! Special functions needed by the data-generation processes and the
//! transformation-model metrics: ln Γ, regularized incomplete gamma/beta,
//! normal CDF / quantile, Student-t CDF / quantile, gamma quantile.
//!
//! All implementations are standard (Lanczos, Numerical-Recipes-style
//! series/continued fractions, Acklam inverse-normal) with accuracy well
//! beyond what the DGPs require (~1e-10 relative).

use std::f64::consts::PI;

/// ln Γ(x) via the Lanczos approximation (g = 7, n = 9 coefficients).
pub fn ln_gamma(x: f64) -> f64 {
    const G: f64 = 7.0;
    const COEF: [f64; 9] = [
        0.99999999999980993,
        676.5203681218851,
        -1259.1392167224028,
        771.32342877765313,
        -176.61502916214059,
        12.507343278686905,
        -0.13857109526572012,
        9.9843695780195716e-6,
        1.5056327351493116e-7,
    ];
    if x < 0.5 {
        // reflection formula
        return (PI / (PI * x).sin()).ln() - ln_gamma(1.0 - x);
    }
    let x = x - 1.0;
    let mut a = COEF[0];
    let t = x + G + 0.5;
    for (i, &c) in COEF.iter().enumerate().skip(1) {
        a += c / (x + i as f64);
    }
    0.5 * (2.0 * PI).ln() + (x + 0.5) * t.ln() - t + a.ln()
}

/// Regularized lower incomplete gamma P(a, x).
pub fn gammp(a: f64, x: f64) -> f64 {
    assert!(a > 0.0 && x >= 0.0, "gammp domain: a={a} x={x}");
    if x == 0.0 {
        return 0.0;
    }
    if x < a + 1.0 {
        gamma_series(a, x)
    } else {
        1.0 - gamma_cf(a, x)
    }
}

/// Series representation of P(a, x), converges fast for x < a+1.
fn gamma_series(a: f64, x: f64) -> f64 {
    let mut ap = a;
    let mut sum = 1.0 / a;
    let mut del = sum;
    for _ in 0..500 {
        ap += 1.0;
        del *= x / ap;
        sum += del;
        if del.abs() < sum.abs() * 1e-15 {
            break;
        }
    }
    sum * (-x + a * x.ln() - ln_gamma(a)).exp()
}

/// Continued fraction for Q(a, x) = 1 − P(a, x), converges for x ≥ a+1.
fn gamma_cf(a: f64, x: f64) -> f64 {
    const TINY: f64 = 1e-300;
    let mut b = x + 1.0 - a;
    let mut c = 1.0 / TINY;
    let mut d = 1.0 / b;
    let mut h = d;
    for i in 1..500 {
        let an = -(i as f64) * (i as f64 - a);
        b += 2.0;
        d = an * d + b;
        if d.abs() < TINY {
            d = TINY;
        }
        c = b + an / c;
        if c.abs() < TINY {
            c = TINY;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < 1e-15 {
            break;
        }
    }
    (-x + a * x.ln() - ln_gamma(a)).exp() * h
}

/// Regularized incomplete beta I_x(a, b) (continued fraction, NR style).
pub fn betai(a: f64, b: f64, x: f64) -> f64 {
    assert!((0.0..=1.0).contains(&x), "betai domain x={x}");
    if x == 0.0 || x == 1.0 {
        return x;
    }
    let bt = (ln_gamma(a + b) - ln_gamma(a) - ln_gamma(b)
        + a * x.ln()
        + b * (1.0 - x).ln())
    .exp();
    if x < (a + 1.0) / (a + b + 2.0) {
        bt * beta_cf(a, b, x) / a
    } else {
        1.0 - bt * beta_cf(b, a, 1.0 - x) / b
    }
}

fn beta_cf(a: f64, b: f64, x: f64) -> f64 {
    const TINY: f64 = 1e-300;
    let qab = a + b;
    let qap = a + 1.0;
    let qam = a - 1.0;
    let mut c = 1.0;
    let mut d = 1.0 - qab * x / qap;
    if d.abs() < TINY {
        d = TINY;
    }
    d = 1.0 / d;
    let mut h = d;
    for m in 1..300 {
        let m = m as f64;
        let m2 = 2.0 * m;
        let aa = m * (b - m) * x / ((qam + m2) * (a + m2));
        d = 1.0 + aa * d;
        if d.abs() < TINY {
            d = TINY;
        }
        c = 1.0 + aa / c;
        if c.abs() < TINY {
            c = TINY;
        }
        d = 1.0 / d;
        h *= d * c;
        let aa = -(a + m) * (qab + m) * x / ((a + m2) * (qap + m2));
        d = 1.0 + aa * d;
        if d.abs() < TINY {
            d = TINY;
        }
        c = 1.0 + aa / c;
        if c.abs() < TINY {
            c = TINY;
        }
        d = 1.0 / d;
        let del = d * c;
        h *= del;
        if (del - 1.0).abs() < 1e-14 {
            break;
        }
    }
    h
}

/// Standard normal CDF Φ(x).
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * erfc(-x / std::f64::consts::SQRT_2)
}

/// Standard normal PDF φ(x).
#[inline]
pub fn norm_pdf(x: f64) -> f64 {
    (-(x * x) / 2.0).exp() / (2.0 * PI).sqrt()
}

/// Complementary error function (rational Chebyshev fit, |err| < 1.2e-7,
/// refined by one Newton step against erf'): accurate to ~1e-12 after
/// refinement — enough for quantile transforms.
pub fn erfc(x: f64) -> f64 {
    // NR "erfcc" base approximation
    let z = x.abs();
    let t = 2.0 / (2.0 + z);
    let ty = 4.0 * t - 2.0;
    const COF: [f64; 28] = [
        -1.3026537197817094,
        6.4196979235649026e-1,
        1.9476473204185836e-2,
        -9.561514786808631e-3,
        -9.46595344482036e-4,
        3.66839497852761e-4,
        4.2523324806907e-5,
        -2.0278578112534e-5,
        -1.624290004647e-6,
        1.303655835580e-6,
        1.5626441722e-8,
        -8.5238095915e-8,
        6.529054439e-9,
        5.059343495e-9,
        -9.91364156e-10,
        -2.27365122e-10,
        9.6467911e-11,
        2.394038e-12,
        -6.886027e-12,
        8.94487e-13,
        3.13092e-13,
        -1.12708e-13,
        3.81e-16,
        7.106e-15,
        -1.523e-15,
        -9.4e-17,
        1.21e-16,
        -2.8e-17,
    ];
    let mut d = 0.0;
    let mut dd = 0.0;
    for &c in COF.iter().rev().take(COF.len() - 1) {
        let tmp = d;
        d = ty * d - dd + c;
        dd = tmp;
    }
    let ans = t * (-z * z + 0.5 * (COF[0] + ty * d) - dd).exp();
    if x >= 0.0 {
        ans
    } else {
        2.0 - ans
    }
}

/// Inverse standard normal CDF Φ⁻¹(p) — Acklam's algorithm plus one
/// Halley refinement step (absolute error ≲ 1e-15 in the bulk).
pub fn norm_quantile(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "norm_quantile domain p={p}");
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    let plow = 0.02425;
    let x = if p < plow {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - plow {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    };
    // Halley refinement
    let e = norm_cdf(x) - p;
    let u = e * (2.0 * PI).sqrt() * (x * x / 2.0).exp();
    x - u / (1.0 + x * u / 2.0)
}

/// Student-t CDF with ν degrees of freedom.
pub fn t_cdf(t: f64, nu: f64) -> f64 {
    let x = nu / (nu + t * t);
    let p = 0.5 * betai(nu / 2.0, 0.5, x);
    if t >= 0.0 {
        1.0 - p
    } else {
        p
    }
}

/// Student-t PDF.
pub fn t_pdf(t: f64, nu: f64) -> f64 {
    let c = (ln_gamma((nu + 1.0) / 2.0) - ln_gamma(nu / 2.0)).exp()
        / (nu * PI).sqrt();
    c * (1.0 + t * t / nu).powf(-(nu + 1.0) / 2.0)
}

/// Student-t quantile via Newton on the CDF, started from the normal
/// quantile (good enough for ν ≥ 1 over the DGP range).
pub fn t_quantile(p: f64, nu: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "t_quantile domain p={p}");
    let mut x = norm_quantile(p) * (nu / (nu - 2.0).max(0.5)).sqrt();
    // bracket, then safeguarded Newton (raw Newton runs away in the
    // polynomially-thin t tails)
    let (mut lo, mut hi) = (-1.0f64, 1.0f64);
    while t_cdf(lo, nu) > p {
        lo *= 2.0;
        if lo < -1e12 {
            break;
        }
    }
    while t_cdf(hi, nu) < p {
        hi *= 2.0;
        if hi > 1e12 {
            break;
        }
    }
    x = x.clamp(lo, hi);
    for _ in 0..200 {
        let f = t_cdf(x, nu) - p;
        if f.abs() < 1e-13 {
            break;
        }
        if f > 0.0 {
            hi = x;
        } else {
            lo = x;
        }
        if (hi - lo) < 1e-14 * (1.0 + x.abs()) {
            break;
        }
        let d = t_pdf(x, nu);
        let newton = if d > 1e-300 { x - f / d } else { f64::NAN };
        x = if newton.is_finite() && newton > lo && newton < hi {
            newton
        } else {
            0.5 * (lo + hi)
        };
    }
    x
}

/// Gamma(shape, scale) quantile via Wilson–Hilferty start + Newton on
/// `gammp`.
pub fn gamma_quantile(p: f64, shape: f64, scale: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "gamma_quantile domain p={p}");
    // Wilson–Hilferty: X ≈ a (1 − 1/(9a) + z √(1/(9a)))³
    let z = norm_quantile(p);
    let a = shape;
    let mut x = a * (1.0 - 1.0 / (9.0 * a) + z / (3.0 * a.sqrt())).powi(3);
    if x <= 0.0 {
        x = 1e-8;
    }
    // bracket the root so safeguarded Newton can never run away in the
    // flat tails (the pdf → 0 there and a raw Newton step overshoots)
    let (mut lo, mut hi) = (0.0f64, x.max(1.0));
    while gammp(a, hi) < p {
        hi *= 2.0;
        if hi > 1e12 {
            break;
        }
    }
    x = x.clamp(lo + 1e-12, hi);
    for _ in 0..200 {
        let f = gammp(a, x) - p;
        if f.abs() < 1e-13 {
            break;
        }
        if f > 0.0 {
            hi = x;
        } else {
            lo = x;
        }
        if (hi - lo) < 1e-14 * (1.0 + x) {
            break;
        }
        // gamma pdf (unit scale)
        let d = ((a - 1.0) * x.ln() - x - ln_gamma(a)).exp();
        let newton = if d > 1e-300 { x - f / d } else { f64::NAN };
        x = if newton.is_finite() && newton > lo && newton < hi {
            newton
        } else {
            0.5 * (lo + hi)
        };
    }
    x * scale
}

/// Exponential(rate) quantile.
#[inline]
pub fn exp_quantile(p: f64, rate: f64) -> f64 {
    -(1.0 - p).ln() / rate
}

/// Log-normal(μ, σ) quantile.
#[inline]
pub fn lognormal_quantile(p: f64, mu: f64, sigma: f64) -> f64 {
    (mu + sigma * norm_quantile(p)).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ln_gamma_known_values() {
        assert!((ln_gamma(1.0)).abs() < 1e-12);
        assert!((ln_gamma(2.0)).abs() < 1e-12);
        assert!((ln_gamma(5.0) - 24.0f64.ln()).abs() < 1e-10);
        assert!((ln_gamma(0.5) - (PI.sqrt()).ln()).abs() < 1e-10);
    }

    #[test]
    fn norm_cdf_symmetry_and_values() {
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-12);
        assert!((norm_cdf(1.96) - 0.9750021048517795).abs() < 1e-9);
        for &x in &[0.3, 1.1, 2.7] {
            assert!((norm_cdf(x) + norm_cdf(-x) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn norm_quantile_roundtrip() {
        for &p in &[1e-6, 0.01, 0.2, 0.5, 0.8, 0.99, 1.0 - 1e-6] {
            let x = norm_quantile(p);
            assert!((norm_cdf(x) - p).abs() < 1e-9, "p={p} x={x}");
        }
    }

    #[test]
    fn t_cdf_matches_known() {
        // t(1) is Cauchy: CDF(1) = 0.75
        assert!((t_cdf(1.0, 1.0) - 0.75).abs() < 1e-8);
        // large nu ≈ normal
        assert!((t_cdf(1.96, 1e6) - norm_cdf(1.96)).abs() < 1e-5);
    }

    #[test]
    fn t_quantile_roundtrip() {
        for &nu in &[3.0, 5.0, 10.0] {
            for &p in &[0.01, 0.1, 0.5, 0.9, 0.99] {
                let x = t_quantile(p, nu);
                assert!((t_cdf(x, nu) - p).abs() < 1e-8, "nu={nu} p={p}");
            }
        }
    }

    #[test]
    fn gamma_quantile_roundtrip() {
        for &a in &[0.5, 1.0, 2.0, 7.5] {
            for &p in &[0.05, 0.3, 0.5, 0.9, 0.99] {
                let x = gamma_quantile(p, a, 1.0);
                assert!((gammp(a, x) - p).abs() < 1e-8, "a={a} p={p} x={x}");
            }
        }
    }

    #[test]
    fn gammp_basic() {
        // P(1, x) = 1 - e^{-x}
        for &x in &[0.1, 1.0, 3.0] {
            assert!((gammp(1.0, x) - (1.0 - (-x).exp())).abs() < 1e-12);
        }
    }

    #[test]
    fn betai_symmetry() {
        // I_x(a,b) = 1 - I_{1-x}(b,a)
        let (a, b, x) = (2.5, 1.5, 0.3);
        assert!((betai(a, b, x) - (1.0 - betai(b, a, 1.0 - x))).abs() < 1e-12);
    }

    #[test]
    fn exp_and_lognormal_quantiles() {
        assert!((exp_quantile(0.5, 2.0) - 0.5f64.ln().abs() / 2.0).abs() < 1e-12);
        assert!((lognormal_quantile(0.5, 0.3, 1.1) - 0.3f64.exp()).abs() < 1e-12);
    }
}
