//! Minimal `anyhow`-style error handling (the `anyhow` crate is
//! unavailable in the offline registry): a string-chain error type, a
//! `Result` alias, a `Context` extension trait for `Result`/`Option`,
//! and the `anyhow!` macro. The API mirrors the subset of `anyhow` the
//! coordinator and runtime use, so call sites read identically.

use std::fmt;

/// A chain of human-readable error messages, outermost first.
pub struct Error {
    msgs: Vec<String>,
}

impl Error {
    /// Build from a single message (what `anyhow!` expands to).
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { msgs: vec![m.to_string()] }
    }

    /// Prepend a context message (outermost-first chain order).
    pub fn context(mut self, m: impl fmt::Display) -> Self {
        self.msgs.insert(0, m.to_string());
        self
    }

    /// The full `outer: inner: …` chain.
    pub fn chain(&self) -> String {
        self.msgs.join(": ")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` renders the whole chain, like anyhow
            write!(f, "{}", self.chain())
        } else {
            write!(f, "{}", self.msgs.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain())
    }
}

// Blanket conversion so `?` works on std error types (io, parse, …)
// and in-tree errors like `LinalgError`. `Error` itself deliberately
// does NOT implement `std::error::Error`, exactly like `anyhow::Error`,
// so this blanket impl cannot overlap the reflexive `From<T> for T`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut msgs = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            msgs.push(s.to_string());
            src = s.source();
        }
        Error { msgs }
    }
}

/// Drop-in alias matching `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(|| ..)` to
/// `Result` and `Option`, mirroring `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        // `{:#}` so a wrapped `Error`'s existing chain survives intact
        self.map_err(|e| Error::msg(format!("{e:#}")).context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{e:#}")).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Format-style error constructor, compatible with `anyhow::anyhow!`.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(::std::format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<String> {
        let s = std::fs::read_to_string("/definitely/not/a/file")
            .context("reading config")?;
        Ok(s)
    }

    #[test]
    fn chain_renders_outermost_first() {
        let e = fails_io().unwrap_err();
        let full = format!("{e:#}");
        assert!(full.starts_with("reading config: "), "chain: {full}");
        // plain display is the outermost message only
        assert_eq!(format!("{e}"), "reading config");
    }

    #[test]
    fn macro_and_question_mark() {
        fn parse(v: &str) -> Result<usize> {
            if v.is_empty() {
                return Err(crate::anyhow!("empty value"));
            }
            Ok(v.parse()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(format!("{:#}", parse("").unwrap_err()).contains("empty value"));
        assert!(format!("{:#}", parse("x").unwrap_err()).contains("invalid digit"));
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.with_context(|| format!("missing {}", "field")).unwrap_err();
        assert_eq!(format!("{e:#}"), "missing field");
    }
}
