//! Shared utilities: RNG + distributions, special functions, summary
//! statistics, a stopwatch, CSV/report writers, error handling, the
//! deterministic thread pool, and a tiny randomized property-test
//! harness (the `proptest` crate is unavailable offline).

pub mod degrade;
pub mod error;
pub mod parallel;
pub mod proptest;
pub mod report;
pub mod rng;
pub mod special;

use std::time::Instant;

/// Simple wall-clock stopwatch used by the experiment harness / benches.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    /// Elapsed seconds since start.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed milliseconds since start.
    pub fn millis(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n−1 denominator; 0 for n ≤ 1).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Median (copies + sorts).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// `mean ± std` formatting used in the paper tables.
pub fn fmt_ms(xs: &[f64]) -> String {
    format!("{:.2} ± {:.2}", mean(xs), std_dev(xs))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((std_dev(&xs) - (5.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
        assert!((median(&[3.0, 1.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        let a = sw.secs();
        let b = sw.secs();
        assert!(b >= a && a >= 0.0);
    }
}
