//! Output writers for the experiment harness: aligned console tables
//! (matching the paper's table layout), CSV series for the figures, and a
//! minimal JSON writer for machine-readable results (no `serde` offline).

use std::fmt::Write as _;
use std::fs;
use std::io::Write as _;
use std::path::Path;

/// A console table with a title, column headers and string rows.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "\n== {} ==", self.title);
        let line = |w: &Vec<usize>| -> String {
            let mut s = String::from("+");
            for &wi in w {
                s.push_str(&"-".repeat(wi + 2));
                s.push('+');
            }
            s
        };
        let _ = writeln!(out, "{}", line(&widths));
        let mut hdr = String::from("|");
        for i in 0..ncol {
            let _ = write!(hdr, " {:<w$} |", self.headers[i], w = widths[i]);
        }
        let _ = writeln!(out, "{hdr}");
        let _ = writeln!(out, "{}", line(&widths));
        for row in &self.rows {
            let mut r = String::from("|");
            for i in 0..ncol {
                let _ = write!(r, " {:<w$} |", row[i], w = widths[i]);
            }
            let _ = writeln!(out, "{r}");
        }
        let _ = writeln!(out, "{}", line(&widths));
        out
    }

    /// Print to stdout and, if `path` is Some, also save as CSV.
    pub fn emit(&self, path: Option<&Path>) {
        print!("{}", self.render());
        if let Some(p) = path {
            if let Err(e) = self.save_csv(p) {
                eprintln!("warn: could not save {}: {e}", p.display());
            } else {
                println!("saved {}", p.display());
            }
        }
    }

    pub fn save_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        let mut f = fs::File::create(path)?;
        writeln!(f, "{}", self.headers.join(","))?;
        for row in &self.rows {
            let cells: Vec<String> = row.iter().map(|c| csv_escape(c)).collect();
            writeln!(f, "{}", cells.join(","))?;
        }
        Ok(())
    }
}

fn csv_escape(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Write named numeric series to a CSV file (one column per series) — the
/// figure benches use this to emit plot data.
pub fn write_series_csv(
    path: &Path,
    columns: &[(&str, &[f64])],
) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        fs::create_dir_all(dir)?;
    }
    let mut f = fs::File::create(path)?;
    let headers: Vec<&str> = columns.iter().map(|(h, _)| *h).collect();
    writeln!(f, "{}", headers.join(","))?;
    let rows = columns.iter().map(|(_, v)| v.len()).max().unwrap_or(0);
    for r in 0..rows {
        let cells: Vec<String> = columns
            .iter()
            .map(|(_, v)| {
                v.get(r).map(|x| format!("{x}")).unwrap_or_default()
            })
            .collect();
        writeln!(f, "{}", cells.join(","))?;
    }
    Ok(())
}

/// Minimal JSON value for machine-readable result dumps.
pub enum Json {
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn render(&self) -> String {
        match self {
            Json::Num(x) => {
                if x.is_finite() {
                    format!("{x}")
                } else {
                    "null".to_string()
                }
            }
            Json::Str(s) => format!("\"{}\"", s.replace('\\', "\\\\").replace('"', "\\\"")),
            Json::Arr(xs) => {
                let inner: Vec<String> = xs.iter().map(|x| x.render()).collect();
                format!("[{}]", inner.join(","))
            }
            Json::Obj(kvs) => {
                let inner: Vec<String> = kvs
                    .iter()
                    .map(|(k, v)| format!("\"{}\":{}", k, v.render()))
                    .collect();
                format!("{{{}}}", inner.join(","))
            }
        }
    }

    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        if let Some(dir) = path.parent() {
            fs::create_dir_all(dir)?;
        }
        fs::write(path, self.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["method", "err"]);
        t.row(vec!["l2-hull".into(), "0.44 ± 0.16".into()]);
        t.row(vec!["uniform".into(), "0.29".into()]);
        let s = t.render();
        assert!(s.contains("l2-hull"));
        assert!(s.contains("| method"));
    }

    #[test]
    fn json_renders() {
        let j = Json::Obj(vec![
            ("a".into(), Json::Num(1.5)),
            ("b".into(), Json::Arr(vec![Json::Str("x\"y".into())])),
        ]);
        assert_eq!(j.render(), "{\"a\":1.5,\"b\":[\"x\\\"y\"]}");
    }

    #[test]
    fn csv_escape_quotes() {
        assert_eq!(csv_escape("a,b"), "\"a,b\"");
        assert_eq!(csv_escape("plain"), "plain");
    }
}
