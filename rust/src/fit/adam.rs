//! Adam optimizer (Kingma & Ba) with the standard bias correction —
//! robust first-order fallback for ill-conditioned starts. The
//! iteration loop is allocation-free: moments, best-seen point and the
//! gradient buffer are preallocated and evaluation goes through
//! `Objective::value_grad_into` (pinned by `tests/fit_alloc.rs`).

use super::{FitOptions, Objective};

pub fn minimize(
    obj: &dyn Objective,
    mut x: Vec<f64>,
    opts: &FitOptions,
) -> (Vec<f64>, f64, usize, bool) {
    let n = obj.dim();
    assert_eq!(x.len(), n);
    let (beta1, beta2, eps): (f64, f64, f64) = (0.9, 0.999, 1e-8);
    let mut m = vec![0.0; n];
    let mut v = vec![0.0; n];
    let mut g = vec![0.0; n];
    let mut prev_f = f64::INFINITY;
    let mut best_f = f64::INFINITY;
    let mut best_x = x.clone();
    let mut converged = false;
    let mut iters = 0;
    for t in 1..=opts.max_iters {
        iters = t;
        let f = obj.value_grad_into(&x, &mut g);
        if f.is_finite() && f < best_f {
            best_f = f;
            best_x.copy_from_slice(&x);
        }
        if (prev_f - f).abs() < opts.tol * (1.0 + f.abs()) && t > 10 {
            converged = true;
            break;
        }
        prev_f = f;
        let b1t = 1.0 - beta1.powi(t as i32);
        let b2t = 1.0 - beta2.powi(t as i32);
        for i in 0..n {
            m[i] = beta1 * m[i] + (1.0 - beta1) * g[i];
            v[i] = beta2 * v[i] + (1.0 - beta2) * g[i] * g[i];
            let mh = m[i] / b1t;
            let vh = v[i] / b2t;
            x[i] -= opts.learning_rate * mh / (vh.sqrt() + eps);
        }
    }
    let f_final = obj.value(&x);
    if f_final.is_finite() && f_final <= best_f {
        (x, f_final, iters, converged)
    } else {
        (best_x, best_f, iters, converged)
    }
}

#[cfg(test)]
mod tests {
    use super::super::{FitOptions, Objective, OptimizerKind};

    struct Abs2;
    impl Objective for Abs2 {
        fn dim(&self) -> usize {
            1
        }
        fn value_grad_into(&self, x: &[f64], grad: &mut [f64]) -> f64 {
            grad[0] = 2.0 * x[0];
            x[0] * x[0]
        }
    }

    #[test]
    fn converges_on_scalar() {
        let opts = FitOptions {
            optimizer: OptimizerKind::Adam,
            max_iters: 2000,
            tol: 1e-14,
            learning_rate: 0.1,
            history: 5,
        };
        let (x, f, _, _) = super::minimize(&Abs2, vec![5.0], &opts);
        assert!(f < 1e-8, "f={f} x={x:?}");
    }

    #[test]
    fn returns_best_seen_not_last() {
        // an objective that explodes if x drifts negative keeps best-seen
        struct Tricky;
        impl Objective for Tricky {
            fn dim(&self) -> usize {
                1
            }
            fn value_grad_into(&self, x: &[f64], grad: &mut [f64]) -> f64 {
                if x[0] < 0.05 {
                    grad[0] = 0.0;
                    f64::INFINITY
                } else {
                    grad[0] = 2.0 * (x[0] - 0.1);
                    (x[0] - 0.1).powi(2)
                }
            }
        }
        let opts = FitOptions {
            optimizer: OptimizerKind::Adam,
            max_iters: 200,
            tol: 0.0,
            learning_rate: 0.2,
            history: 5,
        };
        let (_, f, _, _) = super::minimize(&Tricky, vec![1.0], &opts);
        assert!(f.is_finite());
    }
}
