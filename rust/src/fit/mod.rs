//! Maximum-likelihood fitting of MCTMs: an `Objective` abstraction over
//! the two evaluation backends (native Rust and the AOT-compiled XLA
//! executable), plus Adam and L-BFGS optimizers and the high-level
//! `fit` driver used by every experiment.

pub mod adam;
pub mod lbfgs;

use crate::basis::Design;
use crate::mctm::{self, ModelSpec, Params};
use crate::util::Stopwatch;

/// A differentiable objective f: R^p → R.
pub trait Objective {
    fn dim(&self) -> usize;
    fn value_grad(&self, x: &[f64]) -> (f64, Vec<f64>);
    fn value(&self, x: &[f64]) -> f64 {
        self.value_grad(x).0
    }
}

/// Native-Rust weighted MCTM NLL objective.
pub struct NativeNll<'a> {
    pub spec: ModelSpec,
    pub design: &'a Design,
    pub weights: Vec<f64>,
}

impl<'a> NativeNll<'a> {
    pub fn new(spec: ModelSpec, design: &'a Design, weights: Vec<f64>) -> Self {
        assert!(weights.is_empty() || weights.len() == design.n);
        NativeNll { spec, design, weights }
    }
}

impl Objective for NativeNll<'_> {
    fn dim(&self) -> usize {
        self.spec.n_params()
    }

    fn value_grad(&self, x: &[f64]) -> (f64, Vec<f64>) {
        let p = Params::new(self.spec, x.to_vec());
        mctm::nll_grad(self.design, &self.weights, &p)
    }

    fn value(&self, x: &[f64]) -> f64 {
        let p = Params::new(self.spec, x.to_vec());
        mctm::nll(self.design, &self.weights, &p)
    }
}

/// Optimizer selection + stopping configuration.
#[derive(Clone, Debug)]
pub struct FitOptions {
    pub optimizer: OptimizerKind,
    pub max_iters: usize,
    /// stop when |Δf| < tol · (1 + |f|) between successive iterations
    pub tol: f64,
    /// Adam step size
    pub learning_rate: f64,
    /// L-BFGS memory
    pub history: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptimizerKind {
    Adam,
    Lbfgs,
}

impl Default for FitOptions {
    fn default() -> Self {
        FitOptions {
            optimizer: OptimizerKind::Lbfgs,
            max_iters: 300,
            tol: 1e-8,
            learning_rate: 0.05,
            history: 10,
        }
    }
}

/// Fit result: parameters, final NLL, iterations used, wall time.
#[derive(Clone, Debug)]
pub struct FitResult {
    pub params: Params,
    pub nll: f64,
    pub iters: usize,
    pub seconds: f64,
    pub converged: bool,
}

/// Minimize `obj` from `x0`.
pub fn minimize(obj: &dyn Objective, x0: Vec<f64>, opts: &FitOptions) -> (Vec<f64>, f64, usize, bool) {
    match opts.optimizer {
        OptimizerKind::Adam => adam::minimize(obj, x0, opts),
        OptimizerKind::Lbfgs => lbfgs::minimize(obj, x0, opts),
    }
}

/// Fit an MCTM on a (possibly weighted) design with the native backend.
pub fn fit_native(
    spec: ModelSpec,
    design: &Design,
    weights: Vec<f64>,
    opts: &FitOptions,
) -> FitResult {
    let obj = NativeNll::new(spec, design, weights);
    fit_with(&obj, spec, opts)
}

/// Fit with an arbitrary objective (e.g. the XLA-backed one).
pub fn fit_with(obj: &dyn Objective, spec: ModelSpec, opts: &FitOptions) -> FitResult {
    let sw = Stopwatch::start();
    let x0 = Params::init(spec).x;
    let (x, nll, iters, converged) = minimize(obj, x0, opts);
    FitResult {
        params: Params::new(spec, x),
        nll,
        iters,
        seconds: sw.secs(),
        converged,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Convex quadratic for optimizer sanity checks.
    pub struct Quadratic {
        pub center: Vec<f64>,
    }

    impl Objective for Quadratic {
        fn dim(&self) -> usize {
            self.center.len()
        }
        fn value_grad(&self, x: &[f64]) -> (f64, Vec<f64>) {
            let mut v = 0.0;
            let mut g = vec![0.0; x.len()];
            for i in 0..x.len() {
                let scale = (i + 1) as f64;
                let dxi = x[i] - self.center[i];
                v += 0.5 * scale * dxi * dxi;
                g[i] = scale * dxi;
            }
            (v, g)
        }
    }

    #[test]
    fn adam_minimizes_quadratic() {
        let q = Quadratic { center: vec![1.0, -2.0, 3.0] };
        let opts = FitOptions {
            optimizer: OptimizerKind::Adam,
            max_iters: 3000,
            tol: 1e-12,
            learning_rate: 0.05,
            history: 10,
        };
        let (x, v, _, _) = minimize(&q, vec![0.0; 3], &opts);
        assert!(v < 1e-6, "final value {v}");
        for (xi, ci) in x.iter().zip(&q.center) {
            assert!((xi - ci).abs() < 1e-3);
        }
    }

    #[test]
    fn lbfgs_minimizes_quadratic_fast() {
        let q = Quadratic { center: vec![1.0, -2.0, 3.0, 0.5] };
        let opts = FitOptions::default();
        let (x, v, iters, converged) = minimize(&q, vec![0.0; 4], &opts);
        assert!(v < 1e-10, "final value {v}");
        assert!(iters < 50, "iters {iters}");
        assert!(converged);
        for (xi, ci) in x.iter().zip(&q.center) {
            assert!((xi - ci).abs() < 1e-4);
        }
    }

    #[test]
    fn lbfgs_rosenbrock() {
        struct Rosenbrock;
        impl Objective for Rosenbrock {
            fn dim(&self) -> usize {
                2
            }
            fn value_grad(&self, x: &[f64]) -> (f64, Vec<f64>) {
                let (a, b) = (1.0, 100.0);
                let v = (a - x[0]).powi(2) + b * (x[1] - x[0] * x[0]).powi(2);
                let g = vec![
                    -2.0 * (a - x[0]) - 4.0 * b * x[0] * (x[1] - x[0] * x[0]),
                    2.0 * b * (x[1] - x[0] * x[0]),
                ];
                (v, g)
            }
        }
        let opts = FitOptions { max_iters: 2000, ..Default::default() };
        let (x, v, _, _) = minimize(&Rosenbrock, vec![-1.2, 1.0], &opts);
        assert!(v < 1e-8, "final {v} at {x:?}");
    }
}
