//! Maximum-likelihood fitting of MCTMs: an `Objective` abstraction over
//! the two evaluation backends (native Rust and the AOT-compiled XLA
//! executable), plus Adam and L-BFGS optimizers and the high-level
//! `fit` driver used by every experiment.
//!
//! The optimizer loops are allocation-free per iteration: both drivers
//! evaluate through [`Objective::value_grad_into`] into preallocated
//! gradient buffers (pinned by `tests/fit_alloc.rs`), and the native
//! objective keeps a reusable `Params` + kernel scratch so repeated
//! evaluations allocate nothing above the worker pool.

pub mod adam;
pub mod lbfgs;

use crate::basis::Design;
use crate::mctm::{self, ModelSpec, NllScratch, Params};
use crate::util::degrade::DegradeSink;
use crate::util::parallel::Pool;
use crate::util::Stopwatch;
use std::cell::RefCell;

/// A differentiable objective f: R^p → R.
///
/// `value_grad_into` is the required, allocation-free entry point the
/// optimizer loops drive; `value_grad` is a convenience wrapper that
/// allocates a fresh gradient vector.
pub trait Objective {
    fn dim(&self) -> usize;

    /// Evaluate f at `x`, writing ∇f into `grad` (`grad.len() == dim()`)
    /// and returning the value.
    fn value_grad_into(&self, x: &[f64], grad: &mut [f64]) -> f64;

    /// Allocating convenience wrapper over [`Self::value_grad_into`].
    fn value_grad(&self, x: &[f64]) -> (f64, Vec<f64>) {
        let mut grad = vec![0.0; self.dim()];
        let v = self.value_grad_into(x, &mut grad);
        (v, grad)
    }

    fn value(&self, x: &[f64]) -> f64 {
        self.value_grad(x).0
    }
}

/// Native-Rust weighted MCTM NLL objective. Holds a reusable `Params`
/// and kernel scratch behind a `RefCell` (the `Objective` surface is
/// `&self`), so the optimizer loop's repeated evaluations never
/// re-allocate the parameter vector, the ϑ materialization, or the λ
/// offsets — only the per-chunk worker buffers below the pool remain.
pub struct NativeNll<'a> {
    pub spec: ModelSpec,
    pub design: &'a Design,
    pub weights: Vec<f64>,
    state: RefCell<NativeState>,
}

struct NativeState {
    params: Params,
    scratch: NllScratch,
}

impl<'a> NativeNll<'a> {
    pub fn new(spec: ModelSpec, design: &'a Design, weights: Vec<f64>) -> Self {
        assert!(weights.is_empty() || weights.len() == design.n);
        NativeNll {
            spec,
            design,
            weights,
            state: RefCell::new(NativeState {
                params: Params::new(spec, vec![0.0; spec.n_params()]),
                scratch: NllScratch::new(spec),
            }),
        }
    }
}

impl Objective for NativeNll<'_> {
    fn dim(&self) -> usize {
        self.spec.n_params()
    }

    fn value_grad_into(&self, x: &[f64], grad: &mut [f64]) -> f64 {
        let mut st = self.state.borrow_mut();
        let st = &mut *st;
        st.params.x.copy_from_slice(x);
        mctm::nll_grad_into_with(
            self.design,
            &self.weights,
            &st.params,
            grad,
            &mut st.scratch,
            &Pool::current(),
        )
    }

    fn value(&self, x: &[f64]) -> f64 {
        let mut st = self.state.borrow_mut();
        let st = &mut *st;
        st.params.x.copy_from_slice(x);
        mctm::nll_with_scratch(
            self.design,
            &self.weights,
            &st.params,
            &mut st.scratch,
            &Pool::current(),
        )
    }
}

/// Optimizer selection + stopping configuration.
#[derive(Clone, Debug)]
pub struct FitOptions {
    pub optimizer: OptimizerKind,
    pub max_iters: usize,
    /// stop when |Δf| < tol · (1 + |f|) between successive iterations
    pub tol: f64,
    /// Adam step size
    pub learning_rate: f64,
    /// L-BFGS memory
    pub history: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptimizerKind {
    Adam,
    Lbfgs,
}

impl Default for FitOptions {
    fn default() -> Self {
        FitOptions {
            optimizer: OptimizerKind::Lbfgs,
            max_iters: 300,
            tol: 1e-8,
            learning_rate: 0.05,
            history: 10,
        }
    }
}

/// Fit result: parameters, final NLL, iterations used, wall time.
#[derive(Clone, Debug)]
pub struct FitResult {
    pub params: Params,
    pub nll: f64,
    pub iters: usize,
    pub seconds: f64,
    pub converged: bool,
}

/// Minimize `obj` from `x0`.
pub fn minimize(obj: &dyn Objective, x0: Vec<f64>, opts: &FitOptions) -> (Vec<f64>, f64, usize, bool) {
    minimize_with_sink(obj, x0, opts, &DegradeSink::new())
}

/// [`minimize`] with optimizer fallbacks (non-finite start recovery,
/// line-search failure) recorded into `sink`. The sink is pure
/// accounting — iterates are bit-identical with or without it.
pub fn minimize_with_sink(
    obj: &dyn Objective,
    x0: Vec<f64>,
    opts: &FitOptions,
    sink: &DegradeSink,
) -> (Vec<f64>, f64, usize, bool) {
    match opts.optimizer {
        OptimizerKind::Adam => adam::minimize(obj, x0, opts),
        OptimizerKind::Lbfgs => lbfgs::minimize_with_sink(obj, x0, opts, sink),
    }
}

/// Fit an MCTM on a (possibly weighted) design with the native backend.
pub fn fit_native(
    spec: ModelSpec,
    design: &Design,
    weights: Vec<f64>,
    opts: &FitOptions,
) -> FitResult {
    let obj = NativeNll::new(spec, design, weights);
    fit_with(&obj, spec, opts)
}

/// [`fit_native`] with degradation accounting — what `api::Session`
/// calls so optimizer fallbacks land in the run's `Degradations` record.
pub fn fit_native_with_sink(
    spec: ModelSpec,
    design: &Design,
    weights: Vec<f64>,
    opts: &FitOptions,
    sink: &DegradeSink,
) -> FitResult {
    let obj = NativeNll::new(spec, design, weights);
    fit_with_sink(&obj, spec, opts, sink)
}

/// [`fit_native_with_sink`] starting from an explicit parameter vector
/// instead of [`Params::init`] — the warm-start path behind
/// `api::Session::refit_warm`: serving many stress/what-if scenarios
/// off one persisted sketch reuses the previous optimum as the start,
/// which typically converges in a fraction of the cold iterations.
/// `x0.len()` must equal `spec.n_params()` (callers validate).
pub fn fit_native_warm_with_sink(
    spec: ModelSpec,
    design: &Design,
    weights: Vec<f64>,
    x0: Vec<f64>,
    opts: &FitOptions,
    sink: &DegradeSink,
) -> FitResult {
    debug_assert_eq!(x0.len(), spec.n_params());
    let obj = NativeNll::new(spec, design, weights);
    let sw = Stopwatch::start();
    let (x, nll, iters, converged) = minimize_with_sink(&obj, x0, opts, sink);
    FitResult {
        params: Params::new(spec, x),
        nll,
        iters,
        seconds: sw.secs(),
        converged,
    }
}

/// Fit with an arbitrary objective (e.g. the XLA-backed one).
pub fn fit_with(obj: &dyn Objective, spec: ModelSpec, opts: &FitOptions) -> FitResult {
    fit_with_sink(obj, spec, opts, &DegradeSink::new())
}

/// [`fit_with`] recording optimizer fallbacks into `sink`.
pub fn fit_with_sink(
    obj: &dyn Objective,
    spec: ModelSpec,
    opts: &FitOptions,
    sink: &DegradeSink,
) -> FitResult {
    let sw = Stopwatch::start();
    let x0 = Params::init(spec).x;
    let (x, nll, iters, converged) = minimize_with_sink(obj, x0, opts, sink);
    FitResult {
        params: Params::new(spec, x),
        nll,
        iters,
        seconds: sw.secs(),
        converged,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Convex quadratic for optimizer sanity checks.
    pub struct Quadratic {
        pub center: Vec<f64>,
    }

    impl Objective for Quadratic {
        fn dim(&self) -> usize {
            self.center.len()
        }
        fn value_grad_into(&self, x: &[f64], grad: &mut [f64]) -> f64 {
            let mut v = 0.0;
            for i in 0..x.len() {
                let scale = (i + 1) as f64;
                let dxi = x[i] - self.center[i];
                v += 0.5 * scale * dxi * dxi;
                grad[i] = scale * dxi;
            }
            v
        }
    }

    #[test]
    fn adam_minimizes_quadratic() {
        let q = Quadratic { center: vec![1.0, -2.0, 3.0] };
        let opts = FitOptions {
            optimizer: OptimizerKind::Adam,
            max_iters: 3000,
            tol: 1e-12,
            learning_rate: 0.05,
            history: 10,
        };
        let (x, v, _, _) = minimize(&q, vec![0.0; 3], &opts);
        assert!(v < 1e-6, "final value {v}");
        for (xi, ci) in x.iter().zip(&q.center) {
            assert!((xi - ci).abs() < 1e-3);
        }
    }

    #[test]
    fn lbfgs_minimizes_quadratic_fast() {
        let q = Quadratic { center: vec![1.0, -2.0, 3.0, 0.5] };
        let opts = FitOptions::default();
        let (x, v, iters, converged) = minimize(&q, vec![0.0; 4], &opts);
        assert!(v < 1e-10, "final value {v}");
        assert!(iters < 50, "iters {iters}");
        assert!(converged);
        for (xi, ci) in x.iter().zip(&q.center) {
            assert!((xi - ci).abs() < 1e-4);
        }
    }

    #[test]
    fn lbfgs_rosenbrock() {
        struct Rosenbrock;
        impl Objective for Rosenbrock {
            fn dim(&self) -> usize {
                2
            }
            fn value_grad_into(&self, x: &[f64], grad: &mut [f64]) -> f64 {
                let (a, b) = (1.0, 100.0);
                let v = (a - x[0]).powi(2) + b * (x[1] - x[0] * x[0]).powi(2);
                grad[0] = -2.0 * (a - x[0]) - 4.0 * b * x[0] * (x[1] - x[0] * x[0]);
                grad[1] = 2.0 * b * (x[1] - x[0] * x[0]);
                v
            }
        }
        let opts = FitOptions { max_iters: 2000, ..Default::default() };
        let (x, v, _, _) = minimize(&Rosenbrock, vec![-1.2, 1.0], &opts);
        assert!(v < 1e-8, "final {v} at {x:?}");
    }

    #[test]
    fn native_nll_into_matches_allocating_path() {
        use crate::data::dgp::Dgp;
        use crate::util::rng::Rng;
        let mut rng = Rng::new(8);
        let data = Dgp::BivariateNormal.generate(200, &mut rng);
        let design = Design::build(&data, 5, 0.01);
        let spec = ModelSpec::new(2, 5);
        let obj = NativeNll::new(spec, &design, Vec::new());
        let x = Params::init(spec).x;
        let (v, g) = obj.value_grad(&x);
        let mut g2 = vec![0.0; obj.dim()];
        let v2 = obj.value_grad_into(&x, &mut g2);
        assert_eq!(v.to_bits(), v2.to_bits());
        assert_eq!(g, g2);
        assert_eq!(obj.value(&x).to_bits(), v.to_bits());
    }
}
