//! Limited-memory BFGS (two-loop recursion) with Armijo backtracking —
//! the default optimizer: the MCTM NLL is smooth and the parameter
//! dimension is modest (p ≤ ~300), where L-BFGS converges in tens of
//! iterations against Adam's hundreds.

use super::{FitOptions, Objective};

pub fn minimize(
    obj: &dyn Objective,
    mut x: Vec<f64>,
    opts: &FitOptions,
) -> (Vec<f64>, f64, usize, bool) {
    let n = obj.dim();
    assert_eq!(x.len(), n);
    let m = opts.history.max(1);
    let mut s_hist: Vec<Vec<f64>> = Vec::with_capacity(m);
    let mut y_hist: Vec<Vec<f64>> = Vec::with_capacity(m);
    let mut rho: Vec<f64> = Vec::with_capacity(m);

    let (mut f, mut g) = obj.value_grad(&x);
    if !f.is_finite() {
        // fall back: shrink toward origin until finite
        for _ in 0..60 {
            for xi in x.iter_mut() {
                *xi *= 0.5;
            }
            let (f2, g2) = obj.value_grad(&x);
            if f2.is_finite() {
                f = f2;
                g = g2;
                break;
            }
        }
    }
    let mut converged = false;
    let mut iters = 0;

    for it in 0..opts.max_iters {
        iters = it + 1;
        let gnorm = norm(&g);
        if gnorm < opts.tol * (1.0 + f.abs()) {
            converged = true;
            break;
        }

        // two-loop recursion: d = −H g
        let mut q = g.clone();
        let k = s_hist.len();
        let mut alpha = vec![0.0; k];
        for i in (0..k).rev() {
            alpha[i] = rho[i] * dot(&s_hist[i], &q);
            axpy(&mut q, -alpha[i], &y_hist[i]);
        }
        // initial scaling γ = sᵀy / yᵀy
        if k > 0 {
            let gamma = dot(&s_hist[k - 1], &y_hist[k - 1])
                / dot(&y_hist[k - 1], &y_hist[k - 1]).max(1e-300);
            for qi in q.iter_mut() {
                *qi *= gamma;
            }
        }
        for i in 0..k {
            let beta = rho[i] * dot(&y_hist[i], &q);
            axpy(&mut q, alpha[i] - beta, &s_hist[i]);
        }
        let mut d: Vec<f64> = q.iter().map(|v| -v).collect();
        let mut dir_deriv = dot(&g, &d);
        if dir_deriv >= 0.0 {
            // not a descent direction (can happen after a bad pair) —
            // reset to steepest descent
            s_hist.clear();
            y_hist.clear();
            rho.clear();
            d = g.iter().map(|v| -v).collect();
            dir_deriv = -dot(&g, &g);
        }

        // Armijo backtracking
        let c1 = 1e-4;
        let mut step = 1.0;
        let mut accepted = false;
        let mut x_new = x.clone();
        let (mut f_new, mut g_new) = (f, g.clone());
        for _ in 0..50 {
            for i in 0..n {
                x_new[i] = x[i] + step * d[i];
            }
            let (ft, gt) = obj.value_grad(&x_new);
            if ft.is_finite() && ft <= f + c1 * step * dir_deriv {
                f_new = ft;
                g_new = gt;
                accepted = true;
                break;
            }
            step *= 0.5;
        }
        if !accepted {
            // line search failed: gradient is as good as it gets
            converged = true;
            break;
        }

        // curvature pair
        let s: Vec<f64> = (0..n).map(|i| x_new[i] - x[i]).collect();
        let yv: Vec<f64> = (0..n).map(|i| g_new[i] - g[i]).collect();
        let sy = dot(&s, &yv);
        if sy > 1e-12 * norm(&s) * norm(&yv) {
            if s_hist.len() == m {
                s_hist.remove(0);
                y_hist.remove(0);
                rho.remove(0);
            }
            rho.push(1.0 / sy);
            s_hist.push(s);
            y_hist.push(yv);
        }

        let df = (f - f_new).abs();
        x = x_new;
        f = f_new;
        g = g_new;
        if df < opts.tol * (1.0 + f.abs()) {
            converged = true;
            break;
        }
    }
    (x, f, iters, converged)
}

#[inline]
fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[inline]
fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

#[inline]
fn axpy(y: &mut [f64], a: f64, x: &[f64]) {
    for i in 0..y.len() {
        y[i] += a * x[i];
    }
}

#[cfg(test)]
mod tests {
    use super::super::{FitOptions, Objective};

    struct Quartic;
    impl Objective for Quartic {
        fn dim(&self) -> usize {
            2
        }
        fn value_grad(&self, x: &[f64]) -> (f64, Vec<f64>) {
            let v = x[0].powi(4) + (x[1] - 1.0).powi(2);
            (v, vec![4.0 * x[0].powi(3), 2.0 * (x[1] - 1.0)])
        }
    }

    #[test]
    fn converges_quartic() {
        let opts = FitOptions::default();
        let (x, f, _, _) = super::minimize(&Quartic, vec![2.0, -3.0], &opts);
        assert!(f < 1e-8, "f={f}");
        assert!((x[1] - 1.0).abs() < 1e-4);
    }

    #[test]
    fn survives_infinite_start() {
        struct Guard;
        impl Objective for Guard {
            fn dim(&self) -> usize {
                1
            }
            fn value_grad(&self, x: &[f64]) -> (f64, Vec<f64>) {
                if x[0].abs() > 3.0 {
                    (f64::INFINITY, vec![0.0])
                } else {
                    (x[0] * x[0], vec![2.0 * x[0]])
                }
            }
        }
        let opts = FitOptions::default();
        let (x, f, _, _) = super::minimize(&Guard, vec![10.0], &opts);
        assert!(f < 1e-8, "f={f} x={x:?}");
    }
}
