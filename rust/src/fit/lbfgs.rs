//! Limited-memory BFGS (two-loop recursion) with Armijo backtracking —
//! the default optimizer: the MCTM NLL is smooth and the parameter
//! dimension is modest (p ≤ ~300), where L-BFGS converges in tens of
//! iterations against Adam's hundreds.
//!
//! The iteration loop performs **no heap allocation** (pinned by
//! `tests/fit_alloc.rs`): every buffer — gradient, direction, trial
//! point, curvature scratch and the (s, y, ρ) history, stored as a
//! fixed ring of `m` preallocated slots — is allocated once up front,
//! and evaluation goes through `Objective::value_grad_into`. The line
//! search memoizes the (value, gradient) pair of the accepted point (it
//! already computed both to test acceptance), so no re-evaluation
//! happens at the start of the next iteration.

use super::{FitOptions, Objective};
use crate::util::degrade::DegradeSink;

/// [`minimize_with_sink`] with degradation accounting discarded.
pub fn minimize(
    obj: &dyn Objective,
    x: Vec<f64>,
    opts: &FitOptions,
) -> (Vec<f64>, f64, usize, bool) {
    minimize_with_sink(obj, x, opts, &DegradeSink::new())
}

/// Minimize `obj` from `x`, recording numerical fallbacks (non-finite
/// start recovery, line-search failure) into `sink`. The sink never
/// changes the iterates — same inputs give bit-identical output with or
/// without a live sink.
pub fn minimize_with_sink(
    obj: &dyn Objective,
    mut x: Vec<f64>,
    opts: &FitOptions,
    sink: &DegradeSink,
) -> (Vec<f64>, f64, usize, bool) {
    let n = obj.dim();
    assert_eq!(x.len(), n);
    let m = opts.history.max(1);
    // fixed ring of history slots: logical pair i ∈ [0, len) lives in
    // physical slot (head + i) % m, oldest first — identical update
    // order to a push/pop deque, without the per-iteration allocation
    let mut s_hist: Vec<Vec<f64>> = (0..m).map(|_| vec![0.0; n]).collect();
    let mut y_hist: Vec<Vec<f64>> = (0..m).map(|_| vec![0.0; n]).collect();
    let mut rho = vec![0.0; m];
    let mut head = 0usize;
    let mut len = 0usize;

    let mut g = vec![0.0; n];
    let mut g_new = vec![0.0; n];
    let mut q = vec![0.0; n];
    let mut d = vec![0.0; n];
    let mut x_new = vec![0.0; n];
    let mut s_tmp = vec![0.0; n];
    let mut y_tmp = vec![0.0; n];
    let mut alpha = vec![0.0; m];

    let mut f = obj.value_grad_into(&x, &mut g);
    if !f.is_finite() {
        // fall back: shrink toward origin until finite
        sink.nonfinite_start();
        for _ in 0..60 {
            for xi in x.iter_mut() {
                *xi *= 0.5;
            }
            let f2 = obj.value_grad_into(&x, &mut g_new);
            if f2.is_finite() {
                f = f2;
                g.copy_from_slice(&g_new);
                break;
            }
        }
    }
    let mut converged = false;
    let mut iters = 0;

    for it in 0..opts.max_iters {
        iters = it + 1;
        let gnorm = norm(&g);
        if gnorm < opts.tol * (1.0 + f.abs()) {
            converged = true;
            break;
        }

        // two-loop recursion: d = −H g
        q.copy_from_slice(&g);
        for i in (0..len).rev() {
            let pi = (head + i) % m;
            alpha[i] = rho[pi] * dot(&s_hist[pi], &q);
            axpy(&mut q, -alpha[i], &y_hist[pi]);
        }
        // initial scaling γ = sᵀy / yᵀy
        if len > 0 {
            let pl = (head + len - 1) % m;
            let gamma =
                dot(&s_hist[pl], &y_hist[pl]) / dot(&y_hist[pl], &y_hist[pl]).max(1e-300);
            for qi in q.iter_mut() {
                *qi *= gamma;
            }
        }
        for i in 0..len {
            let pi = (head + i) % m;
            let beta = rho[pi] * dot(&y_hist[pi], &q);
            axpy(&mut q, alpha[i] - beta, &s_hist[pi]);
        }
        for i in 0..n {
            d[i] = -q[i];
        }
        let mut dir_deriv = dot(&g, &d);
        if dir_deriv >= 0.0 {
            // not a descent direction (can happen after a bad pair) —
            // reset to steepest descent
            len = 0;
            head = 0;
            for i in 0..n {
                d[i] = -g[i];
            }
            dir_deriv = -dot(&g, &g);
        }

        // Armijo backtracking; the accepted trial's (value, gradient)
        // pair lands in (f_new, g_new) — memoized for the next iteration
        let c1 = 1e-4;
        let mut step = 1.0;
        let mut accepted = false;
        let mut f_new = f;
        for _ in 0..50 {
            for i in 0..n {
                x_new[i] = x[i] + step * d[i];
            }
            let ft = obj.value_grad_into(&x_new, &mut g_new);
            if ft.is_finite() && ft <= f + c1 * step * dir_deriv {
                f_new = ft;
                accepted = true;
                break;
            }
            step *= 0.5;
        }
        if !accepted {
            // line search failed: the current point is as good as the
            // backtracking budget can certify — stop here, but make the
            // early exit visible instead of silently reporting success
            sink.line_search_failure();
            converged = true;
            break;
        }

        // curvature pair — built in scratch first so a rejected pair
        // cannot corrupt a live ring slot
        for i in 0..n {
            s_tmp[i] = x_new[i] - x[i];
            y_tmp[i] = g_new[i] - g[i];
        }
        let sy = dot(&s_tmp, &y_tmp);
        if sy > 1e-12 * norm(&s_tmp) * norm(&y_tmp) {
            let slot = (head + len) % m;
            s_hist[slot].copy_from_slice(&s_tmp);
            y_hist[slot].copy_from_slice(&y_tmp);
            rho[slot] = 1.0 / sy;
            if len == m {
                head = (head + 1) % m; // overwrote the oldest pair
            } else {
                len += 1;
            }
        }

        let df = (f - f_new).abs();
        std::mem::swap(&mut x, &mut x_new);
        std::mem::swap(&mut g, &mut g_new);
        f = f_new;
        if df < opts.tol * (1.0 + f.abs()) {
            converged = true;
            break;
        }
    }
    (x, f, iters, converged)
}

#[inline]
fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

#[inline]
fn norm(a: &[f64]) -> f64 {
    dot(a, a).sqrt()
}

#[inline]
fn axpy(y: &mut [f64], a: f64, x: &[f64]) {
    for i in 0..y.len() {
        y[i] += a * x[i];
    }
}

#[cfg(test)]
mod tests {
    use super::super::{FitOptions, Objective};

    struct Quartic;
    impl Objective for Quartic {
        fn dim(&self) -> usize {
            2
        }
        fn value_grad_into(&self, x: &[f64], grad: &mut [f64]) -> f64 {
            grad[0] = 4.0 * x[0].powi(3);
            grad[1] = 2.0 * (x[1] - 1.0);
            x[0].powi(4) + (x[1] - 1.0).powi(2)
        }
    }

    #[test]
    fn converges_quartic() {
        let opts = FitOptions::default();
        let (x, f, _, _) = super::minimize(&Quartic, vec![2.0, -3.0], &opts);
        assert!(f < 1e-8, "f={f}");
        assert!((x[1] - 1.0).abs() < 1e-4);
    }

    #[test]
    fn survives_infinite_start() {
        struct Guard;
        impl Objective for Guard {
            fn dim(&self) -> usize {
                1
            }
            fn value_grad_into(&self, x: &[f64], grad: &mut [f64]) -> f64 {
                if x[0].abs() > 3.0 {
                    grad[0] = 0.0;
                    f64::INFINITY
                } else {
                    grad[0] = 2.0 * x[0];
                    x[0] * x[0]
                }
            }
        }
        let opts = FitOptions::default();
        let (x, f, _, _) = super::minimize(&Guard, vec![10.0], &opts);
        assert!(f < 1e-8, "f={f} x={x:?}");
    }

    #[test]
    fn ring_history_survives_long_runs() {
        // > m accepted pairs so the ring wraps several times; the
        // optimizer must still converge on an ill-conditioned quadratic
        struct Ill;
        impl Objective for Ill {
            fn dim(&self) -> usize {
                12
            }
            fn value_grad_into(&self, x: &[f64], grad: &mut [f64]) -> f64 {
                let mut v = 0.0;
                for i in 0..x.len() {
                    let s = ((i + 1) * (i + 1)) as f64;
                    v += 0.5 * s * x[i] * x[i];
                    grad[i] = s * x[i];
                }
                v
            }
        }
        let opts = FitOptions { history: 3, max_iters: 500, ..Default::default() };
        let (_, f, _, converged) = super::minimize(&Ill, vec![1.0; 12], &opts);
        assert!(f < 1e-10, "f={f}");
        assert!(converged);
    }
}
