//! `mctm-serve` — the deployment-shaped serving binary: point it at a
//! directory of persisted `*.mctm` model artifacts and it answers
//! density / CDF / quantile / sample / conditional queries over HTTP
//! until killed. Unlike `mctm-coreset serve` it carries no experiment
//! configuration at all — fit and `save` elsewhere, serve here.
//!
//! USAGE: mctm-serve --models DIR [--addr HOST:PORT] [--threads N]

use mctm_coreset::server::{ModelRegistry, Server};
use std::path::PathBuf;
use std::sync::Arc;

fn usage() -> &'static str {
    "mctm-serve — serve persisted mctm-coreset model artifacts over HTTP

USAGE:
  mctm-serve --models DIR [--addr HOST:PORT] [--threads N]

  --models DIR     directory of *.mctm model artifacts (written by
                   `mctm-coreset save --out`), registered by file stem
  --addr HOST:PORT bind address (default 127.0.0.1:7878; :0 picks a
                   free port — the bound address is printed)
  --threads N      worker threads (default: available parallelism)

ENDPOINTS (GET, JSON):
  /health   /metrics   /v1/models
  /v1/models/{name}/density?y=a,b,…
  /v1/models/{name}/cdf?j=0&y=1.5
  /v1/models/{name}/quantile?j=0&p=0.5
  /v1/models/{name}/sample?n=10&seed=1
  /v1/models/{name}/conditional?given=a,b&n=5&seed=2"
}

fn parse_args() -> Result<(PathBuf, String), String> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut models: Option<PathBuf> = None;
    let mut addr = "127.0.0.1:7878".to_string();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--models" => {
                models = Some(PathBuf::from(
                    args.get(i + 1).ok_or("--models needs a value")?,
                ));
                i += 2;
            }
            "--addr" => {
                addr = args.get(i + 1).ok_or("--addr needs a value")?.clone();
                i += 2;
            }
            "--threads" => {
                let n: usize = args
                    .get(i + 1)
                    .ok_or("--threads needs a value")?
                    .parse()
                    .map_err(|_| "--threads needs a positive integer".to_string())?;
                mctm_coreset::util::parallel::set_threads(n);
                i += 2;
            }
            "--help" | "-h" | "help" => {
                println!("{}", usage());
                std::process::exit(0);
            }
            other => return Err(format!("unknown flag {other}\n\n{}", usage())),
        }
    }
    let models = models.ok_or_else(|| format!("--models DIR is required\n\n{}", usage()))?;
    Ok((models, addr))
}

fn main() {
    let (models, addr) = match parse_args() {
        Ok(v) => v,
        Err(msg) => {
            eprintln!("{msg}");
            std::process::exit(2);
        }
    };
    let registry = Arc::new(ModelRegistry::new());
    match registry.load_dir(&models) {
        Ok(0) => {
            eprintln!("no *.mctm artifacts in {}", models.display());
            std::process::exit(1);
        }
        Ok(n) => println!("loaded {n} model(s) from {}", models.display()),
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
    for name in registry.names() {
        println!("  {name}");
    }
    let server = match Server::bind(&addr, registry) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    };
    println!("serving on http://{}", server.local_addr());
    server.run();
}
