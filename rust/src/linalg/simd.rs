//! Runtime-dispatched SIMD variants of the blocked linear-algebra
//! kernels: explicit f64×4-lane (AVX2 + FMA) implementations of
//! [`panel_matvec`](crate::linalg::panel_matvec),
//! [`panel_accum_t`](crate::linalg::panel_accum_t),
//! [`panel_accum_t1`](crate::linalg::panel_accum_t1) and the syrk
//! updates behind the Gram paths, selected once per process through
//! [`KernelBackend`].
//!
//! ## Backend selection
//!
//! The backend is a process global resolved exactly like the worker
//! count in `util::parallel`: the `MCTM_SIMD` environment variable
//! (`off` / `0` / `false` / `scalar` force the scalar reference path)
//! is consulted first, then `is_x86_feature_detected!` picks Simd when
//! the host has AVX2 + FMA. [`set_backend`] overrides at runtime (the
//! facade's `SessionBuilder::kernel_backend` and the benches use it);
//! a Simd request on a host without the features clamps to Scalar, so
//! [`backend`] never returns an unrunnable variant.
//!
//! ## Numerical contract — per-backend guarantees
//!
//! * **Scalar** is the bit-exact reference: every pre-existing bitwise
//!   pin (blocked ≡ row-at-a-time, plane-direct ≡ materialized,
//!   threads/consumers/artifact reproduction) holds unchanged.
//! * **Simd** forks the floating-point summation order (4-wide FMA
//!   lanes + horizontal reduction), so it is pinned to ≤ 1e-12
//!   *relative* agreement with Scalar (`tests/simd_kernels.rs`) — and
//!   it is internally deterministic: the lane grouping depends only on
//!   the problem shape, never on threads, so same seed + same backend
//!   ⇒ bitwise-same results. Cross-backend bit-identity is explicitly
//!   NOT claimed.
//!
//! The kernels themselves live here as `unsafe` `#[target_feature]`
//! functions plus safe wrappers that fall back to the scalar reference
//! on non-x86_64 targets; the public dispatching entry points stay in
//! `linalg` so call sites are untouched.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Which kernel implementation the process runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelBackend {
    /// Bit-exact reference kernels (scalar f64, 4-row blocking only).
    Scalar,
    /// AVX2 + FMA f64×4-lane kernels (x86_64 with runtime detection).
    Simd,
}

impl KernelBackend {
    fn to_tag(self) -> usize {
        match self {
            KernelBackend::Scalar => 1,
            KernelBackend::Simd => 2,
        }
    }

    fn from_tag(tag: usize) -> KernelBackend {
        if tag == 2 {
            KernelBackend::Simd
        } else {
            KernelBackend::Scalar
        }
    }
}

/// 0 = unresolved (env / feature detection on first use), 1 = Scalar,
/// 2 = Simd — the same lazy-global idiom as `parallel::GLOBAL_THREADS`.
static BACKEND: AtomicUsize = AtomicUsize::new(0);

/// Whether the AVX2 + FMA kernels can run on this host.
pub fn simd_available() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        std::is_x86_feature_detected!("avx2") && std::is_x86_feature_detected!("fma")
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

fn resolve_default_backend() -> KernelBackend {
    if let Ok(v) = std::env::var("MCTM_SIMD") {
        let v = v.trim().to_ascii_lowercase();
        if matches!(v.as_str(), "off" | "0" | "false" | "scalar") {
            return KernelBackend::Scalar;
        }
    }
    if simd_available() {
        KernelBackend::Simd
    } else {
        KernelBackend::Scalar
    }
}

/// Pin the kernel backend. A `Simd` request on a host without
/// AVX2 + FMA clamps to `Scalar` (the choice never changes
/// correctness — Scalar is the reference — only throughput and the
/// FP summation order).
pub fn set_backend(b: KernelBackend) {
    let b = if b == KernelBackend::Simd && !simd_available() {
        KernelBackend::Scalar
    } else {
        b
    };
    BACKEND.store(b.to_tag(), Ordering::SeqCst);
}

/// The active kernel backend: `MCTM_SIMD` env override, else AVX2+FMA
/// auto-detection, else whatever [`set_backend`] chose — resolved once
/// and cached (compare-exchange so a lazy init never clobbers a
/// concurrent explicit [`set_backend`]).
pub fn backend() -> KernelBackend {
    match BACKEND.load(Ordering::SeqCst) {
        0 => {
            let b = resolve_default_backend();
            match BACKEND.compare_exchange(0, b.to_tag(), Ordering::SeqCst, Ordering::SeqCst) {
                Ok(_) => b,
                Err(current) => KernelBackend::from_tag(current),
            }
        }
        tag => KernelBackend::from_tag(tag),
    }
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! The AVX2 + FMA kernel bodies. All of them assume the same slice
    //! shapes their scalar twins `debug_assert`, and are only reachable
    //! through the safe wrappers below after a runtime feature check.
    use std::arch::x86_64::*;
    use std::ops::Range;

    /// Horizontal sums of four accumulators into `[Σs0, Σs1, Σs2, Σs3]`.
    #[inline]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn hsum4(s0: __m256d, s1: __m256d, s2: __m256d, s3: __m256d) -> [f64; 4] {
        // hadd pairs within 128-bit halves; the permutes regroup the
        // low/high halves per accumulator so one add finishes all four.
        let t0 = _mm256_hadd_pd(s0, s1);
        let t1 = _mm256_hadd_pd(s2, s3);
        let lo = _mm256_permute2f128_pd(t0, t1, 0x20);
        let hi = _mm256_permute2f128_pd(t0, t1, 0x31);
        let mut out = [0.0f64; 4];
        _mm256_storeu_pd(out.as_mut_ptr(), _mm256_add_pd(lo, hi));
        out
    }

    /// # Safety
    /// Requires AVX2 + FMA; `panel.len() == out.len() * d`, `v.len() == d`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn panel_matvec(panel: &[f64], d: usize, v: &[f64], out: &mut [f64]) {
        let rows = out.len();
        let vp = v.as_ptr();
        let d4 = d & !3;
        let mut r = 0usize;
        while r + 4 <= rows {
            let p0 = panel.as_ptr().add(r * d);
            let p1 = p0.add(d);
            let p2 = p1.add(d);
            let p3 = p2.add(d);
            let mut s0 = _mm256_setzero_pd();
            let mut s1 = _mm256_setzero_pd();
            let mut s2 = _mm256_setzero_pd();
            let mut s3 = _mm256_setzero_pd();
            let mut k = 0usize;
            while k < d4 {
                let vk = _mm256_loadu_pd(vp.add(k));
                s0 = _mm256_fmadd_pd(_mm256_loadu_pd(p0.add(k)), vk, s0);
                s1 = _mm256_fmadd_pd(_mm256_loadu_pd(p1.add(k)), vk, s1);
                s2 = _mm256_fmadd_pd(_mm256_loadu_pd(p2.add(k)), vk, s2);
                s3 = _mm256_fmadd_pd(_mm256_loadu_pd(p3.add(k)), vk, s3);
                k += 4;
            }
            let mut sums = hsum4(s0, s1, s2, s3);
            while k < d {
                let vk = *vp.add(k);
                sums[0] += *p0.add(k) * vk;
                sums[1] += *p1.add(k) * vk;
                sums[2] += *p2.add(k) * vk;
                sums[3] += *p3.add(k) * vk;
                k += 1;
            }
            out[r] = sums[0];
            out[r + 1] = sums[1];
            out[r + 2] = sums[2];
            out[r + 3] = sums[3];
            r += 4;
        }
        while r < rows {
            let p = panel.as_ptr().add(r * d);
            let mut acc = _mm256_setzero_pd();
            let mut k = 0usize;
            while k < d4 {
                acc = _mm256_fmadd_pd(
                    _mm256_loadu_pd(p.add(k)),
                    _mm256_loadu_pd(vp.add(k)),
                    acc,
                );
                k += 4;
            }
            let mut lanes = [0.0f64; 4];
            _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
            let mut s = (lanes[0] + lanes[2]) + (lanes[1] + lanes[3]);
            while k < d {
                s += *p.add(k) * *vp.add(k);
                k += 1;
            }
            out[r] = s;
            r += 1;
        }
    }

    /// # Safety
    /// Requires AVX2 + FMA; panel lengths `ca.len() * d`, `cad.len() ==
    /// ca.len()`, `acc.len() == d`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn panel_accum_t(
        a_panel: &[f64],
        ad_panel: &[f64],
        d: usize,
        ca: &[f64],
        cad: &[f64],
        acc: &mut [f64],
    ) {
        let rows = ca.len();
        let d4 = d & !3;
        let mut r = 0usize;
        while r + 4 <= rows {
            let a0 = a_panel.as_ptr().add(r * d);
            let a1 = a0.add(d);
            let a2 = a1.add(d);
            let a3 = a2.add(d);
            let b0 = ad_panel.as_ptr().add(r * d);
            let b1 = b0.add(d);
            let b2 = b1.add(d);
            let b3 = b2.add(d);
            let c0 = _mm256_set1_pd(ca[r]);
            let c1 = _mm256_set1_pd(ca[r + 1]);
            let c2 = _mm256_set1_pd(ca[r + 2]);
            let c3 = _mm256_set1_pd(ca[r + 3]);
            let e0 = _mm256_set1_pd(cad[r]);
            let e1 = _mm256_set1_pd(cad[r + 1]);
            let e2 = _mm256_set1_pd(cad[r + 2]);
            let e3 = _mm256_set1_pd(cad[r + 3]);
            let mut k = 0usize;
            while k < d4 {
                let mut g = _mm256_loadu_pd(acc.as_ptr().add(k));
                g = _mm256_fmadd_pd(c0, _mm256_loadu_pd(a0.add(k)), g);
                g = _mm256_fmadd_pd(e0, _mm256_loadu_pd(b0.add(k)), g);
                g = _mm256_fmadd_pd(c1, _mm256_loadu_pd(a1.add(k)), g);
                g = _mm256_fmadd_pd(e1, _mm256_loadu_pd(b1.add(k)), g);
                g = _mm256_fmadd_pd(c2, _mm256_loadu_pd(a2.add(k)), g);
                g = _mm256_fmadd_pd(e2, _mm256_loadu_pd(b2.add(k)), g);
                g = _mm256_fmadd_pd(c3, _mm256_loadu_pd(a3.add(k)), g);
                g = _mm256_fmadd_pd(e3, _mm256_loadu_pd(b3.add(k)), g);
                _mm256_storeu_pd(acc.as_mut_ptr().add(k), g);
                k += 4;
            }
            while k < d {
                let mut g = acc[k];
                g += ca[r] * *a0.add(k) + cad[r] * *b0.add(k);
                g += ca[r + 1] * *a1.add(k) + cad[r + 1] * *b1.add(k);
                g += ca[r + 2] * *a2.add(k) + cad[r + 2] * *b2.add(k);
                g += ca[r + 3] * *a3.add(k) + cad[r + 3] * *b3.add(k);
                acc[k] = g;
                k += 1;
            }
            r += 4;
        }
        while r < rows {
            let a = a_panel.as_ptr().add(r * d);
            let b = ad_panel.as_ptr().add(r * d);
            let c = _mm256_set1_pd(ca[r]);
            let e = _mm256_set1_pd(cad[r]);
            let mut k = 0usize;
            while k < d4 {
                let mut g = _mm256_loadu_pd(acc.as_ptr().add(k));
                g = _mm256_fmadd_pd(c, _mm256_loadu_pd(a.add(k)), g);
                g = _mm256_fmadd_pd(e, _mm256_loadu_pd(b.add(k)), g);
                _mm256_storeu_pd(acc.as_mut_ptr().add(k), g);
                k += 4;
            }
            while k < d {
                acc[k] += ca[r] * *a.add(k) + cad[r] * *b.add(k);
                k += 1;
            }
            r += 1;
        }
    }

    /// # Safety
    /// Requires AVX2 + FMA; `panel.len() == c.len() * d`, `acc.len() == d`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn panel_accum_t1(panel: &[f64], d: usize, c: &[f64], acc: &mut [f64]) {
        let rows = c.len();
        let d4 = d & !3;
        let mut r = 0usize;
        while r + 4 <= rows {
            let p0 = panel.as_ptr().add(r * d);
            let p1 = p0.add(d);
            let p2 = p1.add(d);
            let p3 = p2.add(d);
            let c0 = _mm256_set1_pd(c[r]);
            let c1 = _mm256_set1_pd(c[r + 1]);
            let c2 = _mm256_set1_pd(c[r + 2]);
            let c3 = _mm256_set1_pd(c[r + 3]);
            let mut k = 0usize;
            while k < d4 {
                let mut g = _mm256_loadu_pd(acc.as_ptr().add(k));
                g = _mm256_fmadd_pd(c0, _mm256_loadu_pd(p0.add(k)), g);
                g = _mm256_fmadd_pd(c1, _mm256_loadu_pd(p1.add(k)), g);
                g = _mm256_fmadd_pd(c2, _mm256_loadu_pd(p2.add(k)), g);
                g = _mm256_fmadd_pd(c3, _mm256_loadu_pd(p3.add(k)), g);
                _mm256_storeu_pd(acc.as_mut_ptr().add(k), g);
                k += 4;
            }
            while k < d {
                let mut g = acc[k];
                g += c[r] * *p0.add(k);
                g += c[r + 1] * *p1.add(k);
                g += c[r + 2] * *p2.add(k);
                g += c[r + 3] * *p3.add(k);
                acc[k] = g;
                k += 1;
            }
            r += 4;
        }
        while r < rows {
            let p = panel.as_ptr().add(r * d);
            let cv = _mm256_set1_pd(c[r]);
            let mut k = 0usize;
            while k < d4 {
                let mut g = _mm256_loadu_pd(acc.as_ptr().add(k));
                g = _mm256_fmadd_pd(cv, _mm256_loadu_pd(p.add(k)), g);
                _mm256_storeu_pd(acc.as_mut_ptr().add(k), g);
                k += 4;
            }
            while k < d {
                acc[k] += c[r] * *p.add(k);
                k += 1;
            }
            r += 1;
        }
    }

    /// # Safety
    /// Requires AVX2 + FMA; `r0..r3` same length `dcols`, `g` a flat
    /// `dcols × dcols` buffer, `ir`/`jr` within `[0, dcols]`.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn syrk_upper_rows4_range(
        r0: &[f64],
        r1: &[f64],
        r2: &[f64],
        r3: &[f64],
        ir: Range<usize>,
        jr: Range<usize>,
        g: &mut [f64],
    ) {
        let dcols = r0.len();
        for i in ir {
            let (a0, a1, a2, a3) = (r0[i], r1[i], r2[i], r3[i]);
            if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                continue;
            }
            let va0 = _mm256_set1_pd(a0);
            let va1 = _mm256_set1_pd(a1);
            let va2 = _mm256_set1_pd(a2);
            let va3 = _mm256_set1_pd(a3);
            let grow = g.as_mut_ptr().add(i * dcols);
            let mut j = jr.start.max(i);
            while j + 4 <= jr.end {
                let mut gv = _mm256_loadu_pd(grow.add(j));
                gv = _mm256_fmadd_pd(va0, _mm256_loadu_pd(r0.as_ptr().add(j)), gv);
                gv = _mm256_fmadd_pd(va1, _mm256_loadu_pd(r1.as_ptr().add(j)), gv);
                gv = _mm256_fmadd_pd(va2, _mm256_loadu_pd(r2.as_ptr().add(j)), gv);
                gv = _mm256_fmadd_pd(va3, _mm256_loadu_pd(r3.as_ptr().add(j)), gv);
                _mm256_storeu_pd(grow.add(j), gv);
                j += 4;
            }
            while j < jr.end {
                // scalar FMA chain in the SAME order as the vector
                // lanes, so an entry's bits never depend on whether the
                // tile grouping lands it in the 4-wide or remainder
                // path — this is what keeps the L2-tiled Gram
                // bit-identical to the untiled sweep on this backend
                let g0 = a0.mul_add(r0[j], *grow.add(j));
                let g1 = a1.mul_add(r1[j], g0);
                let g2 = a2.mul_add(r2[j], g1);
                *grow.add(j) = a3.mul_add(r3[j], g2);
                j += 1;
            }
        }
    }

    /// # Safety
    /// Requires AVX2 + FMA; same shape contract as
    /// [`syrk_upper_rows4_range`] with a single row.
    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn syrk_upper_row1_range(
        row: &[f64],
        ir: Range<usize>,
        jr: Range<usize>,
        g: &mut [f64],
    ) {
        let dcols = row.len();
        for i in ir {
            let xi = row[i];
            if xi == 0.0 {
                continue;
            }
            let vxi = _mm256_set1_pd(xi);
            let grow = g.as_mut_ptr().add(i * dcols);
            let mut j = jr.start.max(i);
            while j + 4 <= jr.end {
                let mut gv = _mm256_loadu_pd(grow.add(j));
                gv = _mm256_fmadd_pd(vxi, _mm256_loadu_pd(row.as_ptr().add(j)), gv);
                _mm256_storeu_pd(grow.add(j), gv);
                j += 4;
            }
            while j < jr.end {
                // scalar FMA to match the vector lanes (see rows4)
                *grow.add(j) = xi.mul_add(row[j], *grow.add(j));
                j += 1;
            }
        }
    }
}

// ---------------------------------------------------------------------
// Safe Simd entry points. On x86_64 they run the AVX2+FMA bodies after
// asserting availability; on other targets they degrade to the scalar
// reference so the crate builds and behaves identically everywhere.
// `tests/simd_kernels.rs` calls these directly (guarded on
// `simd_available()`) to pin Simd-vs-Scalar agreement per kernel.

/// SIMD [`crate::linalg::panel_matvec`]. Panics (debug) if the host
/// lacks AVX2+FMA on x86_64; scalar fallback elsewhere.
pub fn panel_matvec_simd(panel: &[f64], d: usize, v: &[f64], out: &mut [f64]) {
    #[cfg(target_arch = "x86_64")]
    {
        debug_assert!(simd_available(), "Simd backend on non-AVX2 host");
        unsafe { x86::panel_matvec(panel, d, v, out) }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        super::panel_matvec_scalar(panel, d, v, out)
    }
}

/// SIMD [`crate::linalg::panel_accum_t`].
pub fn panel_accum_t_simd(
    a_panel: &[f64],
    ad_panel: &[f64],
    d: usize,
    ca: &[f64],
    cad: &[f64],
    acc: &mut [f64],
) {
    #[cfg(target_arch = "x86_64")]
    {
        debug_assert!(simd_available(), "Simd backend on non-AVX2 host");
        unsafe { x86::panel_accum_t(a_panel, ad_panel, d, ca, cad, acc) }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        super::panel_accum_t_scalar(a_panel, ad_panel, d, ca, cad, acc)
    }
}

/// SIMD [`crate::linalg::panel_accum_t1`].
pub fn panel_accum_t1_simd(panel: &[f64], d: usize, c: &[f64], acc: &mut [f64]) {
    #[cfg(target_arch = "x86_64")]
    {
        debug_assert!(simd_available(), "Simd backend on non-AVX2 host");
        unsafe { x86::panel_accum_t1(panel, d, c, acc) }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        super::panel_accum_t1_scalar(panel, d, c, acc)
    }
}

/// SIMD [`crate::linalg::syrk_upper_rows4_range`].
pub fn syrk_upper_rows4_range_simd(
    r0: &[f64],
    r1: &[f64],
    r2: &[f64],
    r3: &[f64],
    ir: std::ops::Range<usize>,
    jr: std::ops::Range<usize>,
    g: &mut [f64],
) {
    #[cfg(target_arch = "x86_64")]
    {
        debug_assert!(simd_available(), "Simd backend on non-AVX2 host");
        unsafe { x86::syrk_upper_rows4_range(r0, r1, r2, r3, ir, jr, g) }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        super::syrk_upper_rows4_range_scalar(r0, r1, r2, r3, ir, jr, g)
    }
}

/// SIMD [`crate::linalg::syrk_upper_row1_range`].
pub fn syrk_upper_row1_range_simd(
    row: &[f64],
    ir: std::ops::Range<usize>,
    jr: std::ops::Range<usize>,
    g: &mut [f64],
) {
    #[cfg(target_arch = "x86_64")]
    {
        debug_assert!(simd_available(), "Simd backend on non-AVX2 host");
        unsafe { x86::syrk_upper_row1_range(row, ir, jr, g) }
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        super::syrk_upper_row1_range_scalar(row, ir, jr, g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_tags_roundtrip() {
        for b in [KernelBackend::Scalar, KernelBackend::Simd] {
            assert_eq!(KernelBackend::from_tag(b.to_tag()), b);
        }
        // unknown tags degrade to the reference backend
        assert_eq!(KernelBackend::from_tag(0), KernelBackend::Scalar);
        assert_eq!(KernelBackend::from_tag(7), KernelBackend::Scalar);
    }

    #[test]
    fn backend_resolves_to_a_runnable_variant() {
        let b = backend();
        if b == KernelBackend::Simd {
            assert!(simd_available());
        }
    }
}
