//! Small dense linear algebra used by the coreset pipeline: a row-major
//! matrix type, Gram products (syrk), Cholesky factorization + triangular
//! solves, Householder QR, and inverse-via-Cholesky — everything the
//! leverage-score computation and the Gaussian-copula math need.
//! Dimensions are small (dJ ≤ ~150), rows are many (n up to ~600k), so
//! hot loops are written cache-friendly over contiguous rows, blocked
//! four rows at a time, and row-sharded across the deterministic worker
//! pool (`util::parallel`): fixed chunking + tree reduction keep results
//! bit-identical for any thread count.
//!
//! ## Kernel backends
//!
//! The hot kernels — [`panel_matvec`], [`panel_accum_t`],
//! [`panel_accum_t1`] and the syrk updates — exist in two
//! implementations selected once per process through
//! [`simd::KernelBackend`] (see the [`simd`] module for the selection
//! and numerical-contract details): the **Scalar** bodies
//! (`*_scalar`, kept verbatim as the bit-exact reference every bitwise
//! pin is stated against) and the AVX2+FMA **Simd** variants. The
//! public entry points here dispatch on [`simd::backend()`]; within
//! one backend every determinism guarantee (thread count, consumer
//! count, chunking) holds unchanged, because the lane/blocking shape
//! depends only on the problem size.

pub mod simd;

use crate::util::parallel::{add_assign, tree_reduce, Pool, ROW_CHUNK};
use simd::KernelBackend;
use std::ops::Range;

/// Row-major dense f64 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let r = rows.len();
        let c = if r > 0 { rows[0].len() } else { 0 };
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f64 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f64 {
        &mut self.data[r * self.cols + c]
    }

    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Select a subset of rows (coreset restriction A(S)).
    pub fn select_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (k, &i) in idx.iter().enumerate() {
            out.row_mut(k).copy_from_slice(self.row(i));
        }
        out
    }

    /// Dense matmul, blocked four output rows at a time (each pass over
    /// `other`'s rows feeds four accumulator rows, quartering the reload
    /// traffic of the naive triple loop) and row-sharded on the pool for
    /// tall left factors. Every output row is produced by exactly one
    /// chunk with a fixed k-order, so results don't depend on the thread
    /// count.
    pub fn matmul(&self, other: &Mat) -> Mat {
        self.matmul_with(other, &Pool::current())
    }

    /// [`Mat::matmul`] on an explicit pool.
    pub fn matmul_with(&self, other: &Mat, pool: &Pool) -> Mat {
        assert_eq!(self.cols, other.rows);
        let mut out = Mat::zeros(self.rows, other.cols);
        let nc = other.cols;
        if nc == 0 || self.rows == 0 {
            return out;
        }
        let items: Vec<&mut [f64]> = out.data.chunks_mut(ROW_CHUNK * nc).collect();
        pool.for_items(items, |ci, chunk| {
            matmul_row_block(self, other, ci * ROW_CHUNK, chunk);
        });
        out
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                *out.at_mut(j, i) = self.at(i, j);
            }
        }
        out
    }

    /// Gram matrix XᵀX, upper-triangle computed then mirrored (syrk-style).
    /// This is the L3 hot path for leverage scores: O(n·D²/2) FLOPs over
    /// contiguous rows, blocked four rows per accumulator pass and
    /// row-sharded on the pool. Per-chunk partial Grams are combined by
    /// a fixed-shape tree reduction, so the result is bit-identical for
    /// any thread count (see EXPERIMENTS.md §Perf).
    pub fn gram(&self) -> Mat {
        self.gram_with(&Pool::current())
    }

    /// [`Mat::gram`] on an explicit pool (the determinism tests compare
    /// `Pool::new(1)` against larger pools).
    pub fn gram_with(&self, pool: &Pool) -> Mat {
        let d = self.cols;
        let partials = pool.map_chunks(self.rows, ROW_CHUNK, |_, r| {
            let mut g = vec![0.0; d * d];
            gram_upper_block(self, r.start, r.end, &mut g);
            g
        });
        let upper = tree_reduce(partials, |mut a, b| {
            add_assign(&mut a, &b);
            a
        })
        .unwrap_or_else(|| vec![0.0; d * d]);
        let mut g = Mat::from_vec(d, d, upper);
        // mirror
        for i in 0..d {
            for j in (i + 1)..d {
                g.data[j * d + i] = g.data[i * d + j];
            }
        }
        g
    }

    pub fn trace(&self) -> f64 {
        assert_eq!(self.rows, self.cols);
        (0..self.rows).map(|i| self.at(i, i)).sum()
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }
}

/// One 4-row rank-4 syrk update into the flat d×d upper triangle: each
/// load of the accumulator row `g[i·d..]` absorbs four rank-1 updates.
/// Shared by [`Mat::gram_with`]'s row blocks and the plane-gathered
/// stacked Gram (`coreset::leverage`), so both accumulate in the same
/// floating-point order **by construction** — the bitwise-identity
/// contract between the two paths lives here, not in two hand-synced
/// copies. Dispatches on the active [`simd::KernelBackend`].
pub fn syrk_upper_rows4(r0: &[f64], r1: &[f64], r2: &[f64], r3: &[f64], g: &mut [f64]) {
    let d = r0.len();
    syrk_upper_rows4_range(r0, r1, r2, r3, 0..d, 0..d, g)
}

/// [`syrk_upper_rows4`] restricted to the (i, j) tile `ir × jr` of the
/// upper triangle (j additionally clamped to j ≥ i) — the building
/// block of the L2-tiled stacked Gram in `coreset::leverage`. With
/// `ir = jr = 0..d` this *is* the full-width update: per entry the
/// 4-term expression and accumulation order are identical, so tiled
/// and untiled accumulation are bit-identical on either backend.
pub fn syrk_upper_rows4_range(
    r0: &[f64],
    r1: &[f64],
    r2: &[f64],
    r3: &[f64],
    ir: Range<usize>,
    jr: Range<usize>,
    g: &mut [f64],
) {
    match simd::backend() {
        KernelBackend::Scalar => syrk_upper_rows4_range_scalar(r0, r1, r2, r3, ir, jr, g),
        KernelBackend::Simd => simd::syrk_upper_rows4_range_simd(r0, r1, r2, r3, ir, jr, g),
    }
}

/// The scalar reference body of [`syrk_upper_rows4_range`] (bit-exact
/// baseline for the Simd variant).
pub fn syrk_upper_rows4_range_scalar(
    r0: &[f64],
    r1: &[f64],
    r2: &[f64],
    r3: &[f64],
    ir: Range<usize>,
    jr: Range<usize>,
    g: &mut [f64],
) {
    let d = r0.len();
    for i in ir {
        let (a0, a1, a2, a3) = (r0[i], r1[i], r2[i], r3[i]);
        if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
            continue;
        }
        let grow = &mut g[i * d..(i + 1) * d];
        for j in jr.start.max(i)..jr.end {
            grow[j] += a0 * r0[j] + a1 * r1[j] + a2 * r2[j] + a3 * r3[j];
        }
    }
}

/// Single-row rank-1 syrk update — the remainder companion of
/// [`syrk_upper_rows4`]. Dispatches on the active backend.
pub fn syrk_upper_row1(row: &[f64], g: &mut [f64]) {
    let d = row.len();
    syrk_upper_row1_range(row, 0..d, 0..d, g)
}

/// [`syrk_upper_row1`] restricted to an (i, j) tile — the remainder
/// companion of [`syrk_upper_rows4_range`].
pub fn syrk_upper_row1_range(row: &[f64], ir: Range<usize>, jr: Range<usize>, g: &mut [f64]) {
    match simd::backend() {
        KernelBackend::Scalar => syrk_upper_row1_range_scalar(row, ir, jr, g),
        KernelBackend::Simd => simd::syrk_upper_row1_range_simd(row, ir, jr, g),
    }
}

/// The scalar reference body of [`syrk_upper_row1_range`].
pub fn syrk_upper_row1_range_scalar(
    row: &[f64],
    ir: Range<usize>,
    jr: Range<usize>,
    g: &mut [f64],
) {
    let d = row.len();
    for i in ir {
        let xi = row[i];
        if xi == 0.0 {
            continue;
        }
        let grow = &mut g[i * d..(i + 1) * d];
        for j in jr.start.max(i)..jr.end {
            grow[j] += xi * row[j];
        }
    }
}

/// Upper-triangular syrk accumulation over rows `[lo, hi)` of `x` into
/// the flat d×d buffer `g`, four rows per pass. Summation order is
/// fixed by the row range alone.
fn gram_upper_block(x: &Mat, lo: usize, hi: usize, g: &mut [f64]) {
    let mut r = lo;
    while r + 4 <= hi {
        syrk_upper_rows4(x.row(r), x.row(r + 1), x.row(r + 2), x.row(r + 3), g);
        r += 4;
    }
    while r < hi {
        syrk_upper_row1(x.row(r), g);
        r += 1;
    }
}

/// Product rows `[row0, row0 + chunk_rows)` of `a·b` into `out` (flat,
/// width `b.cols`), four output rows per pass over `b` so each loaded
/// `b` row feeds four accumulators. Per-row k-order matches the naive
/// triple loop, so each output row is bit-identical to the serial
/// product no matter how chunks are scheduled.
fn matmul_row_block(a: &Mat, b: &Mat, row0: usize, out: &mut [f64]) {
    let nc = b.cols;
    let rows = out.len() / nc;
    let mut bi = 0usize;
    while bi < rows {
        let blk = (rows - bi).min(4);
        for k in 0..a.cols {
            let brow = b.row(k);
            for r in 0..blk {
                let aik = a.at(row0 + bi + r, k);
                if aik == 0.0 {
                    continue;
                }
                let orow = &mut out[(bi + r) * nc..(bi + r + 1) * nc];
                for (j, &bv) in brow.iter().enumerate() {
                    orow[j] += aik * bv;
                }
            }
        }
        bi += blk;
    }
}

/// Panel GEMV: `out[r] = Σ_k panel[r·d + k] · v[k]` for the
/// `out.len()` rows of a contiguous (rows × d) panel — the blocked
/// matrix–vector kernel behind the plane-major NLL evaluation
/// (`mctm::model`). Dispatches on the active [`simd::KernelBackend`]:
/// the Scalar body keeps each row's k-order that of the naive dot (so
/// every output element is bit-identical to row-at-a-time evaluation);
/// the Simd body accumulates in f64×4 FMA lanes with a horizontal
/// reduction (≤ 1e-12 relative agreement, internally deterministic).
pub fn panel_matvec(panel: &[f64], d: usize, v: &[f64], out: &mut [f64]) {
    debug_assert_eq!(panel.len(), out.len() * d);
    debug_assert_eq!(v.len(), d);
    match simd::backend() {
        KernelBackend::Scalar => panel_matvec_scalar(panel, d, v, out),
        KernelBackend::Simd => simd::panel_matvec_simd(panel, d, v, out),
    }
}

/// The scalar reference body of [`panel_matvec`]: four accumulator
/// chains per pass over `v` (the [`Mat::matmul_with`] 4-row blocking
/// idiom) quarter the reload traffic of row-at-a-time dots, while each
/// row's k-order stays that of the naive dot.
pub fn panel_matvec_scalar(panel: &[f64], d: usize, v: &[f64], out: &mut [f64]) {
    let rows = out.len();
    debug_assert_eq!(panel.len(), rows * d);
    debug_assert_eq!(v.len(), d);
    let mut r = 0usize;
    while r + 4 <= rows {
        let p0 = &panel[r * d..(r + 1) * d];
        let p1 = &panel[(r + 1) * d..(r + 2) * d];
        let p2 = &panel[(r + 2) * d..(r + 3) * d];
        let p3 = &panel[(r + 3) * d..(r + 4) * d];
        let (mut s0, mut s1, mut s2, mut s3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        for k in 0..d {
            let vk = v[k];
            s0 += p0[k] * vk;
            s1 += p1[k] * vk;
            s2 += p2[k] * vk;
            s3 += p3[k] * vk;
        }
        out[r] = s0;
        out[r + 1] = s1;
        out[r + 2] = s2;
        out[r + 3] = s3;
        r += 4;
    }
    while r < rows {
        let p = &panel[r * d..(r + 1) * d];
        let mut s = 0.0f64;
        for k in 0..d {
            s += p[k] * v[k];
        }
        out[r] = s;
        r += 1;
    }
}

/// Transposed-panel accumulation: `acc[k] += Σ_r ca[r]·a[r·d + k] +
/// cad[r]·ad[r·d + k]` over two parallel (rows × d) panels — the
/// gradient update ∂θ_j += A_jᵀ·c_a + A'_jᵀ·c_ad of the blocked NLL
/// kernel. Dispatches on the active [`simd::KernelBackend`]; the
/// Scalar body is bit-identical to a row-at-a-time loop, the Simd body
/// vectorizes over k with FMA (≤ 1e-12 relative agreement).
pub fn panel_accum_t(
    a_panel: &[f64],
    ad_panel: &[f64],
    d: usize,
    ca: &[f64],
    cad: &[f64],
    acc: &mut [f64],
) {
    match simd::backend() {
        KernelBackend::Scalar => panel_accum_t_scalar(a_panel, ad_panel, d, ca, cad, acc),
        KernelBackend::Simd => simd::panel_accum_t_simd(a_panel, ad_panel, d, ca, cad, acc),
    }
}

/// The scalar reference body of [`panel_accum_t`]: four rows per pass
/// so each load of the accumulator row absorbs four updates; the adds
/// into `acc[k]` stay row-sequential (one `+=` per row, each row's
/// pair combined as `ca·a + cad·ad`), so the accumulated values are
/// bit-identical to a row-at-a-time loop.
pub fn panel_accum_t_scalar(
    a_panel: &[f64],
    ad_panel: &[f64],
    d: usize,
    ca: &[f64],
    cad: &[f64],
    acc: &mut [f64],
) {
    let rows = ca.len();
    debug_assert_eq!(a_panel.len(), rows * d);
    debug_assert_eq!(ad_panel.len(), rows * d);
    debug_assert_eq!(cad.len(), rows);
    debug_assert_eq!(acc.len(), d);
    let mut r = 0usize;
    while r + 4 <= rows {
        let a0 = &a_panel[r * d..(r + 1) * d];
        let a1 = &a_panel[(r + 1) * d..(r + 2) * d];
        let a2 = &a_panel[(r + 2) * d..(r + 3) * d];
        let a3 = &a_panel[(r + 3) * d..(r + 4) * d];
        let b0 = &ad_panel[r * d..(r + 1) * d];
        let b1 = &ad_panel[(r + 1) * d..(r + 2) * d];
        let b2 = &ad_panel[(r + 2) * d..(r + 3) * d];
        let b3 = &ad_panel[(r + 3) * d..(r + 4) * d];
        let (c0, c1, c2, c3) = (ca[r], ca[r + 1], ca[r + 2], ca[r + 3]);
        let (e0, e1, e2, e3) = (cad[r], cad[r + 1], cad[r + 2], cad[r + 3]);
        for k in 0..d {
            let mut g = acc[k];
            g += c0 * a0[k] + e0 * b0[k];
            g += c1 * a1[k] + e1 * b1[k];
            g += c2 * a2[k] + e2 * b2[k];
            g += c3 * a3[k] + e3 * b3[k];
            acc[k] = g;
        }
        r += 4;
    }
    while r < rows {
        let a = &a_panel[r * d..(r + 1) * d];
        let b = &ad_panel[r * d..(r + 1) * d];
        let (c, e) = (ca[r], cad[r]);
        for k in 0..d {
            acc[k] += c * a[k] + e * b[k];
        }
        r += 1;
    }
}

/// Single-panel transposed accumulation: `acc[k] += Σ_r c[r]·panel[r·d
/// + k]` — the Γ-gradient update ∂γ_j += Xᵀ·c_a of the blocked
/// conditional kernel (`mctm::conditional`). A separate kernel rather
/// than [`panel_accum_t`] with a zero coefficient panel, because `0 ·
/// x` must never touch the second panel at all (a masked row may hold
/// NaN, and 0·NaN would poison the accumulator). Dispatches on the
/// active backend.
pub fn panel_accum_t1(panel: &[f64], d: usize, c: &[f64], acc: &mut [f64]) {
    match simd::backend() {
        KernelBackend::Scalar => panel_accum_t1_scalar(panel, d, c, acc),
        KernelBackend::Simd => simd::panel_accum_t1_simd(panel, d, c, acc),
    }
}

/// The scalar reference body of [`panel_accum_t1`]: one `+=` per row
/// into each `acc[k]`, rows ascending, so the accumulated values are
/// bit-identical to a row-at-a-time `acc[k] += c·x[k]` loop.
pub fn panel_accum_t1_scalar(panel: &[f64], d: usize, c: &[f64], acc: &mut [f64]) {
    let rows = c.len();
    debug_assert_eq!(panel.len(), rows * d);
    debug_assert_eq!(acc.len(), d);
    let mut r = 0usize;
    while r + 4 <= rows {
        let p0 = &panel[r * d..(r + 1) * d];
        let p1 = &panel[(r + 1) * d..(r + 2) * d];
        let p2 = &panel[(r + 2) * d..(r + 3) * d];
        let p3 = &panel[(r + 3) * d..(r + 4) * d];
        let (c0, c1, c2, c3) = (c[r], c[r + 1], c[r + 2], c[r + 3]);
        for k in 0..d {
            let mut g = acc[k];
            g += c0 * p0[k];
            g += c1 * p1[k];
            g += c2 * p2[k];
            g += c3 * p3[k];
            acc[k] = g;
        }
        r += 4;
    }
    while r < rows {
        let p = &panel[r * d..(r + 1) * d];
        let cv = c[r];
        for k in 0..d {
            acc[k] += cv * p[k];
        }
        r += 1;
    }
}

/// Lower-triangular Cholesky factor L with G = L Lᵀ.
#[derive(Clone, Debug)]
pub struct Cholesky {
    pub l: Mat,
}

/// Errors from factorizations (`thiserror` is unavailable offline, so
/// Display/Error are hand-rolled).
#[derive(Debug)]
pub enum LinalgError {
    NotPosDef(usize, f64),
    Dim(String),
}

impl std::fmt::Display for LinalgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LinalgError::NotPosDef(pivot, value) => {
                write!(f, "matrix not positive definite at pivot {pivot} (value {value})")
            }
            LinalgError::Dim(msg) => write!(f, "dimension mismatch: {msg}"),
        }
    }
}

impl std::error::Error for LinalgError {}

impl Cholesky {
    /// Factor a symmetric positive-definite matrix.
    pub fn new(g: &Mat) -> Result<Self, LinalgError> {
        assert_eq!(g.rows, g.cols);
        let n = g.rows;
        let mut l = Mat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut s = g.at(i, j);
                for k in 0..j {
                    s -= l.at(i, k) * l.at(j, k);
                }
                if i == j {
                    // NaN pivots (overflowed or poisoned input) are
                    // caught here as NotPosDef instead of silently
                    // propagating NaN through every downstream solve
                    if s.is_nan() || s <= 0.0 {
                        return Err(LinalgError::NotPosDef(i, s));
                    }
                    *l.at_mut(i, j) = s.sqrt();
                } else {
                    *l.at_mut(i, j) = s / l.at(j, j);
                }
            }
        }
        Ok(Cholesky { l })
    }

    /// Solve L y = b in place.
    pub fn forward_solve(&self, b: &mut [f64]) {
        let n = self.l.rows;
        assert_eq!(b.len(), n);
        for i in 0..n {
            let mut s = b[i];
            let lrow = self.l.row(i);
            for k in 0..i {
                s -= lrow[k] * b[k];
            }
            b[i] = s / lrow[i];
        }
    }

    /// Solve Lᵀ x = y in place.
    pub fn backward_solve(&self, y: &mut [f64]) {
        let n = self.l.rows;
        for i in (0..n).rev() {
            let mut s = y[i];
            for k in (i + 1)..n {
                s -= self.l.at(k, i) * y[k];
            }
            y[i] = s / self.l.at(i, i);
        }
    }

    /// Solve G x = b.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut x = b.to_vec();
        self.forward_solve(&mut x);
        self.backward_solve(&mut x);
        x
    }

    /// ‖L⁻¹ v‖² — the quadratic form vᵀ G⁻¹ v, i.e. a leverage score when
    /// v is a data row and G the Gram matrix.
    pub fn quad_form_inv(&self, v: &[f64], scratch: &mut Vec<f64>) -> f64 {
        scratch.clear();
        scratch.extend_from_slice(v);
        self.forward_solve(scratch);
        scratch.iter().map(|x| x * x).sum()
    }

    /// Explicit inverse of L (row-major lower triangular), used to ship
    /// L⁻¹ to the XLA leverage kernel.
    pub fn l_inverse(&self) -> Mat {
        let n = self.l.rows;
        let mut inv = Mat::zeros(n, n);
        for col in 0..n {
            let mut e = vec![0.0; n];
            e[col] = 1.0;
            self.forward_solve(&mut e);
            for r in 0..n {
                *inv.at_mut(r, col) = e[r];
            }
        }
        inv
    }

    /// log det G = 2 Σ log L_ii.
    pub fn log_det(&self) -> f64 {
        (0..self.l.rows).map(|i| self.l.at(i, i).ln()).sum::<f64>() * 2.0
    }
}

/// Relative rungs of the escalating ridge-jitter retry ladder used by
/// [`cholesky_ridge_ladder`]: each rung adds `rung × scale` to the
/// diagonal, where `scale` is the mean absolute diagonal of the failed
/// matrix. The top rung (4×) recovers matrices whose smallest
/// eigenvalue is as low as minus a few times the diagonal scale; beyond
/// that the input is not meaningfully a Gram matrix and the caller gets
/// the original `NotPosDef`.
pub const RIDGE_LADDER_REL: [f64; 6] = [1e-8, 1e-6, 1e-4, 1e-2, 1.0, 4.0];

/// Factor `g`, recovering from `NotPosDef` via an escalating
/// ridge-jitter ladder: attempt 0 factors `g` exactly as given (so the
/// clean path stays bit-identical to a plain [`Cholesky::new`]), then
/// each bounded retry adds `RIDGE_LADDER_REL[rung] × mean |diag|` to a
/// copy of the diagonal. Returns the factor and the rung that
/// succeeded (0 = clean, no jitter). Exhausting the ladder returns the
/// *original* failure, and non-finite diagonals fail fast (no amount
/// of jitter fixes an inf/NaN Gram).
pub fn cholesky_ridge_ladder(g: &Mat) -> Result<(Cholesky, usize), LinalgError> {
    let first = match Cholesky::new(g) {
        Ok(ch) => return Ok((ch, 0)),
        Err(e) => e,
    };
    let n = g.rows;
    let diag_scale = (0..n).map(|i| g.at(i, i).abs()).sum::<f64>() / n.max(1) as f64;
    if !diag_scale.is_finite() || diag_scale <= 0.0 {
        return Err(first);
    }
    for (rung, rel) in RIDGE_LADDER_REL.iter().enumerate() {
        let mut jittered = g.clone();
        let lambda = rel * diag_scale;
        for i in 0..n {
            *jittered.at_mut(i, i) = g.at(i, i) + lambda;
        }
        if let Ok(ch) = Cholesky::new(&jittered) {
            return Ok((ch, rung + 1));
        }
    }
    Err(first)
}

/// Thin Householder QR (R only, plus leverage helper via Q): used as a
/// numerically-robust cross-check for the Gram–Cholesky leverage path.
pub struct Qr {
    /// packed Householder vectors + R (LAPACK-style)
    a: Mat,
    /// the scalar factors
    tau: Vec<f64>,
}

impl Qr {
    pub fn new(x: &Mat) -> Self {
        let (m, n) = (x.rows, x.cols);
        assert!(m >= n, "QR expects tall matrix");
        let mut a = x.clone();
        let mut tau = vec![0.0; n];
        for k in 0..n {
            // norm of column k below diagonal
            let mut norm2 = 0.0;
            for i in k..m {
                let v = a.at(i, k);
                norm2 += v * v;
            }
            let norm = norm2.sqrt();
            if norm == 0.0 {
                tau[k] = 0.0;
                continue;
            }
            let alpha = if a.at(k, k) >= 0.0 { -norm } else { norm };
            let akk = a.at(k, k);
            let v0 = akk - alpha;
            // v = (v0, a[k+1..m, k]); normalize so v[0] = 1
            let mut vnorm2 = v0 * v0;
            for i in (k + 1)..m {
                let v = a.at(i, k);
                vnorm2 += v * v;
            }
            if vnorm2 == 0.0 {
                tau[k] = 0.0;
                continue;
            }
            tau[k] = 2.0 * v0 * v0 / vnorm2;
            // store normalized v below diagonal; R diagonal gets alpha
            for i in (k + 1)..m {
                *a.at_mut(i, k) /= v0;
            }
            *a.at_mut(k, k) = alpha;
            // apply H = I − τ v vᵀ (v normalized, v[0] = 1) to remaining
            // columns: col_j −= τ (vᵀ col_j) v
            for j in (k + 1)..n {
                let mut dot = a.at(k, j);
                for i in (k + 1)..m {
                    dot += a.at(i, k) * a.at(i, j);
                }
                let t = tau[k] * dot;
                *a.at_mut(k, j) -= t;
                for i in (k + 1)..m {
                    let vik = a.at(i, k);
                    *a.at_mut(i, j) -= t * vik;
                }
            }
        }
        Qr { a, tau }
    }

    /// Extract upper-triangular R (n×n).
    pub fn r(&self) -> Mat {
        let n = self.a.cols;
        let mut r = Mat::zeros(n, n);
        for i in 0..n {
            for j in i..n {
                *r.at_mut(i, j) = self.a.at(i, j);
            }
        }
        r
    }

    /// Row leverage scores: ‖Q_i‖² computed as ‖R⁻ᵀ x_i‖² for the original
    /// rows (requires the caller to pass the original matrix).
    pub fn leverage_scores(&self, x: &Mat) -> Vec<f64> {
        let r = self.r();
        // Solve Rᵀ z = x_iᵀ per row.
        let n = r.rows;
        let mut scores = Vec::with_capacity(x.rows);
        let mut z = vec![0.0; n];
        for i in 0..x.rows {
            let xi = x.row(i);
            // forward solve with Rᵀ (lower triangular with entries R[j][i])
            for j in 0..n {
                let mut s = xi[j];
                for k in 0..j {
                    s -= r.at(k, j) * z[k];
                }
                z[j] = s / r.at(j, j);
            }
            scores.push(z.iter().map(|v| v * v).sum());
        }
        scores
    }

    pub fn tau(&self) -> &[f64] {
        &self.tau
    }
}

/// Invert a unit-lower-triangular matrix (ones on the diagonal) — used for
/// Λ⁻¹ in the Gaussian-copula marginal variance computation.
pub fn unit_lower_inverse(l: &Mat) -> Mat {
    let n = l.rows;
    assert_eq!(n, l.cols);
    let mut inv = Mat::eye(n);
    // forward substitution per column of the identity
    for col in 0..n {
        for i in 0..n {
            let mut s = if i == col { 1.0 } else { 0.0 };
            for k in 0..i {
                s -= l.at(i, k) * inv.at(k, col);
            }
            *inv.at_mut(i, col) = s; // diagonal is 1
        }
    }
    inv
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_mat(rng: &mut Rng, r: usize, c: usize) -> Mat {
        Mat::from_vec(r, c, (0..r * c).map(|_| rng.normal()).collect())
    }

    #[test]
    fn gram_matches_naive() {
        let mut rng = Rng::new(1);
        let x = random_mat(&mut rng, 37, 5);
        let g = x.gram();
        let g2 = x.transpose().matmul(&x);
        for i in 0..5 {
            for j in 0..5 {
                assert!((g.at(i, j) - g2.at(i, j)).abs() < 1e-10);
            }
        }
    }

    #[test]
    fn cholesky_roundtrip() {
        let mut rng = Rng::new(2);
        let x = random_mat(&mut rng, 50, 6);
        let g = x.gram();
        let ch = Cholesky::new(&g).unwrap();
        let llt = ch.l.matmul(&ch.l.transpose());
        for i in 0..6 {
            for j in 0..6 {
                assert!((llt.at(i, j) - g.at(i, j)).abs() < 1e-8);
            }
        }
    }

    #[test]
    fn cholesky_solve_residual() {
        let mut rng = Rng::new(3);
        let x = random_mat(&mut rng, 40, 4);
        let g = x.gram();
        let ch = Cholesky::new(&g).unwrap();
        let b = vec![1.0, -2.0, 0.5, 3.0];
        let sol = ch.solve(&b);
        // residual G sol − b
        for i in 0..4 {
            let mut r = -b[i];
            for j in 0..4 {
                r += g.at(i, j) * sol[j];
            }
            assert!(r.abs() < 1e-8, "residual {r}");
        }
    }

    #[test]
    fn not_pos_def_detected() {
        let g = Mat::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]); // eig −1
        assert!(Cholesky::new(&g).is_err());
    }

    #[test]
    fn nan_pivot_is_not_pos_def() {
        let g = Mat::from_rows(&[vec![f64::NAN, 0.0], vec![0.0, 1.0]]);
        assert!(matches!(Cholesky::new(&g), Err(LinalgError::NotPosDef(0, _))));
        let g2 = Mat::from_rows(&[vec![1.0, 0.0], vec![0.0, f64::NAN]]);
        assert!(matches!(Cholesky::new(&g2), Err(LinalgError::NotPosDef(1, _))));
    }

    #[test]
    fn ridge_ladder_clean_path_is_bit_identical() {
        let mut rng = Rng::new(6);
        let x = random_mat(&mut rng, 40, 4);
        let g = x.gram();
        let plain = Cholesky::new(&g).unwrap();
        let (laddered, rung) = cholesky_ridge_ladder(&g).unwrap();
        assert_eq!(rung, 0, "pos-def input must not be jittered");
        for (a, b) in plain.l.data.iter().zip(&laddered.l.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn ridge_ladder_recovers_indefinite_matrix() {
        // eigenvalues {−0.5, 2.5}: rungs up to 1e-2 leave it indefinite
        // (scale = 1), rung 1.0 shifts eigenvalues to {0.5, 3.5}
        let g = Mat::from_rows(&[vec![1.0, 1.5], vec![1.5, 1.0]]);
        let (ch, rung) = cholesky_ridge_ladder(&g).unwrap();
        assert!(rung >= 1, "must have taken a jitter rung");
        assert!(ch.l.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn ridge_ladder_gives_up_on_non_finite_diag() {
        let g = Mat::from_rows(&[vec![f64::INFINITY, 0.0], vec![0.0, 1.0]]);
        assert!(cholesky_ridge_ladder(&g).is_err());
        let g2 = Mat::from_rows(&[vec![f64::NAN, 0.0], vec![0.0, 1.0]]);
        assert!(cholesky_ridge_ladder(&g2).is_err());
    }

    #[test]
    fn quad_form_inv_is_leverage() {
        let mut rng = Rng::new(4);
        let x = random_mat(&mut rng, 60, 5);
        let g = x.gram();
        let ch = Cholesky::new(&g).unwrap();
        let mut scratch = Vec::new();
        // leverage scores sum to d for full-rank X
        let total: f64 = (0..x.rows)
            .map(|i| ch.quad_form_inv(x.row(i), &mut scratch))
            .sum();
        assert!((total - 5.0).abs() < 1e-8, "sum leverage {total}");
    }

    #[test]
    fn l_inverse_correct() {
        let mut rng = Rng::new(5);
        let x = random_mat(&mut rng, 30, 4);
        let g = x.gram();
        let ch = Cholesky::new(&g).unwrap();
        let linv = ch.l_inverse();
        let prod = linv.matmul(&ch.l);
        for i in 0..4 {
            for j in 0..4 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod.at(i, j) - want).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn qr_leverage_matches_cholesky() {
        let mut rng = Rng::new(6);
        let x = random_mat(&mut rng, 80, 6);
        let g = x.gram();
        let ch = Cholesky::new(&g).unwrap();
        let qr = Qr::new(&x);
        let qr_scores = qr.leverage_scores(&x);
        let mut scratch = Vec::new();
        for i in 0..x.rows {
            let c = ch.quad_form_inv(x.row(i), &mut scratch);
            assert!(
                (qr_scores[i] - c).abs() < 1e-7,
                "row {i}: qr {} chol {c}",
                qr_scores[i]
            );
        }
    }

    #[test]
    fn unit_lower_inverse_roundtrip() {
        let l = Mat::from_rows(&[
            vec![1.0, 0.0, 0.0],
            vec![0.7, 1.0, 0.0],
            vec![-0.3, 0.4, 1.0],
        ]);
        let inv = unit_lower_inverse(&l);
        let prod = l.matmul(&inv);
        for i in 0..3 {
            for j in 0..3 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod.at(i, j) - want).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn gram_and_matmul_bit_identical_across_pools() {
        let mut rng = Rng::new(123);
        // > ROW_CHUNK rows so the work really spans several chunks
        let x = random_mat(&mut rng, 3 * ROW_CHUNK + 17, 9);
        let b = random_mat(&mut rng, 9, 6);
        let g1 = x.gram_with(&Pool::new(1));
        let m1 = x.matmul_with(&b, &Pool::new(1));
        for t in [2, 8] {
            let gt = x.gram_with(&Pool::new(t));
            let mt = x.matmul_with(&b, &Pool::new(t));
            for (a, c) in g1.data.iter().zip(&gt.data) {
                assert_eq!(a.to_bits(), c.to_bits(), "gram differs at {t} threads");
            }
            for (a, c) in m1.data.iter().zip(&mt.data) {
                assert_eq!(a.to_bits(), c.to_bits(), "matmul differs at {t} threads");
            }
        }
    }

    #[test]
    fn blocked_gram_matches_naive_large() {
        let mut rng = Rng::new(77);
        // odd row count exercises the 4-row remainder path across chunks
        let x = random_mat(&mut rng, ROW_CHUNK + 5, 7);
        let g = x.gram();
        let g2 = x.transpose().matmul(&x);
        for i in 0..7 {
            for j in 0..7 {
                let denom = 1.0 + g2.at(i, j).abs();
                assert!((g.at(i, j) - g2.at(i, j)).abs() / denom < 1e-10);
            }
        }
    }

    #[test]
    fn panel_matvec_bitwise_matches_row_dots() {
        // the SCALAR body is the bit-exact one (the Simd dispatch forks
        // FP order — its agreement pin lives in tests/simd_kernels.rs)
        let mut rng = Rng::new(31);
        let (rows, d) = (23, 6); // odd row count exercises the remainder path
        let panel: Vec<f64> = (0..rows * d).map(|_| rng.normal()).collect();
        let v: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let mut out = vec![0.0; rows];
        panel_matvec_scalar(&panel, d, &v, &mut out);
        for r in 0..rows {
            let mut s = 0.0;
            for k in 0..d {
                s += panel[r * d + k] * v[k];
            }
            assert_eq!(out[r].to_bits(), s.to_bits(), "row {r}");
        }
    }

    #[test]
    fn panel_accum_t_bitwise_matches_row_loop() {
        let mut rng = Rng::new(32);
        let (rows, d) = (21, 5);
        let a: Vec<f64> = (0..rows * d).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..rows * d).map(|_| rng.normal()).collect();
        let ca: Vec<f64> = (0..rows).map(|_| rng.normal()).collect();
        let cad: Vec<f64> = (0..rows).map(|_| rng.normal()).collect();
        let mut acc = vec![0.0; d];
        panel_accum_t_scalar(&a, &b, d, &ca, &cad, &mut acc);
        let mut want = vec![0.0; d];
        for r in 0..rows {
            for k in 0..d {
                want[k] += ca[r] * a[r * d + k] + cad[r] * b[r * d + k];
            }
        }
        for k in 0..d {
            assert_eq!(acc[k].to_bits(), want[k].to_bits(), "k={k}");
        }
    }

    #[test]
    fn panel_accum_t1_bitwise_matches_row_loop() {
        let mut rng = Rng::new(33);
        let (rows, d) = (19, 3); // remainder rows + small d
        let p: Vec<f64> = (0..rows * d).map(|_| rng.normal()).collect();
        let c: Vec<f64> = (0..rows).map(|_| rng.normal()).collect();
        let mut acc = vec![0.0; d];
        panel_accum_t1_scalar(&p, d, &c, &mut acc);
        let mut want = vec![0.0; d];
        for r in 0..rows {
            for k in 0..d {
                want[k] += c[r] * p[r * d + k];
            }
        }
        for k in 0..d {
            assert_eq!(acc[k].to_bits(), want[k].to_bits(), "k={k}");
        }
    }

    #[test]
    fn tiled_syrk_ranges_cover_full_update_bitwise() {
        // splitting the upper triangle into (i, j) tiles and replaying
        // the SAME 4-row update per tile must reproduce the full-width
        // update bit for bit — the contract the L2-tiled stacked Gram
        // (coreset::leverage) is built on
        let mut rng = Rng::new(34);
        let d = 11; // not a multiple of the 3-wide tiles below
        let rows: Vec<Vec<f64>> =
            (0..4).map(|_| (0..d).map(|_| rng.normal()).collect()).collect();
        let mut g_full = vec![0.0; d * d];
        syrk_upper_rows4_range_scalar(
            &rows[0], &rows[1], &rows[2], &rows[3], 0..d, 0..d, &mut g_full,
        );
        let tile = 3;
        let ntiles = d.div_ceil(tile);
        let mut g_tiled = vec![0.0; d * d];
        for it in 0..ntiles {
            let ir = it * tile..((it + 1) * tile).min(d);
            for jt in it..ntiles {
                let jr = jt * tile..((jt + 1) * tile).min(d);
                syrk_upper_rows4_range_scalar(
                    &rows[0], &rows[1], &rows[2], &rows[3], ir.clone(), jr, &mut g_tiled,
                );
            }
        }
        for (k, (a, b)) in g_full.iter().zip(&g_tiled).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "entry {k}");
        }
    }

    #[test]
    fn select_rows_restriction() {
        let x = Mat::from_rows(&[vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        let s = x.select_rows(&[2, 0]);
        assert_eq!(s.row(0), &[5.0, 6.0]);
        assert_eq!(s.row(1), &[1.0, 2.0]);
    }
}
