//! The paper's 14 two-dimensional data-generation processes (§E.1.1),
//! implemented exactly as specified. Each returns an (n × 2) matrix.

use crate::linalg::Mat;
use crate::util::rng::Rng;
use crate::util::special::{
    exp_quantile, gamma_quantile, lognormal_quantile, t_cdf, t_quantile,
};
use std::f64::consts::PI;

/// Enumeration of the 14 DGPs in the order of §E.1.1 / Tables 3–4.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Dgp {
    BivariateNormal,
    NonlinearCorrelation,
    NormalMixture,
    GeometricMixed,
    SkewT,
    Heteroscedastic,
    CopulaComplex,
    Spiral,
    Circular,
    TCopula,
    Piecewise,
    Hourglass,
    BimodalClusters,
    Sinusoidal,
}

impl Dgp {
    pub fn all() -> [Dgp; 14] {
        [
            Dgp::BivariateNormal,
            Dgp::NonlinearCorrelation,
            Dgp::NormalMixture,
            Dgp::GeometricMixed,
            Dgp::SkewT,
            Dgp::Heteroscedastic,
            Dgp::CopulaComplex,
            Dgp::Spiral,
            Dgp::Circular,
            Dgp::TCopula,
            Dgp::Piecewise,
            Dgp::Hourglass,
            Dgp::BimodalClusters,
            Dgp::Sinusoidal,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Dgp::BivariateNormal => "bivariate-normal",
            Dgp::NonlinearCorrelation => "nonlinear-correlation",
            Dgp::NormalMixture => "normal-mixture",
            Dgp::GeometricMixed => "geometric-mixed",
            Dgp::SkewT => "skew-t",
            Dgp::Heteroscedastic => "heteroscedastic",
            Dgp::CopulaComplex => "copula-complex",
            Dgp::Spiral => "spiral",
            Dgp::Circular => "circular",
            Dgp::TCopula => "t-copula",
            Dgp::Piecewise => "piecewise",
            Dgp::Hourglass => "hourglass",
            Dgp::BimodalClusters => "bimodal-clusters",
            Dgp::Sinusoidal => "sinusoidal",
        }
    }

    /// Generate n samples.
    pub fn generate(&self, n: usize, rng: &mut Rng) -> Mat {
        let mut out = Mat::zeros(n, 2);
        for i in 0..n {
            let (y1, y2) = self.sample(rng);
            *out.at_mut(i, 0) = y1;
            *out.at_mut(i, 1) = y2;
        }
        out
    }

    /// One sample.
    pub fn sample(&self, rng: &mut Rng) -> (f64, f64) {
        match self {
            // 1. bivariate normal, ρ = 0.7
            Dgp::BivariateNormal => {
                let rho = 0.7;
                let z1 = rng.normal();
                let z2 = rng.normal();
                (z1, rho * z1 + (1.0 - rho * rho).sqrt() * z2)
            }
            // 2. non-linear correlation ρ(X) = sin(X)
            Dgp::NonlinearCorrelation => {
                let x = rng.uniform(-3.0, 3.0);
                let e1 = rng.normal_ms(0.0, 0.5);
                let y1 = x * x + e1;
                // standardize Y1 around its conditional mean for the
                // correlation structure, as in the reference DGP
                let rho = x.sin();
                let z = rng.normal();
                let y2 = rho * e1 / 0.5 + (1.0 - rho * rho).max(0.0).sqrt() * z;
                (y1, y2)
            }
            // 3. mixture of two bivariate normals
            Dgp::NormalMixture => {
                if rng.f64() < 0.5 {
                    let (a, b) = correlated(rng, 0.8);
                    (a, b)
                } else {
                    let (a, b) = correlated(rng, -0.5 / 1.5);
                    (3.0 + 1.5f64.sqrt() * a, -2.0 + 1.5f64.sqrt() * b)
                }
            }
            // 4. geometric mixed: circle + cross
            Dgp::GeometricMixed => {
                if rng.f64() < 0.5 {
                    let r = rng.normal_ms(2.0, 0.2);
                    let t = rng.uniform(0.0, 2.0 * PI);
                    (r * t.cos(), r * t.sin())
                } else {
                    // cross: two perpendicular lines
                    let along = rng.uniform(-3.0, 3.0);
                    let off = rng.normal_ms(0.0, 0.15);
                    if rng.f64() < 0.5 {
                        (along, off)
                    } else {
                        (off, along)
                    }
                }
            }
            // 5. skew-t(ξ=0, Ω=[[1,.5],[.5,1]], α=(5,−3), ν=4) — Azzalini
            Dgp::SkewT => {
                // skew-normal via conditioning representation, then
                // divide by sqrt(chi2/nu)
                let alpha: [f64; 2] = [5.0, -3.0];
                let rho = 0.5;
                // delta = Ω α / sqrt(1 + αᵀ Ω α)
                let oa = [alpha[0] + rho * alpha[1], rho * alpha[0] + alpha[1]];
                let denom = (1.0 + alpha[0] * oa[0] + alpha[1] * oa[1]).sqrt();
                let delta = [oa[0] / denom, oa[1] / denom];
                // sample (Z0, Z) with corr(Z0, Z_j) = delta_j, Z ~ N(0, Ω)
                loop {
                    let z0 = rng.normal();
                    let (mut z1, mut z2) = correlated(rng, rho);
                    // adjust to achieve corr(z0, z) = delta via
                    // z_j' = delta_j z0 + sqrt(1−delta_j²)·(residual)
                    // use the standard construction: X = delta |Z0| + sqrt(1-delta²) Z'
                    // where Z' has adjusted correlation; we use the simple
                    // component-wise Azzalini form with Ω residual corr.
                    z1 = delta[0] * z0.abs() + (1.0 - delta[0] * delta[0]).sqrt() * z1;
                    z2 = delta[1] * z0.abs() + (1.0 - delta[1] * delta[1]).sqrt() * z2;
                    let w = rng.chi2(4.0) / 4.0;
                    let s = w.sqrt();
                    return (z1 / s, z2 / s);
                }
            }
            // 6. heteroscedastic
            Dgp::Heteroscedastic => {
                let x = rng.uniform(-3.0, 3.0);
                let y1 = rng.normal_ms(x * x, (0.5 * x).exp());
                let y2 = rng.normal_ms(x.sin(), x.abs().sqrt().max(1e-6));
                (y1, y2)
            }
            // 7. Clayton copula (θ=2) with Gamma(2,1) and LogNormal(0,1)
            Dgp::CopulaComplex => {
                let theta = 2.0;
                let u1 = rng.f64_open();
                let v = rng.f64_open();
                // conditional inverse for Clayton
                let u2 = ((u1.powf(-theta) * (v.powf(-theta / (theta + 1.0)) - 1.0))
                    + 1.0)
                    .powf(-1.0 / theta);
                let u2 = u2.clamp(1e-12, 1.0 - 1e-12);
                (
                    gamma_quantile(u1.clamp(1e-12, 1.0 - 1e-12), 2.0, 1.0),
                    lognormal_quantile(u2, 0.0, 1.0),
                )
            }
            // 8. spiral
            Dgp::Spiral => {
                let t = rng.uniform(0.0, 3.0 * PI);
                let r = 0.5 * t;
                (
                    r * t.cos() + rng.normal_ms(0.0, 0.5),
                    r * t.sin() + rng.normal_ms(0.0, 0.5),
                )
            }
            // 9. circular
            Dgp::Circular => {
                let theta = rng.uniform(0.0, 2.0 * PI);
                let r = rng.normal_ms(5.0, 1.0);
                (r * theta.cos(), r * theta.sin())
            }
            // 10. t-copula(ρ=0.7, ν=3) with t(5) and Exp(1) marginals
            Dgp::TCopula => {
                let rho = 0.7;
                let (z1, z2) = correlated(rng, rho);
                let w = (rng.chi2(3.0) / 3.0).sqrt();
                let (t1, t2) = (z1 / w, z2 / w);
                let u1 = t_cdf(t1, 3.0).clamp(1e-12, 1.0 - 1e-12);
                let u2 = t_cdf(t2, 3.0).clamp(1e-12, 1.0 - 1e-12);
                (t_quantile(u1, 5.0), exp_quantile(u2, 1.0))
            }
            // 11. piecewise regimes
            Dgp::Piecewise => {
                let y1 = rng.normal_ms(0.0, 2.0);
                let y2 = if y1 < -1.0 {
                    1.5 * y1 + rng.normal_ms(0.0, 0.5)
                } else if y1 < 1.0 {
                    -0.5 * y1 + rng.normal_ms(0.0, 0.8)
                } else {
                    -2.0 * y1 + rng.normal_ms(0.0, 0.5)
                };
                (y1, y2)
            }
            // 12. hourglass: σ²(Y1) = 0.2 + 0.3 Y1²
            Dgp::Hourglass => {
                let y1 = rng.normal_ms(0.0, 2.0);
                let s = (0.2 + 0.3 * y1 * y1).sqrt();
                (y1, rng.normal_ms(0.0, s))
            }
            // 13. bimodal clusters with opposing correlations
            Dgp::BimodalClusters => {
                if rng.f64() < 0.5 {
                    let (a, b) = correlated(rng, 0.8);
                    (-2.0 + a, 2.0 + b)
                } else {
                    let (a, b) = correlated(rng, -0.7);
                    (2.0 + a, 2.0 + b)
                }
            }
            // 14. sinusoidal
            Dgp::Sinusoidal => {
                let y1 = rng.uniform(-3.0, 3.0);
                let y2 = 2.0 * (PI * y1).sin() + rng.normal_ms(0.0, 0.5);
                (y1, y2)
            }
        }
    }

    /// The 5 "representative scenarios" of Table 1.
    pub fn table1() -> [Dgp; 5] {
        [
            Dgp::BivariateNormal,
            Dgp::NonlinearCorrelation,
            Dgp::NormalMixture,
            Dgp::GeometricMixed,
            Dgp::Heteroscedastic,
        ]
    }

    /// The 9 DGPs of the Figure 9 timing comparison.
    pub fn figure9() -> [Dgp; 9] {
        [
            Dgp::BivariateNormal,
            Dgp::NonlinearCorrelation,
            Dgp::NormalMixture,
            Dgp::SkewT,
            Dgp::Heteroscedastic,
            Dgp::CopulaComplex,
            Dgp::Spiral,
            Dgp::Circular,
            Dgp::BimodalClusters,
        ]
    }
}

/// Pair of standard normals with correlation ρ.
#[inline]
fn correlated(rng: &mut Rng, rho: f64) -> (f64, f64) {
    let z1 = rng.normal();
    let z2 = rng.normal();
    (z1, rho * z1 + (1.0 - rho * rho).max(0.0).sqrt() * z2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{mean, std_dev};

    fn column(m: &Mat, c: usize) -> Vec<f64> {
        (0..m.rows).map(|r| m.at(r, c)).collect()
    }

    fn sample_corr(m: &Mat) -> f64 {
        let (a, b) = (column(m, 0), column(m, 1));
        let (ma, mb) = (mean(&a), mean(&b));
        let mut num = 0.0;
        for i in 0..a.len() {
            num += (a[i] - ma) * (b[i] - mb);
        }
        num / ((a.len() - 1) as f64 * std_dev(&a) * std_dev(&b))
    }

    #[test]
    fn all_generate_finite() {
        let mut rng = Rng::new(1);
        for dgp in Dgp::all() {
            let m = dgp.generate(500, &mut rng);
            assert_eq!((m.rows, m.cols), (500, 2));
            assert!(
                m.data.iter().all(|x| x.is_finite()),
                "{} produced non-finite values",
                dgp.name()
            );
        }
    }

    #[test]
    fn bivariate_normal_correlation() {
        let mut rng = Rng::new(2);
        let m = Dgp::BivariateNormal.generate(50_000, &mut rng);
        assert!((sample_corr(&m) - 0.7).abs() < 0.02);
    }

    #[test]
    fn circular_radius_distribution() {
        let mut rng = Rng::new(3);
        let m = Dgp::Circular.generate(20_000, &mut rng);
        let radii: Vec<f64> = (0..m.rows)
            .map(|r| (m.at(r, 0).powi(2) + m.at(r, 1).powi(2)).sqrt())
            .collect();
        assert!((mean(&radii) - 5.0).abs() < 0.1);
        assert!((std_dev(&radii) - 1.0).abs() < 0.1);
        // linear correlation should vanish
        assert!(sample_corr(&m).abs() < 0.05);
    }

    #[test]
    fn copula_complex_marginals() {
        let mut rng = Rng::new(4);
        let m = Dgp::CopulaComplex.generate(50_000, &mut rng);
        let y1 = column(&m, 0);
        let y2 = column(&m, 1);
        // Gamma(2,1): mean 2
        assert!((mean(&y1) - 2.0).abs() < 0.05, "gamma mean {}", mean(&y1));
        assert!(y1.iter().all(|&x| x > 0.0));
        // LogNormal(0,1): median 1
        let med = crate::util::median(&y2);
        assert!((med - 1.0).abs() < 0.08, "lognormal median {med}");
        // Clayton θ=2 ⇒ strong positive lower-tail dependence: positive corr
        assert!(sample_corr(&m) > 0.2);
    }

    #[test]
    fn t_copula_marginals() {
        let mut rng = Rng::new(5);
        let m = Dgp::TCopula.generate(30_000, &mut rng);
        let y2 = column(&m, 1);
        // Exp(1): mean 1, all positive
        assert!(y2.iter().all(|&x| x >= 0.0));
        assert!((mean(&y2) - 1.0).abs() < 0.05);
        // positive dependence from ρ=0.7
        assert!(sample_corr(&m) > 0.3);
    }

    #[test]
    fn hourglass_variance_grows() {
        let mut rng = Rng::new(6);
        let m = Dgp::Hourglass.generate(50_000, &mut rng);
        let (mut inner, mut outer) = (Vec::new(), Vec::new());
        for r in 0..m.rows {
            let (y1, y2) = (m.at(r, 0), m.at(r, 1));
            if y1.abs() < 0.5 {
                inner.push(y2);
            } else if y1.abs() > 3.0 {
                outer.push(y2);
            }
        }
        assert!(std_dev(&outer) > 2.0 * std_dev(&inner));
    }

    #[test]
    fn bimodal_clusters_two_modes() {
        let mut rng = Rng::new(7);
        let m = Dgp::BimodalClusters.generate(20_000, &mut rng);
        let left = (0..m.rows).filter(|&r| m.at(r, 0) < 0.0).count();
        let frac = left as f64 / m.rows as f64;
        assert!((frac - 0.5).abs() < 0.05);
    }

    #[test]
    fn skew_t_is_skewed_and_heavy() {
        let mut rng = Rng::new(8);
        let m = Dgp::SkewT.generate(50_000, &mut rng);
        let y1 = column(&m, 0);
        // α₁ = 5 ⇒ strongly right-skewed first margin
        let med = crate::util::median(&y1);
        let mn = mean(&y1);
        assert!(mn > med, "right skew expected: mean {mn} median {med}");
        // ν = 4 ⇒ heavy tails: kurtosis proxy
        let sd = std_dev(&y1);
        let p_far = y1.iter().filter(|&&x| (x - mn).abs() > 4.0 * sd).count();
        assert!(p_far > 10);
    }

    #[test]
    fn sinusoidal_follows_sine() {
        let mut rng = Rng::new(9);
        let m = Dgp::Sinusoidal.generate(20_000, &mut rng);
        let mut err = 0.0;
        for r in 0..m.rows {
            let expect = 2.0 * (PI * m.at(r, 0)).sin();
            err += (m.at(r, 1) - expect).powi(2);
        }
        let mse = err / m.rows as f64;
        assert!((mse - 0.25).abs() < 0.05, "residual mse {mse}");
    }

    #[test]
    fn piecewise_regime_slopes() {
        let mut rng = Rng::new(10);
        let m = Dgp::Piecewise.generate(50_000, &mut rng);
        // slope in Y1 ≥ 1 regime should be about −2
        let pts: Vec<(f64, f64)> = (0..m.rows)
            .map(|r| (m.at(r, 0), m.at(r, 1)))
            .filter(|&(a, _)| a >= 1.0)
            .collect();
        let mx = mean(&pts.iter().map(|p| p.0).collect::<Vec<_>>());
        let my = mean(&pts.iter().map(|p| p.1).collect::<Vec<_>>());
        let mut num = 0.0;
        let mut den = 0.0;
        for &(x, y) in &pts {
            num += (x - mx) * (y - my);
            den += (x - mx) * (x - mx);
        }
        let slope = num / den;
        assert!((slope + 2.0).abs() < 0.1, "slope {slope}");
    }
}
