//! Synthetic daily equity-return panels (substitution for the paper's
//! CRSP/Yahoo 10- and 20-stock datasets; DESIGN.md §5).
//!
//! Reproduces the stylized facts that drive the coreset comparison:
//!   * heavy tails (t(6) innovations),
//!   * volatility clustering (GARCH(1,1) per stock),
//!   * cross-sectional dependence through a market factor plus sector
//!     factors (the 10/20 tickers of Tables 7/8 grouped into sectors),
//!   * occasional market-wide crash days (jump mixture) — the extreme
//!     points the convex-hull component is designed to capture.

use crate::linalg::Mat;
use crate::util::rng::Rng;

/// Sector labels (0=staples, 1=energy, 2=tech, 3=health) mirroring the
/// ticker lists of Tables 7–8.
fn sector(i: usize) -> usize {
    // first 10: JNJ PG KO XOM WMT IBM GE MMM MCD PFE
    // next 10:  AAPL MSFT INTC CSCO AMGN CMCSA COST GILD SBUX TOT
    const SECTORS: [usize; 20] = [3, 0, 0, 1, 0, 2, 2, 2, 0, 3, 2, 2, 2, 2, 3, 2, 0, 3, 0, 1];
    SECTORS[i % 20]
}

/// GARCH(1,1) parameters for the **idiosyncratic** component (typical
/// daily-equity magnitudes; α + β = 0.95 keeps the recursion stable
/// under t-innovations).
const OMEGA: f64 = 0.25e-5;
const ALPHA: f64 = 0.05;
const BETA: f64 = 0.90;

/// Generate an (n_days × n_stocks) matrix of daily returns.
pub fn generate(n_days: usize, n_stocks: usize, rng: &mut Rng) -> Mat {
    assert!(n_stocks <= 20, "tickers defined for up to 20 stocks");
    let mut out = Mat::zeros(n_days, n_stocks);
    // state: per-stock idiosyncratic conditional variance
    let uncond = OMEGA / (1.0 - ALPHA - BETA); // = 0.5e-4 ⇒ idio sd ≈ 0.7%
    let mut h = vec![uncond; n_stocks];
    let mut prev_e2 = vec![uncond; n_stocks];
    // per-stock loadings
    let beta_mkt: Vec<f64> = (0..n_stocks)
        .map(|i| 0.7 + 0.06 * (i % 7) as f64)
        .collect();
    let beta_sec = 0.5;

    for day in 0..n_days {
        // factors: market + 4 sectors, heavy-tailed
        let crash = rng.f64() < 0.004; // a few crash days per decade
        let mkt_scale = if crash { 4.0 } else { 1.0 };
        let f_mkt = rng.student_t(6.0) * 0.006 * mkt_scale;
        let f_sec: Vec<f64> = (0..4).map(|_| rng.student_t(6.0) * 0.004).collect();
        for s in 0..n_stocks {
            // GARCH update driven by the idiosyncratic shock only (the
            // factor variance is stationary by construction)
            h[s] = (OMEGA + ALPHA * prev_e2[s] + BETA * h[s]).min(25.0 * uncond);
            let idio = rng.student_t(6.0) * h[s].sqrt();
            prev_e2[s] = idio * idio;
            let r = beta_mkt[s] * f_mkt + beta_sec * f_sec[sector(s)] + idio;
            *out.at_mut(day, s) = r;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{mean, std_dev};

    fn col(m: &Mat, c: usize) -> Vec<f64> {
        (0..m.rows).map(|r| m.at(r, c)).collect()
    }

    fn corr(a: &[f64], b: &[f64]) -> f64 {
        let (ma, mb) = (mean(a), mean(b));
        let mut num = 0.0;
        for i in 0..a.len() {
            num += (a[i] - ma) * (b[i] - mb);
        }
        num / ((a.len() - 1) as f64 * std_dev(a) * std_dev(b))
    }

    #[test]
    fn shapes_and_scale() {
        let mut rng = Rng::new(1);
        let m = generate(2000, 10, &mut rng);
        assert_eq!((m.rows, m.cols), (2000, 10));
        // daily returns: mean ≈ 0, sd on the order of 1–3%
        for c in 0..10 {
            let v = col(&m, c);
            assert!(mean(&v).abs() < 0.005);
            let sd = std_dev(&v);
            assert!((0.003..0.08).contains(&sd), "sd {sd}");
        }
    }

    #[test]
    fn cross_correlation_positive() {
        let mut rng = Rng::new(2);
        let m = generate(5000, 10, &mut rng);
        let mut cs = Vec::new();
        for i in 0..10 {
            for j in (i + 1)..10 {
                cs.push(corr(&col(&m, i), &col(&m, j)));
            }
        }
        let avg = mean(&cs);
        assert!(avg > 0.1, "avg pairwise corr {avg}");
    }

    #[test]
    fn heavy_tails_present() {
        let mut rng = Rng::new(3);
        let m = generate(10_000, 5, &mut rng);
        let v = col(&m, 0);
        let sd = std_dev(&v);
        let extreme = v.iter().filter(|&&x| x.abs() > 5.0 * sd).count();
        // normal would give ~0.006%% → ~0–1 in 10k; heavy tails give more
        assert!(extreme >= 3, "extreme days {extreme}");
    }

    #[test]
    fn volatility_clusters() {
        let mut rng = Rng::new(4);
        let m = generate(20_000, 3, &mut rng);
        let v = col(&m, 0);
        // autocorrelation of |r| should be clearly positive
        let absr: Vec<f64> = v.iter().map(|x| x.abs()).collect();
        let mu = mean(&absr);
        let mut num = 0.0;
        let mut den = 0.0;
        for i in 1..absr.len() {
            num += (absr[i] - mu) * (absr[i - 1] - mu);
        }
        for x in &absr {
            den += (x - mu) * (x - mu);
        }
        let ac1 = num / den;
        assert!(ac1 > 0.05, "abs-return autocorr {ac1}");
    }

    #[test]
    fn sector_correlation_exceeds_cross_sector() {
        let mut rng = Rng::new(5);
        let m = generate(8000, 20, &mut rng);
        let (mut same, mut diff) = (Vec::new(), Vec::new());
        for i in 0..20 {
            for j in (i + 1)..20 {
                let c = corr(&col(&m, i), &col(&m, j));
                if sector(i) == sector(j) {
                    same.push(c);
                } else {
                    diff.push(c);
                }
            }
        }
        assert!(mean(&same) > mean(&diff), "{} vs {}", mean(&same), mean(&diff));
    }
}
