//! Deterministic fault injection for the streaming pipeline.
//!
//! [`FaultySource`] wraps any [`ShardSource`] and injects faults from a
//! seeded [`FaultPlan`]: transient read errors (retryable), fatal read
//! errors, NaN/inf cell corruption, spurious empty shards, and
//! mid-stream termination. Everything is a pure function of the plan,
//! its seed, and the call sequence — no wall clock, no OS state — so a
//! faulty run is exactly reproducible, which is what lets the test
//! suite prove the headline invariant: a run with injected *transient*
//! faults plus producer retries is **bit-identical** to the fault-free
//! run (transient faults fire *before* the wrapped source is advanced,
//! so a retry re-requests the same underlying shard).

use super::{ShardError, ShardSource};
use crate::linalg::Mat;
use crate::util::rng::Rng;

/// Seeded description of which faults to inject where. All shard
/// indices are 0-based positions in the *underlying* stream (spurious
/// empty shards do not advance them).
#[derive(Clone, Debug)]
pub struct FaultPlan {
    seed: u64,
    /// Inject transient errors before every k-th underlying shard.
    transient_every: Option<usize>,
    /// Consecutive transient errors per injection site.
    transient_repeats: usize,
    /// Poison this many cells per delivered shard with NaN/inf.
    nan_cells_per_shard: usize,
    /// Emit one spurious zero-row shard before every k-th shard.
    empty_before_every: Option<usize>,
    /// Return a fatal error when the stream reaches this shard.
    fatal_at_shard: Option<usize>,
    /// End the stream (Ok(None)) when it reaches this shard.
    truncate_at_shard: Option<usize>,
}

impl FaultPlan {
    /// A plan that injects nothing (build it up with the `with_*`
    /// methods). The seed drives only the corrupted-cell positions.
    pub fn new(seed: u64) -> Self {
        FaultPlan {
            seed,
            transient_every: None,
            transient_repeats: 1,
            nan_cells_per_shard: 0,
            empty_before_every: None,
            fatal_at_shard: None,
            truncate_at_shard: None,
        }
    }

    /// Transient errors before every `every`-th shard (1-based period),
    /// `repeats` consecutive failures per site. `repeats` at or below
    /// the producer's retry budget is recoverable; above it, the run
    /// fails with a typed error.
    pub fn with_transients(mut self, every: usize, repeats: usize) -> Self {
        assert!(every > 0 && repeats > 0);
        self.transient_every = Some(every);
        self.transient_repeats = repeats;
        self
    }

    /// Poison `cells` seeded positions per shard with NaN (even draws)
    /// or +inf (odd draws).
    pub fn with_nan_cells(mut self, cells: usize) -> Self {
        self.nan_cells_per_shard = cells;
        self
    }

    /// Emit a spurious zero-row shard before every `every`-th shard.
    pub fn with_empty_shards(mut self, every: usize) -> Self {
        assert!(every > 0);
        self.empty_before_every = Some(every);
        self
    }

    /// Fail fatally when the stream reaches shard `idx` (0-based).
    pub fn with_fatal_at(mut self, idx: usize) -> Self {
        self.fatal_at_shard = Some(idx);
        self
    }

    /// Terminate the stream cleanly at shard `idx` (0-based).
    pub fn with_truncation_at(mut self, idx: usize) -> Self {
        self.truncate_at_shard = Some(idx);
        self
    }
}

/// A [`ShardSource`] adapter that injects the faults described by a
/// [`FaultPlan`]. See the module docs for the determinism contract.
pub struct FaultySource<S: ShardSource> {
    inner: S,
    plan: FaultPlan,
    rng: Rng,
    /// Underlying shards delivered so far = index of the next one.
    delivered: usize,
    /// Remaining transient failures at the current injection site.
    transient_pending: usize,
    /// Site the pending counter was armed for (avoids re-arming after
    /// the retries at a site are exhausted).
    transient_armed_for: Option<usize>,
    /// Site a spurious empty shard was already emitted for.
    empty_emitted_for: Option<usize>,
}

impl<S: ShardSource> FaultySource<S> {
    pub fn new(inner: S, plan: FaultPlan) -> Self {
        let rng = Rng::new(plan.seed);
        FaultySource {
            inner,
            plan,
            rng,
            delivered: 0,
            transient_pending: 0,
            transient_armed_for: None,
            empty_emitted_for: None,
        }
    }

    fn poison(&mut self, mut shard: Mat) -> Mat {
        let cells = shard.rows * shard.cols;
        if cells == 0 {
            return shard;
        }
        for k in 0..self.plan.nan_cells_per_shard {
            let pos = self.rng.usize(cells);
            shard.data[pos] = if k % 2 == 0 { f64::NAN } else { f64::INFINITY };
        }
        shard
    }
}

impl<S: ShardSource> ShardSource for FaultySource<S> {
    fn next_shard(&mut self) -> Result<Option<Mat>, ShardError> {
        let idx = self.delivered;
        if self.plan.fatal_at_shard == Some(idx) {
            return Err(ShardError::Fatal(format!(
                "injected fatal fault at shard {idx}"
            )));
        }
        if self.plan.truncate_at_shard == Some(idx) {
            return Ok(None);
        }
        // transient faults fire BEFORE touching the wrapped source, so
        // a retry sees the exact same underlying shard
        if let Some(every) = self.plan.transient_every {
            if (idx + 1) % every == 0 && self.transient_armed_for != Some(idx) {
                self.transient_armed_for = Some(idx);
                self.transient_pending = self.plan.transient_repeats;
            }
            if self.transient_pending > 0 {
                self.transient_pending -= 1;
                return Err(ShardError::Transient(format!(
                    "injected transient fault before shard {idx}"
                )));
            }
        }
        if let Some(every) = self.plan.empty_before_every {
            if (idx + 1) % every == 0 && self.empty_emitted_for != Some(idx) {
                self.empty_emitted_for = Some(idx);
                return Ok(Some(Mat::zeros(0, self.inner.dim())));
            }
        }
        match self.inner.next_shard()? {
            Some(shard) => {
                self.delivered += 1;
                Ok(Some(self.poison(shard)))
            }
            None => Ok(None),
        }
    }

    fn dim(&self) -> usize {
        self.inner.dim()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::MatShards;

    fn base(rows: usize) -> MatShards {
        let data = Mat::from_vec(rows, 2, (0..rows * 2).map(|x| x as f64).collect());
        MatShards::new(data, 2)
    }

    fn drain_with_retries<S: ShardSource>(mut src: S, max_retries: usize) -> Vec<Mat> {
        let mut out = Vec::new();
        loop {
            let mut attempts = 0;
            let shard = loop {
                match src.next_shard() {
                    Ok(s) => break s,
                    Err(ShardError::Transient(_)) if attempts < max_retries => attempts += 1,
                    Err(e) => panic!("unexpected {e}"),
                }
            };
            match shard {
                Some(s) if s.rows == 0 => continue,
                Some(s) => out.push(s),
                None => break,
            }
        }
        out
    }

    #[test]
    fn transient_faults_then_identical_stream() {
        let clean = drain_with_retries(base(10), 0);
        let plan = FaultPlan::new(7).with_transients(2, 2);
        let faulty = drain_with_retries(FaultySource::new(base(10), plan), 3);
        assert_eq!(clean.len(), faulty.len());
        for (a, b) in clean.iter().zip(&faulty) {
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn empty_shards_do_not_change_content() {
        let clean = drain_with_retries(base(10), 0);
        let plan = FaultPlan::new(7).with_empty_shards(2);
        let faulty = drain_with_retries(FaultySource::new(base(10), plan), 0);
        assert_eq!(clean.len(), faulty.len());
        for (a, b) in clean.iter().zip(&faulty) {
            assert_eq!(a.data, b.data);
        }
    }

    #[test]
    fn fatal_fires_at_the_named_shard() {
        let plan = FaultPlan::new(1).with_fatal_at(1);
        let mut src = FaultySource::new(base(10), plan);
        assert!(src.next_shard().unwrap().is_some());
        assert!(matches!(src.next_shard(), Err(ShardError::Fatal(_))));
        // idempotent: asking again still fails
        assert!(matches!(src.next_shard(), Err(ShardError::Fatal(_))));
    }

    #[test]
    fn truncation_ends_the_stream_cleanly() {
        let plan = FaultPlan::new(1).with_truncation_at(2);
        let shards = drain_with_retries(FaultySource::new(base(10), plan), 0);
        assert_eq!(shards.len(), 2);
    }

    #[test]
    fn nan_cells_are_injected_deterministically() {
        let run = |seed| {
            let plan = FaultPlan::new(seed).with_nan_cells(1);
            drain_with_retries(FaultySource::new(base(6), plan), 0)
        };
        let a = run(3);
        let b = run(3);
        let total_bad: usize = a
            .iter()
            .map(|s| s.data.iter().filter(|x| !x.is_finite()).count())
            .sum();
        assert!(total_bad >= 1, "at least one cell poisoned");
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                       y.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>());
        }
    }
}
