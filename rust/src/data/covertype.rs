//! Synthetic Covertype-like terrain data (substitution for UCI Covertype
//! — no dataset/network in the build image; DESIGN.md §5).
//!
//! Reproduces the *statistical shape* the paper's experiment depends on:
//! 10 continuous terrain variables over ~581k rows with
//!   * multimodal marginals (elevation differs sharply by cover type),
//!   * right-skewed distance variables with long tails,
//!   * bounded, left-skewed hillshade indices,
//!   * strong non-linear cross-dependence (hillshade ↔ aspect/slope,
//!     distances ↔ elevation).
//! Seven latent "cover types" drive a mixture, exactly the mechanism
//! that makes uniform subsampling miss rare-but-extreme strata — the
//! behaviour the ℓ₂-hull coreset exploits.

use crate::data::sparse::SparseMat;
use crate::linalg::Mat;
use crate::util::rng::Rng;
use std::f64::consts::PI;

/// Column order (mirrors the 10 continuous Covertype variables).
pub const COLUMNS: [&str; 10] = [
    "elevation",
    "aspect",
    "slope",
    "hdist_hydrology",
    "vdist_hydrology",
    "hdist_roadways",
    "hillshade_9am",
    "hillshade_noon",
    "hillshade_3pm",
    "hdist_firepoints",
];

/// Per-cover-type latent parameters (means roughly mimic the real
/// dataset's strata; weights mimic its strong class imbalance).
struct CoverType {
    weight: f64,
    elevation_mean: f64,
    elevation_sd: f64,
    slope_shape: f64,
    dist_scale: f64,
}

const TYPES: [CoverType; 7] = [
    CoverType { weight: 0.365, elevation_mean: 3150.0, elevation_sd: 120.0, slope_shape: 2.0, dist_scale: 300.0 },
    CoverType { weight: 0.488, elevation_mean: 2950.0, elevation_sd: 160.0, slope_shape: 2.5, dist_scale: 250.0 },
    CoverType { weight: 0.062, elevation_mean: 2400.0, elevation_sd: 140.0, slope_shape: 4.0, dist_scale: 150.0 },
    CoverType { weight: 0.005, elevation_mean: 2200.0, elevation_sd: 90.0, slope_shape: 5.0, dist_scale: 100.0 },
    CoverType { weight: 0.016, elevation_mean: 2800.0, elevation_sd: 100.0, slope_shape: 3.0, dist_scale: 200.0 },
    CoverType { weight: 0.030, elevation_mean: 2500.0, elevation_sd: 130.0, slope_shape: 4.5, dist_scale: 170.0 },
    CoverType { weight: 0.035, elevation_mean: 3400.0, elevation_sd: 90.0, slope_shape: 3.5, dist_scale: 350.0 },
];

/// Cumulative type weights (and their total) for latent-type sampling.
fn cum_weights() -> ([f64; 7], f64) {
    let mut cum = [0.0f64; 7];
    let mut acc = 0.0;
    for (i, t) in TYPES.iter().enumerate() {
        acc += t.weight;
        cum[i] = acc;
    }
    (cum, acc)
}

/// Draw a latent cover-type index (consumes exactly one `rng.f64()`).
fn sample_type(cum: &[f64; 7], total: f64, rng: &mut Rng) -> usize {
    let u = rng.f64() * total;
    cum.iter().position(|&c| u <= c).unwrap_or(6)
}

/// Generate n synthetic terrain observations (n × 10).
pub fn generate(n: usize, rng: &mut Rng) -> Mat {
    let mut out = Mat::zeros(n, 10);
    let (cum, total) = cum_weights();
    for r in 0..n {
        let ti = sample_type(&cum, total, rng);
        terrain_row(ti, rng, out.row_mut(r));
    }
    out
}

/// Fill `row` (10 values) with one observation of cover type `ti`.
/// The draw sequence is exactly the pre-refactor `generate` body, so
/// `generate` stays bitwise-identical across this extraction (pinned
/// by `onehot_extends_base_columns` below via the shared helpers).
fn terrain_row(ti: usize, rng: &mut Rng, row: &mut [f64]) {
    let t = &TYPES[ti];
    let elevation = rng.normal_ms(t.elevation_mean, t.elevation_sd);
    // aspect in degrees [0, 360): mixture of two prevailing exposures
    let aspect = if rng.f64() < 0.6 {
        (rng.normal_ms(120.0, 60.0)).rem_euclid(360.0)
    } else {
        (rng.normal_ms(310.0, 50.0)).rem_euclid(360.0)
    };
    // slope: right-skewed gamma, steeper at low elevation types
    let slope = rng.gamma(t.slope_shape, 4.0).min(60.0);
    // distances: right-skewed, elevation-coupled long tails
    let hydro_h = rng.gamma(1.5, t.dist_scale * (1.0 + (elevation - 2000.0).max(0.0) / 3000.0));
    let hydro_v = 0.15 * hydro_h * rng.normal_ms(0.4, 0.6) + rng.normal_ms(0.0, 15.0);
    let road = rng.gamma(1.8, 900.0 + 0.4 * (elevation - 2200.0).max(0.0));
    let fire = rng.gamma(1.6, 800.0 + 0.3 * (elevation - 2200.0).max(0.0));
    // hillshade: deterministic sun-geometry core + noise, bounded 0..254
    let asp_rad = aspect * PI / 180.0;
    let slope_rad = slope * PI / 180.0;
    let hs = |sun_azimuth: f64, sun_alt: f64, rng: &mut Rng| -> f64 {
        let az = sun_azimuth * PI / 180.0;
        let alt = sun_alt * PI / 180.0;
        let v = 254.0
            * (alt.sin() * slope_rad.cos()
                + alt.cos() * slope_rad.sin() * (az - asp_rad).cos());
        (v + rng.normal_ms(0.0, 8.0)).clamp(0.0, 254.0)
    };
    let hs9 = hs(105.0, 45.0, rng);
    let hsnoon = hs(180.0, 60.0, rng);
    let hs3 = hs(255.0, 45.0, rng);

    row[0] = elevation;
    row[1] = aspect;
    row[2] = slope;
    row[3] = hydro_h;
    row[4] = hydro_v;
    row[5] = road;
    row[6] = hs9;
    row[7] = hsnoon;
    row[8] = hs3;
    row[9] = fire;
}

/// Width of the one-hot encoding: 10 continuous columns + 4 wilderness
/// areas + 40 soil types — the real Covertype design shape.
pub const ONEHOT_COLS: usize = 54;
/// Wilderness area of each cover type (deterministic, like the strong
/// type↔area association in the real data).
const WILDERNESS_OF_TYPE: [usize; 7] = [0, 0, 1, 2, 3, 1, 0];
/// First soil type of each cover type's range.
const SOIL_BASE: [usize; 7] = [20, 10, 0, 0, 12, 2, 32];
/// Number of soil types each cover type draws from (uniformly).
const SOIL_SPAN: [usize; 7] = [10, 14, 6, 4, 8, 8, 8];

/// One one-hot observation: the 10 terrain values (same draws as
/// [`generate`]) plus the indicator indices — wilderness is a
/// deterministic function of the latent type, soil is drawn uniformly
/// from the type's range *after* the terrain draws (so the shared
/// terrain stream is untouched).
fn onehot_row(
    cum: &[f64; 7],
    total: f64,
    rng: &mut Rng,
    terrain: &mut [f64],
) -> (usize, usize) {
    let ti = sample_type(cum, total, rng);
    terrain_row(ti, rng, terrain);
    let soil = SOIL_BASE[ti] + rng.usize(SOIL_SPAN[ti]);
    (WILDERNESS_OF_TYPE[ti], soil)
}

/// Generate n one-hot-encoded observations (n × [`ONEHOT_COLS`]):
/// columns 0..10 are the continuous terrain variables, 10..14 the
/// wilderness-area indicators, 14..54 the soil-type indicators —
/// exactly one of each indicator block is 1 per row.
pub fn generate_onehot(n: usize, rng: &mut Rng) -> Mat {
    let (cum, total) = cum_weights();
    let mut out = Mat::zeros(n, ONEHOT_COLS);
    for r in 0..n {
        let row = out.row_mut(r);
        let (wilderness, soil) = {
            let (terrain, _) = row.split_at_mut(10);
            onehot_row(&cum, total, rng, terrain)
        };
        row[10 + wilderness] = 1.0;
        row[14 + soil] = 1.0;
    }
    out
}

/// [`generate_onehot`] directly in CSR form: 12 stored entries per row
/// (10 continuous + 2 indicators) out of 54 columns, so a Covertype-like
/// design is born at ~22% density and never materializes densely. Same
/// seed ⇒ `to_dense()` is bitwise-equal to [`generate_onehot`] (pinned
/// by `sparse_onehot_matches_dense_bitwise` below).
pub fn generate_onehot_sparse(n: usize, rng: &mut Rng) -> SparseMat {
    let (cum, total) = cum_weights();
    let mut out = SparseMat::new(ONEHOT_COLS);
    let mut terrain = [0.0f64; 10];
    let mut entries: Vec<(usize, f64)> = Vec::with_capacity(12);
    for _ in 0..n {
        let (wilderness, soil) = onehot_row(&cum, total, rng, &mut terrain);
        entries.clear();
        for (c, &v) in terrain.iter().enumerate() {
            entries.push((c, v));
        }
        entries.push((10 + wilderness, 1.0));
        entries.push((14 + soil, 1.0));
        out.push_row(&entries);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{mean, median, std_dev};

    fn col(m: &Mat, c: usize) -> Vec<f64> {
        (0..m.rows).map(|r| m.at(r, c)).collect()
    }

    #[test]
    fn shapes_and_finiteness() {
        let mut rng = Rng::new(1);
        let m = generate(2000, &mut rng);
        assert_eq!((m.rows, m.cols), (2000, 10));
        assert!(m.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn hillshade_bounded() {
        let mut rng = Rng::new(2);
        let m = generate(5000, &mut rng);
        for c in 6..=8 {
            let v = col(&m, c);
            assert!(v.iter().all(|&x| (0.0..=254.0).contains(&x)));
        }
    }

    #[test]
    fn distances_right_skewed() {
        let mut rng = Rng::new(3);
        let m = generate(20_000, &mut rng);
        for c in [3usize, 5, 9] {
            let v = col(&m, c);
            assert!(v.iter().all(|&x| x >= 0.0));
            assert!(
                mean(&v) > median(&v),
                "col {c} should be right-skewed: mean {} median {}",
                mean(&v),
                median(&v)
            );
        }
    }

    #[test]
    fn elevation_multimodal_via_type_strata() {
        let mut rng = Rng::new(4);
        let m = generate(50_000, &mut rng);
        let e = col(&m, 0);
        // mixture of strata at 2200..3400 ⇒ overall sd far above the
        // within-type sd (~150)
        assert!(std_dev(&e) > 180.0, "sd {}", std_dev(&e));
        // rare low-elevation stratum exists
        let low = e.iter().filter(|&&x| x < 2350.0).count();
        assert!(low > 50 && (low as f64) < 0.2 * e.len() as f64);
    }

    #[test]
    fn sparse_onehot_matches_dense_bitwise() {
        // same seed ⇒ the CSR generator densifies to exactly the dense
        // generator's bits, with exactly 12 stored entries per row
        let n = 3000;
        let dense = generate_onehot(n, &mut Rng::new(9));
        let sparse = generate_onehot_sparse(n, &mut Rng::new(9));
        assert_eq!((dense.rows, dense.cols), (n, ONEHOT_COLS));
        assert_eq!((sparse.rows, sparse.cols), (n, ONEHOT_COLS));
        assert_eq!(sparse.nnz(), 12 * n);
        let back = sparse.to_dense();
        for (i, (a, b)) in dense.data.iter().zip(&back.data).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "cell {i}: {a} vs {b}");
        }
    }

    #[test]
    fn onehot_extends_base_columns() {
        // the indicator blocks are well-formed (exactly one wilderness
        // and one soil indicator per row, in the documented ranges) and
        // the continuous block keeps the terrain generator's shape
        // invariants
        let m = generate_onehot(5000, &mut Rng::new(10));
        for r in 0..m.rows {
            let row = m.row(r);
            let wild: Vec<usize> =
                (10..14).filter(|&c| row[c] != 0.0).collect();
            let soil: Vec<usize> =
                (14..54).filter(|&c| row[c] != 0.0).collect();
            assert_eq!(wild.len(), 1, "row {r}");
            assert_eq!(soil.len(), 1, "row {r}");
            assert_eq!(row[wild[0]], 1.0);
            assert_eq!(row[soil[0]], 1.0);
            for c in 6..=8 {
                assert!((0.0..=254.0).contains(&row[c]), "row {r} col {c}");
            }
            assert!(row.iter().all(|x| x.is_finite()));
        }
        // soil indices respect the per-type ranges: every base+span is
        // inside the 40-column block
        for (b, s) in SOIL_BASE.iter().zip(&SOIL_SPAN) {
            assert!(b + s <= 40);
        }
    }

    #[test]
    fn refactored_generate_is_stable() {
        // the terrain_row extraction must not move any draw: two calls
        // with the same seed agree, and the generator still produces
        // the multimodal-elevation shape the tests above pin
        let a = generate(500, &mut Rng::new(11));
        let b = generate(500, &mut Rng::new(11));
        for (x, y) in a.data.iter().zip(&b.data) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn hillshade_depends_on_aspect() {
        let mut rng = Rng::new(5);
        let m = generate(30_000, &mut rng);
        // morning hillshade should be higher for east-facing (aspect
        // ~105°) than west-facing (~255°) on steep slopes
        let (mut east, mut west) = (Vec::new(), Vec::new());
        for r in 0..m.rows {
            let aspect = m.at(r, 1);
            let slope = m.at(r, 2);
            if slope < 15.0 {
                continue;
            }
            if (aspect - 105.0).abs() < 30.0 {
                east.push(m.at(r, 6));
            } else if (aspect - 255.0).abs() < 30.0 {
                west.push(m.at(r, 6));
            }
        }
        assert!(mean(&east) > mean(&west) + 20.0);
    }
}
