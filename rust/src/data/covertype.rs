//! Synthetic Covertype-like terrain data (substitution for UCI Covertype
//! — no dataset/network in the build image; DESIGN.md §5).
//!
//! Reproduces the *statistical shape* the paper's experiment depends on:
//! 10 continuous terrain variables over ~581k rows with
//!   * multimodal marginals (elevation differs sharply by cover type),
//!   * right-skewed distance variables with long tails,
//!   * bounded, left-skewed hillshade indices,
//!   * strong non-linear cross-dependence (hillshade ↔ aspect/slope,
//!     distances ↔ elevation).
//! Seven latent "cover types" drive a mixture, exactly the mechanism
//! that makes uniform subsampling miss rare-but-extreme strata — the
//! behaviour the ℓ₂-hull coreset exploits.

use crate::linalg::Mat;
use crate::util::rng::Rng;
use std::f64::consts::PI;

/// Column order (mirrors the 10 continuous Covertype variables).
pub const COLUMNS: [&str; 10] = [
    "elevation",
    "aspect",
    "slope",
    "hdist_hydrology",
    "vdist_hydrology",
    "hdist_roadways",
    "hillshade_9am",
    "hillshade_noon",
    "hillshade_3pm",
    "hdist_firepoints",
];

/// Per-cover-type latent parameters (means roughly mimic the real
/// dataset's strata; weights mimic its strong class imbalance).
struct CoverType {
    weight: f64,
    elevation_mean: f64,
    elevation_sd: f64,
    slope_shape: f64,
    dist_scale: f64,
}

const TYPES: [CoverType; 7] = [
    CoverType { weight: 0.365, elevation_mean: 3150.0, elevation_sd: 120.0, slope_shape: 2.0, dist_scale: 300.0 },
    CoverType { weight: 0.488, elevation_mean: 2950.0, elevation_sd: 160.0, slope_shape: 2.5, dist_scale: 250.0 },
    CoverType { weight: 0.062, elevation_mean: 2400.0, elevation_sd: 140.0, slope_shape: 4.0, dist_scale: 150.0 },
    CoverType { weight: 0.005, elevation_mean: 2200.0, elevation_sd: 90.0, slope_shape: 5.0, dist_scale: 100.0 },
    CoverType { weight: 0.016, elevation_mean: 2800.0, elevation_sd: 100.0, slope_shape: 3.0, dist_scale: 200.0 },
    CoverType { weight: 0.030, elevation_mean: 2500.0, elevation_sd: 130.0, slope_shape: 4.5, dist_scale: 170.0 },
    CoverType { weight: 0.035, elevation_mean: 3400.0, elevation_sd: 90.0, slope_shape: 3.5, dist_scale: 350.0 },
];

/// Generate n synthetic terrain observations (n × 10).
pub fn generate(n: usize, rng: &mut Rng) -> Mat {
    let mut out = Mat::zeros(n, 10);
    // cumulative type weights
    let mut cum = [0.0f64; 7];
    let mut acc = 0.0;
    for (i, t) in TYPES.iter().enumerate() {
        acc += t.weight;
        cum[i] = acc;
    }
    let total = acc;
    for r in 0..n {
        let u = rng.f64() * total;
        let t = &TYPES[cum.iter().position(|&c| u <= c).unwrap_or(6)];

        let elevation = rng.normal_ms(t.elevation_mean, t.elevation_sd);
        // aspect in degrees [0, 360): mixture of two prevailing exposures
        let aspect = if rng.f64() < 0.6 {
            (rng.normal_ms(120.0, 60.0)).rem_euclid(360.0)
        } else {
            (rng.normal_ms(310.0, 50.0)).rem_euclid(360.0)
        };
        // slope: right-skewed gamma, steeper at low elevation types
        let slope = rng.gamma(t.slope_shape, 4.0).min(60.0);
        // distances: right-skewed, elevation-coupled long tails
        let hydro_h = rng.gamma(1.5, t.dist_scale * (1.0 + (elevation - 2000.0).max(0.0) / 3000.0));
        let hydro_v = 0.15 * hydro_h * rng.normal_ms(0.4, 0.6) + rng.normal_ms(0.0, 15.0);
        let road = rng.gamma(1.8, 900.0 + 0.4 * (elevation - 2200.0).max(0.0));
        let fire = rng.gamma(1.6, 800.0 + 0.3 * (elevation - 2200.0).max(0.0));
        // hillshade: deterministic sun-geometry core + noise, bounded 0..254
        let asp_rad = aspect * PI / 180.0;
        let slope_rad = slope * PI / 180.0;
        let hs = |sun_azimuth: f64, sun_alt: f64, rng: &mut Rng| -> f64 {
            let az = sun_azimuth * PI / 180.0;
            let alt = sun_alt * PI / 180.0;
            let v = 254.0
                * (alt.sin() * slope_rad.cos()
                    + alt.cos() * slope_rad.sin() * (az - asp_rad).cos());
            (v + rng.normal_ms(0.0, 8.0)).clamp(0.0, 254.0)
        };
        let hs9 = hs(105.0, 45.0, rng);
        let hsnoon = hs(180.0, 60.0, rng);
        let hs3 = hs(255.0, 45.0, rng);

        let row = out.row_mut(r);
        row[0] = elevation;
        row[1] = aspect;
        row[2] = slope;
        row[3] = hydro_h;
        row[4] = hydro_v;
        row[5] = road;
        row[6] = hs9;
        row[7] = hsnoon;
        row[8] = hs3;
        row[9] = fire;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::{mean, median, std_dev};

    fn col(m: &Mat, c: usize) -> Vec<f64> {
        (0..m.rows).map(|r| m.at(r, c)).collect()
    }

    #[test]
    fn shapes_and_finiteness() {
        let mut rng = Rng::new(1);
        let m = generate(2000, &mut rng);
        assert_eq!((m.rows, m.cols), (2000, 10));
        assert!(m.data.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn hillshade_bounded() {
        let mut rng = Rng::new(2);
        let m = generate(5000, &mut rng);
        for c in 6..=8 {
            let v = col(&m, c);
            assert!(v.iter().all(|&x| (0.0..=254.0).contains(&x)));
        }
    }

    #[test]
    fn distances_right_skewed() {
        let mut rng = Rng::new(3);
        let m = generate(20_000, &mut rng);
        for c in [3usize, 5, 9] {
            let v = col(&m, c);
            assert!(v.iter().all(|&x| x >= 0.0));
            assert!(
                mean(&v) > median(&v),
                "col {c} should be right-skewed: mean {} median {}",
                mean(&v),
                median(&v)
            );
        }
    }

    #[test]
    fn elevation_multimodal_via_type_strata() {
        let mut rng = Rng::new(4);
        let m = generate(50_000, &mut rng);
        let e = col(&m, 0);
        // mixture of strata at 2200..3400 ⇒ overall sd far above the
        // within-type sd (~150)
        assert!(std_dev(&e) > 180.0, "sd {}", std_dev(&e));
        // rare low-elevation stratum exists
        let low = e.iter().filter(|&&x| x < 2350.0).count();
        assert!(low > 50 && (low as f64) < 0.2 * e.len() as f64);
    }

    #[test]
    fn hillshade_depends_on_aspect() {
        let mut rng = Rng::new(5);
        let m = generate(30_000, &mut rng);
        // morning hillshade should be higher for east-facing (aspect
        // ~105°) than west-facing (~255°) on steep slopes
        let (mut east, mut west) = (Vec::new(), Vec::new());
        for r in 0..m.rows {
            let aspect = m.at(r, 1);
            let slope = m.at(r, 2);
            if slope < 15.0 {
                continue;
            }
            if (aspect - 105.0).abs() < 30.0 {
                east.push(m.at(r, 6));
            } else if (aspect - 255.0).abs() < 30.0 {
                west.push(m.at(r, 6));
            }
        }
        assert!(mean(&east) > mean(&west) + 20.0);
    }
}
