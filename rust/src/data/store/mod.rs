//! Out-of-core column store: a chunked, versioned binary on-disk format
//! so a fit can stream datasets that never fit in RAM.
//!
//! The format reuses the artifact idioms (`runtime/artifact.rs`): every
//! f64 is stored as its exact bit pattern (so store → read → store is
//! byte-identical and a store-backed fit is bitwise-equal to the same
//! rows in memory), every chunk carries an FNV-1a checksum, and writes
//! are atomic (`.tmp` + rename). Zero external dependencies.
//!
//! ## Layout (version 1)
//!
//! ```text
//! [0..16)   magic  "mctm-store v1" + 3 NUL bytes
//! [16..24)  rows        u64 LE
//! [24..32)  cols        u64 LE
//! [32..40)  chunk_rows  u64 LE
//! [40..48)  FNV-1a 64 of bytes [0..40), u64 LE
//! then ceil(rows / chunk_rows) chunks, each:
//! [0..8)    FNV-1a 64 of the payload, u64 LE
//! [8..)     r_c · cols f64 bit patterns, u64 LE, column-major
//! ```
//!
//! Chunk `c` holds `r_c = min(chunk_rows, rows − c·chunk_rows)` rows and
//! starts at byte `48 + c·(8 + chunk_rows·cols·8)` — every chunk except
//! the last is full, so readers seek straight to any chunk. Values are
//! column-major *within a chunk* (each chunk is a small column store):
//! unit-stride per-column scans without giving up row-chunked streaming.
//!
//! ## Memory model
//!
//! [`StoreWriter`] holds one chunk of rows; [`StoreReader`] reads one
//! chunk per `next_shard` call. An import (CSV or generator → store) and
//! a store-backed fit therefore both run at O(budget + chunk_rows·cols)
//! peak memory, independent of the total row count — pinned by
//! `tests/store_alloc.rs`.
//!
//! ## Failure semantics
//!
//! [`StoreReader::open`] validates the header checksum and the exact
//! file length (a truncated or padded file is a typed error naming the
//! byte counts). Per-chunk checksum mismatches surface as
//! [`ShardError::Fatal`] naming the chunk and "checksum"; transient I/O
//! errors surface as [`ShardError::Transient`] and are retried by the
//! streaming producer under the PR-6 pins.

use crate::anyhow;
use crate::data::{csv, ShardError, ShardSource};
use crate::linalg::Mat;
use crate::runtime::artifact::fnv1a64;
use crate::util::error::{Context, Result};
use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

/// 13 magic characters + 3 NUL padding bytes = 16-byte magic.
const MAGIC: &[u8; 16] = b"mctm-store v1\0\0\0";
const HEADER_LEN: u64 = 48;
/// Default rows per chunk for `mctm import` (matches the streaming
/// pipeline's default shard size).
pub const DEFAULT_CHUNK_ROWS: usize = 2048;

fn header_bytes(rows: u64, cols: u64, chunk_rows: u64) -> [u8; 48] {
    let mut h = [0u8; 48];
    h[0..16].copy_from_slice(MAGIC);
    h[16..24].copy_from_slice(&rows.to_le_bytes());
    h[24..32].copy_from_slice(&cols.to_le_bytes());
    h[32..40].copy_from_slice(&chunk_rows.to_le_bytes());
    let crc = fnv1a64(&h[0..40]);
    h[40..48].copy_from_slice(&crc.to_le_bytes());
    h
}

fn tmp_path(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    PathBuf::from(os)
}

/// Encode one chunk's rows (row-major `buf`, `r × cols`) as the on-disk
/// column-major payload.
fn encode_chunk(buf: &[f64], r: usize, cols: usize) -> Vec<u8> {
    let mut payload = Vec::with_capacity(r * cols * 8);
    for col in 0..cols {
        for row in 0..r {
            payload.extend_from_slice(&buf[row * cols + col].to_bits().to_le_bytes());
        }
    }
    payload
}

/// Streaming writer: buffers one chunk of rows, writes to `<path>.tmp`,
/// and atomically renames on [`finish`](StoreWriter::finish). Dropping
/// an unfinished writer removes the partial `.tmp` file.
pub struct StoreWriter {
    out: Option<BufWriter<File>>,
    path: PathBuf,
    tmp: PathBuf,
    cols: usize,
    chunk_rows: usize,
    buf: Vec<f64>,
    rows: u64,
}

impl StoreWriter {
    /// Start writing a store at `path` for `cols`-wide rows, flushed in
    /// chunks of `chunk_rows` rows.
    pub fn create(path: &Path, cols: usize, chunk_rows: usize) -> Result<Self> {
        if cols == 0 {
            return Err(anyhow!("store must have at least one column"));
        }
        if chunk_rows == 0 {
            return Err(anyhow!("chunk_rows must be positive"));
        }
        let tmp = tmp_path(path);
        let file = File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        let mut out = BufWriter::new(file);
        // placeholder header (rows = 0); patched by finish()
        out.write_all(&header_bytes(0, cols as u64, chunk_rows as u64))
            .with_context(|| format!("writing {}", tmp.display()))?;
        Ok(StoreWriter {
            out: Some(out),
            path: path.to_path_buf(),
            tmp,
            cols,
            chunk_rows,
            buf: Vec::with_capacity(chunk_rows * cols),
            rows: 0,
        })
    }

    /// Append one row (must have exactly `cols` values).
    pub fn push_row(&mut self, row: &[f64]) -> Result<()> {
        if row.len() != self.cols {
            return Err(anyhow!(
                "row has {} values, store expects {}",
                row.len(),
                self.cols
            ));
        }
        self.buf.extend_from_slice(row);
        self.rows += 1;
        if self.buf.len() == self.chunk_rows * self.cols {
            self.flush_chunk()?;
        }
        Ok(())
    }

    /// Append every row of a matrix.
    pub fn push_mat(&mut self, m: &Mat) -> Result<()> {
        for r in 0..m.rows {
            self.push_row(m.row(r))?;
        }
        Ok(())
    }

    fn flush_chunk(&mut self) -> Result<()> {
        let r = self.buf.len() / self.cols;
        if r == 0 {
            return Ok(());
        }
        let payload = encode_chunk(&self.buf, r, self.cols);
        let crc = fnv1a64(&payload);
        let out = match self.out.as_mut() {
            Some(o) => o,
            None => return Err(anyhow!("store writer already finished")),
        };
        out.write_all(&crc.to_le_bytes())
            .and_then(|()| out.write_all(&payload))
            .with_context(|| format!("writing {}", self.tmp.display()))?;
        self.buf.clear();
        Ok(())
    }

    /// Flush the tail chunk, patch the header with the final row count,
    /// and atomically rename `.tmp` into place. Returns the row count.
    pub fn finish(mut self) -> Result<u64> {
        self.flush_chunk()?;
        let out = match self.out.take() {
            Some(o) => o,
            None => return Err(anyhow!("store writer already finished")),
        };
        let mut file = out
            .into_inner()
            .map_err(|e| anyhow!("flushing {}: {}", self.tmp.display(), e.error()))?;
        file.seek(SeekFrom::Start(0))
            .and_then(|_| {
                file.write_all(&header_bytes(
                    self.rows,
                    self.cols as u64,
                    self.chunk_rows as u64,
                ))
            })
            .with_context(|| format!("patching header of {}", self.tmp.display()))?;
        drop(file);
        std::fs::rename(&self.tmp, &self.path).with_context(|| {
            format!("renaming {} -> {}", self.tmp.display(), self.path.display())
        })?;
        Ok(self.rows)
    }
}

impl Drop for StoreWriter {
    fn drop(&mut self) {
        // finish() took `out`, so a remaining writer means an abandoned
        // import — don't leave a half-written .tmp behind
        if self.out.take().is_some() {
            let _ = std::fs::remove_file(&self.tmp);
        }
    }
}

/// Seek-based chunk reader; implements [`ShardSource`], so a store
/// streams straight into Merge & Reduce (`Session::fit`/`coreset` via
/// `dataset=store:/path`) one chunk at a time.
pub struct StoreReader {
    file: File,
    path: String,
    rows: u64,
    cols: usize,
    chunk_rows: usize,
    next_chunk: u64,
}

impl StoreReader {
    /// Open and validate a store file (magic, header checksum, exact
    /// file length — a truncated file is rejected here, not mid-read).
    pub fn open(path: &Path) -> Result<Self> {
        let mut file =
            File::open(path).with_context(|| format!("opening {}", path.display()))?;
        let mut h = [0u8; 48];
        file.read_exact(&mut h)
            .map_err(|e| match e.kind() {
                std::io::ErrorKind::UnexpectedEof => {
                    anyhow!("{}: truncated store header", path.display())
                }
                _ => anyhow!("{}: reading header: {e}", path.display()),
            })?;
        if &h[0..16] != MAGIC {
            return Err(anyhow!("{}: not a mctm store file (bad magic)", path.display()));
        }
        let stored_crc = u64::from_le_bytes([
            h[40], h[41], h[42], h[43], h[44], h[45], h[46], h[47],
        ]);
        if fnv1a64(&h[0..40]) != stored_crc {
            return Err(anyhow!("{}: header checksum mismatch", path.display()));
        }
        let rows = u64::from_le_bytes([h[16], h[17], h[18], h[19], h[20], h[21], h[22], h[23]]);
        let cols = u64::from_le_bytes([h[24], h[25], h[26], h[27], h[28], h[29], h[30], h[31]]);
        let chunk_rows =
            u64::from_le_bytes([h[32], h[33], h[34], h[35], h[36], h[37], h[38], h[39]]);
        if cols == 0 || chunk_rows == 0 {
            return Err(anyhow!("{}: corrupt header (zero cols/chunk_rows)", path.display()));
        }
        let n_chunks = rows.div_ceil(chunk_rows);
        let expected = HEADER_LEN + n_chunks * 8 + rows * cols * 8;
        let actual = file
            .metadata()
            .with_context(|| format!("stat {}", path.display()))?
            .len();
        if actual != expected {
            return Err(anyhow!(
                "{}: store file truncated or padded: expected {expected} bytes, found {actual}",
                path.display()
            ));
        }
        Ok(StoreReader {
            file,
            path: path.display().to_string(),
            rows,
            cols: cols as usize,
            chunk_rows: chunk_rows as usize,
            next_chunk: 0,
        })
    }

    /// Total rows in the store.
    pub fn rows(&self) -> u64 {
        self.rows
    }

    /// Columns per row.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Rows per full chunk.
    pub fn chunk_rows(&self) -> usize {
        self.chunk_rows
    }

    /// Number of chunks (the last may be partial).
    pub fn n_chunks(&self) -> u64 {
        self.rows.div_ceil(self.chunk_rows as u64)
    }

    /// Rewind to the first chunk (a reader is reusable across fits).
    pub fn reset(&mut self) {
        self.next_chunk = 0;
    }

    fn read_chunk(&mut self, c: u64) -> Result<Mat, ShardError> {
        let stride = 8 + (self.chunk_rows * self.cols * 8) as u64;
        let offset = HEADER_LEN + c * stride;
        let r = (self.rows - c * self.chunk_rows as u64).min(self.chunk_rows as u64) as usize;
        let payload_len = r * self.cols * 8;
        let io_err = |what: &str, e: std::io::Error, path: &str| match e.kind() {
            // a short file is permanent corruption, not a flaky read
            std::io::ErrorKind::UnexpectedEof => ShardError::Fatal(format!(
                "{path}: store file truncated reading chunk {c} {what}"
            )),
            _ => ShardError::Transient(format!("{path}: chunk {c} {what}: {e}")),
        };
        self.file
            .seek(SeekFrom::Start(offset))
            .map_err(|e| io_err("seek", e, &self.path))?;
        let mut crc_bytes = [0u8; 8];
        self.file
            .read_exact(&mut crc_bytes)
            .map_err(|e| io_err("header", e, &self.path))?;
        let mut payload = vec![0u8; payload_len];
        self.file
            .read_exact(&mut payload)
            .map_err(|e| io_err("payload", e, &self.path))?;
        let stored = u64::from_le_bytes(crc_bytes);
        let computed = fnv1a64(&payload);
        if stored != computed {
            return Err(ShardError::Fatal(format!(
                "{}: chunk {c} checksum mismatch (stored {stored:016x}, computed {computed:016x})",
                self.path
            )));
        }
        // decode column-major payload into a row-major Mat
        let mut data = vec![0.0f64; r * self.cols];
        for col in 0..self.cols {
            for row in 0..r {
                let o = (col * r + row) * 8;
                let bits = u64::from_le_bytes([
                    payload[o],
                    payload[o + 1],
                    payload[o + 2],
                    payload[o + 3],
                    payload[o + 4],
                    payload[o + 5],
                    payload[o + 6],
                    payload[o + 7],
                ]);
                data[row * self.cols + col] = f64::from_bits(bits);
            }
        }
        Ok(Mat::from_vec(r, self.cols, data))
    }
}

impl ShardSource for StoreReader {
    fn next_shard(&mut self) -> Result<Option<Mat>, ShardError> {
        if self.next_chunk >= self.n_chunks() {
            return Ok(None);
        }
        let c = self.next_chunk;
        let m = self.read_chunk(c)?;
        // only a successful read consumes the chunk — a transient error
        // leaves the cursor in place so the producer's retry re-reads it
        self.next_chunk = c + 1;
        Ok(Some(m))
    }

    fn dim(&self) -> usize {
        self.cols
    }
}

/// Materialize a whole store in memory (the batch `dataset=store:` path;
/// streaming fits should use [`StoreReader`] directly).
pub fn read_all(path: &Path) -> Result<Mat> {
    let mut reader = StoreReader::open(path)?;
    let cols = reader.cols();
    let mut data: Vec<f64> = Vec::with_capacity(reader.rows() as usize * cols);
    let mut rows = 0usize;
    loop {
        match reader.next_shard() {
            Ok(Some(m)) => {
                rows += m.rows;
                data.extend_from_slice(&m.data);
            }
            Ok(None) => break,
            Err(e) => return Err(anyhow!("{}: {e}", path.display())),
        }
    }
    Ok(Mat::from_vec(rows, cols, data))
}

/// Convert a CSV file (same dialect as `dataset=file:` — see
/// [`csv`]) to a store in one bounded-memory pass: one line and one
/// chunk live at a time, never the whole matrix. Returns (rows, cols).
pub fn import_csv(src: &Path, out: &Path, chunk_rows: usize) -> Result<(u64, usize)> {
    let file =
        File::open(src).with_context(|| format!("reading {}", src.display()))?;
    let reader = BufReader::new(file);
    let mut writer: Option<StoreWriter> = None;
    let mut ncol: Option<usize> = None;
    for (lineno, line) in reader.lines().enumerate() {
        let line = line.with_context(|| format!("reading {}", src.display()))?;
        let parsed = csv::parse_line(&line, lineno)
            .with_context(|| format!("parsing {}", src.display()))?;
        let vals = match parsed {
            csv::ParsedLine::Skip => continue,
            // non-numeric first line with no data yet — header, skip
            csv::ParsedLine::Bad { .. } if ncol.is_none() && lineno == 0 => continue,
            csv::ParsedLine::Bad { col, token, reason } => {
                return Err(anyhow!(
                    "line {}, column {}: `{token}`: {reason}",
                    lineno + 1,
                    col + 1
                ))
                .with_context(|| format!("parsing {}", src.display()))
            }
            csv::ParsedLine::Row(vals) => vals,
        };
        match ncol {
            None => ncol = Some(vals.len()),
            Some(c) if c != vals.len() => {
                return Err(anyhow!(
                    "line {}: {} columns, expected {c}",
                    lineno + 1,
                    vals.len()
                ))
                .with_context(|| format!("parsing {}", src.display()))
            }
            _ => {}
        }
        let w = match &mut writer {
            Some(w) => w,
            None => {
                let cols = vals.len();
                writer = Some(StoreWriter::create(out, cols, chunk_rows)?);
                match &mut writer {
                    Some(w) => w,
                    None => unreachable!("just created"),
                }
            }
        };
        w.push_row(&vals)?;
    }
    let (writer, cols) = match (writer, ncol) {
        (Some(w), Some(c)) => (w, c),
        _ => {
            return Err(anyhow!("no numeric rows found"))
                .with_context(|| format!("parsing {}", src.display()))
        }
    };
    let rows = writer.finish()?;
    Ok((rows, cols))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("mctm_store_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    fn random_mat(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let mut data = Vec::with_capacity(rows * cols);
        for _ in 0..rows * cols {
            data.push(rng.normal());
        }
        Mat::from_vec(rows, cols, data)
    }

    fn write_store(m: &Mat, path: &Path, chunk_rows: usize) {
        let mut w = StoreWriter::create(path, m.cols, chunk_rows).unwrap();
        w.push_mat(m).unwrap();
        assert_eq!(w.finish().unwrap(), m.rows as u64);
    }

    #[test]
    fn round_trip_is_bitwise() {
        let dir = tmp_dir("roundtrip");
        let path = dir.join("a.store");
        let m = random_mat(23, 3, 7); // 23 rows, chunk 8 → partial tail
        write_store(&m, &path, 8);
        let back = read_all(&path).unwrap();
        assert_eq!((back.rows, back.cols), (m.rows, m.cols));
        for (a, b) in m.data.iter().zip(back.data.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn round_trip_preserves_special_bit_patterns() {
        let dir = tmp_dir("bits");
        let path = dir.join("b.store");
        // −0.0, subnormals and exact extremes must survive exactly
        let m = Mat::from_vec(
            3,
            2,
            vec![
                -0.0,
                f64::MIN_POSITIVE / 2.0, // subnormal
                f64::MAX,
                f64::MIN,
                1.0e-308,
                -1.0e-308,
            ],
        );
        write_store(&m, &path, 2);
        let back = read_all(&path).unwrap();
        for (a, b) in m.data.iter().zip(back.data.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(back.data[0].to_bits(), (-0.0f64).to_bits());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn reader_shards_match_chunk_geometry() {
        let dir = tmp_dir("geometry");
        let path = dir.join("c.store");
        let m = random_mat(10, 2, 3);
        write_store(&m, &path, 4);
        let mut r = StoreReader::open(&path).unwrap();
        assert_eq!((r.rows(), r.cols(), r.chunk_rows(), r.n_chunks()), (10, 2, 4, 3));
        let sizes: Vec<usize> =
            std::iter::from_fn(|| r.next_shard().unwrap().map(|s| s.rows)).collect();
        assert_eq!(sizes, vec![4, 4, 2]);
        r.reset();
        assert_eq!(r.next_shard().unwrap().unwrap().rows, 4);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn truncated_file_is_typed_open_error() {
        let dir = tmp_dir("trunc");
        let path = dir.join("d.store");
        write_store(&random_mat(9, 2, 5), &path, 4);
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 5]).unwrap();
        let e = format!("{:#}", StoreReader::open(&path).unwrap_err());
        assert!(e.contains("truncated"), "{e}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn bit_flip_in_chunk_is_fatal_checksum_error() {
        let dir = tmp_dir("flip");
        let path = dir.join("e.store");
        write_store(&random_mat(9, 2, 5), &path, 4);
        let mut bytes = std::fs::read(&path).unwrap();
        let n = bytes.len();
        bytes[n - 1] ^= 0x40; // flip a payload bit in the last chunk
        std::fs::write(&path, &bytes).unwrap();
        let mut r = StoreReader::open(&path).unwrap();
        assert!(r.next_shard().is_ok()); // chunk 0 intact
        assert!(r.next_shard().is_ok()); // chunk 1 intact
        match r.next_shard() {
            Err(ShardError::Fatal(m)) => {
                assert!(m.contains("checksum"), "{m}");
                assert!(m.contains("chunk 2"), "{m}");
            }
            other => panic!("expected fatal checksum error, got {other:?}"),
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn bit_flip_in_header_rejected_at_open() {
        let dir = tmp_dir("hdr");
        let path = dir.join("f.store");
        write_store(&random_mat(4, 2, 5), &path, 4);
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[17] ^= 0x01; // corrupt the row count
        std::fs::write(&path, &bytes).unwrap();
        let e = format!("{:#}", StoreReader::open(&path).unwrap_err());
        assert!(e.contains("header checksum mismatch"), "{e}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn wrong_magic_rejected() {
        let dir = tmp_dir("magic");
        let path = dir.join("g.store");
        std::fs::write(&path, vec![0u8; 64]).unwrap();
        let e = format!("{:#}", StoreReader::open(&path).unwrap_err());
        assert!(e.contains("bad magic"), "{e}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn abandoned_writer_cleans_up_tmp() {
        let dir = tmp_dir("abandon");
        let path = dir.join("h.store");
        {
            let mut w = StoreWriter::create(&path, 2, 4).unwrap();
            w.push_row(&[1.0, 2.0]).unwrap();
            // dropped without finish()
        }
        assert!(!tmp_path(&path).exists(), "tmp file left behind");
        assert!(!path.exists(), "final file must not appear without finish()");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn finish_is_atomic_rename() {
        let dir = tmp_dir("atomic");
        let path = dir.join("i.store");
        write_store(&random_mat(4, 2, 1), &path, 4);
        assert!(path.exists());
        assert!(!tmp_path(&path).exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn import_csv_streams_and_round_trips() {
        let dir = tmp_dir("import");
        let csv_path = dir.join("in.csv");
        let store_path = dir.join("in.store");
        std::fs::write(
            &csv_path,
            "x,y\n# comment\n1.5,2\n-3,4.25\n\n5,6\n7,8\n9,10\n",
        )
        .unwrap();
        let (rows, cols) = import_csv(&csv_path, &store_path, 2).unwrap();
        assert_eq!((rows, cols), (5, 2));
        let back = read_all(&store_path).unwrap();
        let direct = csv::load_csv(&csv_path).unwrap();
        assert_eq!(back.data.len(), direct.data.len());
        for (a, b) in back.data.iter().zip(direct.data.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn import_csv_rejects_bad_input_with_position() {
        let dir = tmp_dir("import_bad");
        let csv_path = dir.join("bad.csv");
        let store_path = dir.join("bad.store");
        std::fs::write(&csv_path, "1,2\n3,oops\n").unwrap();
        let e = format!(
            "{:#}",
            import_csv(&csv_path, &store_path, 4).unwrap_err()
        );
        assert!(e.contains("line 2"), "{e}");
        assert!(e.contains("`oops`"), "{e}");
        assert!(!store_path.exists(), "no store on failed import");
        assert!(!tmp_path(&store_path).exists(), "no tmp on failed import");

        std::fs::write(&csv_path, "1,2\n3\n").unwrap();
        let e = format!(
            "{:#}",
            import_csv(&csv_path, &store_path, 4).unwrap_err()
        );
        assert!(e.contains("1 columns, expected 2"), "{e}");

        std::fs::write(&csv_path, "# nothing\n").unwrap();
        let e = format!(
            "{:#}",
            import_csv(&csv_path, &store_path, 4).unwrap_err()
        );
        assert!(e.contains("no numeric rows"), "{e}");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
