//! Workload generation: the paper's 14 two-dimensional simulation DGPs
//! (§E.1.1), the synthetic Covertype-like terrain generator and the
//! synthetic equity-return generator (§3.2 substitutions — DESIGN.md §5),
//! plus a shard-iterator used by the streaming coordinator, the
//! deterministic fault-injection adapter (`faulty`), the out-of-core
//! column store (`store`) and CSR sparse rows (`sparse`).

pub mod covertype;
pub mod csv;
pub mod dgp;
pub mod equity;
pub mod faulty;
pub mod sparse;
pub mod store;

use crate::util::degrade::DegradeSink;
use crate::linalg::Mat;
use std::fmt;

/// A shard-read failure. `Transient` errors are retried by the
/// streaming producer with a bounded, attempt-count backoff (no wall
/// clock, so retried runs stay bit-identical to fault-free runs);
/// `Fatal` errors — and transient errors that exhaust the retry
/// budget — shut the pipeline down orderly and surface as
/// `ApiError::Stream` with shard provenance.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ShardError {
    /// Retryable (e.g. a flaky read); the producer re-requests the
    /// same shard without consuming a sequence number.
    Transient(String),
    /// Not retryable; the stream is shut down.
    Fatal(String),
}

impl ShardError {
    pub fn message(&self) -> &str {
        match self {
            ShardError::Transient(m) | ShardError::Fatal(m) => m,
        }
    }
}

impl fmt::Display for ShardError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ShardError::Transient(m) => write!(f, "transient shard error: {m}"),
            ShardError::Fatal(m) => write!(f, "fatal shard error: {m}"),
        }
    }
}

impl std::error::Error for ShardError {}

/// A source of data shards for the streaming pipeline.
pub trait ShardSource {
    /// Next shard of raw rows: `Ok(Some(mat))` delivers a shard,
    /// `Ok(None)` ends the stream, `Err` reports a read failure
    /// (transient errors are retried by the consumer — see
    /// [`ShardError`]).
    fn next_shard(&mut self) -> Result<Option<Mat>, ShardError>;
    /// Output dimension J.
    fn dim(&self) -> usize;
}

// Boxed sources forward, so `api::SourceInput` can carry a type-erased
// stream and hand it to the pipeline's generic `run`.
impl<S: ShardSource + ?Sized> ShardSource for Box<S> {
    fn next_shard(&mut self) -> Result<Option<Mat>, ShardError> {
        (**self).next_shard()
    }

    fn dim(&self) -> usize {
        (**self).dim()
    }
}

/// What to do with non-finite (NaN/±inf) cells at ingestion.
///
/// Set via `SessionBuilder::on_invalid`; applied by the streaming
/// producer per shard (in sequence order, so scrubbing is deterministic
/// at any consumer count) and by the batch path before the design is
/// built. Every action is counted into the run's
/// [`Degradations`](crate::util::degrade::Degradations) record.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum InvalidPolicy {
    /// Reject the run with a typed error naming the first offending
    /// shard/row/column (the default — bad data never enters silently).
    #[default]
    Error,
    /// Zero out every row containing a non-finite cell (row count, and
    /// therefore `n_seen`, is preserved).
    MaskRow,
    /// Remove every row containing a non-finite cell.
    DropRow,
}

/// Scrub `data` in place per `policy`, recording into `sink`.
///
/// Returns `Err(row, col)` of the first offending cell under
/// [`InvalidPolicy::Error`]; otherwise the (possibly smaller) matrix.
/// Under `DropRow` the surviving rows keep their original order.
pub fn scrub_invalid(
    mut data: Mat,
    policy: InvalidPolicy,
    sink: &DegradeSink,
) -> Result<Mat, (usize, usize)> {
    let cols = data.cols;
    // fast path: scan first so clean data is never copied or rewritten
    let mut bad_rows: Vec<usize> = Vec::new();
    let mut bad_cells = 0usize;
    for r in 0..data.rows {
        let row = data.row(r);
        let cells = row.iter().filter(|x| !x.is_finite()).count();
        if cells > 0 {
            if policy == InvalidPolicy::Error {
                let col = row
                    .iter()
                    .position(|x| !x.is_finite())
                    .unwrap_or(0);
                return Err((r, col));
            }
            bad_rows.push(r);
            bad_cells += cells;
        }
    }
    if bad_rows.is_empty() {
        return Ok(data);
    }
    sink.invalid_cells(bad_cells);
    match policy {
        InvalidPolicy::Error => unreachable!("handled above"),
        InvalidPolicy::MaskRow => {
            for &r in &bad_rows {
                for c in 0..cols {
                    data.data[r * cols + c] = 0.0;
                }
            }
            sink.rows_masked(bad_rows.len());
            Ok(data)
        }
        InvalidPolicy::DropRow => {
            let mut bad = vec![false; data.rows];
            for &r in &bad_rows {
                bad[r] = true;
            }
            let keep: Vec<usize> = (0..data.rows).filter(|&r| !bad[r]).collect();
            sink.rows_dropped(bad_rows.len());
            Ok(data.select_rows(&keep))
        }
    }
}

/// Shard an in-memory matrix.
pub struct MatShards {
    data: Mat,
    shard: usize,
    pos: usize,
}

impl MatShards {
    pub fn new(data: Mat, shard: usize) -> Self {
        assert!(shard > 0);
        MatShards { data, shard, pos: 0 }
    }
}

impl ShardSource for MatShards {
    fn next_shard(&mut self) -> Result<Option<Mat>, ShardError> {
        if self.pos >= self.data.rows {
            return Ok(None);
        }
        let end = (self.pos + self.shard).min(self.data.rows);
        let idx: Vec<usize> = (self.pos..end).collect();
        self.pos = end;
        Ok(Some(self.data.select_rows(&idx)))
    }

    fn dim(&self) -> usize {
        self.data.cols
    }
}

/// Generator-backed shard source (shards produced on demand, nothing
/// materialized — the "data never fits in memory" path).
pub struct GenShards<F: FnMut(usize) -> Mat> {
    gen: F,
    j: usize,
    remaining: usize,
    shard: usize,
}

impl<F: FnMut(usize) -> Mat> GenShards<F> {
    pub fn new(gen: F, j: usize, total: usize, shard: usize) -> Self {
        GenShards { gen, j, remaining: total, shard }
    }
}

impl<F: FnMut(usize) -> Mat> ShardSource for GenShards<F> {
    fn next_shard(&mut self) -> Result<Option<Mat>, ShardError> {
        if self.remaining == 0 {
            return Ok(None);
        }
        let take = self.shard.min(self.remaining);
        self.remaining -= take;
        Ok(Some((self.gen)(take)))
    }

    fn dim(&self) -> usize {
        self.j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat_shards_cover_everything() {
        let data = Mat::from_vec(10, 2, (0..20).map(|x| x as f64).collect());
        let mut src = MatShards::new(data, 4);
        let mut total = 0;
        let mut shards = 0;
        while let Some(s) = src.next_shard().unwrap() {
            total += s.rows;
            shards += 1;
            assert_eq!(s.cols, 2);
        }
        assert_eq!(total, 10);
        assert_eq!(shards, 3); // 4 + 4 + 2
    }

    #[test]
    fn gen_shards_respect_total() {
        let mut src = GenShards::new(|n| Mat::zeros(n, 3), 3, 10, 3);
        let sizes: Vec<usize> =
            std::iter::from_fn(|| src.next_shard().unwrap().map(|s| s.rows)).collect();
        assert_eq!(sizes, vec![3, 3, 3, 1]);
    }

    fn dirty_mat() -> Mat {
        // row 1 has a NaN, row 3 has an inf + a NaN
        Mat::from_vec(
            4,
            2,
            vec![1.0, 2.0, f64::NAN, 3.0, 4.0, 5.0, f64::INFINITY, f64::NAN],
        )
    }

    #[test]
    fn scrub_error_reports_first_cell() {
        let sink = DegradeSink::new();
        let err = scrub_invalid(dirty_mat(), InvalidPolicy::Error, &sink).unwrap_err();
        assert_eq!(err, (1, 0));
        assert!(sink.snapshot().is_clean(), "error path records nothing");
    }

    #[test]
    fn scrub_mask_zeroes_rows_and_counts() {
        let sink = DegradeSink::new();
        let m = scrub_invalid(dirty_mat(), InvalidPolicy::MaskRow, &sink).unwrap();
        assert_eq!(m.rows, 4);
        assert_eq!(m.row(1), &[0.0, 0.0]);
        assert_eq!(m.row(3), &[0.0, 0.0]);
        assert_eq!(m.row(2), &[4.0, 5.0]);
        let d = sink.snapshot();
        assert_eq!((d.rows_masked, d.invalid_cells), (2, 3));
    }

    #[test]
    fn scrub_drop_removes_rows_in_order() {
        let sink = DegradeSink::new();
        let m = scrub_invalid(dirty_mat(), InvalidPolicy::DropRow, &sink).unwrap();
        assert_eq!(m.rows, 2);
        assert_eq!(m.row(0), &[1.0, 2.0]);
        assert_eq!(m.row(1), &[4.0, 5.0]);
        assert_eq!(sink.snapshot().rows_dropped, 2);
    }

    #[test]
    fn scrub_clean_is_identity() {
        let sink = DegradeSink::new();
        let m = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let out = scrub_invalid(m.clone(), InvalidPolicy::DropRow, &sink).unwrap();
        assert_eq!(out.data, m.data);
        assert!(sink.snapshot().is_clean());
    }
}
