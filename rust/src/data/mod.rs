//! Workload generation: the paper's 14 two-dimensional simulation DGPs
//! (§E.1.1), the synthetic Covertype-like terrain generator and the
//! synthetic equity-return generator (§3.2 substitutions — DESIGN.md §5),
//! plus a shard-iterator used by the streaming coordinator.

pub mod covertype;
pub mod csv;
pub mod dgp;
pub mod equity;

use crate::linalg::Mat;

/// A source of data shards for the streaming pipeline.
pub trait ShardSource {
    /// Next shard of raw rows, or None when exhausted.
    fn next_shard(&mut self) -> Option<Mat>;
    /// Output dimension J.
    fn dim(&self) -> usize;
}

// Boxed sources forward, so `api::SourceInput` can carry a type-erased
// stream and hand it to the pipeline's generic `run`.
impl<S: ShardSource + ?Sized> ShardSource for Box<S> {
    fn next_shard(&mut self) -> Option<Mat> {
        (**self).next_shard()
    }

    fn dim(&self) -> usize {
        (**self).dim()
    }
}

/// Shard an in-memory matrix.
pub struct MatShards {
    data: Mat,
    shard: usize,
    pos: usize,
}

impl MatShards {
    pub fn new(data: Mat, shard: usize) -> Self {
        assert!(shard > 0);
        MatShards { data, shard, pos: 0 }
    }
}

impl ShardSource for MatShards {
    fn next_shard(&mut self) -> Option<Mat> {
        if self.pos >= self.data.rows {
            return None;
        }
        let end = (self.pos + self.shard).min(self.data.rows);
        let idx: Vec<usize> = (self.pos..end).collect();
        self.pos = end;
        Some(self.data.select_rows(&idx))
    }

    fn dim(&self) -> usize {
        self.data.cols
    }
}

/// Generator-backed shard source (shards produced on demand, nothing
/// materialized — the "data never fits in memory" path).
pub struct GenShards<F: FnMut(usize) -> Mat> {
    gen: F,
    j: usize,
    remaining: usize,
    shard: usize,
}

impl<F: FnMut(usize) -> Mat> GenShards<F> {
    pub fn new(gen: F, j: usize, total: usize, shard: usize) -> Self {
        GenShards { gen, j, remaining: total, shard }
    }
}

impl<F: FnMut(usize) -> Mat> ShardSource for GenShards<F> {
    fn next_shard(&mut self) -> Option<Mat> {
        if self.remaining == 0 {
            return None;
        }
        let take = self.shard.min(self.remaining);
        self.remaining -= take;
        Some((self.gen)(take))
    }

    fn dim(&self) -> usize {
        self.j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mat_shards_cover_everything() {
        let data = Mat::from_vec(10, 2, (0..20).map(|x| x as f64).collect());
        let mut src = MatShards::new(data, 4);
        let mut total = 0;
        let mut shards = 0;
        while let Some(s) = src.next_shard() {
            total += s.rows;
            shards += 1;
            assert_eq!(s.cols, 2);
        }
        assert_eq!(total, 10);
        assert_eq!(shards, 3); // 4 + 4 + 2
    }

    #[test]
    fn gen_shards_respect_total() {
        let mut src = GenShards::new(|n| Mat::zeros(n, 3), 3, 10, 3);
        let sizes: Vec<usize> = std::iter::from_fn(|| src.next_shard().map(|s| s.rows)).collect();
        assert_eq!(sizes, vec![3, 3, 3, 1]);
    }
}
