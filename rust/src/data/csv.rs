//! Minimal CSV loader so the launcher can run on user-supplied data
//! (`dataset=file:/path/to.csv`): numeric columns, optional header,
//! comma/semicolon/tab separated. Not a general CSV parser — quoted
//! fields are not supported (numeric matrices never need them).
//!
//! Malformed input is rejected with line- and column-numbered errors
//! (both 1-based): ragged rows, non-numeric tokens, and non-finite
//! tokens (`NaN`/`inf` parse as valid `f64` but are never valid
//! observations — file data is validated strictly at parse time, so
//! the session's `InvalidPolicy` only ever concerns in-memory and
//! generated sources).

use crate::linalg::Mat;
use crate::anyhow;
use crate::util::error::{Context, Result};
use std::path::Path;

/// Load a numeric matrix from a delimited text file. A first line that
/// fails to parse as numbers is treated as a header and skipped.
pub fn load_csv(path: &Path) -> Result<Mat> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse_csv(&text).with_context(|| format!("parsing {}", path.display()))
}

/// One parsed line of delimited numeric text — shared between the
/// whole-file parser below and the bounded-memory streaming importer
/// (`data::store::import_csv`), so both accept the exact same dialect.
pub(crate) enum ParsedLine {
    /// Blank, comment, or separator-only line — nothing to do.
    Skip,
    /// A numeric row.
    Row(Vec<f64>),
    /// A non-numeric token; callers decide header-vs-error (a bad first
    /// line with no data yet is a header, anywhere else it's an error).
    Bad { col: usize, token: String, reason: String },
}

/// Parse one line. Non-finite tokens are rejected here with a
/// line/column-numbered error (both 1-based, hence `lineno`): they are
/// data missingness, never a header, so no caller policy applies.
pub(crate) fn parse_line(line: &str, lineno: usize) -> Result<ParsedLine> {
    let line = line.trim();
    if line.is_empty() || line.starts_with('#') {
        return Ok(ParsedLine::Skip);
    }
    let fields: Vec<&str> = line
        .split(|c| c == ',' || c == ';' || c == '\t')
        .map(|f| f.trim())
        .filter(|f| !f.is_empty())
        .collect();
    let mut vals: Vec<f64> = Vec::with_capacity(fields.len());
    for (col, f) in fields.iter().enumerate() {
        match f.parse::<f64>() {
            Ok(v) if v.is_finite() => vals.push(v),
            // "nan"/"inf" parse as f64 but are rejected here: a
            // non-finite token is data missingness, not a header
            Ok(_) => {
                return Err(anyhow!(
                    "line {}, column {}: non-finite value `{f}`",
                    lineno + 1,
                    col + 1
                ))
            }
            Err(e) => {
                return Ok(ParsedLine::Bad {
                    col,
                    token: (*f).to_string(),
                    reason: e.to_string(),
                })
            }
        }
    }
    if vals.is_empty() {
        return Ok(ParsedLine::Skip);
    }
    Ok(ParsedLine::Row(vals))
}

/// Parse delimited numeric text into a matrix.
pub fn parse_csv(text: &str) -> Result<Mat> {
    let mut rows: Vec<Vec<f64>> = Vec::new();
    let mut ncol = None;
    for (lineno, line) in text.lines().enumerate() {
        let vals = match parse_line(line, lineno)? {
            ParsedLine::Skip => continue,
            // non-numeric first line with no data yet — header, skip
            ParsedLine::Bad { .. } if rows.is_empty() && lineno == 0 => continue,
            ParsedLine::Bad { col, token, reason } => {
                return Err(anyhow!(
                    "line {}, column {}: `{token}`: {reason}",
                    lineno + 1,
                    col + 1
                ))
            }
            ParsedLine::Row(vals) => vals,
        };
        match ncol {
            None => ncol = Some(vals.len()),
            Some(c) if c != vals.len() => {
                return Err(anyhow!(
                    "line {}: {} columns, expected {c}",
                    lineno + 1,
                    vals.len()
                ))
            }
            _ => {}
        }
        rows.push(vals);
    }
    if rows.is_empty() {
        return Err(anyhow!("no numeric rows found"));
    }
    Ok(Mat::from_rows(&rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_with_header() {
        let m = parse_csv("a,b\n1,2\n3.5,-4\n").unwrap();
        assert_eq!((m.rows, m.cols), (2, 2));
        assert_eq!(m.at(1, 0), 3.5);
        assert_eq!(m.at(1, 1), -4.0);
    }

    #[test]
    fn parses_without_header_and_tabs() {
        let m = parse_csv("1\t2\t3\n4\t5\t6\n").unwrap();
        assert_eq!((m.rows, m.cols), (2, 3));
    }

    #[test]
    fn skips_comments_and_blank_lines() {
        let m = parse_csv("# comment\n\n1,2\n# another\n3,4\n").unwrap();
        assert_eq!(m.rows, 2);
    }

    #[test]
    fn rejects_ragged_rows_with_line_number() {
        let e = format!("{:#}", parse_csv("1,2\n3\n").unwrap_err());
        assert!(e.contains("line 2"), "{e}");
        assert!(e.contains("1 columns, expected 2"), "{e}");
    }

    #[test]
    fn rejects_mid_file_garbage_with_position() {
        let e = format!("{:#}", parse_csv("1,2\n3,y\n").unwrap_err());
        assert!(e.contains("line 2"), "{e}");
        assert!(e.contains("column 2"), "{e}");
        assert!(e.contains("`y`"), "{e}");
    }

    #[test]
    fn rejects_non_finite_tokens_with_position() {
        for (text, line, col) in [
            ("1,2\nNaN,4\n", 2, 1),
            ("1,2\n3,inf\n", 2, 2),
            ("1,-inf\n", 1, 2),
            // even on the first line: non-finite is data, not a header
            ("nan,2\n3,4\n", 1, 1),
        ] {
            let e = format!("{:#}", parse_csv(text).unwrap_err());
            assert!(e.contains("non-finite"), "{text:?}: {e}");
            assert!(e.contains(&format!("line {line}")), "{text:?}: {e}");
            assert!(e.contains(&format!("column {col}")), "{text:?}: {e}");
        }
    }

    #[test]
    fn rejects_empty() {
        assert!(parse_csv("# nothing\n").is_err());
    }
}
