//! Minimal CSV loader so the launcher can run on user-supplied data
//! (`dataset=file:/path/to.csv`): numeric columns, optional header,
//! comma/semicolon/tab separated. Not a general CSV parser — quoted
//! fields are not supported (numeric matrices never need them).

use crate::linalg::Mat;
use crate::anyhow;
use crate::util::error::{Context, Result};
use std::path::Path;

/// Load a numeric matrix from a delimited text file. A first line that
/// fails to parse as numbers is treated as a header and skipped.
pub fn load_csv(path: &Path) -> Result<Mat> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse_csv(&text).with_context(|| format!("parsing {}", path.display()))
}

/// Parse delimited numeric text into a matrix.
pub fn parse_csv(text: &str) -> Result<Mat> {
    let mut rows: Vec<Vec<f64>> = Vec::new();
    let mut ncol = None;
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = line
            .split(|c| c == ',' || c == ';' || c == '\t')
            .map(|f| f.trim())
            .filter(|f| !f.is_empty())
            .collect();
        let parsed: std::result::Result<Vec<f64>, _> =
            fields.iter().map(|f| f.parse::<f64>()).collect();
        match parsed {
            Ok(vals) => {
                if vals.is_empty() {
                    continue;
                }
                match ncol {
                    None => ncol = Some(vals.len()),
                    Some(c) if c != vals.len() => {
                        return Err(anyhow!(
                            "line {}: {} columns, expected {c}",
                            lineno + 1,
                            vals.len()
                        ))
                    }
                    _ => {}
                }
                rows.push(vals);
            }
            Err(_) if rows.is_empty() && lineno == 0 => {
                // header line — skip
            }
            Err(e) => {
                return Err(anyhow!("line {}: {e}", lineno + 1));
            }
        }
    }
    if rows.is_empty() {
        return Err(anyhow!("no numeric rows found"));
    }
    Ok(Mat::from_rows(&rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_with_header() {
        let m = parse_csv("a,b\n1,2\n3.5,-4\n").unwrap();
        assert_eq!((m.rows, m.cols), (2, 2));
        assert_eq!(m.at(1, 0), 3.5);
        assert_eq!(m.at(1, 1), -4.0);
    }

    #[test]
    fn parses_without_header_and_tabs() {
        let m = parse_csv("1\t2\t3\n4\t5\t6\n").unwrap();
        assert_eq!((m.rows, m.cols), (2, 3));
    }

    #[test]
    fn skips_comments_and_blank_lines() {
        let m = parse_csv("# comment\n\n1,2\n# another\n3,4\n").unwrap();
        assert_eq!(m.rows, 2);
    }

    #[test]
    fn rejects_ragged_rows() {
        assert!(parse_csv("1,2\n3\n").is_err());
    }

    #[test]
    fn rejects_mid_file_garbage() {
        assert!(parse_csv("1,2\nx,y\n").is_err());
    }

    #[test]
    fn rejects_empty() {
        assert!(parse_csv("# nothing\n").is_err());
    }
}
