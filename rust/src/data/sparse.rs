//! CSR sparse rows for one-hot-heavy covariate blocks.
//!
//! Covertype-like designs are mostly one-hot: 10 continuous terrain
//! columns plus 44 indicator columns, ~12 non-zeros out of 54 per row.
//! [`SparseMat`] stores such matrices in compressed sparse row form so
//! Gram accumulation and leverage scoring run at O(nnz) gather cost
//! instead of O(n·d) — see `coreset::leverage::sparse_leverage_scores`,
//! which gathers rows into the existing dense `syrk_upper_rows4` /
//! `linv_quad_form` kernels and is **bitwise-identical** to densifying
//! first (same kernels, same FP order, only the zero-skipping gather
//! differs — and gathering writes the same `f64` bits a dense row holds).
//!
//! Conversions are exact: [`from_dense`](SparseMat::from_dense) drops
//! only cells whose bit pattern is exactly `+0.0` (a stored `-0.0` is a
//! real value and is kept), so `from_dense → to_dense` is lossless down
//! to the bit level.

use crate::linalg::Mat;

/// A CSR (compressed sparse row) matrix of `f64` values.
///
/// `indptr` has `rows + 1` entries; row `r`'s non-zeros are
/// `indices[indptr[r]..indptr[r+1]]` (strictly ascending column ids) and
/// `values[indptr[r]..indptr[r+1]]`.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseMat {
    /// Number of rows.
    pub rows: usize,
    /// Number of columns (logical width).
    pub cols: usize,
    /// Row pointers, `rows + 1` entries.
    pub indptr: Vec<usize>,
    /// Column indices, strictly ascending within each row.
    pub indices: Vec<usize>,
    /// Non-zero values, parallel to `indices`.
    pub values: Vec<f64>,
}

impl SparseMat {
    /// An empty matrix with `cols` columns and no rows yet.
    pub fn new(cols: usize) -> Self {
        SparseMat { rows: 0, cols, indptr: vec![0], indices: Vec::new(), values: Vec::new() }
    }

    /// Append one row given as `(column, value)` pairs in strictly
    /// ascending column order.
    pub fn push_row(&mut self, entries: &[(usize, f64)]) {
        let mut last: Option<usize> = None;
        for &(c, v) in entries {
            assert!(c < self.cols, "column {c} out of range (cols = {})", self.cols);
            if let Some(p) = last {
                assert!(c > p, "columns must be strictly ascending ({p} then {c})");
            }
            last = Some(c);
            self.indices.push(c);
            self.values.push(v);
        }
        self.rows += 1;
        self.indptr.push(self.indices.len());
    }

    /// Compress a dense matrix, dropping only cells whose bit pattern is
    /// exactly `+0.0` (so `-0.0` survives and
    /// [`to_dense`](Self::to_dense) is bitwise-lossless).
    pub fn from_dense(m: &Mat) -> Self {
        let mut s = SparseMat::new(m.cols);
        s.indptr.reserve(m.rows);
        for r in 0..m.rows {
            let row = m.row(r);
            for (c, &v) in row.iter().enumerate() {
                if v.to_bits() != 0 {
                    s.indices.push(c);
                    s.values.push(v);
                }
            }
            s.rows += 1;
            s.indptr.push(s.indices.len());
        }
        s
    }

    /// Expand back to a dense matrix (absent cells become `+0.0`).
    pub fn to_dense(&self) -> Mat {
        let mut m = Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let (idx, val) = self.row(r);
            for (&c, &v) in idx.iter().zip(val.iter()) {
                m.data[r * self.cols + c] = v;
            }
        }
        m
    }

    /// Number of stored non-zeros.
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    /// Stored fraction: `nnz / (rows · cols)` (1.0 for an empty matrix).
    pub fn density(&self) -> f64 {
        if self.rows == 0 || self.cols == 0 {
            return 1.0;
        }
        self.nnz() as f64 / (self.rows as f64 * self.cols as f64)
    }

    /// Row `r` as parallel `(indices, values)` slices.
    pub fn row(&self, r: usize) -> (&[usize], &[f64]) {
        let (lo, hi) = (self.indptr[r], self.indptr[r + 1]);
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// Scatter row `r` into a dense buffer (`out.len() == cols`),
    /// zero-filling first. The gathered row is bitwise-identical to the
    /// dense row it came from (up to dropped `+0.0` cells), which is
    /// what makes sparse scoring bit-compatible with the dense kernels.
    pub fn gather_row_into(&self, r: usize, out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.cols);
        out.fill(0.0);
        let (idx, val) = self.row(r);
        for (&c, &v) in idx.iter().zip(val.iter()) {
            out[c] = v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_dense_round_trips_bitwise() {
        // includes -0.0 (kept) and +0.0 (dropped) and a subnormal
        let m = Mat::from_vec(
            3,
            4,
            vec![
                1.0, 0.0, -0.0, 2.5, //
                0.0, 0.0, 0.0, 0.0, //
                f64::MIN_POSITIVE / 4.0, -3.0, 0.0, 4.0,
            ],
        );
        let s = SparseMat::from_dense(&m);
        assert_eq!(s.nnz(), 6); // -0.0 kept, the five +0.0 dropped
        let back = s.to_dense();
        assert_eq!(back.data.len(), m.data.len());
        for (a, b) in m.data.iter().zip(back.data.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn push_row_matches_from_dense() {
        let mut s = SparseMat::new(3);
        s.push_row(&[(0, 1.0), (2, 2.0)]);
        s.push_row(&[]);
        s.push_row(&[(1, -4.5)]);
        let d = s.to_dense();
        let s2 = SparseMat::from_dense(&d);
        assert_eq!(s, s2);
        assert_eq!((s.rows, s.cols, s.nnz()), (3, 3, 3));
    }

    #[test]
    #[should_panic(expected = "strictly ascending")]
    fn push_row_rejects_unordered_columns() {
        let mut s = SparseMat::new(3);
        s.push_row(&[(2, 1.0), (1, 2.0)]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn push_row_rejects_out_of_range_column() {
        let mut s = SparseMat::new(3);
        s.push_row(&[(3, 1.0)]);
    }

    #[test]
    fn gather_row_matches_dense_row() {
        let m = Mat::from_vec(2, 3, vec![1.0, 0.0, 3.0, 0.0, 5.0, 0.0]);
        let s = SparseMat::from_dense(&m);
        let mut buf = vec![9.0; 3]; // stale garbage must be cleared
        s.gather_row_into(1, &mut buf);
        assert_eq!(buf, &[0.0, 5.0, 0.0]);
        s.gather_row_into(0, &mut buf);
        assert_eq!(buf, &[1.0, 0.0, 3.0]);
    }

    #[test]
    fn density_counts_stored_fraction() {
        let m = Mat::from_vec(2, 2, vec![1.0, 0.0, 0.0, 1.0]);
        let s = SparseMat::from_dense(&m);
        assert_eq!(s.density(), 0.5);
        assert_eq!(SparseMat::new(4).density(), 1.0);
    }
}
