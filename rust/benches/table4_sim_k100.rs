//! Regenerates paper Table 4: same protocol as Table 3 with k = 100.
fn main() {
    mctm_coreset::benchsupport::run_sim_table(
        "Table 4: simulation DGPs, coreset size 100",
        100,
        "table4_sim_k100.csv",
    );
}
