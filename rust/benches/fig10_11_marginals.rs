//! Regenerates paper Figures 10–11: predicted marginal densities of the
//! bivariate-normal DGP under coresets of size k ∈ {50, 100, 500} built
//! by each method, over 10 replicate trials, against the true N(0,1)
//! marginal.
//!
//! Each replicate is one facade run: `SessionBuilder` → `Session::fit`
//! → `FittedModel::marginal_density` — the same query surface library
//! users hit.

use mctm_coreset::benchsupport::{banner, bench_fit_options, results_dir, Scale};
use mctm_coreset::prelude::*;
use mctm_coreset::util::report::write_series_csv;
use mctm_coreset::util::special::norm_pdf;

fn main() {
    let scale = Scale::from_env();
    let n = scale.pick(1_000, 10_000, 10_000);
    let reps = scale.pick(2, 5, 10);
    let ks: Vec<usize> = match scale {
        Scale::Fast => vec![50, 100],
        _ => vec![50, 100, 500],
    };
    banner("fig10_11_marginals", &format!("bivariate normal, n={n}, reps={reps}"));

    let mut rng = Rng::new(1011);
    let data = Dgp::BivariateNormal.generate(n, &mut rng);
    let opts = bench_fit_options(scale);

    // density evaluation grid over both margins
    let grid: Vec<f64> = (0..81).map(|i| -4.0 + 0.1 * i as f64).collect();

    for margin in [0usize, 1] {
        let mut cols: Vec<(String, Vec<f64>)> = vec![
            ("y".to_string(), grid.clone()),
            (
                "true_density".to_string(),
                grid.iter().map(|&y| norm_pdf(y)).collect(),
            ),
        ];
        for &k in &ks {
            for method in [Method::Uniform, Method::L2Only, Method::L2Hull] {
                // mean predicted density over replicate coreset fits
                let mut acc = vec![0.0; grid.len()];
                for rep in 0..reps {
                    let session = SessionBuilder::new()
                        .method_tag(method)
                        .budget(k)
                        .basis_size(7)
                        .seed(2000 + rep as u64)
                        .fit_options(opts.clone())
                        .build()
                        .expect("valid bench session");
                    let model = session.fit(&data).expect("non-empty data");
                    for (gi, &y) in grid.iter().enumerate() {
                        acc[gi] += model.marginal_density(margin, y) / reps as f64;
                    }
                }
                cols.push((format!("{}_k{k}", method.name()), acc));
                println!("  margin {margin}: done {} k={k}", method.name());
            }
        }
        let named: Vec<(&str, &[f64])> =
            cols.iter().map(|(n, v)| (n.as_str(), v.as_slice())).collect();
        let fname = if margin == 0 {
            "fig10_marginal_x.csv"
        } else {
            "fig11_marginal_y.csv"
        };
        write_series_csv(&results_dir().join(fname), &named).expect("write csv");
    }
    println!("saved fig10/fig11 CSVs");
}
