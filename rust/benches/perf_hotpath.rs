//! §Perf micro-benchmarks of every hot path, native AND XLA backends:
//!   L3-a  leverage pipeline (basis build, Gram, scoring)
//!   L3-b  NLL + gradient evaluation (the optimizer inner loop)
//!   L3-c  convex-hull selection
//!   L1/L2 AOT artifacts: tiled nll_grad, fused nll_eval, gram, leverage
//! Results feed EXPERIMENTS.md §Perf (before/after iteration log).

use mctm_coreset::basis::Design;
use mctm_coreset::benchsupport::{banner, results_dir, time_median, Scale};
use mctm_coreset::coreset::hull::select_hull_points;
use mctm_coreset::coreset::leverage::mctm_leverage_scores;
use mctm_coreset::data::dgp::Dgp;
use mctm_coreset::linalg::{Cholesky, Mat};
use mctm_coreset::mctm::{self, ModelSpec, Params};
use mctm_coreset::runtime::{Engine, TiledNll};
use mctm_coreset::util::report::Table;
use mctm_coreset::util::rng::Rng;
use std::path::Path;

fn main() {
    let scale = Scale::from_env();
    let n = scale.pick(2_000, 20_000, 100_000);
    let iters = scale.pick(3, 5, 7);
    banner("perf_hotpath", &format!("n={n}, J=2 and J=10, median of {iters}"));

    let mut table = Table::new(
        "Perf: hot-path medians (seconds)",
        &["path", "config", "seconds", "throughput"],
    );

    // ---- L3: J=2 simulation-scale ------------------------------------
    let mut rng = Rng::new(1);
    let data2 = Dgp::BivariateNormal.generate(n, &mut rng);
    bench_native(&mut table, "J=2 d=7", &data2, iters);

    // ---- L3: J=10 covertype-scale ------------------------------------
    let data10 = mctm_coreset::data::covertype::generate(n / 2, &mut rng);
    bench_native(&mut table, "J=10 d=7", &data10, iters);

    // ---- L1/L2 via PJRT ----------------------------------------------
    if Path::new("artifacts/manifest.json").exists() {
        bench_xla(&mut table, &data2, 2, iters);
        bench_xla(&mut table, &data10, 10, iters);
    } else {
        println!("(artifacts/ missing — run `make artifacts` for the XLA rows)");
    }

    table.emit(Some(&results_dir().join("perf_hotpath.csv")));
}

fn bench_native(table: &mut Table, cfg: &str, data: &Mat, iters: usize) {
    let n = data.rows;
    let d = 7usize;

    // basis construction
    let t_design = time_median(iters, || {
        std::hint::black_box(Design::build(data, d, 0.01));
    });
    table.row(vec![
        "L3 basis build".into(),
        cfg.into(),
        format!("{t_design:.4}"),
        format!("{:.1} Mrow/s", n as f64 / t_design / 1e6),
    ]);

    let design = Design::build(data, d, 0.01);

    // leverage scores (Gram + Cholesky + scoring)
    let t_lev = time_median(iters, || {
        std::hint::black_box(mctm_leverage_scores(&design).unwrap());
    });
    table.row(vec![
        "L3 leverage scores".into(),
        cfg.into(),
        format!("{t_lev:.4}"),
        format!("{:.1} Mrow/s", n as f64 / t_lev / 1e6),
    ]);

    // Gram alone (the syrk kernel)
    let stacked = design.stacked();
    let t_gram = time_median(iters, || {
        std::hint::black_box(stacked.gram());
    });
    let dj = stacked.cols;
    let flops = n as f64 * (dj * dj) as f64; // ~2·n·D²/2
    table.row(vec![
        "L3 gram (syrk)".into(),
        cfg.into(),
        format!("{t_gram:.4}"),
        format!("{:.2} GF/s", flops / t_gram / 1e9),
    ]);

    // cholesky + scoring split
    let gram = stacked.gram();
    let mut gr = gram.clone();
    let stab = 1e-10 * gram.trace() / gram.rows as f64;
    for i in 0..gr.rows {
        *gr.at_mut(i, i) += stab;
    }
    let ch = Cholesky::new(&gr).unwrap();
    let t_score = time_median(iters, || {
        let mut scratch = Vec::new();
        let mut acc = 0.0;
        for i in 0..stacked.rows {
            acc += ch.quad_form_inv(stacked.row(i), &mut scratch);
        }
        std::hint::black_box(acc);
    });
    table.row(vec![
        "L3 leverage scoring".into(),
        cfg.into(),
        format!("{t_score:.4}"),
        format!("{:.1} Mrow/s", n as f64 / t_score / 1e6),
    ]);

    // NLL + grad (optimizer inner loop)
    let spec = ModelSpec::new(data.cols, d);
    let p = Params::init(spec);
    let t_nll = time_median(iters, || {
        std::hint::black_box(mctm::nll_grad(&design, &[], &p));
    });
    table.row(vec![
        "L3 nll_grad".into(),
        cfg.into(),
        format!("{t_nll:.4}"),
        format!("{:.1} Mrow/s", n as f64 / t_nll / 1e6),
    ]);

    // hull selection on the derivative points
    let dp = design.deriv_points();
    let mut rng = Rng::new(7);
    let t_hull = time_median(3.min(iters), || {
        std::hint::black_box(select_hull_points(&dp, 20, &mut rng));
    });
    table.row(vec![
        "L3 hull select k=20".into(),
        cfg.into(),
        format!("{t_hull:.4}"),
        format!("{:.2} Mpt/s", dp.rows as f64 / t_hull / 1e6),
    ]);
}

fn bench_xla(table: &mut Table, data: &Mat, j: usize, iters: usize) {
    let d = 7usize;
    let engine = match Engine::new(Path::new("artifacts")) {
        Ok(e) => e,
        Err(e) => {
            println!("xla engine unavailable: {e:#}");
            return;
        }
    };
    let cfg = format!("J={j} d={d} (xla)");
    let design = Design::build(data, d, 0.01);
    let scaled = design.scaler.transform(data);
    let spec = ModelSpec::new(j, d);
    let p = Params::init(spec);
    let runner = TiledNll::new(&engine, j, d).unwrap();

    let n = data.rows;
    let t_grad = time_median(iters, || {
        std::hint::black_box(runner.nll_grad(&p.x, &scaled.data, &[]).unwrap());
    });
    table.row(vec![
        "XLA nll_grad (tiled)".into(),
        cfg.clone(),
        format!("{t_grad:.4}"),
        format!("{:.1} Mrow/s", n as f64 / t_grad / 1e6),
    ]);

    let t_eval = time_median(iters, || {
        std::hint::black_box(runner.nll_eval(&p.x, &scaled.data, &[]).unwrap());
    });
    table.row(vec![
        "XLA nll_eval (pallas fused)".into(),
        cfg.clone(),
        format!("{t_eval:.4}"),
        format!("{:.1} Mrow/s", n as f64 / t_eval / 1e6),
    ]);

    // gram + leverage artifacts over the stacked matrix
    if let Ok(lev) = mctm_coreset::runtime::engine::TiledLeverage::new(&engine, j * d) {
        let stacked = design.stacked();
        let t_gram = time_median(iters, || {
            std::hint::black_box(lev.gram(&stacked.data).unwrap());
        });
        table.row(vec![
            "XLA gram (pallas tiled)".into(),
            cfg.clone(),
            format!("{t_gram:.4}"),
            format!("{:.1} Mrow/s", n as f64 / t_gram / 1e6),
        ]);
        let g = Mat::from_vec(j * d, j * d, lev.gram(&stacked.data).unwrap());
        let mut gr = g.clone();
        let stab = 1e-10 * g.trace() / g.rows as f64;
        for i in 0..gr.rows {
            *gr.at_mut(i, i) += stab;
        }
        let ch = Cholesky::new(&gr).unwrap();
        let linv = ch.l_inverse();
        let t_scores = time_median(iters, || {
            std::hint::black_box(lev.scores(&stacked.data, &linv.data).unwrap());
        });
        table.row(vec![
            "XLA leverage (pallas)".into(),
            cfg,
            format!("{t_scores:.4}"),
            format!("{:.1} Mrow/s", n as f64 / t_scores / 1e6),
        ]);
    }
}
