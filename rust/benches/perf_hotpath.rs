//! §Perf micro-benchmarks of every hot path, native AND XLA backends:
//!   L3-a  leverage pipeline (basis build, Gram, scoring)
//!   L3-b  NLL + gradient evaluation (the optimizer inner loop)
//!   L3-c  convex-hull selection + batched hull distances
//!   L4    John-ellipsoid rounding scans (§4 extension)
//!   L1/L2 AOT artifacts: tiled nll_grad, fused nll_eval, gram, leverage
//! Each parallel-ported path is timed at thread counts {1, 2, 4, max}
//! (serial-vs-parallel medians + scaling); `MCTM_THREADS` pins the max.
//! Results feed EXPERIMENTS.md §Perf (before/after iteration log).
//!
//! PR 8: the NLL sweep and the conditional path run once per kernel
//! backend (Scalar, and Simd where AVX2+FMA is detected), and setting
//! `MCTM_BENCH_JSON=<path>` additionally dumps those rows — plus the
//! serving-qps rows — as machine-readable JSON (`make bench-json`
//! writes BENCH_PR8.json at the repo root).

use mctm_coreset::basis::Design;
use mctm_coreset::benchsupport::{banner, results_dir, time_median, Scale};
use mctm_coreset::coreset::ellipsoid::ellipsoid_scores;
use mctm_coreset::coreset::hull::{dist_to_hull_batch, select_hull_points};
use mctm_coreset::coreset::leverage::mctm_leverage_scores;
use mctm_coreset::linalg::{simd, Cholesky};
use mctm_coreset::mctm;
use mctm_coreset::mctm::conditional::{
    cond_nll_grad_reference, cond_nll_grad_with, CondDesign, CondSpec,
};
use mctm_coreset::prelude::*;
use mctm_coreset::runtime::{Engine, TiledNll};
use mctm_coreset::util::parallel;
use mctm_coreset::util::report::{Json, Table};
use std::path::Path;

/// Accumulates the PR 8 machine-readable rows; dumped as JSON when
/// `MCTM_BENCH_JSON` names an output path, otherwise discarded.
struct JsonRows(Vec<Json>);

impl JsonRows {
    /// `throughput` is (value, unit), e.g. `(rows_per_s, "row/s")`.
    fn row(
        &mut self,
        kernel: &str,
        backend: &str,
        config: &str,
        threads: usize,
        median_s: f64,
        throughput: (f64, &str),
    ) {
        self.0.push(Json::Obj(vec![
            ("kernel".into(), Json::Str(kernel.into())),
            ("backend".into(), Json::Str(backend.into())),
            ("config".into(), Json::Str(config.into())),
            ("threads".into(), Json::Num(threads as f64)),
            ("median_s".into(), Json::Num(median_s)),
            ("throughput".into(), Json::Num(throughput.0)),
            ("unit".into(), Json::Str(throughput.1.into())),
        ]));
    }
}

/// The backends this host can run: Scalar always, Simd when detected.
fn backend_sweep() -> Vec<(KernelBackend, &'static str)> {
    let mut v = vec![(KernelBackend::Scalar, "scalar")];
    if simd_available() {
        v.push((KernelBackend::Simd, "simd"));
    }
    v
}

fn main() {
    let scale = Scale::from_env();
    let n = scale.pick(2_000, 20_000, 100_000);
    let iters = scale.pick(3, 5, 7);
    let max_threads = parallel::threads();
    banner(
        "perf_hotpath",
        &format!("n={n}, J=2 and J=10, median of {iters}, serial vs parallel"),
    );

    let mut table = Table::new(
        "Perf: hot-path medians (seconds), scaling over threads",
        &["path", "config", "threads", "seconds", "speedup", "throughput"],
    );

    // ---- L3: J=2 simulation-scale ------------------------------------
    let mut rng = Rng::new(1);
    let data2 = Dgp::BivariateNormal.generate(n, &mut rng);
    bench_native(&mut table, "J=2 d=7", &data2, iters, max_threads);

    // ---- L3: J=10 covertype-scale ------------------------------------
    let data10 = mctm_coreset::data::covertype::generate(n / 2, &mut rng);
    bench_native(&mut table, "J=10 d=7", &data10, iters, max_threads);

    // ---- L3-b: blocked-kernel sweep (ISSUE 5 / PR 8) -----------------
    // serial row-at-a-time reference vs the blocked plane-major kernel
    // per backend at threads {1, 2, 4, max}; shapes from simulation to
    // beyond covertype scale (the 50k/200k rows are where blocking and
    // the SIMD lanes must win)
    let mut json = JsonRows(Vec::new());
    bench_nll_sweep(&mut table, &mut json, scale, iters, max_threads);

    // ---- Conditional path: row-at-a-time vs panel kernels (PR 8) -----
    bench_conditional(&mut table, &mut json, scale, iters, max_threads);

    // ---- Serving layer: queries/sec over HTTP (ISSUE 7) --------------
    bench_serving(&mut table, &mut json, scale, max_threads);

    // ---- Out-of-core ingestion: store drain vs in-memory (PR 9) ------
    bench_store(&mut table, &mut json, scale, iters);

    // ---- Sparse leverage on one-hot designs (PR 9) -------------------
    bench_sparse_leverage(&mut table, &mut json, scale, iters, max_threads);

    // ---- L1/L2 via PJRT ----------------------------------------------
    if Path::new("artifacts/manifest.json").exists() {
        bench_xla(&mut table, &data2, 2, iters);
        bench_xla(&mut table, &data10, 10, iters);
    } else {
        println!("(artifacts/ missing — run `make artifacts` for the XLA rows)");
    }

    // leave the global pool at the benchmark's max for any later code
    parallel::set_threads(max_threads);
    table.emit(Some(&results_dir().join("perf_hotpath.csv")));

    if let Ok(path) = std::env::var("MCTM_BENCH_JSON") {
        let doc = Json::Obj(vec![
            ("bench".into(), Json::Str("perf_hotpath".into())),
            ("scale".into(), Json::Str(format!("{scale:?}").to_ascii_lowercase())),
            ("max_threads".into(), Json::Num(max_threads as f64)),
            ("simd_available".into(), Json::Str(simd_available().to_string())),
            ("rows".into(), Json::Arr(json.0)),
        ]);
        match doc.save(Path::new(&path)) {
            Ok(()) => println!("saved {path}"),
            Err(e) => eprintln!("warn: could not save {path}: {e}"),
        }
    }
}

/// Thread counts to sweep: 1, 2, 4, …, up to the configured max.
fn thread_sweep(max: usize) -> Vec<usize> {
    let mut v = vec![1usize, 2, 4, max];
    v.retain(|&t| t <= max);
    v.sort_unstable();
    v.dedup();
    v
}

/// Time `f` at each thread count and append one table row per count,
/// with speedup relative to the single-thread median.
fn bench_scaling<F: FnMut()>(
    table: &mut Table,
    path: &str,
    cfg: &str,
    iters: usize,
    max_threads: usize,
    throughput: impl Fn(f64) -> String,
    mut f: F,
) {
    let mut serial = f64::NAN;
    for &t in &thread_sweep(max_threads) {
        parallel::set_threads(t);
        let sec = time_median(iters, &mut f);
        if t == 1 {
            serial = sec;
        }
        table.row(vec![
            path.into(),
            cfg.into(),
            format!("{t}"),
            format!("{sec:.4}"),
            format!("{:.2}x", serial / sec),
            throughput(sec),
        ]);
    }
}

fn bench_native(table: &mut Table, cfg: &str, data: &Mat, iters: usize, max_threads: usize) {
    let n = data.rows;
    let d = 7usize;

    // basis construction
    bench_scaling(
        table,
        "L3 basis build",
        cfg,
        iters,
        max_threads,
        |s| format!("{:.1} Mrow/s", n as f64 / s / 1e6),
        || {
            std::hint::black_box(Design::build(data, d, 0.01));
        },
    );

    let design = Design::build(data, d, 0.01);

    // leverage pipeline (Gram + Cholesky + scoring)
    bench_scaling(
        table,
        "L3 leverage scores",
        cfg,
        iters,
        max_threads,
        |s| format!("{:.1} Mrow/s", n as f64 / s / 1e6),
        || {
            std::hint::black_box(mctm_leverage_scores(&design).unwrap());
        },
    );

    // Gram alone (the blocked syrk kernel)
    let stacked = design.stacked();
    let dj = stacked.cols;
    let flops = n as f64 * (dj * dj) as f64; // ~2·n·D²/2
    bench_scaling(
        table,
        "L3 gram (syrk)",
        cfg,
        iters,
        max_threads,
        |s| format!("{:.2} GF/s", flops / s / 1e9),
        || {
            std::hint::black_box(stacked.gram());
        },
    );

    // NLL + grad (optimizer inner loop)
    let spec = ModelSpec::new(data.cols, d);
    let p = Params::init(spec);
    bench_scaling(
        table,
        "L3 nll_grad",
        cfg,
        iters,
        max_threads,
        |s| format!("{:.1} Mrow/s", n as f64 / s / 1e6),
        || {
            std::hint::black_box(mctm::nll_grad(&design, &[], &p));
        },
    );

    // cholesky + scoring split (serial kernel — reference row)
    parallel::set_threads(1);
    let gram = stacked.gram();
    let mut gr = gram.clone();
    let stab = 1e-10 * gram.trace() / gram.rows as f64;
    for i in 0..gr.rows {
        *gr.at_mut(i, i) += stab;
    }
    let ch = Cholesky::new(&gr).unwrap();
    let t_score = time_median(iters, || {
        let mut scratch = Vec::new();
        let mut acc = 0.0;
        for i in 0..stacked.rows {
            acc += ch.quad_form_inv(stacked.row(i), &mut scratch);
        }
        std::hint::black_box(acc);
    });
    table.row(vec![
        "L3 scoring (quad_form ref)".into(),
        cfg.into(),
        "1".into(),
        format!("{t_score:.4}"),
        "1.00x".into(),
        format!("{:.1} Mrow/s", n as f64 / t_score / 1e6),
    ]);

    // hull selection on the derivative points (L3-c): the support-
    // direction prefilter and the greedy distance scans are row-parallel
    let dp = design.deriv_points();
    let hull_iters = 3.min(iters).max(1);
    bench_scaling(
        table,
        "L3 hull select k=20",
        cfg,
        hull_iters,
        max_threads,
        |s| format!("{:.2} Mpt/s", dp.rows as f64 / s / 1e6),
        || {
            // fresh RNG per call: every thread count times the IDENTICAL
            // selection problem, so the speedup column is pure scaling
            let mut rng = Rng::new(7);
            std::hint::black_box(select_hull_points(&dp, 20, &mut rng));
        },
    );

    // batched hull-distance queries against a fixed selected hull
    // (strided query subset keeps the serial rows affordable)
    let mut hull_rng = Rng::new(8);
    let hull20 = select_hull_points(&dp, 20, &mut hull_rng);
    let q_idx: Vec<usize> = (0..dp.rows).step_by(8).collect();
    let queries = dp.select_rows(&q_idx);
    bench_scaling(
        table,
        "L3 dist_to_hull_batch",
        cfg,
        hull_iters,
        max_threads,
        |s| format!("{:.2} Mq/s", queries.rows as f64 / s / 1e6),
        || {
            std::hint::black_box(dist_to_hull_batch(
                &dp,
                &hull20,
                &queries,
                &parallel::Pool::current(),
            ));
        },
    );

    // John-ellipsoid rounding (L4): per-iteration moment rebuild +
    // violator scan are row-parallel
    bench_scaling(
        table,
        "L4 ellipsoid scores",
        cfg,
        hull_iters,
        max_threads,
        |s| format!("{:.2} Mrow/s", n as f64 / s / 1e6),
        || {
            std::hint::black_box(ellipsoid_scores(data, 0.05));
        },
    );
    parallel::set_threads(max_threads);
}

/// ISSUE 5 / PR 8 sweep: `nll_grad` — the optimizer inner loop — as
/// serial row-at-a-time reference (`nll_grad_reference`) vs the
/// blocked plane-major kernel per backend at threads {1, 2, 4, max},
/// over (n, J, d) ∈ {(5k, 3, 8), (50k, 5, 8), (200k, 10, 8)}. The fast
/// (CI-smoke) scale runs only the smallest shape; the sweep feeds
/// EXPERIMENTS.md §Perf iterations 7 and 10.
fn bench_nll_sweep(
    table: &mut Table,
    json: &mut JsonRows,
    scale: Scale,
    iters: usize,
    max_threads: usize,
) {
    let ambient = simd::backend();
    let shapes: &[(usize, usize, usize)] = if scale == Scale::Fast {
        &[(5_000, 3, 8)]
    } else {
        &[(5_000, 3, 8), (50_000, 5, 8), (200_000, 10, 8)]
    };
    for &(n, j, d) in shapes {
        let mut rng = Rng::new(0xB10C + n as u64);
        let data = Mat::from_vec(n, j, (0..n * j).map(|_| rng.normal()).collect());
        let design = Design::build(&data, d, 0.01);
        let spec = ModelSpec::new(j, d);
        let p = Params::init(spec);
        let cfg = format!("n={n} J={j} d={d}");

        // serial row-at-a-time baseline (the pre-refactor kernel; does
        // not dispatch, so it is timed once per shape)
        parallel::set_threads(1);
        let t_ref = time_median(iters, || {
            std::hint::black_box(mctm::nll_grad_reference(&design, &[], &p));
        });
        table.row(vec![
            "L3 nll_grad rows (ref)".into(),
            cfg.clone(),
            "1".into(),
            format!("{t_ref:.4}"),
            "1.00x".into(),
            format!("{:.1} Mrow/s", n as f64 / t_ref / 1e6),
        ]);
        json.row("nll_grad_ref", "rows", &cfg, 1, t_ref, (n as f64 / t_ref, "row/s"));

        // blocked plane-major kernel per backend, thread sweep; speedup
        // column is relative to the row-at-a-time reference so the
        // single-thread rows isolate the blocking and SIMD wins from
        // the threading win
        for &(b, tag) in &backend_sweep() {
            simd::set_backend(b);
            for &t in &thread_sweep(max_threads) {
                parallel::set_threads(t);
                let sec = time_median(iters, || {
                    std::hint::black_box(mctm::nll_grad(&design, &[], &p));
                });
                table.row(vec![
                    format!("L3 nll_grad blocked/{tag}"),
                    cfg.clone(),
                    format!("{t}"),
                    format!("{sec:.4}"),
                    format!("{:.2}x", t_ref / sec),
                    format!("{:.1} Mrow/s", n as f64 / sec / 1e6),
                ]);
                json.row("nll_grad_blocked", tag, &cfg, t, sec, (n as f64 / sec, "row/s"));
            }
        }
        simd::set_backend(ambient);
    }
    parallel::set_threads(max_threads);
}

/// PR 8: the conditional objective — row-at-a-time reference
/// (`cond_nll_grad_reference`) vs the panel-kernel blocked engine, per
/// backend, at threads {1, 2, 4, max}. J = 2 response dimensions with a
/// q = 2 covariate shift, d = 8 basis functions.
fn bench_conditional(
    table: &mut Table,
    json: &mut JsonRows,
    scale: Scale,
    iters: usize,
    max_threads: usize,
) {
    let ambient = simd::backend();
    let shapes: &[usize] = if scale == Scale::Fast {
        &[5_000]
    } else {
        &[5_000, 50_000, 200_000]
    };
    let (j, d, q) = (2usize, 8usize, 2usize);
    let spec = CondSpec::new(j, d, q);
    for &n in shapes {
        let mut rng = Rng::new(0xC0ED + n as u64);
        let y = Mat::from_vec(n, j, (0..n * j).map(|_| rng.normal()).collect());
        let x = Mat::from_vec(n, q, (0..n * q).map(|_| rng.normal()).collect());
        let cd = CondDesign::build(&y, &x, d, 0.01);
        let params: Vec<f64> = (0..spec.n_params()).map(|_| 0.2 * rng.normal()).collect();
        let cfg = format!("n={n} J={j} d={d} q={q}");

        // serial row-at-a-time baseline (naive dots; no dispatch)
        parallel::set_threads(1);
        let t_ref = time_median(iters, || {
            std::hint::black_box(cond_nll_grad_reference(&cd, &[], spec, &params));
        });
        table.row(vec![
            "L3 cond_nll_grad rows (ref)".into(),
            cfg.clone(),
            "1".into(),
            format!("{t_ref:.4}"),
            "1.00x".into(),
            format!("{:.1} Mrow/s", n as f64 / t_ref / 1e6),
        ]);
        json.row("cond_nll_grad_ref", "rows", &cfg, 1, t_ref, (n as f64 / t_ref, "row/s"));

        for &(b, tag) in &backend_sweep() {
            simd::set_backend(b);
            for &t in &thread_sweep(max_threads) {
                parallel::set_threads(t);
                let sec = time_median(iters, || {
                    std::hint::black_box(cond_nll_grad_with(
                        &cd,
                        &[],
                        spec,
                        &params,
                        &parallel::Pool::current(),
                    ));
                });
                table.row(vec![
                    format!("L3 cond_nll_grad panel/{tag}"),
                    cfg.clone(),
                    format!("{t}"),
                    format!("{sec:.4}"),
                    format!("{:.2}x", t_ref / sec),
                    format!("{:.1} Mrow/s", n as f64 / sec / 1e6),
                ]);
                json.row("cond_nll_grad_panel", tag, &cfg, t, sec, (n as f64 / sec, "row/s"));
            }
        }
        simd::set_backend(ambient);
    }
    parallel::set_threads(max_threads);
}

/// ISSUE 7 sweep: sustained queries/sec through the HTTP serving layer
/// (one fitted model, fresh connection per request — the server speaks
/// `Connection: close`), at client concurrency {1, 4, max}. The mix
/// rotates over the four cheap query kinds; sample rows dominate the
/// response-size cost, the transform inversion dominates quantile.
fn bench_serving(table: &mut Table, json: &mut JsonRows, scale: Scale, max_threads: usize) {
    use mctm_coreset::server::{ModelRegistry, Server};
    use std::io::{Read, Write};
    use std::net::TcpStream;
    use std::sync::Arc;

    let mut rng = Rng::new(42);
    let data = Dgp::BivariateNormal.generate(2_000, &mut rng);
    let model = SessionBuilder::new()
        .budget(100)
        .basis_size(5)
        .seed(3)
        .max_iters(60)
        .build()
        .unwrap()
        .fit(&data)
        .unwrap();
    let registry = Arc::new(ModelRegistry::new());
    registry.insert("bench", model);
    parallel::set_threads(max_threads); // worker count is read at run()
    let handle = Server::bind("127.0.0.1:0", registry).unwrap().spawn();
    let addr = handle.addr();

    let per_client = scale.pick(60, 250, 600);
    let targets = [
        "/v1/models/bench/density?y=0.5,-0.25",
        "/v1/models/bench/cdf?j=0&y=1.0",
        "/v1/models/bench/quantile?j=1&p=0.75",
        "/v1/models/bench/sample?n=8&seed=1",
    ];
    let mut sweep = vec![1usize, 4, max_threads];
    sweep.retain(|&c| c <= max_threads.max(1));
    sweep.sort_unstable();
    sweep.dedup();
    let mut serial_qps = f64::NAN;
    for &clients in &sweep {
        let sw = Stopwatch::start();
        let workers: Vec<_> = (0..clients)
            .map(|c| {
                std::thread::spawn(move || {
                    for i in 0..per_client {
                        let t = targets[(c + i) % targets.len()];
                        let mut s = TcpStream::connect(addr).unwrap();
                        s.write_all(
                            format!("GET {t} HTTP/1.1\r\nHost: bench\r\n\r\n").as_bytes(),
                        )
                        .unwrap();
                        let mut resp = String::new();
                        s.read_to_string(&mut resp).unwrap();
                        assert!(resp.starts_with("HTTP/1.1 200"), "{t}: {resp}");
                    }
                })
            })
            .collect();
        for w in workers {
            w.join().unwrap();
        }
        let secs = sw.secs();
        let qps = (clients * per_client) as f64 / secs;
        if clients == 1 {
            serial_qps = qps;
        }
        table.row(vec![
            "serve HTTP qps".into(),
            format!("{} query kinds", targets.len()),
            format!("{clients}"),
            format!("{secs:.4}"),
            format!("{:.2}x", qps / serial_qps),
            format!("{qps:.0} req/s"),
        ]);
        json.row(
            "serve_http",
            "-",
            &format!("{} query kinds", targets.len()),
            clients,
            secs,
            (qps, "req/s"),
        );
    }
    handle.stop();
}

/// PR 9: out-of-core ingestion cost — draining an on-disk column store
/// shard-by-shard (seek + checksum + decode per chunk) vs the
/// equivalent in-memory shard materialization (`MatShards` produces an
/// owned `Mat` per shard via row selection, so the in-mem row times the
/// same per-shard copy, not a whole-matrix clone). The gap is the price
/// of fitting datasets that do not fit in RAM.
fn bench_store(table: &mut Table, json: &mut JsonRows, scale: Scale, iters: usize) {
    use mctm_coreset::data::store::{StoreReader, StoreWriter, DEFAULT_CHUNK_ROWS};

    let n = scale.pick(20_000, 100_000, 400_000);
    let cols = 8usize;
    let chunk = DEFAULT_CHUNK_ROWS;
    let mut rng = Rng::new(0x570E);
    let data = Mat::from_vec(n, cols, (0..n * cols).map(|_| rng.normal()).collect());
    let cfg = format!("n={n} d={cols} chunk={chunk}");

    let dir = std::env::temp_dir().join(format!("mctm_bench_store_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("bench.store");
    {
        let mut w = StoreWriter::create(&path, cols, chunk).unwrap();
        w.push_mat(&data).unwrap();
        w.finish().unwrap();
    }

    // in-memory reference: per-shard row selection on the resident Mat
    let shard_idx: Vec<Vec<usize>> = (0..n)
        .step_by(chunk)
        .map(|lo| (lo..(lo + chunk).min(n)).collect())
        .collect();
    let t_mem = time_median(iters, || {
        let mut rows = 0usize;
        for ix in &shard_idx {
            rows += std::hint::black_box(data.select_rows(ix)).rows;
        }
        assert_eq!(rows, n);
    });
    table.row(vec![
        "ingest in-mem shards".into(),
        cfg.clone(),
        "1".into(),
        format!("{t_mem:.4}"),
        "1.00x".into(),
        format!("{:.1} Mrow/s", n as f64 / t_mem / 1e6),
    ]);
    json.row("ingest_inmem", "-", &cfg, 1, t_mem, (n as f64 / t_mem, "row/s"));

    // store drain: open + seek/checksum/decode every chunk
    let t_store = time_median(iters, || {
        let mut r = StoreReader::open(&path).unwrap();
        let mut rows = 0usize;
        while let Some(m) = r.next_shard().unwrap() {
            rows += std::hint::black_box(m).rows;
        }
        assert_eq!(rows, n);
    });
    table.row(vec![
        "ingest store drain".into(),
        cfg.clone(),
        "1".into(),
        format!("{t_store:.4}"),
        format!("{:.2}x", t_mem / t_store),
        format!("{:.1} Mrow/s", n as f64 / t_store / 1e6),
    ]);
    json.row("ingest_store", "-", &cfg, 1, t_store, (n as f64 / t_store, "row/s"));
    std::fs::remove_dir_all(&dir).unwrap();
}

/// PR 9: leverage scoring on one-hot-heavy designs — the CSR gather
/// path (`sparse_leverage_scores_ridged_with`, O(nnz) Gram) vs
/// densify-first on the same 54-column covertype one-hot block, at
/// threads {1, 2, 4, max}. Bitwise equality of the two is pinned in the
/// unit tests; this row measures what skipping the zeros buys.
fn bench_sparse_leverage(
    table: &mut Table,
    json: &mut JsonRows,
    scale: Scale,
    iters: usize,
    max_threads: usize,
) {
    use mctm_coreset::coreset::leverage::{
        leverage_scores_ridged_with, sparse_leverage_scores_ridged_with,
    };

    let n = scale.pick(5_000, 50_000, 200_000);
    let mut rng = Rng::new(0x01E5);
    let sp = mctm_coreset::data::covertype::generate_onehot_sparse(n, &mut rng);
    let dense = sp.to_dense();
    let cfg = format!("n={n} d={} nnz/row=12", dense.cols);

    let mut t_dense_serial = f64::NAN;
    for &t in &thread_sweep(max_threads) {
        parallel::set_threads(t);
        let pool = parallel::Pool::current();
        let sec_d = time_median(iters, || {
            std::hint::black_box(leverage_scores_ridged_with(&dense, 0.0, &pool).unwrap());
        });
        if t == 1 {
            t_dense_serial = sec_d;
        }
        table.row(vec![
            "leverage dense (one-hot)".into(),
            cfg.clone(),
            format!("{t}"),
            format!("{sec_d:.4}"),
            format!("{:.2}x", t_dense_serial / sec_d),
            format!("{:.1} Mrow/s", n as f64 / sec_d / 1e6),
        ]);
        json.row("leverage_dense", "-", &cfg, t, sec_d, (n as f64 / sec_d, "row/s"));

        let sec_s = time_median(iters, || {
            std::hint::black_box(sparse_leverage_scores_ridged_with(&sp, 0.0, &pool).unwrap());
        });
        table.row(vec![
            "leverage sparse (csr)".into(),
            cfg.clone(),
            format!("{t}"),
            format!("{sec_s:.4}"),
            format!("{:.2}x", t_dense_serial / sec_s),
            format!("{:.1} Mrow/s", n as f64 / sec_s / 1e6),
        ]);
        json.row("leverage_sparse", "-", &cfg, t, sec_s, (n as f64 / sec_s, "row/s"));
    }
    parallel::set_threads(max_threads);
}

/// XLA rows degrade gracefully at every step: a missing PJRT runtime
/// (stub build), a missing artifact entry, or a runtime error prints a
/// note and skips — the bench must never panic because L1/L2 is absent.
fn bench_xla(table: &mut Table, data: &Mat, j: usize, iters: usize) {
    let d = 7usize;
    let engine = match Engine::new(Path::new("artifacts")) {
        Ok(e) => e,
        Err(e) => {
            println!("xla engine unavailable: {e:#}");
            return;
        }
    };
    let cfg = format!("J={j} d={d} (xla)");
    let design = Design::build(data, d, 0.01);
    let scaled = design.scaler.transform(data);
    let spec = ModelSpec::new(j, d);
    let p = Params::init(spec);
    let runner = match TiledNll::new(&engine, j, d) {
        Ok(r) => r,
        Err(e) => {
            println!("xla nll runner unavailable: {e:#}");
            return;
        }
    };

    let n = data.rows;
    match runner.nll_grad(&p.x, &scaled.data, &[]) {
        Ok(_) => {
            let t_grad = time_median(iters, || {
                std::hint::black_box(runner.nll_grad(&p.x, &scaled.data, &[]).unwrap());
            });
            table.row(vec![
                "XLA nll_grad (tiled)".into(),
                cfg.clone(),
                "1".into(),
                format!("{t_grad:.4}"),
                "1.00x".into(),
                format!("{:.1} Mrow/s", n as f64 / t_grad / 1e6),
            ]);
        }
        Err(e) => println!("xla nll_grad failed: {e:#}"),
    }

    match runner.nll_eval(&p.x, &scaled.data, &[]) {
        Ok(_) => {
            let t_eval = time_median(iters, || {
                std::hint::black_box(runner.nll_eval(&p.x, &scaled.data, &[]).unwrap());
            });
            table.row(vec![
                "XLA nll_eval (pallas fused)".into(),
                cfg.clone(),
                "1".into(),
                format!("{t_eval:.4}"),
                "1.00x".into(),
                format!("{:.1} Mrow/s", n as f64 / t_eval / 1e6),
            ]);
        }
        Err(e) => println!("xla nll_eval unavailable: {e:#}"),
    }

    // gram + leverage artifacts over the stacked matrix
    let lev = match mctm_coreset::runtime::engine::TiledLeverage::new(&engine, j * d) {
        Ok(l) => l,
        Err(e) => {
            println!("xla leverage runner unavailable: {e:#}");
            return;
        }
    };
    let stacked = design.stacked();
    let g = match lev.gram(&stacked.data) {
        Ok(g) => g,
        Err(e) => {
            println!("xla gram failed: {e:#}");
            return;
        }
    };
    let t_gram = time_median(iters, || {
        std::hint::black_box(lev.gram(&stacked.data).unwrap());
    });
    table.row(vec![
        "XLA gram (pallas tiled)".into(),
        cfg.clone(),
        "1".into(),
        format!("{t_gram:.4}"),
        "1.00x".into(),
        format!("{:.1} Mrow/s", n as f64 / t_gram / 1e6),
    ]);
    let g = Mat::from_vec(j * d, j * d, g);
    let mut gr = g.clone();
    let stab = 1e-10 * g.trace() / g.rows as f64;
    for i in 0..gr.rows {
        *gr.at_mut(i, i) += stab;
    }
    let ch = match Cholesky::new(&gr) {
        Ok(c) => c,
        Err(e) => {
            println!("xla gram not factorizable: {e}");
            return;
        }
    };
    let linv = ch.l_inverse();
    match lev.scores(&stacked.data, &linv.data) {
        Ok(_) => {
            let t_scores = time_median(iters, || {
                std::hint::black_box(lev.scores(&stacked.data, &linv.data).unwrap());
            });
            table.row(vec![
                "XLA leverage (pallas)".into(),
                cfg,
                "1".into(),
                format!("{t_scores:.4}"),
                "1.00x".into(),
                format!("{:.1} Mrow/s", n as f64 / t_scores / 1e6),
            ]);
        }
        Err(e) => println!("xla leverage scores failed: {e:#}"),
    }
}
