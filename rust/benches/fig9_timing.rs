//! Regenerates paper Figure 9: computation time (sampling + optimization
//! split) for 9 simulation distributions across the three methods.

use mctm_coreset::benchsupport::{banner, bench_fit_options, results_dir, Scale};
use mctm_coreset::coordinator::experiment::TableRunner;
use mctm_coreset::coreset::Method;
use mctm_coreset::data::dgp::Dgp;
use mctm_coreset::util::mean;
use mctm_coreset::util::report::Table;
use mctm_coreset::util::rng::Rng;

fn main() {
    let scale = Scale::from_env();
    let n = scale.pick(1_000, 10_000, 10_000);
    let k = 100;
    let reps = scale.pick(2, 5, 10);
    banner("fig9_timing", &format!("9 DGPs, n={n}, k={k}, reps={reps}"));

    let mut table = Table::new(
        "Figure 9: computation time per DGP (seconds)",
        &["DGP", "method", "sample(s)", "fit(s)", "total(s)"],
    );
    for dgp in Dgp::figure9() {
        let mut rng = Rng::new(9 ^ dgp.name().len() as u64);
        let data = dgp.generate(n, &mut rng);
        let runner = TableRunner::new(&data, 7, bench_fit_options(scale), 0xF9);
        for method in [Method::L2Hull, Method::L2Only, Method::Uniform] {
            let stats = runner.run(method, k, reps);
            table.row(vec![
                dgp.name().into(),
                method.name().into(),
                format!("{:.4}", mean(&stats.sample_secs)),
                format!("{:.4}", mean(&stats.fit_secs)),
                format!("{:.4}", mean(&stats.total_secs())),
            ]);
        }
        println!("  done {}", dgp.name());
    }
    table.emit(Some(&results_dir().join("fig9_timing.csv")));
}
