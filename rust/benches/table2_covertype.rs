//! Regenerates paper Table 2 + Figure 13: Covertype (synthetic terrain
//! substitute, DESIGN.md §5), J = 10 continuous variables, coreset sizes
//! k ∈ {50, 200, 500}, every method in the strategy registry
//! (`Method::all()` — the §4 ellipsoid pair included), against the
//! full-data benchmark fit.

use mctm_coreset::benchsupport::{banner, bench_fit_options, results_dir, Scale};
use mctm_coreset::coordinator::experiment::{summarize, TableRunner};
use mctm_coreset::data::covertype;
use mctm_coreset::prelude::*;
use mctm_coreset::util::report::{write_series_csv, Table};

fn main() {
    let scale = Scale::from_env();
    // the paper uses a 300k benchmark subsample of the 581k dataset; the
    // default container scale uses 50k (same J=10 model, same shapes)
    let n = scale.pick(5_000, 50_000, 300_000);
    let reps = scale.pick(2, 3, 5);
    let ks: Vec<usize> = match scale {
        Scale::Fast => vec![50, 200],
        _ => vec![50, 200, 500],
    };
    banner(
        "table2_covertype",
        &format!("synthetic Covertype, n={n}, J=10, reps={reps}"),
    );

    let mut rng = Rng::new(581_012);
    let sw = Stopwatch::start();
    let data = covertype::generate(n, &mut rng);
    println!("  generated {}x10 in {:.1}s", data.rows, sw.secs());

    let runner = TableRunner::new(&data, 7, bench_fit_options(scale), 54);
    println!(
        "  BENCHMARK full fit: nll={:.2} iters={} time={:.1}s",
        runner.full.fit.nll, runner.full.fit.iters, runner.full.seconds
    );

    let mut table = Table::new(
        "Table 2: Covertype performance per coreset size",
        &["k", "method", "theta L2", "lambda err", "LR", "impr(%)", "time(s)"],
    );
    // Figure 13 series: per k, per method, the four panel metrics
    let mut fig_k = Vec::new();
    let mut fig_method = Vec::new();
    let mut fig_lr = Vec::new();
    let mut fig_l2 = Vec::new();
    let mut fig_lam = Vec::new();
    let mut fig_time = Vec::new();

    for &k in &ks {
        // registry-driven: every registered method (ellipsoid pair
        // included) lands in the table automatically
        let all = runner.run_all(k, reps);
        let unif = all.last().unwrap(); // Method::all ends with Uniform
        for stats in &all {
            let mut row = vec![format!("{k}")];
            row.extend(summarize(stats, unif));
            table.row(row);
            fig_k.push(k as f64);
            fig_method.push(stats.method_name.to_string());
            fig_lr.push(mean(&stats.lr));
            fig_l2.push(mean(&stats.theta_l2));
            fig_lam.push(mean(&stats.lambda_err));
            fig_time.push(mean(&stats.total_secs()));
        }
        println!("  done k={k}");
    }
    // benchmark row (full data): zero errors, LR = 1 by definition
    table.row(vec![
        format!("n={n}"),
        "benchmark".into(),
        "0".into(),
        "0".into(),
        "1".into(),
        "-".into(),
        format!("{:.1}", runner.full.seconds),
    ]);
    table.emit(Some(&results_dir().join("table2_covertype.csv")));

    let method_codes: Vec<f64> = fig_method
        .iter()
        .map(|m| {
            Method::all()
                .iter()
                .position(|x| x.name() == m)
                .unwrap_or(99) as f64
        })
        .collect();
    write_series_csv(
        &results_dir().join("fig13_covertype.csv"),
        &[
            ("k", &fig_k),
            ("method_code", &method_codes),
            ("loglik_ratio", &fig_lr),
            ("theta_l2", &fig_l2),
            ("lambda_l2", &fig_lam),
            ("total_time_s", &fig_time),
        ],
    )
    .expect("writing fig13 csv");
    println!(
        "figure 13 series saved (method codes: {})",
        Method::all()
            .iter()
            .enumerate()
            .map(|(i, m)| format!("{i}={}", m.name()))
            .collect::<Vec<_>>()
            .join(", ")
    );
    println!(
        "\nspeedup vs full fit at largest k: coreset total ≈ {:.2}s vs {:.1}s full",
        fig_time.last().copied().unwrap_or(f64::NAN),
        runner.full.seconds
    );
}
