//! Regenerates paper Table 3 (and its Table 1 subset): performance of
//! {ℓ₂-hull, ℓ₂-only, uniform} at coreset size k = 30 over the 14
//! simulation DGPs (n = 10 000, mean ± std over repetitions).
fn main() {
    mctm_coreset::benchsupport::run_sim_table(
        "Table 3: simulation DGPs, coreset size 30",
        30,
        "table3_sim_k30.csv",
    );
}
