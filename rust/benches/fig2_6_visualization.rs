//! Regenerates the scatter data behind paper Figures 2–6: for each DGP,
//! a ~100-point coreset from 1 000 original samples under each sampling
//! method (uniform / ℓ₂-sensitivity / ℓ₂-hull). Output: tidy CSV with
//! (dgp, method, selected y1, y2, weight) — plus the raw cloud.
//!
//! Coresets are built through the facade's sketching half
//! (`Session::coreset`), so this bench exercises exactly the public
//! entry point.

use mctm_coreset::benchsupport::{banner, results_dir, Scale};
use mctm_coreset::prelude::*;
use std::io::Write;

/// One facade sketch: indices + weights of a k-point coreset of `data`.
fn sketch(data: &Mat, method: Method, k: usize, seed: u64) -> CoresetReport {
    SessionBuilder::new()
        .method_tag(method)
        .budget(k)
        .basis_size(7)
        .seed(seed)
        .build()
        .expect("valid sketch session")
        .coreset(data)
        .expect("non-empty data")
}

fn main() {
    let scale = Scale::from_env();
    let n = scale.pick(300, 1_000, 1_000);
    let k = scale.pick(50, 100, 100);
    banner("fig2_6_visualization", &format!("coresets of {k} from n={n}, all 14 DGPs"));

    let path = results_dir().join("fig2_6_coreset_scatter.csv");
    let mut f = std::fs::File::create(&path).expect("create csv");
    writeln!(f, "dgp,method,kind,y1,y2,weight").unwrap();
    for dgp in Dgp::all() {
        let mut rng = Rng::new(0xF16 ^ dgp.name().len() as u64);
        let data = dgp.generate(n, &mut rng);
        // raw cloud (subsampled for file size)
        for r in (0..n).step_by(4) {
            writeln!(
                f,
                "{},none,raw,{},{},1",
                dgp.name(),
                data.at(r, 0),
                data.at(r, 1)
            )
            .unwrap();
        }
        for (mi, method) in [Method::Uniform, Method::L2Only, Method::L2Hull]
            .into_iter()
            .enumerate()
        {
            let cs = sketch(&data, method, k, 0xF16 + mi as u64);
            let indices = cs.indices.as_deref().expect("batch path");
            for (idx, w) in indices.iter().zip(&cs.weights) {
                writeln!(
                    f,
                    "{},{},coreset,{},{},{}",
                    dgp.name(),
                    method.name(),
                    data.at(*idx, 0),
                    data.at(*idx, 1),
                    w
                )
                .unwrap();
            }
        }
        println!("  done {}", dgp.name());
    }
    println!("saved {}", path.display());

    // sanity headline: the hull method must cover the bounding box of
    // the cloud better than uniform (max |y| among selected points)
    let mut rng = Rng::new(99);
    let data = Dgp::BimodalClusters.generate(n, &mut rng);
    let extent = |m: Method, seed: u64| -> f64 {
        let cs = sketch(&data, m, k, seed);
        cs.indices
            .as_deref()
            .expect("batch path")
            .iter()
            .map(|&i| data.at(i, 0).abs().max(data.at(i, 1).abs()))
            .fold(0.0, f64::max)
    };
    let e_hull = extent(Method::L2Hull, 7);
    let e_unif = extent(Method::Uniform, 7);
    println!("coverage extent (bimodal clusters): l2-hull={e_hull:.2} uniform={e_unif:.2}");
}
