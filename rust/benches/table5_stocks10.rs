//! Regenerates paper Table 5 + Figure 1 (top row): 10-stock daily
//! returns, coreset sizes k ∈ {50, 100, 200, 300}.
fn main() {
    mctm_coreset::benchsupport::run_equity_table(
        "Table 5: 10 stock return series",
        10,
        "table5_stocks10.csv",
    );
}
