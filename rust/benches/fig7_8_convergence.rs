//! Regenerates paper Figures 7–8: convergence of likelihood ratio,
//! parameter error and λ error as the coreset size grows, for six DGPs
//! (normal mixture, non-linear correlation, bimodal clusters; circular,
//! copula-complex, heteroscedastic).

use mctm_coreset::benchsupport::{banner, bench_fit_options, results_dir, Scale};
use mctm_coreset::coordinator::experiment::TableRunner;
use mctm_coreset::coreset::Method;
use mctm_coreset::data::dgp::Dgp;
use mctm_coreset::util::report::write_series_csv;
use mctm_coreset::util::rng::Rng;
use mctm_coreset::util::{mean, std_dev};

fn main() {
    let scale = Scale::from_env();
    let n = scale.pick(1_000, 10_000, 10_000);
    let reps = scale.pick(2, 3, 10);
    let ks: Vec<usize> = match scale {
        Scale::Fast => vec![20, 50, 100],
        _ => vec![20, 30, 50, 75, 100, 150, 200, 300],
    };
    let dgps = [
        Dgp::NormalMixture,
        Dgp::NonlinearCorrelation,
        Dgp::BimodalClusters,
        Dgp::Circular,
        Dgp::CopulaComplex,
        Dgp::Heteroscedastic,
    ];
    banner(
        "fig7_8_convergence",
        &format!("6 DGPs, n={n}, k in {ks:?}, reps={reps}"),
    );

    for dgp in dgps {
        let mut rng = Rng::new(0x78 ^ dgp.name().len() as u64);
        let data = dgp.generate(n, &mut rng);
        let runner = TableRunner::new(&data, 7, bench_fit_options(scale), 0x78);
        let mut cols: Vec<(String, Vec<f64>)> =
            vec![("k".to_string(), ks.iter().map(|&k| k as f64).collect())];
        for method in [Method::L2Hull, Method::L2Only, Method::Uniform] {
            let mut lr_m = Vec::new();
            let mut lr_s = Vec::new();
            let mut l2_m = Vec::new();
            let mut l2_s = Vec::new();
            let mut lam_m = Vec::new();
            let mut lam_s = Vec::new();
            for &k in &ks {
                let stats = runner.run(method, k, reps);
                lr_m.push(mean(&stats.lr));
                lr_s.push(std_dev(&stats.lr));
                l2_m.push(mean(&stats.theta_l2));
                l2_s.push(std_dev(&stats.theta_l2));
                lam_m.push(mean(&stats.lambda_err));
                lam_s.push(std_dev(&stats.lambda_err));
            }
            let m = method.name();
            cols.push((format!("{m}_lr_mean"), lr_m));
            cols.push((format!("{m}_lr_std"), lr_s));
            cols.push((format!("{m}_theta_mean"), l2_m));
            cols.push((format!("{m}_theta_std"), l2_s));
            cols.push((format!("{m}_lambda_mean"), lam_m));
            cols.push((format!("{m}_lambda_std"), lam_s));
        }
        let named: Vec<(&str, &[f64])> =
            cols.iter().map(|(n, v)| (n.as_str(), v.as_slice())).collect();
        let path = results_dir().join(format!("fig7_8_{}.csv", dgp.name()));
        write_series_csv(&path, &named).expect("write csv");
        println!("  done {} -> {}", dgp.name(), path.display());
    }
}
