//! Ablations over the design choices DESIGN.md calls out:
//!   A1 — hull/sensitivity split α (Algorithm 1 uses α = 0.8)
//!   A2 — hull budget on heavy-tailed data (paper §3.1: t-copula /
//!        skew-t need a larger hull component at fixed k)
//!   A3 — Bernstein basis size d (model flexibility vs coreset size)
//!   A4 — Merge & Reduce intermediate buffer factor (accuracy vs memory)
//!
//! All coreset construction and fitting is driven through the facade
//! (`mctm_coreset::prelude`): sessions for the samples, `FittedModel`
//! for the metrics.

use mctm_coreset::benchsupport::{banner, bench_fit_options, results_dir, Scale};
use mctm_coreset::coordinator::experiment::{design_of, full_fit, TableRunner};
use mctm_coreset::coreset::hull::select_hull_points;
use mctm_coreset::coreset::samplers::HULL_SPLIT;
use mctm_coreset::fit::fit_native;
use mctm_coreset::prelude::*;
use mctm_coreset::util::report::Table;

fn main() {
    let scale = Scale::from_env();
    let n = scale.pick(2_000, 10_000, 10_000);
    let reps = scale.pick(2, 5, 10);
    banner("ablation_design", &format!("n={n}, reps={reps}"));
    ablation_hull_split(n, reps, scale);
    ablation_degree(n, reps, scale);
    ablation_buffer_factor(scale);
}

/// A1 + A2: sweep the hull fraction (1 − α) on a benign and a
/// heavy-tailed DGP at fixed k.
fn ablation_hull_split(n: usize, reps: usize, scale: Scale) {
    let k = 50;
    let mut table = Table::new(
        &format!("A1/A2: hull fraction sweep (k = {k}, default split = {:.1})", 1.0 - HULL_SPLIT),
        &["DGP", "hull fraction", "LR", "theta L2"],
    );
    for dgp in [Dgp::NormalMixture, Dgp::TCopula, Dgp::SkewT] {
        let mut rng = Rng::new(0xAB1);
        let data = dgp.generate(n, &mut rng);
        let design = design_of(&data, 7);
        let spec = ModelSpec::new(2, 7);
        let opts = bench_fit_options(scale);
        let full = full_fit(&design, spec, &opts);
        for hull_frac in [0.0, 0.1, 0.2, 0.4, 0.6] {
            // emulate the split by building the two parts explicitly:
            // the sensitivity part through the facade's sketching half,
            // the hull part via the geometry layer
            let mut lrs = Vec::new();
            let mut l2s = Vec::new();
            for rep in 0..reps {
                let k2 = (hull_frac * k as f64).round() as usize;
                let k1 = k - k2;
                let session = SessionBuilder::new()
                    .method_tag(Method::L2Only)
                    .budget(k1.max(1))
                    .basis_size(7)
                    .seed(0xAB2 + rep as u64)
                    .fit_options(opts.clone())
                    .build()
                    .expect("valid ablation session");
                let cs = session.coreset(&data).expect("non-empty data");
                let mut indices = cs.indices.clone().expect("batch path");
                let mut weights = cs.weights.clone();
                if k2 > 0 {
                    let mut hull_rng = Rng::new(0xAB8 + rep as u64);
                    let dp = design.deriv_points();
                    let hull = select_hull_points(&dp, k2, &mut hull_rng);
                    let seen: std::collections::HashSet<usize> =
                        indices.iter().cloned().collect();
                    for p in hull {
                        let obs = p / design.j;
                        if !seen.contains(&obs) {
                            indices.push(obs);
                            weights.push(1.0);
                        }
                    }
                }
                let sub = design.select(&indices);
                let fit = fit_native(spec, &sub, weights, &opts);
                lrs.push(loglik_ratio(
                    mctm_coreset::mctm::nll(&design, &[], &fit.params),
                    full.fit.nll,
                    design.n,
                    design.j,
                ));
                l2s.push(theta_l2(&fit.params, &full.fit.params));
            }
            table.row(vec![
                dgp.name().into(),
                format!("{hull_frac:.1}"),
                fmt_ms(&lrs),
                fmt_ms(&l2s),
            ]);
        }
        println!("  done {}", dgp.name());
    }
    table.emit(Some(&results_dir().join("ablation_hull_split.csv")));
}

/// A3: Bernstein basis size d at fixed coreset size.
fn ablation_degree(n: usize, reps: usize, scale: Scale) {
    let mut table = Table::new(
        "A3: basis size d (k = 100, normal mixture)",
        &["d", "method", "LR", "theta L2"],
    );
    let mut rng = Rng::new(0xAB3);
    let data = Dgp::NormalMixture.generate(n, &mut rng);
    for d in [4usize, 7, 10] {
        let runner = TableRunner::new(&data, d, bench_fit_options(scale), 0xAB4);
        for method in [Method::L2Hull, Method::Uniform] {
            let stats = runner.run(method, 100, reps);
            table.row(vec![
                format!("{d}"),
                method.name().into(),
                fmt_ms(&stats.lr),
                fmt_ms(&stats.theta_l2),
            ]);
        }
        println!("  done d={d}");
    }
    table.emit(Some(&results_dir().join("ablation_degree.csv")));
}

/// A4: Merge & Reduce buffer factor — streamed-coreset quality vs the
/// intermediate memory multiplier, driven end to end through
/// `Session::fit` on a shard source.
fn ablation_buffer_factor(scale: Scale) {
    let total = scale.pick(10_000, 40_000, 100_000);
    let k = 100;
    let spec = ModelSpec::new(2, 6);
    let opts = bench_fit_options(scale);
    let mut table = Table::new(
        &format!("A4: merge-reduce buffer factor (stream n = {total}, k = {k})"),
        &["buffer factor", "holdout LR", "levels memory (rows)"],
    );
    // holdout reference
    let mut rng = Rng::new(0xAB6);
    let holdout = Dgp::NormalMixture.generate(20_000, &mut rng);
    let ho_design = design_of(&holdout, 6);
    let batch = fit_native(spec, &ho_design, Vec::new(), &opts);

    for factor in [1usize, 2, 4, 8] {
        let mut lrs = Vec::new();
        for rep in 0..3u64 {
            let mut gen_rng = Rng::new(0xAB7 + rep);
            let source = GenShards::new(
                move |m| Dgp::NormalMixture.generate(m, &mut gen_rng),
                2,
                total,
                total / 10,
            );
            let session = SessionBuilder::new()
                .method_tag(Method::L2Hull)
                .budget(k)
                .basis_size(6)
                .seed(rep)
                .buffer_factor(factor)
                .fit_options(opts.clone())
                .build()
                .expect("valid streaming session");
            let model = session.fit(source).expect("non-empty stream");
            // the streamed fit's params live on the streamed coreset's
            // scaled axis — FittedModel::nll evaluates with that scaler
            lrs.push(loglik_ratio(
                model.nll(&holdout),
                batch.nll,
                ho_design.n,
                2,
            ));
        }
        table.row(vec![
            format!("{factor}"),
            fmt_ms(&lrs),
            format!("≤ {} per level", factor * k),
        ]);
        println!("  done factor={factor} (mean LR {:.3})", mean(&lrs));
    }
    table.emit(Some(&results_dir().join("ablation_buffer_factor.csv")));
}
