//! Regenerates paper Table 6 + Figure 1 (bottom row): 20-stock daily
//! returns, coreset sizes k ∈ {50, 100, 200, 300}.
fn main() {
    mctm_coreset::benchsupport::run_equity_table(
        "Table 6: 20 stock return series",
        20,
        "table6_stocks20.csv",
    );
}
