//! Cross-backend numerics: the native Rust MCTM objective and the
//! AOT-compiled XLA artifacts must agree to near machine precision —
//! this pins the whole L1/L2 math against the independent L3
//! implementation. Skips (with a note) when artifacts/ is absent.

use mctm_coreset::basis::Design;
use mctm_coreset::linalg::{Cholesky, Mat};
use mctm_coreset::mctm::{self, ModelSpec, Params};
use mctm_coreset::runtime::engine::TiledLeverage;
use mctm_coreset::runtime::{Engine, TiledNll};
use mctm_coreset::util::rng::Rng;
use std::path::Path;

fn engine() -> Option<Engine> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP cross_backend: artifacts/ missing (run `make artifacts`)");
        return None;
    }
    Some(Engine::new(dir).expect("engine"))
}

fn random_design(n: usize, j: usize, d: usize, seed: u64) -> (Mat, Design) {
    let mut rng = Rng::new(seed);
    let data = Mat::from_vec(n, j, (0..n * j).map(|_| rng.normal()).collect());
    let design = Design::build(&data, d, 0.01);
    (data, design)
}

fn random_params(spec: ModelSpec, seed: u64) -> Params {
    let mut rng = Rng::new(seed);
    Params::new(
        spec,
        (0..spec.n_params()).map(|_| 0.4 * rng.normal()).collect(),
    )
}

#[test]
fn nll_grad_matches_native_all_configs() {
    let Some(engine) = engine() else { return };
    for &(j, d) in &[(2usize, 7usize), (3, 7), (10, 7)] {
        let spec = ModelSpec::new(j, d);
        // n chosen to exercise padding (not a multiple of the tile)
        let (data, design) = random_design(700, j, d, 11 + j as u64);
        let scaled = design.scaler.transform(&data);
        let runner = TiledNll::new(&engine, j, d).expect("runner");
        for pseed in [1u64, 2, 3] {
            let p = random_params(spec, pseed);
            let (xv, xg) = runner.nll_grad(&p.x, &scaled.data, &[]).expect("xla");
            let (nv, ng) = mctm::nll_grad(&design, &[], &p);
            assert!(
                (xv - nv).abs() < 1e-8 * (1.0 + nv.abs()),
                "J={j}: value {xv} vs {nv}"
            );
            for (k, (a, b)) in xg.iter().zip(&ng).enumerate() {
                assert!(
                    (a - b).abs() < 1e-7 * (1.0 + b.abs()),
                    "J={j} grad[{k}]: {a} vs {b}"
                );
            }
        }
    }
}

#[test]
fn weighted_nll_matches_native() {
    let Some(engine) = engine() else { return };
    let (j, d) = (2, 7);
    let spec = ModelSpec::new(j, d);
    let (data, design) = random_design(300, j, d, 42);
    let scaled = design.scaler.transform(&data);
    let mut rng = Rng::new(5);
    let w: Vec<f64> = (0..300).map(|_| rng.uniform(0.1, 5.0)).collect();
    let p = random_params(spec, 9);
    let runner = TiledNll::new(&engine, j, d).unwrap();
    let (xv, _) = runner.nll_grad(&p.x, &scaled.data, &w).unwrap();
    let nv = mctm::nll(&design, &w, &p);
    assert!((xv - nv).abs() < 1e-8 * (1.0 + nv.abs()), "{xv} vs {nv}");
}

#[test]
fn fused_pallas_eval_matches_native() {
    let Some(engine) = engine() else { return };
    for &(j, d) in &[(2usize, 7usize), (10, 7)] {
        let spec = ModelSpec::new(j, d);
        let (data, design) = random_design(1025, j, d, 77); // 3 tiles, padded
        let scaled = design.scaler.transform(&data);
        let p = random_params(spec, 3);
        let runner = TiledNll::new(&engine, j, d).unwrap();
        let xv = runner.nll_eval(&p.x, &scaled.data, &[]).unwrap();
        let nv = mctm::nll(&design, &[], &p);
        assert!(
            (xv - nv).abs() < 1e-8 * (1.0 + nv.abs()),
            "J={j}: fused {xv} vs native {nv}"
        );
    }
}

#[test]
fn pallas_leverage_pipeline_matches_native() {
    let Some(engine) = engine() else { return };
    let (j, d) = (2usize, 7usize);
    let (_, design) = random_design(900, j, d, 13);
    let stacked = design.stacked();

    // native
    let native = mctm_coreset::coreset::leverage::leverage_scores(&stacked).unwrap();

    // xla: pallas gram → cholesky (L3) → pallas leverage
    let lev = TiledLeverage::new(&engine, j * d).unwrap();
    let mut gram = Mat::from_vec(j * d, j * d, lev.gram(&stacked.data).unwrap());
    let stab = 1e-10 * gram.trace() / gram.rows as f64;
    for i in 0..gram.rows {
        *gram.at_mut(i, i) += stab;
    }
    let ch = Cholesky::new(&gram).unwrap();
    let linv = ch.l_inverse();
    let scores = lev.scores(&stacked.data, &linv.data).unwrap();

    assert_eq!(scores.len(), native.len());
    for (i, (a, b)) in scores.iter().zip(&native).enumerate() {
        assert!((a - b).abs() < 1e-8 * (1.0 + b), "row {i}: {a} vs {b}");
    }
}

#[test]
fn tile_padding_is_invariant() {
    // same data evaluated at n = tile and n = tile+1 must give
    // prefix-consistent results (padding rows contribute nothing)
    let Some(engine) = engine() else { return };
    let (j, d) = (2usize, 7usize);
    let spec = ModelSpec::new(j, d);
    let (data, design) = random_design(513, j, d, 21);
    let scaled = design.scaler.transform(&data);
    let p = random_params(spec, 4);
    let runner = TiledNll::new(&engine, j, d).unwrap();

    let (v_all, _) = runner.nll_grad(&p.x, &scaled.data, &[]).unwrap();
    // weight vector zeroing the last row == evaluating 512 rows
    let mut w = vec![1.0; 513];
    w[512] = 0.0;
    let (v_prefix, _) = runner.nll_grad(&p.x, &scaled.data, &w).unwrap();
    let idx: Vec<usize> = (0..512).collect();
    let sub = scaled.select_rows(&idx);
    let (v_sub, _) = runner.nll_grad(&p.x, &sub.data, &[]).unwrap();
    assert!((v_prefix - v_sub).abs() < 1e-9 * (1.0 + v_sub.abs()));
    assert!(v_all != v_prefix, "row 513 should contribute");
}
