//! End-to-end coordinator tests, driven through the public facade
//! (`mctm_coreset::prelude`): streaming pipeline vs batch coresets,
//! CLI/config plumbing, dataset registry. The consumer-count
//! bit-identity pins from PRs 2/3 are preserved verbatim — now running
//! through `SessionBuilder` → `Session::coreset`/`fit`.

use mctm_coreset::prelude::*;

#[test]
fn streaming_quality_close_to_batch() {
    let total = 30_000;
    let opts = FitOptions { max_iters: 150, ..Default::default() };

    // batch: materialize everything; the full fit is the identity
    // coreset (budget ≥ n) through the same facade
    let mut rng = Rng::new(41);
    let batch_data = Dgp::BivariateNormal.generate(total, &mut rng);
    let full = SessionBuilder::new()
        .budget(total)
        .basis_size(6)
        .seed(41)
        .fit_options(opts.clone())
        .build()
        .unwrap()
        .fit(&batch_data)
        .unwrap();
    let batch_model = SessionBuilder::new()
        .method("l2-hull")
        .budget(100)
        .basis_size(6)
        .seed(42)
        .fit_options(opts.clone())
        .build()
        .unwrap()
        .fit(&batch_data)
        .unwrap();
    assert!(batch_model.diagnostics().coreset.stream.is_none());

    // streaming: same distribution through Merge & Reduce — the session
    // picks the streaming path automatically from the shard source
    let mut gen_rng = Rng::new(43);
    let source = GenShards::new(
        move |n| Dgp::BivariateNormal.generate(n, &mut gen_rng),
        2,
        total,
        3_000,
    );
    let stream_model = SessionBuilder::new()
        .method("l2-hull")
        .budget(100)
        .basis_size(6)
        .seed(44)
        .fit_options(opts)
        .build()
        .unwrap()
        .fit(source)
        .unwrap();
    let sdiag = stream_model.diagnostics();
    let sstats = sdiag.coreset.stream.as_ref().expect("streaming path");
    assert_eq!(sstats.n_seen, total);
    assert_eq!(sdiag.coreset.n_seen, total);

    // both coreset fits must approximate the batch full fit on full
    // data. FittedModel::nll evaluates with each model's OWN scaler, so
    // the streamed fit (whose params live on the streamed coreset's
    // scaled axis) is handled correctly without manual design plumbing.
    let full_nll = full.diagnostics().fit_nll;
    let lr_batch = loglik_ratio(batch_model.nll(&batch_data), full_nll, total, 2);
    let lr_stream = loglik_ratio(stream_model.nll(&batch_data), full_nll, total, 2);
    assert!(lr_batch < 1.3, "batch coreset LR {lr_batch}");
    // the stream compresses 30k → 100 through a random reduce tree;
    // quality is necessarily below one-shot sampling but bounded
    assert!(lr_stream < 1.8, "streamed coreset LR {lr_stream}");
    assert!(
        (lr_stream - 1.0) < 20.0 * (lr_batch - 1.0) + 0.1,
        "stream {lr_stream} vs batch {lr_batch}"
    );
}

/// Shared driver for the consumer-count bit-identity pins: build the
/// streamed coreset through the facade at `consumers` ∈ {1, 4} and
/// compare weights + rows bit for bit.
fn assert_stream_deterministic(method: &str, total: usize, budget: usize, seed: u64) {
    let make_source = move || {
        let mut rng = Rng::new(seed);
        GenShards::new(
            move |n| Dgp::CopulaComplex.generate(n, &mut rng),
            2,
            total,
            1_000,
        )
    };
    let run = |consumers: usize| {
        SessionBuilder::new()
            .method(method)
            .budget(budget)
            .basis_size(6)
            .consumers(consumers)
            .build()
            .unwrap()
            .coreset(make_source())
            .unwrap()
    };
    let c1 = run(1);
    let c4 = run(4);
    let (s1, s4) = (
        c1.stream.as_ref().expect("streaming path"),
        c4.stream.as_ref().expect("streaming path"),
    );
    assert_eq!(s1.n_seen, total);
    assert_eq!(s1.n_seen, s4.n_seen);
    assert_eq!(s1.n_shards, s4.n_shards);
    assert!(c1.size <= budget && c1.size > 0);
    // ISSUE 5 satellite: the Merge & Reduce tree threads hull
    // provenance up to the report — hull methods must report a real,
    // consumer-count-independent count, not the old hardcoded 0
    assert!(
        c1.n_hull > 0,
        "{method}: streaming n_hull lost its provenance"
    );
    assert!(c1.n_hull <= c1.size);
    assert_eq!(c1.n_hull, c4.n_hull);
    assert_eq!(c1.weights.len(), c4.weights.len(), "coreset sizes differ");
    for (i, (a, b)) in c1.weights.iter().zip(&c4.weights).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "weight {i}: {a} vs {b}");
    }
    for (i, (a, b)) in c1.rows.data.iter().zip(&c4.rows.data).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "row value {i}: {a} vs {b}");
    }
}

#[test]
fn streaming_hull_deterministic_across_consumers() {
    // ISSUE 2 acceptance, preserved through the facade: the L2Hull leaf
    // reduce runs the parallel geometry kernels; per-shard RNGs plus
    // the in-order reorder fold keep the final coreset bit-identical
    // for any consumer count — including the single-consumer path,
    // which uses the full worker pool inside its leaf reduces, so this
    // also pins pool-width independence of the whole reduce.
    assert_stream_deterministic("l2-hull", 8_000, 50, 71);
}

#[test]
fn streaming_ellipsoid_deterministic_across_consumers() {
    // ISSUE 3 acceptance, preserved through the facade: the ellipsoid
    // hybrid streams end to end — Khachiyan rounding and hull selection
    // execute inside every leaf/tree reduce via the strategy registry —
    // bit-identical for any consumer count.
    assert_stream_deterministic("ellipsoid-hull", 6_000, 50, 73);
}

#[test]
fn backpressure_bounds_queue() {
    let mut rng = Rng::new(47);
    let source = GenShards::new(
        move |n| Dgp::Spiral.generate(n, &mut rng),
        2,
        20_000,
        1_000,
    );
    let report = SessionBuilder::new()
        .method_tag(Method::Uniform)
        .budget(50)
        .basis_size(5)
        .queue_cap(2)
        .build()
        .unwrap()
        .coreset(source)
        .unwrap();
    let stats = report.stream.as_ref().expect("streaming path");
    assert_eq!(stats.n_shards, 20);
    assert!(stats.peak_queue <= 2);
    assert!(report.size <= 50);
}

#[test]
fn batch_vs_streaming_dispatch_is_automatic() {
    // the SAME session fits either path purely from the source type:
    // a Mat takes the batch path, shards of that Mat take Merge &
    // Reduce — and both produce valid, deterministic models
    let mut rng = Rng::new(90);
    let data = Dgp::NormalMixture.generate(6_000, &mut rng);
    let session = SessionBuilder::new()
        .method("l2-hull")
        .budget(80)
        .basis_size(6)
        .seed(17)
        .max_iters(120)
        .build()
        .unwrap();

    let batch = session.fit(&data).unwrap();
    assert!(batch.diagnostics().coreset.stream.is_none());
    assert!(batch.diagnostics().coreset.indices.is_some());

    let streamed = session.fit(MatShards::new(data.clone(), 1_000)).unwrap();
    let sdiag = streamed.diagnostics();
    assert!(sdiag.coreset.stream.is_some());
    assert!(sdiag.coreset.indices.is_none());
    assert_eq!(sdiag.coreset.n_seen, 6_000);

    // determinism: rerunning either path reproduces it bit for bit
    let batch2 = session.fit(&data).unwrap();
    assert_eq!(
        batch.diagnostics().coreset.indices,
        batch2.diagnostics().coreset.indices
    );
    assert_eq!(batch.params().x, batch2.params().x);
    let streamed2 = session.fit(MatShards::new(data.clone(), 1_000)).unwrap();
    assert_eq!(sdiag.coreset.weights, streamed2.diagnostics().coreset.weights);

    // both models answer the same queries with comparable quality on
    // the SAME evaluation data (each using its own scaler internally)
    let full = SessionBuilder::new()
        .budget(6_000)
        .basis_size(6)
        .seed(17)
        .max_iters(120)
        .build()
        .unwrap()
        .fit(&data)
        .unwrap();
    let full_nll = full.diagnostics().fit_nll;
    let lr_batch = loglik_ratio(batch.nll(&data), full_nll, 6_000, 2);
    let lr_stream = loglik_ratio(streamed.nll(&data), full_nll, 6_000, 2);
    assert!(lr_batch < 1.5, "batch LR {lr_batch}");
    assert!(lr_stream < 2.0, "streamed LR {lr_stream}");
}

#[test]
fn dataset_registry_resolves_all_names() {
    let mut rng = Rng::new(53);
    for dgp in Dgp::all() {
        let m = load_dataset(dgp.name(), 50, &mut rng).unwrap();
        assert_eq!((m.rows, m.cols), (50, 2));
    }
    assert_eq!(load_dataset("covertype", 40, &mut rng).unwrap().cols, 10);
    assert_eq!(load_dataset("stocks10", 40, &mut rng).unwrap().cols, 10);
    assert_eq!(load_dataset("stocks20", 40, &mut rng).unwrap().cols, 20);
    assert!(matches!(
        load_dataset("nope", 10, &mut rng).unwrap_err(),
        ApiError::UnknownDataset { .. }
    ));
}

#[test]
fn cli_parses_and_validates() {
    let cli = Cli::parse(&[
        "fit".into(),
        "--set".into(),
        "dataset=spiral".into(),
        "--set".into(),
        "k=25".into(),
        "--shards".into(),
        "4".into(),
    ])
    .unwrap();
    assert_eq!(cli.command, "fit");
    assert_eq!(cli.config.dataset, "spiral");
    assert_eq!(cli.config.k, 25);
    assert_eq!(cli.shards, 4);
    assert!(Cli::parse(&["fit".into(), "--bogus".into()]).is_err());
    assert!(Cli::parse(&["fit".into(), "--set".into(), "zzz=1".into()]).is_err());
    // bad numbers in flags are typed config errors, not panics
    assert!(matches!(
        Cli::parse(&["fit".into(), "--shards".into(), "x".into()]).unwrap_err(),
        ApiError::Config { .. }
    ));
}

#[test]
fn cli_method_roundtrip_every_registered_name() {
    // ISSUE 3 satellite: parse → name() → parse is the identity for
    // every registered strategy, through the real CLI path
    for m in Method::all() {
        let cli = Cli::parse(&[
            "fit".into(),
            "--set".into(),
            format!("method={}", m.name()),
        ])
        .unwrap();
        assert_eq!(cli.config.method, m);
        assert_eq!(cli.config.method.name(), m.name());
    }
    // unknown method: the typed error must list every valid name
    let err = Cli::parse(&["fit".into(), "--set".into(), "method=bogus".into()]).unwrap_err();
    let msg = format!("{err}");
    for m in Method::all() {
        assert!(msg.contains(m.name()), "error should list {}: {msg}", m.name());
    }
    assert!(matches!(err, ApiError::UnknownMethod { .. }));
}

#[test]
fn help_runs() {
    let cli = Cli::parse(&[]).unwrap();
    assert_eq!(cli.command, "help");
    cli.run().unwrap();
}
