//! End-to-end coordinator tests: streaming pipeline vs batch coresets,
//! CLI/config plumbing, dataset registry.

use mctm_coreset::coordinator::cli::{load_dataset, Cli};
use mctm_coreset::coordinator::experiment::design_of;
use mctm_coreset::coordinator::pipeline::StreamingPipeline;
use mctm_coreset::coreset::{build_coreset, Method};
use mctm_coreset::data::dgp::Dgp;
use mctm_coreset::data::GenShards;
use mctm_coreset::fit::{fit_native, FitOptions};
use mctm_coreset::mctm::{self, loglik_ratio, ModelSpec};
use mctm_coreset::util::rng::Rng;

#[test]
fn streaming_quality_close_to_batch() {
    let total = 30_000;
    let spec = ModelSpec::new(2, 6);
    let opts = FitOptions { max_iters: 150, ..Default::default() };

    // batch: materialize everything, coreset, fit
    let mut rng = Rng::new(41);
    let batch_data = Dgp::BivariateNormal.generate(total, &mut rng);
    let batch_design = design_of(&batch_data, 6);
    let full = fit_native(spec, &batch_design, Vec::new(), &opts);
    let cs = build_coreset(&batch_design, Method::L2Hull, 100, &mut rng);
    let sub = batch_design.select(&cs.indices);
    let batch_fit = fit_native(spec, &sub, cs.weights.clone(), &opts);

    // streaming: same distribution through Merge & Reduce
    let mut gen_rng = Rng::new(43);
    let source = GenShards::new(
        move |n| Dgp::BivariateNormal.generate(n, &mut gen_rng),
        2,
        total,
        3_000,
    );
    let pipeline = StreamingPipeline::new(Method::L2Hull, 100, 6);
    let (streamed, stats) = pipeline.run(source);
    assert_eq!(stats.n_seen, total);
    let s_design = design_of(&streamed.rows, 6);
    let stream_fit = fit_native(spec, &s_design, streamed.weights.clone(), &opts);

    // both coreset fits must approximate the batch full fit on full data.
    // IMPORTANT: the streamed fit's parameters live on the streamed
    // coreset's scaled axis — evaluate them on a full-data design built
    // with THAT scaler (see Design::build_with_scaler docs).
    let eval_design = mctm_coreset::basis::Design::build_with_scaler(
        &batch_data,
        6,
        s_design.scaler.clone(),
    );
    let lr_batch = loglik_ratio(
        mctm::nll(&batch_design, &[], &batch_fit.params),
        full.nll,
        total,
        2,
    );
    let lr_stream = loglik_ratio(
        mctm::nll(&eval_design, &[], &stream_fit.params),
        full.nll,
        total,
        2,
    );
    assert!(lr_batch < 1.3, "batch coreset LR {lr_batch}");
    // the stream compresses 30k → 100 through a random reduce tree;
    // quality is necessarily below one-shot sampling but bounded
    assert!(lr_stream < 1.8, "streamed coreset LR {lr_stream}");
    assert!(
        (lr_stream - 1.0) < 20.0 * (lr_batch - 1.0) + 0.1,
        "stream {lr_stream} vs batch {lr_batch}"
    );
}

#[test]
fn streaming_hull_deterministic_across_consumers() {
    // ISSUE 2 acceptance: the L2Hull leaf reduce now runs the parallel
    // geometry kernels (hull selection included). Per-shard RNGs plus
    // the in-order reorder fold must keep the final coreset
    // bit-identical for any consumer count — including the
    // single-consumer path, which uses the full worker pool inside its
    // leaf reduces, so this also pins pool-width independence of the
    // whole reduce.
    let make_source = |seed: u64| {
        let mut rng = Rng::new(seed);
        GenShards::new(
            move |n| Dgp::CopulaComplex.generate(n, &mut rng),
            2,
            8_000,
            1_000,
        )
    };
    let run = |consumers: usize| {
        let mut p = StreamingPipeline::new(Method::L2Hull, 50, 6);
        p.consumers = consumers;
        p.run(make_source(71))
    };
    let (c1, s1) = run(1);
    let (c4, s4) = run(4);
    assert_eq!(s1.n_seen, 8_000);
    assert_eq!(s1.n_seen, s4.n_seen);
    assert_eq!(s1.n_shards, s4.n_shards);
    assert_eq!(c1.weights.len(), c4.weights.len(), "coreset sizes differ");
    for (i, (a, b)) in c1.weights.iter().zip(&c4.weights).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "weight {i}: {a} vs {b}");
    }
    for (i, (a, b)) in c1.rows.data.iter().zip(&c4.rows.data).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "row value {i}: {a} vs {b}");
    }
}

#[test]
fn streaming_ellipsoid_deterministic_across_consumers() {
    // ISSUE 3 acceptance: `--method ellipsoid-hull` runs end to end
    // through the streaming pipeline — the Khachiyan rounding and hull
    // selection execute inside every leaf/tree reduce via the strategy
    // registry — and per-shard RNGs + the in-order reorder fold keep
    // the final coreset bit-identical for any consumer count.
    let make_source = |seed: u64| {
        let mut rng = Rng::new(seed);
        GenShards::new(
            move |n| Dgp::CopulaComplex.generate(n, &mut rng),
            2,
            6_000,
            1_000,
        )
    };
    let run = |consumers: usize| {
        let mut p = StreamingPipeline::new(Method::EllipsoidHull, 50, 6);
        p.consumers = consumers;
        p.run(make_source(73))
    };
    let (c1, s1) = run(1);
    let (c4, s4) = run(4);
    assert_eq!(s1.n_seen, 6_000);
    assert_eq!(s1.n_seen, s4.n_seen);
    assert_eq!(s1.n_shards, s4.n_shards);
    assert!(c1.len() <= 50 && !c1.is_empty());
    assert_eq!(c1.weights.len(), c4.weights.len(), "coreset sizes differ");
    for (i, (a, b)) in c1.weights.iter().zip(&c4.weights).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "weight {i}: {a} vs {b}");
    }
    for (i, (a, b)) in c1.rows.data.iter().zip(&c4.rows.data).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "row value {i}: {a} vs {b}");
    }
}

#[test]
fn backpressure_bounds_queue() {
    let pipeline = {
        let mut p = StreamingPipeline::new(Method::Uniform, 50, 5);
        p.queue_cap = 2;
        p
    };
    let mut rng = Rng::new(47);
    let source = GenShards::new(
        move |n| Dgp::Spiral.generate(n, &mut rng),
        2,
        20_000,
        1_000,
    );
    let (out, stats) = pipeline.run(source);
    assert_eq!(stats.n_shards, 20);
    assert!(stats.peak_queue <= 2);
    assert!(out.len() <= 50);
}

#[test]
fn dataset_registry_resolves_all_names() {
    let mut rng = Rng::new(53);
    for dgp in Dgp::all() {
        let m = load_dataset(dgp.name(), 50, &mut rng).unwrap();
        assert_eq!((m.rows, m.cols), (50, 2));
    }
    assert_eq!(load_dataset("covertype", 40, &mut rng).unwrap().cols, 10);
    assert_eq!(load_dataset("stocks10", 40, &mut rng).unwrap().cols, 10);
    assert_eq!(load_dataset("stocks20", 40, &mut rng).unwrap().cols, 20);
    assert!(load_dataset("nope", 10, &mut rng).is_err());
}

#[test]
fn cli_parses_and_validates() {
    let cli = Cli::parse(&[
        "fit".into(),
        "--set".into(),
        "dataset=spiral".into(),
        "--set".into(),
        "k=25".into(),
        "--shards".into(),
        "4".into(),
    ])
    .unwrap();
    assert_eq!(cli.command, "fit");
    assert_eq!(cli.config.dataset, "spiral");
    assert_eq!(cli.config.k, 25);
    assert_eq!(cli.shards, 4);
    assert!(Cli::parse(&["fit".into(), "--bogus".into()]).is_err());
    assert!(Cli::parse(&["fit".into(), "--set".into(), "zzz=1".into()]).is_err());
}

#[test]
fn cli_method_roundtrip_every_registered_name() {
    // ISSUE 3 satellite: parse → name() → parse is the identity for
    // every registered strategy, through the real CLI path
    for m in Method::all() {
        let cli = Cli::parse(&[
            "fit".into(),
            "--set".into(),
            format!("method={}", m.name()),
        ])
        .unwrap();
        assert_eq!(cli.config.method, m);
        assert_eq!(cli.config.method.name(), m.name());
    }
    // unknown method: the error must list every valid name
    let err = Cli::parse(&["fit".into(), "--set".into(), "method=bogus".into()]).unwrap_err();
    let msg = format!("{err:#}");
    for m in Method::all() {
        assert!(msg.contains(m.name()), "error should list {}: {msg}", m.name());
    }
}

#[test]
fn help_runs() {
    let cli = Cli::parse(&[]).unwrap();
    assert_eq!(cli.command, "help");
    cli.run().unwrap();
}
