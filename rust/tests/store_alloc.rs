//! PR 9 bounded-memory acceptance: a store-backed streaming fit's peak
//! **live heap bytes** are O(budget + chunk) — independent of the row
//! count. A byte-tracking global allocator (the `fit_alloc.rs` idiom,
//! tracking live/peak bytes instead of allocation counts) measures the
//! peak over `Session::coreset(StoreSource)` for an 8× larger store
//! with the same chunk geometry; an O(n) ingestion path would add at
//! least the materialized-matrix delta (≥ 2.2 MB here), so the pin
//! asserts the peaks differ by far less.
//!
//! Everything runs inside ONE `#[test]` so no concurrent test can
//! perturb the global counters.

use mctm_coreset::data::covertype;
use mctm_coreset::prelude::*;
use std::alloc::{GlobalAlloc, Layout, System};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};

struct PeakAlloc;

static LIVE: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for PeakAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            let live = LIVE.fetch_add(layout.size(), Ordering::SeqCst) + layout.size();
            PEAK.fetch_max(live, Ordering::SeqCst);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        LIVE.fetch_sub(layout.size(), Ordering::SeqCst);
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: PeakAlloc = PeakAlloc;

/// Peak live bytes above the starting level while `f` runs.
fn peak_during<F: FnOnce()>(f: F) -> usize {
    let base = LIVE.load(Ordering::SeqCst);
    PEAK.store(base, Ordering::SeqCst);
    f();
    PEAK.load(Ordering::SeqCst).saturating_sub(base)
}

const CHUNK: usize = 500;
const N_SMALL: usize = 4_000;
const N_LARGE: usize = 32_000;

/// Write an n-row covertype store chunk by chunk (the writer itself is
/// bounded-memory, but this runs outside the measured window anyway).
fn write_covertype_store(n: usize, path: &Path) {
    let mut rng = Rng::new(5);
    let mut w = StoreWriter::create(path, 10, CHUNK).unwrap();
    let mut remaining = n;
    while remaining > 0 {
        let take = CHUNK.min(remaining);
        w.push_mat(&covertype::generate(take, &mut rng)).unwrap();
        remaining -= take;
    }
    assert_eq!(w.finish().unwrap(), n as u64);
}

fn session() -> Session {
    SessionBuilder::new()
        .method("l2-hull")
        .budget(60)
        .basis_size(5)
        .seed(11)
        .consumers(1)
        .threads(1)
        .build()
        .unwrap()
}

fn run_fit(path: PathBuf) -> usize {
    let report = session().coreset(StoreSource::new(path)).unwrap();
    assert!(report.size > 0);
    report.n_seen
}

#[test]
fn store_backed_fit_peak_memory_does_not_grow_with_rows() {
    let dir = std::env::temp_dir().join(format!("mctm_store_alloc_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let small = dir.join("small.store");
    let large = dir.join("large.store");
    write_covertype_store(N_SMALL, &small);
    write_covertype_store(N_LARGE, &large);

    // warm-up: thread pool, lazily initialized statics, allocator pools
    assert_eq!(run_fit(small.clone()), N_SMALL);

    let mut peak_small = 0usize;
    let p = small.clone();
    let peak1 = peak_during(|| {
        peak_small = run_fit(p);
    });
    assert_eq!(peak_small, N_SMALL);

    let mut peak_large_rows = 0usize;
    let p = large.clone();
    let peak2 = peak_during(|| {
        peak_large_rows = run_fit(p);
    });
    assert_eq!(peak_large_rows, N_LARGE);

    // O(n) ingestion of the large store would materialize ≥
    // N_LARGE·10·8 = 2.56 MB (vs 0.32 MB for the small one): a ≥ 2.2 MB
    // peak delta. The streaming path holds one chunk (40 KB) plus
    // O(budget) state either way, so the two peaks must stay within a
    // 1 MB slack of each other — and both far below the large matrix.
    let delta = peak2.abs_diff(peak1);
    assert!(
        delta < 1_000_000,
        "peak grew with row count: small={peak1} large={peak2} (delta {delta})"
    );
    assert!(
        peak2 < N_LARGE * 10 * 8,
        "peak {peak2} is at materialized-matrix scale"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}
