//! PR 9 out-of-core acceptance: a store-backed streaming fit is
//! **bitwise-identical** to the equivalent in-memory `MatShards` fit —
//! same seed, consumers {1, 4} × threads {1, 2, 8} — and the
//! `store:`-prefixed registry path materializes the exact bits the
//! store was written from. Typed-error surfaces (truncation at open,
//! checksum at read) are pinned at the facade level too.

use mctm_coreset::prelude::*;
use std::path::PathBuf;
use std::time::Duration;

const TOTAL: usize = 6_000;
const SHARD: usize = 1_000;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mctm_storetest_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn data() -> Mat {
    Dgp::BivariateNormal.generate(TOTAL, &mut Rng::new(7))
}

/// Write `m` into a store whose chunk geometry equals the in-memory
/// shard size — shard-sequence equality is what the bitwise pin needs.
fn write_store(m: &Mat, path: &std::path::Path, chunk_rows: usize) {
    let mut w = StoreWriter::create(path, m.cols, chunk_rows).unwrap();
    w.push_mat(m).unwrap();
    w.finish().unwrap();
}

fn session(consumers: usize, threads: usize) -> Session {
    SessionBuilder::new()
        .method("l2-hull")
        .budget(60)
        .basis_size(5)
        .seed(11)
        .consumers(consumers)
        .threads(threads)
        .build()
        .unwrap()
}

fn with_timeout<T: Send + 'static>(secs: u64, f: impl FnOnce() -> T + Send + 'static) -> T {
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = tx.send(f());
    });
    rx.recv_timeout(Duration::from_secs(secs))
        .expect("pipeline did not finish within the timeout")
}

fn bits(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn store_backed_coreset_is_bitwise_equal_to_mat_shards() {
    let dir = tmp_dir("bitwise");
    let path = dir.join("rows.store");
    let m = data();
    write_store(&m, &path, SHARD);

    for consumers in [1usize, 4] {
        for threads in [1usize, 2, 8] {
            let inmem = {
                let m = m.clone();
                with_timeout(120, move || {
                    session(consumers, threads)
                        .coreset(MatShards::new(m, SHARD))
                        .unwrap()
                })
            };
            let stored = {
                let path = path.clone();
                with_timeout(120, move || {
                    session(consumers, threads)
                        .coreset(StoreSource::new(path))
                        .unwrap()
                })
            };
            assert_eq!(
                bits(&stored.rows.data),
                bits(&inmem.rows.data),
                "rows differ at consumers={consumers} threads={threads}"
            );
            assert_eq!(
                bits(&stored.weights),
                bits(&inmem.weights),
                "weights differ at consumers={consumers} threads={threads}"
            );
            assert_eq!(stored.n_seen, TOTAL);
            assert_eq!(inmem.n_seen, TOTAL);
            assert!(stored.degradations.is_clean(), "{:?}", stored.degradations);
        }
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn store_registry_name_streams_and_batches_the_same_bits() {
    let dir = tmp_dir("registry");
    let path = dir.join("rows.store");
    let m = data();
    write_store(&m, &path, SHARD);
    let name = format!("store:{}", path.display());

    // batch path: `store:` materializes the exact bits written
    let loaded = load_dataset(&name, TOTAL, &mut Rng::new(1)).unwrap();
    assert_eq!(bits(&loaded.data), bits(&m.data));

    // the batch coreset over the store equals the in-memory batch
    // coreset over the same matrix
    let via_store = session(1, 2)
        .coreset(NamedSource::batch(name.as_str(), TOTAL))
        .unwrap();
    let via_mem = session(1, 2).coreset(&m).unwrap();
    assert_eq!(bits(&via_store.rows.data), bits(&via_mem.rows.data));
    assert_eq!(bits(&via_store.weights), bits(&via_mem.weights));

    // the streaming registry path reaches the same reader the
    // StoreSource does (chunk geometry from the store file)
    let name2 = name.clone();
    let streamed = with_timeout(120, move || {
        session(2, 2)
            .coreset(NamedSource::stream(name2.as_str(), TOTAL, SHARD))
            .unwrap()
    });
    let direct = {
        let path = path.clone();
        with_timeout(120, move || {
            session(2, 2).coreset(StoreSource::new(path)).unwrap()
        })
    };
    assert_eq!(bits(&streamed.rows.data), bits(&direct.rows.data));
    assert_eq!(bits(&streamed.weights), bits(&direct.weights));
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn truncated_store_is_a_typed_io_error_at_open() {
    let dir = tmp_dir("truncated");
    let path = dir.join("rows.store");
    write_store(&data(), &path, SHARD);
    let full = std::fs::read(&path).unwrap();
    std::fs::write(&path, &full[..full.len() - 9]).unwrap();

    let err = session(1, 1)
        .coreset(StoreSource::new(path))
        .unwrap_err();
    match &err {
        ApiError::Io(msg) => assert!(msg.contains("truncated"), "{msg}"),
        other => panic!("expected ApiError::Io, got {other}"),
    }
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn corrupted_chunk_surfaces_checksum_stream_error() {
    let dir = tmp_dir("corrupt");
    let path = dir.join("rows.store");
    write_store(&data(), &path, SHARD);
    // flip one payload bit inside chunk 2 (header is 48 bytes; each
    // chunk is 8 + SHARD·2·8 bytes; offset 100 lands in the payload)
    let stride = 8 + SHARD * 2 * 8;
    let mut bytes = std::fs::read(&path).unwrap();
    let off = 48 + 2 * stride + 100;
    bytes[off] ^= 0x10;
    std::fs::write(&path, &bytes).unwrap();

    let path2 = path.clone();
    let err = with_timeout(120, move || {
        session(2, 1)
            .coreset(StoreSource::new(path2))
            .unwrap_err()
    });
    match &err {
        ApiError::Stream { shard_seq, .. } => assert_eq!(*shard_seq, Some(2)),
        other => panic!("expected ApiError::Stream, got {other}"),
    }
    let msg = err.to_string();
    assert!(msg.contains("checksum"), "{msg}");
    assert!(msg.contains("fatal"), "{msg}");
    std::fs::remove_dir_all(&dir).unwrap();
}
