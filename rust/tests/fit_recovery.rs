//! Statistical recovery: the fitted MCTM must reproduce ground-truth
//! structure of known DGPs — marginal densities, dependence parameters,
//! and coreset-vs-full convergence as k grows.

use mctm_coreset::coordinator::experiment::{design_of, TableRunner};
use mctm_coreset::coreset::Method;
use mctm_coreset::data::dgp::Dgp;
use mctm_coreset::fit::{fit_native, FitOptions};
use mctm_coreset::mctm::{marginal_density, ModelSpec};
use mctm_coreset::util::mean;
use mctm_coreset::util::rng::Rng;
use mctm_coreset::util::special::norm_pdf;

#[test]
fn gaussian_marginal_density_recovered() {
    let mut rng = Rng::new(1);
    let data = Dgp::BivariateNormal.generate(8_000, &mut rng);
    let design = design_of(&data, 7);
    let spec = ModelSpec::new(2, 7);
    let fit = fit_native(spec, &design, Vec::new(), &FitOptions::default());
    // fitted marginal vs true N(0,1) on a grid
    let mut max_err: f64 = 0.0;
    for i in 0..61 {
        let y = -3.0 + 0.1 * i as f64;
        let f = marginal_density(&fit.params, &design.scaler, 0, y);
        max_err = max_err.max((f - norm_pdf(y)).abs());
    }
    // 0.08 rather than 0.05: the Bernstein marginal has visible boundary
    // bias at |y| ≈ 3 where the min–max scaler clamps (PR 2 triage —
    // keep the bound tight enough to catch a broken transform)
    assert!(max_err < 0.08, "max marginal density error {max_err}");
}

#[test]
fn copula_whitens_the_dependence() {
    // after fitting, z = Λ h̃(y) should be near-uncorrelated
    let mut rng = Rng::new(2);
    let data = Dgp::BivariateNormal.generate(6_000, &mut rng);
    let design = design_of(&data, 7);
    let spec = ModelSpec::new(2, 7);
    let fit = fit_native(spec, &design, Vec::new(), &FitOptions::default());
    let theta = fit.params.theta();
    let d = 7;
    let lam = fit.params.lambda(1, 0);
    let (mut s1, mut s2, mut s12, mut s11, mut s22) = (0.0, 0.0, 0.0, 0.0, 0.0);
    for i in 0..design.n {
        let h1: f64 = design
            .a_row(i, 0)
            .iter()
            .zip(&theta[0..d])
            .map(|(a, t)| a * t)
            .sum();
        let h2: f64 = design
            .a_row(i, 1)
            .iter()
            .zip(&theta[d..2 * d])
            .map(|(a, t)| a * t)
            .sum();
        let z1 = h1;
        let z2 = h2 + lam * h1;
        s1 += z1;
        s2 += z2;
        s11 += z1 * z1;
        s22 += z2 * z2;
        s12 += z1 * z2;
    }
    let n = design.n as f64;
    let corr = (s12 / n - s1 / n * s2 / n)
        / ((s11 / n - (s1 / n).powi(2)).sqrt() * (s22 / n - (s2 / n).powi(2)).sqrt());
    // 0.08 rather than 0.05: sampling noise of ρ̂ at n = 6k plus the
    // finite-basis bias leaves ~0.05–0.06 residual correlation on some
    // seeds (PR 2 triage)
    assert!(corr.abs() < 0.08, "residual z correlation {corr}");
}

#[test]
fn coreset_error_shrinks_with_k() {
    let mut rng = Rng::new(3);
    let data = Dgp::NormalMixture.generate(6_000, &mut rng);
    let opts = FitOptions { max_iters: 150, ..Default::default() };
    let runner = TableRunner::new(&data, 6, opts, 5);
    let small = runner.run(Method::L2Hull, 25, 4);
    let large = runner.run(Method::L2Hull, 400, 4);
    let lr_small = mean(&small.lr);
    let lr_large = mean(&large.lr);
    // additive slack 0.05 rather than 0.02: at k=25 the 4-rep mean LR is
    // itself noisy, so the 0.6× contraction needs headroom (PR 2 triage)
    assert!(
        lr_large - 1.0 < 0.6 * (lr_small - 1.0) + 0.05,
        "LR must improve with k: k=25 → {lr_small}, k=400 → {lr_large}"
    );
    assert!(
        mean(&large.theta_l2) < mean(&small.theta_l2) + 0.5,
        "theta error should not grow with k"
    );
}

#[test]
fn hull_method_beats_uniform_on_heteroscedastic() {
    // one of the paper's 12/14 winning scenarios, statistically robust
    // margin: average LR over reps
    let mut rng = Rng::new(4);
    let data = Dgp::Heteroscedastic.generate(8_000, &mut rng);
    let opts = FitOptions { max_iters: 150, ..Default::default() };
    let runner = TableRunner::new(&data, 7, opts, 11);
    let hull = runner.run(Method::L2Hull, 30, 6);
    let unif = runner.run(Method::Uniform, 30, 6);
    let lr_hull = mean(&hull.lr);
    let lr_unif = mean(&unif.lr);
    // margin 0.08 rather than 0.05: 6 reps of k=30 coresets on the
    // heteroscedastic DGP leave ~0.06 std on the mean-LR gap (PR 2
    // triage — the paper's claim is "wins or ties", not a fixed margin)
    assert!(
        lr_hull < lr_unif + 0.08,
        "l2-hull should not lose clearly: {lr_hull} vs uniform {lr_unif}"
    );
}

#[test]
fn equity_fit_is_stable_for_20_dims() {
    // J=20 exercises the largest λ block (190 free copula params)
    let mut rng = Rng::new(5);
    let data = mctm_coreset::data::equity::generate(1_500, 20, &mut rng);
    let design = design_of(&data, 5);
    let spec = ModelSpec::new(20, 5);
    let opts = FitOptions { max_iters: 80, ..Default::default() };
    let fit = fit_native(spec, &design, Vec::new(), &opts);
    assert!(fit.nll.is_finite());
    // fitted transforms stay monotone by construction; sanity: NLL
    // below the init value
    let init = mctm_coreset::mctm::Params::init(spec);
    let init_nll = mctm_coreset::mctm::nll(&design, &[], &init);
    assert!(fit.nll < init_nll);
}
