//! Per-backend kernel agreement pins (PR 8).
//!
//! Kernel-level property tests call the `*_scalar` reference bodies and
//! the `*_simd` wrappers directly (no global dispatch involved), so
//! they are safe under the parallel test harness; the single
//! end-to-end test that toggles the process-global backend
//! ([`backend_toggle_end_to_end`]) is the only one touching the
//! dispatcher state, and restores the ambient selection when done.
//!
//! Contract under test (see `linalg::simd`): Scalar is bit-exact
//! against every retained reference; Simd agrees to ≤ 1e-12 relative
//! and is internally deterministic.

use mctm_coreset::basis::Design;
use mctm_coreset::linalg::simd::{
    self, panel_accum_t1_simd, panel_accum_t_simd, panel_matvec_simd, simd_available,
    syrk_upper_row1_range_simd, syrk_upper_rows4_range_simd, KernelBackend,
};
use mctm_coreset::linalg::{
    panel_accum_t1_scalar, panel_accum_t_scalar, panel_matvec_scalar,
    syrk_upper_row1_range_scalar, syrk_upper_rows4_range_scalar, Mat,
};
use mctm_coreset::mctm::conditional::{
    cond_nll_grad_reference, cond_nll_grad_with, CondDesign, CondSpec,
};
use mctm_coreset::mctm::{nll_grad_with, ModelSpec, Params};
use mctm_coreset::util::parallel::Pool;
use mctm_coreset::util::rng::Rng;

const REL_TOL: f64 = 1e-12;

fn assert_close(a: &[f64], b: &[f64], tag: &str) {
    assert_eq!(a.len(), b.len(), "{tag}: length");
    for (k, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= REL_TOL * y.abs().max(1.0),
            "{tag}[{k}]: {x} vs {y}"
        );
    }
}

fn randv(rng: &mut Rng, n: usize) -> Vec<f64> {
    (0..n).map(|_| rng.normal()).collect()
}

/// The ambient backend the process would resolve on its own — used to
/// restore global state after the toggling test.
fn ambient_backend() -> KernelBackend {
    if let Ok(v) = std::env::var("MCTM_SIMD") {
        let v = v.trim().to_ascii_lowercase();
        if matches!(v.as_str(), "off" | "0" | "false" | "scalar") {
            return KernelBackend::Scalar;
        }
    }
    if simd_available() {
        KernelBackend::Simd
    } else {
        KernelBackend::Scalar
    }
}

#[test]
fn panel_matvec_simd_agrees_with_scalar() {
    if !simd_available() {
        return;
    }
    let mut rng = Rng::new(101);
    // row counts exercise every 4-block/remainder split, d both below
    // and above a lane width, incl. d % 4 ≠ 0
    for (rows, d) in [(1usize, 3usize), (2, 8), (5, 4), (7, 5), (16, 12), (33, 11), (130, 6)] {
        let panel = randv(&mut rng, rows * d);
        let v = randv(&mut rng, d);
        let mut out_s = vec![0.0; rows];
        let mut out_v = vec![0.0; rows];
        panel_matvec_scalar(&panel, d, &v, &mut out_s);
        panel_matvec_simd(&panel, d, &v, &mut out_v);
        assert_close(&out_v, &out_s, &format!("matvec {rows}x{d}"));
        // internally deterministic: same inputs ⇒ bitwise-same
        let mut out_v2 = vec![0.0; rows];
        panel_matvec_simd(&panel, d, &v, &mut out_v2);
        for (a, b) in out_v.iter().zip(&out_v2) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}

#[test]
fn panel_accum_t_simd_agrees_with_scalar() {
    if !simd_available() {
        return;
    }
    let mut rng = Rng::new(102);
    for (rows, d) in [(1usize, 5usize), (3, 4), (6, 9), (21, 7), (64, 13)] {
        let a = randv(&mut rng, rows * d);
        let b = randv(&mut rng, rows * d);
        let ca = randv(&mut rng, rows);
        let cad = randv(&mut rng, rows);
        let init = randv(&mut rng, d); // nonzero starting accumulator
        let mut acc_s = init.clone();
        let mut acc_v = init.clone();
        panel_accum_t_scalar(&a, &b, d, &ca, &cad, &mut acc_s);
        panel_accum_t_simd(&a, &b, d, &ca, &cad, &mut acc_v);
        assert_close(&acc_v, &acc_s, &format!("accum_t {rows}x{d}"));
    }
}

#[test]
fn panel_accum_t1_simd_agrees_with_scalar() {
    if !simd_available() {
        return;
    }
    let mut rng = Rng::new(103);
    for (rows, d) in [(1usize, 2usize), (4, 6), (10, 3), (19, 8), (57, 5)] {
        let p = randv(&mut rng, rows * d);
        let c = randv(&mut rng, rows);
        let init = randv(&mut rng, d);
        let mut acc_s = init.clone();
        let mut acc_v = init.clone();
        panel_accum_t1_scalar(&p, d, &c, &mut acc_s);
        panel_accum_t1_simd(&p, d, &c, &mut acc_v);
        assert_close(&acc_v, &acc_s, &format!("accum_t1 {rows}x{d}"));
    }
}

#[test]
fn syrk_simd_agrees_with_scalar_and_is_tile_stable() {
    if !simd_available() {
        return;
    }
    let mut rng = Rng::new(104);
    let d = 23; // odd width: remainder lanes in every tile
    let rows: Vec<Vec<f64>> = (0..4).map(|_| randv(&mut rng, d)).collect();
    let mut zero_row = randv(&mut rng, d);
    zero_row[5] = 0.0; // exercise the zero-skip predicate
    // full-width update
    let mut g_s = vec![0.0; d * d];
    let mut g_v = vec![0.0; d * d];
    syrk_upper_rows4_range_scalar(&rows[0], &rows[1], &rows[2], &rows[3], 0..d, 0..d, &mut g_s);
    syrk_upper_row1_range_scalar(&zero_row, 0..d, 0..d, &mut g_s);
    syrk_upper_rows4_range_simd(&rows[0], &rows[1], &rows[2], &rows[3], 0..d, 0..d, &mut g_v);
    syrk_upper_row1_range_simd(&zero_row, 0..d, 0..d, &mut g_v);
    assert_close(&g_v, &g_s, "syrk full");
    // tile-grouping stability: replaying the same update per (i, j)
    // tile must reproduce the full-width SIMD result bit for bit (the
    // property the L2-tiled Gram relies on — the scalar remainder of
    // the SIMD kernel chains the exact same FMAs as the vector lanes)
    let tile = 5;
    let ntiles = d.div_ceil(tile);
    let mut g_t = vec![0.0; d * d];
    for it in 0..ntiles {
        let ir = it * tile..((it + 1) * tile).min(d);
        for jt in it..ntiles {
            let jr = jt * tile..((jt + 1) * tile).min(d);
            syrk_upper_rows4_range_simd(
                &rows[0], &rows[1], &rows[2], &rows[3], ir.clone(), jr.clone(), &mut g_t,
            );
            syrk_upper_row1_range_simd(&zero_row, ir.clone(), jr, &mut g_t);
        }
    }
    for (k, (a, b)) in g_t.iter().zip(&g_v).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "tiled syrk entry {k}");
    }
}

fn random_design(n: usize, j: usize, d: usize, seed: u64) -> Design {
    let mut rng = Rng::new(seed);
    let data = Mat::from_vec(n, j, (0..n * j).map(|_| rng.normal()).collect());
    Design::build(&data, d, 0.01)
}

/// The one test that toggles the process-global dispatch: pin Scalar,
/// record NLL/grad/leverage and the conditional blocked-vs-reference
/// bitwise identity, then flip to Simd and require ≤ 1e-12 relative
/// agreement on everything — including masked zero-weight rows and a
/// dJ ≥ 80 design that drives the L2-tiled Gram.
#[test]
fn backend_toggle_end_to_end() {
    use mctm_coreset::coreset::leverage::mctm_leverage_scores_with;
    let pool = Pool::new(2);
    let n = 2_300;
    let design = random_design(n, 3, 6, 201);
    let wide = random_design(500, 10, 9, 202); // dJ = 90 ⇒ tiled Gram
    let spec = ModelSpec::new(3, 6);
    let mut rng = Rng::new(203);
    let params = Params::new(
        spec,
        (0..spec.n_params()).map(|_| 0.3 * rng.normal()).collect(),
    );
    let mut w: Vec<f64> = (0..n).map(|_| rng.uniform(0.5, 2.0)).collect();
    w[17] = 0.0;
    w[2200] = 0.0; // masked rows in both chunks

    // conditional problem
    let q = 2;
    let x = Mat::from_vec(n, q, (0..n * q).map(|_| rng.normal()).collect());
    let y = Mat::from_vec(n, 2, (0..n * 2).map(|_| rng.normal()).collect());
    let cspec = CondSpec::new(2, 5, q);
    let cd = CondDesign::build(&y, &x, 5, 0.01);
    let cparams: Vec<f64> = (0..cspec.n_params()).map(|_| 0.3 * rng.normal()).collect();

    simd::set_backend(KernelBackend::Scalar);
    let (v_s, g_s) = nll_grad_with(&design, &w, &params, &pool);
    let lev_s = mctm_leverage_scores_with(&design, &pool).unwrap();
    let lev_wide_s = mctm_leverage_scores_with(&wide, &pool).unwrap();
    let (cv_s, cg_s) = cond_nll_grad_with(&cd, &w, cspec, &cparams, &pool);
    // on the Scalar backend the blocked conditional kernel must equal
    // the retained row-at-a-time reference bit for bit
    let (cv_r, cg_r) = cond_nll_grad_reference(&cd, &w, cspec, &cparams);
    assert_eq!(cv_s.to_bits(), cv_r.to_bits(), "cond value vs reference");
    for (k, (a, b)) in cg_s.iter().zip(&cg_r).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "cond grad {k} vs reference");
    }

    if simd_available() {
        simd::set_backend(KernelBackend::Simd);
        let (v_v, g_v) = nll_grad_with(&design, &w, &params, &pool);
        assert!(
            (v_v - v_s).abs() <= REL_TOL * v_s.abs().max(1.0),
            "nll: {v_v} vs {v_s}"
        );
        assert_close(&g_v, &g_s, "nll grad");
        let lev_v = mctm_leverage_scores_with(&design, &pool).unwrap();
        assert_close(&lev_v, &lev_s, "leverage");
        let lev_wide_v = mctm_leverage_scores_with(&wide, &pool).unwrap();
        assert_close(&lev_wide_v, &lev_wide_s, "leverage dJ=90");
        let (cv_v, cg_v) = cond_nll_grad_with(&cd, &w, cspec, &cparams, &pool);
        assert!(
            (cv_v - cv_s).abs() <= REL_TOL * cv_s.abs().max(1.0),
            "cond nll: {cv_v} vs {cv_s}"
        );
        assert_close(&cg_v, &cg_s, "cond grad");
        // internal determinism on Simd: repeat ⇒ bitwise-same
        let (cv_v2, cg_v2) = cond_nll_grad_with(&cd, &w, cspec, &cparams, &pool);
        assert_eq!(cv_v.to_bits(), cv_v2.to_bits());
        for (a, b) in cg_v.iter().zip(&cg_v2) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
    simd::set_backend(ambient_backend());
}
